"""Make `python/` importable when pytest runs from the repo root
(`pytest python/tests/` and `cd python && pytest tests/` both work)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
