"""Golden logits generator for the served CNN classifier (``crate::nn``).

``rust/src/nn/mod.rs`` builds a small int8-quantized classifier from a
seeded weight set and serves it through the coordinator under per-layer
approximation plans; ``rust/tests/nn_infer.rs`` pins the network's
output logits to literals produced by this script (the repo's
no-toolchain validation discipline: run twice, byte-identical).

The script is a line-for-line mirror of the Rust subsystem:

* weights — the shared xorshift64 stream (shifts 13/7/17, state seeded
  ``seed | 1``), each value ``(next & 127) - 64``, one distinct seed per
  GEMM-bearing layer;
* eval batch — ``image.scene(16, 16)`` plus ``image.texture(16, 16,
  0x5EED0 + i)`` (both already bit-exact mirrors of the Rust
  generators), centered by -128;
* graph — conv1 3x3 1->4 SAME s1 shift7, maxpool 2x2 s2, conv2 3x3
  4->8 SAME s2 shift7, conv3 3x3 8->8 VALID s1 shift7, dense1 32->16
  shift6 + relu, dense2 16->10 shift8; convs requantize via the bdcn
  idiom (round-shift then clip to [0, 127]), dense layers round-shift
  then clip to [-128, 127];
* arithmetic — exact layers are plain integer matmuls (the k = 0 word
  model is exact for these operand ranges), approximate layers run
  through :func:`ref.matmul_scalar` (proposed family, n = 8, W = 24) —
  the normative mirror of the Rust word kernel.

Two plans are pinned: ``exact`` (every layer k = 0) and the default
``mixed`` plan (exact first/last, interior at proposed k = 4 / 6 / 5 —
``nn::InferPlan::mixed_default``).  Run it directly:

    python3 -m compile.kernels.cnn_goldens        (from python/)
    python3 python/compile/kernels/cnn_goldens.py (from the repo root)
"""
from __future__ import annotations

import sys

import numpy as np

if __package__ in (None, ""):
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import image  # type: ignore
    import kernels.ref as ref  # type: ignore
else:
    from .. import image
    from . import ref

N, W = 8, 24
INPUT_SIDE = 16
N_CLASSES = 10
BATCH = 4
MASK64 = (1 << 64) - 1

# (name, seed, length) per GEMM-bearing layer — must match
# nn::Network::seeded() exactly, in execution order.
WEIGHTS = [
    ("conv1", 0xD1CE01, 3 * 3 * 1 * 4),
    ("conv2", 0xD1CE11, 3 * 3 * 4 * 8),
    ("conv3", 0xD1CE21, 3 * 3 * 8 * 8),
    ("dense1", 0xD1CE31, 32 * 16),
    ("dense2", 0xD1CE41, 16 * 10),
]

# per-GEMM-layer approximation level per pinned plan (0 = exact);
# mixed mirrors nn::InferPlan::mixed_default / nn::MIXED_KS
PLANS = [("EXACT", [0, 0, 0, 0, 0]), ("MIXED", [0, 4, 6, 5, 0])]


def seeded_weights(seed: int, n: int) -> np.ndarray:
    """Mirror of bench::XorShift + nn::seeded_weights."""
    x = (seed | 1) & MASK64
    out = np.empty(n, dtype=np.int64)
    for i in range(n):
        x = (x ^ (x << 13)) & MASK64
        x ^= x >> 7
        x = (x ^ (x << 17)) & MASK64
        out[i] = (x & 127) - 64
    return out


def im2col(x: np.ndarray, kh: int, kw: int, stride: int,
           pad: bool) -> np.ndarray:
    """Mirror of apps::im2col::im2col on an (h, w, cin) input."""
    h, w, cin = x.shape
    ph, pw = (kh // 2, kw // 2) if pad else (0, 0)
    if pad:
        oh, ow = -(-h // stride), -(-w // stride)
    else:
        oh, ow = (h - kh) // stride + 1, (w - kw) // stride + 1
    feat = kh * kw * cin
    mat = np.zeros((oh * ow, feat), dtype=np.int64)
    for dy in range(kh):
        for dx in range(kw):
            for y in range(oh):
                sy = y * stride + dy - ph
                if sy < 0 or sy >= h:
                    continue
                for xx in range(ow):
                    sx = xx * stride + dx - pw
                    if sx < 0 or sx >= w:
                        continue
                    t = (dy * kw + dx) * cin
                    mat[y * ow + xx, t:t + cin] = x[sy, sx]
    return mat


def gemm(a: np.ndarray, b: np.ndarray, k: int) -> np.ndarray:
    """Exact integer matmul at k = 0, proposed-PE word model otherwise."""
    if k == 0:
        return a.astype(np.int64) @ b.astype(np.int64)
    return ref.matmul_scalar(a, b, k, n=N, w=W, signed=True,
                             family="proposed")


def requant(v: np.ndarray, shift: int) -> np.ndarray:
    """bdcn::requant — ReLU-fused int8 requantization."""
    return np.clip((v + (1 << (shift - 1))) >> shift, 0, 127)


def rshift_round_clip8(v: np.ndarray, shift: int) -> np.ndarray:
    """apps::rshift_round + apps::clip8 — signed int8 requantization."""
    return np.clip((v + (1 << (shift - 1))) >> shift, -128, 127)


def maxpool(x: np.ndarray, k: int, stride: int) -> np.ndarray:
    """VALID channel-wise max-pooling on an (h, w, cin) input."""
    h, w, cin = x.shape
    oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
    out = np.zeros((oh, ow, cin), dtype=np.int64)
    for y in range(oh):
        for xx in range(ow):
            win = x[y * stride:y * stride + k, xx * stride:xx * stride + k]
            out[y, xx] = win.reshape(-1, cin).max(axis=0)
    return out


def forward(img: np.ndarray, ks: list[int],
            wts: dict[str, np.ndarray]) -> np.ndarray:
    """One image through the graph at per-layer levels ``ks``."""
    x = img.astype(np.int64).reshape(INPUT_SIDE, INPUT_SIDE, 1) - 128

    def conv(x, name, cin, cout, stride, pad, shift, k):
        mat = im2col(x, 3, 3, stride, pad)
        y = gemm(mat, wts[name].reshape(3 * 3 * cin, cout), k)
        oh = int(round(np.sqrt(y.shape[0])))  # all convs here are square
        return requant(y, shift).reshape(oh, -1, cout)

    x = conv(x, "conv1", 1, 4, 1, True, 7, ks[0])       # 16x16x4
    x = maxpool(x, 2, 2)                                # 8x8x4
    x = conv(x, "conv2", 4, 8, 2, True, 7, ks[1])       # 4x4x8
    x = conv(x, "conv3", 8, 8, 1, False, 7, ks[2])      # 2x2x8
    a = x.reshape(1, -1)                                # flatten 32
    a = rshift_round_clip8(gemm(a, wts["dense1"].reshape(32, 16), ks[3]), 6)
    a = np.maximum(a, 0)                                # relu
    a = rshift_round_clip8(gemm(a, wts["dense2"].reshape(16, 10), ks[4]), 8)
    return a.reshape(N_CLASSES)


def eval_batch() -> list[np.ndarray]:
    """Mirror of nn::eval_batch(BATCH)."""
    return [image.scene(INPUT_SIDE, INPUT_SIDE) if i == 0 else
            image.texture(INPUT_SIDE, INPUT_SIDE, 0x5EED0 + i)
            for i in range(BATCH)]


def main() -> None:
    wts = {name: seeded_weights(seed, n) for name, seed, n in WEIGHTS}
    for name, _, _ in WEIGHTS:
        lo, hi = int(wts[name].min()), int(wts[name].max())
        assert -64 <= lo and hi <= 63, f"{name} weight range [{lo},{hi}]"

    # spot-check: the k = 0 PE path equals the plain integer matmul on
    # real layer operands (no W = 24 wrap at these ranges)
    batch = eval_batch()
    x0 = batch[0].astype(np.int64).reshape(INPUT_SIDE, INPUT_SIDE, 1) - 128
    mat = im2col(x0, 3, 3, 1, True)[:8]
    b0 = wts["conv1"].reshape(9, 4)
    assert np.array_equal(ref.matmul_scalar(mat, b0, 0, n=N, w=W,
                                            signed=True, family="proposed"),
                          mat @ b0), "k=0 PE != exact matmul"
    print("spot-check OK: k=0 matmul_scalar == exact matmul",
          file=sys.stderr)

    print("// Generated by python/compile/kernels/cnn_goldens.py — "
          "do not hand-edit.")
    print(f"// batch {BATCH}: scene(16,16) + texture(16,16, 0x5EED0+i); "
          "plans: exact, mixed [0,4,6,5,0] (proposed)")
    results = {}
    for plan, ks in PLANS:
        logits = np.concatenate([forward(img, ks, wts) for img in batch])
        results[plan] = logits
        vals = ", ".join(str(int(v)) for v in logits)
        print(f"pub const {plan}_LOGITS: [i64; {BATCH * N_CLASSES}] = "
              f"[{vals}];")
        top1 = [int(np.argmax(logits[b * N_CLASSES:(b + 1) * N_CLASSES]))
                for b in range(BATCH)]
        print(f"// {plan.lower()} top-1 per image: {top1}")
    for plan in ("EXACT", "MIXED"):
        lo, hi = int(results[plan].min()), int(results[plan].max())
        assert -128 <= lo and hi <= 127, f"{plan} logits [{lo},{hi}]"
    match = sum(int(np.argmax(results["EXACT"][b * 10:(b + 1) * 10]) ==
                    np.argmax(results["MIXED"][b * 10:(b + 1) * 10]))
                for b in range(BATCH))
    print(f"// mixed-vs-exact top-1 agreement: {match}/{BATCH}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
