"""Oracle validation of the 64-lane transposed bit-plane word kernel.

``rust/src/gemm/lanes.rs`` evaluates 64 independent MAC chains per u64
bit-plane (bit ``l`` of plane ``i`` = bit ``i`` of lane ``l``'s
carry-save rail). This script is a line-for-line transcription of that
kernel into Python and a differential test against :func:`ref.mac_scalar`
— the same oracle that pins the scalar Rust word model. Run it directly:

    python3 -m compile.kernels.lanes_check        (from python/)
    python3 python/compile/kernels/lanes_check.py (from the repo root)

It exercises every family x signedness x k (including k > n clamps) over
randomized multi-step chains and fails loudly on the first mismatching
lane/plane. No JAX required — pure ints, like the scalar oracle.

Since the metered fast path fused energy accounting into the lane
kernel, the script also validates the fused meter's *structure*: the
per-lane pre-step windows it charges (the k-bit low region of each
lane's carry-save rails, gathered from the shared bit planes) must
stream identically to the windows of the scalar reference walk. A
deterministic synthetic per-MAC energy function stands in for the
technology table so per-lane energy sums compare with exact integer
equality, and the fixed-seed grand total is pinned as a golden — any
drift in lane packing, window extraction, or charge ordering moves it.
(Real-fJ agreement between the fused and scalar Rust meters is pinned
separately by `rust/tests/energy_model.rs` / `prop_equiv.rs`.)
"""
from __future__ import annotations

import random
import sys

if __package__ in (None, ""):
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import kernels.ref as ref  # type: ignore
else:
    from . import ref

LANES = 64
M64 = (1 << LANES) - 1


def _bcast(bit: int) -> int:
    """Broadcast a single bit across all 64 lanes."""
    return M64 if bit else 0


def lane_mac64(a: int, b_planes: list[int], sp: list[int], kp: list[int],
               k: int, n: int, w: int, signed: bool, family: str) -> None:
    """One fused MAC across 64 lanes — mirrors ``LanePlan::mac64``.

    ``a`` is the broadcast A encoding; ``b_planes[j]`` carries bit ``j``
    of each lane's B encoding; ``sp``/``kp`` are the w sum/carry planes,
    updated in place.
    """
    au = a & ((1 << n) - 1)
    amask = (1 << k) - 1
    bw = ref.bw_const(n, w) if signed else 0
    # kc += bw: bit-serial ripple add of the broadcast constant
    if bw:
        carry = 0
        for i in range(w):
            bb = _bcast((bw >> i) & 1)
            old = kp[i]
            kp[i] = old ^ bb ^ carry
            carry = (old & bb) | (old & carry) | (bb & carry)
    for j in range(n):
        span = (((1 << n) - 1) << j) & ((1 << w) - 1)
        nm = ref.nppc_mask(n, j, signed)
        aa = span & amask
        lo, hi = j, min(j + n, w)
        sel = b_planes[j]
        c_out = [0] * w
        for i in range(lo, hi):
            abit = _bcast((au >> (i - j)) & 1)
            p = sel & abit
            x = (p ^ _bcast((nm >> i) & 1)) & M64
            s, kc = sp[i], kp[i]
            if not (aa >> i) & 1:  # exact 3:2 compressor plane
                s2 = x ^ s ^ kc
                c = (x & s) | (x & kc) | (s & kc)
            elif family == "proposed":
                osk = s | kc
                if not (nm >> i) & 1:
                    s2, c = osk & ~x & M64, x
                else:
                    s2, c = (~osk | ~x) & M64, osk & x
            elif family == "axsa5":
                s2, c = x ^ s ^ kc, 0
            elif family == "sips12":
                s2, c = ~(x ^ s) & M64, kc
            elif family == "nano6":
                s2, c = ~s & M64, x & kc
            elif family == "trunc":
                t = x ^ p  # drop the product: the nm tie-off alone remains
                s2 = t ^ s ^ kc
                c = (t & s) | (t & kc) | (s & kc)
            elif family == "loa":
                s2, c = (x | s) & M64, kc
            else:
                raise ValueError(family)
            sp[i] = s2 & M64
            c_out[i] = c & M64
        # kc = (carries << 1) + (kc outside the span): ripple from lo up
        carry = 0
        for i in range(lo, w):
            add = c_out[i - 1] if (lo < i <= hi) else 0
            passthru = kp[i] if i >= hi else 0
            kp[i] = add ^ passthru ^ carry
            carry = (add & passthru) | (add & carry) | (passthru & carry)


def lane_get(planes: list[int], l: int) -> int:
    return sum(((p >> l) & 1) << i for i, p in enumerate(planes))


def synth_fj(win_s: int, win_kc: int, a: int, b: int) -> int:
    """Deterministic synthetic per-MAC energy (integer, exact).

    Stands in for the ``EnergyLut`` state-major table read: any change
    to the pre-step window or operand encodings changes the value, so
    summed charges only agree when the fused walk reads the identical
    (window, a, b) stream as the scalar reference walk.
    """
    return (win_s * 1000003 ^ win_kc * 8191 ^ a * 131 ^ b) & 0xFFFFFFFF


def lane_window(sp: list[int], kp: list[int], l: int, kb: int) -> tuple[int, int]:
    """Lane ``l``'s pre-step automaton window: the ``kb`` low rail bits
    gathered from the shared planes — exactly what the fused meter
    charges before each ``mac64`` step."""
    ws = sum(((sp[i] >> l) & 1) << i for i in range(kb))
    wk = sum(((kp[i] >> l) & 1) << i for i in range(kb))
    return ws, wk


def lane_set(planes: list[int], l: int, v: int) -> None:
    for i in range(len(planes)):
        planes[i] = (planes[i] & ~(1 << l)) | (((v >> i) & 1) << l)


def check_point(rng: random.Random, k: int, n: int, w: int, signed: bool,
                family: str, steps: int = 5) -> int:
    """Differential chain check of one design point; returns the summed
    per-lane synthetic energy (for the golden grand total)."""
    kb = min(k, w)
    kmask = (1 << kb) - 1
    sp, kp = [0] * w, [0] * w
    s = [rng.getrandbits(w) for _ in range(LANES)]
    kc = [rng.getrandbits(w) for _ in range(LANES)]
    fused_e = [0] * LANES
    scalar_e = [0] * LANES
    for l in range(LANES):
        lane_set(sp, l, s[l])
        lane_set(kp, l, kc[l])
    for step in range(steps):
        a = rng.getrandbits(n)
        bs = [rng.getrandbits(n) for _ in range(LANES)]
        b_planes = [sum(((bs[l] >> j) & 1) << l for l in range(LANES))
                    for j in range(n)]
        # fused meter: charge every lane its pre-step window energy from
        # the shared planes, THEN run the compute step — the order the
        # Rust kernel uses (EnergyLut::mac_fj_lanes before mac64)
        for l in range(LANES):
            ws, wk = lane_window(sp, kp, l, kb)
            fused_e[l] += synth_fj(ws, wk, a, bs[l])
        lane_mac64(a, b_planes, sp, kp, k, n, w, signed, family)
        for l in range(LANES):
            # scalar reference meter: same pre-step convention on the
            # lane's private rails
            scalar_e[l] += synth_fj(s[l] & kmask, kc[l] & kmask, a, bs[l])
            s[l], kc[l] = ref.mac_scalar(a, bs[l], s[l], kc[l], k, n, w,
                                         signed, family)
            got = (lane_get(sp, l), lane_get(kp, l))
            if got != (s[l], kc[l]):
                raise SystemExit(
                    f"MISMATCH {family} n={n} k={k} signed={signed} "
                    f"step={step} lane={l}: lane={got} scalar={(s[l], kc[l])}")
    for l in range(LANES):
        if fused_e[l] != scalar_e[l]:
            raise SystemExit(
                f"ENERGY MISMATCH {family} n={n} k={k} signed={signed} "
                f"lane={l}: fused={fused_e[l]} scalar={scalar_e[l]}")
    return sum(fused_e)


#: Golden grand total of the per-lane synthetic energy sums over the
#: whole fixed-seed sweep. Deterministic: any change to lane packing,
#: window extraction, charge ordering, or the PRNG draw order moves it.
GOLDEN_ENERGY_SUM = 25235898928358


def main() -> None:
    rng = random.Random(20260808)
    points = 0
    energy_sum = 0
    for family in ref.FAMILIES:
        for signed in (False, True):
            for n, w in ((8, 24), (16, 40), (4, 16)):
                for k in (0, 1, 3, n, n + 4):
                    energy_sum += check_point(rng, k, n, w, signed, family)
                    points += 1
    if energy_sum != GOLDEN_ENERGY_SUM:
        raise SystemExit(f"ENERGY GOLDEN DRIFT: sweep total {energy_sum} "
                         f"!= pinned {GOLDEN_ENERGY_SUM}")
    print(f"lane kernel == scalar oracle on {points} design points "
          f"x {LANES} lanes (per-lane energy sums exact): OK")


if __name__ == "__main__":
    main()
