"""Oracle validation of the 64-lane transposed bit-plane word kernel.

``rust/src/gemm/lanes.rs`` evaluates 64 independent MAC chains per u64
bit-plane (bit ``l`` of plane ``i`` = bit ``i`` of lane ``l``'s
carry-save rail). This script is a line-for-line transcription of that
kernel into Python and a differential test against :func:`ref.mac_scalar`
— the same oracle that pins the scalar Rust word model. Run it directly:

    python3 -m compile.kernels.lanes_check        (from python/)
    python3 python/compile/kernels/lanes_check.py (from the repo root)

It exercises every family x signedness x k (including k > n clamps) over
randomized multi-step chains and fails loudly on the first mismatching
lane/plane. No JAX required — pure ints, like the scalar oracle.
"""
from __future__ import annotations

import random
import sys

if __package__ in (None, ""):
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import kernels.ref as ref  # type: ignore
else:
    from . import ref

LANES = 64
M64 = (1 << LANES) - 1


def _bcast(bit: int) -> int:
    """Broadcast a single bit across all 64 lanes."""
    return M64 if bit else 0


def lane_mac64(a: int, b_planes: list[int], sp: list[int], kp: list[int],
               k: int, n: int, w: int, signed: bool, family: str) -> None:
    """One fused MAC across 64 lanes — mirrors ``LanePlan::mac64``.

    ``a`` is the broadcast A encoding; ``b_planes[j]`` carries bit ``j``
    of each lane's B encoding; ``sp``/``kp`` are the w sum/carry planes,
    updated in place.
    """
    au = a & ((1 << n) - 1)
    amask = (1 << k) - 1
    bw = ref.bw_const(n, w) if signed else 0
    # kc += bw: bit-serial ripple add of the broadcast constant
    if bw:
        carry = 0
        for i in range(w):
            bb = _bcast((bw >> i) & 1)
            old = kp[i]
            kp[i] = old ^ bb ^ carry
            carry = (old & bb) | (old & carry) | (bb & carry)
    for j in range(n):
        span = (((1 << n) - 1) << j) & ((1 << w) - 1)
        nm = ref.nppc_mask(n, j, signed)
        aa = span & amask
        lo, hi = j, min(j + n, w)
        sel = b_planes[j]
        c_out = [0] * w
        for i in range(lo, hi):
            abit = _bcast((au >> (i - j)) & 1)
            p = sel & abit
            x = (p ^ _bcast((nm >> i) & 1)) & M64
            s, kc = sp[i], kp[i]
            if not (aa >> i) & 1:  # exact 3:2 compressor plane
                s2 = x ^ s ^ kc
                c = (x & s) | (x & kc) | (s & kc)
            elif family == "proposed":
                osk = s | kc
                if not (nm >> i) & 1:
                    s2, c = osk & ~x & M64, x
                else:
                    s2, c = (~osk | ~x) & M64, osk & x
            elif family == "axsa5":
                s2, c = x ^ s ^ kc, 0
            elif family == "sips12":
                s2, c = ~(x ^ s) & M64, kc
            elif family == "nano6":
                s2, c = ~s & M64, x & kc
            elif family == "trunc":
                t = x ^ p  # drop the product: the nm tie-off alone remains
                s2 = t ^ s ^ kc
                c = (t & s) | (t & kc) | (s & kc)
            elif family == "loa":
                s2, c = (x | s) & M64, kc
            else:
                raise ValueError(family)
            sp[i] = s2 & M64
            c_out[i] = c & M64
        # kc = (carries << 1) + (kc outside the span): ripple from lo up
        carry = 0
        for i in range(lo, w):
            add = c_out[i - 1] if (lo < i <= hi) else 0
            passthru = kp[i] if i >= hi else 0
            kp[i] = add ^ passthru ^ carry
            carry = (add & passthru) | (add & carry) | (passthru & carry)


def lane_get(planes: list[int], l: int) -> int:
    return sum(((p >> l) & 1) << i for i, p in enumerate(planes))


def lane_set(planes: list[int], l: int, v: int) -> None:
    for i in range(len(planes)):
        planes[i] = (planes[i] & ~(1 << l)) | (((v >> i) & 1) << l)


def check_point(rng: random.Random, k: int, n: int, w: int, signed: bool,
                family: str, steps: int = 5) -> None:
    sp, kp = [0] * w, [0] * w
    s = [rng.getrandbits(w) for _ in range(LANES)]
    kc = [rng.getrandbits(w) for _ in range(LANES)]
    for l in range(LANES):
        lane_set(sp, l, s[l])
        lane_set(kp, l, kc[l])
    for step in range(steps):
        a = rng.getrandbits(n)
        bs = [rng.getrandbits(n) for _ in range(LANES)]
        b_planes = [sum(((bs[l] >> j) & 1) << l for l in range(LANES))
                    for j in range(n)]
        lane_mac64(a, b_planes, sp, kp, k, n, w, signed, family)
        for l in range(LANES):
            s[l], kc[l] = ref.mac_scalar(a, bs[l], s[l], kc[l], k, n, w,
                                         signed, family)
            got = (lane_get(sp, l), lane_get(kp, l))
            if got != (s[l], kc[l]):
                raise SystemExit(
                    f"MISMATCH {family} n={n} k={k} signed={signed} "
                    f"step={step} lane={l}: lane={got} scalar={(s[l], kc[l])}")


def main() -> None:
    rng = random.Random(20260808)
    points = 0
    for family in ref.FAMILIES:
        for signed in (False, True):
            for n, w in ((8, 24), (16, 40), (4, 16)):
                for k in (0, 1, 3, n, n + 4):
                    check_point(rng, k, n, w, signed, family)
                    points += 1
    print(f"lane kernel == scalar oracle on {points} design points "
          f"x {LANES} lanes: OK")


if __name__ == "__main__":
    main()
