"""Pure-jnp oracle for the approximate systolic-array PE (VLSID'26 repro).

This module is the **bit-exact reference semantics** for every layer of the
stack: the Rust PE model, the Rust netlist evaluation, and the Pallas kernel
in ``axmm.py`` are all required (and tested) to agree with it bit-for-bit.

PE microarchitecture (DESIGN.md §1/§3): an N x N grid of PPC/NPPC cells
folds the Baugh-Wooley partial products of ``a*b`` directly into a W-bit
carry-save accumulator ``(S, K)``.  Row ``j`` of the grid is one 3:2
compressor layer restricted to bit span ``[j, j+N)``; carries escaping the
top of a span are merged with an exact adder (the PE's small merge logic).
The ``k`` least-significant columns use *approximate* cells.

Cell families (paper Table I + reconstructed baselines, DESIGN.md §2):

* ``proposed`` — the paper's approximate PPC/NPPC (normative Table I):
    PPC : C = p,                S = (Sin|Cin) & ~p
    NPPC: C = (Sin|Cin) & ~p,   S = ~(Sin|Cin) | p      (p = a_i & b_j)
* ``axsa5``   — Waris et al. AxSA (TC'21) [5]: carry-elided compressor —
  exact 3-input XOR sum, carry output removed (C = 0).
* ``sips12``  — Waris et al. SiPS'19 [12]: XNOR-based inexact cell,
    S = ~(x ^ Sin), C = Cin.
* ``nano6``   — Chen/Lombardi NANOARCH'15 [6]: inexact cell,
    S = ~Sin, C = x & Cin.
* ``trunc``   — truncated partial products (zoo variant): the AND gate of
  an approximate column is dropped entirely (the cell sees ``x = nm``, the
  Baugh-Wooley complement tie-off alone) but the 3:2 compression stays
  exact.  Classic fixed-width truncated-multiplier behaviour.
* ``loa``     — lower-part OR adder (zoo variant, Mahdiani et al. LOA):
  approximate columns OR the incoming partial product into the sum rail,
    S = x | Sin, C = Cin (carry passes through, no generation).

The exact cells are full adders on ``p`` (PPC) / ``~p`` (NPPC); Baugh-Wooley
sign handling adds the width-W correction constant per multiplication.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FAMILIES = ("proposed", "axsa5", "sips12", "nano6", "trunc", "loa")

# Default widths: operand bits N, accumulator bits W (guard bits allow
# >= 2^(W-2N) accumulations without overflow).
DEF_N = 8
DEF_W = 24


def acc_width(n: int) -> int:
    """Default accumulator width for N-bit operands (8 guard bits)."""
    return 2 * n + 8


def bw_const(n: int, w: int) -> int:
    """Baugh-Wooley correction constant at accumulator width ``w``.

    ``a*b = grid_core + 2^N - 2^(2N-1)`` and ``-2^(2N-1) mod 2^W`` is ones
    on bits [2N-1, W).
    """
    return ((1 << n) | (((1 << (w - (2 * n - 1))) - 1) << (2 * n - 1))) & ((1 << w) - 1)


def nppc_mask(n: int, j: int, signed: bool) -> int:
    """Bit positions (absolute weights) of NPPC cells in row ``j``."""
    if not signed:
        return 0
    if j < n - 1:
        return 1 << (n - 1 + j)
    return ((1 << (n - 1)) - 1) << j


# ---------------------------------------------------------------------------
# Scalar (pure python int) model — mirrors rust/src/pe/word.rs exactly.
# Used for golden-vector generation and slow cross-checks.
# ---------------------------------------------------------------------------

def mac_scalar(a: int, b: int, s: int, kc: int, k: int, n: int = DEF_N,
               w: int = DEF_W, signed: bool = True,
               family: str = "proposed") -> tuple[int, int]:
    """One fused MAC folding ``a*b`` into carry-save accumulator (s, kc).

    ``a``/``b`` are N-bit encodings (two's complement for signed); the
    returned state satisfies ``resolve(s,kc) == old + a*b (mod 2^W)`` when
    the PE is exact (k == 0).
    """
    mw = (1 << w) - 1
    au = a & ((1 << n) - 1)
    s &= mw
    kc &= mw
    if signed:
        kc = (kc + bw_const(n, w)) & mw  # injected via grid tie-offs; bits
        # land above column N-1 >= k, i.e. always in the exact region.
    amask = (1 << k) - 1
    for j in range(n):
        span = (((1 << n) - 1) << j) & mw
        p = ((au << j) & mw) if ((b >> j) & 1) else 0
        nm = nppc_mask(n, j, signed)
        x = (p ^ nm) & mw
        aa = span & amask
        ee = span & ~amask & mw
        osk = s | kc
        if family == "proposed":
            ap, an = aa & ~nm, aa & nm
            s_a = ((osk & ~x) & ap) | (((~osk) | ~x) & an)
            c_a = (x & ap) | ((osk & x) & an)
            k_pass = 0
        elif family == "sips12":
            s_a = (~(x ^ s)) & aa
            c_a = kc & aa
            k_pass = 0
        elif family == "nano6":
            s_a = (~s) & aa
            c_a = (x & kc) & aa
            k_pass = 0
        elif family == "axsa5":
            s_a = (x ^ s ^ kc) & aa   # exact sum, carry elided
            c_a = 0
            k_pass = 0
        elif family == "trunc":
            # partial product dropped: cell input is nm alone, exact 3:2
            s_a = (nm ^ s ^ kc) & aa
            c_a = ((nm & s) | (nm & kc) | (s & kc)) & aa
            k_pass = 0
        elif family == "loa":
            s_a = (x | s) & aa        # OR-fold the product into the sum
            c_a = kc & aa             # carry passes, never generated
            k_pass = 0
        else:
            raise ValueError(f"unknown family {family!r}")
        s_e = (x ^ s ^ kc) & ee
        c_e = ((x & s) | (x & kc) | (s & kc)) & ee
        s = ((s_a | s_e) | (s & ~span)) & mw
        kc = (((((c_a | c_e) & mw) << 1) | k_pass) + (kc & ~span & mw)) & mw
    return s, kc


def resolve_scalar(s: int, kc: int, w: int = DEF_W) -> int:
    """Drain the carry-save accumulator to a signed integer."""
    v = (s + kc) & ((1 << w) - 1)
    return v - (1 << w) if v >= (1 << (w - 1)) else v


def mac_value_scalar(a: int, b: int, c: int, k: int, n: int = DEF_N,
                     w: int = DEF_W, signed: bool = True,
                     family: str = "proposed") -> int:
    """Full resolved ``a*b + c`` through the (possibly approximate) PE."""
    s, kc = mac_scalar(a & ((1 << n) - 1), b & ((1 << n) - 1),
                       c & ((1 << w) - 1), 0, k, n, w, signed, family)
    return resolve_scalar(s, kc, w)


def matmul_scalar(A, B, k: int, n: int = DEF_N, w: int = DEF_W,
                  signed: bool = True, family: str = "proposed"):
    """Reference integer matmul through the approximate PE (numpy, slow)."""
    A = np.asarray(A, dtype=np.int64)
    B = np.asarray(B, dtype=np.int64)
    m, kk = A.shape
    kk2, nn = B.shape
    assert kk == kk2
    out = np.zeros((m, nn), dtype=np.int64)
    mask = (1 << n) - 1
    for i in range(m):
        for jj in range(nn):
            s = kc = 0
            for t in range(kk):
                s, kc = mac_scalar(int(A[i, t]) & mask, int(B[t, jj]) & mask,
                                   s, kc, k, n, w, signed, family)
            out[i, jj] = resolve_scalar(s, kc, w)
    return out


# ---------------------------------------------------------------------------
# Vectorized jnp model — identical math on uint32 words (requires W <= 32).
# ---------------------------------------------------------------------------

def _u32(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.uint32)


def mac_step(a_enc, b_enc, s, kc, kmask, n: int = DEF_N, w: int = DEF_W,
             signed: bool = True, family: str = "proposed",
             inject: bool = True):
    """Vectorized fused MAC: fold ``a*b`` into carry-save state (s, kc).

    All arrays uint32 and broadcast-compatible; ``kmask = (1<<k)-1`` as a
    uint32 scalar (runtime approximation level).  Returns (s', kc').
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}")
    mw = _u32((1 << w) - 1)
    au = a_enc & _u32((1 << n) - 1)
    s = s & mw
    kc = kc & mw
    if signed and inject:
        kc = (kc + _u32(bw_const(n, w))) & mw
    for j in range(n):
        span = _u32((((1 << n) - 1) << j) & ((1 << w) - 1))
        bj = (b_enc >> _u32(j)) & _u32(1)
        p = jnp.where(bj != 0, (au << _u32(j)) & mw, _u32(0))
        nm = _u32(nppc_mask(n, j, signed))
        x = (p ^ nm) & mw
        aa = span & kmask
        ee = span & (~kmask) & mw
        osk = s | kc
        if family == "proposed":
            ap, an = aa & (~nm), aa & nm
            s_a = ((osk & ~x) & ap) | (((~osk) | (~x)) & an)
            c_a = (x & ap) | ((osk & x) & an)
            k_pass = _u32(0)
        elif family == "sips12":
            s_a = (~(x ^ s)) & aa
            c_a = kc & aa
            k_pass = _u32(0)
        elif family == "nano6":
            s_a = (~s) & aa
            c_a = (x & kc) & aa
            k_pass = _u32(0)
        elif family == "trunc":
            s_a = (nm ^ s ^ kc) & aa
            c_a = ((nm & s) | (nm & kc) | (s & kc)) & aa
            k_pass = _u32(0)
        elif family == "loa":
            s_a = (x | s) & aa
            c_a = kc & aa
            k_pass = _u32(0)
        else:  # axsa5: exact sum, carry elided
            s_a = (x ^ s ^ kc) & aa
            c_a = _u32(0)
            k_pass = _u32(0)
        s_e = (x ^ s ^ kc) & ee
        c_e = ((x & s) | (x & kc) | (s & kc)) & ee
        s = ((s_a | s_e) | (s & (~span))) & mw
        kc = (((((c_a | c_e) & mw) << _u32(1)) | k_pass) + (kc & (~span) & mw)) & mw
    return s, kc


def encode(v, n: int = DEF_N):
    """int array -> N-bit two's-complement encoding (uint32)."""
    return jnp.asarray(v, jnp.int32).astype(jnp.uint32) & _u32((1 << n) - 1)


def decode(v, w: int = DEF_W):
    """W-bit value (uint32) -> signed int32 via sign extension."""
    v = jnp.asarray(v, jnp.uint32) & _u32((1 << w) - 1)
    sign = v >> _u32(w - 1)
    ext = jnp.where(sign != 0, _u32((0xFFFFFFFF ^ ((1 << w) - 1)) & 0xFFFFFFFF),
                    _u32(0))
    return (v | ext).astype(jnp.int32)


def resolve(s, kc, w: int = DEF_W):
    """Drain carry-save state to signed int32 (exact W-bit adder)."""
    return decode((s + kc) & _u32((1 << w) - 1), w)


def kmask_of(k):
    """Runtime approximation level k -> column mask (1<<k)-1 as uint32."""
    return (_u32(1) << jnp.asarray(k, jnp.uint32)) - _u32(1)


def axmm_ref(A, B, k, n: int = DEF_N, w: int = DEF_W, signed: bool = True,
             family: str = "proposed"):
    """Approximate matmul oracle: int32 (M,K') @ (K',N') -> int32 (M,N').

    ``k`` may be a traced scalar (runtime approximation level).
    Pure jnp, untiled — the Pallas kernel in ``axmm.py`` must match this
    bit-for-bit.
    """
    A = jnp.asarray(A, jnp.int32)
    B = jnp.asarray(B, jnp.int32)
    m, kk = A.shape
    _, nn = B.shape
    kmask = kmask_of(k)
    ae = encode(A, n)   # (m, kk)
    be = encode(B, n)   # (kk, nn)
    s = jnp.zeros((m, nn), jnp.uint32)
    kc = jnp.zeros((m, nn), jnp.uint32)
    for t in range(kk):  # static unroll: kk is a trace-time constant
        s, kc = mac_step(ae[:, t:t + 1], be[t:t + 1, :], s, kc, kmask,
                         n, w, signed, family)
    return resolve(s, kc, w)


def exact_matmul(A, B):
    """Exact int32 oracle (what the k=0 PE must reproduce mod 2^W)."""
    return jnp.asarray(A, jnp.int32) @ jnp.asarray(B, jnp.int32)
