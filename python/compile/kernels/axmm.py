"""Pallas kernel: tiled bit-exact approximate matmul (Layer 1).

The hot-spot of the paper's system — GEMM through the approximate PE grid —
expressed as a Pallas kernel.  Cell semantics are imported from ``ref`` so
there is exactly one source of truth; what this file adds is the *schedule*:
an (M, N) output tiling whose blocks stream through VMEM via BlockSpec while
the K reduction runs as a ``fori_loop`` of word-level bit-plane updates.

Hardware adaptation (DESIGN.md §5): the paper targets an ASIC systolic
array.  On a TPU-shaped machine the same insight — approximation as cheaper
bit-plane arithmetic — maps each partial-product row to full-width VPU
bitwise ops over the packed uint32 accumulator planes, with the output tile
resident in VMEM.  ``interpret=True`` everywhere: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md), so real-TPU
performance is estimated analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import ref

# Default output tile. 32x32 int32/uint32 state = 5 planes * 4 KiB = 20 KiB
# of VMEM per tile (a, b slices + s/kc/out), far under the ~16 MiB budget;
# chosen so the bit-plane ops stay on full (8,128)-lane registers.
DEF_BM = 32
DEF_BN = 32


def _kernel(ae_ref, be_ref, km_ref, o_ref, *, kk: int, n: int, w: int,
            signed: bool, family: str):
    """One (bm, bn) output tile: carry-save fold over the K dimension."""
    kmask = km_ref[0, 0]
    bm, bn = o_ref.shape
    s0 = jnp.zeros((bm, bn), jnp.uint32)
    k0 = jnp.zeros((bm, bn), jnp.uint32)

    def body(t, carry):
        s, kc = carry
        a_col = ae_ref[:, pl.dslice(t, 1)]   # (bm, 1)
        b_row = be_ref[pl.dslice(t, 1), :]   # (1, bn)
        return ref.mac_step(a_col, b_row, s, kc, kmask, n, w, signed, family)

    s, kc = lax.fori_loop(0, kk, body, (s0, k0))
    o_ref[...] = ref.resolve(s, kc, w)


@functools.partial(jax.jit, static_argnames=(
    "n", "w", "signed", "family", "bm", "bn"))
def axmm(A, B, k, n: int = ref.DEF_N, w: int = ref.DEF_W, signed: bool = True,
         family: str = "proposed", bm: int = DEF_BM, bn: int = DEF_BN):
    """Approximate matmul ``A @ B`` through the paper's PE, Pallas-tiled.

    A: int32 (M, K'), B: int32 (K', N'), k: runtime approximation level
    (number of approximate LSB columns).  Bit-identical to ``ref.axmm_ref``.
    """
    A = jnp.asarray(A, jnp.int32)
    B = jnp.asarray(B, jnp.int32)
    m, kk = A.shape
    kb, nn = B.shape
    assert kk == kb, f"inner dims mismatch: {kk} vs {kb}"
    bm = min(bm, m)
    bn = min(bn, nn)
    # pad M, N up to tile multiples (padded rows/cols sliced off below)
    mp = (m + bm - 1) // bm * bm
    np_ = (nn + bn - 1) // bn * bn
    ae = ref.encode(A, n)
    be = ref.encode(B, n)
    if mp != m:
        ae = jnp.pad(ae, ((0, mp - m), (0, 0)))
    if np_ != nn:
        be = jnp.pad(be, ((0, 0), (0, np_ - nn)))
    km = ref.kmask_of(k).reshape(1, 1)
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, kk=kk, n=n, w=w, signed=signed,
                          family=family),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kk), lambda i, j: (i, 0)),
            pl.BlockSpec((kk, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(ae, be, km)
    return out[:m, :nn]
