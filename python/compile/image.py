"""Deterministic procedural test scenes (integer-only math).

The paper evaluates on standard photographs we cannot redistribute
(DESIGN.md §2 substitution).  These scenes are specified with *pure integer
arithmetic* so the Rust side (`rust/src/apps/image.rs`) reproduces them
bit-for-bit — a strong cross-language golden for the application pipelines.

Scene layout (h x w, uint8):
  * base: horizontal gradient  v = (x * 255) / (w - 1)
  * top third: 16x16 checkerboard (224 / 32)
  * three filled disks (smooth-ish luminance steps)
  * diagonal stripes band in the lower quarter
  * a dark frame border (2 px)
"""

from __future__ import annotations

import numpy as np


def scene(h: int = 256, w: int = 256) -> np.ndarray:
    """The canonical test image; must match axsys::apps::image::scene."""
    y = np.arange(h).reshape(-1, 1)
    x = np.arange(w).reshape(1, -1)
    v = (x * 255) // (w - 1)
    v = np.broadcast_to(v, (h, w)).copy()

    checker = (((x // 16) + (y // 16)) % 2 == 0)
    top = np.broadcast_to(y < h // 3, (h, w))
    v[top & checker] = 224
    v[top & ~checker] = 32

    for (cy, cx, r, val) in ((h // 2, w // 4, h // 8, 200),
                             (h // 2, w // 2, h // 10, 90),
                             ((5 * h) // 8, (3 * w) // 4, h // 7, 150)):
        d = (y - cy) ** 2 + (x - cx) ** 2
        v[d < r * r] = val

    band = np.broadcast_to(y >= (3 * h) // 4, (h, w))
    stripes = (((x + y) // 8) % 2 == 0)
    v[band & stripes] = 240
    v[band & ~stripes] = 16

    v[:2, :] = 8
    v[-2:, :] = 8
    v[:, :2] = 8
    v[:, -2:] = 8
    return v.astype(np.uint8)


def texture(h: int = 64, w: int = 64, seed: int = 1234) -> np.ndarray:
    """Seeded pseudo-random texture via an explicit LCG (reproducible in
    Rust without pulling in numpy's generator)."""
    out = np.empty(h * w, dtype=np.uint8)
    state = np.uint64(seed)
    a = np.uint64(6364136223846793005)
    c = np.uint64(1442695040888963407)
    with np.errstate(over="ignore"):
        for i in range(h * w):
            state = state * a + c
            out[i] = np.uint8((state >> np.uint64(33)) & np.uint64(0xFF))
    return out.reshape(h, w)


def write_pgm(path: str, img: np.ndarray) -> None:
    """Binary PGM (P5) writer."""
    img = np.asarray(img, dtype=np.uint8)
    h, w = img.shape
    with open(path, "wb") as f:
        f.write(f"P5\n{w} {h}\n255\n".encode())
        f.write(img.tobytes())


def read_pgm(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        data = f.read()
    assert data[:2] == b"P5"
    parts = data.split(b"\n", 3)
    w, h = map(int, parts[1].split())
    assert parts[2].strip() == b"255"
    return np.frombuffer(parts[3][: h * w], dtype=np.uint8).reshape(h, w)


def psnr(a: np.ndarray, b: np.ndarray, peak: float = 255.0) -> float:
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    mse = np.mean((a - b) ** 2)
    if mse == 0:
        return float("inf")
    return 10.0 * np.log10(peak * peak / mse)


def ssim(a: np.ndarray, b: np.ndarray, peak: float = 255.0) -> float:
    """Global (single-window) SSIM — matches the Rust implementation."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    c1 = (0.01 * peak) ** 2
    c2 = (0.03 * peak) ** 2
    mu_a, mu_b = a.mean(), b.mean()
    va, vb = a.var(), b.var()
    cov = ((a - mu_a) * (b - mu_b)).mean()
    return float(((2 * mu_a * mu_b + c1) * (2 * cov + c2))
                 / ((mu_a ** 2 + mu_b ** 2 + c1) * (va + vb + c2)))
