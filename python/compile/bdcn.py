"""BDCN-lite: a bi-directional cascade CNN edge detector (Layer 2).

The paper integrates its approximate PEs into the first two blocks of BDCN
(He et al., TPAMI'22) [17].  The pretrained VGG-backbone BDCN and BSDS500
are unavailable here (DESIGN.md §2), so we train a compact cascade network
with the same *structure* — stacked conv blocks, per-block side outputs,
bidirectional (shallow-to-deep and deep-to-shallow) supervision, final fused
edge map — at artifact-build time on synthetic edge-labelled scenes.

What the paper measures (PSNR/SSIM of approx-PE output against the exact-PE
output of the same network) depends on error propagation through the
cascade, not on edge-detection quality, so this substitution preserves the
experiment.

Inference is fully int8-quantized: every conv runs as im2col + the L1
approximate GEMM; blocks 1-2 use approximation level ``k`` (runtime input),
blocks 3-4 are exact (k=0) — the paper's Fig. 12 hybrid scheme.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import image as imglib
from .kernels.axmm import axmm

CHANNELS = 8
N_BLOCKS = 4
TRAIN_STEPS = 400
PATCH = 48


# ---------------------------------------------------------------------------
# Float model (training only; never exported).
# ---------------------------------------------------------------------------

def init_params(key):
    """Blocks of two 3x3 convs + one 1x1 side head each."""
    params = []
    c_in = 1
    for b in range(N_BLOCKS):
        k1, k2, k3, key = jax.random.split(key, 4)
        params.append({
            "w1": jax.random.normal(k1, (3, 3, c_in, CHANNELS)) * 0.3,
            "w2": jax.random.normal(k2, (3, 3, CHANNELS, CHANNELS)) * 0.2,
            "side": jax.random.normal(k3, (1, 1, CHANNELS, 1)) * 0.2,
        })
        c_in = CHANNELS
    return params


def _conv_f(x, w):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))


def forward_float(params, x):
    """x: (B,H,W,1) in [0,1]. Returns (fused_logits, side_logits list).

    Bi-directional cascade: side outputs are accumulated both
    shallow->deep and deep->shallow; the fused map sums all sides.
    """
    sides = []
    h = x
    for b, p in enumerate(params):
        h = jax.nn.relu(_conv_f(h, p["w1"]))
        h = jax.nn.relu(_conv_f(h, p["w2"]))
        sides.append(_conv_f(h, p["side"]))
    d2s = []  # deep-to-shallow cascade: each side sees deeper sides
    acc = jnp.zeros_like(sides[0])
    for s in reversed(sides):
        acc = acc + s
        d2s.append(acc)
    s2d = []
    acc = jnp.zeros_like(sides[0])
    for s in sides:
        acc = acc + s
        s2d.append(acc)
    fused = sum(sides)
    return fused, s2d + d2s


def _gt_edges(img_u8):
    """Ground truth: thresholded 8-neighbour Laplacian magnitude."""
    x = img_u8.astype(np.int32)
    h, w = x.shape
    acc = 8 * x[1:h - 1, 1:w - 1]
    for dy in range(3):
        for dx in range(3):
            if dy == 1 and dx == 1:
                continue
            acc = acc - x[dy:h - 2 + dy, dx:w - 2 + dx]
    e = (np.abs(acc) > 96).astype(np.float32)
    out = np.zeros((h, w), np.float32)
    out[1:h - 1, 1:w - 1] = e
    return out


def _training_set(n_patches: int = 64, seed: int = 7):
    rng = np.random.default_rng(seed)
    base = imglib.scene(256, 256)
    xs, ys = [], []
    for _ in range(n_patches):
        oy = int(rng.integers(0, 256 - PATCH))
        ox = int(rng.integers(0, 256 - PATCH))
        p = base[oy:oy + PATCH, ox:ox + PATCH]
        xs.append(p.astype(np.float32) / 255.0)
        ys.append(_gt_edges(p))
    x = np.stack(xs)[..., None]
    y = np.stack(ys)[..., None]
    return jnp.asarray(x), jnp.asarray(y)


def train(seed: int = 0, steps: int = TRAIN_STEPS, lr: float = 3e-3):
    """Adam training of the float cascade; deterministic given the seed."""
    key = jax.random.PRNGKey(seed)
    params = init_params(key)
    x, y = _training_set()
    pos = jnp.clip(y.mean(), 0.02, 0.5)
    wpos, wneg = 1.0 / pos, 1.0 / (1.0 - pos)

    def loss_fn(p):
        fused, sides = forward_float(p, x)
        def bce(logit):
            z = jax.nn.log_sigmoid(logit)
            zn = jax.nn.log_sigmoid(-logit)
            return -(wpos * y * z + wneg * (1 - y) * zn).mean()
        return bce(fused) + 0.3 * sum(bce(s) for s in sides) / len(sides)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    flat, tree = jax.tree_util.tree_flatten(params)
    m = [jnp.zeros_like(f) for f in flat]
    v = [jnp.zeros_like(f) for f in flat]
    losses = []
    for step in range(steps):
        lval, g = grad_fn(jax.tree_util.tree_unflatten(tree, flat))
        gflat = jax.tree_util.tree_flatten(g)[0]
        t = step + 1
        for i in range(len(flat)):
            m[i] = 0.9 * m[i] + 0.1 * gflat[i]
            v[i] = 0.999 * v[i] + 0.001 * gflat[i] ** 2
            mh = m[i] / (1 - 0.9 ** t)
            vh = v[i] / (1 - 0.999 ** t)
            flat[i] = flat[i] - lr * mh / (jnp.sqrt(vh) + 1e-8)
        losses.append(float(lval))
    return jax.tree_util.tree_unflatten(tree, flat), losses


# ---------------------------------------------------------------------------
# int8 quantization + integer inference through the approximate GEMM.
# ---------------------------------------------------------------------------

def quantize(params):
    """Per-tensor symmetric int8 weights + power-of-two activation shifts.

    Activations are kept in int8 [-128,127] between layers; each conv's
    int32 accumulator is right-shifted by a calibrated power of two.
    """
    q = []
    for p in params:
        qp = {}
        for name in ("w1", "w2", "side"):
            w = np.asarray(p[name])
            scale = np.abs(w).max() / 127.0 if np.abs(w).max() > 0 else 1.0
            qp[name] = np.clip(np.round(w / scale), -127, 127).astype(np.int32)
            qp[name + "_scale"] = float(scale)
        q.append(qp)
    return q


def _conv_q(x, wq, k, approx: bool):
    """Integer conv via im2col + approximate GEMM.

    x: (H, W, Cin) int32 in int8 range; wq: (kh, kw, Cin, Cout) int32.
    Returns int32 accumulators (H, W, Cout) (SAME padding).
    """
    kh, kw, cin, cout = wq.shape
    h, w = x.shape[0], x.shape[1]
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((ph, ph), (pw, pw), (0, 0)))
    cols = [xp[dy:dy + h, dx:dx + w, :].reshape(h * w, cin)
            for dy in range(kh) for dx in range(kw)]
    mat = jnp.concatenate(cols, axis=1)                 # (H*W, kh*kw*cin)
    wmat = jnp.asarray(wq.reshape(kh * kw * cin, cout), jnp.int32)
    klevel = k if approx else jnp.zeros((), jnp.int32)
    y = axmm(mat, wmat, klevel, bm=512, bn=8)
    return y.reshape(h, w, cout)


def _requant(acc, shift: int):
    """int32 accumulator -> int8 activation with ReLU."""
    v = (acc + (1 << (shift - 1))) >> shift
    return jnp.clip(v, 0, 127)


# calibrated accumulator shifts (see aot.py: calibrate_shifts)
DEFAULT_SHIFTS = {"w1": 7, "w2": 9, "side": 8}


def forward_int8(qparams, img_u8, k, shifts=None):
    """Quantized inference: uint8 (H,W) image -> int32 edge map 0..255.

    Blocks 0-1 run their GEMMs at approximation level ``k`` (runtime
    scalar); blocks 2-3 are exact — the paper's hybrid BDCN (Fig. 12).
    """
    shifts = shifts or DEFAULT_SHIFTS
    # input centered to int8 like every other pipeline
    x = (jnp.asarray(img_u8, jnp.int32) - 128).astype(jnp.int32)[..., None]
    side_acc = None
    for b, p in enumerate(qparams):
        approx = b < 2
        a1 = _conv_q(x, p["w1"], k, approx)
        x = _requant(a1, shifts["w1"])
        a2 = _conv_q(x, p["w2"], k, approx)
        x = _requant(a2, shifts["w2"])
        s = _conv_q(x, p["side"], k, approx)[:, :, 0]   # int32 logits
        side_acc = s if side_acc is None else side_acc + s
    # fused logits -> 0..255 edge map (linear mapping of the logit range)
    e = (side_acc + (1 << (DEFAULT_SHIFTS["side"] - 1))) >> DEFAULT_SHIFTS["side"]
    return jnp.clip(e + 128, 0, 255)


# ---------------------------------------------------------------------------
# Weight persistence (artifacts/bdcn_weights.npz).
# ---------------------------------------------------------------------------

def save_qparams(path: str, qparams, losses=None):
    flat = {}
    for i, p in enumerate(qparams):
        for name in ("w1", "w2", "side"):
            flat[f"b{i}_{name}"] = p[name]
            flat[f"b{i}_{name}_scale"] = p[name + "_scale"]
    if losses is not None:
        flat["losses"] = np.asarray(losses, np.float32)
    np.savez(path, **flat)


def load_qparams(path: str):
    z = np.load(path)
    q = []
    for i in range(N_BLOCKS):
        q.append({name: z[f"b{i}_{name}"].astype(np.int32)
                  for name in ("w1", "w2", "side")}
                 | {name + "_scale": float(z[f"b{i}_{name}_scale"])
                    for name in ("w1", "w2", "side")})
    return q


def export_qparams_txt(path: str, qparams):
    """Flat text export for the Rust SA-backed BDCN (no zip/npz dep):
    one tensor per line: ``b{i}_{name} d0 d1 d2 d3 v...``."""
    with open(path, "w") as f:
        for i, p in enumerate(qparams):
            for name in ("w1", "w2", "side"):
                w = np.asarray(p[name], np.int32)
                dims = " ".join(map(str, w.shape))
                vals = " ".join(map(str, w.reshape(-1).tolist()))
                f.write(f"b{i}_{name} {dims} {vals}\n")


def get_or_train_qparams(artifacts_dir: str):
    path = os.path.join(artifacts_dir, "bdcn_weights.npz")
    if os.path.exists(path):
        return load_qparams(path)
    params, losses = train()
    q = quantize(params)
    os.makedirs(artifacts_dir, exist_ok=True)
    save_qparams(path, q, losses)
    return q


def bdcn_pipeline_fn(qparams, h: int = 128, w: int = 128):
    """Returns a jittable fn(img_int32 (h,w), k) -> int32 edge map."""
    def fn(img, k):
        return forward_int8(qparams, img, k)
    return fn
