"""AOT compile path: lower every L2 pipeline to HLO **text** artifacts.

Run once by ``make artifacts``; Python never executes at request time.
Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (all inputs int32; ``k`` is a shape-(1,) runtime scalar):
  gemm64.hlo.txt      (64,64) @ (64,64), k        -> (64,64)
  axmm_b16.hlo.txt    (16,8,8) @ (16,8,8), k      -> (16,8,8)   [SA tiles]
  dct256.hlo.txt      (256,256) image, k          -> recon, coeffs
  edge256.hlo.txt     (256,256) image, k          -> (254,254) edge map
  bdcn128.hlo.txt     (128,128) image, k          -> (128,128) edge map
plus golden input/output vectors (raw little-endian i32 ``.bin`` + a
manifest) that the Rust runtime tests replay, the deterministic test
scenes as PGM, and the build-time-trained BDCN weights.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import bdcn, image, model
from .kernels.axmm import axmm


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants is ESSENTIAL: the default dump elides big
    # literals as `constant({...})`, which the Rust-side HLO text parser
    # then mis-reads as empty — DCT matrices / CNN weights would vanish.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def _write_bin(path: str, arr) -> None:
    np.asarray(arr, dtype="<i4").tofile(path)


# ---------------------------------------------------------------------------
# Exported computations. Every fn takes int32 arrays + k as shape (1,) i32
# and returns a tuple (lowered with return_tuple=True).
# ---------------------------------------------------------------------------

def fn_gemm64(a, b, k):
    return (axmm(a, b, k[0]),)


def fn_axmm_b16(a, b, k):
    f = jax.vmap(lambda x, y: axmm(x, y, k[0], bm=8, bn=8))
    return (f(a, b),)


def fn_dct256(img, k):
    recon, coeff = model.dct_pipeline(img, k[0], h=256, w=256)
    return (recon, coeff)


def fn_edge256(img, k):
    return (model.edge_pipeline(img, k[0]),)


def make_fn_bdcn(qparams, h=128, w=128):
    def fn_bdcn(img, k):
        return (bdcn.forward_int8(qparams, img, k[0]),)
    return fn_bdcn


def build(outdir: str) -> None:
    os.makedirs(outdir, exist_ok=True)
    golden_dir = os.path.join(outdir, "golden")
    img_dir = os.path.join(outdir, "images")
    os.makedirs(golden_dir, exist_ok=True)
    os.makedirs(img_dir, exist_ok=True)

    print("[aot] test scenes")
    scene256 = image.scene(256, 256)
    scene128 = image.scene(128, 128)
    image.write_pgm(os.path.join(img_dir, "scene256.pgm"), scene256)
    image.write_pgm(os.path.join(img_dir, "scene128.pgm"), scene128)
    image.write_pgm(os.path.join(img_dir, "texture64.pgm"),
                    image.texture(64, 64))

    print("[aot] bdcn weights (train-on-first-build)")
    qparams = bdcn.get_or_train_qparams(outdir)
    bdcn.export_qparams_txt(os.path.join(outdir, "bdcn_weights.txt"), qparams)
    fn_bdcn = make_fn_bdcn(qparams)

    rng = np.random.default_rng(42)
    a64 = rng.integers(-128, 128, (64, 64), dtype=np.int32)
    b64 = rng.integers(-128, 128, (64, 64), dtype=np.int32)
    at = rng.integers(-128, 128, (16, 8, 8), dtype=np.int32)
    bt = rng.integers(-128, 128, (16, 8, 8), dtype=np.int32)
    img256 = scene256.astype(np.int32)
    img128 = scene128.astype(np.int32)

    jobs = [
        ("gemm64", fn_gemm64,
         [_spec((64, 64)), _spec((64, 64)), _spec((1,))],
         [a64, b64]),
        ("axmm_b16", fn_axmm_b16,
         [_spec((16, 8, 8)), _spec((16, 8, 8)), _spec((1,))],
         [at, bt]),
        ("dct256", fn_dct256,
         [_spec((256, 256)), _spec((1,))],
         [img256]),
        ("edge256", fn_edge256,
         [_spec((256, 256)), _spec((1,))],
         [img256]),
        ("bdcn128", fn_bdcn,
         [_spec((128, 128)), _spec((1,))],
         [img128]),
    ]

    manifest = []
    for name, fn, specs, inputs in jobs:
        print(f"[aot] lowering {name}")
        lowered = jax.jit(fn).lower(*specs)
        _write(os.path.join(outdir, f"{name}.hlo.txt"), to_hlo_text(lowered))

        # goldens at two approximation levels
        jfn = jax.jit(fn)
        for k in (0, 6):
            karr = np.array([k], dtype=np.int32)
            outs = jfn(*inputs, karr)
            case = f"{name}_k{k}"
            for i, arr in enumerate(inputs):
                _write_bin(os.path.join(golden_dir, f"{case}_in{i}.bin"), arr)
            _write_bin(os.path.join(golden_dir, f"{case}_k.bin"), karr)
            for i, arr in enumerate(outs):
                _write_bin(os.path.join(golden_dir, f"{case}_out{i}.bin"),
                           np.array(arr))
            shapes_in = ";".join("x".join(map(str, np.asarray(x).shape))
                                 for x in inputs)
            shapes_out = ";".join("x".join(map(str, np.asarray(o).shape))
                                  for o in outs)
            manifest.append(f"{case} {name}.hlo.txt {len(inputs)} "
                            f"{shapes_in} {k} {len(outs)} {shapes_out}")
        del jfn

    with open(os.path.join(golden_dir, "manifest.txt"), "w") as f:
        f.write("# case hlo n_inputs in_shapes k n_outputs out_shapes\n")
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] manifest: {len(manifest)} golden cases")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output artifact (legacy Makefile arg; the whole "
                         "directory containing it is built)")
    ap.add_argument("--outdir", default=None)
    args = ap.parse_args()
    outdir = args.outdir or (os.path.dirname(args.out) if args.out else None)
    if not outdir:
        outdir = os.path.join(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))), "artifacts")
    build(outdir)


if __name__ == "__main__":
    main()
