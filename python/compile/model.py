"""Layer 2: application compute graphs built on the L1 approximate-GEMM.

Every matrix product in these models routes through the Pallas kernel
(`kernels.axmm.axmm`), so the whole pipeline lowers to a single HLO module
with the approximation level ``k`` as a *runtime* scalar input.

Pipelines (paper §V):
  * 8x8 integer-scaled DCT (HEVC-style coefficients [18]) forward +
    reconstruction — image compression proxy.
  * Laplacian kernel edge detection via im2col + GEMM.
(The CNN edge detector lives in ``bdcn.py``.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.axmm import axmm

# HEVC 8-point integer DCT matrix (Meher et al. [18]); entries fit int8.
DCT8 = np.array([
    [64, 64, 64, 64, 64, 64, 64, 64],
    [89, 75, 50, 18, -18, -50, -75, -89],
    [83, 36, -36, -83, -83, -36, 36, 83],
    [75, -18, -89, -50, 50, 89, 18, -75],
    [64, -64, -64, 64, 64, -64, -64, 64],
    [50, -89, 18, 75, -75, -18, 89, -50],
    [36, -83, 83, -36, -36, 83, -83, 36],
    [18, -50, 75, -89, 89, -75, 50, -18],
], dtype=np.int32)

# Right-shift schedule for the four GEMM stages (fwd x2, inv x2).  The
# matrix gain is ||C row||^2 ~= 2^15 per transform pair, so the shifts must
# sum to 30 to make forward+inverse unity-gain; the split keeps every
# intermediate inside the signed 8-bit PE operand range (see
# tests/test_model.py::test_dct_intermediates_fit_int8).
DCT_SHIFTS = (9, 9, 6, 6)

# 8-neighbour Laplacian (sums to zero -> invariant to the -128 centering).
LAPLACIAN = np.array([[-1, -1, -1], [-1, 8, -1], [-1, -1, -1]], dtype=np.int32)


def _rshift_round(v, s: int):
    """Arithmetic right shift with round-to-nearest (ties away from zero
    for non-negatives — the hardware's adder-based rounding)."""
    return (v + (1 << (s - 1))) >> s if s > 0 else v


def _clip8(v):
    return jnp.clip(v, -128, 127)


def _to_blocks(img):
    """(H, W) -> (nb*8, 8) stacked 8x8 blocks (row-major block order)."""
    h, w = img.shape
    nbh, nbw = h // 8, w // 8
    b = img.reshape(nbh, 8, nbw, 8).transpose(0, 2, 1, 3).reshape(-1, 8, 8)
    return b.reshape(-1, 8)


def _from_blocks(b, h: int, w: int):
    nbh, nbw = h // 8, w // 8
    return (b.reshape(nbh, nbw, 8, 8).transpose(0, 2, 1, 3).reshape(h, w))


def _blockwise_left(mat, blocks, k, **ax):
    """Per-block ``mat @ block`` for stacked blocks (nb*8, 8).

    Implemented as one wide GEMM: transpose each block so the contraction
    runs over the stacked axis — blocks laid side by side: (8, nb*8).
    """
    nb = blocks.shape[0] // 8
    wide = blocks.reshape(nb, 8, 8).transpose(1, 0, 2).reshape(8, nb * 8)
    out = axmm(jnp.asarray(mat, jnp.int32), wide, k, **ax)   # (8, nb*8)
    return out.reshape(8, nb, 8).transpose(1, 0, 2).reshape(nb * 8, 8)


def _blockwise_right(blocks, mat, k, **ax):
    """Per-block ``block @ mat`` — a single tall GEMM (nb*8, 8) @ (8, 8)."""
    return axmm(blocks, jnp.asarray(mat, jnp.int32), k, **ax)


def dct_forward(img, k, shifts=DCT_SHIFTS):
    """Centered image -> int8 DCT coefficient blocks (stacked nb*8 x 8)."""
    x = _to_blocks(jnp.asarray(img, jnp.int32) - 128)
    t = _blockwise_left(DCT8, x, k)
    t = _clip8(_rshift_round(t, shifts[0]))
    y = _blockwise_right(t, DCT8.T, k)
    return _clip8(_rshift_round(y, shifts[1]))


def dct_inverse(coeff, k, h: int, w: int, shifts=DCT_SHIFTS):
    """int8 coefficient blocks -> reconstructed uint8-range image."""
    t = _blockwise_left(DCT8.T, coeff, k)
    t = _clip8(_rshift_round(t, shifts[2]))
    x = _blockwise_right(t, DCT8, k)
    x = _rshift_round(x, shifts[3])
    return jnp.clip(_from_blocks(x, h, w) + 128, 0, 255)


@functools.partial(jax.jit, static_argnames=("h", "w"))
def dct_pipeline(img, k, h: int = 256, w: int = 256):
    """Full compress->reconstruct pipeline. Returns (recon, coeffs)."""
    c = dct_forward(img, k)
    r = dct_inverse(c, k, h, w)
    return r, _from_blocks(c, h, w)


def _im2col3(img):
    """(H, W) -> ((H-2)*(W-2), 9) patches of the 3x3 neighbourhood."""
    h, w = img.shape
    cols = [img[dy:h - 2 + dy, dx:w - 2 + dx].reshape(-1, 1)
            for dy in range(3) for dx in range(3)]
    return jnp.concatenate(cols, axis=1)


@jax.jit
def edge_pipeline(img, k):
    """Laplacian edge detection: uint8 image -> uint8-range edge map."""
    x = _im2col3(jnp.asarray(img, jnp.int32) - 128)          # (P, 9)
    kern = LAPLACIAN.reshape(9, 1)
    y = axmm(x, jnp.asarray(kern, jnp.int32), k, bm=256)     # (P, 1)
    h, w = img.shape
    e = jnp.abs(y.reshape(h - 2, w - 2))
    return jnp.clip(_rshift_round(e, 2), 0, 255)


@jax.jit
def gemm_pipeline(a, b, k):
    """Raw approximate GEMM (the coordinator's tile artifact)."""
    return axmm(a, b, k)
