"""BDCN-lite: quantization, integer inference, cascade error damping."""

import os

import numpy as np
import pytest

from compile import bdcn, image

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def qparams():
    p = os.path.join(ART, "bdcn_weights.npz")
    if not os.path.exists(p):
        pytest.skip("run `make artifacts` first (trains the CNN)")
    return bdcn.load_qparams(p)


def test_weights_are_int8(qparams):
    for blk in qparams:
        for name in ("w1", "w2", "side"):
            w = blk[name]
            assert w.dtype == np.int32
            assert np.abs(w).max() <= 127
            assert blk[name + "_scale"] > 0


def test_architecture_shapes(qparams):
    assert len(qparams) == bdcn.N_BLOCKS
    assert qparams[0]["w1"].shape == (3, 3, 1, bdcn.CHANNELS)
    for blk in qparams[1:]:
        assert blk["w1"].shape == (3, 3, bdcn.CHANNELS, bdcn.CHANNELS)
    for blk in qparams:
        assert blk["side"].shape == (1, 1, bdcn.CHANNELS, 1)


def test_txt_export_roundtrip(qparams, tmp_path):
    p = str(tmp_path / "w.txt")
    bdcn.export_qparams_txt(p, qparams)
    text = open(p).read().strip().splitlines()
    assert len(text) == bdcn.N_BLOCKS * 3
    first = text[0].split()
    assert first[0] == "b0_w1"
    dims = list(map(int, first[1:5]))
    vals = list(map(int, first[5:]))
    assert len(vals) == int(np.prod(dims))
    assert (np.array(vals).reshape(dims) == qparams[0]["w1"]).all()


def test_inference_deterministic(qparams):
    img = image.scene(32, 32)
    a = np.array(bdcn.forward_int8(qparams, img, 3))
    b = np.array(bdcn.forward_int8(qparams, img, 3))
    assert (a == b).all()
    assert a.min() >= 0 and a.max() <= 255


def test_cascade_dampens_error_vs_kernel(qparams):
    """The paper's core §V-B observation: the CNN cascade (late blocks
    exact) tolerates approximation far better than the Laplacian kernel."""
    from compile import model
    img = image.scene(48, 48)
    cnn0 = np.array(bdcn.forward_int8(qparams, img, 0))
    cnn8 = np.array(bdcn.forward_int8(qparams, img, 8))
    lap0 = np.array(model.edge_pipeline(img, 0))
    lap8 = np.array(model.edge_pipeline(img, 8))
    cnn_psnr = image.psnr(cnn0, cnn8)
    lap_psnr = image.psnr(lap0, lap8)
    assert cnn_psnr > lap_psnr + 5.0, (cnn_psnr, lap_psnr)


def test_quality_monotone_in_k(qparams):
    img = image.scene(32, 32)
    e0 = np.array(bdcn.forward_int8(qparams, img, 0))
    p2 = image.psnr(e0, np.array(bdcn.forward_int8(qparams, img, 2)))
    p8 = image.psnr(e0, np.array(bdcn.forward_int8(qparams, img, 8)))
    assert p2 >= p8
    assert p2 > 25.0


def test_training_converges_quickly():
    """Sanity on the build-time training loop (few steps only)."""
    params, losses = bdcn.train(steps=25)
    assert losses[-1] < losses[0]
    q = bdcn.quantize(params)
    assert len(q) == bdcn.N_BLOCKS
