"""L2 model tests: DCT + Laplacian pipelines."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import image, model
from compile.kernels import ref


def test_dct_matrix_properties():
    c = model.DCT8.astype(np.int64)
    # integer HEVC basis: rows near-orthogonal (integer rounding leaves
    # tiny off-diagonal residue), near-equal norms
    gram = c @ c.T
    off = gram - np.diag(np.diag(gram))
    assert np.abs(off).max() / gram[0, 0] < 0.01
    norms = np.diag(gram)
    assert norms.max() / norms.min() < 1.01
    # all entries fit the signed 8-bit PE
    assert np.abs(c).max() <= 127


def test_blocks_roundtrip():
    img = np.arange(32 * 48, dtype=np.int32).reshape(32, 48)
    b = model._to_blocks(img)
    back = np.array(model._from_blocks(b, 32, 48))
    assert (back == img).all()


def test_dct_exact_reconstruction_quality():
    img = image.scene(64, 64)
    r, _ = model.dct_pipeline(img, 0, h=64, w=64)
    p = image.psnr(img, np.array(r))
    assert p > 38.0, p


def test_dct_intermediates_fit_int8():
    """The shift schedule must keep every GEMM operand in [-128, 127]."""
    img = image.scene(64, 64)
    c = np.array(model.dct_forward(img, 0))
    assert c.min() >= -128 and c.max() <= 127


def test_dct_quality_monotone_in_k():
    img = image.scene(64, 64)
    exact, _ = model.dct_pipeline(img, 0, h=64, w=64)
    exact = np.array(exact)
    prev = np.inf
    for k in (2, 4, 6, 8):
        r, _ = model.dct_pipeline(img, k, h=64, w=64)
        p = image.psnr(exact, np.array(r))
        assert p <= prev + 1.0, (k, p, prev)
        assert p > 15.0
        prev = p


def test_dct_flat_image_is_fixed_point_dc():
    """A flat image has only DC energy; reconstruction must be near-flat."""
    img = np.full((16, 16), 200, dtype=np.uint8)
    r, c = model.dct_pipeline(img, 0, h=16, w=16)
    r = np.array(r)
    assert np.abs(r.astype(int) - 200).max() <= 2
    cb = np.array(c).reshape(2, 8, 2, 8)
    # AC coefficients are zero for a flat block
    assert np.abs(cb[:, 1:, :, :]).max() == 0
    assert np.abs(cb[:, :, :, 1:]).max() == 0


def test_edge_flat_zero():
    img = np.full((16, 16), 93, dtype=np.uint8)
    e = np.array(model.edge_pipeline(img, 0))
    assert (e == 0).all()


def test_edge_detects_step():
    img = np.zeros((16, 16), dtype=np.uint8)
    img[:, 8:] = 255
    e = np.array(model.edge_pipeline(img, 0))
    # the vertical step must be the strongest response column
    col_strength = e.sum(axis=0)
    assert col_strength.argmax() in (5, 6, 7)
    assert e.max() > 100


def test_edge_offset_invariance():
    """Laplacian sums to zero: adding a constant changes nothing (until
    the uint8 clip)."""
    img = image.scene(32, 32)
    shifted = np.clip(img.astype(np.int32) + 10, 0, 245).astype(np.uint8)
    # only check on interiors away from clipped extremes
    e1 = np.array(model.edge_pipeline(img, 0))
    e2 = np.array(model.edge_pipeline(np.clip(img, 10, 245), 0))
    del shifted
    assert e1.shape == e2.shape  # structural smoke; exact equality needs
    # unclipped data, covered by the flat test


@given(k=st.integers(0, 8), seed=st.integers(0, 2**31))
@settings(max_examples=8, deadline=None)
def test_gemm_pipeline_matches_ref(k, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, (16, 16), dtype=np.int32)
    b = rng.integers(-128, 128, (16, 16), dtype=np.int32)
    y = np.array(model.gemm_pipeline(a, b, k))
    want = np.array(ref.axmm_ref(a, b, k))
    assert (y == want).all()


def test_rshift_round_semantics():
    v = np.array([10, -10, 7, -7, 0], dtype=np.int32)
    out = np.array(model._rshift_round(v, 2))
    # floor division semantics: (v + 2) >> 2
    assert (out == np.array([3, -2, 2, -2, 0])).all()
