"""AOT artifact sanity: HLO text quality + golden manifest consistency."""

import glob
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

EXPECTED_ARTIFACTS = ["gemm64", "axmm_b16", "dct256", "edge256", "bdcn128"]


def _need_artifacts():
    if not os.path.exists(os.path.join(ART, "golden", "manifest.txt")):
        pytest.skip("run `make artifacts` first")


def test_all_artifacts_present():
    _need_artifacts()
    for name in EXPECTED_ARTIFACTS:
        p = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(p), name


def test_no_elided_constants():
    """Regression for the `constant({...})` bug: the default HLO dump
    elides large literals, which the Rust-side parser silently reads as
    empty — DCT matrices and CNN weights vanished (bit-exactness broke).
    """
    _need_artifacts()
    for name in EXPECTED_ARTIFACTS:
        text = open(os.path.join(ART, f"{name}.hlo.txt")).read()
        assert "constant({...})" not in text, name
        assert "{...}" not in text, name


def test_hlo_is_parseable_text():
    _need_artifacts()
    for name in EXPECTED_ARTIFACTS:
        text = open(os.path.join(ART, f"{name}.hlo.txt")).read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # k must be a runtime parameter, not folded away
        assert "parameter(" in text, name


def test_manifest_matches_files():
    _need_artifacts()
    lines = [l for l in open(os.path.join(ART, "golden", "manifest.txt"))
             if l.strip() and not l.startswith("#")]
    assert len(lines) == 10  # 5 artifacts x k in {0, 6}
    for line in lines:
        f = line.split()
        case, hlo, n_in = f[0], f[1], int(f[2])
        assert os.path.exists(os.path.join(ART, hlo))
        for i in range(n_in):
            assert os.path.exists(
                os.path.join(ART, "golden", f"{case}_in{i}.bin")), case
        n_out = int(f[5])
        for i in range(n_out):
            assert os.path.exists(
                os.path.join(ART, "golden", f"{case}_out{i}.bin")), case


def test_golden_shapes_consistent():
    _need_artifacts()
    for line in open(os.path.join(ART, "golden", "manifest.txt")):
        if line.startswith("#") or not line.strip():
            continue
        f = line.split()
        case = f[0]
        out_shapes = [tuple(map(int, g.split("x"))) for g in f[6].split(";")]
        for i, shape in enumerate(out_shapes):
            data = np.fromfile(
                os.path.join(ART, "golden", f"{case}_out{i}.bin"), dtype="<i4")
            assert data.size == int(np.prod(shape)), (case, i)


def test_goldens_match_live_models():
    """Replay two golden cases against the live Python models — catches
    drift between committed artifacts and the current code."""
    _need_artifacts()
    from compile import model
    a = np.fromfile(os.path.join(ART, "golden", "gemm64_k6_in0.bin"),
                    dtype="<i4").reshape(64, 64).astype(np.int32)
    b = np.fromfile(os.path.join(ART, "golden", "gemm64_k6_in1.bin"),
                    dtype="<i4").reshape(64, 64).astype(np.int32)
    want = np.fromfile(os.path.join(ART, "golden", "gemm64_k6_out0.bin"),
                       dtype="<i4").reshape(64, 64)
    got = np.array(model.gemm_pipeline(a, b, 6))
    assert (got == want).all()

    img = np.fromfile(os.path.join(ART, "golden", "edge256_k0_in0.bin"),
                      dtype="<i4").reshape(256, 256).astype(np.int32)
    want = np.fromfile(os.path.join(ART, "golden", "edge256_k0_out0.bin"),
                       dtype="<i4").reshape(254, 254)
    got = np.array(model.edge_pipeline(img.astype(np.uint8), 0))
    assert (got == want).all()


def test_pgm_images_exported():
    _need_artifacts()
    assert glob.glob(os.path.join(ART, "images", "*.pgm"))
