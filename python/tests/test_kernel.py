"""L1 correctness: Pallas kernel vs pure-jnp ref vs exact oracle.

This is the CORE correctness signal for the compile path: everything the
Rust side executes (HLO artifacts) lowers from these functions.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.axmm import axmm


def rand_mat(rng, m, n, lo=-128, hi=128):
    return rng.integers(lo, hi, (m, n), dtype=np.int32)


# ---------------------------------------------------------------------------
# Table I: the paper's normative truth tables for the approximate cells.
# ---------------------------------------------------------------------------

# rows: (a, b, Cin, Sin) -> (C, S) approx PPC / approx NPPC (paper Table I)
TABLE_I_PPC = {
    (0, 0, 0, 0): (0, 0), (0, 0, 0, 1): (0, 1), (0, 0, 1, 0): (0, 1),
    (0, 0, 1, 1): (0, 1), (0, 1, 0, 0): (0, 0), (0, 1, 0, 1): (0, 1),
    (0, 1, 1, 0): (0, 1), (0, 1, 1, 1): (0, 1), (1, 0, 0, 0): (0, 0),
    (1, 0, 0, 1): (0, 1), (1, 0, 1, 0): (0, 1), (1, 0, 1, 1): (0, 1),
    (1, 1, 0, 0): (1, 0), (1, 1, 0, 1): (1, 0), (1, 1, 1, 0): (1, 0),
    (1, 1, 1, 1): (1, 0),
}
TABLE_I_NPPC = {
    (0, 0, 0, 0): (0, 1), (0, 0, 0, 1): (1, 0), (0, 0, 1, 0): (1, 0),
    (0, 0, 1, 1): (1, 0), (0, 1, 0, 0): (0, 1), (0, 1, 0, 1): (1, 0),
    (0, 1, 1, 0): (1, 0), (0, 1, 1, 1): (1, 0), (1, 0, 0, 0): (0, 1),
    (1, 0, 0, 1): (1, 0), (1, 0, 1, 0): (1, 0), (1, 0, 1, 1): (1, 0),
    (1, 1, 0, 0): (0, 1), (1, 1, 0, 1): (0, 1), (1, 1, 1, 0): (0, 1),
    (1, 1, 1, 1): (0, 1),
}


def proposed_ppc(a, b, cin, sin):
    p = a & b
    return p, (sin | cin) & (1 - p)


def proposed_nppc(a, b, cin, sin):
    p = a & b
    return (sin | cin) & (1 - p), (1 - (sin | cin)) | p


@pytest.mark.parametrize("key", sorted(TABLE_I_PPC))
def test_table1_ppc(key):
    a, b, cin, sin = key
    assert proposed_ppc(a, b, cin, sin) == TABLE_I_PPC[key]


@pytest.mark.parametrize("key", sorted(TABLE_I_NPPC))
def test_table1_nppc(key):
    a, b, cin, sin = key
    assert proposed_nppc(a, b, cin, sin) == TABLE_I_NPPC[key]


def test_table1_error_cases():
    """Paper §III-B: exactly 5 erroneous rows, EDs -1,-1,-1,+1,-1."""
    errs = {}
    for (a, b, cin, sin), (c, s) in TABLE_I_PPC.items():
        exact = (a & b) + cin + sin
        ed = (2 * c + s) - exact
        if ed != 0:
            errs[(a, b, cin, sin)] = ed
    assert errs == {(0, 0, 1, 1): -1, (0, 1, 1, 1): -1, (1, 0, 1, 1): -1,
                    (1, 1, 0, 0): +1, (1, 1, 1, 1): -1}


def test_table1_nppc_matches_exact_complement():
    """Exact NPPC is FA(~p, Cin, Sin); approx NPPC EDs mirror the PPC's."""
    for (a, b, cin, sin), (c, s) in TABLE_I_NPPC.items():
        exact = (1 - (a & b)) + cin + sin
        assert (2 * c + s) - exact in (-1, 0, 1)


# ---------------------------------------------------------------------------
# Exact PE == integer arithmetic.
# ---------------------------------------------------------------------------

def test_exact_mac_exhaustive_4bit_signed():
    for a in range(-8, 8):
        for b in range(-8, 8):
            for c in (0, 1, -7, 100, -100):
                y = ref.mac_value_scalar(a & 15, b & 15, c & 0xFFFF, 0,
                                         n=4, w=16)
                assert y == a * b + c, (a, b, c)


def test_exact_mac_exhaustive_4bit_unsigned():
    for a in range(16):
        for b in range(16):
            y = ref.mac_value_scalar(a, b, 37, 0, n=4, w=16, signed=False)
            assert y == a * b + 37


@given(st.integers(-128, 127), st.integers(-128, 127),
       st.integers(-60000, 60000))
@settings(max_examples=300, deadline=None)
def test_exact_mac_8bit_prop(a, b, c):
    y = ref.mac_value_scalar(a & 255, b & 255, c & 0xFFFFFF, 0)
    assert y == a * b + c


def test_exact_matmul_matches_oracle():
    rng = np.random.default_rng(1)
    A, B = rand_mat(rng, 13, 8), rand_mat(rng, 8, 9)
    y = np.array(ref.axmm_ref(A, B, 0))
    assert (y == A.astype(np.int64) @ B).all()


# ---------------------------------------------------------------------------
# Approximate properties.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ref.FAMILIES)
def test_k0_is_exact(family):
    rng = np.random.default_rng(2)
    A, B = rand_mat(rng, 8, 8), rand_mat(rng, 8, 8)
    y = np.array(ref.axmm_ref(A, B, 0, family=family))
    assert (y == A.astype(np.int64) @ B).all()


def test_error_monotone_in_k():
    rng = np.random.default_rng(3)
    A, B = rand_mat(rng, 16, 16), rand_mat(rng, 16, 16)
    exact = A.astype(np.int64) @ B
    meds = []
    for k in (0, 2, 4, 6, 8):
        y = np.array(ref.axmm_ref(A, B, k)).astype(np.int64)
        meds.append(np.abs(y - exact).mean())
    assert meds[0] == 0
    assert all(meds[i] <= meds[i + 1] + 1e-9 for i in range(len(meds) - 1))


def test_nmed_regression_lock_k6_signed():
    """Spot-lock the proposed design's error level (cf. paper Table V)."""
    rng = np.random.default_rng(4)
    a = rng.integers(-128, 128, 4096, dtype=np.int32)
    b = rng.integers(-128, 128, 4096, dtype=np.int32)
    y = np.array(ref.axmm_ref(a.reshape(-1, 1), b.reshape(1, -1), 6))
    exact = a.reshape(-1, 1).astype(np.int64) @ b.reshape(1, -1)
    nmed = np.abs(y - exact).mean() / (1 << 14)
    assert 0.001 < nmed < 0.004, nmed  # paper: 0.0022


@pytest.mark.parametrize("family", ref.FAMILIES)
def test_families_bounded_error_k4(family):
    rng = np.random.default_rng(5)
    A, B = rand_mat(rng, 12, 8), rand_mat(rng, 8, 12)
    exact = A.astype(np.int64) @ B
    y = np.array(ref.axmm_ref(A, B, 4, family=family)).astype(np.int64)
    # k=4 approximates weights < 16; accumulated over K=8 with carries the
    # deviation stays well under 2^11 per output.
    assert np.abs(y - exact).max() < (1 << 11)


# ---------------------------------------------------------------------------
# Pallas kernel vs ref — bit identity.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ref.FAMILIES)
@pytest.mark.parametrize("k", [0, 3, 7])
def test_pallas_matches_ref(family, k):
    rng = np.random.default_rng(6)
    A, B = rand_mat(rng, 16, 8), rand_mat(rng, 8, 16)
    yr = np.array(ref.axmm_ref(A, B, k, family=family))
    yp = np.array(axmm(A, B, k, family=family))
    assert (yr == yp).all()


@given(m=st.integers(1, 40), kk=st.integers(1, 12), nn=st.integers(1, 40),
       k=st.integers(0, 8), seed=st.integers(0, 2**31))
@settings(max_examples=25, deadline=None)
def test_pallas_matches_ref_shapes(m, kk, nn, k, seed):
    """Hypothesis sweep over shapes (incl. ragged tiles) and k."""
    rng = np.random.default_rng(seed)
    A, B = rand_mat(rng, m, kk), rand_mat(rng, kk, nn)
    yr = np.array(ref.axmm_ref(A, B, k))
    yp = np.array(axmm(A, B, k))
    assert (yr == yp).all()


@given(k=st.integers(0, 8), seed=st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_pallas_tile_size_invariance(k, seed):
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(seed)
    A, B = rand_mat(rng, 24, 8), rand_mat(rng, 8, 24)
    y1 = np.array(axmm(A, B, k, bm=8, bn=8))
    y2 = np.array(axmm(A, B, k, bm=32, bn=16))
    assert (y1 == y2).all()


def test_unsigned_path():
    rng = np.random.default_rng(7)
    A = rng.integers(0, 256, (9, 8), dtype=np.int32)
    B = rng.integers(0, 256, (8, 9), dtype=np.int32)
    y = np.array(ref.axmm_ref(A, B, 0, signed=False))
    assert (y == A.astype(np.int64) @ B).all()
    yp = np.array(axmm(A, B, 5, signed=False))
    yr = np.array(ref.axmm_ref(A, B, 5, signed=False))
    assert (yp == yr).all()


# ---------------------------------------------------------------------------
# Scalar model (golden generator) vs jnp model.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ref.FAMILIES)
def test_scalar_matches_jnp(family):
    rng = np.random.default_rng(8)
    A, B = rand_mat(rng, 6, 5), rand_mat(rng, 5, 7)
    for k in (0, 1, 5, 8):
        ys = ref.matmul_scalar(A, B, k, family=family)
        yj = np.array(ref.axmm_ref(A, B, k, family=family))
        assert (ys == yj).all()


@given(a=st.integers(-128, 127), b=st.integers(-128, 127),
       k=st.integers(0, 10), fam=st.sampled_from(ref.FAMILIES))
@settings(max_examples=200, deadline=None)
def test_scalar_mac_bounded_deviation(a, b, k, fam):
    """|approx - exact| for one MAC is bounded by the approximated span."""
    y = ref.mac_value_scalar(a & 255, b & 255, 0, k, family=fam)
    # every approximate column can be off by at most ~N cells' worth
    bound = (1 << (k + 1)) * 8 + (1 << k)
    assert abs(y - a * b) <= bound, (a, b, k, fam, y, a * b)
