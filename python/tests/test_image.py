"""Image utilities + cross-language identity with the Rust implementation."""

import os

import numpy as np

from compile import image

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_scene_deterministic():
    assert (image.scene(64, 64) == image.scene(64, 64)).all()


def test_scene_structure():
    s = image.scene(256, 256)
    assert s.shape == (256, 256)
    assert s[0, 0] == 8 and s[255, 255] == 8           # border
    assert (s[:85] == 224).any() and (s[:85] == 32).any()  # checker
    assert (s[200:] == 240).any() and (s[200:] == 16).any()  # stripes


def test_texture_lcg_reproducible():
    a = image.texture(16, 16, seed=1234)
    b = image.texture(16, 16, seed=1234)
    c = image.texture(16, 16, seed=77)
    assert (a == b).all()
    assert (a != c).any()


def test_pgm_roundtrip(tmp_path):
    img = image.scene(32, 48)
    p = str(tmp_path / "t.pgm")
    image.write_pgm(p, img)
    back = image.read_pgm(p)
    assert (back == img).all()


def test_exported_scene_matches_generator():
    """artifacts/images/scene256.pgm (consumed by Rust) is the generator
    output — the cross-language golden."""
    p = os.path.join(ART, "images", "scene256.pgm")
    if not os.path.exists(p):
        import pytest
        pytest.skip("run `make artifacts` first")
    assert (image.read_pgm(p) == image.scene(256, 256)).all()


def test_psnr_ssim_identities():
    img = image.scene(32, 32)
    assert image.psnr(img, img) == float("inf")
    assert abs(image.ssim(img, img) - 1.0) < 1e-12
    noisy = img.copy()
    noisy[::3, ::3] = np.clip(noisy[::3, ::3].astype(int) + 15, 0, 255)
    assert 15 < image.psnr(img, noisy) < 60
    assert image.ssim(img, noisy) < 1.0


def test_psnr_symmetry():
    a = image.scene(16, 16)
    b = image.texture(16, 16)
    assert abs(image.psnr(a, b) - image.psnr(b, a)) < 1e-9
    assert abs(image.ssim(a, b) - image.ssim(b, a)) < 1e-12
