//! Latency-formula regression: the cycle-accurate array must report
//! exactly `3N - 2` compute cycles for an NxN GEMM on an NxN array (the
//! formula of [11] cited in `systolic/mod.rs` §doc), plus the documented
//! drain model (results stream out one column per cycle -> N drain
//! cycles, `total = 4N - 2`).

use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig,
                         GemmRequest};
use axsys::pe::word::PeConfig;
use axsys::systolic::Systolic;
use axsys::Family;

fn ints(seed: u64, len: usize) -> Vec<i64> {
    let mut s = seed | 1;
    (0..len).map(|_| {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        (s as i64 & 255) - 128
    }).collect()
}

#[test]
fn square_gemm_cycles_are_3n_minus_2_for_all_sizes() {
    for size in 1usize..=16 {
        let cfg = PeConfig::new(8, true, Family::Proposed, 0);
        let mut sa = Systolic::square(cfg, size);
        let a = ints(size as u64, size * size);
        let b = ints(size as u64 + 100, size * size);
        let (_, st) = sa.run_tile(&a, &b, size);
        assert_eq!(st.cycles, (3 * size - 2) as u64, "compute, size={size}");
        assert_eq!(st.drain_cycles, size as u64, "drain, size={size}");
        assert_eq!(st.total_cycles(), (4 * size - 2) as u64, "total, size={size}");
        assert_eq!(st.tiles, 1);
        assert_eq!(st.macs, (size * size * size) as u64);
    }
}

#[test]
fn rectangular_tile_cycles_follow_the_general_skew_formula() {
    // the 3N-2 formula is the square special case of
    // (rows-1) + (cols-1) + K compute cycles
    let cfg = PeConfig::new(8, true, Family::Proposed, 0);
    for (rows, cols, kk) in [(3usize, 5usize, 7usize), (8, 2, 1), (1, 1, 9)] {
        let mut sa = Systolic::new(cfg, rows, cols);
        let a = ints(7, rows * kk);
        let b = ints(8, kk * cols);
        let (_, st) = sa.run_tile(&a, &b, kk);
        assert_eq!(st.cycles, (rows - 1 + cols - 1 + kk) as u64,
                   "({rows},{cols},{kk})");
        assert_eq!(st.drain_cycles, cols as u64);
    }
}

#[test]
fn served_systolic_requests_report_the_formula_cycles() {
    // one 8x8x8 request = exactly one tile through the serving path:
    // the response must carry the 3*8-2 = 22 compute + 8 drain cycles
    let c = Coordinator::new(CoordinatorConfig {
        workers: 1,
        backend: BackendKind::Systolic,
        ..Default::default()
    });
    let resp = c.call(GemmRequest {
        a: ints(1, 64),
        b: ints(2, 64),
        m: 8,
        kk: 8,
        nn: 8,
        k: 0,
        ..Default::default()
    });
    assert_eq!(resp.sa_stats.tiles, 1);
    assert_eq!(resp.sa_stats.cycles, 22);
    assert_eq!(resp.sa_stats.drain_cycles, 8);
    assert_eq!(resp.sa_stats.total_cycles(), 30);
    c.shutdown();
}
