//! Event-loop server tests at connection scale, plus the bugfix-sweep
//! regressions that a big poll set cannot tolerate: ≥1k concurrent
//! loopback connections with zero lost/reordered/corrupted replies and
//! exactly-accounted wire bytes, and a panicking request handler that
//! neither kills its connection's neighbors nor poisons fleet stats.

use std::sync::Arc;

use axsys::apps::bdcn::{Block, Tensor};
use axsys::apps::image::scene;
use axsys::coordinator::{AppKind, BackendKind, Coordinator,
                         CoordinatorConfig};
use axsys::net::client::Client;
use axsys::net::loadgen::{self, ScaleConfig};
use axsys::net::proto::{self, ErrCode, Frame};
use axsys::net::server::{NetServer, ServerConfig};
use axsys::net::NetError;

fn start(workers: usize, cfg: ServerConfig) -> (Arc<Coordinator>, NetServer) {
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers,
        backend: BackendKind::Word,
        ..Default::default()
    }));
    let server = NetServer::bind("127.0.0.1:0", coord.clone(), cfg)
        .expect("bind loopback");
    (coord, server)
}

/// Wire size of one frame (length prefix included), for exact byte
/// accounting against the server's counters.
fn wire_len(f: &Frame) -> u64 {
    let mut buf = Vec::new();
    proto::encode(f, &mut buf).expect("encodable");
    buf.len() as u64
}

#[test]
fn thousand_concurrent_connections_lose_and_reorder_nothing() {
    const CONNS: usize = 1100;
    const PER_CONN: usize = 3;
    let (_coord, server) = start(2, ServerConfig::default());
    let cfg = ScaleConfig {
        addr: server.local_addr().to_string(),
        conns: CONNS,
        per_conn: PER_CONN,
        threads: 0,
    };
    // run_scale itself verifies every reply against its request's
    // unique tag — an Ok return *is* the zero-loss/zero-reorder proof
    let doc = loadgen::run_scale(&cfg).expect("scale run");
    assert_eq!(doc.get("served_requests"),
               Some(&axsys::bench::Json::Int((CONNS * PER_CONN) as i64)),
               "open-files limit clamped the run below the target scale");
    let ns = server.stats();
    assert_eq!(ns.gemm_requests, (CONNS * PER_CONN) as u64);
    assert_eq!(ns.error_replies, 0);
    assert_eq!(ns.frames_out as usize, CONNS * PER_CONN + 1); // + stats
    // exact inbound byte accounting = the bounded-memory story: every
    // frame the clients sent was parsed and consumed, nothing else
    let tag_req = Frame::GemmReq(proto::GemmReq {
        k: 0, m: 1, kk: 1, nn: 1, a: vec![7], b: vec![1],
    });
    let want_in = (CONNS * PER_CONN) as u64 * wire_len(&tag_req)
        + wire_len(&Frame::StatsReq);
    assert_eq!(ns.bytes_in, want_in);
    server.shutdown();
}

#[test]
fn opened_equals_closed_after_drain() {
    let (_coord, server) = start(2, ServerConfig {
        shards: 3, // exercise an explicit non-default shard count too
        ..Default::default()
    });
    let cfg = ScaleConfig {
        addr: server.local_addr().to_string(),
        conns: 40,
        per_conn: 2,
        threads: 4,
    };
    loadgen::run_scale(&cfg).expect("scale run");
    // client sockets are gone; give the shards a beat to observe the
    // EOFs, then verify the live registries fully drained
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let ns = server.stats();
        if ns.connections_closed >= 40 || std::time::Instant::now() > deadline {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let ns = server.stats();
    assert!(ns.connections_opened >= 41); // 40 + the stats probe
    assert!(ns.connections_closed >= 40,
            "shards failed to reap closed connections: opened {} closed {}",
            ns.connections_opened, ns.connections_closed);
    server.shutdown();
}

/// Structurally-broken BDCN weights: the shapes promise more data than
/// the tensors hold, so serving a `bdcn` request panics inside the
/// forward pass — in a resolver thread, mid-request.
fn bogus_blocks() -> Vec<Block> {
    let mk = |kh: usize, kw: usize, ci: usize, co: usize| Tensor {
        shape: [kh, kw, ci, co],
        data: vec![1], // far too short for the declared shape
    };
    (0..axsys::apps::bdcn::N_BLOCKS)
        .map(|_| Block {
            w1: mk(3, 3, 1, 4),
            w2: mk(3, 3, 4, 4),
            side: mk(1, 1, 4, 1),
        })
        .collect()
}

#[test]
fn handler_panic_answers_internal_and_stats_survive() {
    let (_coord, server) = start(2, ServerConfig {
        bdcn: Some(Arc::new(bogus_blocks())),
        ..Default::default()
    });
    let mut client = Client::connect(server.local_addr()).unwrap();
    // the panicking request gets a typed Internal error, not a hang or
    // a dropped connection
    let img = scene(16, 16);
    match client.app(AppKind::Bdcn, &img, 0) {
        Err(NetError::Server { code, .. }) => {
            assert_eq!(code, ErrCode::Internal);
        }
        other => panic!("expected a typed Internal error, got {other:?}"),
    }
    // the same connection keeps serving afterwards...
    let got = client.gemm(&[3], &[5], 1, 1, 1, 0).unwrap();
    assert_eq!(got.out, vec![15]);
    // ...and both stats surfaces still answer (no poisoned locks)
    let ws = client.stats().unwrap();
    assert!(ws.frames_in >= 3);
    let ns = server.stats();
    assert_eq!(ns.error_replies, 1);
    assert_eq!(ns.app_requests, 1);
    server.shutdown();
}
