//! Golden PSNR regression tests on the coordinator-served path.
//!
//! The checked-in images under `tests/data/` were tuned against the
//! bit-exact Python oracle so the served pipelines land exactly on the
//! paper's §V headline numbers:
//!
//! * `golden_dct.pgm` (128x128): DCT reconstruction-vs-input PSNR is
//!   **38.21 dB** at the approximate design point (proposed family,
//!   k = 5) and 42.43 dB at the exact point (oracle-measured
//!   38.215223 / 42.426121 dB);
//! * `golden_edge.pgm` (128x128): edge-map approximate-vs-exact PSNR is
//!   **30.45 dB** at k = 4 (oracle-measured 30.449833 dB).
//!
//! Any arithmetic drift anywhere in the served stack — PE model, LUT
//! automaton, tiling, im2col lowering, requantization — moves these by
//! far more than the ±0.05 dB tolerance.

use std::path::PathBuf;

use axsys::apps::image::{read_pgm, scene, Image};
use axsys::apps::{dct, edge, CoordinatorGemm, WordGemm};
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use axsys::pe::word::PeConfig;
use axsys::Family;

const TOL_DB: f64 = 0.05;

fn golden(name: &str) -> Image {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data")
        .join(name);
    let img = read_pgm(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()));
    assert_eq!((img.h, img.w), (128, 128), "golden image shape");
    img
}

#[test]
fn dct_served_psnr_pins_the_paper_38_21_db() {
    let img = golden("golden_dct.pgm");
    for backend in [BackendKind::Word, BackendKind::Lut] {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 4, backend, ..Default::default()
        });
        // exact design point through the serving path
        let exact = c.serve_dct(&img, 0);
        assert!((exact.psnr_db - 42.43).abs() <= TOL_DB,
                "{backend:?} exact DCT PSNR {} != 42.43±{TOL_DB}",
                exact.psnr_db);
        // approximate design point (proposed, k = 5): the headline number
        let apx = c.serve_dct(&img, 5);
        assert!((apx.psnr_db - 38.21).abs() <= TOL_DB,
                "{backend:?} approx DCT PSNR {} != 38.21±{TOL_DB}",
                apx.psnr_db);
        assert!(apx.gemm_requests >= 4, "4 GEMM stages per pipeline");
        c.shutdown();
    }
}

#[test]
fn edge_served_psnr_pins_the_paper_30_45_db() {
    let img = golden("golden_edge.pgm");
    for backend in [BackendKind::Word, BackendKind::Lut] {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 4, backend, ..Default::default()
        });
        // exact design point: served result is bit-identical to the
        // single-threaded exact pipeline (self-PSNR infinite)
        let exact = c.serve_edge(&img, 0);
        assert!(exact.psnr_db.is_infinite());
        let mut wg = WordGemm {
            cfg: PeConfig::new(8, true, Family::Proposed, 0),
        };
        assert_eq!(exact.out.data, edge::pipeline(&mut wg, &img).data,
                   "{backend:?} served exact edge must be bit-identical");
        // approximate design point (proposed, k = 4): the headline number
        let apx = c.serve_edge(&img, 4);
        assert!((apx.psnr_db - 30.45).abs() <= TOL_DB,
                "{backend:?} approx edge PSNR {} != 30.45±{TOL_DB}",
                apx.psnr_db);
        c.shutdown();
    }
}

#[test]
fn served_pipelines_bit_identical_to_single_threaded_on_all_backends() {
    // the acceptance gate: DCT and edge through the coordinator on
    // word/lut/systolic == the pre-existing single-threaded WordGemm
    // path, at both the exact and an approximate design point
    let img = scene(64, 64);
    for k in [0u32, 5] {
        let cfg = PeConfig::new(8, true, Family::Proposed, k);
        let mut wg = WordGemm { cfg };
        let (dct_want, coeff_want) = dct::pipeline(&mut wg, &img);
        let edge_want = edge::pipeline(&mut wg, &img);
        for backend in [BackendKind::Word, BackendKind::Lut,
                        BackendKind::Systolic] {
            let c = Coordinator::new(CoordinatorConfig {
                workers: 3, backend, ..Default::default()
            });
            let mut g = CoordinatorGemm::new(&c, k);
            let (dct_got, coeff_got) = dct::pipeline(&mut g, &img);
            assert_eq!(dct_got.data, dct_want.data, "dct {backend:?} k={k}");
            assert_eq!(coeff_got, coeff_want, "coeffs {backend:?} k={k}");
            assert_eq!(edge::pipeline(&mut g, &img).data, edge_want.data,
                       "edge {backend:?} k={k}");
            // and the app endpoints serve the same bits
            assert_eq!(c.serve_dct(&img, k).out.data, dct_want.data,
                       "serve_dct {backend:?} k={k}");
            assert_eq!(c.serve_edge(&img, k).out.data, edge_want.data,
                       "serve_edge {backend:?} k={k}");
            c.shutdown();
        }
    }
}
