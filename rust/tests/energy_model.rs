//! Energy-subsystem consistency + golden tests (DESIGN.md §4).
//!
//! * **Consistency:** for random operand streams across all six cell
//!   families × signedness × k, `EnergyLut` aggregation equals direct
//!   netlist activity-replay energy **exactly** (same f64 values, same
//!   order), and the systolic-sim meter (netlist replay per MAC) agrees
//!   with the blocked-GEMM meters (table lookups) on identical requests.
//! * **Golden:** the 8×8 array-level energy savings of the proposed
//!   exact and approximate PEs vs the conventional-MAC baseline,
//!   computed through the per-MAC model on a fixed synthetic stream,
//!   reproduce the oracle-pinned values (Python port of the netlist +
//!   library, differentially validated against the word model) — the
//!   model's rendition of the paper's ~22% / ~32% headline.

use axsys::bench::xorshift_ints as ints;
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig,
                         GemmRequest};
use axsys::energy::{self, EnergyLut, Replayer};
use axsys::gemm::BlockedGemm;
use axsys::pe::lut;
use axsys::pe::word::PeConfig;
use axsys::pe::{Design, Signedness};
use axsys::systolic::Systolic;
use axsys::Family;

fn chain(seed: u64, len: usize) -> Vec<(i64, i64)> {
    ints(seed, len).into_iter().zip(ints(seed ^ 0xDEAD, len)).collect()
}

fn close(a: f64, b: f64, rel: f64) -> bool {
    (a - b).abs() <= rel * a.abs().max(b.abs()).max(1e-12)
}

#[test]
fn lut_aggregation_equals_replay_exactly_all_families() {
    // n = 4: every family × signedness × k, tiny tables, exhaustive-ish
    for family in Family::ALL {
        for signed in [Signedness::Signed, Signedness::Unsigned] {
            for k in [0u32, 1, 2, 3, 4] {
                let d = Design { n: 4, signed, family, k,
                                 optimized_exact: true };
                let elut = EnergyLut::try_build(&d).expect("4-bit builds");
                let mut rep = Replayer::new(&d);
                for seed in [7u64, 19, 311] {
                    let ops = chain(seed.wrapping_mul(k as u64 + 1), 48);
                    assert_eq!(elut.chain_fj(&ops), rep.chain_fj(&ops),
                               "{family:?} {signed:?} k={k} seed={seed}");
                }
            }
        }
    }
    // n = 8 spot checks (bigger tables; exactness must still be bit-level)
    for (family, signed, k) in [(Family::Proposed, true, 2u32),
                                (Family::Nano6, false, 2)] {
        let d = Design {
            n: 8,
            signed: if signed { Signedness::Signed } else { Signedness::Unsigned },
            family, k, optimized_exact: true,
        };
        let elut = energy::cached_design(&d).expect("8-bit builds");
        let mut rep = Replayer::new(&d);
        let ops = chain(0xC0FFEE ^ k as u64, 200);
        assert_eq!(elut.chain_fj(&ops), rep.chain_fj(&ops),
                   "{family:?} signed={signed} k={k}");
    }
}

#[test]
fn blocked_meters_agree_with_systolic_replay_meter() {
    // same request, three independent meters: the lut kernel walks the
    // automaton, the word kernel recovers states from live rails, the
    // systolic array replays the netlist gate by gate — all must charge
    // the same energy (tolerance: cross-element f64 summation order).
    // The shape tiles the 4x4 array evenly: ragged tiles would add
    // zero-operand padding MACs that only the systolic meter sees.
    let (m, kk, nn) = (12usize, 10usize, 8usize);
    let a = ints(41, m * kk);
    let b = ints(42, kk * nn);
    for k in [0u32, 3] {
        let cfg = PeConfig::new(8, true, Family::Proposed, k);
        let elut = energy::cached(&cfg).expect("tabulable");
        let plut = lut::cached(&cfg).expect("compilable");
        let mut eng = BlockedGemm::default();
        eng.set_meter(Some(elut.clone()));
        let out_lut = eng.matmul_lut(&plut, &a, &b, m, kk, nn);
        let e_lut = eng.take_energy_fj();
        let out_word = eng.matmul_word(&cfg, &a, &b, m, kk, nn);
        let e_word = eng.take_energy_fj();
        let mut sa = Systolic::square(cfg, 4);
        sa.enable_meter();
        let (out_sa, st) = sa.gemm(&a, &b, m, kk, nn);
        assert_eq!(out_lut, out_word, "k={k}");
        assert_eq!(out_lut, out_sa, "k={k}");
        assert_eq!(st.metered_macs, st.macs);
        assert!(e_lut > 0.0);
        assert!(close(e_lut, e_word, 1e-9), "k={k}: {e_lut} vs {e_word}");
        assert!(close(e_lut, st.energy_fj, 1e-9),
                "k={k}: blocked {e_lut} vs systolic {}", st.energy_fj);
    }
}

#[test]
fn metered_lane_lut_sweep_matches_scalar_lut_meter() {
    // the 64-chain LUT lane sweep under the meter vs the 8-chain scalar
    // sweep: identical bits and identical per-MAC energy-table reads
    // (f64 summation order is the only tolerated difference). The
    // column range straddles the 64-chain engagement width, so the
    // sweep covers full lane groups, the ragged chain tail, and the
    // narrow shapes that never reach the lane loop.
    for (m, kk, nn) in [(6usize, 18usize, 96usize), (5, 9, 70), (4, 30, 12)] {
        let a = ints(0x1A0E ^ nn as u64, m * kk);
        let b = ints(0x52EE ^ nn as u64, kk * nn);
        for k in [2u32, 4] {
            let cfg = PeConfig::new(8, true, Family::Proposed, k);
            let elut = energy::cached(&cfg).expect("tabulable");
            let plut = lut::cached(&cfg).expect("compilable");
            let mut lane = BlockedGemm::default();
            let mut scalar = BlockedGemm::default();
            scalar.set_lane_kernel(false);
            lane.set_meter(Some(elut.clone()));
            scalar.set_meter(Some(elut.clone()));
            let out_lane = lane.matmul_lut(&plut, &a, &b, m, kk, nn);
            let e_lane = lane.take_energy_fj();
            let out_scalar = scalar.matmul_lut(&plut, &a, &b, m, kk, nn);
            let e_scalar = scalar.take_energy_fj();
            assert_eq!(out_lane, out_scalar, "{m}x{kk}x{nn} k={k}");
            assert_eq!(out_lane,
                       axsys::pe::word::matmul(&cfg, &a, &b, m, kk, nn),
                       "{m}x{kk}x{nn} k={k} vs word");
            assert!(e_scalar > 0.0, "{m}x{kk}x{nn} k={k}: meter idle");
            assert!(close(e_lane, e_scalar, 1e-9),
                    "{m}x{kk}x{nn} k={k}: lane {e_lane} vs scalar {e_scalar}");
        }
    }
}

#[test]
fn served_energy_is_backend_independent_and_fully_covered() {
    let (m, kk, nn) = (16usize, 8usize, 16usize);
    let a = ints(51, m * kk);
    let b = ints(52, kk * nn);
    let mut energies = Vec::new();
    for backend in [BackendKind::Lut, BackendKind::Word,
                    BackendKind::Systolic] {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 3, backend, ..Default::default()
        });
        let resp = c.call(GemmRequest {
            a: a.clone(), b: b.clone(), m, kk, nn, k: 2,
            ..Default::default()
        });
        assert_eq!(resp.sa_stats.metered_macs, resp.sa_stats.macs,
                   "{backend:?}: full meter coverage");
        assert!(resp.energy_uj() > 0.0, "{backend:?}");
        energies.push((backend, resp.sa_stats.energy_fj));
        let s = c.stats();
        assert!(close(s.energy_fj, resp.sa_stats.energy_fj, 1e-12),
                "{backend:?}: fleet total");
        c.shutdown();
    }
    // identical per-MAC model behind every backend (the systolic path
    // pads ragged tiles with zero-operand MACs; this shape tiles evenly,
    // so all three meter exactly the same MAC population)
    let (b0, e0) = energies[0];
    for &(bk, e) in &energies[1..] {
        assert!(close(e0, e, 1e-9), "{b0:?} {e0} vs {bk:?} {e}");
    }
}

#[test]
fn wide_design_points_serve_unmetered_but_correct() {
    // n = 16 has no energy table: the word backend must still serve the
    // request (bit-correct), just with zero meter coverage
    let c = Coordinator::new(CoordinatorConfig {
        workers: 2,
        backend: BackendKind::Word,
        n_bits: 16,
        ..Default::default()
    });
    let (m, kk, nn) = (9usize, 6usize, 7usize);
    let a = ints(61, m * kk);
    let b = ints(62, kk * nn);
    let resp = c.call(GemmRequest { a: a.clone(), b: b.clone(), m, kk, nn,
                                    k: 3, ..Default::default() });
    let cfg = PeConfig::new(16, true, Family::Proposed, 3);
    // reference through the same tiling the coordinator applies
    let mut want = vec![0i64; m * nn];
    for ti in (0..m).step_by(8) {
        for tj in (0..nn).step_by(8) {
            let th = (m - ti).min(8);
            let tw = (nn - tj).min(8);
            let ap: Vec<i64> = (0..th)
                .flat_map(|i| a[(ti + i) * kk..(ti + i + 1) * kk].to_vec())
                .collect();
            let bp: Vec<i64> = (0..kk)
                .flat_map(|t| b[t * nn + tj..t * nn + tj + tw].to_vec())
                .collect();
            let tile = axsys::pe::word::matmul(&cfg, &ap, &bp, th, kk, tw);
            for i in 0..th {
                for j in 0..tw {
                    want[(ti + i) * nn + tj + j] = tile[i * tw + j];
                }
            }
        }
    }
    assert_eq!(resp.out, want);
    assert_eq!(resp.sa_stats.metered_macs, 0, "no table for n = 16");
    assert_eq!(resp.energy_uj(), 0.0);
    c.shutdown();
}

// ---------------------------------------------------------------------
// Golden numbers — oracle-pinned (Python port of netlist + library;
// see DESIGN.md §4 for derivation and the deviation discussion).
// ---------------------------------------------------------------------

/// The fixed synthetic stream behind the goldens: 4096 signed-8-bit
/// MACs replayed as 64 chains of 64.
fn golden_stream() -> (Vec<i64>, Vec<i64>) {
    (ints(0xE7E5, 4096), ints(0x1A7B, 4096))
}

#[test]
fn golden_mean_mac_energies() {
    let (a, b) = golden_stream();
    for (label, d, want) in [
        ("exact [6]",
         Design::conventional_exact(8, Signedness::Signed), 55.136053455),
        ("proposed exact",
         Design::proposed_exact(8, Signedness::Signed), 50.520325745),
        ("proposed approx k=7",
         Design::approximate_default(8, Signedness::Signed, Family::Proposed),
         45.496647502),
    ] {
        let got = energy::mean_mac_fj(&d, &a, &b, 64);
        assert!(close(got, want, 1e-6), "{label}: {got} vs oracle {want}");
    }
    let conv = energy::conventional_mean_mac_fj(8, false, &a, &b);
    assert!(close(conv, 69.680298499, 1e-6), "gemmini MAC: {conv}");
    let hafsa = energy::conventional_mean_mac_fj(8, true, &a, &b);
    assert!(close(hafsa, 72.669358569, 1e-6), "HA-FSA MAC: {hafsa}");
}

#[test]
fn golden_array_savings_reproduce_paper_headline() {
    // paper: the proposed 8-bit exact and approximate PEs in an 8x8
    // array save ~22% and ~32% energy vs the existing design. Through
    // the per-MAC model the savings vs the conventional-MAC baseline
    // land at 26.73% / 33.74% (oracle-pinned; the exact-PE saving
    // overshoots the paper by ~5 points — DESIGN.md §6 discusses why).
    let (a, b) = golden_stream();
    let e6 = energy::mean_mac_fj(
        &Design::conventional_exact(8, Signedness::Signed), &a, &b, 64);
    let pe = energy::mean_mac_fj(
        &Design::proposed_exact(8, Signedness::Signed), &a, &b, 64);
    let pa = energy::mean_mac_fj(
        &Design::approximate_default(8, Signedness::Signed, Family::Proposed),
        &a, &b, 64);
    let conv = energy::conventional_mean_mac_fj(8, false, &a, &b);
    // orderings first: approx < exact < exact [6] < conventional MAC
    assert!(pa < pe, "approx PE must be cheaper than exact: {pa} vs {pe}");
    assert!(pe < e6, "proposed exact must beat exact [6]: {pe} vs {e6}");
    assert!(e6 < conv, "fused PEs must beat the conventional MAC: {e6} vs {conv}");
    let arr = |fj| energy::array_fj_per_cycle(fj, 8, 8);
    let s_exact = 1.0 - arr(pe) / arr(conv);
    let s_apx = 1.0 - arr(pa) / arr(conv);
    // oracle-pinned band
    assert!((s_exact - 0.267291).abs() < 1.5e-3,
            "exact 8x8 saving drifted: {s_exact}");
    assert!((s_apx - 0.337374).abs() < 1.5e-3,
            "approx 8x8 saving drifted: {s_apx}");
    // and the paper-ballpark band the reproduction must stay inside
    assert!((0.15..=0.45).contains(&s_exact), "{s_exact}");
    assert!((0.15..=0.45).contains(&s_apx), "{s_apx}");
    assert!(s_apx > s_exact, "approximation must increase the saving");
}

#[test]
fn golden_energy_decreases_with_k() {
    // more approximate columns -> less switched energy, monotonically
    let a = ints(0xA11CE, 512);
    let b = ints(0xB0B, 512);
    let want = [(0u32, 50.729141), (2, 50.364719), (4, 49.133676),
                (6, 47.019692), (8, 44.342738)];
    let mut prev = f64::INFINITY;
    for (k, oracle) in want {
        let d = Design::approximate(8, Signedness::Signed,
                                    Family::Proposed, k);
        let got = energy::mean_mac_fj(&d, &a, &b, 32);
        assert!(close(got, oracle, 1e-5), "k={k}: {got} vs {oracle}");
        assert!(got < prev, "k={k}: {got} !< {prev}");
        prev = got;
    }
}
