//! Property-based differential fuzz: on randomly drawn design points and
//! shapes, every engine must produce bit-identical GEMM results.
//!
//! * `lut == word == systolic` over (m, kk, nn) up to 48, three operand
//!   ranges, all six cell families, k in 0..=6, signed and unsigned;
//! * the cache-blocked driver (`gemm::BlockedGemm`, both lut and word
//!   engines, including deliberately ragged block sizes that never
//!   divide the problem shape) equals the naive `lut`/`word` walks on
//!   the same sweep;
//! * `CoordinatorGemm` (the served, tiled, multi-worker path) equals the
//!   single-threaded `WordGemm` on the same sweep (signed — the
//!   coordinator's device configs are signed);
//! * intra-request fan-out (row/column-block tiling across worker
//!   counts and MAC-budgeted batch drains) equals both the
//!   single-threaded blocked engine and the naive word walk, and its
//!   per-tile metered energy sums to the single-threaded total;
//! * the zoo's accuracy router (`zoo::route` / `zoo::route_among`)
//!   picks the cheapest satisfying design point — or refuses with a
//!   typed error — on 256 seeded random SLOs, word shapes, and
//!   registry subsets.
//!
//! Deterministic xorshift PRNG. The master seed comes from `PROP_SEED`
//! (CI pins it; default below), and every case derives its own sub-seed
//! that is printed in the panic message — re-running with
//! `PROP_SEED=<master>` reproduces the exact failing sweep, and the
//! reported per-case seed identifies the single shrunk repro.

use axsys::apps::{CoordinatorGemm, Gemm, WordGemm};
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig,
                         GemmRequest};
use axsys::energy;
use axsys::gemm::{BlockSizes, BlockedGemm};
use axsys::pe::lut::matmul as lut_matmul;
use axsys::pe::word::{matmul as word_matmul, PeConfig};
use axsys::systolic::Systolic;
use axsys::Family;

const DEFAULT_SEED: u64 = 0xA55_ED_5EED;
/// Full sweep in release (the CI pinned-seed run); a reduced prefix of
/// the same deterministic sequence in debug so `cargo test -q` stays
/// fast — the cycle-accurate systolic leg dominates unoptimized builds.
const TRIPLE_CASES: usize = if cfg!(debug_assertions) { 120 } else { 500 };
const COORD_CASES_PER_FAMILY: usize = if cfg!(debug_assertions) { 15 } else { 40 };

fn master_seed() -> u64 {
    std::env::var("PROP_SEED").ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_SEED)
}

struct XorShift(u64);

impl XorShift {
    fn new(seed: u64) -> Self {
        XorShift(seed | 1)
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// One randomly drawn case: design point + shape + operands.
struct Case {
    seed: u64,
    family: Family,
    signed: bool,
    k: u32,
    m: usize,
    kk: usize,
    nn: usize,
    a: Vec<i64>,
    b: Vec<i64>,
}

impl Case {
    /// Derive everything from one per-case seed (the shrunk repro unit).
    fn draw(seed: u64, force_signed: bool) -> Case {
        let mut r = XorShift::new(seed);
        let family = Family::ALL[r.below(Family::ALL.len() as u64) as usize];
        let signed = force_signed || r.below(2) == 0;
        let k = r.below(7) as u32; // 0..=6
        let m = 1 + r.below(48) as usize;
        let kk = 1 + r.below(48) as usize;
        let nn = 1 + r.below(48) as usize;
        // operand ranges: full 8-bit, narrow, and boolean-ish
        let draw_range = r.below(3);
        let mut draw = |len: usize| -> Vec<i64> {
            (0..len).map(|_| {
                let v = r.next();
                match draw_range {
                    0 => {
                        if signed { (v as i64 & 255) - 128 } else { v as i64 & 255 }
                    }
                    1 => {
                        if signed { (v as i64 & 15) - 8 } else { v as i64 & 15 }
                    }
                    _ => (v & 1) as i64,
                }
            }).collect()
        };
        let a = draw(m * kk);
        let b = draw(kk * nn);
        Case { seed, family, signed, k, m, kk, nn, a, b }
    }

    fn cfg(&self) -> PeConfig {
        PeConfig::new(8, self.signed, self.family, self.k)
    }

    fn describe(&self, master: u64) -> String {
        format!("case seed {:#x} (master PROP_SEED={}): {:?} signed={} k={} \
                 shape ({}, {}, {})",
                self.seed, master, self.family, self.signed, self.k,
                self.m, self.kk, self.nn)
    }
}

#[test]
fn fuzz_lut_word_systolic_bit_identical() {
    let master = master_seed();
    let mut rng = XorShift::new(master);
    for i in 0..TRIPLE_CASES {
        let case = Case::draw(rng.next(), false);
        let cfg = case.cfg();
        let want = word_matmul(&cfg, &case.a, &case.b, case.m, case.kk, case.nn);
        let lut = lut_matmul(&cfg, &case.a, &case.b, case.m, case.kk, case.nn);
        assert_eq!(lut, want, "lut != word [{i}] {}", case.describe(master));
        // vary the array geometry too: ragged tiles are part of the sweep
        let (rows, cols) = (1 + (case.seed % 8) as usize,
                            1 + ((case.seed >> 8) % 8) as usize);
        let (sys, st) = Systolic::new(cfg, rows, cols)
            .gemm(&case.a, &case.b, case.m, case.kk, case.nn);
        assert_eq!(sys, want,
                   "systolic({rows}x{cols}) != word [{i}] {}",
                   case.describe(master));
        assert!(st.macs > 0);
    }
}

#[test]
fn fuzz_blocked_matches_naive_over_ragged_shapes() {
    // blocked == naive == word for shapes that are never multiples of
    // the block sizes: the per-element state must survive KC panel
    // boundaries and MC/NC remainders bit-exactly
    let master = master_seed();
    let mut rng = XorShift::new(master.wrapping_add(2));
    let cases = if cfg!(debug_assertions) { 120 } else { 400 };
    // awkward blocks exercise raggedness on nearly every case; the
    // default blocks exercise the production configuration
    let mut engines = [
        BlockedGemm::new(BlockSizes { mc: 5, kc: 7, nc: 3 }),
        BlockedGemm::default(),
    ];
    for i in 0..cases {
        let case = Case::draw(rng.next(), false);
        let cfg = case.cfg();
        let want = word_matmul(&cfg, &case.a, &case.b, case.m, case.kk, case.nn);
        let naive_lut = lut_matmul(&cfg, &case.a, &case.b,
                                   case.m, case.kk, case.nn);
        assert_eq!(naive_lut, want, "naive lut != word [{i}] {}",
                   case.describe(master));
        for (ei, eng) in engines.iter_mut().enumerate() {
            let lut = eng.matmul(&cfg, &case.a, &case.b,
                                 case.m, case.kk, case.nn);
            assert_eq!(lut, want, "blocked(lut)[{ei}] != word [{i}] {}",
                       case.describe(master));
            let word = eng.matmul_word(&cfg, &case.a, &case.b,
                                       case.m, case.kk, case.nn);
            assert_eq!(word, want, "blocked(word)[{ei}] != word [{i}] {}",
                       case.describe(master));
        }
    }
}

#[test]
fn fuzz_fanout_matches_single_threaded_blocked_and_naive() {
    // Intra-request fan-out: a served request split into row/column
    // blocks across several workers under a MAC-budgeted batch drain
    // must stay bit-identical to both the single-threaded blocked
    // engine and the naive word walk, with the per-tile metered
    // femtojoules summing to the single-threaded meter's total (exact
    // in real arithmetic — same multiset of per-MAC table reads — so
    // only f64 summation-order rounding is tolerated).
    let master = master_seed();
    let mut rng = XorShift::new(master.wrapping_add(3));
    let cases = if cfg!(debug_assertions) { 10 } else { 30 };
    // (workers, sw tile, batch MAC budget): serial per-tile, paired
    // workers with an aggressive budget, and a wide ragged-tile pool
    let pools: Vec<(Coordinator, String)> =
        [(1usize, (8usize, 8usize), 1u64 << 20),
         (2, (16, 24), 1),
         (5, (8, 40), 2_000)]
        .into_iter()
        .map(|(workers, (tr, tc), batch_macs)| {
            let c = Coordinator::new(CoordinatorConfig {
                workers,
                backend: BackendKind::Word,
                sw_tile: Some((tr, tc)),
                batch_macs,
                ..Default::default()
            });
            (c, format!("workers={workers} tile={tr}x{tc} \
                         budget={batch_macs}"))
        })
        .collect();
    for i in 0..cases {
        let mut case = Case::draw(rng.next(), true);
        case.family = Family::Proposed; // meterable design points
        let cfg = case.cfg();
        let want = word_matmul(&cfg, &case.a, &case.b,
                               case.m, case.kk, case.nn);
        let meter = energy::cached(&cfg);
        let mut eng = BlockedGemm::single_threaded(BlockSizes::default());
        eng.set_meter(meter.clone());
        let st = eng.matmul_word(&cfg, &case.a, &case.b,
                                 case.m, case.kk, case.nn);
        let ref_fj = eng.take_energy_fj();
        assert_eq!(st, want, "blocked != word [{i}] {}",
                   case.describe(master));
        let macs = (case.m * case.kk * case.nn) as u64;
        let expect_metered = if meter.is_some() { macs } else { 0 };
        for (c, desc) in &pools {
            let resp = c.call(GemmRequest {
                a: case.a.clone(), b: case.b.clone(),
                m: case.m, kk: case.kk, nn: case.nn, k: case.k,
                ..Default::default()
            });
            assert_eq!(resp.out, want, "fanout({desc}) != word [{i}] {}",
                       case.describe(master));
            assert_eq!(resp.sa_stats.metered_macs, expect_metered,
                       "fanout({desc}) meter coverage [{i}] {}",
                       case.describe(master));
            let tol = 1e-9 * ref_fj.abs().max(1.0);
            assert!((resp.sa_stats.energy_fj - ref_fj).abs() < tol,
                    "fanout({desc}) energy {} != {} [{i}] {}",
                    resp.sa_stats.energy_fj, ref_fj, case.describe(master));
        }
    }
    for (c, _) in pools {
        c.shutdown();
    }
}

#[test]
fn fuzz_metered_lane_matches_metered_scalar() {
    // The fused metered lane kernels (the 64-lane word kernel and the
    // 64-chain LUT sweep) against the metered scalar walk, across
    // families, signedness, and k: bits must be exact and the
    // accumulated femtojoules within 1e-9 relative — both sides read
    // the identical multiset of per-MAC energy-table entries, so f64
    // summation order is the only admissible difference. Shapes from
    // Case::draw straddle the 32-column lane gate in both directions
    // (nn in 1..=48), so the sweep covers lane engagement, the narrow
    // scalar fallback, and the ragged last lane group.
    let master = master_seed();
    let mut rng = XorShift::new(master.wrapping_add(5));
    let cases = if cfg!(debug_assertions) { 40 } else { 150 };
    // a ragged blocking (nc = 48 keeps panels above the lane gate) and
    // the production default
    let mut engines: Vec<(BlockedGemm, BlockedGemm)> =
        [BlockSizes { mc: 5, kc: 7, nc: 48 }, BlockSizes::default()]
        .into_iter()
        .map(|bs| {
            let lane = BlockedGemm::single_threaded(bs);
            let mut scalar = BlockedGemm::single_threaded(bs);
            scalar.set_lane_kernel(false);
            (lane, scalar)
        })
        .collect();
    let (mut metered, mut wide) = (0usize, 0usize);
    for i in 0..cases {
        let case = Case::draw(rng.next(), false);
        let cfg = case.cfg();
        // some drawn design points have no tabulable energy model —
        // skip those, and assert below that the sweep still metered a
        // meaningful share
        let Some(meter) = energy::cached(&cfg) else { continue };
        metered += 1;
        wide += (case.nn >= 32) as usize;
        let want = word_matmul(&cfg, &case.a, &case.b,
                               case.m, case.kk, case.nn);
        for (ei, (lane, scalar)) in engines.iter_mut().enumerate() {
            lane.set_meter(Some(meter.clone()));
            scalar.set_meter(Some(meter.clone()));
            for word in [true, false] {
                let run = |e: &mut BlockedGemm| if word {
                    e.matmul_word(&cfg, &case.a, &case.b,
                                  case.m, case.kk, case.nn)
                } else {
                    e.matmul(&cfg, &case.a, &case.b,
                             case.m, case.kk, case.nn)
                };
                let got_l = run(lane);
                let fj_l = lane.take_energy_fj();
                let got_s = run(scalar);
                let fj_s = scalar.take_energy_fj();
                let eng = if word { "word" } else { "lut" };
                assert_eq!(got_l, want,
                           "metered lane({eng})[{ei}] != word [{i}] {}",
                           case.describe(master));
                assert_eq!(got_s, want,
                           "metered scalar({eng})[{ei}] != word [{i}] {}",
                           case.describe(master));
                assert!(fj_s > 0.0, "scalar({eng})[{ei}] meter idle [{i}] {}",
                        case.describe(master));
                let tol = 1e-9 * fj_s.abs().max(1.0);
                assert!((fj_l - fj_s).abs() < tol,
                        "lane({eng})[{ei}] energy {fj_l} != scalar {fj_s} \
                         [{i}] {}", case.describe(master));
            }
            lane.set_meter(None);
            scalar.set_meter(None);
        }
    }
    // the sweep must exercise both the lane gate and the fallback under
    // any seed; the floors are conservative because tabulability varies
    // across drawn (family, k) points
    assert!(metered >= cases / 10 && wide > 0,
            "sweep degenerate: {metered} metered / {wide} wide of {cases} \
             (master PROP_SEED={master})");
}

/// The accuracy-router property fuzz: seeded random SLOs (and word
/// shapes, and registry subsets) against the zoo's selection core.
const ROUTER_CASES: usize = 256;

#[test]
fn fuzz_router_picks_cheapest_satisfying_point_or_refuses_typed() {
    use axsys::zoo::{registry, route, route_among, AccuracySlo, RouteError};
    let master = master_seed();
    let mut rng = XorShift::new(master.wrapping_add(4));
    let reg = registry();
    let (mut routed, mut unsat) = (0usize, 0usize);
    for i in 0..ROUTER_CASES {
        let seed = rng.next();
        let mut r = XorShift::new(seed);
        // random SLO spanning the registry's occupied NMED/PSNR ranges,
        // from demands-exact through looser-than-everything
        let max_nmed = match r.below(4) {
            0 => None,
            1 => Some(0.0), // demands bit-exact arithmetic
            2 => Some(r.below(2_200) as f64 * 1e-5), // 0..0.022
            _ => Some(r.below(100) as f64 * 1e-7),   // ultra-tight
        };
        let min_psnr_db = match r.below(3) {
            0 => None,
            1 => Some(0.1 + r.below(800) as f64 * 0.1), // 0.1..80.1 dB
            _ => Some(200.0 + r.below(100) as f64),     // exact-only
        };
        let slo = AccuracySlo { max_nmed, min_psnr_db };
        // word shapes: mostly the registered 8-bit signed pool, with
        // uncovered shapes mixed in (the only unsatisfiable direction —
        // the registry's exact point satisfies every valid SLO)
        let (n_bits, signed) = match r.below(8) {
            0 => (16, true),
            1 => (8, false),
            _ => (8, true),
        };
        let who = format!("case seed {seed:#x} (master PROP_SEED={master}) \
                           [{i}]: slo `{slo}` n={n_bits} signed={signed}");
        if max_nmed.is_none() && min_psnr_db.is_none() {
            // an empty SLO is a client error: typed Invalid, never a
            // default route, never Unsatisfiable
            assert!(matches!(route(n_bits, signed, &slo),
                             Err(RouteError::Invalid(_))),
                    "{who}: empty SLO not refused as Invalid");
            continue;
        }
        match route(n_bits, signed, &slo) {
            Ok(e) => {
                routed += 1;
                assert_eq!((e.design.n, e.design.is_signed()),
                           (n_bits, signed), "{who}: wrong word shape");
                assert!(e.satisfies(&slo),
                        "{who}: routed {} violates the SLO", e.label());
                // cheapest: no satisfying registered point is cheaper
                for other in reg {
                    if other.satisfies(&slo) {
                        assert!(e.mean_mac_fj <= other.mean_mac_fj,
                                "{who}: {} beaten by {}",
                                e.label(), other.label());
                    }
                }
                if max_nmed == Some(0.0) {
                    assert_eq!(e.nmed, 0.0,
                               "{who}: exact demand served approximate");
                }
            }
            Err(RouteError::Unsatisfiable { n_bits: nb, signed: sg, .. }) => {
                unsat += 1;
                assert_eq!((nb, sg), (n_bits, signed), "{who}");
                assert!(
                    !reg.iter().any(|e| e.design.n == n_bits
                        && e.design.is_signed() == signed
                        && e.satisfies(&slo)),
                    "{who}: refused but a satisfying point is registered");
            }
            Err(e) => panic!("{who}: unexpected {e:?}"),
        }
        // the same SLO over a random registry subset: the selection
        // core must agree with a linear scan of that subset
        let mask = r.next();
        let subset: Vec<_> = reg.iter().enumerate()
            .filter(|(j, _)| mask >> (j % 64) & 1 == 1)
            .map(|(_, e)| e)
            .collect();
        let want_fj = subset.iter()
            .filter(|e| e.satisfies(&slo))
            .map(|e| e.mean_mac_fj)
            .fold(f64::INFINITY, f64::min);
        match route_among(subset.iter().copied(), &slo) {
            Some(e) => {
                assert!(e.satisfies(&slo), "{who}: subset pick violates");
                assert!(subset.iter().any(|s| std::ptr::eq(*s, e)),
                        "{who}: pick outside the subset");
                assert_eq!(e.mean_mac_fj, want_fj,
                           "{who}: subset pick not cheapest");
            }
            None => assert!(want_fj.is_infinite(),
                            "{who}: subset refused with a satisfying point"),
        }
    }
    // the sweep must genuinely exercise both outcomes under any seed
    // (expected ~68% routed / ~21% unsatisfiable of 256 cases)
    assert!(routed >= 80 && unsat >= 25,
            "sweep degenerate: routed={routed} unsatisfiable={unsat} \
             of {ROUTER_CASES} (master PROP_SEED={master})");
    // malformed SLOs are Invalid — never Unsatisfiable, never a route
    for bad in [AccuracySlo { max_nmed: Some(f64::NAN), min_psnr_db: None },
                AccuracySlo { max_nmed: Some(-1e-3), min_psnr_db: None },
                AccuracySlo { max_nmed: None,
                              min_psnr_db: Some(f64::INFINITY) },
                AccuracySlo { max_nmed: None, min_psnr_db: Some(0.0) },
                AccuracySlo::default()] {
        assert!(matches!(route(8, true, &bad), Err(RouteError::Invalid(_))),
                "not refused as Invalid: {bad:?}");
    }
    // uncovered word shapes are typed-unsatisfiable even for the
    // loosest SLO (the registry is 8-bit signed only)
    let loose = AccuracySlo { max_nmed: Some(1.0), min_psnr_db: None };
    assert!(matches!(route(16, true, &loose),
                     Err(RouteError::Unsatisfiable { n_bits: 16, .. })));
    assert!(matches!(route(8, false, &loose),
                     Err(RouteError::Unsatisfiable { signed: false, .. })));
}

#[test]
fn fuzz_coordinator_matches_single_threaded_word() {
    let master = master_seed();
    let mut rng = XorShift::new(master.wrapping_add(1));
    for family in Family::ALL {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 4,
            backend: BackendKind::Word,
            family,
            ..Default::default()
        });
        for i in 0..COORD_CASES_PER_FAMILY {
            let mut case = Case::draw(rng.next(), true);
            case.family = family; // the coordinator fixes family per pool
            let cfg = case.cfg();
            let want = WordGemm { cfg }
                .gemm(&case.a, &case.b, case.m, case.kk, case.nn);
            let mut g = CoordinatorGemm::new(&c, case.k);
            let got = g.gemm(&case.a, &case.b, case.m, case.kk, case.nn);
            assert_eq!(got, want,
                       "CoordinatorGemm != WordGemm [{family:?}/{i}] {}",
                       case.describe(master));
        }
        c.shutdown();
    }
}
