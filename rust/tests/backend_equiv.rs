//! Differential / property suite: every GEMM backend must produce
//! bit-identical results on the same PE design point.
//!
//! The engines compared:
//! * `word`     — bit-plane carry-save walk (the normative software model,
//!   itself pinned to the Python oracle's goldens);
//! * `lut`      — product table + carry-save-window automaton;
//! * `systolic` — cycle-accurate array simulation.
//!
//! Sweep: all six `Family` variants x k in {0, 2, 4} x signed/unsigned on
//! seeded-random matrices, plus spot checks beyond the sweep (k = 7,
//! ragged shapes, accumulation-heavy inner dimensions). `Proposed` with
//! k = 0 must additionally equal exact i64 GEMM.

use axsys::apps::{Gemm, LutGemm, SystolicGemm, WordGemm};
use axsys::pe::lut::{matmul as lut_matmul, ProductLut};
use axsys::pe::word::{matmul as word_matmul, PeConfig};
use axsys::systolic::Systolic;
use axsys::Family;

/// Seeded xorshift operand stream, drawn from the config's natural
/// operand range (signed: [-128, 127], unsigned: [0, 255]).
fn ints(seed: u64, len: usize, signed: bool) -> Vec<i64> {
    let mut s = seed | 1;
    (0..len).map(|_| {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        if signed { (s as i64 & 255) - 128 } else { s as i64 & 255 }
    }).collect()
}

fn exact(a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize) -> Vec<i64> {
    let mut out = vec![0i64; m * nn];
    for i in 0..m {
        for j in 0..nn {
            out[i * nn + j] =
                (0..kk).map(|t| a[i * kk + t] * b[t * nn + j]).sum();
        }
    }
    out
}

#[test]
fn all_backends_bit_identical_across_family_k_signedness() {
    let (m, kk, nn) = (12usize, 17usize, 9usize);
    for (fi, family) in Family::ALL.into_iter().enumerate() {
        for signed in [true, false] {
            for k in [0u32, 2, 4] {
                let cfg = PeConfig::new(8, signed, family, k);
                let a = ints(100 + fi as u64 * 7 + k as u64, m * kk, signed);
                let b = ints(200 + fi as u64 * 11 + k as u64, kk * nn, signed);
                let want = word_matmul(&cfg, &a, &b, m, kk, nn);
                let lut = lut_matmul(&cfg, &a, &b, m, kk, nn);
                assert_eq!(lut, want,
                           "lut != word: {family:?} signed={signed} k={k}");
                let (sys, _) = Systolic::new(cfg, 4, 5).gemm(&a, &b, m, kk, nn);
                assert_eq!(sys, want,
                           "systolic != word: {family:?} signed={signed} k={k}");
            }
        }
    }
}

#[test]
fn proposed_k0_matches_exact_i64_gemm() {
    let (m, kk, nn) = (10usize, 14usize, 11usize);
    for signed in [true, false] {
        let cfg = PeConfig::new(8, signed, Family::Proposed, 0);
        let a = ints(31, m * kk, signed);
        let b = ints(32, kk * nn, signed);
        let want = exact(&a, &b, m, kk, nn);
        assert_eq!(word_matmul(&cfg, &a, &b, m, kk, nn), want,
                   "word signed={signed}");
        assert_eq!(lut_matmul(&cfg, &a, &b, m, kk, nn), want,
                   "lut signed={signed}");
        let (sys, _) = Systolic::new(cfg, 8, 8).gemm(&a, &b, m, kk, nn);
        assert_eq!(sys, want, "systolic signed={signed}");
    }
}

#[test]
fn lut_matches_word_at_high_k_and_long_chains() {
    // beyond the sweep: the paper's default k = N-1 and an inner
    // dimension long enough to cycle the window automaton many times
    let (m, kk, nn) = (4usize, 300usize, 3usize);
    for family in Family::ALL {
        let cfg = PeConfig::new(8, true, family, 7);
        let a = ints(41, m * kk, true);
        let b = ints(42, kk * nn, true);
        assert_eq!(lut_matmul(&cfg, &a, &b, m, kk, nn),
                   word_matmul(&cfg, &a, &b, m, kk, nn),
                   "{family:?} k=7");
    }
}

#[test]
fn ragged_and_degenerate_shapes_agree() {
    let cfg = PeConfig::new(8, true, Family::Proposed, 3);
    for (m, kk, nn) in [(1usize, 1usize, 1usize), (1, 37, 1), (5, 1, 7),
                        (13, 9, 2)] {
        let a = ints(50 + m as u64, m * kk, true);
        let b = ints(60 + nn as u64, kk * nn, true);
        assert_eq!(lut_matmul(&cfg, &a, &b, m, kk, nn),
                   word_matmul(&cfg, &a, &b, m, kk, nn),
                   "shape ({m},{kk},{nn})");
    }
}

#[test]
fn gemm_trait_backends_agree_through_pipeline_interface() {
    // the pluggable Gemm trait used by the DCT/edge/BDCN pipelines
    let cfg = PeConfig::new(8, true, Family::Axsa5, 4);
    let (m, kk, nn) = (8usize, 8usize, 16usize);
    let a = ints(71, m * kk, true);
    let b = ints(72, kk * nn, true);
    let w = WordGemm { cfg }.gemm(&a, &b, m, kk, nn);
    let l = LutGemm { cfg }.gemm(&a, &b, m, kk, nn);
    let s = SystolicGemm::new(cfg, 8).gemm(&a, &b, m, kk, nn);
    assert_eq!(w, l);
    assert_eq!(w, s);
}

#[test]
fn lut_tables_stay_small_across_the_sweep() {
    // memory property: every swept design point compiles to tables, and
    // the automaton state count stays within the analytical envelope
    for family in Family::ALL {
        for signed in [true, false] {
            for k in [0u32, 2, 4, 7] {
                let cfg = PeConfig::new(8, signed, family, k);
                let lut = ProductLut::try_build(&cfg)
                    .expect("sweep points must be LUT-compilable");
                assert!(lut.states() <= 1 << k.max(1),
                        "{family:?} signed={signed} k={k}: {} states",
                        lut.states());
            }
        }
    }
}

#[test]
fn out_of_range_operands_wrap_identically() {
    // operands outside the N-bit range must be re-encoded the same way
    // by every engine (the hardware only ever sees N bits)
    let cfg = PeConfig::new(8, true, Family::Sips12, 4);
    let a: Vec<i64> = vec![300, -300, 128, -129, 1 << 20, -(1 << 20)];
    let b: Vec<i64> = vec![-1000, 999, 256, -256, 77, -77];
    assert_eq!(lut_matmul(&cfg, &a, &b, 2, 3, 2),
               word_matmul(&cfg, &a, &b, 2, 3, 2));
}
