//! Golden regression pins for the paper's error metrics (Table V
//! setting): exhaustive 8-bit single-MAC sweeps per approximate family.
//!
//! The pinned numbers were generated from the Python oracle
//! (`python/compile/kernels/ref.py::mac_scalar`) over all 65 536 operand
//! pairs — the Rust word model mirrors it bit-for-bit, so these values
//! must never drift. If a refactor changes any of them, the arithmetic
//! changed, not just the implementation: that is a bug unless the paper
//! mapping itself was wrong (in which case regenerate the goldens from
//! the oracle and say so in the commit).

use axsys::error::exhaustive_metrics;
use axsys::pe::word::PeConfig;
use axsys::Family;

/// (family, signed, k, med, nmed, mred, max_ed, error_rate)
#[allow(clippy::type_complexity)]
const GOLDEN: &[(Family, bool, u32, f64, f64, f64, u64, f64)] = &[
    (Family::Proposed, true, 2, 1.0, 6.103515625e-05,
     0.0021752896304558945, 3, 0.5),
    (Family::Proposed, true, 4, 7.125, 0.00043487548828125,
     0.013972360106945313, 21, 0.8125),
    (Family::Proposed, true, 6, 35.65625, 0.0021762847900390625,
     0.06374472048890063, 109, 0.9375),
    (Family::Proposed, false, 2, 1.0, 1.5378700499807768e-05,
     0.0006369821030026554, 3, 0.5),
    (Family::Proposed, false, 4, 7.125, 0.00010957324106113033,
     0.003580747221153849, 21, 0.8125),
    (Family::Proposed, false, 6, 35.65625, 0.0005483467896962706,
     0.01378729871554553, 109, 0.9375),
    (Family::Axsa5, true, 2, 0.25, 1.52587890625e-05,
     0.0005764524712185496, 4, 0.0625),
    (Family::Axsa5, true, 4, 5.75, 0.0003509521484375,
     0.012284222910042818, 44, 0.31640625),
    (Family::Axsa5, true, 6, 50.25, 0.0030670166015625,
     0.10272117862187753, 300, 0.598876953125),
    (Family::Axsa5, false, 2, 0.25, 3.844675124951942e-06,
     0.00010591447570186614, 4, 0.0625),
    (Family::Axsa5, false, 4, 5.75, 8.842752787389466e-05,
     0.0018339410421101816, 44, 0.31640625),
    (Family::Axsa5, false, 6, 50.25, 0.0007727797001153403,
     0.011311007868927376, 300, 0.598876953125),
    (Family::Sips12, true, 2, 1.25, 7.62939453125e-05,
     0.0023897031364602654, 5, 1.0),
    (Family::Sips12, true, 4, 8.5546875, 0.0005221366882324219,
     0.01708665160216088, 49, 1.0),
    (Family::Sips12, true, 6, 56.17529296875, 0.0034286677837371826,
     0.115537162540702, 321, 1.0),
    (Family::Sips12, false, 2, 1.25, 1.922337562475971e-05,
     0.0006819970598979667, 5, 1.0),
    (Family::Sips12, false, 4, 8.5546875, 0.00013155997693194924,
     0.003680690695327011, 49, 1.0),
    (Family::Sips12, false, 6, 56.17529296875, 0.0008639030060553633,
     0.017676069027603658, 321, 1.0),
    (Family::Nano6, true, 2, 1.25, 7.62939453125e-05,
     0.0023897031364602853, 4, 0.9375),
    (Family::Nano6, true, 4, 9.375, 0.00057220458984375,
     0.01896271217007697, 44, 0.9921875),
    (Family::Nano6, true, 6, 62.78515625, 0.003832101821899414,
     0.12835077452689272, 300, 0.99853515625),
    (Family::Nano6, false, 2, 1.25, 1.922337562475971e-05,
     0.0006725578261434934, 4, 0.9375),
    (Family::Nano6, false, 4, 9.375, 0.0001441753171856978,
     0.003959690814068116, 44, 0.9921875),
    (Family::Nano6, false, 6, 62.78515625, 0.0009655541138023837,
     0.019390517847794404, 300, 0.99853515625),
];

fn close(got: f64, want: f64, what: &str) {
    // the sweeps are deterministic; the tolerance only absorbs benign
    // float-summation reassociation if the loop structure ever changes
    let tol = want.abs().max(1e-12) * 1e-9;
    assert!((got - want).abs() <= tol,
            "{what}: got {got:e}, golden {want:e}");
}

#[test]
fn table5_metrics_pinned_to_oracle_goldens() {
    for &(family, signed, k, med, nmed, mred, max_ed, er) in GOLDEN {
        let cfg = PeConfig::new(8, signed, family, k);
        let m = exhaustive_metrics(&cfg);
        let what = format!("{family:?} signed={signed} k={k}");
        close(m.med, med, &format!("{what} med"));
        close(m.nmed, nmed, &format!("{what} nmed"));
        close(m.mred, mred, &format!("{what} mred"));
        assert_eq!(m.max_ed, max_ed, "{what} max_ed");
        close(m.error_rate, er, &format!("{what} error_rate"));
    }
}

#[test]
fn exact_configs_have_zero_golden_error() {
    for family in Family::ALL {
        for signed in [true, false] {
            let m = exhaustive_metrics(&PeConfig::new(8, signed, family, 0));
            assert_eq!(m.med, 0.0, "{family:?} signed={signed}");
            assert_eq!(m.max_ed, 0, "{family:?} signed={signed}");
            assert_eq!(m.error_rate, 0.0, "{family:?} signed={signed}");
        }
    }
}

#[test]
fn paper_family_ordering_preserved_at_k6_signed() {
    // Table V ordering (signed, k = 6): proposed < [5] < [12] < [6] on NMED
    let nmed = |f: Family| {
        exhaustive_metrics(&PeConfig::new(8, true, f, 6)).nmed
    };
    assert!(nmed(Family::Proposed) < nmed(Family::Axsa5));
    assert!(nmed(Family::Axsa5) < nmed(Family::Sips12));
    assert!(nmed(Family::Sips12) < nmed(Family::Nano6));
}
