//! Integration tests for the network serving layer: results served over
//! TCP must be **bit-identical** to in-process `Coordinator` responses
//! on every backend (GEMM and all three application pipelines),
//! concurrent pipelined clients must see correct isolated in-order
//! replies, and admission-gate overload must block — never drop or
//! reorder — per-connection traffic.

use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Arc;

use axsys::apps::bdcn::{self, Block, Tensor};
use axsys::apps::image::scene;
use axsys::apps::{CoordinatorGemm, Gemm};
use axsys::bench::{xorshift_ints as ints, Json};
use axsys::coordinator::{AppKind, BackendKind, Coordinator, CoordinatorConfig,
                         GemmRequest};
use axsys::net::client::{Client, RemoteGemm};
use axsys::net::loadgen::{self, LoadgenConfig};
use axsys::net::proto::{self, ErrCode, Frame};
use axsys::net::server::{NetServer, ServerConfig};
use axsys::net::NetError;

fn start(backend: BackendKind, workers: usize, cfg: ServerConfig)
         -> (Arc<Coordinator>, NetServer) {
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers,
        backend,
        ..Default::default()
    }));
    let server = NetServer::bind("127.0.0.1:0", coord.clone(), cfg)
        .expect("bind loopback");
    (coord, server)
}

/// Tiny deterministic int8 BDCN cascade (1 -> 4 -> 4 channels per
/// block) so the weight-dependent app is servable without artifacts.
fn synthetic_blocks() -> Vec<Block> {
    let mut seed = 0x0B5Eu64;
    let mut cin = 1usize;
    let mut blocks = Vec::new();
    for _ in 0..bdcn::N_BLOCKS {
        let c = 4usize;
        let mk = |kh: usize, kw: usize, ci: usize, co: usize, s: u64| Tensor {
            shape: [kh, kw, ci, co],
            data: ints(s, kh * kw * ci * co),
        };
        blocks.push(Block {
            w1: mk(3, 3, cin, c, seed),
            w2: mk(3, 3, c, c, seed + 1),
            side: mk(1, 1, c, 1, seed + 2),
        });
        seed += 3;
        cin = c;
    }
    blocks
}

#[test]
fn remote_gemm_bit_identical_to_in_process_for_all_backends() {
    let cases: &[(usize, usize, usize, u32)] =
        &[(20, 16, 24, 0), (17, 13, 40, 3), (8, 8, 8, 7)];
    for backend in [BackendKind::Word, BackendKind::Lut,
                    BackendKind::Systolic] {
        let (coord, server) = start(backend, 3, ServerConfig::default());
        let mut client = Client::connect(server.local_addr()).unwrap();
        for (i, &(m, kk, nn, k)) in cases.iter().enumerate() {
            let a = ints(2 * i as u64 + 1, m * kk);
            let b = ints(2 * i as u64 + 2, kk * nn);
            let want = coord.call(GemmRequest {
                a: a.clone(), b: b.clone(), m, kk, nn, k,
                ..Default::default()
            });
            let got = client.gemm(&a, &b, m, kk, nn, k).unwrap();
            assert_eq!(got.out, want.out, "{backend:?} case {i}: bits differ");
            assert_eq!((got.m as usize, got.nn as usize), (m, nn));
            // software backends count exactly m*kk*nn MACs; the systolic
            // array also counts the MACs of tile-padding PEs
            assert!(got.macs >= (m * kk * nn) as u64, "{backend:?} case {i}");
        }
        server.shutdown();
    }
}

#[test]
fn remote_apps_bit_identical_to_in_process() {
    let blocks = Arc::new(synthetic_blocks());
    for backend in [BackendKind::Word, BackendKind::Lut,
                    BackendKind::Systolic] {
        let (coord, server) = start(backend, 3, ServerConfig {
            bdcn: Some(blocks.clone()),
            ..Default::default()
        });
        let mut client = Client::connect(server.local_addr()).unwrap();
        let img = scene(16, 16);
        // the gate-level-metered systolic replay is ~1000x slower, so
        // its CNN cascade runs on a smaller image (same invariant)
        let bdcn_img = if backend == BackendKind::Systolic {
            scene(8, 8)
        } else {
            img.clone()
        };
        for k in [0u32, 4] {
            let want = coord.serve_dct(&img, k);
            let got = client.app(AppKind::Dct, &img, k).unwrap();
            assert_eq!(got.image().data, want.out.data,
                       "{backend:?} dct k={k}: bits differ over TCP");
            let want = coord.serve_edge(&img, k);
            let got = client.app(AppKind::Edge, &img, k).unwrap();
            assert_eq!(got.image().data, want.out.data,
                       "{backend:?} edge k={k}: bits differ over TCP");
            assert_eq!(got.psnr_db.is_finite(), want.psnr_db.is_finite(),
                       "{backend:?} edge k={k}: quality class differs");
            let want = coord.serve_bdcn(&blocks, &bdcn_img, k);
            let got = client.app(AppKind::Bdcn, &bdcn_img, k).unwrap();
            assert_eq!(got.image().data, want.out.data,
                       "{backend:?} bdcn k={k}: bits differ over TCP");
        }
        server.shutdown();
    }
}

#[test]
fn concurrent_pipelined_clients_get_isolated_ordered_replies() {
    let (coord, server) = start(BackendKind::Lut, 4, ServerConfig::default());
    let addr = server.local_addr();
    const CLIENTS: usize = 5;
    const PER: usize = 12;
    let handles: Vec<_> = (0..CLIENTS).map(|ci| {
        let coord = coord.clone();
        std::thread::spawn(move || {
            // expectations via the in-process path first, then the same
            // requests pipelined over one connection: send all, receive
            // all — replies must come back in order, none lost, none
            // from another client's stream
            let mut shapes = Vec::new();
            let mut want = Vec::new();
            for i in 0..PER {
                let s = (ci * 100 + i) as u64;
                let m = 5 + (s % 20) as usize;
                let kk = 4 + (s % 13) as usize;
                let nn = 6 + (s % 17) as usize;
                let k = (s % 6) as u32;
                let a = ints(2 * s + 1, m * kk);
                let b = ints(2 * s + 2, kk * nn);
                want.push(coord.call(GemmRequest {
                    a: a.clone(), b: b.clone(), m, kk, nn, k,
                    ..Default::default()
                }).out);
                shapes.push((a, b, m, kk, nn, k));
            }
            let mut client = Client::connect(addr).unwrap();
            for (a, b, m, kk, nn, k) in &shapes {
                client.send_gemm(a, b, *m, *kk, *nn, *k).unwrap();
            }
            for (i, w) in want.iter().enumerate() {
                let got = client.recv_gemm().unwrap();
                assert_eq!(&got.out, w,
                           "client {ci} reply {i} lost/reordered/corrupted");
            }
        })
    }).collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let ns = server.stats();
    assert_eq!(ns.gemm_requests, (CLIENTS * PER) as u64);
    assert!(ns.connections_opened >= CLIENTS as u64);
    server.shutdown();
}

#[test]
fn overloaded_admission_gate_blocks_and_loses_nothing() {
    // max_inflight 2 with 64 requests pipelined before any reply is
    // read: the gate must stall socket reads (backpressure), and every
    // reply must still arrive, in order, bit-correct
    let (coord, server) = start(BackendKind::Lut, 2, ServerConfig {
        max_inflight: 2,
        ..Default::default()
    });
    let (m, kk, nn, k) = (16usize, 8usize, 16usize, 3u32);
    let mut want = Vec::new();
    let mut reqs = Vec::new();
    for i in 0..64u64 {
        let a = ints(2 * i + 1, m * kk);
        let b = ints(2 * i + 2, kk * nn);
        want.push(coord.call(GemmRequest {
            a: a.clone(), b: b.clone(), m, kk, nn, k,
            ..Default::default()
        }).out);
        reqs.push((a, b));
    }
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let rstream = stream.try_clone().unwrap();
    let writer = std::thread::spawn(move || {
        let mut w = stream;
        let mut scratch = Vec::new();
        for (a, b) in reqs {
            let f = Frame::GemmReq(proto::GemmReq {
                k,
                m: m as u32,
                kk: kk as u32,
                nn: nn as u32,
                a,
                b,
                slo: None,
            });
            proto::write_frame(&mut w, &f, &mut scratch).unwrap();
        }
    });
    let mut br = BufReader::new(rstream);
    let mut scratch = Vec::new();
    for (i, w) in want.iter().enumerate() {
        match proto::read_frame(&mut br, &mut scratch).unwrap() {
            Some(Frame::GemmResp(r)) => {
                assert_eq!(&r.out, w, "reply {i} corrupted under overload");
            }
            other => panic!("reply {i}: expected GemmResp, got {other:?}"),
        }
    }
    writer.join().expect("writer thread");
    server.shutdown();
}

#[test]
fn malformed_requests_get_typed_errors_and_server_survives() {
    let (coord, server) = start(BackendKind::Lut, 2, ServerConfig::default());
    let mut client = Client::connect(server.local_addr()).unwrap();
    // bad PGM payload -> typed BadImage error, connection stays usable
    client.send(&Frame::AppReq(proto::AppReq {
        app: AppKind::Dct,
        k: 2,
        pgm: b"P6 not a pgm".to_vec(),
        slo: None,
    })).unwrap();
    match client.recv().unwrap() {
        Frame::Error(e) => assert_eq!(e.code, ErrCode::BadImage, "{}", e.msg),
        other => panic!("expected error frame, got {other:?}"),
    }
    // shape rule: dct needs multiple-of-8 dimensions
    match client.app(AppKind::Dct, &scene(12, 12), 2) {
        Err(NetError::Server { code, .. }) => {
            assert_eq!(code, ErrCode::BadImage);
        }
        other => panic!("expected server error, got {other:?}"),
    }
    // bdcn without weights -> typed Unsupported
    match client.app(AppKind::Bdcn, &scene(16, 16), 2) {
        Err(NetError::Server { code, .. }) => {
            assert_eq!(code, ErrCode::Unsupported);
        }
        other => panic!("expected server error, got {other:?}"),
    }
    // empty GEMM dims -> typed Malformed (a zero-tile request would
    // never complete on the pool)
    client.send(&Frame::GemmReq(proto::GemmReq {
        k: 0, m: 0, kk: 0, nn: 0, a: vec![], b: vec![], slo: None,
    })).unwrap();
    match client.recv().unwrap() {
        Frame::Error(e) => assert_eq!(e.code, ErrCode::Malformed, "{}", e.msg),
        other => panic!("expected error frame, got {other:?}"),
    }
    // the same connection still serves valid requests afterwards
    let a = ints(1, 64);
    let b = ints(2, 64);
    let want = coord.call(GemmRequest {
        a: a.clone(), b: b.clone(), m: 8, kk: 8, nn: 8, k: 2,
        ..Default::default()
    }).out;
    assert_eq!(client.gemm(&a, &b, 8, 8, 8, 2).unwrap().out, want);
    // garbage framing kills only that connection; the server survives
    {
        use std::io::Write as _;
        let mut s2 = TcpStream::connect(server.local_addr()).unwrap();
        s2.write_all(&[0xFF; 64]).unwrap();
        let mut br = BufReader::new(s2.try_clone().unwrap());
        let mut rb = Vec::new();
        // the broken connection gets a typed error frame or a close
        match proto::read_frame(&mut br, &mut rb) {
            Ok(Some(Frame::Error(_))) | Ok(None) | Err(_) => {}
            other => panic!("expected error/close, got {other:?}"),
        }
    }
    let mut c3 = Client::connect(server.local_addr()).unwrap();
    assert_eq!(c3.gemm(&a, &b, 8, 8, 8, 2).unwrap().out, want,
               "a fresh connection must still be served");
    let ns = server.stats();
    assert!(ns.error_replies >= 4, "typed errors counted: {ns:?}");
    server.shutdown();
}

#[test]
fn remote_gemm_drops_into_app_pipelines_and_stats_flow() {
    let (coord, server) = start(BackendKind::Lut, 3, ServerConfig::default());
    let img = scene(16, 16);
    // RemoteGemm implements Gemm: the DCT pipeline runs over TCP
    // unchanged and must match the in-process CoordinatorGemm bits
    let mut rg = RemoteGemm::connect(server.local_addr(), 5).unwrap();
    let (recon, _) = axsys::apps::dct::pipeline(&mut rg, &img);
    let mut cg = CoordinatorGemm::new(&coord, 5);
    let (want, _) = axsys::apps::dct::pipeline(&mut cg, &img);
    assert_eq!(recon.data, want.data,
               "pipeline over RemoteGemm must be bit-identical");
    assert!(rg.requests >= 4, "dct issues >= 4 GEMM stages: {}", rg.requests);
    let st = rg.stats().unwrap();
    assert!(st.macs > 0 && st.metered_macs == st.macs,
            "lut-served requests are fully metered: {st:?}");
    // the stats frame reflects the served traffic and the net counters
    let mut c = Client::connect(server.local_addr()).unwrap();
    let ws = c.stats().unwrap();
    assert!(ws.requests >= rg.requests + cg.requests);
    assert!(ws.energy_fj > 0.0 && ws.metered_macs > 0);
    assert!(ws.frames_in >= rg.requests && ws.frames_out >= rg.requests);
    assert!(ws.bytes_in > 0 && ws.bytes_out > 0);
    assert!(ws.latency_p50_us > 0.0);
    let ns = server.stats();
    assert!(ns.connections_opened >= 2);
    assert!(ns.gemm_requests >= rg.requests);
    assert!(ns.latency_percentile(0.5) > 0.0);
    server.shutdown();
}

#[test]
fn slo_routed_requests_over_tcp_match_in_process_routing() {
    use axsys::pe::word::{matmul, PeConfig};
    use axsys::zoo::{self, AccuracySlo};
    let (coord, server) = start(BackendKind::Word, 3, ServerConfig::default());
    let mut client = Client::connect(server.local_addr()).unwrap();
    let (m, kk, nn) = (12usize, 9usize, 14usize);
    let a = ints(41, m * kk);
    let b = ints(42, kk * nn);
    // a loose NMED bound must route an approximate point, and the wire
    // reply must be bit-identical to the word kernel at that point
    let loose = AccuracySlo { max_nmed: Some(5e-3), min_psnr_db: None };
    let e = zoo::route(8, true, &loose).expect("loose bound is satisfiable");
    assert!(e.nmed > 0.0, "loose bound should route an approximate point");
    let want = matmul(&PeConfig::from_design(&e.design), &a, &b, m, kk, nn);
    let got = client.gemm_slo(&a, &b, m, kk, nn, &loose).unwrap();
    assert_eq!(got.out, want, "SLO-routed TCP reply != routed word kernel");
    // ... and to the in-process SLO path against the same pool
    let inproc = coord.try_call(GemmRequest {
        a: a.clone(), b: b.clone(), m, kk, nn, k: 0, slo: Some(loose),
        ..Default::default()
    }).expect("in-process routing");
    assert_eq!(inproc.out, got.out, "wire and in-process routing disagree");
    // an exact SLO is bit-identical to an unrouted exact request
    let exact = AccuracySlo { max_nmed: Some(0.0), min_psnr_db: None };
    let got0 = client.gemm_slo(&a, &b, m, kk, nn, &exact).unwrap();
    let want0 = client.gemm(&a, &b, m, kk, nn, 0).unwrap();
    assert_eq!(got0.out, want0.out, "exact SLO != exact arithmetic");
    // SLO-routed apps serve the routed design point's bits
    let img = scene(16, 16);
    let got = client.app_slo(AppKind::Edge, &img, 7, Some(&loose)).unwrap();
    let want = coord.serve_edge_slo(&img, &loose).expect("edge routes");
    assert_eq!(got.image().data, want.out.data,
               "SLO-routed edge over TCP: bits differ");
    // the coordinator's SLO counters travel in the stats frame (the
    // three wire requests above plus the one in-process try_call)
    let ws = client.stats().unwrap();
    assert_eq!(ws.slo_requests, 4, "{ws:?}");
    assert!(ws.slo_exact >= 1, "{ws:?}");
    assert_eq!(ws.slo_unsatisfiable, 0, "{ws:?}");
    assert_eq!(ws.slo_tier.iter().sum::<u64>(), ws.slo_requests, "{ws:?}");
    let ns = server.stats();
    assert_eq!(ns.slo_requests, 3, "wire-admitted SLO requests: {ns:?}");
    assert_eq!(ns.slo_rejections, 0, "{ns:?}");
    server.shutdown();

    // a pool whose word shape the registry does not cover refuses SLO
    // traffic with a typed wire error — and the connection survives
    let coord16 = Arc::new(Coordinator::new(CoordinatorConfig {
        workers: 2,
        backend: BackendKind::Word,
        n_bits: 16,
        ..Default::default()
    }));
    let server16 = NetServer::bind("127.0.0.1:0", coord16.clone(),
                                   ServerConfig::default()).expect("bind");
    let mut c16 = Client::connect(server16.local_addr()).unwrap();
    match c16.gemm_slo(&a, &b, m, kk, nn, &loose) {
        Err(NetError::Server { code, msg }) => {
            assert_eq!(code, ErrCode::SloUnsatisfiable, "{msg}");
            assert!(msg.contains("n=16"), "refusal names the shape: {msg}");
        }
        other => panic!("expected SloUnsatisfiable, got {other:?}"),
    }
    let want16 = coord16.call(GemmRequest {
        a: a.clone(), b: b.clone(), m, kk, nn, k: 0, ..Default::default()
    });
    let got16 = c16.gemm(&a, &b, m, kk, nn, 0).unwrap();
    assert_eq!(got16.out, want16.out,
               "connection must survive a refused SLO");
    let ws16 = c16.stats().unwrap();
    assert_eq!(ws16.slo_requests, 1, "{ws16:?}");
    assert_eq!(ws16.slo_unsatisfiable, 1, "{ws16:?}");
    let ns16 = server16.stats();
    assert_eq!(ns16.slo_requests, 1, "{ns16:?}");
    assert_eq!(ns16.slo_rejections, 1, "{ns16:?}");
    server16.shutdown();
}

#[test]
fn shutdown_drains_inflight_replies() {
    let (coord, server) = start(BackendKind::Lut, 2, ServerConfig::default());
    let mut client = Client::connect(server.local_addr()).unwrap();
    let (m, kk, nn, k) = (24usize, 8usize, 24usize, 2u32);
    let mut want = Vec::new();
    for i in 0..6u64 {
        let a = ints(2 * i + 1, m * kk);
        let b = ints(2 * i + 2, kk * nn);
        want.push(coord.call(GemmRequest {
            a: a.clone(), b: b.clone(), m, kk, nn, k,
            ..Default::default()
        }).out);
        client.send_gemm(&ints(2 * i + 1, m * kk), &ints(2 * i + 2, kk * nn),
                         m, kk, nn, k).unwrap();
    }
    // give the reader time to admit everything, then drain-shutdown
    // concurrently with the client reading its replies: every admitted
    // request must still be answered before the connection closes
    std::thread::sleep(std::time::Duration::from_millis(300));
    let h = std::thread::spawn(move || server.shutdown());
    for (i, w) in want.iter().enumerate() {
        let got = client.recv_gemm();
        assert_eq!(&got.expect("drained reply").out, w,
                   "reply {i} lost in shutdown drain");
    }
    h.join().expect("shutdown thread");
}

#[test]
fn loadgen_emits_serve_net_report_against_loopback() {
    let (_coord, server) = start(BackendKind::Lut, 3, ServerConfig::default());
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        clients: 3,
        requests: 24,
        k_max: 4,
        seed: 7,
        apps: true,
        slo: None,
    };
    let doc = loadgen::run(&cfg).expect("loadgen run");
    match doc.get("throughput_req_per_sec") {
        Some(&Json::Num(v)) => assert!(v > 0.0, "throughput {v}"),
        other => panic!("throughput missing: {other:?}"),
    }
    assert_eq!(doc.get("served_requests"), Some(&Json::Int(24)));
    let lat = doc.get("latency_us").expect("latency section");
    match (lat.get("p50"), lat.get("p99")) {
        (Some(&Json::Num(p50)), Some(&Json::Num(p99))) => {
            assert!(p50 > 0.0 && p50 <= p99, "{p50} vs {p99}");
        }
        other => panic!("percentiles missing: {other:?}"),
    }
    let server_j = doc.get("server").expect("server section");
    match server_j.get("energy_uj_total") {
        Some(&Json::Num(v)) => assert!(v > 0.0, "served energy {v}"),
        other => panic!("energy_uj_total missing: {other:?}"),
    }
    // the artifact serializes as a JSON document
    let dir = std::env::temp_dir().join("axsys_net_serve_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("BENCH_serve_net.json");
    std::fs::write(&p, doc.pretty()).unwrap();
    let text = std::fs::read_to_string(&p).unwrap();
    assert!(text.starts_with('{') && text.ends_with("}\n"), "{text}");
    server.shutdown();
}
