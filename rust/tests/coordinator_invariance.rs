//! Service-level invariance tests for the coordinator on the table-driven
//! `Lut` backend: results must not depend on worker count, batch size or
//! queue depth; coalesced batched dispatch must be bit-identical to
//! one-at-a-time execution; and a saturated queue must exert
//! backpressure (block the submitter) rather than drop tiles.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig,
                         GemmRequest};
use axsys::pe::lut::matmul as lut_matmul;
use axsys::pe::word::PeConfig;
use axsys::Family;

fn ints(seed: u64, len: usize) -> Vec<i64> {
    let mut s = seed | 1;
    (0..len).map(|_| {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        (s as i64 & 255) - 128
    }).collect()
}

fn reference_tiled(k: u32, a: &[i64], b: &[i64], m: usize, kk: usize,
                   nn: usize, sa: usize) -> Vec<i64> {
    // same 8-wide tiling the coordinator performs (approximate carry-save
    // walks are tile-local, so tiling is part of the semantics)
    let cfg = PeConfig::new(8, true, Family::Proposed, k);
    let mut out = vec![0i64; m * nn];
    for ti in (0..m).step_by(sa) {
        for tj in (0..nn).step_by(sa) {
            let th = (m - ti).min(sa);
            let tw = (nn - tj).min(sa);
            let ap: Vec<i64> = (0..th).flat_map(
                |i| a[(ti + i) * kk..(ti + i + 1) * kk].to_vec()).collect();
            let bp: Vec<i64> = (0..kk).flat_map(
                |t| b[t * nn + tj..t * nn + tj + tw].to_vec()).collect();
            let tile = lut_matmul(&cfg, &ap, &bp, th, kk, tw);
            for i in 0..th {
                for j in 0..tw {
                    out[(ti + i) * nn + tj + j] = tile[i * tw + j];
                }
            }
        }
    }
    out
}

#[test]
fn lut_results_invariant_to_worker_count_and_batch() {
    let (m, kk, nn) = (29usize, 13usize, 31usize);
    let a = ints(1, m * kk);
    let b = ints(2, kk * nn);
    for k in [0u32, 4] {
        let want = reference_tiled(k, &a, &b, m, kk, nn, 8);
        for workers in [1usize, 4, 8] {
            for batch in [1usize, 4, 16] {
                let c = Coordinator::new(CoordinatorConfig {
                    workers,
                    batch,
                    backend: BackendKind::Lut,
                    ..Default::default()
                });
                let resp = c.call(GemmRequest {
                    a: a.clone(), b: b.clone(), m, kk, nn, k,
                    ..Default::default()
                });
                assert_eq!(resp.out, want,
                           "k={k} workers={workers} batch={batch}");
                c.shutdown();
            }
        }
    }
}

#[test]
fn lut_and_word_backends_agree_through_the_service() {
    let (m, kk, nn) = (21usize, 10usize, 18usize);
    let a = ints(3, m * kk);
    let b = ints(4, kk * nn);
    for k in [0u32, 2, 4] {
        let mut outs = Vec::new();
        for backend in [BackendKind::Word, BackendKind::Lut] {
            let c = Coordinator::new(CoordinatorConfig {
                workers: 3, backend, ..Default::default()
            });
            outs.push(c.call(GemmRequest {
                a: a.clone(), b: b.clone(), m, kk, nn, k,
                ..Default::default()
            }).out);
            c.shutdown();
        }
        assert_eq!(outs[0], outs[1], "k={k}");
    }
}

#[test]
fn coalesced_batches_bit_identical_to_one_at_a_time() {
    // With batch=1 a worker executes exactly one tile per dispatch, so
    // nothing can coalesce; with large batches a worker pulls many tiles
    // of the same request and stacks the ones sharing a B panel into one
    // blocked GEMM. Every configuration must produce the same bits for
    // every request, on both software backends.
    let reqs: &[(usize, usize, usize, u32)] = &[
        (40, 9, 24, 0),   // multi row+col tiles, exact
        (17, 13, 40, 3),  // ragged both ways, approximate
        (64, 8, 8, 5),    // single tile column: maximally coalescable
        (8, 24, 64, 7),   // single tile row: nothing to coalesce
    ];
    for backend in [BackendKind::Lut, BackendKind::Word] {
        let run_with = |workers: usize, batch: usize| -> Vec<Vec<i64>> {
            let c = Coordinator::new(CoordinatorConfig {
                workers, batch, backend, ..Default::default()
            });
            let ids: Vec<u64> = reqs.iter().enumerate()
                .map(|(i, &(m, kk, nn, k))| c.submit(GemmRequest {
                    a: ints(2 * i as u64 + 1, m * kk),
                    b: ints(2 * i as u64 + 2, kk * nn),
                    m, kk, nn, k,
                    ..Default::default()
                }))
                .collect();
            let outs = ids.into_iter().map(|id| c.wait(id).out).collect();
            c.shutdown();
            outs
        };
        let want = run_with(1, 1); // strictly per-tile execution
        for (workers, batch) in [(1, 64), (4, 16), (8, 64)] {
            assert_eq!(run_with(workers, batch), want,
                       "{backend:?} workers={workers} batch={batch}");
        }
    }
}

#[test]
fn dispatch_counters_track_batches_and_coalescing() {
    // one worker + deep batch: the 8 row tiles of a single-column
    // request share one B panel and should coalesce into few device
    // calls; the counters must reflect every pulled tile exactly once
    let c = Coordinator::new(CoordinatorConfig {
        workers: 1,
        batch: 64,
        backend: BackendKind::Lut,
        sw_tile: Some((8, 8)), // pin the historical 8x8 tile geometry
        ..Default::default()
    });
    let (m, kk, nn) = (64usize, 8usize, 8usize); // 8 tiles, all tj = 0
    let a = ints(11, m * kk);
    let b = ints(12, kk * nn);
    let resp = c.call(GemmRequest { a, b, m, kk, nn, k: 4, ..Default::default() });
    assert_eq!(resp.tiles, 8);
    let s = c.stats();
    assert!(s.worker_dispatches >= 1, "{}", s.worker_dispatches);
    assert_eq!(s.dispatched_tiles, 8);
    assert!(s.max_dispatch_tiles >= 1 && s.max_dispatch_tiles <= 8);
    // every dispatch coalesces to at least one call, never more than
    // its tiles; a dispatch that saw >1 same-B tiles must have merged
    // them (coalesced_calls == worker_dispatches in that case)
    assert!(s.coalesced_calls >= s.worker_dispatches);
    assert!(s.coalesced_calls <= s.dispatched_tiles);
    assert_eq!(s.coalesced_calls, s.worker_dispatches,
               "same-B tiles in one dispatch must merge into one call");
    assert!(s.mean_dispatch_tiles() >= 1.0);
    assert!(s.mean_dispatch_exec_us() > 0.0);
    assert_eq!(s.lut_macs, (m * kk * nn) as u64);
    c.shutdown();
}

#[test]
fn saturated_queue_blocks_submit_instead_of_dropping() {
    // queue_depth 1, single worker: a 16x16-tile request (256 tiles) can
    // only complete if submit() stalls until capacity frees up. Drops
    // would surface as wrong output or a hung wait().
    let c = Arc::new(Coordinator::new(CoordinatorConfig {
        workers: 1,
        queue_depth: 1,
        batch: 1,
        backend: BackendKind::Lut,
        sw_tile: Some((8, 8)), // many tiny tiles: the saturation scenario
        ..Default::default()
    }));
    let (m, kk, nn) = (128usize, 8usize, 128usize); // 256 tiles of 8x8
    let a = vec![2i64; m * kk];
    let b = vec![3i64; kk * nn];
    let submitted = Arc::new(AtomicBool::new(false));
    let id = {
        let c = c.clone();
        let submitted = submitted.clone();
        let (a, b) = (a.clone(), b.clone());
        let h = std::thread::spawn(move || {
            let id = c.submit(GemmRequest { a, b, m, kk, nn, k: 0, ..Default::default() });
            submitted.store(true, Ordering::SeqCst);
            id
        });
        h.join().expect("submitter thread")
    };
    assert!(submitted.load(Ordering::SeqCst));
    let resp = c.wait(id);
    // every element is 2*3*kk — any dropped tile would leave zeros
    assert!(resp.out.iter().all(|&v| v == 6 * kk as i64),
            "dropped or corrupted tiles under backpressure");
    assert_eq!(resp.out.len(), m * nn);
}

#[test]
fn shutdown_with_saturated_queue_joins_all_workers() {
    // Regression for the drop/shutdown liveness contract: tearing a
    // coordinator down while its bounded queue is (or just was)
    // saturated must deterministically drain every accepted tile, wake
    // any parked worker, and join the whole pool. A hang here would
    // stall the test binary, so the teardown runs under a watchdog.
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        // explicit shutdown() after a saturating request completes
        let c = Coordinator::new(CoordinatorConfig {
            workers: 2,
            queue_depth: 1,
            batch: 1,
            backend: BackendKind::Lut,
            sw_tile: Some((8, 8)),
            ..Default::default()
        });
        let (m, kk, nn) = (64usize, 8usize, 64usize); // 64 tiles, depth 1
        let id = c.submit(GemmRequest {
            a: vec![1; m * kk], b: vec![1; kk * nn], m, kk, nn, k: 0,
            ..Default::default()
        });
        let resp = c.wait(id);
        assert!(resp.out.iter().all(|&v| v == kk as i64));
        c.shutdown();

        // drop without wait(): tiles of an unclaimed request are still
        // in flight when the queue closes — Drop must drain and join,
        // never leave workers parked on the request channel
        let c2 = Coordinator::new(CoordinatorConfig {
            workers: 1,
            queue_depth: 1,
            batch: 1,
            backend: BackendKind::Lut,
            sw_tile: Some((8, 8)),
            ..Default::default()
        });
        for r in 0..3u64 {
            c2.submit(GemmRequest {
                a: ints(r + 1, 32 * 8), b: ints(r + 2, 8 * 32),
                m: 32, kk: 8, nn: 32, k: 0,
                ..Default::default()
            });
        }
        drop(c2);
        done_tx.send(()).unwrap();
    });
    done_rx.recv_timeout(std::time::Duration::from_secs(120)).expect(
        "coordinator teardown hung: workers left parked on the request channel");
}

#[test]
fn fanout_and_coalescing_coexist_bit_identically() {
    // A fanned-out large request (8-row blocks spread under a tiny MAC
    // budget) and a stream of small coalescable requests share one
    // pool: the big request's row blocks hit the budget after one or
    // two pulls while the small requests' same-B tiles still stack into
    // single device calls. Every result — bits, meter coverage, energy
    // up to summation-order rounding — must match strictly per-tile
    // serial execution.
    let big = (48usize, 10usize, 32usize, 3u32);
    let small: Vec<(usize, usize, usize, u32)> =
        (0..6).map(|i| (16, 8, 8, (i % 4) as u32 * 2)).collect();
    let run_with = |workers: usize, batch: usize, batch_macs: u64| {
        let c = Coordinator::new(CoordinatorConfig {
            workers,
            batch,
            batch_macs,
            backend: BackendKind::Lut,
            sw_tile: Some((8, 32)),
            ..Default::default()
        });
        let mut reqs = vec![big];
        reqs.extend(small.iter().copied());
        let ids: Vec<u64> = reqs.iter().enumerate()
            .map(|(i, &(m, kk, nn, k))| c.submit(GemmRequest {
                a: ints(3 * i as u64 + 1, m * kk),
                b: ints(3 * i as u64 + 2, kk * nn),
                m, kk, nn, k,
                ..Default::default()
            }))
            .collect();
        let outs: Vec<(Vec<i64>, f64, u64)> = ids.into_iter().map(|id| {
            let r = c.wait(id);
            (r.out, r.sa_stats.energy_fj, r.sa_stats.metered_macs)
        }).collect();
        let s = c.stats();
        c.shutdown();
        (outs, s)
    };
    let (want, _) = run_with(1, 1, 1); // strictly per-tile serial
    let (got, s) = run_with(4, 16, 2_000);
    for (i, (w, g)) in want.iter().zip(&got).enumerate() {
        assert_eq!(g.0, w.0, "request {i}: fan-out changed the bits");
        assert_eq!(g.2, w.2, "request {i}: meter coverage");
        let tol = 1e-9 * w.1.max(1.0);
        assert!((g.1 - w.1).abs() < tol, "request {i}: energy sum");
    }
    // 48/8 = 6 row blocks for the big request, 16/8 = 2 tiles per small
    assert_eq!(s.dispatched_tiles, 6 + 6 * 2);
    assert!(s.coalesced_calls <= s.dispatched_tiles);
    assert!(s.max_dispatch_tiles >= 1);
}

#[test]
fn interleaved_ks_under_lut_do_not_cross_talk() {
    // per-request k routes to distinct shared tables; interleaving
    // requests must not mix them up
    let c = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Lut, ..Default::default()
    });
    let (m, kk, nn) = (8usize, 8usize, 8usize);
    let a = ints(5, m * kk);
    let b = ints(6, kk * nn);
    let ids: Vec<(u32, u64)> = (0..24).map(|i| {
        let k = (i % 4) * 2; // 0, 2, 4, 6
        (k, c.submit(GemmRequest { a: a.clone(), b: b.clone(), m, kk, nn, k,
                                   ..Default::default() }))
    }).collect();
    for (k, id) in ids {
        let cfg = PeConfig::new(8, true, Family::Proposed, k);
        let want = lut_matmul(&cfg, &a, &b, m, kk, nn);
        assert_eq!(c.wait(id).out, want, "k={k}");
    }
    let s = c.stats();
    assert_eq!(s.requests, 24);
    assert_eq!(s.lut_macs, 24 * (m * kk * nn) as u64);
    c.shutdown();
}
