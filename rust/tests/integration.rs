//! Cross-module integration tests: applications x backends x runtime.
//!
//! Tests that need AOT artifacts skip gracefully when `make artifacts`
//! has not run (CI without python), but exercise the full PJRT path when
//! it has.

use std::path::PathBuf;

use axsys::apps::image::{psnr, read_pgm, scene};
use axsys::apps::{bdcn, dct, edge, SystolicGemm, WordGemm};
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig, GemmRequest};
use axsys::pe::word::{matmul, PeConfig};
use axsys::runtime::{read_golden_bin, read_manifest, Runtime, TensorI32};
use axsys::Family;

/// Artifacts present on disk (enough for file-based cross-checks).
fn artifacts_dir() -> Option<PathBuf> {
    let dir = Runtime::default_artifacts_dir();
    dir.join("golden/manifest.txt").exists().then_some(dir)
}

/// Artifacts present AND the PJRT client compiled in — required by tests
/// that execute them; without the feature Runtime::new always errors, so
/// skip rather than panic even if `make artifacts` populated the files.
fn pjrt_dir() -> Option<PathBuf> {
    if cfg!(feature = "pjrt") {
        artifacts_dir()
    } else {
        None
    }
}

fn cfg(k: u32) -> PeConfig {
    PeConfig::new(8, true, Family::Proposed, k)
}

// ---------------------------------------------------------------
// application pipelines: backend equivalence
// ---------------------------------------------------------------

#[test]
fn dct_word_and_systolic_agree() {
    let img = scene(64, 64);
    for k in [0u32, 3, 7] {
        let (rw, cw) = dct::pipeline(&mut WordGemm { cfg: cfg(k) }, &img);
        let (rs, cs) = dct::pipeline(&mut SystolicGemm::new(cfg(k), 8), &img);
        assert_eq!(rw.data, rs.data, "k={k}");
        assert_eq!(cw, cs, "k={k}");
    }
}

#[test]
fn dct_backend_invariant_to_array_shape() {
    let img = scene(32, 32);
    let (r1, _) = dct::pipeline(&mut SystolicGemm::new(cfg(5), 4), &img);
    let (r2, _) = dct::pipeline(&mut SystolicGemm::new(cfg(5), 8), &img);
    assert_eq!(r1.data, r2.data);
}

#[test]
fn edge_word_and_systolic_agree() {
    let img = scene(48, 48);
    for k in [0u32, 6] {
        let ew = edge::pipeline(&mut WordGemm { cfg: cfg(k) }, &img);
        let es = edge::pipeline(&mut SystolicGemm::new(cfg(k), 8), &img);
        assert_eq!(ew.data, es.data, "k={k}");
    }
}

#[test]
fn applications_full_quality_ladder() {
    // the paper's Table VI shape on a smaller image: CNN > DCT > kernel
    // robustness at high k is not universal, but all must degrade
    // monotonically and stay finite
    let img = scene(64, 64);
    let (e0, _) = dct::pipeline(&mut WordGemm { cfg: cfg(0) }, &img);
    let mut last = f64::INFINITY;
    for k in [2u32, 4, 6, 8] {
        let (r, _) = dct::pipeline(&mut WordGemm { cfg: cfg(k) }, &img);
        let p = psnr(&e0.data, &r.data);
        assert!(p.is_finite() && p > 10.0);
        assert!(p <= last + 1.0);
        last = p;
    }
}

// ---------------------------------------------------------------
// coordinator: service-level behaviour
// ---------------------------------------------------------------

#[test]
fn coordinator_matches_direct_word_model() {
    let c = Coordinator::new(CoordinatorConfig {
        workers: 3, backend: BackendKind::Word, ..Default::default()
    });
    let (m, kk, nn) = (19usize, 11usize, 23usize);
    let a: Vec<i64> = (0..m * kk).map(|i| ((i * 41) % 255) as i64 - 127).collect();
    let b: Vec<i64> = (0..kk * nn).map(|i| ((i * 59) % 255) as i64 - 127).collect();
    for k in [0u32, 5] {
        let resp = c.call(GemmRequest { a: a.clone(), b: b.clone(), m, kk,
                                        nn, k, ..Default::default() });
        // per-tile word model with the same 8-wide tiling the coordinator
        // performs (approximate state walks are tile-local)
        let mut want = vec![0i64; m * nn];
        let pc = cfg(k);
        for ti in (0..m).step_by(8) {
            for tj in (0..nn).step_by(8) {
                let th = (m - ti).min(8);
                let tw = (nn - tj).min(8);
                let ap: Vec<i64> = (0..th).flat_map(
                    |i| a[(ti + i) * kk..(ti + i + 1) * kk].to_vec()).collect();
                let bp: Vec<i64> = (0..kk).flat_map(
                    |t| b[t * nn + tj..t * nn + tj + tw].to_vec()).collect();
                let tile = matmul(&pc, &ap, &bp, th, kk, tw);
                for i in 0..th {
                    for j in 0..tw {
                        want[(ti + i) * nn + tj + j] = tile[i * tw + j];
                    }
                }
            }
        }
        assert_eq!(resp.out, want, "k={k}");
    }
    c.shutdown();
}

#[test]
fn coordinator_backpressure_small_queue() {
    // queue depth 2 with many tiles: submit must still complete
    let c = Coordinator::new(CoordinatorConfig {
        workers: 2, queue_depth: 2, backend: BackendKind::Word,
        ..Default::default()
    });
    let (m, kk, nn) = (64usize, 8usize, 64usize); // 64 tiles
    let a = vec![1i64; m * kk];
    let b = vec![1i64; kk * nn];
    let resp = c.call(GemmRequest { a, b, m, kk, nn, k: 0, ..Default::default() });
    assert!(resp.out.iter().all(|&v| v == kk as i64));
    c.shutdown();
}

#[test]
fn coordinator_interleaved_ks_do_not_cross_talk() {
    let c = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Word, ..Default::default()
    });
    let (m, kk, nn) = (8usize, 8usize, 8usize);
    let a: Vec<i64> = (0..64).map(|i| (i as i64 * 7 % 255) - 127).collect();
    let b: Vec<i64> = (0..64).map(|i| (i as i64 * 13 % 255) - 127).collect();
    // submit alternating k, verify each against a direct computation
    let ids: Vec<(u32, u64)> = (0..16).map(|i| {
        let k = (i % 4) * 2;
        (k, c.submit(GemmRequest { a: a.clone(), b: b.clone(), m, kk, nn, k,
                                   ..Default::default() }))
    }).collect();
    for (k, id) in ids {
        let resp = c.wait(id);
        let want = matmul(&cfg(k), &a, &b, m, kk, nn);
        assert_eq!(resp.out, want, "k={k}");
    }
    c.shutdown();
}

// ---------------------------------------------------------------
// PJRT runtime (requires artifacts)
// ---------------------------------------------------------------

#[test]
fn golden_replay_all_cases() {
    let Some(dir) = pjrt_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let golden = dir.join("golden");
    let rt = Runtime::new(&dir).expect("runtime");
    let cases = read_manifest(&golden).expect("manifest");
    assert_eq!(cases.len(), 10);
    for case in &cases {
        let mut inputs = Vec::new();
        for (i, shape) in case.in_shapes.iter().enumerate() {
            let data = read_golden_bin(
                &golden.join(format!("{}_in{i}.bin", case.case))).unwrap();
            inputs.push(TensorI32::new(shape.clone(), data));
        }
        inputs.push(TensorI32::scalar1(case.k));
        let outs = rt.run(&case.artifact, &inputs).expect("run");
        for (i, shape) in case.out_shapes.iter().enumerate() {
            let want = read_golden_bin(
                &golden.join(format!("{}_out{i}.bin", case.case))).unwrap();
            assert_eq!(&outs[i].dims, shape, "{} out{}", case.case, i);
            assert_eq!(outs[i].data, want, "{} out{}", case.case, i);
        }
    }
}

#[test]
fn pjrt_gemm_matches_word_model_across_k() {
    let Some(dir) = pjrt_dir() else {
        return;
    };
    let rt = Runtime::new(&dir).expect("runtime");
    let exe = rt.load("gemm64").expect("gemm64");
    let a: Vec<i64> = (0..64 * 64).map(|i| ((i * 37) % 255) as i64 - 127).collect();
    let b: Vec<i64> = (0..64 * 64).map(|i| ((i * 91) % 255) as i64 - 127).collect();
    let a32: Vec<i32> = a.iter().map(|&v| v as i32).collect();
    let b32: Vec<i32> = b.iter().map(|&v| v as i32).collect();
    for k in [0u32, 1, 4, 8] {
        let outs = rt.execute_i32(&exe, &[
            TensorI32::new(vec![64, 64], a32.clone()),
            TensorI32::new(vec![64, 64], b32.clone()),
            TensorI32::scalar1(k as i32),
        ]).expect("exec");
        let want = matmul(&cfg(k), &a, &b, 64, 64, 64);
        let got: Vec<i64> = outs[0].data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, want, "k={k}");
    }
}

#[test]
fn pjrt_coordinator_backend_exact_path() {
    let Some(_) = pjrt_dir() else {
        return;
    };
    let c = Coordinator::new(CoordinatorConfig {
        workers: 1, backend: BackendKind::Pjrt, ..Default::default()
    });
    let (m, kk, nn) = (16usize, 16usize, 16usize);
    let a: Vec<i64> = (0..m * kk).map(|i| ((i * 23) % 255) as i64 - 127).collect();
    let b: Vec<i64> = (0..kk * nn).map(|i| ((i * 71) % 255) as i64 - 127).collect();
    // exact requests are bit-identical regardless of K chunking
    let resp = c.call(GemmRequest { a: a.clone(), b: b.clone(), m, kk, nn,
                                    k: 0, ..Default::default() });
    let mut want = vec![0i64; m * nn];
    for i in 0..m {
        for j in 0..nn {
            want[i * nn + j] = (0..kk).map(|t| a[i * kk + t] * b[t * nn + j]).sum();
        }
    }
    assert_eq!(resp.out, want);
    c.shutdown();
}

#[test]
fn scene_pgm_cross_language_identity() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let img = read_pgm(&dir.join("images/scene256.pgm")).expect("pgm");
    let ours = scene(256, 256);
    assert_eq!(img, ours, "python and rust scene generators must be identical");
}

#[test]
fn bdcn_weights_cross_language() {
    let Some(dir) = pjrt_dir() else {
        return;
    };
    let blocks = bdcn::load_weights(&dir.join("bdcn_weights.txt")).expect("weights");
    let img = scene(64, 64);
    // PJRT bdcn128 vs rust-side forward on the 128 scene
    let rt = Runtime::new(&dir).expect("runtime");
    let img128 = scene(128, 128);
    let outs = rt.run("bdcn128", &[
        TensorI32::new(vec![128, 128], img128.to_i32()),
        TensorI32::scalar1(4),
    ]).expect("bdcn128");
    let want = bdcn::forward_word(&blocks, &img128, 4);
    let got: Vec<u8> = outs[0].data.iter().map(|&v| v.clamp(0, 255) as u8).collect();
    assert_eq!(got, want.data);
    let _ = img;
}
