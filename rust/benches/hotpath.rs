//! Hot-path performance benchmarks (the §Perf deliverable).
//!
//! Measures every layer the request path touches:
//!   L3: word-level MAC + GEMM, cycle-accurate SA stepping, netlist
//!       evaluation, coordinator end-to-end throughput;
//!   runtime: PJRT execution of the AOT artifacts (gemm64 / axmm_b16 /
//!       full DCT pipeline).
//!
//! ```bash
//! cargo bench --bench hotpath
//! ```
//! Results are recorded in EXPERIMENTS.md §Perf (before/after log).

use axsys::bench::{black_box, run, speedup, xorshift_ints as ints};
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig, GemmRequest};
use axsys::gemm::BlockedGemm;
use axsys::netlist::random_vectors;
use axsys::pe::lut::ProductLut;
use axsys::pe::netlist_builder::pe_netlists;
use axsys::pe::word::{mac_step, matmul, PeConfig};
use axsys::pe::{Design, Signedness};
use axsys::runtime::{Runtime, TensorI32};
use axsys::systolic::Systolic;
use axsys::Family;

fn main() {
    let cfg = PeConfig::new(8, true, Family::Proposed, 7);
    let cfg0 = PeConfig::new(8, true, Family::Proposed, 0);

    // L3 kernel: single fused MAC (the innermost hot function)
    let mut s = 0u64;
    let mut kc = 0u64;
    let m = run("word::mac_step (1 MAC, k=7)", 200, || {
        let (s2, k2) = mac_step(black_box(&cfg), black_box(0x5A), black_box(0xC3),
                                black_box(s), black_box(kc));
        s = s2;
        kc = k2;
    });
    println!("    -> {:.1} M MAC/s", 1e3 / m.median_ns);

    // L3: functional GEMM 64x64x64
    let a = ints(1, 64 * 64);
    let b = ints(2, 64 * 64);
    let g = run("word::matmul 64x64x64 (k=7)", 400, || {
        black_box(matmul(black_box(&cfg), &a, &b, 64, 64, 64));
    });
    println!("    -> {:.1} M MAC/s",
             (64.0 * 64.0 * 64.0) / g.median_ns * 1e3);

    // exact config for comparison (same path, different masks)
    run("word::matmul 64x64x64 (k=0)", 400, || {
        black_box(matmul(black_box(&cfg0), &a, &b, 64, 64, 64));
    });

    // lut_vs_word: the serving-scale comparison (issue acceptance gate:
    // >= 5x on 256x256x256). Same arithmetic, table-driven vs bit-plane.
    let cfg4 = PeConfig::new(8, true, Family::Proposed, 4);
    let al = ints(5, 256 * 256);
    let bl = ints(6, 256 * 256);
    let lut4 = ProductLut::try_build(&cfg4).expect("lut k=4");
    assert_eq!(lut4.matmul(&al, &bl, 256, 256, 256),
               matmul(&cfg4, &al, &bl, 256, 256, 256),
               "lut and word disagree — bench comparison would be invalid");
    let w256 = run("word::matmul 256x256x256 (k=4)", 1500, || {
        black_box(matmul(black_box(&cfg4), &al, &bl, 256, 256, 256));
    });
    let l256 = run("lut::matmul  256x256x256 (k=4)", 1500, || {
        black_box(lut4.matmul(black_box(&al), &bl, 256, 256, 256));
    });
    let sx = speedup(&w256, &l256);
    println!("    -> lut_vs_word: {:.1}x speedup ({:.1} -> {:.1} M MAC/s){}",
             sx,
             (256.0f64 * 256.0 * 256.0) / w256.median_ns * 1e3,
             (256.0f64 * 256.0 * 256.0) / l256.median_ns * 1e3,
             if sx >= 5.0 { "  [>=5x OK]" } else { "  [BELOW 5x TARGET]" });
    let lut7 = ProductLut::try_build(&PeConfig::new(8, true, Family::Proposed, 7))
        .expect("lut k=7");
    let l7 = run("lut::matmul  256x256x256 (k=7)", 1500, || {
        black_box(lut7.matmul(black_box(&al), &bl, 256, 256, 256));
    });
    println!("    -> k=7 table: {} states, {} KiB, {:.1} M MAC/s",
             lut7.states(), lut7.table_bytes() / 1024,
             (256.0f64 * 256.0 * 256.0) / l7.median_ns * 1e3);

    // blocked_vs_naive: the MC×KC×NC packed-panel driver against the
    // PR 1 naive LUT walk on the same 256³ problem (issue acceptance
    // gate: blocked must win). Bit-identity asserted before timing.
    let mut bg = BlockedGemm::default();
    assert_eq!(bg.matmul(&cfg4, &al, &bl, 256, 256, 256),
               matmul(&cfg4, &al, &bl, 256, 256, 256),
               "blocked and word disagree — bench comparison would be invalid");
    let g256 = run("gemm::blocked lut 256x256x256 (k=4)", 1500, || {
        black_box(bg.matmul(black_box(&cfg4), &al, &bl, 256, 256, 256));
    });
    let gx = speedup(&l256, &g256);
    println!("    -> blocked_vs_naive: {:.2}x over naive lut ({:.1} M MAC/s){}",
             gx, (256.0f64 * 256.0 * 256.0) / g256.median_ns * 1e3,
             if gx >= 1.0 { "  [blocked >= naive OK]" }
             else { "  [REGRESSION vs naive lut]" });
    let gw256 = run("gemm::blocked word 256x256x256 (k=4)", 1500, || {
        black_box(bg.matmul_word(black_box(&cfg4), &al, &bl, 256, 256, 256));
    });
    println!("    -> blocked word: {:.2}x over naive word ({:.1} M MAC/s)",
             speedup(&w256, &gw256),
             (256.0f64 * 256.0 * 256.0) / gw256.median_ns * 1e3);

    // L3: cycle-accurate systolic tile stream
    let mut sa = Systolic::square(cfg, 8);
    let at = ints(3, 8 * 8);
    let bt = ints(4, 8 * 8);
    let t = run("systolic 8x8 tile (K=8)", 300, || {
        black_box(sa.run_tile(black_box(&at), black_box(&bt), 8));
    });
    println!("    -> {:.2} M cycle-steps/s (22 cycles x 64 PEs per tile)",
             22.0 * 64.0 / t.median_ns * 1e3);

    // L3: gate-level netlist evaluation (hardware-model hot loop)
    let nets = pe_netlists(&Design::approximate_default(
        8, Signedness::Signed, Family::Proposed), 24);
    let vecs = random_vectors(nets.grid.inputs.len(), 64, 3);
    let mut scratch = Vec::new();
    let n = run("netlist eval PE grid (64 vectors)", 300, || {
        for v in &vecs {
            black_box(nets.grid.eval_into(black_box(v), &mut scratch));
        }
    });
    println!("    -> {:.1} M gate-evals/s",
             64.0 * nets.grid.gates.len() as f64 / n.median_ns * 1e3);

    // coordinator end-to-end (word backend, 4 workers)
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Word, ..Default::default()
    });
    let c = run("coordinator 16 reqs 64x64x64 (4 workers)", 800, || {
        let ids: Vec<u64> = (0..16).map(|i| {
            coord.submit(GemmRequest {
                a: a.clone(), b: b.clone(), m: 64, kk: 64, nn: 64,
                k: (i % 8) as u32,
                ..Default::default()
            })
        }).collect();
        for id in ids {
            black_box(coord.wait(id));
        }
    });
    println!("    -> {:.0} req/s end-to-end", 16.0 / (c.median_ns * 1e-9));
    coord.shutdown();

    // coordinator end-to-end on the table-driven backend
    let coord_lut = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Lut, ..Default::default()
    });
    let cl = run("coordinator 16 reqs 64x64x64 (4 workers, lut)", 800, || {
        let ids: Vec<u64> = (0..16).map(|i| {
            coord_lut.submit(GemmRequest {
                a: a.clone(), b: b.clone(), m: 64, kk: 64, nn: 64,
                k: (i % 8) as u32,
                ..Default::default()
            })
        }).collect();
        for id in ids {
            black_box(coord_lut.wait(id));
        }
    });
    println!("    -> {:.0} req/s end-to-end ({:.1}x vs word backend)",
             16.0 / (cl.median_ns * 1e-9), speedup(&c, &cl));
    coord_lut.shutdown();

    // app serving throughput: the paper's pipelines end-to-end through
    // the coordinator (every GEMM stage tiled across the worker pool on
    // the table-driven backend)
    let coord_apps = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Lut, ..Default::default()
    });
    let img = axsys::apps::image::scene(256, 256);
    let da = run("coordinator serve_dct 256x256 (lut, k=5)", 2000, || {
        black_box(coord_apps.serve_dct(black_box(&img), 5));
    });
    println!("    -> {:.2} Mpix/s served", (256.0 * 256.0) / da.median_ns * 1e3);
    let ea = run("coordinator serve_edge 256x256 (lut, k=4)", 2000, || {
        black_box(coord_apps.serve_edge(black_box(&img), 4));
    });
    println!("    -> {:.2} Mpix/s served (each call includes the exact \
              reference pass)", (256.0 * 256.0) / ea.median_ns * 1e3);
    let sa_stats = coord_apps.stats_snapshot();
    println!("    -> app stats: dct {} reqs (mean PSNR {:.2} dB), edge {} \
              reqs (mean {:.2} dB); gemm p50 {:.1} µs p99 {:.1} µs",
             sa_stats.dct.requests, sa_stats.dct.mean_psnr_db(),
             sa_stats.edge.requests, sa_stats.edge.mean_psnr_db(),
             sa_stats.latency_percentile(0.50),
             sa_stats.latency_percentile(0.99));
    coord_apps.shutdown();

    // PJRT: AOT artifact execution
    let dir = Runtime::default_artifacts_dir();
    if cfg!(feature = "pjrt") && dir.join("gemm64.hlo.txt").exists() {
        let rt = Runtime::new(&dir).expect("runtime");
        let exe = rt.load("gemm64").expect("gemm64");
        let a32: Vec<i32> = a.iter().map(|&v| v as i32).collect();
        let b32: Vec<i32> = b.iter().map(|&v| v as i32).collect();
        let inputs = [
            TensorI32::new(vec![64, 64], a32),
            TensorI32::new(vec![64, 64], b32),
            TensorI32::scalar1(7),
        ];
        let p = run("PJRT gemm64 (AOT pallas, k=7)", 800, || {
            black_box(rt.execute_i32(&exe, &inputs).expect("exec"));
        });
        println!("    -> {:.1} M MAC/s via XLA",
                 (64.0 * 64.0 * 64.0) / p.median_ns * 1e3);

        let exe_b = rt.load("axmm_b16").expect("axmm_b16");
        let ta: Vec<i32> = (0..16 * 64).map(|i| ((i * 37) % 255) as i32 - 127).collect();
        let tb: Vec<i32> = (0..16 * 64).map(|i| ((i * 91) % 255) as i32 - 127).collect();
        let inputs_b = [
            TensorI32::new(vec![16, 8, 8], ta),
            TensorI32::new(vec![16, 8, 8], tb),
            TensorI32::scalar1(7),
        ];
        run("PJRT axmm_b16 (16 SA tiles)", 500, || {
            black_box(rt.execute_i32(&exe_b, &inputs_b).expect("exec"));
        });

        if dir.join("dct256.hlo.txt").exists() {
            let exe_d = rt.load("dct256").expect("dct256");
            let img = axsys::apps::image::scene(256, 256);
            let inputs_d = [
                TensorI32::new(vec![256, 256], img.to_i32()),
                TensorI32::scalar1(2),
            ];
            let d = run("PJRT dct256 full pipeline (k=2)", 1500, || {
                black_box(rt.execute_i32(&exe_d, &inputs_d).expect("exec"));
            });
            println!("    -> {:.1} Mpix/s through 4 approximate GEMM stages",
                     (256.0 * 256.0) / d.median_ns * 1e3);
        }
    } else {
        println!("(PJRT benches skipped: run `make artifacts`)");
    }
}
