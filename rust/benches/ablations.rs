//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! A. accumulator guard bits (W = 2N+G): overflow rate vs accumulation
//!    depth — justifies the default G = 8;
//! B. coordinator batch size: throughput vs batching granularity;
//! C. systolic array geometry: tiles/s and utilization for one workload;
//! D. chunked-K accumulation (the PJRT serving mode) vs monolithic
//!    approximate accumulation: quality cost of splitting the reduction;
//! E. quality-vs-energy Pareto across k for the DCT workload.
//!
//! ```bash
//! cargo bench --bench ablations
//! ```

use std::time::Instant;

use axsys::apps::image::{psnr, scene};
use axsys::apps::{dct, WordGemm};
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig, GemmRequest};
use axsys::hw;
use axsys::pe::word::{mac_step_planned, MacPlan, PeConfig};
use axsys::pe::{Design, Signedness};
use axsys::systolic::Systolic;
use axsys::Family;

fn ints(seed: u64, len: usize) -> Vec<i64> {
    let mut s = seed | 1;
    (0..len).map(|_| {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        (s as i64 & 255) - 128
    }).collect()
}

fn main() {
    guard_bits();
    batch_size();
    array_geometry();
    chunked_k();
    pareto();
}

/// A. guard bits: fraction of random length-L dot products that overflow
/// a (2N+G)-bit accumulator.
fn guard_bits() {
    println!("=== Ablation A: accumulator guard bits (8-bit operands) ===");
    println!("{:>3} {:>8} {:>12} {:>12} {:>12}", "G", "W", "L=64", "L=256", "L=1024");
    for g in [2u32, 4, 8, 12] {
        print!("{:>3} {:>8}", g, 16 + g);
        for chain in [64usize, 256, 1024] {
            let mut cfg = PeConfig::new(8, true, Family::Proposed, 0);
            cfg.w = 16 + g;
            let plan = MacPlan::new(&cfg);
            let mut overflows = 0;
            let mut s0 = 99u64;
            let mut rnd = || {
                s0 ^= s0 << 13;
                s0 ^= s0 >> 7;
                s0 ^= s0 << 17;
                s0
            };
            let samples = 300;
            for _ in 0..samples {
                let mut s = 0u64;
                let mut kc = 0u64;
                let mut exact = 0i64;
                for _ in 0..chain {
                    let a = (rnd() as i64 & 255) - 128;
                    let b = (rnd() as i64 & 255) - 128;
                    let (s2, k2) = mac_step_planned(&plan, cfg.encode(a),
                                                    cfg.encode(b), s, kc);
                    s = s2;
                    kc = k2;
                    exact += a * b;
                }
                let y = cfg.decode(s.wrapping_add(kc) & cfg.word_mask());
                if y != exact {
                    overflows += 1;
                }
            }
            print!(" {:>11.1}%", overflows as f64 / samples as f64 * 100.0);
        }
        println!();
    }
    println!("(G = 8 default: zero overflow through L = 256, the largest\n\
              reduction any shipped pipeline performs)\n");
}

/// B. worker batch size vs coordinator throughput.
fn batch_size() {
    println!("=== Ablation B: coordinator batch size (word backend) ===");
    let (m, kk, nn) = (64usize, 16usize, 64usize);
    let a = ints(1, m * kk);
    let b = ints(2, kk * nn);
    println!("{:>6} {:>12}", "batch", "req/s");
    for batch in [1usize, 4, 16, 64] {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 4, batch, backend: BackendKind::Word, ..Default::default()
        });
        let t0 = Instant::now();
        let reqs = 24;
        let ids: Vec<u64> = (0..reqs).map(|_| c.submit(GemmRequest {
            a: a.clone(), b: b.clone(), m, kk, nn, k: 7,
            ..Default::default()
        })).collect();
        for id in ids {
            c.wait(id);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("{:>6} {:>12.1}", batch, reqs as f64 / dt);
        c.shutdown();
    }
    println!();
}

/// C. array geometry: same GEMM, different SA shapes.
fn array_geometry() {
    println!("=== Ablation C: systolic geometry for a 32x32x32 GEMM ===");
    let (m, kk, nn) = (32usize, 32usize, 32usize);
    let a = ints(3, m * kk);
    let b = ints(4, kk * nn);
    println!("{:>8} {:>10} {:>10} {:>12} {:>10}", "array", "tiles",
             "cycles", "macs/cycle", "wall µs");
    for (r, c) in [(4usize, 4usize), (8, 8), (16, 16), (4, 16), (16, 4)] {
        let cfg = PeConfig::new(8, true, Family::Proposed, 7);
        let mut sa = Systolic::new(cfg, r, c);
        let t0 = Instant::now();
        let (_, st) = sa.gemm(&a, &b, m, kk, nn);
        let wall = t0.elapsed().as_secs_f64() * 1e6;
        println!("{:>8} {:>10} {:>10} {:>12.1} {:>10.0}",
                 format!("{r}x{c}"), st.tiles, st.total_cycles(),
                 st.macs as f64 / st.total_cycles() as f64, wall);
    }
    println!("(bigger arrays amortize the 3N-2 skew fill; utilization =\n\
              macs/cycle / PEs shows the fill/drain tax on small tiles)\n");
}

/// D. chunked-K (PJRT serving mode) vs monolithic accumulation quality.
fn chunked_k() {
    println!("=== Ablation D: chunked-K accumulation (approximate requests) ===");
    let (m, kk, nn) = (16usize, 64usize, 16usize);
    let a = ints(5, m * kk);
    let b = ints(6, kk * nn);
    let exact: Vec<i64> = (0..m).flat_map(|i| (0..nn).map(move |j| (i, j)))
        .map(|(i, j)| (0..kk).map(|t| a[i * kk + t] * b[t * nn + j]).sum())
        .collect();
    println!("{:>2} {:>16} {:>16}", "k", "monolithic MED", "chunked-8 MED");
    for k in [2u32, 5, 8] {
        let cfg = PeConfig::new(8, true, Family::Proposed, k);
        let mono = axsys::pe::word::matmul(&cfg, &a, &b, m, kk, nn);
        // chunked: split K into 8-chunks, each through the PE, sum outside
        let mut chunked = vec![0i64; m * nn];
        for c0 in (0..kk).step_by(8) {
            let cw = (kk - c0).min(8);
            let ac: Vec<i64> = (0..m).flat_map(
                |i| a[i * kk + c0..i * kk + c0 + cw].to_vec()).collect();
            let bc: Vec<i64> = (c0..c0 + cw).flat_map(
                |t| b[t * nn..(t + 1) * nn].to_vec()).collect();
            let part = axsys::pe::word::matmul(&cfg, &ac, &bc, m, cw, nn);
            for (o, p) in chunked.iter_mut().zip(part) {
                *o += p;
            }
        }
        let med = |y: &[i64]| y.iter().zip(&exact)
            .map(|(&v, &e)| (v - e).abs() as f64)
            .sum::<f64>() / y.len() as f64;
        println!("{:>2} {:>16.1} {:>16.1}", k, med(&mono), med(&chunked));
    }
    println!("(chunking resets the approximate carry-save walk every 8 MACs\n\
              — slightly different error, same magnitude; k=0 identical)\n");
}

/// E. DCT quality-vs-energy Pareto (the deployment decision the paper
/// motivates).
fn pareto() {
    println!("=== Ablation E: DCT quality vs SA energy across k ===");
    let img = scene(128, 128);
    let mk = |k: u32| WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, k) };
    let (exact, _) = dct::pipeline(&mut mk(0), &img);
    println!("{:>2} {:>10} {:>14} {:>12}", "k", "PSNR dB", "SA PDP (fJ)",
             "energy -%");
    let base = hw::sa_metrics(&Design::proposed_exact(8, Signedness::Signed), 8)
        .pdp_fj;
    for k in 0..=8u32 {
        let (r, _) = dct::pipeline(&mut mk(k), &img);
        let d = if k == 0 {
            Design::proposed_exact(8, Signedness::Signed)
        } else {
            Design::approximate(8, Signedness::Signed, Family::Proposed, k)
        };
        let pdp = hw::sa_metrics(&d, 8).pdp_fj;
        let p = psnr(&exact.data, &r.data);
        println!("{:>2} {:>10.2} {:>14.1} {:>11.1}%", k,
                 if p.is_finite() { p } else { 99.99 }, pdp,
                 (1.0 - pdp / base) * 100.0);
    }
    println!("(k = 2-4 is the paper's sweet spot: >44 dB at measurable\n\
              energy savings)");
}
