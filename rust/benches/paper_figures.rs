//! Regenerates every FIGURE series of the paper's evaluation:
//!
//! * Fig. 8  — area & PDP savings of the proposed designs across SA sizes
//! * Fig. 9  — PDP vs NMED scatter (signed 8-bit PE, k = N-1)
//! * Fig. 10 — PDP and MRED vs approximation factor k
//! * Fig. 11 — DCT image pipeline outputs + PSNR/SSIM (written as PGM)
//! * Fig. 13 — kernel vs BDCN edge-detection grid across k (PGM grid)
//!
//! ```bash
//! cargo bench --bench paper_figures [-- --fig8|--fig9|--fig10|--fig11|--fig13]
//! ```
//! PGM outputs land in `out/figures/`.

use axsys::apps::image::{psnr, scene, ssim, write_pgm};
use axsys::apps::{bdcn, dct, edge, WordGemm};
use axsys::hw;
use axsys::pe::word::PeConfig;
use axsys::runtime::Runtime;
use axsys::Family;

fn want(flag: &str) -> bool {
    let args: Vec<String> = std::env::args().collect();
    let any = args.iter().any(|a| a.starts_with("--fig"));
    !any || args.iter().any(|a| a == flag)
}

fn main() -> anyhow::Result<()> {
    let out = std::path::PathBuf::from("out/figures");
    std::fs::create_dir_all(&out)?;
    if want("--fig8") {
        fig8();
    }
    if want("--fig9") {
        fig9();
    }
    if want("--fig10") {
        fig10();
    }
    if want("--fig11") {
        fig11(&out)?;
    }
    if want("--fig13") {
        fig13(&out)?;
    }
    Ok(())
}

fn fig8() {
    // paper (8-bit signed): area savings up to 5.9%, PDP up to 14.1% for
    // the exact design; approx-vs-[5] up to 24.2% at 16x16
    println!("=== Fig 8: savings across SA sizes (8-bit signed) ===");
    println!("{:>5} {:>16} {:>15} {:>22}", "size", "area saving %",
             "PDP saving %", "approx vs [5] PDP %");
    for p in hw::fig8(8) {
        println!("{:>5} {:>16.1} {:>15.1} {:>22.1}",
                 format!("{0}x{0}", p.size), p.area_saving_pct,
                 p.pdp_saving_pct, p.approx_pdp_vs_best_pct);
    }
    println!("(paper: exact area up to 5.9%, exact PDP up to 14.1%, approx \
              vs [5] up to 24.2%)\n");
}

fn fig9() {
    println!("=== Fig 9: PDP vs NMED, signed 8-bit, k = N-1 ===");
    println!("{:<12} {:>12} {:>10}", "design", "PDP (fJ)", "NMED");
    for p in hw::fig9() {
        println!("{:<12} {:>12.1} {:>10.4}", p.label, p.pdp_fj, p.nmed);
    }
    println!("(paper's pattern: proposed has the lowest PDP; [5] slightly \
              lower NMED but worse area/power/delay)\n");
}

fn fig10() {
    println!("=== Fig 10: PDP and MRED vs k (signed 8-bit, proposed) ===");
    println!("{:>2} {:>12} {:>10}", "k", "PDP (fJ)", "MRED");
    for p in hw::fig10() {
        println!("{:>2} {:>12.1} {:>10.4}", p.k, p.pdp_fj, p.mred);
    }
    println!("(paper's pattern: PDP decreases monotonically, MRED grows)\n");
}

fn fig11(out: &std::path::Path) -> anyhow::Result<()> {
    println!("=== Fig 11: DCT pipeline images (k=2) ===");
    let img = scene(256, 256);
    let mk = |k: u32| WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, k) };
    let (exact, coeff) = dct::pipeline(&mut mk(0), &img);
    let (apx, _) = dct::pipeline(&mut mk(2), &img);
    // coefficient visualization (log-scaled magnitude)
    let mut cimg = axsys::apps::image::Image::new(256, 256);
    for (o, &c) in cimg.data.iter_mut().zip(coeff.iter()) {
        *o = (((c.unsigned_abs() as f64 + 1.0).ln() * 46.0) as i64)
            .clamp(0, 255) as u8;
    }
    write_pgm(&out.join("fig11_input.pgm"), &img)?;
    write_pgm(&out.join("fig11_coefficients.pgm"), &cimg)?;
    write_pgm(&out.join("fig11_recon_exact.pgm"), &exact)?;
    write_pgm(&out.join("fig11_recon_k2.pgm"), &apx)?;
    println!("  k=2 vs exact: PSNR {:.2} dB SSIM {:.4} (paper: 45.97 dB / 0.991)",
             psnr(&exact.data, &apx.data), ssim(&exact.data, &apx.data));
    println!("  wrote {}/fig11_*.pgm\n", out.display());
    Ok(())
}

fn fig13(out: &std::path::Path) -> anyhow::Result<()> {
    println!("=== Fig 13: kernel vs BDCN edge maps across k ===");
    let img = scene(128, 128);
    let mk = |k: u32| WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, k) };
    let lap_exact = edge::pipeline(&mut mk(0), &img);
    write_pgm(&out.join("fig13_kernel_exact.pgm"), &lap_exact)?;
    let weights = Runtime::default_artifacts_dir().join("bdcn_weights.txt");
    let blocks = bdcn::load_weights(&weights).ok();
    let bdcn_exact = blocks.as_ref().map(|b| bdcn::forward_word(b, &img, 0));
    if let Some(e) = &bdcn_exact {
        write_pgm(&out.join("fig13_bdcn_exact.pgm"), e)?;
    }
    println!("{:>2} {:>18} {:>18}", "k", "kernel PSNR (dB)", "BDCN PSNR (dB)");
    for k in [2u32, 4, 6, 8] {
        let lap = edge::pipeline(&mut mk(k), &img);
        write_pgm(&out.join(format!("fig13_kernel_k{k}.pgm")), &lap)?;
        let bp = match (&blocks, &bdcn_exact) {
            (Some(b), Some(ex)) => {
                let e = bdcn::forward_word(b, &img, k);
                write_pgm(&out.join(format!("fig13_bdcn_k{k}.pgm")), &e)?;
                psnr(&ex.data, &e.data)
            }
            _ => f64::NAN,
        };
        println!("{:>2} {:>18.2} {:>18.2}", k,
                 psnr(&lap_exact.data, &lap.data), bp);
    }
    println!("(paper's pattern: BDCN stays far above the kernel method at \
              every k)\n  wrote {}/fig13_*.pgm\n", out.display());
    Ok(())
}
