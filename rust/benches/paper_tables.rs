//! Regenerates every TABLE of the paper's evaluation (II, III, IV, V, VI)
//! side by side with the published values.
//!
//! Absolute numbers come from our calibrated 90 nm-class model (one anchor:
//! the conventional exact PPC, Table II row 1) — everything else is
//! composed structurally, so the *relative* story (who wins, by what
//! factor) is genuine model output. See EXPERIMENTS.md for the recorded
//! comparison and deviations.
//!
//! ```bash
//! cargo bench --bench paper_tables [-- --table2|--table3|--table4|--table5|--table6|--headline]
//! ```

use axsys::apps::image::{psnr, scene, ssim};
use axsys::apps::{bdcn, dct, edge, WordGemm};
use axsys::error::table5_row;
use axsys::hw;
use axsys::pe::word::PeConfig;
use axsys::pe::{Design, Signedness};
use axsys::runtime::Runtime;
use axsys::Family;

fn want(flag: &str) -> bool {
    let args: Vec<String> = std::env::args().collect();
    let any = args.iter().any(|a| a.starts_with("--table") || a == "--headline");
    !any || args.iter().any(|a| a == flag)
}

fn main() {
    if want("--table2") {
        table2();
    }
    if want("--table3") {
        table3();
    }
    if want("--table4") {
        table4();
    }
    if want("--table5") {
        table5();
    }
    if want("--table6") {
        table6();
    }
    if want("--headline") {
        headline();
    }
}

// paper Table II (area µm², power µW, delay ps, PDP aJ), [PPC, NPPC]
const PAPER_T2: [(&str, [f64; 4], [f64; 4]); 5] = [
    ("Exact [6]", [25.81, 1.03, 262.0, 269.86], [24.92, 0.99, 238.0, 235.62]),
    ("Prop Ext", [24.98, 0.99, 255.0, 252.45], [23.47, 0.99, 216.0, 213.84]),
    ("Design [6]", [13.32, 0.64, 187.0, 119.04], [12.54, 0.61, 156.0, 95.16]),
    ("Design [5]", [14.13, 0.58, 157.0, 91.06], [13.22, 0.60, 148.0, 88.80]),
    ("Prop Apx", [10.19, 0.44, 110.0, 48.40], [9.40, 0.37, 147.0, 54.39]),
];

fn table2() {
    println!("=== Table II: PPC/NPPC cell metrics (ours, then paper) ===");
    println!("{:<12} | {:>30} | {:>30}", "design",
             "PPC: area power delay PDP", "NPPC: area power delay PDP");
    for (row, paper) in hw::table2().iter().zip(PAPER_T2.iter()) {
        let f = |m: &hw::HwMetrics| {
            format!("{:6.2} {:5.2} {:5.0} {:7.1}", m.area_um2, m.power_uw,
                    m.delay_ns * 1e3, m.pdp_fj * 1e3)
        };
        let fp = |p: &[f64; 4]| {
            format!("{:6.2} {:5.2} {:5.0} {:7.1}", p[0], p[1], p[2], p[3])
        };
        println!("{:<12} | {} | {}", row.label, f(&row.ppc), f(&row.nppc));
        println!("{:<12} | {} | {}", "  (paper)", fp(&paper.1), fp(&paper.2));
    }
    // headline cell claims
    let rows = hw::table2();
    let exact = &rows[0];
    let prop_e = &rows[1];
    let d5 = &rows[3];
    let prop_a = &rows[4];
    println!("\ncell-level energy savings:");
    println!("  proposed exact vs [6]:    {:5.1}%  (paper:  6.4%)",
             (1.0 - prop_e.ppc.pdp_fj / exact.ppc.pdp_fj) * 100.0);
    println!("  proposed approx vs [5]:   {:5.1}%  (paper: 46.8%)",
             (1.0 - prop_a.ppc.pdp_fj / d5.ppc.pdp_fj) * 100.0);
    println!();
}

// paper Table III, signed PADP (x1e3 µm²·fJ) for the key rows
const PAPER_T3_SIGNED_PADP: [(&str, u32, f64); 10] = [
    ("Design [6] exact", 4, 21.82),
    ("Design [6] exact", 8, 1162.39),
    ("Proposed exact", 4, 17.06),
    ("Proposed exact", 8, 879.02),
    ("HA-FSA [10]", 8, 1662.1),
    ("Gemmini [13]", 8, 1763.7),
    ("Design [6] approx", 8, 1171.47),
    ("Design [12] approx", 8, 966.75),
    ("Design [5] approx", 8, 431.93),
    ("Proposed approx", 8, 334.66),
];

fn table3() {
    println!("=== Table III: PE metrics (signed; ours + paper PADP) ===");
    println!("{:<22} {:>2}  {:>8} {:>7} {:>6} {:>9}  {:>9}",
             "design", "N", "area", "power", "delay", "PADP", "paperPADP");
    for row in hw::table3() {
        let paper = PAPER_T3_SIGNED_PADP.iter()
            .find(|(l, n, _)| row.label.starts_with(l) && *n == row.n)
            .map(|(_, _, v)| format!("{v:9.1}"))
            .unwrap_or_else(|| "        -".into());
        if let Some(m) = row.signed {
            println!("{:<22} {:>2}  {:>8.1} {:>7.1} {:>6.2} {:>9.1}  {}",
                     row.label, row.n, m.area_um2, m.power_uw, m.delay_ns,
                     m.padp, paper);
        }
    }
    println!();
}

// paper Table IV: 8-bit signed PDP (pJ) per size, rows = exact [6] /
// prop exact / approx [5] / prop approx
const PAPER_T4_8B: [(usize, [f64; 4]); 4] = [
    (3, [21.44, 19.53, 11.50, 9.36]),
    (4, [40.58, 37.62, 23.46, 19.35]),
    (8, [179.78, 150.15, 71.40, 56.18]),
    (16, [1037.71, 891.30, 510.00, 386.50]),
];

fn table4() {
    println!("=== Table IV: SA @250MHz, 8-bit signed (PDP pJ, ours|paper) ===");
    let designs: [(&str, Design); 4] = [
        ("Exact [6]", Design { n: 8, signed: Signedness::Signed,
                               family: Family::Proposed, k: 0,
                               optimized_exact: false }),
        ("Proposed Exact", Design::proposed_exact(8, Signedness::Signed)),
        ("Approx. [5]", Design::approximate_default(
            8, Signedness::Signed, Family::Axsa5)),
        ("Proposed Approx.", Design::approximate_default(
            8, Signedness::Signed, Family::Proposed)),
    ];
    print!("{:<18}", "design");
    for (size, _) in PAPER_T4_8B {
        print!(" {:>17}", format!("{size}x{size}"));
    }
    println!();
    for (di, (label, d)) in designs.iter().enumerate() {
        print!("{label:<18}");
        for (size, paper) in PAPER_T4_8B.iter() {
            let m = hw::sa_metrics(d, *size);
            print!(" {:>8.2}|{:<8.2}", m.pdp_fj / 1e3, paper[di]);
        }
        println!();
    }
    println!();
}

// paper Table V (signed): proposed k=2..8 + baselines at k=6
const PAPER_T5_SIGNED: [(&str, u32, f64, f64); 8] = [
    ("Proposed", 2, 0.0001, 0.0037),
    ("Proposed", 4, 0.0004, 0.0130),
    ("Proposed", 5, 0.0006, 0.0286),
    ("Proposed", 6, 0.0022, 0.0481),
    ("Proposed", 8, 0.0081, 0.2418),
    ("Design [5]", 6, 0.0033, 0.0626),
    ("Design [6]", 6, 0.0079, 0.1064),
    ("Design [12]", 6, 0.0046, 0.0758),
];

fn table5() {
    println!("=== Table V: 8-bit PE error metrics (ours + paper signed cols) ===");
    println!("{:<12} {:>2} | {:>7} {:>7} | {:>7} {:>7} | {:>7} {:>7}",
             "design", "k", "NMED(u)", "MRED(u)", "NMED(s)", "MRED(s)",
             "pNMEDs", "pMREDs");
    let families = [("Proposed", Family::Proposed),
                    ("Design [5]", Family::Axsa5),
                    ("Design [6]", Family::Nano6),
                    ("Design [12]", Family::Sips12)];
    for (label, fam) in families {
        let ks: &[u32] = if fam == Family::Proposed { &[2, 4, 5, 6, 8] } else { &[6] };
        for &k in ks {
            let (u, s) = table5_row(fam, k, 8);
            let paper = PAPER_T5_SIGNED.iter()
                .find(|(l, pk, _, _)| *l == label && *pk == k);
            let (pn, pm) = paper
                .map(|(_, _, n, m)| (format!("{n:7.4}"), format!("{m:7.4}")))
                .unwrap_or(("      -".into(), "      -".into()));
            println!("{:<12} {:>2} | {:>7.4} {:>7.4} | {:>7.4} {:>7.4} | {} {}",
                     label, k, u.nmed, u.mred, s.nmed, s.mred, pn, pm);
        }
    }
    println!();
}

// paper Table VI (proposed rows): k -> (DCT psnr/ssim, edge, bdcn)
const PAPER_T6: [(u32, [f64; 6]); 4] = [
    (2, [45.97, 0.991, 30.45, 0.910, 75.98, 1.0]),
    (4, [38.21, 0.955, 20.51, 0.894, 68.55, 1.0]),
    (6, [35.67, 0.923, 12.76, 0.678, 51.52, 0.999]),
    (8, [28.43, 0.872, 11.41, 0.651, 34.60, 0.995]),
];

fn table6() {
    println!("=== Table VI: application quality, proposed PE (ours|paper) ===");
    let img = scene(256, 256);
    let img128 = scene(128, 128);
    let mk = |k: u32| WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, k) };
    let (dct_exact, _) = dct::pipeline(&mut mk(0), &img);
    let edge_exact = edge::pipeline(&mut mk(0), &img);
    let weights = Runtime::default_artifacts_dir().join("bdcn_weights.txt");
    let blocks = bdcn::load_weights(&weights).ok();
    let bdcn_exact = blocks.as_ref().map(|b| bdcn::forward_word(b, &img128, 0));

    println!("{:<3} {:>24} {:>24} {:>24}", "k",
             "DCT psnr ssim | paper", "EDGE psnr ssim | paper",
             "BDCN psnr ssim | paper");
    for (k, p) in PAPER_T6 {
        let (r, _) = dct::pipeline(&mut mk(k), &img);
        let e = edge::pipeline(&mut mk(k), &img);
        let dctm = (psnr(&dct_exact.data, &r.data), ssim(&dct_exact.data, &r.data));
        let edgem = (psnr(&edge_exact.data, &e.data),
                     ssim(&edge_exact.data, &e.data));
        let bdcnm = match (&blocks, &bdcn_exact) {
            (Some(b), Some(ex)) => {
                let out = bdcn::forward_word(b, &img128, k);
                (psnr(&ex.data, &out.data), ssim(&ex.data, &out.data))
            }
            _ => (f64::NAN, f64::NAN),
        };
        println!("{:<3} {:>6.2} {:>5.3}|{:>5.1} {:>4.2}  {:>6.2} {:>5.3}|{:>5.1} \
                  {:>4.2}  {:>6.2} {:>5.3}|{:>5.1} {:>4.2}",
                 k, dctm.0, dctm.1, p[0], p[1], edgem.0, edgem.1, p[2], p[3],
                 bdcnm.0, bdcnm.1, p[4], p[5]);
    }
    println!();
}

fn headline() {
    println!("=== Headline claims (ours | paper) ===");
    let conv8 = Design { n: 8, signed: Signedness::Signed,
                         family: Family::Proposed, k: 0, optimized_exact: false };
    let prop8 = Design::proposed_exact(8, Signedness::Signed);
    let apx8 = Design::approximate_default(8, Signedness::Signed, Family::Proposed);
    let d5 = Design::approximate_default(8, Signedness::Signed, Family::Axsa5);

    let sa = |d: &Design| hw::sa_metrics(d, 8);
    let e0 = sa(&conv8).pdp_fj;
    println!("8x8 SA energy saving, proposed exact  vs [6]: {:5.1}% | paper 16%",
             (1.0 - sa(&prop8).pdp_fj / e0) * 100.0);
    println!("8x8 SA energy saving, proposed approx vs [6]: {:5.1}% | paper 68%",
             (1.0 - sa(&apx8).pdp_fj / e0) * 100.0);
    let pe = |d: &Design| hw::pe_metrics(d).pdp_fj;
    println!("8-bit signed PE saving, prop exact vs [6]:    {:5.1}% | paper 24.37%",
             (1.0 - pe(&prop8) / pe(&conv8)) * 100.0);
    println!("8-bit signed PE saving, prop approx vs [5]:   {:5.1}% | paper 22.51%",
             (1.0 - pe(&apx8) / pe(&d5)) * 100.0);
    let s16 = |d: &Design| hw::sa_metrics(d, 16).pdp_fj;
    println!("16x16 SA PDP, prop approx vs exact [6]:       {:5.1}% | paper 62.7%",
             (1.0 - s16(&apx8) / s16(&conv8)) * 100.0);
    println!("16x16 SA PDP, prop approx vs approx [5]:      {:5.1}% | paper 24.2%",
             (1.0 - s16(&apx8) / s16(&d5)) * 100.0);
    let gem = hw::conventional_mac_metrics(8, false);
    println!("PE PADP saving vs Gemmini-style MAC [13]:     {:5.1}% | paper 65.45%",
             (1.0 - hw::pe_metrics(&prop8).padp / gem.padp) * 100.0);
}
