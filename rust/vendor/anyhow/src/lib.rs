//! Minimal offline-vendored subset of the `anyhow` error-handling API.
//!
//! The build environment for this repository cannot reach crates.io, so the
//! crate vendors the (small) slice of anyhow it actually uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`ensure!`] / [`bail!`] macros and the
//! [`Context`] extension trait. Semantics match upstream for this subset:
//! `Error` boxes any `std::error::Error + Send + Sync` (and deliberately
//! does *not* implement `std::error::Error` itself, which is what makes the
//! blanket `From` conversion coherent), `context` wraps an error with a
//! leading message, and the macros build errors from format strings.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Repr {
    Msg(String),
    Boxed(Box<dyn std::error::Error + Send + Sync + 'static>),
    Context(String, Box<Error>),
}

/// A type-erased error with an optional chain of context messages.
pub struct Error(Repr);

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(Repr::Msg(message.to_string()))
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error(Repr::Context(context.to_string(), Box::new(self)))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.0 {
            Repr::Msg(m) => f.write_str(m),
            Repr::Boxed(e) => write!(f, "{e}"),
            Repr::Context(c, inner) => write!(f, "{c}: {inner}"),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // upstream prints the chain on Debug too; one line is enough here
        write!(f, "{self}")
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(Repr::Boxed(Box::new(e)))
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_and_context_chain() {
        let e = io_fail().unwrap_err();
        let text = format!("{e}");
        assert!(text.starts_with("reading config: "), "{text}");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x == 7 {
                bail!("lucky numbers rejected");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-1).unwrap_err()),
                   "x must be positive, got -1");
        assert_eq!(format!("{}", f(7).unwrap_err()), "lucky numbers rejected");
        let e = anyhow!("plain {}", 42);
        assert_eq!(format!("{e}"), "plain 42");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing value");
    }
}
