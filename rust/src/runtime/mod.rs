//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client.
//!
//! This is the only bridge between Layer 3 and the Layer 1/2 compute
//! graphs; Python never runs here. Interchange is HLO *text* (the
//! xla_extension 0.5.1 bundled with the `xla` crate rejects jax >= 0.5's
//! 64-bit-id protos; the text parser reassigns ids — see
//! /opt/xla-example/README.md and DESIGN.md §3).
//!
//! ## Feature gating
//!
//! The `xla` crate links the XLA C++ extension and cannot be built in the
//! offline environment, so the PJRT client is compiled only with
//! `--features pjrt` (which additionally requires adding `xla = "0.1"` to
//! Cargo.toml on a machine that has the toolchain). Without the feature,
//! [`Runtime::new`] returns an error and every artifact-dependent test and
//! benchmark skips; the artifact manifest/golden helpers below work either
//! way. The serving stack itself (Word / Systolic / Lut backends) has no
//! PJRT dependency.

use std::path::{Path, PathBuf};

use anyhow::Result;

/// Shape + data of one int32 tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    /// Dimension sizes, outermost first.
    pub dims: Vec<usize>,
    /// Row-major element data (`dims` product elements).
    pub data: Vec<i32>,
}

impl TensorI32 {
    /// A tensor from shape + row-major data (length-checked).
    pub fn new(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        TensorI32 { dims, data }
    }

    /// A rank-1, single-element tensor (the runtime `k` argument).
    pub fn scalar1(v: i32) -> Self {
        TensorI32 { dims: vec![1], data: vec![v] }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    use anyhow::{Context, Result};

    use super::TensorI32;

    /// A compiled artifact ready to execute.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Artifact name (the `<name>.hlo.txt` stem it was loaded from).
        pub name: String,
    }

    /// The PJRT CPU client plus a compiled-executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        artifacts_dir: std::path::PathBuf,
        cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at an artifacts directory.
        pub fn new(artifacts_dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Runtime {
                client,
                artifacts_dir: artifacts_dir.to_path_buf(),
                cache: Mutex::new(HashMap::new()),
            })
        }

        /// PJRT platform name (e.g. `"cpu"`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile `<artifacts>/<name>.hlo.txt` (cached).
        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            if let Some(e) = self.cache.lock().unwrap().get(name) {
                return Ok(e.clone());
            }
            let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?)
                .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            let entry = std::sync::Arc::new(Executable { exe, name: name.into() });
            self.cache.lock().unwrap().insert(name.into(), entry.clone());
            Ok(entry)
        }

        /// Execute with int32 inputs; returns the int32 outputs of the
        /// result tuple (aot.py lowers with `return_tuple=True`).
        pub fn execute_i32(&self, exe: &Executable, inputs: &[TensorI32])
                           -> Result<Vec<TensorI32>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for t in inputs {
                let lit = xla::Literal::vec1(&t.data);
                let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                let lit = lit.reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
                literals.push(lit);
            }
            let result = exe.exe.execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", exe.name))?;
            let tuple = result[0][0].to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
            let parts = tuple.to_tuple()
                .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
            let mut out = Vec::with_capacity(parts.len());
            for lit in parts {
                let shape = lit.array_shape()
                    .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> =
                    shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
                out.push(TensorI32::new(dims, data));
            }
            Ok(out)
        }

        /// Load-and-run convenience.
        pub fn run(&self, name: &str, inputs: &[TensorI32])
                   -> Result<Vec<TensorI32>> {
            let exe = self.load(name)?;
            self.execute_i32(&exe, inputs)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_impl {
    use std::path::Path;

    use anyhow::Result;

    use super::TensorI32;

    /// Stub standing in for a compiled artifact; never constructed.
    pub struct Executable {
        /// Artifact name (kept for error messages).
        pub name: String,
    }

    /// Stub PJRT client: [`Runtime::new`] always errors, so the methods
    /// below are unreachable but keep every caller compiling unchanged.
    pub struct Runtime {}

    impl Runtime {
        /// Always errors: the `pjrt` feature is disabled in this build.
        pub fn new(_artifacts_dir: &Path) -> Result<Self> {
            Err(anyhow::anyhow!(
                "axsys was built without the `pjrt` feature; rebuild with \
                 `--features pjrt` (and the xla crate) to run AOT artifacts"))
        }

        /// Placeholder platform name for the disabled stub.
        pub fn platform(&self) -> String {
            "pjrt-disabled".into()
        }

        /// Always errors (stub).
        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            Err(anyhow::anyhow!("pjrt feature disabled: cannot load {name}"))
        }

        /// Always errors (stub).
        pub fn execute_i32(&self, exe: &Executable, _inputs: &[TensorI32])
                           -> Result<Vec<TensorI32>> {
            Err(anyhow::anyhow!("pjrt feature disabled: cannot run {}", exe.name))
        }

        /// Always errors (stub).
        pub fn run(&self, name: &str, _inputs: &[TensorI32])
                   -> Result<Vec<TensorI32>> {
            Err(anyhow::anyhow!("pjrt feature disabled: cannot run {name}"))
        }
    }
}

pub use pjrt_impl::{Executable, Runtime};

impl Runtime {
    /// Default artifacts location (repo-relative, overridable via env).
    pub fn default_artifacts_dir() -> PathBuf {
        if let Ok(p) = std::env::var("AXSYS_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }
}

/// Read a golden `.bin` (raw little-endian i32) written by aot.py.
pub fn read_golden_bin(path: &Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "ragged golden file {path:?}");
    Ok(bytes.chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// One golden case from `artifacts/golden/manifest.txt`.
#[derive(Clone, Debug)]
pub struct GoldenCase {
    /// Case name (prefix of the `.bin` golden files).
    pub case: String,
    /// Artifact stem the case executes.
    pub artifact: String,
    /// Shapes of the input tensors, in argument order.
    pub in_shapes: Vec<Vec<usize>>,
    /// Approximation level passed as the trailing scalar argument.
    pub k: i32,
    /// Shapes of the expected output tensors.
    pub out_shapes: Vec<Vec<usize>>,
}

/// Parse the golden manifest.
pub fn read_manifest(dir: &Path) -> Result<Vec<GoldenCase>> {
    let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
    let mut cases = Vec::new();
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        anyhow::ensure!(f.len() == 7, "bad manifest line: {line}");
        let parse_shapes = |s: &str| -> Vec<Vec<usize>> {
            s.split(';')
                .map(|g| g.split('x').map(|d| d.parse().unwrap()).collect())
                .collect()
        };
        cases.push(GoldenCase {
            case: f[0].into(),
            artifact: f[1].trim_end_matches(".hlo.txt").into(),
            in_shapes: parse_shapes(f[3]),
            k: f[4].parse()?,
            out_shapes: parse_shapes(f[6]),
        });
    }
    Ok(cases)
}
