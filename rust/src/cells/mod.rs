//! Bit-level cell models: the paper's PPC / NPPC cells (Fig. 3-4, 7 and
//! Table I) plus reconstructed baseline cells.
//!
//! A *partial product cell* (PPC) fuses one AND-gate partial product with a
//! full-adder stage: it computes `a·b + Cin + Sin` as a (Carry, Sum) pair.
//! The *NAND-based* NPPC computes `~(a·b) + Cin + Sin` — the complemented
//! partial products of Baugh-Wooley signed multiplication.
//!
//! Table I of the paper is **normative** for the proposed approximate
//! cells: the Boolean expressions printed in its §III-B contradict the
//! table and its own error-case list, while the forms implemented here
//! reproduce the table row-for-row (see `tests`).

/// One-bit cell output: (carry_out, sum_out).
pub type CS = (u8, u8);

/// Every cell variant with a gate-level identity in this repo.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum CellKind {
    /// Conventional exact PPC \[6\]: AND + textbook full adder.
    ExactPpc,
    /// Conventional exact NPPC \[6\]: NAND + textbook full adder.
    ExactNppc,
    /// Proposed exact PPC: AND + mirror-adder (complex-gate MAJ carry).
    PropExactPpc,
    /// Proposed exact NPPC: NAND + mirror-adder.
    PropExactNppc,
    /// Proposed approximate PPC (Table I): `C = p`, `S = (Sin|Cin)&~p`.
    PropApxPpc,
    /// Proposed approximate NPPC (Table I): `C = (Sin|Cin)&~p`,
    /// `S = ~(Sin|Cin)|p`.
    PropApxNppc,
    /// Waris SiPS'19 \[12\] inexact cell: `S = ~(p ^ Sin)`, `C = Cin`.
    Sips12Ppc,
    /// NAND-product flavor of the SiPS'19 cell.
    Sips12Nppc,
    /// Chen NANOARCH'15 \[6\] inexact cell: `S = ~Sin`, `C = p & Cin`.
    Nano6Ppc,
    /// NAND-product flavor of the NANOARCH'15 cell.
    Nano6Nppc,
    /// Waris AxSA'21 \[5\] carry-elided compressor: exact 3-input XOR sum,
    /// carry output removed (`C = 0`).
    Axsa5Ppc,
    /// NAND-product flavor of the AxSA cell (sign row/column positions).
    Axsa5Nppc,
    /// Truncated PPC (zoo variant): the AND gate is dropped entirely and
    /// the cell degenerates to a half adder on `(Cin, Sin)`.
    TruncPpc,
    /// Truncated NPPC: the NAND output is tied high (Baugh-Wooley
    /// complement of the dropped product), i.e. a full adder with `x = 1`.
    TruncNppc,
    /// Lower-part-OR PPC (zoo variant, Mahdiani et al. LOA): the product
    /// is OR-folded into the sum rail, `S = p | Sin`, `C = Cin`.
    LoaPpc,
    /// NAND-product flavor of the LOA cell: `S = ~(a·b) | Sin`, `C = Cin`.
    LoaNppc,
}

impl CellKind {
    /// Every cell variant, in Table II presentation order.
    pub const ALL: [CellKind; 16] = [
        CellKind::ExactPpc,
        CellKind::ExactNppc,
        CellKind::PropExactPpc,
        CellKind::PropExactNppc,
        CellKind::PropApxPpc,
        CellKind::PropApxNppc,
        CellKind::Sips12Ppc,
        CellKind::Sips12Nppc,
        CellKind::Nano6Ppc,
        CellKind::Nano6Nppc,
        CellKind::Axsa5Ppc,
        CellKind::Axsa5Nppc,
        CellKind::TruncPpc,
        CellKind::TruncNppc,
        CellKind::LoaPpc,
        CellKind::LoaNppc,
    ];

    /// Stable lower-case name (Verilog module names, CLI output).
    pub fn name(self) -> &'static str {
        match self {
            CellKind::ExactPpc => "exact_ppc",
            CellKind::ExactNppc => "exact_nppc",
            CellKind::PropExactPpc => "prop_exact_ppc",
            CellKind::PropExactNppc => "prop_exact_nppc",
            CellKind::PropApxPpc => "prop_apx_ppc",
            CellKind::PropApxNppc => "prop_apx_nppc",
            CellKind::Sips12Ppc => "sips12_ppc",
            CellKind::Sips12Nppc => "sips12_nppc",
            CellKind::Nano6Ppc => "nano6_ppc",
            CellKind::Nano6Nppc => "nano6_nppc",
            CellKind::Axsa5Ppc => "axsa5_ppc",
            CellKind::Axsa5Nppc => "axsa5_nppc",
            CellKind::TruncPpc => "trunc_ppc",
            CellKind::TruncNppc => "trunc_nppc",
            CellKind::LoaPpc => "loa_ppc",
            CellKind::LoaNppc => "loa_nppc",
        }
    }

    /// Is the partial product complemented (NAND-based) in this cell?
    pub fn is_nppc(self) -> bool {
        matches!(self, CellKind::ExactNppc | CellKind::PropExactNppc
                     | CellKind::PropApxNppc | CellKind::Axsa5Nppc
                     | CellKind::Sips12Nppc | CellKind::Nano6Nppc
                     | CellKind::TruncNppc | CellKind::LoaNppc)
    }
}

/// Evaluate a cell on single-bit inputs. `a`, `b` are the operand bits,
/// `cin`/`sin` the incoming carry/sum. Returns `(carry, sum)`.
pub fn eval(kind: CellKind, a: u8, b: u8, cin: u8, sin: u8) -> CS {
    debug_assert!(a <= 1 && b <= 1 && cin <= 1 && sin <= 1);
    let p = a & b;
    let x = p ^ 1; // complemented product for NPPC-style cells
    match kind {
        CellKind::ExactPpc | CellKind::PropExactPpc => fa(p, cin, sin),
        CellKind::ExactNppc | CellKind::PropExactNppc => fa(x, cin, sin),
        CellKind::PropApxPpc => {
            let o = sin | cin;
            (p, o & (p ^ 1))
        }
        CellKind::PropApxNppc => {
            let o = sin | cin;
            ((o & (p ^ 1)), (o ^ 1) | p)
        }
        CellKind::Sips12Ppc => (cin, (p ^ sin) ^ 1),
        CellKind::Sips12Nppc => (cin, (x ^ sin) ^ 1),
        CellKind::Nano6Ppc => (p & cin, sin ^ 1),
        CellKind::Nano6Nppc => (x & cin, sin ^ 1),
        CellKind::Axsa5Ppc => (0, p ^ cin ^ sin),
        CellKind::Axsa5Nppc => (0, x ^ cin ^ sin),
        CellKind::TruncPpc => (cin & sin, cin ^ sin),
        CellKind::TruncNppc => (cin | sin, (cin ^ sin) ^ 1),
        CellKind::LoaPpc => (cin, p | sin),
        CellKind::LoaNppc => (cin, x | sin),
    }
}

/// Textbook full adder.
#[inline]
pub fn fa(x: u8, cin: u8, sin: u8) -> CS {
    let s = x ^ cin ^ sin;
    let c = (x & cin) | (x & sin) | (cin & sin);
    (c, s)
}

/// Exact arithmetic value a cell is approximating: `p + cin + sin` where
/// `p` is the (possibly complemented) partial product.
pub fn exact_value(kind: CellKind, a: u8, b: u8, cin: u8, sin: u8) -> u8 {
    let p = if kind.is_nppc() { (a & b) ^ 1 } else { a & b };
    p + cin + sin
}

/// Error distance of one cell evaluation: `(2*C + S) - exact`.
pub fn error_distance(kind: CellKind, a: u8, b: u8, cin: u8, sin: u8) -> i8 {
    let (c, s) = eval(kind, a, b, cin, sin);
    (2 * c + s) as i8 - exact_value(kind, a, b, cin, sin) as i8
}

/// Error rate over the 16 input combinations (paper: 5/16 for the
/// proposed approximate PPC and NPPC).
pub fn error_rate(kind: CellKind) -> (u32, u32) {
    let mut bad = 0;
    for v in 0..16u8 {
        let (a, b, cin, sin) = ((v >> 3) & 1, (v >> 2) & 1, (v >> 1) & 1, v & 1);
        if error_distance(kind, a, b, cin, sin) != 0 {
            bad += 1;
        }
    }
    (bad, 16)
}

/// Total error probability weighting each input row by its likelihood
/// under uniform operand bits: P(p=1) = 1/4 for PPC (3/4 for NPPC),
/// carry/sum uniform. Paper §III-B: 25/256 for the proposed cells.
pub fn error_probability_num(kind: CellKind) -> u32 {
    // numerator over denominator 256: each (a,b) combo has weight 16/256,
    // each (cin,sin) weight 1/4 of that -> every row weighs 4/256... the
    // paper instead weights by P(a)·P(b)·P(cin)·P(sin) with all uniform:
    // row weight = 16/256 * ... We reproduce the paper's accounting:
    // rows with (a,b) fixed have P = 1/4 (a,b uniform) * 1/4 (cin,sin) and
    // the squared-probability convention of [16] for ED contributions.
    let mut num = 0u32;
    for v in 0..16u8 {
        let (a, b, cin, sin) = ((v >> 3) & 1, (v >> 2) & 1, (v >> 1) & 1, v & 1);
        if error_distance(kind, a, b, cin, sin) != 0 {
            // P(a,b) * P(cin) * P(sin) with 1/16 granularity -> 16/256 each
            num += 16;
        }
    }
    num
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table I, approximate PPC columns (C, S) in row order
    /// (a, b, Cin, Sin) = 0000..1111.
    const TABLE1_APX_PPC: [(u8, u8); 16] = [
        (0, 0), (0, 1), (0, 1), (0, 1),
        (0, 0), (0, 1), (0, 1), (0, 1),
        (0, 0), (0, 1), (0, 1), (0, 1),
        (1, 0), (1, 0), (1, 0), (1, 0),
    ];
    const TABLE1_APX_NPPC: [(u8, u8); 16] = [
        (0, 1), (1, 0), (1, 0), (1, 0),
        (0, 1), (1, 0), (1, 0), (1, 0),
        (0, 1), (1, 0), (1, 0), (1, 0),
        (0, 1), (0, 1), (0, 1), (0, 1),
    ];

    fn row(v: u8) -> (u8, u8, u8, u8) {
        ((v >> 3) & 1, (v >> 2) & 1, (v >> 1) & 1, v & 1)
    }

    #[test]
    fn table1_proposed_apx_ppc() {
        for v in 0..16u8 {
            let (a, b, cin, sin) = row(v);
            assert_eq!(eval(CellKind::PropApxPpc, a, b, cin, sin),
                       TABLE1_APX_PPC[v as usize], "row {v:04b}");
        }
    }

    #[test]
    fn table1_proposed_apx_nppc() {
        for v in 0..16u8 {
            let (a, b, cin, sin) = row(v);
            assert_eq!(eval(CellKind::PropApxNppc, a, b, cin, sin),
                       TABLE1_APX_NPPC[v as usize], "row {v:04b}");
        }
    }

    #[test]
    fn exact_cells_are_exact() {
        for kind in [CellKind::ExactPpc, CellKind::ExactNppc,
                     CellKind::PropExactPpc, CellKind::PropExactNppc] {
            for v in 0..16u8 {
                let (a, b, cin, sin) = row(v);
                assert_eq!(error_distance(kind, a, b, cin, sin), 0,
                           "{kind:?} row {v:04b}");
            }
        }
    }

    #[test]
    fn proposed_apx_error_cases_match_paper() {
        // §III-B: errors exactly at (a,b,Sin,Cin) in {0011,0111,1011,1100,
        // 1111} — note the paper lists (a,b,S,C); our row order is
        // (a,b,Cin,Sin), for which both orderings coincide on these rows.
        let expected: [(u8, i8); 5] = [
            (0b0011, -1), (0b0111, -1), (0b1011, -1), (0b1100, 1), (0b1111, -1),
        ];
        let mut found = vec![];
        for v in 0..16u8 {
            let (a, b, cin, sin) = row(v);
            let ed = error_distance(CellKind::PropApxPpc, a, b, cin, sin);
            if ed != 0 {
                found.push((v, ed));
            }
        }
        assert_eq!(found, expected);
    }

    #[test]
    fn proposed_error_rate_is_5_of_16() {
        assert_eq!(error_rate(CellKind::PropApxPpc), (5, 16));
        assert_eq!(error_rate(CellKind::PropApxNppc), (5, 16));
    }

    #[test]
    fn nppc_errors_mirror_ppc() {
        // the NPPC table is the PPC table under p -> ~p
        for v in 0..16u8 {
            let (a, b, cin, sin) = row(v);
            let ed_n = error_distance(CellKind::PropApxNppc, a, b, cin, sin);
            assert!(ed_n.abs() <= 1, "row {v:04b}");
        }
    }

    #[test]
    fn baseline_cells_have_bounded_ed() {
        for kind in [CellKind::Sips12Ppc, CellKind::Nano6Ppc] {
            for v in 0..16u8 {
                let (a, b, cin, sin) = row(v);
                assert!(error_distance(kind, a, b, cin, sin).abs() <= 3);
            }
        }
    }
}
