//! Minimal measurement harness (criterion is unavailable offline).
//!
//! `run` executes a closure repeatedly with warmup, reports median /
//! mean / min over per-iteration wall time, and guards against dead-code
//! elimination through `black_box`. The [`report`] submodule runs the
//! fixed `bench-report` suite and emits `BENCH_hotpath.json` through the
//! dependency-free [`Json`] document model.

pub mod report;

use std::time::Instant;

pub use std::hint::black_box;

/// Summary of one timed closure: per-iteration wall times.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Sampled iterations (after the warmup/calibration call).
    pub iters: u32,
    /// Median per-iteration time, ns.
    pub median_ns: f64,
    /// Mean per-iteration time, ns.
    pub mean_ns: f64,
    /// Fastest observed iteration, ns.
    pub min_ns: f64,
}

impl Measurement {
    /// Items-per-second implied by the median time for `items` of work
    /// per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.median_ns * 1e-9)
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "median {} mean {} min {} ({} iters)",
               fmt_ns(self.median_ns), fmt_ns(self.mean_ns),
               fmt_ns(self.min_ns), self.iters)
    }
}

/// Human-readable duration (ns → µs → ms → s as magnitude grows).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Median-time speedup of `fast` relative to `base` (>1 means faster).
pub fn speedup(base: &Measurement, fast: &Measurement) -> f64 {
    base.median_ns / fast.median_ns
}

/// Deterministic xorshift64 stream — the one PRNG every measurement
/// harness shares (directly, or through [`xorshift_ints`]).
pub struct XorShift(u64);

impl XorShift {
    /// Stream seeded by `seed` (zero maps to a nonzero state).
    pub fn new(seed: u64) -> Self {
        XorShift(seed | 1)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
}

/// Deterministic operand stream over the signed 8-bit range
/// `[-128, 127]` — the shared generator for benches, the
/// `bench-report` suite and unit tests, so every harness draws from the
/// same distribution.
pub fn xorshift_ints(seed: u64, len: usize) -> Vec<i64> {
    let mut x = XorShift::new(seed);
    (0..len).map(|_| (x.next_u64() as i64 & 255) - 128).collect()
}

/// Measure `f` with automatic iteration count targeting ~`budget_ms` of
/// total sampling after a short warmup.
pub fn run<F: FnMut()>(label: &str, budget_ms: u64, mut f: F) -> Measurement {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let target = (budget_ms as f64) * 1e6;
    let iters = ((target / once).clamp(3.0, 10_000.0)) as u32;
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(f64::total_cmp);
    let m = Measurement {
        iters,
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        min_ns: samples[0],
    };
    println!("bench {label:<44} {m}");
    m
}

/// Minimal JSON document model for the `bench-report` emitter (serde is
/// unavailable offline). Keys keep insertion order; non-finite floats
/// serialize as `null` so the output is always valid JSON.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// Boolean literal.
    Bool(bool),
    /// Integer, emitted without a decimal point.
    Int(i64),
    /// Double-precision number (`null` when not finite).
    Num(f64),
    /// String, escaped on serialization.
    Str(String),
    /// Ordered array.
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An empty object, ready for [`Json::set`] chaining.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder-style field append (replaces an existing key in place).
    /// Panics when `self` is not an object.
    pub fn set(mut self, key: &str, v: Json) -> Json {
        match &mut self {
            Json::Obj(fields) => {
                if let Some(f) = fields.iter_mut().find(|(k, _)| k == key) {
                    f.1 = v;
                } else {
                    fields.push((key.to_string(), v));
                }
            }
            _ => panic!("Json::set on a non-object"),
        }
        self
    }

    /// Field lookup on objects (`None` otherwise).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Serialize with 2-space indentation and a trailing newline.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        write_json(&mut out, self, 0);
        out.push('\n');
        out
    }
}

fn write_json(out: &mut String, v: &Json, indent: usize) {
    let pad = |out: &mut String, n: usize| {
        for _ in 0..n {
            out.push_str("  ");
        }
    };
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Int(i) => out.push_str(&i.to_string()),
        Json::Num(f) => {
            if f.is_finite() {
                out.push_str(&format!("{f}"));
            } else {
                out.push_str("null");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32));
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                pad(out, indent + 1);
                write_json(out, item, indent + 1);
                if i + 1 < items.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(out, indent);
            out.push(']');
        }
        Json::Obj(fields) => {
            if fields.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, val)) in fields.iter().enumerate() {
                pad(out, indent + 1);
                write_json(out, &Json::Str(k.clone()), 0);
                out.push_str(": ");
                write_json(out, val, indent + 1);
                if i + 1 < fields.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(out, indent);
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut x = 0u64;
        let m = run("noop-ish", 5, || {
            x = black_box(x.wrapping_add(1));
        });
        assert!(m.median_ns >= 0.0);
        assert!(m.iters >= 3);
    }

    #[test]
    fn json_serializes_and_escapes() {
        let j = Json::obj()
            .set("schema", Json::Str("axsys-bench-report/v1".into()))
            .set("n", Json::Int(-3))
            .set("x", Json::Num(1.5))
            .set("bad", Json::Num(f64::NAN))
            .set("esc", Json::Str("a\"b\\c\nd".into()))
            .set("arr", Json::Arr(vec![Json::Bool(true), Json::Null]));
        let s = j.pretty();
        assert!(s.contains("\"schema\": \"axsys-bench-report/v1\""), "{s}");
        assert!(s.contains("\"n\": -3"));
        assert!(s.contains("\"x\": 1.5"));
        assert!(s.contains("\"bad\": null"), "NaN must become null: {s}");
        assert!(s.contains("a\\\"b\\\\c\\nd"));
        assert!(s.ends_with("}\n"));
        assert_eq!(j.get("n"), Some(&Json::Int(-3)));
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn json_set_replaces_in_place() {
        let j = Json::obj().set("a", Json::Int(1)).set("a", Json::Int(2));
        assert_eq!(j.get("a"), Some(&Json::Int(2)));
        if let Json::Obj(fields) = &j {
            assert_eq!(fields.len(), 1);
        }
    }
}
