//! Minimal measurement harness (criterion is unavailable offline).
//!
//! `run` executes a closure repeatedly with warmup, reports median /
//! mean / min over per-iteration wall time, and guards against dead-code
//! elimination through `black_box`.

use std::time::Instant;

pub use std::hint::black_box;

#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub iters: u32,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.median_ns * 1e-9)
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "median {} mean {} min {} ({} iters)",
               fmt_ns(self.median_ns), fmt_ns(self.mean_ns),
               fmt_ns(self.min_ns), self.iters)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Median-time speedup of `fast` relative to `base` (>1 means faster).
pub fn speedup(base: &Measurement, fast: &Measurement) -> f64 {
    base.median_ns / fast.median_ns
}

/// Measure `f` with automatic iteration count targeting ~`budget_ms` of
/// total sampling after a short warmup.
pub fn run<F: FnMut()>(label: &str, budget_ms: u64, mut f: F) -> Measurement {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let target = (budget_ms as f64) * 1e6;
    let iters = ((target / once).clamp(3.0, 10_000.0)) as u32;
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let m = Measurement {
        iters,
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        min_ns: samples[0],
    };
    println!("bench {label:<44} {m}");
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut x = 0u64;
        let m = run("noop-ish", 5, || {
            x = black_box(x.wrapping_add(1));
        });
        assert!(m.median_ns >= 0.0);
        assert!(m.iters >= 3);
    }
}
