//! The `bench-report` fixed suite: machine-readable perf trajectory.
//!
//! [`collect`] runs the same three measurements on every invocation and
//! returns one [`Json`] document, which the CLI writes to
//! `BENCH_hotpath.json` at the repository root so every PR leaves a
//! comparable perf artifact behind:
//!
//! 1. **kernels** — `lut` naive walk vs the cache-blocked driver (lut and
//!    word engines, the word engine both with its 64-lane kernel and the
//!    scalar walk) vs the naive word walk on one `size³` GEMM at `k = 4`,
//!    each as MACs/second (results cross-checked bit-identical before any
//!    timing — a perf number for a wrong kernel is worthless);
//! 2. **roofline** — achieved blocked-kernel MACs/sec against a
//!    bandwidth-bound peak derived from a *measured* sequential memory
//!    sweep (the LUT microkernel reads 8 bytes of table per MAC);
//! 3. **metered_kernels** — the same GEMM with the energy meter
//!    attached: metered-vs-unmetered A/B for both engines (word and lut,
//!    each scalar and lane), the fused path's headline. Every metered
//!    lane run is asserted bit-identical to the unmetered result and its
//!    accumulated fJ cross-checked against the scalar meter to 1e-9-rel
//!    before any timing;
//! 4. **roofline** (cont.), **serve**, **apps**, **energy** — as above:
//!    coordinator throughput on a deterministic mixed-size fleet,
//!    single-request app latency, and the data-dependent per-MAC model
//!    on a fixed synthetic stream (mean fJ/MAC per design plus the
//!    8×8-array savings vs the conventional MAC).
//!
//! The kernel/serve sections run at the process-wide pinned block sizes
//! (`--block-sizes` or the startup autotune; recorded under
//! `config.blocks`) and the pinned fan-out tile (`--sw-tile` or its
//! autotune; recorded under `config.sw_tile`).
//!
//! All sizes shrink with [`ReportConfig::size`] so CI can smoke-run the
//! identical suite in seconds (`axsys bench-report --size 32`).

use std::path::{Path, PathBuf};

use crate::apps::image::scene;
use crate::coordinator::{BackendKind, Coordinator, CoordinatorConfig,
                         GemmRequest};
use crate::energy;
use crate::gemm::BlockedGemm;
use crate::pe::lut::ProductLut;
use crate::pe::word::{matmul as word_matmul, PeConfig};
use crate::pe::{Design, Signedness};
use crate::Family;

use super::{black_box, run, speedup, xorshift_ints as ints, Json,
            Measurement};

/// Knobs of one `bench-report` run (all have CLI flags).
#[derive(Clone, Copy, Debug)]
pub struct ReportConfig {
    /// GEMM edge length: the kernel section times `size x size x size`.
    pub size: usize,
    /// Requests in the serve-throughput fleet.
    pub requests: usize,
    /// Coordinator workers for the serve/apps sections.
    pub workers: usize,
    /// Approximation level of the kernel section.
    pub k: u32,
}

impl Default for ReportConfig {
    fn default() -> Self {
        ReportConfig { size: 256, requests: 48, workers: 4, k: 4 }
    }
}

/// Default artifact location: `BENCH_hotpath.json` at the repository
/// root (one directory above the crate).
pub fn default_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .join("BENCH_hotpath.json")
}

fn meas_json(m: &Measurement, macs: f64) -> Json {
    Json::obj()
        .set("median_ns", Json::Num(m.median_ns))
        .set("min_ns", Json::Num(m.min_ns))
        .set("iters", Json::Int(m.iters as i64))
        .set("macs_per_sec", Json::Num(m.throughput(macs)))
}

/// Kernel timings plus the achieved MACs/sec of the two blocked engines
/// (lut, word) — the roofline section reuses those instead of re-timing.
fn kernel_section(rc: &ReportConfig) -> (Json, f64, f64) {
    let s = rc.size;
    let macs = (s * s * s) as f64;
    let budget = ((macs / 1e6) as u64).clamp(40, 1500);
    let cfg = PeConfig::new(8, true, Family::Proposed, rc.k);
    let a = ints(5, s * s);
    let b = ints(6, s * s);
    let lut = ProductLut::try_build(&cfg).expect("8-bit point compiles");
    let mut eng = BlockedGemm::new(crate::gemm::effective_blocks());
    let mut eng_scalar = BlockedGemm::new(crate::gemm::effective_blocks());
    eng_scalar.set_lane_kernel(false);
    // cross-check every timed path before timing it — including the
    // 64-lane word kernel against its scalar walk (the lane gate needs
    // size >= 32 columns to engage; at CI smoke sizes >= 48 this is a
    // real bit-equality gate on the lane kernel)
    let want = word_matmul(&cfg, &a, &b, s, s, s);
    assert_eq!(lut.matmul(&a, &b, s, s, s), want, "naive lut != word");
    assert_eq!(eng.matmul(&cfg, &a, &b, s, s, s), want, "blocked lut != word");
    assert_eq!(eng.matmul_word(&cfg, &a, &b, s, s, s), want,
               "blocked word (lanes) != word");
    assert_eq!(eng_scalar.matmul_word(&cfg, &a, &b, s, s, s), want,
               "blocked word (scalar) != word");

    let m_word = run("bench-report word naive", budget, || {
        black_box(word_matmul(black_box(&cfg), &a, &b, s, s, s));
    });
    let m_lut = run("bench-report lut naive", budget, || {
        black_box(lut.matmul(black_box(&a), &b, s, s, s));
    });
    let m_blocked = run("bench-report lut blocked", budget, || {
        black_box(eng.matmul(black_box(&cfg), &a, &b, s, s, s));
    });
    let m_blocked_w = run("bench-report word blocked", budget, || {
        black_box(eng.matmul_word(black_box(&cfg), &a, &b, s, s, s));
    });
    let m_scalar_w = run("bench-report word blocked scalar", budget, || {
        black_box(eng_scalar.matmul_word(black_box(&cfg), &a, &b, s, s, s));
    });
    let doc = Json::obj()
        .set("size", Json::Int(s as i64))
        .set("k", Json::Int(rc.k as i64))
        .set("word_naive", meas_json(&m_word, macs))
        .set("lut_naive", meas_json(&m_lut, macs))
        .set("lut_blocked", meas_json(&m_blocked, macs))
        .set("word_blocked", meas_json(&m_blocked_w, macs))
        .set("word_blocked_scalar", meas_json(&m_scalar_w, macs))
        .set("blocked_vs_naive_lut_speedup",
             Json::Num(speedup(&m_lut, &m_blocked)))
        .set("blocked_vs_naive_word_speedup",
             Json::Num(speedup(&m_word, &m_blocked_w)))
        .set("lane_vs_scalar_word_speedup",
             Json::Num(speedup(&m_scalar_w, &m_blocked_w)))
        .set("lut_vs_word_speedup", Json::Num(speedup(&m_word, &m_blocked)));
    (doc, m_blocked.throughput(macs), m_blocked_w.throughput(macs))
}

/// One metered/unmetered A/B pair for a single engine+kernel combination:
/// times the closure with and without the meter and reports both rates,
/// the metered/unmetered ratio, and the mean fJ/MAC of the metered runs.
fn meter_ab(label: &str, budget: u64, macs: f64,
            eng: &mut BlockedGemm,
            elut: &std::sync::Arc<energy::EnergyLut>,
            mut gemm: impl FnMut(&mut BlockedGemm) -> Vec<i64>) -> Json {
    eng.set_meter(None);
    let _ = eng.take_energy_fj();
    let m_plain = run(&format!("bench-report {label} unmetered"), budget,
                      || { black_box(gemm(black_box(eng))); });
    eng.set_meter(Some(elut.clone()));
    let _ = eng.take_energy_fj();
    let mut fj_per_mac = 0.0;
    let m_meter = run(&format!("bench-report {label} metered"), budget, || {
        black_box(gemm(black_box(eng)));
        fj_per_mac = eng.take_energy_fj() / macs;
    });
    eng.set_meter(None);
    Json::obj()
        .set("unmetered", meas_json(&m_plain, macs))
        .set("metered", meas_json(&m_meter, macs))
        .set("metered_vs_unmetered",
             Json::Num(m_plain.median_ns / m_meter.median_ns.max(1e-12)))
        .set("mean_mac_fj", Json::Num(fj_per_mac))
}

/// The fused-path headline: metered-vs-unmetered A/B for scalar/lane ×
/// word/lut on the same `size³` GEMM the kernel section times. Before
/// any timing, every metered variant is asserted bit-identical to the
/// unmetered reference and the lane meters are cross-checked against
/// the scalar meter to 1e-9 relative — a throughput number for a kernel
/// that miscounts femtojoules is worthless.
fn metered_kernels_section(rc: &ReportConfig) -> Json {
    let s = rc.size;
    let macs = (s * s * s) as f64;
    let budget = ((macs / 1e6) as u64).clamp(40, 1500);
    let cfg = PeConfig::new(8, true, Family::Proposed, rc.k);
    let a = ints(7, s * s);
    let b = ints(8, s * s);
    let elut = energy::cached(&cfg).expect("8-bit point meters");
    let want = word_matmul(&cfg, &a, &b, s, s, s);
    let bs = crate::gemm::effective_blocks();
    let mut lane = BlockedGemm::new(bs);
    let mut scalar = BlockedGemm::new(bs);
    scalar.set_lane_kernel(false);

    // correctness gate: bits identical on every metered path, lane
    // meters within 1e-9-rel of the scalar meter (at sizes below the
    // 32-column lane gate both engines take the scalar walk and the
    // cross-check degenerates to exact equality — still asserted)
    let mut fj = |eng: &mut BlockedGemm, word: bool, label: &str| -> f64 {
        eng.set_meter(Some(elut.clone()));
        let _ = eng.take_energy_fj();
        let got = if word { eng.matmul_word(&cfg, &a, &b, s, s, s) }
                  else { eng.matmul(&cfg, &a, &b, s, s, s) };
        assert_eq!(got, want, "{label}: metered bits != reference");
        let e = eng.take_energy_fj();
        assert!(e > 0.0, "{label}: meter accumulated nothing");
        eng.set_meter(None);
        e
    };
    let fj_word_scalar = fj(&mut scalar, true, "word scalar");
    let fj_word_lane = fj(&mut lane, true, "word lane");
    let fj_lut_scalar = fj(&mut scalar, false, "lut scalar");
    let fj_lut_lane = fj(&mut lane, false, "lut lane");
    for (l, sc, label) in [(fj_word_lane, fj_word_scalar, "word"),
                           (fj_lut_lane, fj_lut_scalar, "lut")] {
        assert!((l - sc).abs() <= 1e-9 * sc.abs(),
                "{label}: lane meter {l} fJ != scalar meter {sc} fJ");
    }

    Json::obj()
        .set("size", Json::Int(s as i64))
        .set("k", Json::Int(rc.k as i64))
        .set("word_lane", meter_ab("word lane", budget, macs, &mut lane,
                                   &elut,
                                   |e| e.matmul_word(&cfg, &a, &b, s, s, s)))
        .set("word_scalar", meter_ab("word scalar", budget, macs,
                                     &mut scalar, &elut,
                                     |e| e.matmul_word(&cfg, &a, &b, s, s, s)))
        .set("lut_lane", meter_ab("lut lane", budget, macs, &mut lane,
                                  &elut,
                                  |e| e.matmul(&cfg, &a, &b, s, s, s)))
        .set("lut_scalar", meter_ab("lut scalar", budget, macs, &mut scalar,
                                    &elut,
                                    |e| e.matmul(&cfg, &a, &b, s, s, s)))
}

/// Measured sequential read bandwidth: best-of-5 summing sweep over a
/// 16 MiB `u64` buffer (far past L2, the streaming pattern of the LUT
/// microkernel's table reads). Returns bytes/second.
fn measured_bandwidth_bytes_per_sec() -> f64 {
    const WORDS: usize = 1 << 21; // 16 MiB
    let buf: Vec<u64> = (0..WORDS as u64)
        .map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .collect();
    let mut best = f64::INFINITY;
    let mut acc = 0u64;
    for _ in 0..5 {
        let t0 = std::time::Instant::now();
        let mut sum = 0u64;
        for &v in &buf {
            sum = sum.wrapping_add(v);
        }
        acc = acc.wrapping_add(sum);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    black_box(acc);
    (WORDS * 8) as f64 / best.max(1e-12)
}

/// Achieved MACs/sec against the memory-bandwidth peak. The LUT
/// microkernel reads 8 bytes of table per MAC (`prod` i32 + `trans`
/// u32), so its bandwidth-bound peak is `bw / 8`; the word kernel is
/// compute-bound and reported for context only.
fn roofline_section(lut_macs_per_sec: f64, word_macs_per_sec: f64) -> Json {
    let bw = measured_bandwidth_bytes_per_sec();
    let bytes_per_mac = 8.0;
    let peak = bw / bytes_per_mac;
    Json::obj()
        .set("mem_bw_bytes_per_sec", Json::Num(bw))
        .set("table_bytes_per_mac", Json::Num(bytes_per_mac))
        .set("peak_macs_per_sec", Json::Num(peak))
        .set("lut_blocked_macs_per_sec", Json::Num(lut_macs_per_sec))
        .set("lut_efficiency_pct",
             Json::Num(lut_macs_per_sec / peak.max(1e-9) * 100.0))
        .set("word_blocked_macs_per_sec", Json::Num(word_macs_per_sec))
}

fn serve_section(rc: &ReportConfig) -> Json {
    let c = Coordinator::new(CoordinatorConfig {
        workers: rc.workers,
        backend: BackendKind::Lut,
        ..Default::default()
    });
    // warm the tables for every k the fleet will use (r % 8): the
    // one-time energy-table compiles are seconds-scale and would
    // otherwise land inside the first request at each k, turning the
    // latency percentiles into a measurement of cache cold-start
    // instead of serving (the product-LUT tables build in the same pass)
    for k in 0..rc.requests.min(8) as u32 {
        let pc = PeConfig::new(8, true, Family::Proposed, k);
        let _ = crate::pe::lut::cached(&pc);
        let _ = energy::cached(&pc);
    }
    let span = rc.size.clamp(16, 64);
    let mut rng = super::XorShift::new(0xBE7C);
    let t0 = std::time::Instant::now();
    let mut ids = Vec::with_capacity(rc.requests);
    for r in 0..rc.requests {
        let m = 8 + (rng.next_u64() as usize % span);
        let kk = 8 + (rng.next_u64() as usize % 25);
        let nn = 8 + (rng.next_u64() as usize % span);
        ids.push(c.submit(GemmRequest {
            a: ints(rng.next_u64(), m * kk),
            b: ints(rng.next_u64(), kk * nn),
            m, kk, nn,
            k: (r % 8) as u32,
            ..Default::default()
        }));
    }
    for id in ids {
        c.wait(id);
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = c.stats_snapshot();
    let out = Json::obj()
        .set("backend", Json::Str("lut".into()))
        .set("workers", Json::Int(rc.workers as i64))
        .set("requests", Json::Int(s.requests as i64))
        .set("req_per_sec", Json::Num(s.requests as f64 / wall.max(1e-9)))
        .set("tiles", Json::Int(s.tiles as i64))
        .set("latency_us", Json::obj()
            .set("p50", Json::Num(s.latency_percentile(0.50)))
            .set("p90", Json::Num(s.latency_percentile(0.90)))
            .set("p99", Json::Num(s.latency_percentile(0.99)))
            .set("max", Json::Num(s.max_latency_us))
            .set("mean", Json::Num(s.mean_latency_us())))
        .set("dispatch", Json::obj()
            .set("worker_dispatches", Json::Int(s.worker_dispatches as i64))
            .set("dispatched_tiles", Json::Int(s.dispatched_tiles as i64))
            .set("coalesced_calls", Json::Int(s.coalesced_calls as i64))
            .set("max_dispatch_tiles", Json::Int(s.max_dispatch_tiles as i64))
            .set("mean_dispatch_tiles", Json::Num(s.mean_dispatch_tiles()))
            .set("mean_dispatch_exec_us",
                 Json::Num(s.mean_dispatch_exec_us())))
        .set("lut_macs", Json::Int(s.lut_macs as i64))
        .set("energy_uj_total", Json::Num(s.total_energy_uj()))
        .set("metered_macs", Json::Int(s.metered_macs as i64))
        .set("mean_mac_fj", Json::Num(s.mean_mac_fj()));
    c.shutdown();
    out
}

/// The data-dependent energy model on a fixed synthetic stream (1024
/// MACs, chains of 64): mean fJ/MAC per design and the 8×8 array-level
/// savings vs the conventional MAC — the machine-readable form of the
/// headline `tests/energy_model.rs` golden-pins on the full stream.
fn energy_section() -> Json {
    let a_ops = ints(0xE7E5, 1024);
    let b_ops = ints(0x1A7B, 1024);
    let chain = 64;
    let e6 = energy::mean_mac_fj(
        &Design::conventional_exact(8, Signedness::Signed),
        &a_ops, &b_ops, chain);
    let prop_exact = energy::mean_mac_fj(
        &Design::proposed_exact(8, Signedness::Signed), &a_ops, &b_ops, chain);
    let prop_apx = energy::mean_mac_fj(
        &Design::approximate(8, Signedness::Signed, Family::Proposed, 7),
        &a_ops, &b_ops, chain);
    let conv = energy::conventional_mean_mac_fj(8, false, &a_ops, &b_ops);
    let arr = |fj| energy::array_fj_per_cycle(fj, 8, 8);
    Json::obj()
        .set("stream_macs", Json::Int(1024))
        .set("mean_mac_fj", Json::obj()
            .set("exact6", Json::Num(e6))
            .set("proposed_exact", Json::Num(prop_exact))
            .set("proposed_approx_k7", Json::Num(prop_apx))
            .set("conventional_mac", Json::Num(conv)))
        .set("array8_saving_vs_conventional_pct", Json::obj()
            .set("exact", Json::Num((1.0 - arr(prop_exact) / arr(conv)) * 100.0))
            .set("approx", Json::Num((1.0 - arr(prop_apx) / arr(conv)) * 100.0)))
}

fn apps_section(rc: &ReportConfig) -> Json {
    let side = (rc.size.clamp(32, 256) / 8) * 8;
    let img = scene(side, side);
    let c = Coordinator::new(CoordinatorConfig {
        workers: rc.workers,
        backend: BackendKind::Lut,
        ..Default::default()
    });
    // one warm call each (tables built, pool spun up), then the measured
    // response — per-request latency is what serving cares about
    c.serve_dct(&img, 5);
    let dct = c.serve_dct(&img, 5);
    c.serve_edge(&img, 4);
    let edge = c.serve_edge(&img, 4);
    let out = Json::obj()
        .set("image_side", Json::Int(side as i64))
        .set("dct", Json::obj()
            .set("k", Json::Int(5))
            .set("latency_us", Json::Num(dct.latency_us))
            .set("psnr_db", Json::Num(dct.psnr_db))
            .set("gemm_requests", Json::Int(dct.gemm_requests as i64)))
        .set("edge", Json::obj()
            .set("k", Json::Int(4))
            .set("latency_us", Json::Num(edge.latency_us))
            .set("psnr_db", Json::Num(edge.psnr_db))
            .set("gemm_requests", Json::Int(edge.gemm_requests as i64)));
    c.shutdown();
    out
}

/// Run the full fixed suite and assemble the report document.
pub fn collect(rc: &ReportConfig) -> Json {
    let threads = std::thread::available_parallelism()
        .map(|p| p.get()).unwrap_or(1);
    let generated_unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as i64)
        .unwrap_or(0);
    let bs = crate::gemm::effective_blocks();
    // the fan-out tile resolution mirrors CoordinatorConfig::tile_shape:
    // process-wide pin (--sw-tile / autotune) first, blocks-derived
    // fallback otherwise
    let (tr, tc) = crate::coordinator::effective_sw_tile()
        .unwrap_or((bs.mc, bs.nc * 4));
    let (kernels, lut_mps, word_mps) = kernel_section(rc);
    Json::obj()
        .set("schema", Json::Str("axsys-bench-report/v4".into()))
        .set("generated_unix", Json::Int(generated_unix))
        .set("config", Json::obj()
            .set("size", Json::Int(rc.size as i64))
            .set("requests", Json::Int(rc.requests as i64))
            .set("workers", Json::Int(rc.workers as i64))
            .set("k", Json::Int(rc.k as i64))
            .set("host_threads", Json::Int(threads as i64))
            .set("blocks", Json::obj()
                .set("mc", Json::Int(bs.mc as i64))
                .set("kc", Json::Int(bs.kc as i64))
                .set("nc", Json::Int(bs.nc as i64)))
            .set("sw_tile", Json::obj()
                .set("rows", Json::Int(tr as i64))
                .set("cols", Json::Int(tc as i64))))
        .set("kernels", kernels)
        .set("metered_kernels", metered_kernels_section(rc))
        .set("roofline", roofline_section(lut_mps, word_mps))
        .set("serve", serve_section(rc))
        .set("apps", apps_section(rc))
        .set("energy", energy_section())
}

/// Serialize `doc` to `path` (pretty-printed, trailing newline).
pub fn write_report(path: &Path, doc: &Json) -> std::io::Result<()> {
    std::fs::write(path, doc.pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_suite_produces_complete_report() {
        // the CI smoke shape: everything present, numbers positive
        let rc = ReportConfig { size: 16, requests: 4, workers: 2, k: 4 };
        let doc = collect(&rc);
        let kernels = doc.get("kernels").expect("kernels");
        for key in ["word_naive", "lut_naive", "lut_blocked", "word_blocked",
                    "word_blocked_scalar"] {
            let m = kernels.get(key).expect(key);
            match m.get("macs_per_sec") {
                Some(&Json::Num(v)) => assert!(v > 0.0, "{key}: {v}"),
                other => panic!("{key}.macs_per_sec: {other:?}"),
            }
        }
        assert!(kernels.get("blocked_vs_naive_lut_speedup").is_some());
        assert!(kernels.get("lane_vs_scalar_word_speedup").is_some());
        // the metered A/B: all four engine x kernel pairs, both sides
        // timed, and a recorded fJ/MAC (size 16 sits below the 32-column
        // lane gate, so this also covers the scalar-fallback shape —
        // collect() ran the bit-equality and 1e-9-rel meter cross-check
        // asserts on the way here)
        let mk = doc.get("metered_kernels").expect("metered_kernels");
        for key in ["word_lane", "word_scalar", "lut_lane", "lut_scalar"] {
            let ab = mk.get(key).expect(key);
            for side in ["unmetered", "metered"] {
                match ab.get(side).and_then(|m| m.get("macs_per_sec")) {
                    Some(&Json::Num(v)) => {
                        assert!(v > 0.0, "{key}.{side}: {v}");
                    }
                    other => panic!("{key}.{side}: {other:?}"),
                }
            }
            match (ab.get("metered_vs_unmetered"), ab.get("mean_mac_fj")) {
                (Some(&Json::Num(r)), Some(&Json::Num(fj))) => {
                    assert!(r > 0.0 && fj > 0.0, "{key}: {r} {fj}");
                }
                other => panic!("{key} ratios: {other:?}"),
            }
        }
        // config carries the resolved fan-out tile
        let tile = doc.get("config").and_then(|c| c.get("sw_tile"))
            .expect("config.sw_tile");
        match (tile.get("rows"), tile.get("cols")) {
            (Some(&Json::Int(r)), Some(&Json::Int(c))) => {
                assert!(r >= 1 && c >= 1, "{r}x{c}");
            }
            other => panic!("sw_tile: {other:?}"),
        }
        // roofline: measured bandwidth and a finite efficiency
        let roof = doc.get("roofline").expect("roofline");
        for key in ["mem_bw_bytes_per_sec", "peak_macs_per_sec",
                    "lut_blocked_macs_per_sec", "lut_efficiency_pct"] {
            match roof.get(key) {
                Some(&Json::Num(v)) => {
                    assert!(v > 0.0 && v.is_finite(), "{key}: {v}");
                }
                other => panic!("{key}: {other:?}"),
            }
        }
        let serve = doc.get("serve").expect("serve");
        assert_eq!(serve.get("requests"), Some(&Json::Int(4)));
        let lat = serve.get("latency_us").expect("latency_us");
        match (lat.get("p50"), lat.get("p99")) {
            (Some(&Json::Num(p50)), Some(&Json::Num(p99))) => {
                assert!(p50 > 0.0 && p50 <= p99, "{p50} vs {p99}");
            }
            other => panic!("percentiles missing: {other:?}"),
        }
        let disp = serve.get("dispatch").expect("dispatch");
        match disp.get("worker_dispatches") {
            Some(&Json::Int(v)) => assert!(v >= 1),
            other => panic!("worker_dispatches: {other:?}"),
        }
        assert!(doc.get("apps").and_then(|a| a.get("dct")).is_some());
        // served requests are metered on the lut backend
        match serve.get("energy_uj_total") {
            Some(&Json::Num(v)) => assert!(v > 0.0, "served energy {v}"),
            other => panic!("energy_uj_total: {other:?}"),
        }
        // the energy section carries the headline savings
        let energy = doc.get("energy").expect("energy section");
        let sav = energy.get("array8_saving_vs_conventional_pct")
            .expect("savings");
        match (sav.get("exact"), sav.get("approx")) {
            (Some(&Json::Num(e)), Some(&Json::Num(a))) => {
                assert!(a > e && e > 0.0,
                        "approx must save more than exact: {a} vs {e}");
            }
            other => panic!("savings: {other:?}"),
        }
        // the whole document serializes
        let text = doc.pretty();
        assert!(text.starts_with('{') && text.ends_with("}\n"));
    }
}
