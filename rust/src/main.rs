//! axsys CLI — leader entrypoint for the approximate systolic-array stack.
//!
//! The `COMMANDS` table below is the single source of truth for the
//! subcommand/flag surface: `axsys help` renders it for the terminal,
//! `axsys help --markdown` emits the README's CLI section verbatim, and
//! a unit test in this file fails whenever the README copy drifts.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use axsys::apps::image::{psnr, scene, ssim, texture, write_pgm};
use axsys::coordinator::{AppKind, BackendKind, Coordinator, CoordinatorConfig,
                         GemmRequest};
use axsys::pe::word::PeConfig;
use axsys::pe::{Design, Signedness};
use axsys::runtime::{read_golden_bin, read_manifest, Runtime, TensorI32};
use axsys::Family;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let code = match cmd {
        "selftest" => selftest(),
        "hw-report" => hw_report(),
        "error-sweep" => error_sweep(),
        "dct" => app_dct(rest),
        "edge" => app_edge(rest),
        "cnn" => app_cnn(rest),
        "infer" => infer(rest),
        "serve" => serve(rest),
        "loadgen" => loadgen(rest),
        "apps-report" => apps_report(rest),
        "lut-report" => lut_report(),
        "zoo-report" => zoo_report(rest),
        "nn-report" => nn_report(rest),
        "energy-report" => energy_report(rest),
        "bench-report" => bench_report(rest),
        "emit-verilog" => emit_verilog(rest),
        "help" | "--help" | "-h" => {
            if rest.iter().any(|a| a == "--markdown") {
                print!("{}", help_markdown());
            } else {
                print_help();
            }
            0
        }
        other => {
            eprintln!("unknown command: {other}");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

/// One CLI subcommand: `(name, argument summary, description)`.
///
/// `{BACKENDS}` / `{APPS}` placeholders are substituted with the live
/// parser sets ([`BackendKind::names`] / [`AppKind::names`]) at render
/// time, so the advertised values can never drift from what parses.
struct Cmd {
    name: &'static str,
    args: &'static str,
    help: &'static str,
}

/// Single source of truth for the CLI surface (help text, README table,
/// and the drift test at the bottom of this file).
const COMMANDS: &[Cmd] = &[
    Cmd { name: "selftest", args: "",
          help: "invariants + AOT golden cross-check" },
    Cmd { name: "hw-report", args: "",
          help: "Tables II-IV + Figs 8-10 (hardware model)" },
    Cmd { name: "error-sweep", args: "",
          help: "Table V NMED/MRED sweeps" },
    Cmd { name: "dct", args: "[--k K] [--out DIR]",
          help: "DCT compression pipeline (coordinator-served)" },
    Cmd { name: "edge", args: "[--k K] [--out DIR]",
          help: "Laplacian edge detection (coordinator-served)" },
    Cmd { name: "cnn", args: "[--k K] [--out DIR]",
          help: "BDCN-lite CNN edge detection (coordinator-served)" },
    Cmd { name: "infer",
          args: "[--plan exact|uniform|hybrid|mixed|slo] [--k K] \
                 [--batch N] [--slo SPEC]",
          help: "quantized CNN classifier inference on the seeded eval \
                 batch, each layer at its plan-assigned design point \
                 (coordinator-served)" },
    Cmd { name: "serve",
          args: "[--backend {BACKENDS}] [--workers N] [--requests R] \
                 [--app gemm|{APPS}] [--k K] [--slo SPEC] \
                 [--block-sizes MCxKCxNC] [--sw-tile RxC] [--listen ADDR] \
                 [--shards N] [--max-inflight N] [--port-file PATH]",
          help: "run the GEMM coordinator on synthetic/app traffic, or \
                 serve it over TCP (--listen); --slo routes requests by \
                 accuracy (nmed=X and/or psnr=Y)" },
    Cmd { name: "loadgen",
          args: "--addr HOST:PORT [--clients N] [--requests R] [--k K] \
                 [--slo SPEC] [--seed S] [--gemm-only] [--conns N] \
                 [--per-conn R] [--threads T] [--out PATH]",
          help: "framed-TCP load generator -> BENCH_serve_net.json \
                 (against serve --listen; --conns: connection-scale mode; \
                 --slo: attach an accuracy SLO to half the mix)" },
    Cmd { name: "apps-report", args: "[--backend {BACKENDS}] [--size S]",
          help: "paper §V PSNR tables: all six cell families x k, served" },
    Cmd { name: "lut-report", args: "",
          help: "product-LUT table sizes per design point" },
    Cmd { name: "zoo-report", args: "[--out PATH]",
          help: "design-point zoo: oracle-pinned energy/error columns per \
                 entry + per-tier cheapest table -> ZOO_report.json" },
    Cmd { name: "nn-report", args: "[--batch N] [--out PATH]",
          help: "network-level CNN energy/accuracy table: exact vs \
                 uniform-k vs mixed per-layer plans, per-layer fJ \
                 breakdown -> NN_report.json" },
    Cmd { name: "energy-report", args: "[--size S] [--k K] [--out PATH]",
          help: "array-level energy savings + accuracy-vs-energy scatter \
                 at real workload activity" },
    Cmd { name: "bench-report",
          args: "[--size S] [--requests R] [--workers W] [--k K] \
                 [--block-sizes MCxKCxNC] [--sw-tile RxC] [--out PATH]",
          help: "fixed perf suite (kernels + bandwidth roofline) -> \
                 BENCH_hotpath.json at the repo root" },
    Cmd { name: "emit-verilog", args: "[--out DIR]",
          help: "export every cell + PE design as Verilog" },
    Cmd { name: "help", args: "[--markdown]",
          help: "this message (--markdown: the README CLI table)" },
];

fn expand(template: &str) -> String {
    template
        .replace("{BACKENDS}", &BackendKind::names())
        .replace("{APPS}", &AppKind::names())
}

fn print_help() {
    println!("axsys — energy-efficient exact/approximate systolic arrays (VLSID'26 repro)");
    println!();
    println!("usage: axsys <command> [options]");
    for c in COMMANDS {
        let args = expand(c.args);
        if args.is_empty() {
            println!("  {:<14} {}", c.name, c.help);
        } else if c.name.len() + args.len() < 60 {
            println!("  {:<14} {args}", c.name);
            println!("  {:<14} {}", "", c.help);
        } else {
            println!("  {} {args}", c.name);
            println!("  {:<14} {}", "", c.help);
        }
    }
}

/// The README's CLI section, generated (between the `<!-- CLI:BEGIN -->`
/// / `<!-- CLI:END -->` markers). Regenerate with
/// `cargo run --release -- help --markdown`. Literal pipes in cells are
/// escaped so the GFM table structure survives.
fn help_markdown() -> String {
    let esc = |s: &str| s.replace('|', "\\|");
    let mut s = String::new();
    s.push_str("| command | arguments | description |\n");
    s.push_str("|---------|-----------|-------------|\n");
    for c in COMMANDS {
        s.push_str(&format!("| `{}` | {} | {} |\n",
                            c.name, esc(&expand(c.args)), esc(c.help)));
    }
    s
}

fn opt(rest: &[String], name: &str) -> Option<String> {
    rest.iter().position(|a| a == name)
        .and_then(|i| rest.get(i + 1).cloned())
}

fn opt_k(rest: &[String]) -> u32 {
    opt(rest, "--k").and_then(|v| v.parse().ok()).unwrap_or(2)
}

/// Pin the process-wide GEMM blocking before any engine spins up:
/// `--block-sizes MCxKCxNC` wins, otherwise the startup autotune sweep
/// runs (cached per process). Returns an exit code on a malformed value.
fn pin_block_sizes(rest: &[String]) -> Result<(), i32> {
    use axsys::gemm::{autotune_blocks, set_block_override, BlockSizes};
    if let Some(v) = opt(rest, "--block-sizes") {
        match BlockSizes::parse(&v) {
            Some(bs) => {
                set_block_override(bs);
                println!("  blocks: {}x{}x{} (--block-sizes)",
                         bs.mc, bs.kc, bs.nc);
            }
            None => {
                eprintln!("--block-sizes expects MCxKCxNC (e.g. 64x256x64, \
                           all >= 1)");
                return Err(2);
            }
        }
    } else {
        let bs = autotune_blocks();
        println!("  blocks: {}x{}x{} (startup autotune; pin with \
                  --block-sizes)", bs.mc, bs.kc, bs.nc);
    }
    Ok(())
}

/// Pin the process-wide fan-out tile shape: `--sw-tile RxC` wins,
/// otherwise the startup autotune sweep measures it against live
/// coordinator pools (cached per process). Runs after
/// [`pin_block_sizes`] so candidate tiles align with the pinned
/// blocking. Returns an exit code on a malformed value.
fn pin_sw_tile(rest: &[String], workers: usize) -> Result<(), i32> {
    use axsys::coordinator::{autotune_sw_tile, parse_sw_tile,
                             set_sw_tile_override};
    if let Some(v) = opt(rest, "--sw-tile") {
        match parse_sw_tile(&v) {
            Some(t) => {
                set_sw_tile_override(t);
                println!("  sw-tile: {}x{} (--sw-tile)", t.0, t.1);
            }
            None => {
                eprintln!("--sw-tile expects RxC (e.g. 64x256, both >= 1)");
                return Err(2);
            }
        }
    } else {
        let (tr, tc) = autotune_sw_tile(workers);
        println!("  sw-tile: {tr}x{tc} (startup autotune; pin with \
                  --sw-tile)");
    }
    Ok(())
}

fn out_dir(rest: &[String]) -> PathBuf {
    PathBuf::from(opt(rest, "--out").unwrap_or_else(|| "out".into()))
}

// -------------------------------------------------------------------

fn selftest() -> i32 {
    println!("== cells: Table I truth tables ==");
    use axsys::cells::{error_rate, CellKind};
    for kind in [CellKind::PropApxPpc, CellKind::PropApxNppc] {
        let (bad, total) = error_rate(kind);
        println!("  {:<16} error rate {}/{}", kind.name(), bad, total);
        assert_eq!((bad, total), (5, 16));
    }

    println!("== PE: exact == a*b+c (exhaustive 4-bit, random 8/16-bit) ==");
    for n in [4u32, 8] {
        let cfg = PeConfig::new(n, true, Family::Proposed, 0);
        let half = 1i64 << (n - 1);
        for a in (-half..half).step_by(3) {
            for b in (-half..half).step_by(5) {
                assert_eq!(axsys::pe::word::Pe::mac_value(&cfg, a, b, 77),
                           a * b + 77);
            }
        }
        println!("  n={n} signed OK");
    }

    println!("== systolic: 3N-2 latency + exact GEMM ==");
    let cfg = PeConfig::new(8, true, Family::Proposed, 0);
    let mut sa = axsys::systolic::Systolic::square(cfg, 8);
    let a: Vec<i64> = (0..64).map(|i| (i * 37 % 255) - 127).collect();
    let b: Vec<i64> = (0..64).map(|i| (i * 53 % 255) - 127).collect();
    let (y, st) = sa.run_tile(&a, &b, 8);
    assert_eq!(st.cycles, 22); // 3*8-2
    for i in 0..8 {
        for j in 0..8 {
            let want: i64 = (0..8).map(|t| a[i * 8 + t] * b[t * 8 + j]).sum();
            assert_eq!(y[i * 8 + j], want);
        }
    }
    println!("  8x8 OK ({} cycles)", st.cycles);

    println!("== runtime: AOT golden cross-check ==");
    match golden_check() {
        Ok(n) => println!("  {n} golden cases OK"),
        Err(e) => {
            println!("  SKIPPED/FAILED: {e:#}");
            return 1;
        }
    }
    println!("selftest PASSED");
    0
}

fn golden_check() -> anyhow::Result<usize> {
    let dir = Runtime::default_artifacts_dir();
    let golden = dir.join("golden");
    let cases = read_manifest(&golden)?;
    let rt = Runtime::new(&dir)?;
    println!("  PJRT platform: {}", rt.platform());
    let mut checked = 0;
    for case in &cases {
        let mut inputs = Vec::new();
        for (i, shape) in case.in_shapes.iter().enumerate() {
            let data = read_golden_bin(
                &golden.join(format!("{}_in{i}.bin", case.case)))?;
            inputs.push(TensorI32::new(shape.clone(), data));
        }
        inputs.push(TensorI32::scalar1(case.k));
        let outs = rt.run(&case.artifact, &inputs)?;
        for (i, shape) in case.out_shapes.iter().enumerate() {
            let want = read_golden_bin(
                &golden.join(format!("{}_out{i}.bin", case.case)))?;
            anyhow::ensure!(outs[i].dims == *shape,
                            "{}: out{i} shape {:?} != {:?}",
                            case.case, outs[i].dims, shape);
            anyhow::ensure!(outs[i].data == want,
                            "{}: out{i} data mismatch", case.case);
        }
        checked += 1;
    }
    Ok(checked)
}

// -------------------------------------------------------------------

fn hw_report() -> i32 {
    use axsys::hw;
    println!("== Table II: cell-level (area µm² / power µW / delay ps / PDP aJ) ==");
    for row in hw::table2() {
        let p = row.ppc;
        let n = row.nppc;
        println!("  {:<12} PPC {:6.2} {:5.2} {:5.0} {:7.1}   NPPC {:6.2} {:5.2} {:5.0} {:7.1}",
                 row.label, p.area_um2, p.power_uw, p.delay_ns * 1e3,
                 p.pdp_fj * 1e3, n.area_um2, n.power_uw, n.delay_ns * 1e3,
                 n.pdp_fj * 1e3);
    }

    println!("== Table III: PE-level (area µm² / power µW / delay ns / PADP) ==");
    for row in hw::table3() {
        let fmt = |m: Option<hw::HwMetrics>| match m {
            Some(m) => format!("{:7.1} {:6.1} {:5.2} {:8.2}",
                               m.area_um2, m.power_uw, m.delay_ns, m.padp),
            None => format!("{:>28}", "-"),
        };
        println!("  {:<22} {}b  U[{}]  S[{}]", row.label, row.n,
                 fmt(row.unsigned), fmt(row.signed));
    }

    println!("== Table IV: SA-level @250MHz (area mm² / power mW / delay ns / PDP pJ) ==");
    for row in hw::table4() {
        print!("  {:<22} {}b", row.label, row.n);
        for (size, m) in row.sizes {
            print!("  {}x{size}: {:.4} {:.2} {:.2} {:.2}", size,
                   m.area_um2 / 1e6, m.power_uw / 1e3, m.delay_ns,
                   m.pdp_fj / 1e3);
        }
        println!();
    }

    println!("== Fig 8: savings across sizes (8-bit signed) ==");
    for p in hw::fig8(8) {
        println!("  {0}x{0}: area -{1:.1}%  PDP -{2:.1}%  approx-vs-[5] PDP -{3:.1}%",
                 p.size, p.area_saving_pct, p.pdp_saving_pct,
                 p.approx_pdp_vs_best_pct);
    }
    println!("== Fig 9: PDP vs NMED (k = N-1) ==");
    for p in hw::fig9() {
        println!("  {:<12} PDP {:8.1} fJ  NMED {:.4}", p.label, p.pdp_fj, p.nmed);
    }
    println!("== Fig 10: PDP & MRED vs k ==");
    for p in hw::fig10() {
        println!("  k={}  PDP {:8.1} fJ  MRED {:.4}", p.k, p.pdp_fj, p.mred);
    }
    0
}

fn error_sweep() -> i32 {
    use axsys::error::table5_row;
    println!("== Table V: 8-bit PE error metrics ==");
    println!("  {:<12} {:>2} | {:>8} {:>8} | {:>8} {:>8}",
             "design", "k", "NMED(u)", "MRED(u)", "NMED(s)", "MRED(s)");
    for k in [2u32, 4, 5, 6, 8] {
        let (u, s) = table5_row(Family::Proposed, k, 8);
        println!("  {:<12} {:>2} | {:>8.4} {:>8.4} | {:>8.4} {:>8.4}",
                 "Proposed", k, u.nmed, u.mred, s.nmed, s.mred);
    }
    for f in [Family::Axsa5, Family::Nano6, Family::Sips12] {
        let (u, s) = table5_row(f, 6, 8);
        println!("  {:<12} {:>2} | {:>8.4} {:>8.4} | {:>8.4} {:>8.4}",
                 f.paper_label(), 6, u.nmed, u.mred, s.nmed, s.mred);
    }
    0
}

// -------------------------------------------------------------------

fn app_dct(rest: &[String]) -> i32 {
    let k = opt_k(rest);
    let dir = out_dir(rest);
    std::fs::create_dir_all(&dir).unwrap();
    let img = scene(256, 256);
    // every GEMM stage rides the coordinator's worker pool (the same
    // serving path `serve --app dct` exposes), on the cycle-accurate
    // backend for the paper's cycle/energy accounting
    let c = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Systolic, ..Default::default()
    });
    let exact = c.serve_dct(&img, 0);
    let apx = c.serve_dct(&img, k);
    println!("DCT 256x256, k={k} (coordinator, systolic 8x8 backend)");
    println!("  exact-vs-original  PSNR {:6.2} dB", exact.psnr_db);
    println!("  approx-vs-exact    PSNR {:6.2} dB  SSIM {:.4}",
             psnr(&exact.out.data, &apx.out.data),
             ssim(&exact.out.data, &apx.out.data));
    let st = apx.sa_stats;
    println!("  SA: {} tiles, {} cycles, {} MACs ({} GEMM sub-requests)",
             st.tiles, st.total_cycles(), st.macs, apx.gemm_requests);
    write_pgm(&dir.join("dct_input.pgm"), &img).unwrap();
    write_pgm(&dir.join(format!("dct_recon_k{k}.pgm")), &apx.out).unwrap();
    println!("  wrote {}/dct_recon_k{k}.pgm", dir.display());
    c.shutdown();
    0
}

fn app_edge(rest: &[String]) -> i32 {
    let k = opt_k(rest);
    let dir = out_dir(rest);
    std::fs::create_dir_all(&dir).unwrap();
    let img = scene(256, 256);
    let c = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Lut, ..Default::default()
    });
    let exact = c.serve_edge(&img, 0);
    let apx = c.serve_edge(&img, k); // psnr_db = approx-vs-exact, served
    println!("Laplacian edge 256x256, k={k} (coordinator, lut backend)");
    println!("  approx-vs-exact PSNR {:6.2} dB  SSIM {:.4}",
             apx.psnr_db, ssim(&exact.out.data, &apx.out.data));
    write_pgm(&dir.join(format!("edge_k{k}.pgm")), &apx.out).unwrap();
    c.shutdown();
    0
}

fn app_cnn(rest: &[String]) -> i32 {
    let k = opt_k(rest);
    let dir = out_dir(rest);
    std::fs::create_dir_all(&dir).unwrap();
    let weights = Runtime::default_artifacts_dir().join("bdcn_weights.txt");
    let blocks = match axsys::apps::bdcn::load_weights(&weights) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("cannot load {}: {e:#} (run `make artifacts`)",
                      weights.display());
            return 1;
        }
    };
    let img = scene(128, 128);
    let c = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Lut, ..Default::default()
    });
    let exact = c.serve_bdcn(&blocks, &img, 0);
    let apx = c.serve_bdcn(&blocks, &img, k);
    println!("BDCN-lite edge 128x128, k={k} (blocks 1-2 approx, 3-4 exact; \
              coordinator, lut backend)");
    println!("  approx-vs-exact PSNR {:6.2} dB  SSIM {:.4}",
             apx.psnr_db, ssim(&exact.out.data, &apx.out.data));
    write_pgm(&dir.join(format!("bdcn_k{k}.pgm")), &apx.out).unwrap();
    c.shutdown();
    0
}

/// `infer`: serve the checked-in quantized CNN classifier
/// ([`axsys::nn`]) on its deterministic eval batch under a named
/// per-layer plan — every GEMM-bearing layer runs at its plan-assigned
/// design point through the coordinator, with the per-layer energy
/// breakdown and output quality printed.
fn infer(rest: &[String]) -> i32 {
    use axsys::nn::{self, InferPlan};
    let k = opt_k(rest);
    let batch_n: usize = opt(rest, "--batch")
        .and_then(|v| v.parse().ok()).unwrap_or(4);
    if batch_n == 0 {
        eprintln!("infer: --batch must be >= 1");
        return 2;
    }
    let net = nn::default_network();
    let slots = net.n_gemm_layers();
    let plan_name = opt(rest, "--plan").unwrap_or_else(|| "mixed".into());
    let plan = match plan_name.as_str() {
        "exact" => InferPlan::exact(slots),
        "uniform" => InferPlan::uniform(Some(Family::Proposed), k, slots),
        "hybrid" => InferPlan::hybrid_k(k, slots),
        "mixed" => InferPlan::mixed_default(slots),
        "slo" => {
            let spec = opt(rest, "--slo")
                .unwrap_or_else(|| "nmed=2.5e-3".into());
            match axsys::zoo::AccuracySlo::parse(&spec) {
                Ok(s) => InferPlan::slo_mixed(s, slots),
                Err(e) => {
                    eprintln!("infer: bad --slo '{spec}': {e}");
                    return 2;
                }
            }
        }
        other => {
            eprintln!("infer: unknown --plan '{other}' \
                       (exact|uniform|hybrid|mixed|slo)");
            return 2;
        }
    };
    let c = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Lut, ..Default::default()
    });
    let batch = nn::eval_batch(batch_n);
    let (resp, st) = match c.serve_nn(net, &batch, &plan) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("infer: SLO routing failed: {e}");
            return 1;
        }
    };
    println!("CNN inference: {}x{} x{batch_n} batch, plan '{}' \
              (coordinator, lut backend)",
             nn::INPUT_SIDE, nn::INPUT_SIDE, st.plan);
    println!("  {:<8} {:<14} | {:>5} x {:<3} x {:<3} | {:>9} {:>12}",
             "layer", "point", "m", "kk", "nn", "MACs", "fJ");
    for l in &st.layers {
        println!("  {:<8} {:<14} | {:>5} x {:<3} x {:<3} | {:>9} {:>12.1}",
                 l.name, l.point_label(), l.m, l.kk, l.nn, l.macs,
                 l.energy_fj);
    }
    println!("  total {:.4} µJ over {} GEMM sub-requests ({:.1} µs)",
             st.total_energy_uj(), resp.gemm_requests, resp.latency_us);
    println!("  quality vs exact: logit PSNR {:.2} dB, top-1 match {:.0}%",
             st.logit_psnr_db, st.top1_match * 100.0);
    for b in 0..st.batch {
        let row = &st.logits[b * nn::N_CLASSES..(b + 1) * nn::N_CLASSES];
        println!("  image {b}: class {} | logits {row:?}",
                 nn::top1_of(row));
    }
    c.shutdown();
    0
}

fn emit_verilog(rest: &[String]) -> i32 {
    use axsys::cells::CellKind;
    use axsys::netlist::verilog::to_verilog;
    use axsys::pe::netlist_builder::{cell_netlist, pe_netlists};
    let dir = out_dir(rest).join("verilog");
    std::fs::create_dir_all(&dir).unwrap();
    let mut count = 0;
    for kind in CellKind::ALL {
        let nl = cell_netlist(kind);
        let path = dir.join(format!("cell_{}.v", kind.name()));
        std::fs::write(&path, to_verilog(&nl, kind.name())).unwrap();
        count += 1;
    }
    for (label, d) in [
        ("pe_exact6_8b_signed", Design::conventional_exact(8, Signedness::Signed)),
        ("pe_prop_exact_8b_signed", Design::proposed_exact(8, Signedness::Signed)),
        ("pe_prop_apx_8b_signed",
         Design::approximate_default(8, Signedness::Signed, Family::Proposed)),
        ("pe_prop_apx_4b_signed",
         Design::approximate_default(4, Signedness::Signed, Family::Proposed)),
        ("pe_prop_exact_8b_unsigned",
         Design::proposed_exact(8, Signedness::Unsigned)),
    ] {
        let cfg = axsys::pe::word::PeConfig::from_design(&d);
        let nets = pe_netlists(&d, cfg.w);
        std::fs::write(dir.join(format!("{label}.v")),
                       to_verilog(&nets.grid, label)).unwrap();
        std::fs::write(dir.join(format!("{label}_merge.v")),
                       to_verilog(&nets.merge, &format!("{label}_merge"))).unwrap();
        count += 2;
    }
    println!("wrote {count} Verilog modules to {}", dir.display());
    0
}

/// Run the fixed perf suite and write `BENCH_hotpath.json` (repo root by
/// default) so every PR carries a machine-readable perf trajectory.
fn bench_report(rest: &[String]) -> i32 {
    use axsys::bench::report::{self, ReportConfig};
    let mut rc = ReportConfig::default();
    if let Some(v) = opt(rest, "--size").and_then(|v| v.parse().ok()) {
        rc.size = v;
    }
    if let Some(v) = opt(rest, "--requests").and_then(|v| v.parse().ok()) {
        rc.requests = v;
    }
    if let Some(v) = opt(rest, "--workers").and_then(|v| v.parse().ok()) {
        rc.workers = v;
    }
    if let Some(v) = opt(rest, "--k").and_then(|v| v.parse().ok()) {
        rc.k = v;
    }
    if rc.size < 16 || rc.requests == 0 || rc.workers == 0 || rc.k > 8 {
        eprintln!("bench-report: --size >= 16, --requests/--workers >= 1, \
                   --k 0..=8");
        return 2;
    }
    let out = opt(rest, "--out").map(PathBuf::from)
        .unwrap_or_else(report::default_path);
    println!("bench-report: size={} requests={} workers={} k={}",
             rc.size, rc.requests, rc.workers, rc.k);
    if let Err(code) = pin_block_sizes(rest) {
        return code;
    }
    if let Err(code) = pin_sw_tile(rest, rc.workers) {
        return code;
    }
    let bm = axsys::coordinator::calibrate_batch_macs();
    println!("  batch-macs: {bm} (metered-kernel calibration)");
    let doc = report::collect(&rc);
    if let Err(e) = report::write_report(&out, &doc) {
        eprintln!("cannot write {}: {e}", out.display());
        return 1;
    }
    let speedup = doc.get("kernels")
        .and_then(|k| k.get("blocked_vs_naive_lut_speedup"));
    if let Some(axsys::bench::Json::Num(sx)) = speedup {
        println!("  blocked_vs_naive_lut: {sx:.2}x{}",
                 if *sx >= 1.0 { "  [blocked >= naive OK]" }
                 else { "  [REGRESSION vs naive lut]" });
    }
    if let Some(roof) = doc.get("roofline") {
        if let (Some(axsys::bench::Json::Num(eff)),
                Some(axsys::bench::Json::Num(peak))) =
            (roof.get("lut_efficiency_pct"), roof.get("peak_macs_per_sec"))
        {
            println!("  roofline: lut blocked at {eff:.1}% of the \
                      {peak:.3e} MACs/s bandwidth-bound peak");
        }
    }
    println!("  wrote {}", out.display());
    0
}

fn lut_report() -> i32 {
    use axsys::pe::lut::ProductLut;
    println!("== product-LUT design points (8-bit signed) ==");
    println!("  {:<12} {:>2} | {:>7} {:>12}", "family", "k", "states", "bytes");
    for family in Family::ALL {
        for k in [0u32, 2, 4, 6, 7] {
            let cfg = PeConfig::new(8, true, family, k);
            match ProductLut::try_build(&cfg) {
                Some(lut) => println!("  {:<12} {:>2} | {:>7} {:>12}",
                                      family.name(), k, lut.states(),
                                      lut.table_bytes()),
                None => println!("  {:<12} {:>2} | {:>7} {:>12}",
                                 family.name(), k, "-", "word fallback"),
            }
        }
    }
    0
}

/// Default artifact location for `zoo-report`: repo root, next to the
/// other report artifacts.
fn zoo_report_default_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .join("ZOO_report.json")
}

/// `zoo-report`: the design-point zoo as a table — oracle-derived
/// energy/error/PSNR columns per registered entry plus the cheapest
/// entry per accuracy tier (what the SLO router picks) — and as the
/// `ZOO_report.json` artifact. Every number comes from the cached
/// registry, so this is also what serving-time routing decisions see.
fn zoo_report(rest: &[String]) -> i32 {
    use axsys::zoo::{registry, report_json, route_among, AccuracySlo, Tier,
                     ZOO_N_BITS};
    let out = opt(rest, "--out").map(PathBuf::from)
        .unwrap_or_else(zoo_report_default_path);
    let reg = registry();
    let exact_fj = reg.iter()
        .find(|e| e.design.k == 0)
        .map(|e| e.mean_mac_fj)
        .unwrap_or(f64::NAN);
    println!("== design-point zoo ({} entries, {ZOO_N_BITS}-bit signed) ==",
             reg.len());
    println!("  {:<12} {:<5} | {:>8} {:>7} | {:>10} {:>8} {:>6} | {:>8} {:>9}",
             "entry", "tier", "fJ/MAC", "saving", "nmed", "mred", "max_ed",
             "psnr_dct", "psnr_edge");
    for e in reg {
        let saving = (1.0 - e.mean_mac_fj / exact_fj) * 100.0;
        println!("  {:<12} {:<5} | {:>8.3} {:>6.1}% | {:>10.3e} {:>8.5} \
                  {:>6} | {:>8.2} {:>9.2}",
                 e.label(), e.tier().name(), e.mean_mac_fj, saving, e.nmed,
                 e.mred, e.max_ed, e.psnr_dct, e.psnr_edge);
    }
    println!("== cheapest per tier (what an SLO at the tier bound routes) ==");
    for t in Tier::ALL {
        let pool: Vec<_> = reg.iter().filter(|e| e.tier() == t).collect();
        // cheapest within the tier via the router itself (an SLO loose
        // enough to admit everything), so the table can never disagree
        // with serving-time behaviour
        let slo = AccuracySlo { max_nmed: Some(f64::MAX), min_psnr_db: None };
        match route_among(pool.iter().copied(), &slo) {
            Some(c) => {
                let saving = (1.0 - c.mean_mac_fj / exact_fj) * 100.0;
                println!("  {:<5} | {:>2} entries | cheapest {:<12} \
                          {:>8.3} fJ/MAC ({:>5.1}% vs exact)",
                         t.name(), pool.len(), c.label(), c.mean_mac_fj,
                         saving);
            }
            None => println!("  {:<5} | {:>2} entries", t.name(), pool.len()),
        }
    }
    println!("  note: per-MAC columns rank single design points; for \
              per-layer mixed plans on conv traffic see NN_report.json \
              (`axsys nn-report`)");
    let doc = report_json();
    if let Err(e) = std::fs::write(&out, doc.pretty()) {
        eprintln!("cannot write {}: {e}", out.display());
        return 1;
    }
    println!("  wrote {}", out.display());
    0
}

/// Default artifact location for `nn-report`: repo root, next to the
/// other report artifacts (a CI artifact like `ZOO_report.json`, not
/// checked in).
fn nn_report_default_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .join("NN_report.json")
}

/// `nn-report`: the network-level energy/accuracy table for the served
/// CNN classifier — the exact plan vs uniform-k plans vs the mixed
/// per-layer plan, each row with total energy, the per-layer breakdown
/// and output quality vs exact — printed and written to
/// `NN_report.json`. The per-layer rows are what the zoo's per-MAC
/// columns cannot express: the cross-reference both artifacts carry.
fn nn_report(rest: &[String]) -> i32 {
    use axsys::bench::Json;
    use axsys::nn::{self, InferPlan};
    let batch_n: usize = opt(rest, "--batch")
        .and_then(|v| v.parse().ok()).unwrap_or(4);
    if batch_n == 0 {
        eprintln!("nn-report: --batch must be >= 1");
        return 2;
    }
    let out = opt(rest, "--out").map(PathBuf::from)
        .unwrap_or_else(nn_report_default_path);
    let net = nn::default_network();
    let slots = net.n_gemm_layers();
    let plans = [
        InferPlan::exact(slots),
        InferPlan::uniform(Some(Family::Proposed), 2, slots),
        InferPlan::uniform(Some(Family::Proposed), 4, slots),
        InferPlan::uniform(Some(Family::Proposed), 6, slots),
        InferPlan::mixed_default(slots),
    ];
    let c = Coordinator::new(CoordinatorConfig {
        workers: 4, backend: BackendKind::Lut, ..Default::default()
    });
    let batch = nn::eval_batch(batch_n);
    println!("== CNN network-level energy/accuracy (batch {batch_n}, \
              lut backend) ==");
    println!("  {:<20} | {:>10} {:>7} | {:>8} {:>6}",
             "plan", "energy µJ", "saving", "psnr dB", "top-1");
    let mut rows = Vec::new();
    let mut exact_fj = f64::NAN;
    for plan in &plans {
        let (_, st) = c.serve_nn(net, &batch, plan)
            .expect("pinned plans carry no SLO and cannot fail routing");
        if st.plan == "exact" {
            exact_fj = st.total_energy_fj;
        }
        let saving = (1.0 - st.total_energy_fj / exact_fj) * 100.0;
        println!("  {:<20} | {:>10.4} {:>6.1}% | {:>8.2} {:>5.0}%",
                 st.plan, st.total_energy_uj(), saving, st.logit_psnr_db,
                 st.top1_match * 100.0);
        let layers: Vec<Json> = st.layers.iter().map(|l| {
            Json::obj()
                .set("layer", Json::Str(l.name.into()))
                .set("point", Json::Str(l.point_label()))
                .set("m", Json::Int(l.m as i64))
                .set("kk", Json::Int(l.kk as i64))
                .set("nn", Json::Int(l.nn as i64))
                .set("macs", Json::Int(l.macs as i64))
                .set("energy_fj", Json::Num(l.energy_fj))
                .set("metered_macs", Json::Int(l.metered_macs as i64))
        }).collect();
        rows.push(Json::obj()
            .set("plan", Json::Str(st.plan.clone()))
            .set("total_energy_fj", Json::Num(st.total_energy_fj))
            .set("total_energy_uj", Json::Num(st.total_energy_uj()))
            .set("saving_vs_exact_pct", Json::Num(saving))
            .set("logit_psnr_db", Json::Num(st.logit_psnr_db))
            .set("top1_match", Json::Num(st.top1_match))
            .set("layers", Json::Arr(layers)));
    }
    c.shutdown();
    let doc = Json::obj()
        .set("schema", Json::Str("axsys-nn-report/v1".into()))
        .set("batch", Json::Int(batch_n as i64))
        .set("input_side", Json::Int(nn::INPUT_SIDE as i64))
        .set("n_classes", Json::Int(nn::N_CLASSES as i64))
        .set("gemm_layers",
             Json::Arr(net.gemm_layer_names().iter()
                 .map(|n| Json::Str((*n).into())).collect()))
        .set("see_also",
             Json::Str("ZOO_report.json (zoo-report: per-MAC \
                        design-point columns the SLO router reads)".into()))
        .set("plans", Json::Arr(rows));
    if let Err(e) = std::fs::write(&out, doc.pretty()) {
        eprintln!("cannot write {}: {e}", out.display());
        return 1;
    }
    println!("  wrote {}", out.display());
    0
}

/// Default artifact location for `energy-report`: repo root, next to
/// `BENCH_hotpath.json`.
fn energy_report_default_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .join("ENERGY_report.json")
}

/// Regenerate the paper's array-level energy savings table and a
/// Fig. 9-style accuracy-vs-energy scatter from the per-MAC model at
/// **real workload activity**: operand streams captured from the DCT and
/// edge pipelines (exact arithmetic, so every design replays the same
/// stream) instead of `hw::`'s random vectors. Writes a JSON artifact.
fn energy_report(rest: &[String]) -> i32 {
    use axsys::bench::Json;
    use axsys::energy;
    use axsys::error::exhaustive_metrics;
    let size: usize = opt(rest, "--size")
        .and_then(|v| v.parse().ok()).unwrap_or(64);
    let k: u32 = opt(rest, "--k").and_then(|v| v.parse().ok()).unwrap_or(7);
    if size % 8 != 0 || size < 16 || k == 0 || k > 8 {
        eprintln!("energy-report: --size multiple of 8 >= 16, --k 1..=8");
        return 2;
    }
    let out = opt(rest, "--out").map(PathBuf::from)
        .unwrap_or_else(energy_report_default_path);
    println!("energy-report: {size}x{size} DCT+edge workload streams, \
              approx k={k}, signed 8-bit");

    // operand chains from the real pipelines (one chain per sampled
    // output element; each design replays the identical stream)
    let mut chains = energy::dct_workload_chains(size, 160);
    chains.extend(energy::edge_workload_chains(size, 160));
    let macs: usize = chains.iter().map(|c| c.len()).sum();
    println!("  {} operand chains / {} MACs captured from the GEMM streams",
             chains.len(), macs);
    // the conventional MACs are stateless: same stream, flattened
    let flat_a: Vec<i64> = chains.iter().flatten().map(|p| p.0).collect();
    let flat_b: Vec<i64> = chains.iter().flatten().map(|p| p.1).collect();

    let pe_rows: Vec<(String, f64)> = {
        let mut rows = vec![
            ("Exact [6]".to_string(), energy::mean_mac_fj_chains(
                &Design::conventional_exact(8, Signedness::Signed), &chains)),
            ("Proposed exact".to_string(), energy::mean_mac_fj_chains(
                &Design::proposed_exact(8, Signedness::Signed), &chains)),
        ];
        for family in Family::ALL {
            let d = Design::approximate(8, Signedness::Signed, family, k);
            rows.push((format!("{} approx k={k}", family.paper_label()),
                       energy::mean_mac_fj_chains(&d, &chains)));
        }
        rows
    };
    let conv = ("Gemmini MAC [13]".to_string(),
                energy::conventional_mean_mac_fj(8, false, &flat_a, &flat_b));
    let hafsa = ("HA-FSA MAC [10]".to_string(),
                 energy::conventional_mean_mac_fj(8, true, &flat_a, &flat_b));
    let conv_arr = energy::array_fj_per_cycle(conv.1, 8, 8);
    let e6_arr = energy::array_fj_per_cycle(pe_rows[0].1, 8, 8);

    println!("== per-MAC energy at workload activity, 8x8 array composition ==");
    println!("  {:<22} {:>11} {:>16} {:>9} {:>12}", "design", "fJ/MAC",
             "8x8 fJ/cycle", "vs conv", "vs exact[6]");
    let mut json_rows = Vec::new();
    for (label, fj) in pe_rows.iter().chain([&conv, &hafsa]) {
        let arr = energy::array_fj_per_cycle(*fj, 8, 8);
        let vs_conv = (1.0 - arr / conv_arr) * 100.0;
        let vs_e6 = (1.0 - arr / e6_arr) * 100.0;
        println!("  {label:<22} {fj:>11.3} {arr:>16.1} {vs_conv:>8.1}% \
                  {vs_e6:>11.1}%");
        json_rows.push(Json::obj()
            .set("design", Json::Str(label.clone()))
            .set("mean_mac_fj", Json::Num(*fj))
            .set("array8_fj_per_cycle", Json::Num(arr))
            .set("saving_vs_conventional_pct", Json::Num(vs_conv))
            .set("saving_vs_exact6_pct", Json::Num(vs_e6)));
    }
    let prop_exact = pe_rows[1].1;
    let prop_apx = pe_rows[2].1;
    let s_exact =
        (1.0 - energy::array_fj_per_cycle(prop_exact, 8, 8) / conv_arr) * 100.0;
    let s_apx =
        (1.0 - energy::array_fj_per_cycle(prop_apx, 8, 8) / conv_arr) * 100.0;
    println!("== headline: proposed PEs vs conventional MAC, 8x8 array ==");
    println!("  exact savings  {s_exact:>5.1}%   (paper: ~22%)");
    println!("  approx savings {s_apx:>5.1}%   (paper: ~32%, k = N-1; \
              golden-pinned on a synthetic stream in tests/energy_model.rs)");

    // Fig. 9-style scatter: accuracy (NMED) vs energy per family
    println!("== accuracy vs energy (k={k}, signed 8-bit) ==");
    let mut scatter = Vec::new();
    for family in Family::ALL {
        let label = format!("{} approx k={k}", family.paper_label());
        let fj = pe_rows.iter()
            .find(|(l, _)| *l == label)
            .map(|(_, fj)| *fj)
            .unwrap_or_default();
        let em = exhaustive_metrics(&PeConfig::new(8, true, family, k));
        println!("  {:<12} {:>8.3} fJ/MAC   NMED {:.4}",
                 family.paper_label(), fj, em.nmed);
        scatter.push(Json::obj()
            .set("family", Json::Str(family.name().into()))
            .set("mean_mac_fj", Json::Num(fj))
            .set("nmed", Json::Num(em.nmed)));
    }

    // cross-check: table aggregation == direct netlist replay, exactly.
    // Degrades to a skip message (never a panic) if the point cannot
    // tabulate — the same unmetered-degradation contract the serving
    // workers follow for wide design points.
    let d2 = Design::approximate(8, Signedness::Signed, Family::Proposed, 2);
    match energy::cached_design(&d2) {
        Some(elut) => {
            let mut rep = energy::Replayer::new(&d2);
            for c in chains.iter().take(4) {
                assert_eq!(elut.chain_fj(c), rep.chain_fj(c),
                           "EnergyLut must equal direct replay exactly");
            }
            println!("  [cross-check] EnergyLut == netlist replay on \
                      sampled chains");
        }
        None => println!("  [cross-check] skipped: design point not \
                          tabulable (runs unmetered)"),
    }

    let doc = Json::obj()
        .set("schema", Json::Str("axsys-energy-report/v1".into()))
        .set("config", Json::obj()
            .set("size", Json::Int(size as i64))
            .set("k", Json::Int(k as i64))
            .set("chains", Json::Int(chains.len() as i64))
            .set("macs", Json::Int(macs as i64)))
        .set("designs", Json::Arr(json_rows))
        .set("headline", Json::obj()
            .set("exact_saving_vs_conventional_pct", Json::Num(s_exact))
            .set("approx_saving_vs_conventional_pct", Json::Num(s_apx))
            .set("paper_exact_pct", Json::Num(22.0))
            .set("paper_approx_pct", Json::Num(32.0)))
        .set("accuracy_vs_energy", Json::Arr(scatter));
    if let Err(e) = std::fs::write(&out, doc.pretty()) {
        eprintln!("cannot write {}: {e}", out.display());
        return 1;
    }
    println!("  wrote {}", out.display());
    0
}

fn serve(rest: &[String]) -> i32 {
    let backend = match opt(rest, "--backend") {
        Some(v) => match BackendKind::parse(&v) {
            Some(b) => b,
            None => {
                eprintln!("unknown backend '{v}' (expected {})",
                          BackendKind::names());
                return 2;
            }
        },
        None => BackendKind::Word,
    };
    let workers: usize = opt(rest, "--workers")
        .and_then(|v| v.parse().ok()).unwrap_or(4);
    // pin (or autotune) the GEMM blocking before the pool spins up: the
    // worker engines and the sw-tile fan-out geometry both read it
    if let Err(code) = pin_block_sizes(rest) {
        return code;
    }
    if let Err(code) = pin_sw_tile(rest, workers) {
        return code;
    }
    // size the fan-out drain budget from the measured metered kernel
    // rate, so metered and unmetered requests split identically
    let bm = axsys::coordinator::calibrate_batch_macs();
    println!("  batch-macs: {bm} (metered-kernel calibration)");
    if let Some(addr) = opt(rest, "--listen") {
        // network mode: expose this pool over the framed TCP protocol
        // instead of driving synthetic traffic at it
        return serve_listen(&addr, rest, backend, workers);
    }
    let requests: usize = opt(rest, "--requests")
        .and_then(|v| v.parse().ok()).unwrap_or(64);
    let k = opt_k(rest);
    let app = opt(rest, "--app").unwrap_or_else(|| "gemm".into());
    // validate the app name before spawning the worker pool
    let kind = if app == "gemm" {
        None
    } else {
        match AppKind::parse(&app) {
            Some(kind) => Some(kind),
            None => {
                eprintln!("unknown app '{app}' (expected gemm|{})",
                          AppKind::names());
                return 2;
            }
        }
    };
    // accuracy SLO for the synthetic mix: parsed (and refused with exit
    // code 2) before the pool spins up, routed per request below
    let slo = match opt(rest, "--slo") {
        Some(spec) => match axsys::zoo::AccuracySlo::parse(&spec) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("serve: bad --slo '{spec}': {e}");
                return 2;
            }
        },
        None => None,
    };
    println!("serve: backend={backend:?} workers={workers} requests={requests} \
              k={k} app={app}");
    let c = Coordinator::new(CoordinatorConfig {
        workers, backend, ..Default::default()
    });
    if let Some(kind) = kind {
        let code = serve_apps(&c, kind, requests, k);
        c.shutdown();
        return code;
    }
    let mut seed = 1u64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        seed
    };
    let t0 = std::time::Instant::now();
    let mut ids = Vec::new();
    for _ in 0..requests {
        let m = 8 + (rnd() % 57) as usize;
        let kk = 8 + (rnd() % 25) as usize;
        let nn = 8 + (rnd() % 57) as usize;
        let a: Vec<i64> = (0..m * kk).map(|_| (rnd() as i64 & 255) - 128).collect();
        let b: Vec<i64> = (0..kk * nn).map(|_| (rnd() as i64 & 255) - 128).collect();
        let req = GemmRequest { a, b, m, kk, nn, k, slo, ..Default::default() };
        match c.try_submit(req) {
            Ok(id) => ids.push(id),
            Err(e) => {
                // unsatisfiable against this pool: refuse the whole run
                // (typed, never a silent exact fallback)
                eprintln!("serve: {e}");
                c.shutdown();
                return 2;
            }
        }
    }
    for id in ids {
        c.wait(id);
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = c.stats_snapshot();
    println!("  {} requests in {:.3}s  ({:.1} req/s, {:.1} tiles/s)",
             s.requests, wall, s.requests as f64 / wall, s.tiles as f64 / wall);
    println!("  latency: mean {:.1} µs  max {:.1} µs",
             s.mean_latency_us(), s.max_latency_us);
    if s.lut_macs > 0 {
        println!("  lut: {} MACs table-served, {} tables built, {} cache hits",
                 s.lut_macs, s.lut_builds, s.lut_cache_hits);
    }
    if s.sim_cycles > 0 {
        println!("  simulated: {} cycles, {} MACs", s.sim_cycles, s.sim_macs);
    }
    if s.metered_macs > 0 {
        println!("  energy: {:.3} µJ metered ({:.2} fJ/MAC over {} of {} MACs, \
                  data-dependent model)",
                 s.total_energy_uj(), s.mean_mac_fj(), s.metered_macs,
                 s.sim_macs);
    }
    if let Some(slo) = &slo {
        println!("  slo '{slo}': {} routed ({} exact, {} unsatisfiable); \
                  tiers exact/high/mid/low = {:?}",
                 s.slo_requests, s.slo_exact, s.slo_unsatisfiable, s.slo_tier);
    }
    c.shutdown();
    0
}

/// `serve --listen ADDR`: front the coordinator with the framed TCP
/// server and run until killed. Binding port 0 picks an ephemeral port;
/// `--port-file` writes the bound address for scripts (the CI loopback
/// smoke uses it to find the port before launching `loadgen`).
fn serve_listen(addr: &str, rest: &[String], backend: BackendKind,
                workers: usize) -> i32 {
    use axsys::net::server::{NetServer, ServerConfig};
    let mut scfg = ServerConfig::default();
    if let Some(v) = opt(rest, "--max-inflight").and_then(|v| v.parse().ok()) {
        scfg.max_inflight = v;
    }
    if let Some(v) = opt(rest, "--shards").and_then(|v| v.parse().ok()) {
        scfg.shards = v; // 0 keeps the auto-sizing
    }
    // BDCN weights are optional: without the artifact, `bdcn` requests
    // get a typed Unsupported reply instead of a refusal to start
    scfg.bdcn = axsys::apps::bdcn::load_weights(
        &Runtime::default_artifacts_dir().join("bdcn_weights.txt"))
        .ok()
        .map(Arc::new);
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers, backend, ..Default::default()
    }));
    let server = match NetServer::bind(addr, coord, scfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: cannot listen on {addr}: {e}");
            return 1;
        }
    };
    println!("serve: listening on {} (backend={backend:?} workers={workers}; \
              stop with Ctrl-C)", server.local_addr());
    if let Some(pf) = opt(rest, "--port-file") {
        if let Err(e) = std::fs::write(&pf, format!("{}\n", server.local_addr())) {
            eprintln!("serve: cannot write {pf}: {e}");
            return 1;
        }
        println!("  wrote bound address to {pf}");
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `loadgen`: drive a live `serve --listen` server with the seeded
/// multi-client mix and write the `BENCH_serve_net.json` artifact.
/// `--conns` switches to connection-scale mode: thousands of concurrent
/// connections with tagged replies verified byte-for-byte.
fn loadgen(rest: &[String]) -> i32 {
    use axsys::net::loadgen::{self, LoadgenConfig, ScaleConfig};
    let Some(addr) = opt(rest, "--addr") else {
        eprintln!("loadgen: --addr HOST:PORT is required (start a server \
                   with `axsys serve --listen 127.0.0.1:0`)");
        return 2;
    };
    if let Some(conns) = opt(rest, "--conns").and_then(|v| v.parse().ok()) {
        let mut scfg = ScaleConfig::new(addr);
        scfg.conns = conns;
        if let Some(v) = opt(rest, "--per-conn").and_then(|v| v.parse().ok()) {
            scfg.per_conn = v;
        }
        if let Some(v) = opt(rest, "--threads").and_then(|v| v.parse().ok()) {
            scfg.threads = v;
        }
        if scfg.conns == 0 || scfg.per_conn == 0 {
            eprintln!("loadgen: --conns/--per-conn >= 1");
            return 2;
        }
        let out = opt(rest, "--out").map(PathBuf::from)
            .unwrap_or_else(loadgen::default_path);
        println!("loadgen: addr={} conns={} per-conn={} (scale mode)",
                 scfg.addr, scfg.conns, scfg.per_conn);
        return match loadgen::run_scale(&scfg) {
            Ok(doc) => {
                if let Err(e) = std::fs::write(&out, doc.pretty()) {
                    eprintln!("cannot write {}: {e}", out.display());
                    return 1;
                }
                println!("  wrote {}", out.display());
                0
            }
            Err(e) => {
                eprintln!("loadgen: {e}");
                1
            }
        };
    }
    let mut cfg = LoadgenConfig::new(addr);
    if let Some(v) = opt(rest, "--clients").and_then(|v| v.parse().ok()) {
        cfg.clients = v;
    }
    if let Some(v) = opt(rest, "--requests").and_then(|v| v.parse().ok()) {
        cfg.requests = v;
    }
    if let Some(v) = opt(rest, "--k").and_then(|v| v.parse().ok()) {
        cfg.k_max = v;
    }
    if let Some(v) = opt(rest, "--seed").and_then(|v| v.parse().ok()) {
        cfg.seed = v;
    }
    if rest.iter().any(|a| a == "--gemm-only") {
        cfg.apps = false;
    }
    if let Some(spec) = opt(rest, "--slo") {
        match axsys::zoo::AccuracySlo::parse(&spec) {
            Ok(s) => cfg.slo = Some(s),
            Err(e) => {
                eprintln!("loadgen: bad --slo '{spec}': {e}");
                return 2;
            }
        }
    }
    if cfg.clients == 0 || cfg.requests == 0 || cfg.k_max > 8 {
        eprintln!("loadgen: --clients/--requests >= 1, --k 0..=8");
        return 2;
    }
    let out = opt(rest, "--out").map(PathBuf::from)
        .unwrap_or_else(loadgen::default_path);
    println!("loadgen: addr={} clients={} requests={} k<={} apps={}",
             cfg.addr, cfg.clients, cfg.requests, cfg.k_max, cfg.apps);
    match loadgen::run(&cfg) {
        Ok(doc) => {
            if let Err(e) = std::fs::write(&out, doc.pretty()) {
                eprintln!("cannot write {}: {e}", out.display());
                return 1;
            }
            println!("  wrote {}", out.display());
            0
        }
        Err(e) => {
            eprintln!("loadgen: {e}");
            1
        }
    }
}

/// Drive `requests` application requests (deterministic mixed image set)
/// through the coordinator's app endpoints and report the per-app
/// counters + GEMM-level latency percentiles from `ServiceStats`.
fn serve_apps(c: &Coordinator, kind: AppKind, requests: usize, k: u32) -> i32 {
    let blocks = if kind == AppKind::Bdcn {
        let weights = Runtime::default_artifacts_dir().join("bdcn_weights.txt");
        match axsys::apps::bdcn::load_weights(&weights) {
            Ok(b) => Some(b),
            Err(e) => {
                eprintln!("cannot load {}: {e:#} (run `make artifacts`)",
                          weights.display());
                return 1;
            }
        }
    } else {
        None
    };
    let t0 = std::time::Instant::now();
    for r in 0..requests {
        // mixed deterministic workload: structured scenes + LCG textures
        // (multiples of 8 so every image is DCT-blockable)
        let img = match r % 3 {
            0 => scene(96, 96),
            1 => texture(64, 128, 0xA150 + r as u64),
            _ => scene(64, 64),
        };
        let resp = match kind {
            AppKind::Bdcn => c.serve_bdcn(blocks.as_ref().unwrap(), &img, k),
            _ => c.call_app(kind, &img, k).expect("weight-free app"),
        };
        if r == 0 {
            println!("  first response: {}x{} map, PSNR {:.2} dB, \
                      {} GEMM sub-requests",
                     resp.out.h, resp.out.w, resp.psnr_db, resp.gemm_requests);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = c.stats_snapshot();
    let a = s.app(kind);
    println!("  {} {} requests in {:.3}s ({:.1} req/s)",
             a.requests, kind.name(), wall, a.requests as f64 / wall);
    println!("  app latency: mean {:.1} µs  max {:.1} µs  | mean quality \
              {:.2} dB over {} finite samples",
             a.mean_latency_us(), a.max_latency_us, a.mean_psnr_db(),
             a.psnr_samples);
    if s.metered_macs > 0 {
        println!("  app energy: {:.3} µJ/image ({:.2} fJ/MAC fleet-wide)",
                 a.mean_energy_uj(), s.mean_mac_fj());
    }
    println!("  gemm sub-requests: {} ({} tiles); latency p50 {:.1} µs  \
              p90 {:.1} µs  p99 {:.1} µs",
             a.gemm_requests, s.tiles,
             s.latency_percentile(0.50), s.latency_percentile(0.90),
             s.latency_percentile(0.99));
    if s.lut_macs > 0 {
        println!("  lut: {} MACs table-served, {} tables built, {} cache hits",
                 s.lut_macs, s.lut_builds, s.lut_cache_hits);
    }
    0
}

/// Paper §V quality tables (Table VI pattern): sweep every cell family x
/// approximation level through the coordinator-served pipelines.
fn apps_report(rest: &[String]) -> i32 {
    let backend = match opt(rest, "--backend") {
        Some(v) => match BackendKind::parse(&v) {
            Some(b) => b,
            None => {
                eprintln!("unknown backend '{v}' (expected {})",
                          BackendKind::names());
                return 2;
            }
        },
        None => BackendKind::Lut,
    };
    let size: usize = opt(rest, "--size")
        .and_then(|v| v.parse().ok()).unwrap_or(128);
    if size % 8 != 0 || size < 16 {
        eprintln!("--size must be a multiple of 8, >= 16");
        return 2;
    }
    let img = scene(size, size);
    let weights = Runtime::default_artifacts_dir().join("bdcn_weights.txt");
    let blocks = axsys::apps::bdcn::load_weights(&weights).ok();
    println!("apps-report: {size}x{size} scene, backend={backend:?} \
              (all GEMMs through the coordinator)");
    println!("{:<12} {:>2} | {:>13} {:>13} | {:>13} {}", "family", "k",
             "dct vs-input", "dct vs-exact", "edge vs-exact",
             if blocks.is_some() { "| bdcn vs-exact" } else { "" });
    // exact DCT reference once up front: k=0 is family-independent, so
    // every family row compares against the same served reconstruction
    let exact = {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 4, backend, ..Default::default()
        });
        let r = c.serve_dct(&img, 0);
        c.shutdown();
        r
    };
    for family in Family::ALL {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 4, backend, family, ..Default::default()
        });
        for k in [2u32, 4, 5, 6] {
            let d = c.serve_dct(&img, k);
            let e = c.serve_edge(&img, k);
            let dct_vs_exact = psnr(&exact.out.data, &d.out.data);
            print!("{:<12} {:>2} | {:>10.2} dB {:>10.2} dB | {:>10.2} dB",
                   family.name(), k, d.psnr_db, dct_vs_exact, e.psnr_db);
            match &blocks {
                Some(b) => {
                    let r = c.serve_bdcn(b, &img, k);
                    println!(" | {:>10.2} dB", r.psnr_db);
                }
                None => println!(),
            }
        }
        let s = c.stats_snapshot();
        println!("{:<12}    | {} app requests, {} gemm sub-requests, \
                  gemm p99 {:.1} µs",
                 "", s.dct.requests + s.edge.requests + s.bdcn.requests,
                 s.dct.gemm_requests + s.edge.gemm_requests
                     + s.bdcn.gemm_requests,
                 s.latency_percentile(0.99));
        c.shutdown();
    }
    println!("(dct vs-input at k=5 and edge vs-exact at k=4 are the paper's \
              38.21 / 30.45 dB headline metrics — pinned on golden images \
              in rust/tests/golden_psnr.rs)");
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    const CLI_BEGIN: &str =
        "<!-- CLI:BEGIN (generated by `cargo run --release -- help --markdown`) -->";
    const CLI_END: &str = "<!-- CLI:END -->";

    /// The README's CLI table is generated from [`COMMANDS`]; this test
    /// is the drift guard. On failure, re-run
    /// `cargo run --release -- help --markdown` and paste the output
    /// between the markers in README.md.
    #[test]
    fn readme_cli_table_matches_generated_markdown() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md");
        let readme = std::fs::read_to_string(path).expect("README.md");
        let begin = readme.find(CLI_BEGIN)
            .expect("README.md is missing the CLI:BEGIN marker");
        let end = readme.find(CLI_END)
            .expect("README.md is missing the CLI:END marker");
        let block = readme[begin + CLI_BEGIN.len()..end].trim();
        assert_eq!(block, help_markdown().trim(),
                   "README CLI table drifted from main.rs COMMANDS — \
                    regenerate with `cargo run --release -- help --markdown`");
    }

    #[test]
    fn advertised_flag_sets_come_from_the_parsers() {
        // the serve row must advertise exactly what BackendKind/AppKind
        // parse — the substitution, not a hand-written copy (pipes are
        // escaped for the GFM table, so compare the escaped form)
        let md = help_markdown();
        let esc = |s: String| s.replace('|', "\\|");
        assert!(md.contains(&esc(BackendKind::names())), "{md}");
        assert!(md.contains(&esc(AppKind::names())), "{md}");
        assert!(!md.contains("{BACKENDS}") && !md.contains("{APPS}"),
                "unexpanded placeholder: {md}");
        // every dispatched command is documented and vice versa
        for name in ["selftest", "hw-report", "error-sweep", "dct", "edge",
                     "cnn", "infer", "serve", "loadgen", "apps-report",
                     "lut-report", "zoo-report", "nn-report",
                     "energy-report", "bench-report", "emit-verilog",
                     "help"] {
            assert!(COMMANDS.iter().any(|c| c.name == name),
                    "{name} missing from COMMANDS");
        }
        assert_eq!(COMMANDS.len(), 17, "new commands must be dispatched too");
    }
}
