//! The design-point zoo: a registry of approximate-multiplier variants
//! with oracle-derived energy/error columns, and the accuracy-SLO router
//! that makes approximation a negotiated service property.
//!
//! # Registry
//!
//! [`registry`] enumerates every servable design point — the paper's
//! proposed PPC/NPPC family across `k = 0..=n` plus the two zoo variants
//! ([`Family::Trunc`], [`Family::Loa`]) expressed in the same cell grid —
//! each carrying a [`DesignEntry`] computed **once** (then cached for the
//! process lifetime) from the existing machinery:
//!
//! * `nmed` / `mred` / `max_ed` — [`crate::error::exhaustive_metrics`],
//!   the paper's Table V sweep (all operand pairs, single MAC). Pinned
//!   against the Python oracle in `tests/zoo_goldens.rs` (generator:
//!   `python/compile/kernels/zoo_goldens.py`).
//! * `mean_mac_fj` — gate-netlist activity replay
//!   ([`crate::energy::mean_mac_fj_chains`]) over a fixed seeded operand
//!   stream, so every entry is metered on the *same* traffic.
//! * `psnr_dct` / `psnr_edge` — the §V application pipelines run at the
//!   design point vs the exact-arithmetic result (`f64::INFINITY` for
//!   exact entries, as the paper reports).
//!
//! `loa` is registered from `k = 2`: at `k = 1` the OR-fold is
//! single-MAC exact (zero exhaustive NMED) while it still errs under
//! chained accumulation, so registering it would let the router
//! silently degrade requests that asked for exact arithmetic.
//!
//! # Routing
//!
//! An [`AccuracySlo`] is an upper bound on NMED and/or a lower bound on
//! application PSNR. [`route`] picks the **cheapest** (lowest
//! `mean_mac_fj`) registered entry satisfying every stated bound for the
//! pool's word shape; an SLO no entry satisfies is a typed
//! [`RouteError::Unsatisfiable`] — never a silent fallback in either
//! direction. The coordinator threads the routed design point through
//! request execution ([`crate::coordinator::GemmRequest::slo`]), the wire
//! protocol carries it end-to-end (`net::proto`), and `zoo-report` emits
//! the energy-per-accuracy-tier table.

use std::fmt;
use std::sync::OnceLock;

use crate::apps::image::{psnr, scene};
use crate::apps::{dct, edge, WordGemm};
use crate::bench::{xorshift_ints, Json};
use crate::error::exhaustive_metrics;
use crate::pe::word::PeConfig;
use crate::pe::{Design, Signedness};
use crate::Family;

/// Operand width every registry entry is built at (the paper's setting;
/// the only width the error/energy oracles pin exhaustively).
pub const ZOO_N_BITS: u32 = 8;

/// Side of the deterministic scene the PSNR columns are computed on.
const PSNR_SIDE: usize = 32;

/// Accuracy tier of a design point, by exhaustive NMED. Tier counters in
/// `ServiceStats`/`NetStats` aggregate routed traffic per tier.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Tier {
    /// Bit-exact arithmetic (NMED = 0).
    Exact,
    /// NMED ≤ 2.5e-4 — visually lossless on the §V pipelines.
    High,
    /// NMED ≤ 2.5e-3 — the paper's headline operating region.
    Mid,
    /// Everything deeper.
    Low,
}

impl Tier {
    /// Every tier, strictest first.
    pub const ALL: [Tier; 4] = [Tier::Exact, Tier::High, Tier::Mid, Tier::Low];

    /// Tier of an exhaustive-NMED value.
    pub fn of(nmed: f64) -> Tier {
        if nmed == 0.0 {
            Tier::Exact
        } else if nmed <= 2.5e-4 {
            Tier::High
        } else if nmed <= 2.5e-3 {
            Tier::Mid
        } else {
            Tier::Low
        }
    }

    /// Stable lower-case name (stats keys, report columns).
    pub fn name(self) -> &'static str {
        match self {
            Tier::Exact => "exact",
            Tier::High => "high",
            Tier::Mid => "mid",
            Tier::Low => "low",
        }
    }

    /// Index into per-tier counter arrays (`Tier::ALL` order).
    #[inline]
    pub fn idx(self) -> usize {
        match self {
            Tier::Exact => 0,
            Tier::High => 1,
            Tier::Mid => 2,
            Tier::Low => 3,
        }
    }
}

/// One registered design point with its oracle-derived service columns.
#[derive(Clone, Copy, Debug)]
pub struct DesignEntry {
    /// The hardware design point (8-bit signed for every zoo entry).
    pub design: Design,
    /// Mean per-MAC replay energy (fJ) over the fixed seeded stream.
    pub mean_mac_fj: f64,
    /// Exhaustive single-MAC NMED (Table V setting).
    pub nmed: f64,
    /// Exhaustive single-MAC MRED.
    pub mred: f64,
    /// Worst-case single-MAC error distance.
    pub max_ed: u64,
    /// DCT-pipeline PSNR vs exact arithmetic (dB, `inf` when exact).
    pub psnr_dct: f64,
    /// Edge-pipeline PSNR vs exact arithmetic (dB, `inf` when exact).
    pub psnr_edge: f64,
}

impl DesignEntry {
    /// Accuracy tier of this entry.
    pub fn tier(&self) -> Tier {
        Tier::of(self.nmed)
    }

    /// Stable label, e.g. `proposed/k4` (CLI tables, stats keys).
    pub fn label(&self) -> String {
        format!("{}/k{}", self.design.family.name(), self.design.k)
    }

    /// Worst application PSNR across the two pipeline columns — the
    /// value a `min_psnr_db` bound is checked against.
    pub fn psnr_floor(&self) -> f64 {
        self.psnr_dct.min(self.psnr_edge)
    }

    /// Does this entry satisfy every bound the SLO states?
    pub fn satisfies(&self, slo: &AccuracySlo) -> bool {
        if let Some(mx) = slo.max_nmed {
            if self.nmed > mx {
                return false;
            }
        }
        if let Some(mn) = slo.min_psnr_db {
            if self.psnr_floor() < mn {
                return false;
            }
        }
        true
    }
}

fn entry_for(design: Design) -> DesignEntry {
    let cfg = PeConfig::from_design(&design);
    let em = exhaustive_metrics(&cfg);
    // fixed seeded operand stream: every entry metered on the same
    // traffic (8 chains x 48 MACs of full-range signed operands)
    let chains: Vec<Vec<(i64, i64)>> = (0..8u64)
        .map(|c| {
            let a = xorshift_ints(0xA5_000 + c, 48);
            let b = xorshift_ints(0xB0_000 + c, 48);
            a.into_iter().zip(b).collect()
        })
        .collect();
    let mean_mac_fj = crate::energy::mean_mac_fj_chains(&design, &chains);
    let img = scene(PSNR_SIDE, PSNR_SIDE);
    let run_dct = |c: PeConfig| dct::pipeline(&mut WordGemm { cfg: c }, &img).0;
    let run_edge = |c: PeConfig| edge::pipeline(&mut WordGemm { cfg: c }, &img);
    let exact = PeConfig::new(design.n, design.is_signed(), design.family, 0);
    let psnr_dct = psnr(&run_dct(exact).data, &run_dct(cfg).data);
    let psnr_edge = psnr(&run_edge(exact).data, &run_edge(cfg).data);
    DesignEntry {
        design,
        mean_mac_fj,
        nmed: em.nmed,
        mred: em.mred,
        max_ed: em.max_ed,
        psnr_dct,
        psnr_edge,
    }
}

/// Every registered design point, cheapest-last not guaranteed — the
/// order is (family, k) as documented in the module header. Built once
/// per process (exhaustive sweeps + netlist replay + two pipelines per
/// entry) and cached.
pub fn registry() -> &'static [DesignEntry] {
    static REG: OnceLock<Vec<DesignEntry>> = OnceLock::new();
    REG.get_or_init(|| {
        let s = Signedness::Signed;
        let mut entries =
            vec![entry_for(Design::proposed_exact(ZOO_N_BITS, s))];
        for k in 1..=ZOO_N_BITS {
            entries.push(entry_for(Design::approximate(
                ZOO_N_BITS, s, Family::Proposed, k)));
        }
        for k in 1..=ZOO_N_BITS {
            entries.push(entry_for(Design::approximate(
                ZOO_N_BITS, s, Family::Trunc, k)));
        }
        // loa starts at k = 2 (see module header)
        for k in 2..=ZOO_N_BITS {
            entries.push(entry_for(Design::approximate(
                ZOO_N_BITS, s, Family::Loa, k)));
        }
        entries
    })
}

/// A per-request accuracy service-level objective: an upper bound on
/// exhaustive NMED and/or a lower bound on application PSNR (dB). At
/// least one bound must be stated.
#[derive(Clone, Copy, PartialEq, Debug, Default)]
pub struct AccuracySlo {
    /// Maximum acceptable exhaustive NMED (0 demands exact arithmetic).
    pub max_nmed: Option<f64>,
    /// Minimum acceptable application PSNR in dB (checked against the
    /// worst of the registry's two pipeline columns).
    pub min_psnr_db: Option<f64>,
}

impl AccuracySlo {
    /// SLO demanding bit-exact arithmetic.
    pub fn exact() -> AccuracySlo {
        AccuracySlo { max_nmed: Some(0.0), min_psnr_db: None }
    }

    /// No bounds stated? (An empty SLO is invalid to route.)
    pub fn is_empty(&self) -> bool {
        self.max_nmed.is_none() && self.min_psnr_db.is_none()
    }

    /// Structural validity: at least one bound, every bound finite and
    /// in range (`max_nmed >= 0`, `min_psnr_db > 0`).
    pub fn validate(&self) -> Result<(), RouteError> {
        if self.is_empty() {
            return Err(RouteError::Invalid(
                "SLO states no bound (need max_nmed and/or min_psnr_db)"
                    .into(),
            ));
        }
        if let Some(v) = self.max_nmed {
            if !v.is_finite() || v < 0.0 {
                return Err(RouteError::Invalid(format!(
                    "max_nmed must be finite and >= 0, got {v}")));
            }
        }
        if let Some(v) = self.min_psnr_db {
            if !v.is_finite() || v <= 0.0 {
                return Err(RouteError::Invalid(format!(
                    "min_psnr_db must be finite and > 0, got {v}")));
            }
        }
        Ok(())
    }

    /// Parse the CLI/loadgen form: comma-separated `nmed=<f64>` /
    /// `psnr=<f64>` clauses, e.g. `nmed=1e-3`, `psnr=35`,
    /// `nmed=1e-3,psnr=35`.
    pub fn parse(s: &str) -> Result<AccuracySlo, RouteError> {
        let mut slo = AccuracySlo::default();
        for clause in s.split(',') {
            let clause = clause.trim();
            let (key, val) = clause.split_once('=').ok_or_else(|| {
                RouteError::Invalid(format!(
                    "SLO clause `{clause}` is not key=value"))
            })?;
            let num: f64 = val.trim().parse().map_err(|_| {
                RouteError::Invalid(format!(
                    "SLO clause `{clause}`: `{val}` is not a number"))
            })?;
            match key.trim() {
                "nmed" => slo.max_nmed = Some(num),
                "psnr" => slo.min_psnr_db = Some(num),
                other => {
                    return Err(RouteError::Invalid(format!(
                        "unknown SLO key `{other}` (want nmed/psnr)")))
                }
            }
        }
        slo.validate()?;
        Ok(slo)
    }
}

impl fmt::Display for AccuracySlo {
    // renders as the parse() form, so Display -> parse round-trips
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        if let Some(v) = self.max_nmed {
            write!(f, "nmed={v}")?;
            first = false;
        }
        if let Some(v) = self.min_psnr_db {
            if !first {
                write!(f, ",")?;
            }
            write!(f, "psnr={v}")?;
        }
        if self.is_empty() {
            write!(f, "(empty)")?;
        }
        Ok(())
    }
}

/// Why a request's SLO could not be routed. Returned typed — the
/// coordinator and the wire protocol both refuse rather than silently
/// degrade or silently promote.
#[derive(Clone, PartialEq, Debug)]
pub enum RouteError {
    /// The SLO itself is malformed (empty, non-finite, out of range).
    Invalid(String),
    /// No registered design point for this word shape satisfies the SLO.
    Unsatisfiable {
        /// The SLO that could not be met.
        slo: AccuracySlo,
        /// Operand width the pool serves.
        n_bits: u32,
        /// Signedness the pool serves.
        signed: bool,
    },
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::Invalid(msg) => write!(f, "invalid SLO: {msg}"),
            RouteError::Unsatisfiable { slo, n_bits, signed } => write!(
                f,
                "unsatisfiable SLO `{slo}`: no registered design point \
                 for n={n_bits} signed={signed} meets it"
            ),
        }
    }
}

impl std::error::Error for RouteError {}

/// Cheapest entry among `entries` satisfying `slo` (the selection core
/// [`route`] applies to the registry; exposed so the property fuzz can
/// drive it over arbitrary subsets). Ties on energy break toward lower
/// NMED, then lower `k` — fully deterministic.
pub fn route_among<'a>(
    entries: impl IntoIterator<Item = &'a DesignEntry>,
    slo: &AccuracySlo,
) -> Option<&'a DesignEntry> {
    entries
        .into_iter()
        .filter(|e| e.satisfies(slo))
        .min_by(|a, b| {
            a.mean_mac_fj
                .total_cmp(&b.mean_mac_fj)
                .then(a.nmed.total_cmp(&b.nmed))
                .then(a.design.k.cmp(&b.design.k))
        })
}

/// Route an SLO for a pool serving `n_bits`/`signed` words: the cheapest
/// registered design point of that word shape meeting every bound.
///
/// Errors are typed: a malformed SLO is [`RouteError::Invalid`], an SLO
/// nothing satisfies (including any SLO against a word shape the
/// registry does not cover — only 8-bit signed is registered) is
/// [`RouteError::Unsatisfiable`]. No silent fallback happens in either
/// direction: a satisfiable SLO may route *to* the exact point (it
/// satisfies everything), but an unsatisfiable one never silently runs
/// exact — the caller decides.
pub fn route(
    n_bits: u32,
    signed: bool,
    slo: &AccuracySlo,
) -> Result<&'static DesignEntry, RouteError> {
    slo.validate()?;
    let shape = registry().iter().filter(|e| {
        e.design.n == n_bits && e.design.is_signed() == signed
    });
    route_among(shape, slo).ok_or(RouteError::Unsatisfiable {
        slo: *slo,
        n_bits,
        signed,
    })
}

/// The `zoo-report` document: every entry's columns plus per-tier
/// cheapest-point summary (`axsys zoo-report` writes this JSON and
/// prints the table form).
pub fn report_json() -> Json {
    let reg = registry();
    let exact_fj = reg
        .iter()
        .find(|e| e.tier() == Tier::Exact)
        .map(|e| e.mean_mac_fj)
        .unwrap_or(f64::NAN);
    let entries = reg
        .iter()
        .map(|e| {
            Json::obj()
                .set("family", Json::Str(e.design.family.name().into()))
                .set("k", Json::Int(e.design.k as i64))
                .set("tier", Json::Str(e.tier().name().into()))
                .set("mean_mac_fj", Json::Num(e.mean_mac_fj))
                .set("nmed", Json::Num(e.nmed))
                .set("mred", Json::Num(e.mred))
                .set("max_ed", Json::Int(e.max_ed as i64))
                .set("psnr_dct_db", Json::Num(e.psnr_dct))
                .set("psnr_edge_db", Json::Num(e.psnr_edge))
                .set("saving_vs_exact_pct",
                     Json::Num((1.0 - e.mean_mac_fj / exact_fj) * 100.0))
        })
        .collect();
    let tiers = Tier::ALL
        .iter()
        .map(|&t| {
            let cheapest = route_among(
                reg.iter().filter(|e| e.tier() == t),
                &AccuracySlo { max_nmed: Some(f64::MAX), min_psnr_db: None },
            );
            let mut o = Json::obj()
                .set("tier", Json::Str(t.name().into()))
                .set("entries",
                     Json::Int(reg.iter()
                         .filter(|e| e.tier() == t).count() as i64));
            if let Some(c) = cheapest {
                o = o
                    .set("cheapest", Json::Str(c.label()))
                    .set("cheapest_mean_mac_fj", Json::Num(c.mean_mac_fj))
                    .set("saving_vs_exact_pct",
                         Json::Num((1.0 - c.mean_mac_fj / exact_fj) * 100.0));
            }
            o
        })
        .collect();
    Json::obj()
        .set("schema", Json::Str("axsys-zoo-report/v1".into()))
        .set("n_bits", Json::Int(ZOO_N_BITS as i64))
        .set("signed", Json::Bool(true))
        .set("psnr_scene_side", Json::Int(PSNR_SIDE as i64))
        // per-MAC columns rank single design points; for *per-layer*
        // mixed plans on conv traffic the network-level report is
        // authoritative (`axsys nn-report` -> NN_report.json), so the
        // two artifacts never silently disagree about "cheapest"
        .set("see_also",
             Json::Str("NN_report.json (nn-report: network-level \
                        per-layer mixed-plan energy/accuracy)".into()))
        .set("entries", Json::Arr(entries))
        .set("tiers", Json::Arr(tiers))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_shape_and_exact_point() {
        let reg = registry();
        assert_eq!(reg.len(), 9 + 8 + 7);
        let exact: Vec<_> =
            reg.iter().filter(|e| e.tier() == Tier::Exact).collect();
        assert_eq!(exact.len(), 1, "exactly one exact entry");
        let e = exact[0];
        assert_eq!(e.design.k, 0);
        assert_eq!(e.nmed, 0.0);
        assert_eq!(e.max_ed, 0);
        assert!(e.psnr_dct.is_infinite() && e.psnr_edge.is_infinite());
    }

    #[test]
    fn error_monotone_within_each_family() {
        let reg = registry();
        for family in [Family::Proposed, Family::Trunc, Family::Loa] {
            let mut prev = -1.0;
            for e in reg.iter().filter(|e| e.design.family == family) {
                assert!(e.nmed >= prev, "{} nmed regressed", e.label());
                prev = e.nmed;
            }
        }
    }

    #[test]
    fn route_exact_slo_picks_the_exact_point() {
        let e = route(8, true, &AccuracySlo::exact()).unwrap();
        assert_eq!(e.nmed, 0.0);
        assert_eq!(e.design.k, 0);
    }

    #[test]
    fn route_is_cheapest_satisfying() {
        let slo = AccuracySlo { max_nmed: Some(1e-3), min_psnr_db: None };
        let got = route(8, true, &slo).unwrap();
        for e in registry() {
            if e.satisfies(&slo) {
                assert!(got.mean_mac_fj <= e.mean_mac_fj,
                        "{} cheaper than routed {}", e.label(), got.label());
            }
        }
        assert!(got.nmed <= 1e-3);
    }

    #[test]
    fn unsupported_word_shape_is_typed_unsatisfiable() {
        let slo = AccuracySlo { max_nmed: Some(1.0), min_psnr_db: None };
        match route(16, true, &slo) {
            Err(RouteError::Unsatisfiable { n_bits: 16, .. }) => {}
            other => panic!("want Unsatisfiable, got {other:?}"),
        }
        match route(8, false, &slo) {
            Err(RouteError::Unsatisfiable { signed: false, .. }) => {}
            other => panic!("want Unsatisfiable, got {other:?}"),
        }
    }

    #[test]
    fn empty_and_malformed_slos_are_invalid() {
        assert!(matches!(route(8, true, &AccuracySlo::default()),
                         Err(RouteError::Invalid(_))));
        let bad = AccuracySlo { max_nmed: Some(-1.0), min_psnr_db: None };
        assert!(matches!(route(8, true, &bad), Err(RouteError::Invalid(_))));
        let nan = AccuracySlo { max_nmed: Some(f64::NAN), min_psnr_db: None };
        assert!(matches!(route(8, true, &nan), Err(RouteError::Invalid(_))));
    }

    #[test]
    fn slo_parse_round_trips() {
        let slo = AccuracySlo::parse("nmed=1e-3,psnr=35").unwrap();
        assert_eq!(slo.max_nmed, Some(1e-3));
        assert_eq!(slo.min_psnr_db, Some(35.0));
        let back = AccuracySlo::parse(&slo.to_string()).unwrap();
        assert_eq!(back, slo);
        assert!(AccuracySlo::parse("nmed=abc").is_err());
        assert!(AccuracySlo::parse("qps=9").is_err());
        assert!(AccuracySlo::parse("").is_err());
    }

    #[test]
    fn report_covers_every_entry() {
        let doc = report_json();
        if let Json::Obj(fields) = &doc {
            let entries = fields.iter().find(|(k, _)| k == "entries");
            match entries {
                Some((_, Json::Arr(a))) => {
                    assert_eq!(a.len(), registry().len())
                }
                _ => panic!("entries array missing"),
            }
        } else {
            panic!("report is not an object");
        }
    }
}
