//! Hardware metric composition: cell → PE → systolic array.
//!
//! Regenerates the paper's Table II (cells), Table III (PEs), Table IV
//! (arrays) and the Fig. 8-10 series from the gate-level netlists in
//! [`crate::pe::netlist_builder`] — nothing here copies paper numbers;
//! the library calibration lives in [`crate::tech`] (one anchor row).
//!
//! Power here is **random-activity** power: every netlist is driven with
//! deterministic random vectors through the incremental activity-replay
//! API ([`crate::netlist::Stepper`], aggregated by
//! [`crate::netlist::Netlist::power_uw`]) — the right granularity for
//! the paper's synthesis-style tables. For *data-dependent* energy at
//! real workload activity (what the serving stack reports per request),
//! see [`crate::energy`], which builds its per-MAC model on the same
//! replay API.

use crate::cells::CellKind;
use crate::error::{exhaustive_metrics, ErrorMetrics};
use crate::netlist::{random_vectors, Netlist};
use crate::pe::netlist_builder::{
    cell_netlist, conventional_mac_netlist, pe_netlists,
};
use crate::pe::word::PeConfig;
use crate::pe::{Design, Signedness};
use crate::tech::PERIOD_NS_250MHZ;
use crate::Family;

/// Area / power / delay / energy summary of one hardware unit.
#[derive(Clone, Copy, Debug, Default)]
pub struct HwMetrics {
    /// Cell area, µm².
    pub area_um2: f64,
    /// Total (dynamic + leakage) power at random activity, µW.
    pub power_uw: f64,
    /// Critical-path delay, ns.
    pub delay_ns: f64,
    /// Power-delay product in femtojoules.
    pub pdp_fj: f64,
    /// Power-area-delay product (paper Table III unit: µm²·fJ, scaled 1e3).
    pub padp: f64,
}

impl HwMetrics {
    fn from_parts(area_um2: f64, power_uw: f64, delay_ns: f64) -> Self {
        let pdp_fj = power_uw * delay_ns; // 1 µW * 1 ns = 1 fJ
        HwMetrics {
            area_um2,
            power_uw,
            delay_ns,
            pdp_fj,
            padp: area_um2 * pdp_fj / 1000.0,
        }
    }
}

/// Activity vectors used for every power evaluation (deterministic).
const POWER_VECTORS: usize = 600;

fn netlist_metrics(nl: &Netlist, period_ns: f64, seed: u64) -> HwMetrics {
    let vecs = random_vectors(nl.inputs.len(), POWER_VECTORS, seed);
    let (power, _) = nl.power_uw(&vecs, period_ns);
    HwMetrics::from_parts(nl.area(), power, nl.critical_path_ps() / 1000.0)
}

// ---------------------------------------------------------------------
// Table II — cell-level metrics.
// ---------------------------------------------------------------------

/// One Table II row: (label, PPC metrics, NPPC metrics).
pub struct Table2Row {
    /// Paper row label.
    pub label: &'static str,
    /// AND-product cell metrics.
    pub ppc: HwMetrics,
    /// NAND-product (sign-position) cell metrics.
    pub nppc: HwMetrics,
}

/// Cell-level period: cells are evaluated standalone at their own speed;
/// we use the paper's cell-level order of magnitude (1 GHz toggling).
const CELL_PERIOD_NS: f64 = 1.0;

/// Area/power/delay of one cell's netlist (Table II granularity).
pub fn cell_metrics(kind: CellKind) -> HwMetrics {
    netlist_metrics(&cell_netlist(kind), CELL_PERIOD_NS, 17)
}

/// Regenerate Table II (proposed + existing PPC/NPPC cells).
pub fn table2() -> Vec<Table2Row> {
    vec![
        Table2Row {
            label: "Exact [6]",
            ppc: cell_metrics(CellKind::ExactPpc),
            nppc: cell_metrics(CellKind::ExactNppc),
        },
        Table2Row {
            label: "Prop Ext",
            ppc: cell_metrics(CellKind::PropExactPpc),
            nppc: cell_metrics(CellKind::PropExactNppc),
        },
        Table2Row {
            label: "Design [6]",
            ppc: cell_metrics(CellKind::Nano6Ppc),
            nppc: cell_metrics(CellKind::Nano6Ppc),
        },
        Table2Row {
            label: "Design [5]",
            ppc: cell_metrics(CellKind::Axsa5Ppc),
            nppc: cell_metrics(CellKind::Axsa5Nppc),
        },
        Table2Row {
            label: "Prop Apx",
            ppc: cell_metrics(CellKind::PropApxPpc),
            nppc: cell_metrics(CellKind::PropApxNppc),
        },
    ]
}

// ---------------------------------------------------------------------
// Table III — PE-level metrics.
// ---------------------------------------------------------------------

/// Interconnect/readout delay growth with array size (clock distribution
/// + operand broadcast wiring): a gentle log factor, calibrated against
/// the paper's observed 3x3 -> 16x16 delay creep.
fn wire_factor(size: usize) -> f64 {
    1.0 + 0.045 * (size as f64).log2()
}

/// Compose the metrics of one PE design (grid + amortized merge +
/// registers).
///
/// The drain merge adder is shared per array column in the
/// output-stationary dataflow (results stream out one column per cycle),
/// so each PE carries 1/8 of one merge adder (area, leakage) and its
/// switching fires at drain rate, not MAC rate.
pub fn pe_metrics(d: &Design) -> HwMetrics {
    let cfg = PeConfig::from_design(d);
    let nets = pe_netlists(d, cfg.w);
    let grid = netlist_metrics(&nets.grid, PERIOD_NS_250MHZ, 23);
    let mvecs = random_vectors(nets.merge.inputs.len(), POWER_VECTORS, 29);
    let (mpow, _) = nets.merge.power_uw(&mvecs, PERIOD_NS_250MHZ);
    const COLUMN_SHARE: f64 = 8.0;
    let area = nets.grid.area() + nets.merge.area() / COLUMN_SHARE;
    let power = grid.power_uw + mpow / COLUMN_SHARE / 8.0;
    let delay = grid.delay_ns.max(nets.merge.critical_path_ps() / 1000.0);
    HwMetrics::from_parts(area, power, delay)
}

/// Metrics for the conventional (multiplier + adder) MAC baselines.
pub fn conventional_mac_metrics(n: u32, hybrid: bool) -> HwMetrics {
    let nl = conventional_mac_netlist(n, 2 * n + 8, hybrid);
    netlist_metrics(&nl, PERIOD_NS_250MHZ, 31)
}

/// One Table III row.
pub struct Table3Row {
    /// Paper row label.
    pub label: String,
    /// Operand width in bits.
    pub n: u32,
    /// Unsigned-grid metrics (absent where the paper omits the column).
    pub unsigned: Option<HwMetrics>,
    /// Signed (Baugh-Wooley) metrics.
    pub signed: Option<HwMetrics>,
}

/// Regenerate Table III: exact designs, conventional MACs, approximate
/// designs at k = N-1.
pub fn table3() -> Vec<Table3Row> {
    let mut rows = Vec::new();
    // exact PPC/NPPC-based designs
    for (label, optimized) in [("Design [6] exact", false), ("Proposed exact", true)] {
        for n in [4u32, 8] {
            let mk = |s: Signedness| Design {
                n, signed: s, family: Family::Proposed, k: 0,
                optimized_exact: optimized,
            };
            rows.push(Table3Row {
                label: label.to_string(),
                n,
                unsigned: Some(pe_metrics(&mk(Signedness::Unsigned))),
                signed: Some(pe_metrics(&mk(Signedness::Signed))),
            });
        }
    }
    // conventional MAC baselines (signed only, 8-bit, like the paper)
    rows.push(Table3Row {
        label: "HA-FSA [10]".into(),
        n: 8,
        unsigned: None,
        signed: Some(conventional_mac_metrics(8, true)),
    });
    rows.push(Table3Row {
        label: "Gemmini [13]".into(),
        n: 8,
        unsigned: None,
        signed: Some(conventional_mac_metrics(8, false)),
    });
    // approximate designs at k = N-1
    for family in [Family::Nano6, Family::Sips12, Family::Axsa5, Family::Proposed] {
        for n in [4u32, 8] {
            let mk = |s: Signedness| Design::approximate_default(n, s, family);
            rows.push(Table3Row {
                label: format!("{} approx", family.paper_label()),
                n,
                unsigned: Some(pe_metrics(&mk(Signedness::Unsigned))),
                signed: Some(pe_metrics(&mk(Signedness::Signed))),
            });
        }
    }
    rows
}

// ---------------------------------------------------------------------
// Table IV — systolic-array metrics.
// ---------------------------------------------------------------------

/// Compose SA metrics from a PE design at a given array size.
///
/// area  = size² · PE + skew/edge registers
/// power = size² · PE power (random activity) + register clocking
/// delay = PE critical path · wire factor
pub fn sa_metrics(d: &Design, size: usize) -> HwMetrics {
    let pe = pe_metrics(d);
    let lib = crate::tech::LIB;
    let n = d.n as f64;
    // operand skew registers on two edges: sum_{i<size} i = size(size-1)/2
    // stages per edge, each n bits wide
    let skew_regs = (size * (size - 1)) as f64 * n; // both edges combined
    let reg_area = skew_regs * lib.dff_area;
    let reg_power = skew_regs * (lib.dff_energy_fj * 0.5 / PERIOD_NS_250MHZ
        + lib.dff_leak_nw / 1000.0);
    let area = pe.area_um2 * (size * size) as f64 + reg_area;
    let power = pe.power_uw * (size * size) as f64 + reg_power;
    let delay = pe.delay_ns * wire_factor(size);
    HwMetrics::from_parts(area, power, delay)
}

/// One Table IV row: metrics across the four array sizes.
pub struct Table4Row {
    /// Paper row label.
    pub label: String,
    /// Operand width in bits.
    pub n: u32,
    /// `(array size, metrics)` across [`TABLE4_SIZES`].
    pub sizes: [(usize, HwMetrics); 4],
}

/// Array sizes the paper's Table IV evaluates.
pub const TABLE4_SIZES: [usize; 4] = [3, 4, 8, 16];

fn table4_row(label: &str, d: &Design) -> Table4Row {
    Table4Row {
        label: label.to_string(),
        n: d.n,
        sizes: TABLE4_SIZES.map(|s| (s, sa_metrics(d, s))),
    }
}

/// Regenerate Table IV (signed PEs, exact + approx at `k = N-1`, both
/// widths).
pub fn table4() -> Vec<Table4Row> {
    let mut rows = Vec::new();
    for n in [4u32, 8] {
        rows.push(table4_row("Exact [6]", &Design {
            n, signed: Signedness::Signed, family: Family::Proposed, k: 0,
            optimized_exact: false,
        }));
        rows.push(table4_row("Proposed Exact",
                             &Design::proposed_exact(n, Signedness::Signed)));
        for family in [Family::Sips12, Family::Nano6, Family::Axsa5,
                       Family::Proposed] {
            let label = if family == Family::Proposed {
                "Proposed Approx.".to_string()
            } else {
                format!("Approx. {}", family.paper_label())
            };
            rows.push(table4_row(
                &label,
                &Design::approximate_default(n, Signedness::Signed, family)));
        }
    }
    rows
}

// ---------------------------------------------------------------------
// Figure series.
// ---------------------------------------------------------------------

/// Fig. 8: proposed-vs-\[6\]-exact area/PDP savings (%) per array size,
/// plus proposed-approx-vs-\[5\] PDP improvement.
pub struct Fig8Point {
    /// Array size (NxN).
    pub size: usize,
    /// Proposed-exact area saving over \[6\], percent.
    pub area_saving_pct: f64,
    /// Proposed-exact PDP saving over \[6\], percent.
    pub pdp_saving_pct: f64,
    /// Proposed-approx PDP saving over the best baseline \[5\], percent.
    pub approx_pdp_vs_best_pct: f64,
}

/// Compute the Fig. 8 saving series for operand width `n`.
pub fn fig8(n: u32) -> Vec<Fig8Point> {
    let exact6 = Design {
        n, signed: Signedness::Signed, family: Family::Proposed, k: 0,
        optimized_exact: false,
    };
    let prop_e = Design::proposed_exact(n, Signedness::Signed);
    let prop_a = Design::approximate_default(n, Signedness::Signed, Family::Proposed);
    let axsa = Design::approximate_default(n, Signedness::Signed, Family::Axsa5);
    TABLE4_SIZES.iter().map(|&size| {
        let e6 = sa_metrics(&exact6, size);
        let pe_ = sa_metrics(&prop_e, size);
        let pa = sa_metrics(&prop_a, size);
        let a5 = sa_metrics(&axsa, size);
        Fig8Point {
            size,
            area_saving_pct: (1.0 - pe_.area_um2 / e6.area_um2) * 100.0,
            pdp_saving_pct: (1.0 - pe_.pdp_fj / e6.pdp_fj) * 100.0,
            approx_pdp_vs_best_pct: (1.0 - pa.pdp_fj / a5.pdp_fj) * 100.0,
        }
    }).collect()
}

/// Fig. 9: (PDP, NMED) per design, signed 8-bit, k = N-1.
pub struct Fig9Point {
    /// Paper design label.
    pub label: &'static str,
    /// Power-delay product, fJ.
    pub pdp_fj: f64,
    /// Normalized mean error distance.
    pub nmed: f64,
}

/// Compute the Fig. 9 accuracy-vs-energy scatter.
pub fn fig9() -> Vec<Fig9Point> {
    Family::ALL.iter().map(|&f| {
        let d = Design::approximate_default(8, Signedness::Signed, f);
        let hw = pe_metrics(&d);
        let em = exhaustive_metrics(&PeConfig::from_design(&d));
        Fig9Point { label: f.paper_label(), pdp_fj: hw.pdp_fj, nmed: em.nmed }
    }).collect()
}

/// Fig. 10: PDP and MRED vs approximation factor k (signed 8-bit).
pub struct Fig10Point {
    /// Approximation level.
    pub k: u32,
    /// Power-delay product, fJ.
    pub pdp_fj: f64,
    /// Mean relative error distance.
    pub mred: f64,
}

/// Compute the Fig. 10 PDP/MRED-vs-k series.
pub fn fig10() -> Vec<Fig10Point> {
    (0..=8u32).map(|k| {
        let d = Design::approximate(8, Signedness::Signed, Family::Proposed, k);
        let hw = pe_metrics(&d);
        let em = exhaustive_metrics(&PeConfig::from_design(&d));
        Fig10Point { k, pdp_fj: hw.pdp_fj, mred: em.mred }
    }).collect()
}

/// Error metrics convenience used by the Table V bench.
pub fn table5_metrics(family: Family, k: u32) -> (ErrorMetrics, ErrorMetrics) {
    crate::error::table5_row(family, k, 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_calibration_anchor() {
        // tech::LIB is calibrated so the conventional exact PPC sits near
        // the paper's 25.81 µm² / 262 ps
        let m = cell_metrics(CellKind::ExactPpc);
        assert!((m.area_um2 - 25.81).abs() / 25.81 < 0.10, "{}", m.area_um2);
        assert!((m.delay_ns * 1000.0 - 262.0).abs() / 262.0 < 0.15,
                "{}", m.delay_ns * 1000.0);
    }

    #[test]
    fn table2_orderings() {
        // proposed exact < conventional exact; proposed approx smallest
        let ex = cell_metrics(CellKind::ExactPpc);
        let pe_ = cell_metrics(CellKind::PropExactPpc);
        let ap = cell_metrics(CellKind::PropApxPpc);
        assert!(pe_.area_um2 < ex.area_um2);
        assert!(pe_.pdp_fj < ex.pdp_fj);
        assert!(ap.area_um2 < pe_.area_um2);
        assert!(ap.pdp_fj < pe_.pdp_fj * 0.6,
                "approx should save >40% cell PDP: {} vs {}", ap.pdp_fj, pe_.pdp_fj);
        // NAND-based NPPC cheaper than AND-based PPC (exact flavors)
        let en = cell_metrics(CellKind::ExactNppc);
        assert!(en.area_um2 < ex.area_um2);
    }

    #[test]
    fn pe_orderings_8bit_signed() {
        let conv = pe_metrics(&Design::conventional_exact(8, Signedness::Signed));
        let prop = pe_metrics(&Design::proposed_exact(8, Signedness::Signed));
        let apx = pe_metrics(&Design::approximate_default(
            8, Signedness::Signed, Family::Proposed));
        assert!(prop.pdp_fj < conv.pdp_fj, "proposed exact must beat [6]");
        assert!(apx.pdp_fj < prop.pdp_fj, "approx must beat exact");
        assert!(apx.area_um2 < prop.area_um2);
    }

    #[test]
    fn conventional_macs_dominate_ppc_designs() {
        // paper: PADP improvement of ~65% vs Gemmini-style MAC
        let gem = conventional_mac_metrics(8, false);
        let prop = pe_metrics(&Design::proposed_exact(8, Signedness::Signed));
        assert!(prop.padp < gem.padp);
    }

    #[test]
    fn sa_composition_scales() {
        let d = Design::proposed_exact(8, Signedness::Signed);
        let m3 = sa_metrics(&d, 3);
        let m16 = sa_metrics(&d, 16);
        assert!(m16.area_um2 > 20.0 * m3.area_um2);
        assert!(m16.delay_ns > m3.delay_ns); // wire factor
    }

    #[test]
    fn fig8_savings_positive() {
        for p in fig8(8) {
            assert!(p.area_saving_pct > 0.0, "size {}", p.size);
            assert!(p.pdp_saving_pct > 0.0);
            assert!(p.approx_pdp_vs_best_pct > 0.0,
                    "proposed approx must beat AxSA at size {}", p.size);
        }
    }

    #[test]
    fn fig10_pdp_decreases_mred_increases() {
        let pts = fig10();
        assert!(pts.last().unwrap().pdp_fj < pts[0].pdp_fj);
        assert!(pts.last().unwrap().mred > pts[0].mred);
    }
}
