//! GEMM request coordinator — the serving layer of the stack.
//!
//! Beyond raw GEMM, the coordinator serves the paper's application
//! pipelines end-to-end (`serve_dct` / `serve_edge` / `serve_bdcn`):
//! each pipeline's matrix products — convolutions pre-lowered to GEMM
//! by the shared im2col pass — are submitted through
//! [`CoordinatorGemm`] and fan out across the same worker pool, with
//! per-app counters, quality PSNR and latency percentiles reported in
//! [`ServiceStats`].
//!
//! Arbitrary integer GEMM requests are tiled to the systolic array's
//! output geometry, queued with backpressure, executed by a worker pool
//! (std threads + channels; each worker owns its device — a cycle-accurate
//! SA simulator, the word-level model, the table-driven product-LUT engine
//! sharing process-wide tables via `Arc`, or a PJRT executable running
//! the AOT `axmm_b16` artifact), and reassembled in submission-independent
//! order. Results are deterministic regardless of worker count or
//! batching (tested), and `Word`, `Lut` and `Systolic` are bit-identical
//! to each other for every design point (`tests/backend_equiv.rs`).
//!
//! ## Batched dispatch and intra-request fan-out
//!
//! Workers pull tiles in batches (up to [`CoordinatorConfig::batch`] per
//! queue visit, MAC-capped by [`CoordinatorConfig::batch_macs`]). On the
//! software backends (`Word`/`Lut`) a batch is then **coalesced**: tiles
//! that share one request's B panel (same request, same output-column
//! origin, same `k`) — the shape the im2col-lowered conv tiles from
//! [`crate::apps`] arrive in — are stacked row-wise and executed as a
//! single cache-blocked GEMM through each worker's reusable
//! [`BlockedGemm`] engine. Coalescing only concatenates *independent
//! output rows*, so results stay bit-identical to per-tile execution
//! (enforced by `tests/coordinator_invariance.rs`); batch-size and
//! dispatch-latency counters land in [`ServiceStats`].
//!
//! The same mechanism runs in reverse for one *large* request: the
//! software backends tile it into MC-row blocks
//! ([`CoordinatorConfig::sw_tile`], B panels `Arc`-shared per column),
//! and the MAC budget stops any single worker from vacuuming all of a
//! request's row blocks into its batch — so the blocks fan out across
//! idle workers. Tiling splits only output rows/columns (every output
//! element's full-`kk` MAC chain runs unchanged in exactly one tile),
//! so fan-out is bit-identical to single-threaded execution for every
//! backend and worker count, and each tile's metered femtojoules are
//! exact — the request total sums them in tile-commit order
//! (`tests/prop_equiv.rs` pins both properties).
//!
//! Both fan-out knobs are measured, not guessed: [`autotune_sw_tile`]
//! sweeps candidate tile shapes through a real worker pool at startup
//! and pins the fastest (the CLI `--sw-tile RxC` overrides it), and
//! [`calibrate_batch_macs`] sizes the drain budget from the measured
//! *metered* kernel rate — the fused lane meter made metered and
//! unmetered throughput comparable, so one rate sizes the drain for
//! both kinds of traffic.
//!
//! ## Energy accounting
//!
//! Every request served by a meterable design point reports calibrated,
//! data-dependent energy (DESIGN.md §4): the software workers charge
//! each MAC its [`crate::energy::EnergyLut`] table energy inside the
//! blocked kernels, the systolic workers replay the PE's gate netlist
//! per MAC (the ground-truth cross-check), and the totals surface as
//! [`GemmResponse::energy_uj`] / [`GemmResponse::avg_power_uw`], per-app
//! energy-per-image in [`AppStats`], and fleet totals in
//! [`ServiceStats`]. Metering only reads operands and states the
//! devices already hold — the bit-identity invariance suites run with
//! it enabled.
//!
//! PJRT note: tiles streamed through `axmm_b16` carry K in chunks of 8
//! whose partial results are summed outside the PE; for k = 0 this is
//! bit-identical to the monolithic array, for k > 0 it is the "chunked
//! accumulation" deployment mode (DESIGN.md §3).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::apps::image::{psnr, Image};
use crate::apps::{bdcn, dct, edge, CoordinatorGemm, Gemm};
use crate::nn;
use crate::energy::{self, EnergyLut};
use crate::gemm::BlockedGemm;
use crate::pe::lut::{self, ProductLut};
use crate::pe::word::PeConfig;
use crate::runtime::{Runtime, TensorI32};
use crate::systolic::{SaStats, Systolic};
use crate::zoo::{self, AccuracySlo, RouteError, Tier};
use crate::Family;

/// Which device each worker instantiates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Word-level functional model (bit-plane walk per MAC).
    Word,
    /// Table-driven product-LUT engine (bit-identical to `Word`, fastest;
    /// falls back to the word model for non-LUT-compilable design points).
    Lut,
    /// Cycle-accurate systolic-array simulator (tracks cycles/toggles).
    Systolic,
    /// PJRT CPU execution of the AOT `axmm_b16` artifact.
    Pjrt,
}

impl BackendKind {
    /// Every backend, in CLI-advertised order.
    pub const ALL: [BackendKind; 4] = [BackendKind::Word, BackendKind::Lut,
                                       BackendKind::Systolic, BackendKind::Pjrt];

    /// Stable lower-case name (CLI `--backend` value).
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Word => "word",
            BackendKind::Lut => "lut",
            BackendKind::Systolic => "systolic",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Inverse of [`Self::name`] (`None` for unknown names).
    pub fn parse(s: &str) -> Option<BackendKind> {
        Self::ALL.into_iter().find(|b| b.name() == s)
    }

    /// `"word|lut|systolic|pjrt"` — for CLI error messages, derived from
    /// [`Self::ALL`] so the advertised set can't drift from the parser.
    pub fn names() -> String {
        Self::ALL.map(|b| b.name()).join("|")
    }
}

/// Static configuration of one [`Coordinator`] worker pool.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker-thread count (min 1).
    pub workers: usize,
    /// Device each worker instantiates.
    pub backend: BackendKind,
    /// PE configuration (family + width); the request's `k` overrides
    /// `pe.k` per submission.
    pub family: Family,
    /// Operand width in bits of every worker device.
    pub n_bits: u32,
    /// Systolic tile geometry (square).
    pub sa_size: usize,
    /// Bounded queue depth (backpressure: submit blocks when full).
    pub queue_depth: usize,
    /// Max tiles a worker pulls per batch.
    pub batch: usize,
    /// Output-tile geometry `(rows, cols)` for the software backends
    /// (`Word`/`Lut`). `None` falls back to the process-wide pinned
    /// value when [`autotune_sw_tile`] / [`set_sw_tile_override`] (the
    /// CLI `--sw-tile RxC`) pinned one, else derives the row height
    /// from the process block autotune
    /// ([`crate::gemm::effective_blocks`]`.mc`) and a column width of
    /// four NC panels — so one large request splits into MC-row blocks
    /// that fan out across idle workers while each tile is still a
    /// full cache-blocked GEMM (wide enough for the 64-lane kernels).
    /// `Systolic`/`Pjrt` always tile by [`Self::sa_size`].
    pub sw_tile: Option<(usize, usize)>,
    /// Opportunistic batch-drain MAC budget. A worker's first queue
    /// pull always blocks; it then keeps draining queued tiles only
    /// while the MACs pulled so far stay under this budget (and the
    /// tile count under [`Self::batch`]). Small im2col conv tiles still
    /// coalesce deeply, but the large row-block tiles of one fanned-out
    /// request hit the budget after one or two pulls and spread across
    /// the pool instead of being vacuumed into a single worker's batch.
    /// The default comes from [`default_batch_macs`]: a fixed 1 MiMAC
    /// until [`calibrate_batch_macs`] pins a budget measured against
    /// the *metered* kernel rate — since the metered path is as wide as
    /// the unmetered one, a single measured rate now sizes the drain
    /// for both kinds of traffic.
    pub batch_macs: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            backend: BackendKind::Word,
            family: Family::Proposed,
            n_bits: 8,
            sa_size: 8,
            queue_depth: 256,
            batch: 16,
            sw_tile: None,
            batch_macs: default_batch_macs(),
        }
    }
}

impl CoordinatorConfig {
    /// Resolved output-tile geometry `(rows, cols)` for this backend:
    /// the software engines tile by [`Self::sw_tile`] (or the
    /// autotune-derived default), the per-tile devices by
    /// [`Self::sa_size`] squared.
    fn tile_shape(&self) -> (usize, usize) {
        match self.backend {
            BackendKind::Word | BackendKind::Lut => {
                let (tr, tc) = self.sw_tile
                    .or_else(effective_sw_tile)
                    .unwrap_or_else(|| {
                        let bs = crate::gemm::effective_blocks();
                        (bs.mc, bs.nc * 4)
                    });
                (tr.max(1), tc.max(1))
            }
            BackendKind::Systolic | BackendKind::Pjrt => {
                (self.sa_size, self.sa_size)
            }
        }
    }
}

/// The process-wide pinned fan-out tile shape (None until an override
/// or [`autotune_sw_tile`] pins one). Same contract as the gemm block
/// pin: explicit per-config [`CoordinatorConfig::sw_tile`] always wins,
/// the pin covers configs that left it `None`, and the shape is purely
/// a perf knob — tiling splits only output rows/columns, so it can
/// never change the bits.
static PINNED_SW_TILE: OnceLock<(usize, usize)> = OnceLock::new();

/// The process-wide pinned batch-drain MAC budget (None until
/// [`calibrate_batch_macs`] measures one).
static PINNED_BATCH_MACS: OnceLock<u64> = OnceLock::new();

/// How long one worker's batch drain should keep it busy. Long enough
/// to amortize a dispatch, short enough that one large request's row
/// blocks spread across the pool instead of queueing behind one
/// worker. [`calibrate_batch_macs`] converts it to MACs at the
/// *measured metered* kernel rate.
const BATCH_DRAIN_TARGET_S: f64 = 2e-3;

/// Parse the CLI `--sw-tile RxC` syntax, e.g. `"64x256"`. Both
/// components must be positive integers.
pub fn parse_sw_tile(s: &str) -> Option<(usize, usize)> {
    let (r, c) = s.split_once('x')?;
    let r: usize = r.parse().ok()?;
    let c: usize = c.parse().ok()?;
    if r == 0 || c == 0 {
        return None;
    }
    Some((r, c))
}

/// Pin the process-wide fan-out tile shape (the CLI `--sw-tile`
/// override). First pin wins — returns `false` if autotune or an
/// earlier override already pinned a value (which then stays in force).
pub fn set_sw_tile_override(t: (usize, usize)) -> bool {
    PINNED_SW_TILE.set((t.0.max(1), t.1.max(1))).is_ok()
}

/// The pinned fan-out tile shape, if an override or
/// [`autotune_sw_tile`] ran (`None` otherwise — configs then derive the
/// shape from the block autotune, see [`CoordinatorConfig::sw_tile`]).
pub fn effective_sw_tile() -> Option<(usize, usize)> {
    PINNED_SW_TILE.get().copied()
}

/// Measure the fan-out tile shape the way [`crate::gemm::autotune_blocks`]
/// measures MC/KC/NC: sweep a small candidate grid (row heights and
/// column widths derived from the pinned blocking) by timing one large
/// GEMM through a real pool of `workers` workers per candidate, and pin
/// the fastest shape process-wide (once — later calls return the pinned
/// value immediately). The CLI entry points call this at startup unless
/// `--sw-tile` pinned an explicit shape. Bit-identity is unconditional
/// on tile shape, so the sweep only ever changes speed.
pub fn autotune_sw_tile(workers: usize) -> (usize, usize) {
    *PINNED_SW_TILE.get_or_init(|| {
        let bs = crate::gemm::effective_blocks();
        let (m, kk, nn) = (192usize, 96usize, 192usize);
        let a = crate::bench::xorshift_ints(21, m * kk);
        let b = crate::bench::xorshift_ints(22, kk * nn);
        let mut best = (f64::INFINITY, (bs.mc, bs.nc * 4));
        for tr in [(bs.mc / 2).max(1), bs.mc] {
            for tc in [bs.nc * 2, bs.nc * 4, bs.nc * 8] {
                let c = Coordinator::new(CoordinatorConfig {
                    workers: workers.max(2),
                    backend: BackendKind::Lut,
                    sw_tile: Some((tr, tc)),
                    ..Default::default()
                });
                let req = || GemmRequest {
                    a: a.clone(), b: b.clone(), m, kk, nn, k: 4,
                    ..Default::default()
                };
                // warm (table builds, worker scratch), then best-of-2
                c.call(req());
                let mut dt = f64::INFINITY;
                for _ in 0..2 {
                    let t0 = Instant::now();
                    std::hint::black_box(c.call(req()));
                    dt = dt.min(t0.elapsed().as_secs_f64());
                }
                c.shutdown();
                if dt < best.0 {
                    best = (dt, (tr, tc));
                }
            }
        }
        best.1
    })
}

/// The batch-drain MAC budget new configs should default to: the
/// calibrated value if [`calibrate_batch_macs`] ran, a fixed 1 MiMAC
/// otherwise (deterministic for tests and one-shot callers).
pub fn default_batch_macs() -> u64 {
    PINNED_BATCH_MACS.get().copied().unwrap_or(1 << 20)
}

/// Measure the *metered* blocked-kernel rate and pin the batch-drain
/// MAC budget to [`BATCH_DRAIN_TARGET_S`] worth of it (once — later
/// calls return the pinned value immediately). Before the fused lane
/// meter, the budget was sized against the unmetered MACs/s estimate
/// only, so metered traffic — an order of magnitude slower on the old
/// scalar walk — drained batches far past the latency target; now the
/// metered and unmetered rates are close and one measured number sizes
/// both. Runs with the meter attached on the LUT serving point; the
/// result is clamped to a sane range so a noisy measurement can never
/// starve coalescing (floor) or disable fan-out (ceiling).
pub fn calibrate_batch_macs() -> u64 {
    *PINNED_BATCH_MACS.get_or_init(|| {
        let cfg = PeConfig::new(8, true, Family::Proposed, 4);
        let s = 96usize;
        let a = crate::bench::xorshift_ints(31, s * s);
        let b = crate::bench::xorshift_ints(32, s * s);
        let mut eng = BlockedGemm::single_threaded(
            crate::gemm::effective_blocks());
        eng.set_meter(energy::cached(&cfg));
        // warm: energy/product table builds + packing scratch
        eng.matmul(&cfg, &a, &b, s, s, s);
        let mut dt = f64::INFINITY;
        for _ in 0..2 {
            let t0 = Instant::now();
            std::hint::black_box(eng.matmul(&cfg, &a, &b, s, s, s));
            dt = dt.min(t0.elapsed().as_secs_f64());
        }
        let _ = eng.take_energy_fj();
        let rate = (s * s * s) as f64 / dt.max(1e-9);
        ((rate * BATCH_DRAIN_TARGET_S) as u64).clamp(1 << 16, 1 << 24)
    })
}

/// One GEMM request: `C(m x nn) = A(m x kk) @ B(kk x nn)` at level `k`.
///
/// The design point a request runs at resolves in precedence order:
///
/// 1. [`Self::slo`] — the accuracy SLO is routed through the zoo
///    ([`crate::zoo::route`]) and the chosen entry's family *and* `k`
///    override everything below (a typed [`RouteError`] refuses the
///    request when no registered point satisfies it);
/// 2. [`Self::family`] — explicit per-request family override at the
///    request's own `k`;
/// 3. the pool default ([`CoordinatorConfig::family`]) at the
///    request's `k`.
#[derive(Clone, Debug, Default)]
pub struct GemmRequest {
    /// Left operand, row-major `m x kk`.
    pub a: Vec<i64>,
    /// Right operand, row-major `kk x nn`.
    pub b: Vec<i64>,
    /// Output rows.
    pub m: usize,
    /// Inner (contraction) dimension.
    pub kk: usize,
    /// Output columns.
    pub nn: usize,
    /// Approximation level for this request (0 = exact; ignored when
    /// [`Self::slo`] routes the design point).
    pub k: u32,
    /// Per-request multiplier-family override (`None` = pool default;
    /// ignored when [`Self::slo`] routes the design point).
    pub family: Option<Family>,
    /// Accuracy SLO: when present the zoo router picks the cheapest
    /// registered design point meeting it ([`Coordinator::try_submit`]).
    pub slo: Option<AccuracySlo>,
}

/// Completed response.
#[derive(Clone, Debug)]
pub struct GemmResponse {
    /// Request id (as returned by [`Coordinator::submit`]).
    pub id: u64,
    /// Result matrix, row-major `m x nn`.
    pub out: Vec<i64>,
    /// Output rows.
    pub m: usize,
    /// Output columns.
    pub nn: usize,
    /// End-to-end latency from submit to last tile commit, µs.
    pub latency_us: f64,
    /// Output tiles the request was split into.
    pub tiles: u64,
    /// Merged execution statistics of every tile.
    pub sa_stats: SaStats,
}

impl GemmResponse {
    /// Request-level MAC throughput implied by its end-to-end latency.
    pub fn macs_per_sec(&self) -> f64 {
        if self.latency_us <= 0.0 {
            return 0.0;
        }
        self.sa_stats.macs as f64 / (self.latency_us * 1e-6)
    }

    /// Calibrated data-dependent energy of this request in microjoules
    /// (the per-MAC model of [`crate::energy`]; 0.0 when the design
    /// point is not meterable — see [`SaStats::metered_macs`]).
    pub fn energy_uj(&self) -> f64 {
        self.sa_stats.energy_uj()
    }

    /// Mean modeled power at the paper's 250 MHz clock, µW (simulated
    /// cycles on the systolic backend, MAC-serialized time otherwise).
    pub fn avg_power_uw(&self) -> f64 {
        self.sa_stats.avg_power_uw()
    }
}

struct Pending {
    out: Vec<i64>,
    m: usize,
    nn: usize,
    remaining: usize,
    t_submit: Instant,
    stats: SaStats,
    done: Option<GemmResponse>,
}

struct TileJob {
    req_id: u64,
    /// output tile origin
    ti: usize,
    tj: usize,
    th: usize,
    tw: usize,
    /// row-major A panel, th x kk
    a_panel: Vec<i64>,
    /// row-major B panel, kk x tw — one shared allocation per request
    /// column (every row tile of a column reads the same B region, and
    /// the coalescer merges exactly those tiles into one stacked GEMM)
    b_panel: Arc<Vec<i64>>,
    kk: usize,
    /// resolved design point (SLO/override routing already applied)
    family: Family,
    k: u32,
}

impl TileJob {
    /// MAC count of this tile — the unit of the worker batch budget.
    fn macs(&self) -> u64 {
        (self.th * self.kk * self.tw) as u64
    }
}

/// Stripe count of the pending-request completion map. Request ids
/// distribute as `id % PENDING_STRIPES`, so submitters, waiters and
/// committing workers of *different* requests rarely contend on the
/// same lock (the previous design funneled all of them — and therefore
/// every serving shard — through one map mutex).
const PENDING_STRIPES: usize = 8;

/// The striped completion map: each stripe pairs a mutex-guarded
/// id → [`Pending`] map with a condvar for the waiters of requests that
/// hash to it.
struct SharedMap {
    stripes: Vec<(Mutex<HashMap<u64, Pending>>, Condvar)>,
}

impl SharedMap {
    fn new() -> Self {
        SharedMap {
            stripes: (0..PENDING_STRIPES)
                .map(|_| (Mutex::new(HashMap::new()), Condvar::new()))
                .collect(),
        }
    }

    fn stripe(&self, id: u64) -> &(Mutex<HashMap<u64, Pending>>, Condvar) {
        &self.stripes[(id % PENDING_STRIPES as u64) as usize]
    }
}

type Shared = Arc<SharedMap>;

/// Striped service statistics: one stripe per worker — written only by
/// that worker, so dispatch and completion accounting never contends —
/// with front-end writers (the app endpoints, which run on caller
/// threads) round-robined across the stripes. Folded into one
/// [`ServiceStats`] via [`ServiceStats::merge`] on snapshot.
struct StatsStripes {
    stripes: Vec<Mutex<ServiceStats>>,
    /// Round-robin cursor for writers without a stripe of their own.
    rr: AtomicUsize,
}

impl StatsStripes {
    fn new(n: usize) -> Self {
        StatsStripes {
            stripes: (0..n.max(1))
                .map(|_| Mutex::new(ServiceStats::default()))
                .collect(),
            rr: AtomicUsize::new(0),
        }
    }

    /// Any stripe, round-robined — for the app endpoints' caller-thread
    /// records (the fold sums every stripe, so placement is free).
    fn rotate(&self) -> &Mutex<ServiceStats> {
        let i = self.rr.fetch_add(1, Ordering::Relaxed);
        &self.stripes[i % self.stripes.len()]
    }

    /// Fold every stripe into one fleet view (short lock per stripe).
    fn fold(&self) -> ServiceStats {
        let mut total = ServiceStats::default();
        for s in &self.stripes {
            total.merge(&s.lock().unwrap());
        }
        total
    }
}

/// Application pipelines servable end-to-end through the coordinator
/// (paper §V). Every matrix product inside them is tiled and executed
/// by the worker pool via [`CoordinatorGemm`]; the convolutions arrive
/// pre-lowered to GEMM by the shared im2col pass
/// ([`crate::apps::im2col`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppKind {
    /// 8x8 integer DCT compress -> reconstruct (paper §V-A).
    Dct,
    /// Laplacian edge detection (paper §V-B, kernel path).
    Edge,
    /// BDCN-lite CNN edge cascade (paper §V-B; needs trained weights).
    Bdcn,
    /// Quantized CNN classifier inference ([`crate::nn`]; seeded
    /// checked-in weights, logits returned as a `batch x 10` image).
    Nn,
}

impl AppKind {
    /// Every servable application, in CLI-advertised order.
    pub const ALL: [AppKind; 4] =
        [AppKind::Dct, AppKind::Edge, AppKind::Bdcn, AppKind::Nn];

    /// Stable lower-case name (CLI `--app` value).
    pub fn name(self) -> &'static str {
        match self {
            AppKind::Dct => "dct",
            AppKind::Edge => "edge",
            AppKind::Bdcn => "bdcn",
            AppKind::Nn => "nn",
        }
    }

    /// Inverse of [`Self::name`] (`None` for unknown names).
    pub fn parse(s: &str) -> Option<AppKind> {
        Self::ALL.into_iter().find(|a| a.name() == s)
    }

    /// `"dct|edge|bdcn|nn"` — for CLI error messages.
    pub fn names() -> String {
        Self::ALL.map(|a| a.name()).join("|")
    }
}

/// Completed application-level response.
#[derive(Clone, Debug)]
pub struct AppResponse {
    /// Which pipeline served this request.
    pub app: AppKind,
    /// The pipeline's output image (reconstruction or edge map).
    pub out: Image,
    /// Paper §V quality metric: `dct` reports reconstruction-vs-input
    /// PSNR; `edge`/`bdcn` report approximate-vs-exact PSNR, where the
    /// exact (k = 0) reference runs through the same served path.
    /// Infinite when the request itself is exact and self-referential.
    pub psnr_db: f64,
    /// End-to-end pipeline latency (all GEMM stages + reference run).
    pub latency_us: f64,
    /// GEMM sub-requests issued, including the exact reference run.
    pub gemm_requests: u64,
    /// Merged execution stats of every GEMM sub-request.
    pub sa_stats: SaStats,
}

impl AppResponse {
    /// Total metered energy of every GEMM stage behind this response
    /// (including the exact reference run where one was served), µJ —
    /// with [`Self::psnr_db`] this is one point of the paper's
    /// quality-vs-energy trade.
    pub fn energy_uj(&self) -> f64 {
        self.sa_stats.energy_uj()
    }
}

/// Aggregate counters for one served application pipeline.
#[derive(Clone, Copy, Debug, Default)]
pub struct AppStats {
    /// Application requests completed.
    pub requests: u64,
    /// GEMM sub-requests the pipelines issued through the worker pool.
    pub gemm_requests: u64,
    /// Summed end-to-end pipeline latency, µs.
    pub total_latency_us: f64,
    /// Worst single-request pipeline latency, µs.
    pub max_latency_us: f64,
    /// Sum over requests with a finite quality PSNR (exact
    /// self-referential runs report infinity and are excluded).
    pub psnr_sum_db: f64,
    /// Number of finite-PSNR samples in [`Self::psnr_sum_db`].
    pub psnr_samples: u64,
    /// Summed metered energy of every GEMM sub-request, femtojoules.
    pub energy_fj: f64,
}

impl AppStats {
    /// Mean end-to-end pipeline latency in µs (0.0 before any request).
    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_latency_us / self.requests as f64
        }
    }

    /// Mean quality PSNR over finite samples (0.0 when none recorded).
    pub fn mean_psnr_db(&self) -> f64 {
        if self.psnr_samples == 0 {
            0.0
        } else {
            self.psnr_sum_db / self.psnr_samples as f64
        }
    }

    /// Mean metered energy per served image, µJ (0.0 before any
    /// request). Pairs with [`Self::mean_psnr_db`] for the
    /// energy-vs-quality trade the paper motivates.
    pub fn mean_energy_uj(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.energy_fj * 1e-9 / self.requests as f64
        }
    }

    /// Fold another app-stats block into this one (sums for counters,
    /// max for worst-case latency) — the per-stripe fold behind
    /// [`ServiceStats::merge`].
    pub fn merge(&mut self, o: &AppStats) {
        self.requests += o.requests;
        self.gemm_requests += o.gemm_requests;
        self.total_latency_us += o.total_latency_us;
        self.max_latency_us = self.max_latency_us.max(o.max_latency_us);
        self.psnr_sum_db += o.psnr_sum_db;
        self.psnr_samples += o.psnr_samples;
        self.energy_fj += o.energy_fj;
    }
}

/// Per-GEMM-request latency samples retained for percentile reporting
/// (ring buffer: the most recent window once the cap is reached).
pub const LATENCY_SAMPLE_CAP: usize = 8192;

/// Rounded-linear-rank percentile over an **ascending-sorted** slice:
/// `sorted[round(p * (n-1))]` with `p` clamped to \[0, 1\] (0.0 when
/// empty). The one percentile definition shared by [`LatencyRing`]
/// (and through it [`ServiceStats`] and the network layer's
/// `NetStats`) and the load generator's client-side reports.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

/// Fixed-capacity ring of recent latency samples (µs), capped at
/// [`LATENCY_SAMPLE_CAP`]: once full, new samples overwrite the oldest
/// so percentiles always describe the most recent window. One sampler
/// implementation is shared by [`ServiceStats`] and the network layer's
/// `NetStats` ([`crate::net::server::NetStats`]), so every layer reports
/// percentiles with identical semantics.
#[derive(Clone, Debug, Default)]
pub struct LatencyRing {
    samples: Vec<f64>,
    recorded: u64,
}

impl LatencyRing {
    /// Record one sample in µs (overwrites the oldest once at capacity).
    pub fn record(&mut self, us: f64) {
        if self.samples.len() < LATENCY_SAMPLE_CAP {
            self.samples.push(us);
        } else {
            self.samples[(self.recorded as usize) % LATENCY_SAMPLE_CAP] = us;
        }
        self.recorded += 1;
    }

    /// Samples recorded over the ring's lifetime (≥ the retained window).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Fold another ring's retained window into this one (used when
    /// per-connection network stats merge into fleet totals); both
    /// windows stay bounded by [`LATENCY_SAMPLE_CAP`].
    pub fn merge(&mut self, other: &LatencyRing) {
        for &s in &other.samples {
            self.record(s);
        }
    }

    /// Percentile over the retained window ([`percentile_sorted`] of
    /// the sorted samples; 0.0 when empty). NaN-safe: samples sort by
    /// [`f64::total_cmp`] (NaN to the top end), so one poisoned sample
    /// can never panic the stats path mid-serve.
    pub fn percentile(&self, p: f64) -> f64 {
        let mut v = self.samples.clone();
        v.sort_by(f64::total_cmp);
        percentile_sorted(&v, p)
    }
}

/// Aggregate service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    /// GEMM requests completed.
    pub requests: u64,
    /// Output tiles executed across all requests.
    pub tiles: u64,
    /// Summed end-to-end request latency, µs.
    pub total_latency_us: f64,
    /// Worst single-request latency, µs.
    pub max_latency_us: f64,
    /// Simulated array cycles (systolic backend only).
    pub sim_cycles: u64,
    /// MAC operations executed across all devices.
    pub sim_macs: u64,
    /// Accumulator-bit toggles (systolic backend only).
    pub sim_toggles: u64,
    /// Fleet total of metered data-dependent energy, femtojoules.
    pub energy_fj: f64,
    /// MACs covered by an energy meter (`== sim_macs` when every served
    /// design point was meterable).
    pub metered_macs: u64,
    /// Worker batch dispatches pulled from the tile queue.
    pub worker_dispatches: u64,
    /// Tiles pulled across all dispatches (mean batch size =
    /// `dispatched_tiles / worker_dispatches`).
    pub dispatched_tiles: u64,
    /// Largest single dispatch observed, in tiles.
    pub max_dispatch_tiles: u64,
    /// Device executions after same-B coalescing (`<= dispatched_tiles`;
    /// the gap is tiles that rode along in a stacked GEMM).
    pub coalesced_calls: u64,
    /// Total device execution wall time across dispatches, µs (queue
    /// wait excluded — compare against `total_latency_us` to see
    /// queueing delay).
    pub dispatch_exec_us: f64,
    /// MACs served without the bit-plane walk on the `Lut` backend
    /// (product tables, or the exact integer kernel at `k = 0`).
    pub lut_macs: u64,
    /// Process-wide LUT cache hits observed at snapshot time.
    pub lut_cache_hits: u64,
    /// Process-wide LUT table builds observed at snapshot time.
    pub lut_builds: u64,
    /// Requests that carried an accuracy SLO and were routed through
    /// the zoo ([`crate::zoo::route`]).
    pub slo_requests: u64,
    /// SLO-routed requests that resolved to the bit-exact design point.
    pub slo_exact: u64,
    /// SLO-routed requests per accuracy tier of the chosen design point
    /// ([`Tier::ALL`] order: exact, high, mid, low).
    pub slo_tier: [u64; 4],
    /// SLO-carrying requests refused with a typed
    /// [`RouteError::Unsatisfiable`] (never silently served exact).
    pub slo_unsatisfiable: u64,
    /// Per-app serving counters for `serve_dct` requests.
    pub dct: AppStats,
    /// Per-app serving counters for `serve_edge` requests.
    pub edge: AppStats,
    /// Per-app serving counters for `serve_bdcn` requests.
    pub bdcn: AppStats,
    /// Per-app serving counters for `serve_nn` inference requests.
    pub nn: AppStats,
    /// Recent per-request end-to-end GEMM latencies in µs (at most
    /// [`LATENCY_SAMPLE_CAP`], ring-buffered) — feeds
    /// [`Self::latency_percentile`].
    latency: LatencyRing,
}

impl ServiceStats {
    /// Mean end-to-end request latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_latency_us / self.requests as f64
        }
    }

    /// Mean tiles per worker dispatch (0.0 before any dispatch).
    pub fn mean_dispatch_tiles(&self) -> f64 {
        if self.worker_dispatches == 0 {
            0.0
        } else {
            self.dispatched_tiles as f64 / self.worker_dispatches as f64
        }
    }

    /// Mean device-execution time per dispatch in µs.
    pub fn mean_dispatch_exec_us(&self) -> f64 {
        if self.worker_dispatches == 0 {
            0.0
        } else {
            self.dispatch_exec_us / self.worker_dispatches as f64
        }
    }

    /// Fleet total of metered energy in microjoules.
    pub fn total_energy_uj(&self) -> f64 {
        self.energy_fj * 1e-9
    }

    /// Mean metered energy per MAC in femtojoules (0.0 before any
    /// metered MAC) — the fleet-level calibration number `serve` prints.
    pub fn mean_mac_fj(&self) -> f64 {
        if self.metered_macs == 0 {
            0.0
        } else {
            self.energy_fj / self.metered_macs as f64
        }
    }

    /// Per-app counters for one served pipeline.
    pub fn app(&self, app: AppKind) -> &AppStats {
        match app {
            AppKind::Dct => &self.dct,
            AppKind::Edge => &self.edge,
            AppKind::Bdcn => &self.bdcn,
            AppKind::Nn => &self.nn,
        }
    }

    fn app_mut(&mut self, app: AppKind) -> &mut AppStats {
        match app {
            AppKind::Dct => &mut self.dct,
            AppKind::Edge => &mut self.edge,
            AppKind::Bdcn => &mut self.bdcn,
            AppKind::Nn => &mut self.nn,
        }
    }

    fn record_latency(&mut self, us: f64) {
        self.latency.record(us);
    }

    /// Latency percentile over the retained sample window
    /// ([`LatencyRing::percentile`]; 0.0 when no requests completed yet).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency.percentile(p)
    }

    /// Fold another stats block into this one: sums for all counters
    /// and totals, max for the worst-case fields, ring-merge for the
    /// latency window, [`AppStats::merge`] per app. This is how
    /// [`Coordinator::stats_snapshot`] collapses the per-worker stripes
    /// into the one fleet view every caller sees.
    pub fn merge(&mut self, o: &ServiceStats) {
        self.requests += o.requests;
        self.tiles += o.tiles;
        self.total_latency_us += o.total_latency_us;
        self.max_latency_us = self.max_latency_us.max(o.max_latency_us);
        self.sim_cycles += o.sim_cycles;
        self.sim_macs += o.sim_macs;
        self.sim_toggles += o.sim_toggles;
        self.energy_fj += o.energy_fj;
        self.metered_macs += o.metered_macs;
        self.worker_dispatches += o.worker_dispatches;
        self.dispatched_tiles += o.dispatched_tiles;
        self.max_dispatch_tiles =
            self.max_dispatch_tiles.max(o.max_dispatch_tiles);
        self.coalesced_calls += o.coalesced_calls;
        self.dispatch_exec_us += o.dispatch_exec_us;
        self.lut_macs += o.lut_macs;
        // cache counters are process-wide gauges refreshed at snapshot
        // time, not per-stripe counters — keep the max so a pre-refresh
        // fold is still monotone
        self.lut_cache_hits = self.lut_cache_hits.max(o.lut_cache_hits);
        self.lut_builds = self.lut_builds.max(o.lut_builds);
        self.slo_requests += o.slo_requests;
        self.slo_exact += o.slo_exact;
        for (t, v) in self.slo_tier.iter_mut().zip(o.slo_tier) {
            *t += v;
        }
        self.slo_unsatisfiable += o.slo_unsatisfiable;
        self.dct.merge(&o.dct);
        self.edge.merge(&o.edge);
        self.bdcn.merge(&o.bdcn);
        self.nn.merge(&o.nn);
        self.latency.merge(&o.latency);
    }
}

/// The coordinator: tiler + bounded queue + worker pool + reassembly.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    tx: Option<SyncSender<TileJob>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    shared: Shared,
    next_id: AtomicU64,
    stats: Arc<StatsStripes>,
}

impl Coordinator {
    /// Spawn the worker pool described by `cfg` (threads start
    /// immediately and block on the tile queue).
    pub fn new(cfg: CoordinatorConfig) -> Self {
        // fail in the caller's thread with the real reason, instead of
        // letting every worker panic on the stub Runtime (which would
        // surface as "worker pool gone" or a wait() hang)
        assert!(cfg.backend != BackendKind::Pjrt || cfg!(feature = "pjrt"),
                "BackendKind::Pjrt requires building with --features pjrt \
                 (and the xla crate; see rust/src/runtime/mod.rs)");
        let (tx, rx) = sync_channel::<TileJob>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let shared: Shared = Arc::new(SharedMap::new());
        let stats = Arc::new(StatsStripes::new(cfg.workers.max(1)));
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            let stats = stats.clone();
            let wcfg = cfg.clone();
            workers.push(std::thread::Builder::new()
                .name(format!("axsys-worker-{wid}"))
                .spawn(move || worker_loop(wcfg, wid, rx, shared, stats))
                .expect("spawn worker"));
        }
        Coordinator { cfg, tx: Some(tx), workers, shared,
                      next_id: AtomicU64::new(1), stats }
    }

    /// Route a request's accuracy SLO against the zoo registry for this
    /// pool's word shape, recording the outcome in the SLO counters of
    /// [`ServiceStats`]. Returns the chosen design entry.
    pub fn route_slo(&self, slo: &AccuracySlo)
                     -> Result<&'static zoo::DesignEntry, RouteError> {
        match zoo::route(self.cfg.n_bits, true, slo) {
            Ok(e) => {
                let mut s = self.stats.rotate().lock().unwrap();
                s.slo_requests += 1;
                s.slo_tier[e.tier().idx()] += 1;
                if e.tier() == Tier::Exact {
                    s.slo_exact += 1;
                }
                Ok(e)
            }
            Err(err) => {
                if matches!(err, RouteError::Unsatisfiable { .. }) {
                    let mut s = self.stats.rotate().lock().unwrap();
                    s.slo_requests += 1;
                    s.slo_unsatisfiable += 1;
                }
                Err(err)
            }
        }
    }

    /// Resolve the design point a request runs at (see [`GemmRequest`]
    /// for the precedence), routing and counting its SLO when present.
    fn resolve_point(&self, req: &GemmRequest)
                     -> Result<(Family, u32), RouteError> {
        match &req.slo {
            Some(slo) => {
                let e = self.route_slo(slo)?;
                Ok((e.design.family, e.design.k))
            }
            None => Ok((req.family.unwrap_or(self.cfg.family), req.k)),
        }
    }

    /// Submit a request; blocks only when the tile queue is full
    /// (backpressure). Returns the request id, or a typed
    /// [`RouteError`] when the request's SLO is malformed or no
    /// registered design point satisfies it (the request is refused —
    /// never silently served at a different accuracy).
    pub fn try_submit(&self, req: GemmRequest) -> Result<u64, RouteError> {
        let (family, k) = self.resolve_point(&req)?;
        Ok(self.submit_at(req, family, k))
    }

    /// Submit a request; blocks only when the tile queue is full
    /// (backpressure). Returns the request id.
    ///
    /// # Panics
    ///
    /// On an unroutable [`GemmRequest::slo`] — SLO callers who want the
    /// typed error use [`Self::try_submit`].
    pub fn submit(&self, req: GemmRequest) -> u64 {
        self.try_submit(req).expect("SLO routing failed")
    }

    fn submit_at(&self, req: GemmRequest, family: Family, k: u32) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // software backends fan one request out as (tr x tc) row-block
        // tiles (bit-safe: tiling only splits output rows/columns, each
        // element's full-kk MAC chain is untouched); the per-tile
        // devices keep the systolic array's square geometry
        let (tr, tc) = self.cfg.tile_shape();
        let (m, kk, nn) = (req.m, req.kk, req.nn);
        assert_eq!(req.a.len(), m * kk, "A shape");
        assert_eq!(req.b.len(), kk * nn, "B shape");
        let tiles_m = m.div_ceil(tr);
        let tiles_n = nn.div_ceil(tc);
        {
            let (lock, _) = self.shared.stripe(id);
            lock.lock().unwrap().insert(id, Pending {
                out: vec![0; m * nn],
                m,
                nn,
                remaining: tiles_m * tiles_n,
                t_submit: Instant::now(),
                stats: SaStats::default(),
                done: None,
            });
        }
        let tx = self.tx.as_ref().expect("coordinator shut down");
        // column-major tile emission: every row tile of a column shares
        // one Arc'd B panel (built once), and consecutive queue entries
        // share it too — which is exactly what the workers' batch
        // coalescer merges into a single stacked GEMM
        for bj in 0..tiles_n {
            let tj = bj * tc;
            let tw = (nn - tj).min(tc);
            let mut bp = vec![0i64; kk * tw];
            for t in 0..kk {
                for j in 0..tw {
                    bp[t * tw + j] = req.b[t * nn + tj + j];
                }
            }
            let b_panel = Arc::new(bp);
            for bi in 0..tiles_m {
                let ti = bi * tr;
                let th = (m - ti).min(tr);
                let mut a_panel = vec![0i64; th * kk];
                for i in 0..th {
                    a_panel[i * kk..(i + 1) * kk]
                        .copy_from_slice(&req.a[(ti + i) * kk..(ti + i + 1) * kk]);
                }
                let job = TileJob { req_id: id, ti, tj, th, tw, a_panel,
                                    b_panel: b_panel.clone(), kk, family, k };
                // Blocking send = backpressure: the channel parks this
                // thread until a worker frees queue capacity (replaces
                // the old try_send spin loop, which burned a core per
                // saturated submitter). Workers drain the queue before
                // exiting, so shutdown-while-saturated still completes
                // every submitted tile.
                if tx.send(job).is_err() {
                    panic!("worker pool gone");
                }
            }
        }
        id
    }

    /// Block until a request completes and take its response.
    pub fn wait(&self, id: u64) -> GemmResponse {
        let (lock, cvar) = self.shared.stripe(id);
        let mut map = lock.lock().unwrap();
        loop {
            if let Some(p) = map.get_mut(&id) {
                if let Some(resp) = p.done.take() {
                    map.remove(&id);
                    return resp;
                }
            } else {
                panic!("unknown request {id}");
            }
            map = cvar.wait(map).unwrap();
        }
    }

    /// Submit and wait (simple synchronous call).
    ///
    /// # Panics
    ///
    /// On an unroutable [`GemmRequest::slo`] — see [`Self::try_call`].
    pub fn call(&self, req: GemmRequest) -> GemmResponse {
        let id = self.submit(req);
        self.wait(id)
    }

    /// Submit and wait, with SLO routing errors surfaced typed instead
    /// of panicking (the network server's entry point).
    pub fn try_call(&self, req: GemmRequest) -> Result<GemmResponse, RouteError> {
        let id = self.try_submit(req)?;
        Ok(self.wait(id))
    }

    /// Cheap snapshot of the aggregate service statistics: one short
    /// lock per worker stripe to fold the per-stripe blocks into a
    /// fresh total, every lock released before the caller formats,
    /// encodes or aggregates anything. Concurrent readers — the network
    /// server's stats frames, `loadgen` polling, CLI summaries — must
    /// use this (or [`Self::stats`], its alias) so no stats lock is
    /// ever held across encoding while workers try to commit results.
    /// LUT cache counters are refreshed from the process-wide cache
    /// (lock-free atomics) after the fold.
    pub fn stats_snapshot(&self) -> ServiceStats {
        let mut s = self.stats.fold();
        let (hits, builds) = lut::cache_counters();
        s.lut_cache_hits = hits;
        s.lut_builds = builds;
        s
    }

    /// Alias of [`Self::stats_snapshot`] (the historical name).
    pub fn stats(&self) -> ServiceStats {
        self.stats_snapshot()
    }

    // ---- application endpoints (paper §V through the worker pool) ----

    /// Serve one DCT compress->reconstruct request at level `k`
    /// (`img` dimensions must be multiples of 8). `psnr_db` is the
    /// paper's compression quality: reconstruction vs input.
    pub fn serve_dct(&self, img: &Image, k: u32) -> AppResponse {
        self.serve_dct_at(img, None, k)
    }

    /// [`Self::serve_dct`] with the design point routed by an accuracy
    /// SLO: the cheapest registered zoo entry meeting `slo` runs the
    /// pipeline. Typed refusal when the SLO is malformed or
    /// unsatisfiable — the image is never silently served at a
    /// different accuracy.
    pub fn serve_dct_slo(&self, img: &Image, slo: &AccuracySlo)
                         -> Result<AppResponse, RouteError> {
        let e = self.route_slo(slo)?;
        Ok(self.serve_dct_at(img, Some(e.design.family), e.design.k))
    }

    fn serve_dct_at(&self, img: &Image, family: Option<Family>, k: u32)
                    -> AppResponse {
        let t0 = Instant::now();
        let mut g = CoordinatorGemm::with_family(self, family, k);
        let (recon, _) = dct::pipeline(&mut g, img);
        let quality = psnr(&img.data, &recon.data);
        self.finish_app(AppKind::Dct, recon, quality, t0, &[&g])
    }

    /// Serve one Laplacian edge-detection request at level `k`
    /// (`img` at least 3x3). For `k > 0` the exact reference map is
    /// produced through the same served path and `psnr_db` is
    /// approximate-vs-exact (the paper's §V-B metric).
    pub fn serve_edge(&self, img: &Image, k: u32) -> AppResponse {
        self.serve_edge_at(img, None, k)
    }

    /// [`Self::serve_edge`] with the design point routed by an accuracy
    /// SLO (see [`Self::serve_dct_slo`]).
    pub fn serve_edge_slo(&self, img: &Image, slo: &AccuracySlo)
                          -> Result<AppResponse, RouteError> {
        let e = self.route_slo(slo)?;
        Ok(self.serve_edge_at(img, Some(e.design.family), e.design.k))
    }

    fn serve_edge_at(&self, img: &Image, family: Option<Family>, k: u32)
                     -> AppResponse {
        let t0 = Instant::now();
        let mut g = CoordinatorGemm::with_family(self, family, k);
        let e = edge::pipeline(&mut g, img);
        // the exact reference is family-independent (k = 0 drops every
        // approximate column in every registered family)
        let mut g0 = CoordinatorGemm::new(self, 0);
        let quality = if k == 0 {
            f64::INFINITY
        } else {
            let e0 = edge::pipeline(&mut g0, img);
            psnr(&e0.data, &e.data)
        };
        self.finish_app(AppKind::Edge, e, quality, t0, &[&g, &g0])
    }

    /// Serve one BDCN-lite CNN edge request: cascade blocks 0-1 run at
    /// level `k`, blocks 2-3 exact (the paper's Fig. 12 hybrid scheme).
    /// `psnr_db` is approximate-vs-exact through the same served path.
    pub fn serve_bdcn(&self, blocks: &[bdcn::Block], img: &Image, k: u32)
                      -> AppResponse {
        let t0 = Instant::now();
        let mut ga = CoordinatorGemm::new(self, k);
        let mut ge = CoordinatorGemm::new(self, 0);
        let e = bdcn::forward(&mut ga, &mut ge, blocks, img);
        let mut gr = CoordinatorGemm::new(self, 0);
        let quality = if k == 0 {
            f64::INFINITY
        } else {
            let e0 = bdcn::forward(&mut gr, &mut ge, blocks, img);
            psnr(&e0.data, &e.data)
        };
        self.finish_app(AppKind::Bdcn, e, quality, t0, &[&ga, &ge, &gr])
    }

    /// Serve one quantized CNN inference batch ([`crate::nn`]) under
    /// `plan`: every GEMM-bearing layer runs at its own resolved design
    /// point through the worker pool — one [`CoordinatorGemm`] per
    /// layer, so each layer's metered energy is separable. SLO slots
    /// route through [`Self::route_slo`] (counted in the SLO stats);
    /// a malformed or unsatisfiable per-layer SLO refuses the whole
    /// batch typed, before any GEMM runs.
    ///
    /// [`Network::forward`](nn::Network::forward) stacks the batch into
    /// one GEMM per layer, so consecutive batch tiles share the layer's
    /// B panel and coalesce in the workers
    /// ([`ServiceStats::coalesced_calls`]).
    ///
    /// Returns the [`AppResponse`] (logits as a `batch x 10` image;
    /// `sa_stats` additionally includes the exact reference run, like
    /// the other served apps) and the per-layer [`nn::NnStats`]
    /// breakdown, whose `total_energy_fj` covers the plan's own run
    /// only.
    pub fn serve_nn(&self, net: &nn::Network, batch: &[Image],
                    plan: &nn::InferPlan)
                    -> Result<(AppResponse, nn::NnStats), RouteError> {
        let t0 = Instant::now();
        let points = plan.resolve_with(&mut |s| self.route_slo(s))?;
        let n = net.n_gemm_layers();
        assert_eq!(points.len(), n, "plan/network slot mismatch");
        let mut gs: Vec<CoordinatorGemm<'_>> = points
            .iter()
            .map(|&(f, k)| CoordinatorGemm::with_family(self, f, k))
            .collect();
        let mut geoms = vec![(0usize, 0usize, 0usize); n];
        let logits = net.forward(batch, &mut |slot, a, b, m, kk, nc| {
            geoms[slot] = (m, kk, nc);
            gs[slot].gemm(a, b, m, kk, nc)
        });
        // quality vs the exact reference, served through the same path
        // (family-independent: k = 0 is exact in every family)
        let mut g0 = CoordinatorGemm::new(self, 0);
        let (psnr_db, top1) = if points.iter().all(|&(_, k)| k == 0) {
            (f64::INFINITY, 1.0)
        } else {
            let exact = net.forward(batch, &mut |_, a, b, m, kk, nc| {
                g0.gemm(a, b, m, kk, nc)
            });
            nn::quality(&logits, &exact)
        };
        let names = net.gemm_layer_names();
        let mut layers = Vec::with_capacity(n);
        let mut total_energy_fj = 0.0f64;
        for (i, g) in gs.iter().enumerate() {
            let (m, kk, nc) = geoms[i];
            total_energy_fj += g.stats.energy_fj;
            layers.push(nn::LayerStat {
                name: names[i],
                family: points[i].0,
                k: points[i].1,
                m,
                kk,
                nn: nc,
                macs: g.stats.macs,
                energy_fj: g.stats.energy_fj,
                metered_macs: g.stats.metered_macs,
            });
        }
        let nstats = nn::NnStats {
            plan: plan.name.clone(),
            batch: batch.len(),
            layers,
            total_energy_fj,
            logits: logits.clone(),
            logit_psnr_db: psnr_db,
            top1_match: top1,
        };
        let out = nn::logits_image(&logits, batch.len());
        let mut grefs: Vec<&CoordinatorGemm<'_>> = gs.iter().collect();
        grefs.push(&g0);
        let resp = self.finish_app(AppKind::Nn, out, psnr_db, t0, &grefs);
        Ok((resp, nstats))
    }

    /// Dispatch by [`AppKind`] for the weight-free apps (`Bdcn` needs
    /// its trained blocks — use [`Self::serve_bdcn`]). `Nn` serves the
    /// checked-in [`nn::default_network`] on a single-image batch under
    /// the [`nn::InferPlan::hybrid_k`] plan (exact first/last, interior
    /// at `k` — the wire semantics of a plain-`k` inference request).
    pub fn call_app(&self, app: AppKind, img: &Image, k: u32)
                    -> Option<AppResponse> {
        match app {
            AppKind::Dct => Some(self.serve_dct(img, k)),
            AppKind::Edge => Some(self.serve_edge(img, k)),
            AppKind::Bdcn => None,
            AppKind::Nn => {
                let net = nn::default_network();
                let plan = nn::InferPlan::hybrid_k(k, net.n_gemm_layers());
                let (resp, _) = self
                    .serve_nn(net, std::slice::from_ref(img), &plan)
                    .expect("SLO-free plan cannot fail routing");
                Some(resp)
            }
        }
    }

    /// [`Self::call_app`] with the design point routed by an accuracy
    /// SLO. `Ok(None)` keeps `call_app`'s meaning (the app needs
    /// weights); a routing failure is the typed outer error.
    pub fn call_app_slo(&self, app: AppKind, img: &Image, slo: &AccuracySlo)
                        -> Result<Option<AppResponse>, RouteError> {
        match app {
            AppKind::Dct => self.serve_dct_slo(img, slo).map(Some),
            AppKind::Edge => self.serve_edge_slo(img, slo).map(Some),
            AppKind::Bdcn => {
                // validate + count the SLO even though the app itself
                // is unservable without weights, so refusal semantics
                // stay uniform
                self.route_slo(slo)?;
                Ok(None)
            }
            AppKind::Nn => {
                // per-layer SLO plan: exact first/last, every interior
                // layer routed (and counted) independently
                let net = nn::default_network();
                let plan =
                    nn::InferPlan::slo_mixed(*slo, net.n_gemm_layers());
                self.serve_nn(net, std::slice::from_ref(img), &plan)
                    .map(|(resp, _)| Some(resp))
            }
        }
    }

    fn finish_app(&self, app: AppKind, out: Image, psnr_db: f64,
                  t0: Instant, gs: &[&CoordinatorGemm<'_>]) -> AppResponse {
        let latency_us = t0.elapsed().as_secs_f64() * 1e6;
        let mut sa_stats = SaStats::default();
        let mut gemm_requests = 0;
        for g in gs {
            sa_stats.merge(&g.stats);
            gemm_requests += g.requests;
        }
        {
            let mut s = self.stats.rotate().lock().unwrap();
            let a = s.app_mut(app);
            a.requests += 1;
            a.gemm_requests += gemm_requests;
            a.total_latency_us += latency_us;
            a.max_latency_us = a.max_latency_us.max(latency_us);
            a.energy_fj += sa_stats.energy_fj;
            if psnr_db.is_finite() {
                a.psnr_sum_db += psnr_db;
                a.psnr_samples += 1;
            }
        }
        AppResponse { app, out, psnr_db, latency_us, gemm_requests, sa_stats }
    }

    /// Deterministic teardown: close the queue, let every worker drain
    /// the tiles already accepted, and join them all. Also runs on
    /// `Drop`, so a `Coordinator` can never leak parked worker threads —
    /// even when dropped with the queue saturated (tested in
    /// `coordinator_invariance.rs`).
    pub fn shutdown(mut self) {
        self.teardown();
    }

    fn teardown(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Per-worker state shared by the software (`Word`/`Lut`) devices: the
/// reusable cache-blocked engine (owns the packing scratch — no
/// per-request allocation) plus the A-stacking buffer for coalesced
/// dispatches.
struct SwDevice {
    eng: BlockedGemm,
    stack_a: Vec<i64>,
}

impl SwDevice {
    fn new() -> Box<Self> {
        // single_threaded: the worker pool is the parallelism — a nested
        // per-call fan-out on large coalesced GEMMs would oversubscribe
        // the host and allocate per-thread scratch on every dispatch.
        // Block sizes follow the process-wide pin (CLI override or
        // startup autotune) so the serving path runs what was tuned.
        Box::new(SwDevice {
            eng: BlockedGemm::single_threaded(crate::gemm::effective_blocks()),
            stack_a: Vec::new(),
        })
    }
}

enum Device {
    Word {
        pc: PeConfig,
        /// Per-worker memo of the process-wide shared energy tables,
        /// keyed by the request's routed design point `(family, k)`
        /// (`None` = not tabulable → the request runs unmetered).
        etables: HashMap<(Family, u32), Option<Arc<EnergyLut>>>,
        sw: Box<SwDevice>,
    },
    Lut {
        pc: PeConfig,
        /// Per-worker memo of the process-wide shared tables, keyed by
        /// the request's routed design point `(family, k)` (`None` = not
        /// LUT-compilable, word-model fallback). The `Arc`s point into
        /// `lut::cached`'s global map, so workers share one table per
        /// design point.
        tables: HashMap<(Family, u32), Option<Arc<ProductLut>>>,
        /// Energy-table memo, same keying (see `Device::Word`).
        etables: HashMap<(Family, u32), Option<Arc<EnergyLut>>>,
        /// MACs served without the bit-plane walk since the last drain.
        lut_macs: u64,
        sw: Box<SwDevice>,
    },
    Systolic {
        pc: PeConfig,
        /// One metered array per design point served so far: the
        /// gate-netlist meter ([`Systolic::enable_meter`]) is built once
        /// per `(family, k)`, not per switch (mixed traffic — e.g. the
        /// app endpoints' approx + exact-reference runs — alternates
        /// every request).
        arrays: HashMap<(Family, u32), Box<Systolic>>,
    },
    Pjrt {
        rt: Runtime,
        exe: std::sync::Arc<crate::runtime::Executable>,
    },
}

fn make_device(cfg: &CoordinatorConfig) -> Device {
    match cfg.backend {
        BackendKind::Word => {
            Device::Word {
                pc: PeConfig::new(cfg.n_bits, true, cfg.family, 0),
                etables: HashMap::new(),
                sw: SwDevice::new(),
            }
        }
        BackendKind::Lut => {
            Device::Lut {
                pc: PeConfig::new(cfg.n_bits, true, cfg.family, 0),
                tables: HashMap::new(),
                etables: HashMap::new(),
                lut_macs: 0,
                sw: SwDevice::new(),
            }
        }
        BackendKind::Systolic => {
            Device::Systolic {
                pc: PeConfig::new(cfg.n_bits, true, cfg.family, 0),
                arrays: HashMap::new(),
            }
        }
        BackendKind::Pjrt => {
            let rt = Runtime::new(&Runtime::default_artifacts_dir())
                .expect("PJRT runtime");
            let exe = rt.load("axmm_b16").expect("axmm_b16 artifact");
            Device::Pjrt { rt, exe }
        }
    }
}

fn worker_loop(cfg: CoordinatorConfig, wid: usize,
               rx: Arc<Mutex<Receiver<TileJob>>>,
               shared: Shared, stats: Arc<StatsStripes>) {
    let mut device = make_device(&cfg);
    // every worker owns one stats stripe: dispatch/completion counters
    // commit without contending with the other workers
    let my = &stats.stripes[wid % stats.stripes.len()];
    loop {
        // pull a batch (first blocks, rest opportunistic). The drain is
        // MAC-budgeted: once the pulled work reaches `batch_macs` the
        // worker stops taking more, so the row-block tiles of one
        // fanned-out request spread across idle workers instead of all
        // landing in the first worker's batch; small tiles stay far
        // under budget and still coalesce up to `batch` deep.
        let mut batch = Vec::with_capacity(cfg.batch);
        {
            let rxl = rx.lock().unwrap();
            match rxl.recv() {
                Ok(job) => batch.push(job),
                Err(_) => return, // queue closed
            }
            let mut pulled_macs = batch[0].macs();
            while batch.len() < cfg.batch && pulled_macs < cfg.batch_macs {
                match rxl.try_recv() {
                    Ok(job) => {
                        pulled_macs += job.macs();
                        batch.push(job);
                    }
                    Err(_) => break,
                }
            }
        }
        let t_exec = Instant::now();
        let (results, device_calls) = execute_batch(&cfg, &mut device, &batch);
        let exec_us = t_exec.elapsed().as_secs_f64() * 1e6;
        {
            let mut s = my.lock().unwrap();
            s.worker_dispatches += 1;
            s.dispatched_tiles += batch.len() as u64;
            s.max_dispatch_tiles = s.max_dispatch_tiles.max(batch.len() as u64);
            s.coalesced_calls += device_calls;
            s.dispatch_exec_us += exec_us;
            if let Device::Lut { lut_macs, .. } = &mut device {
                if *lut_macs > 0 {
                    s.lut_macs += *lut_macs;
                    *lut_macs = 0;
                }
            }
        }
        // commit results: each job locks only its request's stripe, so
        // workers completing unrelated requests never serialize here
        for (job, (tile, tstats)) in batch.iter().zip(results) {
            let (lock, cvar) = shared.stripe(job.req_id);
            let mut map = lock.lock().unwrap();
            let p = map.get_mut(&job.req_id).expect("pending entry");
            for i in 0..job.th {
                for j in 0..job.tw {
                    p.out[(job.ti + i) * p.nn + job.tj + j] = tile[i * job.tw + j];
                }
            }
            p.stats.merge(&tstats);
            p.remaining -= 1;
            if p.remaining == 0 {
                let latency_us = p.t_submit.elapsed().as_secs_f64() * 1e6;
                let resp = GemmResponse {
                    id: job.req_id,
                    out: std::mem::take(&mut p.out),
                    m: p.m,
                    nn: p.nn,
                    latency_us,
                    tiles: p.stats.tiles.max(1),
                    sa_stats: p.stats,
                };
                let mut s = my.lock().unwrap();
                s.requests += 1;
                s.tiles += resp.sa_stats.tiles.max(1);
                s.total_latency_us += latency_us;
                s.max_latency_us = s.max_latency_us.max(latency_us);
                s.record_latency(latency_us);
                s.sim_cycles += resp.sa_stats.total_cycles();
                s.sim_macs += resp.sa_stats.macs;
                s.sim_toggles += resp.sa_stats.toggles;
                s.energy_fj += resp.sa_stats.energy_fj;
                s.metered_macs += resp.sa_stats.metered_macs;
                drop(s);
                p.done = Some(resp);
                cvar.notify_all();
            }
        }
    }
}

/// Group batch indices by shared B panel: tiles of the same request with
/// the same output-column origin, inner dimension, tile width and design
/// point were carved from the same B region, so their panels are
/// identical and their A panels can be stacked row-wise into one GEMM.
/// Returns groups in first-seen order; every batch index appears in
/// exactly one group.
fn coalesce(batch: &[TileJob]) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut index: HashMap<(u64, usize, usize, usize, Family, u32), usize> =
        HashMap::new();
    for (i, job) in batch.iter().enumerate() {
        let key = (job.req_id, job.tj, job.kk, job.tw, job.family, job.k);
        match index.get(&key) {
            Some(&g) => groups[g].push(i),
            None => {
                index.insert(key, groups.len());
                groups.push(vec![i]);
            }
        }
    }
    groups
}

/// Execute one coalesced group on a software device. `table` is the
/// worker's memoized LUT handle for the group's `k` (`None` = word
/// path), `elut` its memoized energy table (`None` = unmetered).
/// Returns the stacked result rows (`sum of th` x `tw`) plus the
/// group's metered femtojoules.
fn run_sw_group(sw: &mut SwDevice, pc2: &PeConfig,
                table: Option<&ProductLut>, elut: Option<Arc<EnergyLut>>,
                batch: &[TileJob], group: &[usize]) -> (Vec<i64>, f64) {
    let first = &batch[group[0]];
    // singleton groups (nothing to coalesce) skip the stacking copy and
    // feed the tile's own A panel straight to the engine
    let (a, total_th): (&[i64], usize) = if group.len() == 1 {
        (&first.a_panel, first.th)
    } else {
        sw.stack_a.clear();
        for &i in group {
            debug_assert!(Arc::ptr_eq(&batch[i].b_panel, &first.b_panel)
                          || batch[i].b_panel == first.b_panel,
                          "coalesce key bug");
            sw.stack_a.extend_from_slice(&batch[i].a_panel);
        }
        (&sw.stack_a, group.iter().map(|&i| batch[i].th).sum())
    };
    sw.eng.set_meter(elut);
    let out = match table {
        Some(t) => sw.eng.matmul_lut(t, a, &first.b_panel,
                                     total_th, first.kk, first.tw),
        None => sw.eng.matmul_word(pc2, a, &first.b_panel,
                                   total_th, first.kk, first.tw),
    };
    let energy_fj = sw.eng.take_energy_fj();
    (out, energy_fj)
}

/// Scatter a stacked group result back into per-tile `(tile, stats)`
/// slots aligned with the batch order. The group's metered energy lands
/// on its first tile (every tile of a group belongs to one request, so
/// the request-level sum is exact); per-tile meter coverage is recorded
/// when `metered`.
fn scatter_group(batch: &[TileJob], group: &[usize], stacked: &[i64],
                 group_fj: f64, metered: bool,
                 results: &mut [Option<(Vec<i64>, SaStats)>]) {
    let tw = batch[group[0]].tw;
    let mut row = 0;
    for (gi, &i) in group.iter().enumerate() {
        let job = &batch[i];
        let tile = stacked[row * tw..(row + job.th) * tw].to_vec();
        row += job.th;
        let macs = (job.th * job.kk * job.tw) as u64;
        results[i] = Some((tile, SaStats {
            tiles: 1,
            macs,
            energy_fj: if gi == 0 { group_fj } else { 0.0 },
            metered_macs: if metered { macs } else { 0 },
            ..Default::default()
        }));
    }
}

/// Execute a pulled batch on the worker's device. Returns per-tile
/// results aligned with `batch` order plus the number of device
/// executions after coalescing (== `batch.len()` on the per-tile
/// `Systolic`/`Pjrt` devices).
fn execute_batch(cfg: &CoordinatorConfig, device: &mut Device,
                 batch: &[TileJob]) -> (Vec<(Vec<i64>, SaStats)>, u64) {
    match device {
        Device::Word { pc, etables, sw } => {
            let groups = coalesce(batch);
            let mut results: Vec<Option<(Vec<i64>, SaStats)>> =
                (0..batch.len()).map(|_| None).collect();
            for group in &groups {
                let first = &batch[group[0]];
                let mut pc2 = *pc;
                pc2.family = first.family;
                pc2.k = first.k;
                let elut = etables.entry((first.family, first.k))
                    .or_insert_with(|| energy::cached(&pc2))
                    .clone();
                let metered = elut.is_some();
                let (stacked, fj) =
                    run_sw_group(sw, &pc2, None, elut, batch, group);
                scatter_group(batch, group, &stacked, fj, metered,
                              &mut results);
            }
            (results.into_iter().map(|r| r.expect("group cover")).collect(),
             groups.len() as u64)
        }
        Device::Lut { pc, tables, etables, lut_macs, sw } => {
            let groups = coalesce(batch);
            let mut results: Vec<Option<(Vec<i64>, SaStats)>> =
                (0..batch.len()).map(|_| None).collect();
            for group in &groups {
                let first = &batch[group[0]];
                let mut pc2 = *pc;
                pc2.family = first.family;
                pc2.k = first.k;
                let table = tables.entry((first.family, first.k))
                    .or_insert_with(|| lut::cached(&pc2))
                    .clone();
                if table.is_some() {
                    let total_th: usize =
                        group.iter().map(|&i| batch[i].th).sum();
                    *lut_macs += (total_th * first.kk * first.tw) as u64;
                }
                let elut = etables.entry((first.family, first.k))
                    .or_insert_with(|| energy::cached(&pc2))
                    .clone();
                let metered = elut.is_some();
                let (stacked, fj) = run_sw_group(sw, &pc2, table.as_deref(),
                                                 elut, batch, group);
                scatter_group(batch, group, &stacked, fj, metered,
                              &mut results);
            }
            (results.into_iter().map(|r| r.expect("group cover")).collect(),
             groups.len() as u64)
        }
        Device::Systolic { pc, arrays } => {
            let out = batch.iter().map(|job| {
                let sa = arrays.entry((job.family, job.k)).or_insert_with(|| {
                    let mut pc2 = *pc;
                    pc2.family = job.family;
                    pc2.k = job.k;
                    let mut sa = Systolic::square(pc2, cfg.sa_size);
                    // gate-level ground truth on the slow path
                    sa.enable_meter();
                    Box::new(sa)
                });
                sa.gemm(&job.a_panel, &job.b_panel, job.th, job.kk, job.tw)
            }).collect();
            (out, batch.len() as u64)
        }
        Device::Pjrt { rt, exe } => {
            (execute_batch_pjrt(rt, exe, batch), batch.len() as u64)
        }
    }
}

/// Execute tiles on the AOT `axmm_b16` artifact: (16, 8, 8) @ (16, 8, 8)
/// per call, K split into chunks of 8 with outside summation.
fn execute_batch_pjrt(rt: &Runtime, exe: &crate::runtime::Executable,
                      batch: &[TileJob]) -> Vec<(Vec<i64>, SaStats)> {
    const B: usize = 16;
    const T: usize = 8;
    // flatten every (job, k-chunk) pair into slots
    struct Slot {
        job_idx: usize,
    }
    let mut slots: Vec<Slot> = Vec::new();
    let mut a_buf: Vec<i32> = Vec::new();
    let mut b_buf: Vec<i32> = Vec::new();
    let mut acc: Vec<Vec<i64>> = batch.iter()
        .map(|_| vec![0i64; T * T])
        .collect();
    let mut k_level = 0i32;
    for (ji, job) in batch.iter().enumerate() {
        k_level = job.k as i32; // homogeneous within a batch in practice
        let chunks = job.kk.div_ceil(T);
        for c in 0..chunks {
            slots.push(Slot { job_idx: ji });
            // A chunk: T x T (zero-padded)
            for i in 0..T {
                for t in 0..T {
                    let kidx = c * T + t;
                    let v = if i < job.th && kidx < job.kk {
                        job.a_panel[i * job.kk + kidx] as i32
                    } else { 0 };
                    a_buf.push(v);
                }
            }
            for t in 0..T {
                for j in 0..T {
                    let kidx = c * T + t;
                    let v = if j < job.tw && kidx < job.kk {
                        job.b_panel[kidx * job.tw + j] as i32
                    } else { 0 };
                    b_buf.push(v);
                }
            }
        }
    }
    // run in groups of B slots
    let mut s = 0;
    while s < slots.len() {
        let g = (slots.len() - s).min(B);
        let mut a_in = vec![0i32; B * T * T];
        let mut b_in = vec![0i32; B * T * T];
        a_in[..g * T * T].copy_from_slice(&a_buf[s * T * T..(s + g) * T * T]);
        b_in[..g * T * T].copy_from_slice(&b_buf[s * T * T..(s + g) * T * T]);
        let outs = rt.execute_i32(exe, &[
            TensorI32::new(vec![B, T, T], a_in),
            TensorI32::new(vec![B, T, T], b_in),
            TensorI32::scalar1(k_level),
        ]).expect("pjrt execute");
        let out = &outs[0];
        for gi in 0..g {
            let slot = &slots[s + gi];
            for e in 0..T * T {
                acc[slot.job_idx][e] += out.data[gi * T * T + e] as i64;
            }
        }
        s += g;
    }
    batch.iter().enumerate().map(|(ji, job)| {
        let mut tile = vec![0i64; job.th * job.tw];
        for i in 0..job.th {
            for j in 0..job.tw {
                tile[i * job.tw + j] = acc[ji][i * T + j];
            }
        }
        (tile, SaStats { tiles: 1, macs: (job.th * job.kk * job.tw) as u64,
                         ..Default::default() })
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ints(seed: u64, len: usize) -> Vec<i64> {
        let mut s = seed | 1;
        (0..len).map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s as i64 & 255) - 128
        }).collect()
    }

    fn exact(a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize) -> Vec<i64> {
        let mut out = vec![0i64; m * nn];
        for i in 0..m {
            for j in 0..nn {
                out[i * nn + j] = (0..kk).map(|t| a[i * kk + t] * b[t * nn + j]).sum();
            }
        }
        out
    }

    #[test]
    fn latency_percentile_is_nan_safe() {
        // A poisoned (NaN) sample must not panic the percentile sort:
        // total_cmp orders NaN past every finite sample, so the finite
        // percentiles stay meaningful and only the top end reports NaN.
        let mut r = LatencyRing::default();
        r.record(5.0);
        r.record(f64::NAN);
        r.record(1.0);
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(0.5), 5.0);
        assert!(r.percentile(1.0).is_nan());
    }

    #[test]
    fn service_stats_fold_matches_single_stripe_totals() {
        // Folding split stripes must equal recording into one block.
        let stripes = StatsStripes::new(3);
        for (i, lat) in [120.0, 80.0, 240.0, 60.0].iter().enumerate() {
            let mut s = stripes.stripes[i % 3].lock().unwrap();
            s.requests += 1;
            s.tiles += 2;
            s.total_latency_us += lat;
            s.max_latency_us = s.max_latency_us.max(*lat);
            s.record_latency(*lat);
            s.sim_macs += 10;
            s.energy_fj += 1.5;
        }
        let total = stripes.fold();
        assert_eq!(total.requests, 4);
        assert_eq!(total.tiles, 8);
        assert_eq!(total.sim_macs, 40);
        assert!((total.total_latency_us - 500.0).abs() < 1e-9);
        assert_eq!(total.max_latency_us, 240.0);
        assert!((total.energy_fj - 6.0).abs() < 1e-9);
        assert_eq!(total.latency.recorded(), 4);
        assert_eq!(total.latency_percentile(1.0), 240.0);
    }

    #[test]
    fn backend_names_round_trip() {
        for b in [BackendKind::Word, BackendKind::Lut, BackendKind::Systolic,
                  BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(b.name()), Some(b));
        }
        assert_eq!(BackendKind::parse("tpu"), None);
    }

    #[test]
    fn exact_requests_match_integer_gemm() {
        for backend in [BackendKind::Word, BackendKind::Lut,
                        BackendKind::Systolic] {
            let c = Coordinator::new(CoordinatorConfig {
                backend, workers: 3, ..Default::default()
            });
            let (m, kk, nn) = (20, 16, 24);
            let a = ints(1, m * kk);
            let b = ints(2, kk * nn);
            let resp = c.call(GemmRequest { a: a.clone(), b: b.clone(),
                                            m, kk, nn, k: 0, ..Default::default() });
            assert_eq!(resp.out, exact(&a, &b, m, kk, nn), "{backend:?}");
            c.shutdown();
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let (m, kk, nn) = (33, 10, 17);
        let a = ints(3, m * kk);
        let b = ints(4, kk * nn);
        let mut results = Vec::new();
        for workers in [1usize, 2, 7] {
            let c = Coordinator::new(CoordinatorConfig {
                workers, backend: BackendKind::Word, ..Default::default()
            });
            let resp = c.call(GemmRequest { a: a.clone(), b: b.clone(),
                                            m, kk, nn, k: 5, ..Default::default() });
            results.push(resp.out);
            c.shutdown();
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[0], results[2]);
    }

    #[test]
    fn concurrent_requests_complete() {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 4, backend: BackendKind::Word, ..Default::default()
        });
        let mut ids = Vec::new();
        for r in 0..12u64 {
            let (m, kk, nn) = (8 + r as usize, 8, 9 + r as usize);
            ids.push((r, c.submit(GemmRequest {
                a: ints(r * 2 + 1, m * kk),
                b: ints(r * 2 + 2, kk * nn),
                m, kk, nn, k: (r % 8) as u32,
                ..Default::default()
            })));
        }
        for (_, id) in ids {
            let resp = c.wait(id);
            assert!(!resp.out.is_empty());
        }
        let s = c.stats();
        assert_eq!(s.requests, 12);
        assert!(s.tiles >= 12);
        c.shutdown();
    }

    #[test]
    fn systolic_backend_reports_cycles() {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 2, backend: BackendKind::Systolic, ..Default::default()
        });
        let (m, kk, nn) = (16, 8, 16);
        let resp = c.call(GemmRequest {
            a: ints(5, m * kk), b: ints(6, kk * nn), m, kk, nn, k: 0,
            ..Default::default()
        });
        assert!(resp.sa_stats.total_cycles() > 0);
        assert!(resp.sa_stats.macs > 0);
        // the systolic device meters by direct netlist replay
        assert_eq!(resp.sa_stats.metered_macs, resp.sa_stats.macs);
        assert!(resp.energy_uj() > 0.0 && resp.avg_power_uw() > 0.0);
        c.shutdown();
    }

    #[test]
    fn approximate_requests_route_per_request_k() {
        for backend in [BackendKind::Word, BackendKind::Lut] {
            let c = Coordinator::new(CoordinatorConfig {
                workers: 2, backend, ..Default::default()
            });
            let (m, kk, nn) = (8, 8, 8);
            let a = ints(7, m * kk);
            let b = ints(8, kk * nn);
            let r0 = c.call(GemmRequest { a: a.clone(), b: b.clone(),
                                          m, kk, nn, k: 0, ..Default::default() });
            let r7 = c.call(GemmRequest { a: a.clone(), b: b.clone(),
                                          m, kk, nn, k: 7, ..Default::default() });
            assert_eq!(r0.out, exact(&a, &b, m, kk, nn), "{backend:?}");
            assert_ne!(r0.out, r7.out, "{backend:?}: k=7 must differ");
            c.shutdown();
        }
    }

    #[test]
    fn app_endpoints_report_per_app_stats_and_percentiles() {
        use crate::apps::image::scene;
        let c = Coordinator::new(CoordinatorConfig {
            workers: 3, backend: BackendKind::Lut, ..Default::default()
        });
        let img = scene(32, 32);
        let d0 = c.serve_dct(&img, 0);
        assert_eq!(d0.app, AppKind::Dct);
        assert_eq!((d0.out.h, d0.out.w), (32, 32));
        assert!(d0.psnr_db > 30.0, "exact DCT quality: {}", d0.psnr_db);
        assert!(d0.gemm_requests >= 4, "4 GEMM stages"); // fwd x2 + inv x2
        let e5 = c.serve_edge(&img, 5);
        assert_eq!((e5.out.h, e5.out.w), (30, 30));
        assert!(e5.psnr_db.is_finite(), "approx-vs-exact must be finite");
        let e0 = c.serve_edge(&img, 0);
        assert!(e0.psnr_db.is_infinite(), "exact is self-referential");
        let s = c.stats();
        assert_eq!(s.app(AppKind::Dct).requests, 1);
        assert_eq!(s.app(AppKind::Edge).requests, 2);
        assert_eq!(s.dct.psnr_samples, 1); // dct quality is vs input
        assert_eq!(s.edge.psnr_samples, 1); // only the k=5 run is finite
        assert!(s.edge.mean_psnr_db() > 0.0);
        assert!(s.app(AppKind::Edge).mean_latency_us() > 0.0);
        // GEMM-level percentiles: monotone and within [min, max]
        let (p50, p99) = (s.latency_percentile(0.5), s.latency_percentile(0.99));
        assert!(p50 > 0.0 && p50 <= p99 && p99 <= s.max_latency_us);
        assert_eq!(s.app(AppKind::Bdcn).requests, 0);
        c.shutdown();
    }

    #[test]
    fn served_dct_is_bit_identical_to_single_threaded() {
        use crate::apps::image::scene;
        use crate::apps::WordGemm;
        let img = scene(24, 24);
        let c = Coordinator::new(CoordinatorConfig {
            workers: 4, backend: BackendKind::Word, ..Default::default()
        });
        for k in [0u32, 5] {
            let cfg = PeConfig::new(8, true, Family::Proposed, k);
            let mut wg = WordGemm { cfg };
            let (want, _) = crate::apps::dct::pipeline(&mut wg, &img);
            let got = c.serve_dct(&img, k);
            assert_eq!(got.out.data, want.data, "k={k}");
        }
        c.shutdown();
    }

    #[test]
    fn lut_backend_reports_lut_macs_and_cache_activity() {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 2, backend: BackendKind::Lut, ..Default::default()
        });
        let (m, kk, nn) = (16, 8, 16);
        let resp = c.call(GemmRequest {
            a: ints(9, m * kk), b: ints(10, kk * nn), m, kk, nn, k: 3,
            ..Default::default()
        });
        assert!(resp.macs_per_sec() > 0.0);
        let s = c.stats();
        assert_eq!(s.lut_macs, (m * kk * nn) as u64);
        assert!(s.lut_builds >= 1);
        assert!(s.mean_latency_us() > 0.0);
        c.shutdown();
    }

    #[test]
    fn served_requests_carry_data_dependent_energy() {
        // every software-served request at a tabulable design point is
        // fully metered, and the fleet totals add up
        for backend in [BackendKind::Word, BackendKind::Lut] {
            let c = Coordinator::new(CoordinatorConfig {
                workers: 3, backend, ..Default::default()
            });
            let (m, kk, nn) = (20, 12, 16);
            let mut total = 0.0;
            for (seed, k) in [(1u64, 0u32), (3, 4)] {
                let resp = c.call(GemmRequest {
                    a: ints(seed, m * kk), b: ints(seed + 1, kk * nn),
                    m, kk, nn, k,
                    ..Default::default()
                });
                assert_eq!(resp.sa_stats.metered_macs, resp.sa_stats.macs,
                           "{backend:?} k={k}: full meter coverage");
                assert!(resp.energy_uj() > 0.0, "{backend:?} k={k}");
                total += resp.sa_stats.energy_fj;
            }
            let s = c.stats();
            assert_eq!(s.metered_macs, 2 * (m * kk * nn) as u64);
            assert!((s.energy_fj - total).abs() < 1e-9 * total.max(1.0));
            assert!(s.total_energy_uj() > 0.0 && s.mean_mac_fj() > 0.0);
            c.shutdown();
        }
    }

    #[test]
    fn intra_request_fanout_is_bit_identical_and_budgeted() {
        // One request, forced into 8 single-tile dispatches: with a
        // 1-MAC budget every batch stops after its blocking pull, so
        // max_dispatch_tiles pins the budget and the result must still
        // be bit-identical (and the metered energy equal to within
        // summation-order rounding) vs one worker serving one big tile.
        let (m, kk, nn) = (64, 12, 48);
        let a = ints(31, m * kk);
        let b = ints(32, kk * nn);
        let fan = Coordinator::new(CoordinatorConfig {
            workers: 4, backend: BackendKind::Word,
            sw_tile: Some((8, 48)), batch_macs: 1, ..Default::default()
        });
        let rf = fan.call(GemmRequest { a: a.clone(), b: b.clone(),
                                        m, kk, nn, k: 4, ..Default::default() });
        let sf = fan.stats();
        fan.shutdown();
        assert_eq!(rf.tiles, 8, "64 rows / 8-row tiles");
        assert_eq!(sf.dispatched_tiles, 8);
        assert_eq!(sf.max_dispatch_tiles, 1, "MAC budget caps the drain");
        assert_eq!(sf.worker_dispatches, 8);
        let solo = Coordinator::new(CoordinatorConfig {
            workers: 1, backend: BackendKind::Word,
            sw_tile: Some((64, 48)), ..Default::default()
        });
        let rs = solo.call(GemmRequest { a, b, m, kk, nn, k: 4, ..Default::default() });
        solo.shutdown();
        assert_eq!(rf.out, rs.out, "fan-out must be bit-identical");
        assert_eq!(rf.sa_stats.metered_macs, rs.sa_stats.metered_macs);
        let tol = 1e-9 * rs.sa_stats.energy_fj.max(1.0);
        assert!((rf.sa_stats.energy_fj - rs.sa_stats.energy_fj).abs() < tol,
                "per-tile metered energy must sum to the request total");
    }

    #[test]
    fn wide_design_points_serve_unmetered_instead_of_panicking() {
        // n = 16 is beyond the energy tables: the request must degrade
        // to unmetered-with-coverage-recorded (ServiceStats contract),
        // not panic a worker, and the bits must match the word model.
        for backend in [BackendKind::Word, BackendKind::Lut] {
            let c = Coordinator::new(CoordinatorConfig {
                workers: 2, backend, n_bits: 16, ..Default::default()
            });
            let (m, kk, nn) = (12, 9, 40);
            let a = ints(41, m * kk);
            let b = ints(42, kk * nn);
            let resp = c.call(GemmRequest { a: a.clone(), b: b.clone(),
                                            m, kk, nn, k: 3, ..Default::default() });
            let pc = PeConfig::new(16, true, Family::Proposed, 3);
            let want = crate::pe::word::matmul(&pc, &a, &b, m, kk, nn);
            assert_eq!(resp.out, want, "{backend:?}");
            assert!(resp.sa_stats.macs > 0, "{backend:?}");
            assert_eq!(resp.sa_stats.metered_macs, 0,
                       "{backend:?}: wide point has no meter coverage");
            assert_eq!(resp.sa_stats.energy_fj, 0.0, "{backend:?}");
            let s = c.stats();
            assert_eq!(s.metered_macs, 0);
            c.shutdown();
        }
    }

    #[test]
    fn slo_requests_route_to_cheapest_and_count_tiers() {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 2, backend: BackendKind::Word, ..Default::default()
        });
        let (m, kk, nn) = (12, 8, 10);
        let a = ints(51, m * kk);
        let b = ints(52, kk * nn);
        // exact SLO: bits must equal the exact integer product
        let slo = AccuracySlo::exact();
        let r = c.try_call(GemmRequest {
            a: a.clone(), b: b.clone(), m, kk, nn,
            k: 7, // ignored: the SLO routes the design point
            slo: Some(slo), ..Default::default()
        }).unwrap();
        assert_eq!(r.out, exact(&a, &b, m, kk, nn));
        // loose SLO: must serve the same bits as the routed entry's
        // design point run directly
        let loose = AccuracySlo { max_nmed: Some(5e-3), min_psnr_db: None };
        let e = zoo::route(8, true, &loose).unwrap();
        let r2 = c.try_call(GemmRequest {
            a: a.clone(), b: b.clone(), m, kk, nn,
            slo: Some(loose), ..Default::default()
        }).unwrap();
        let pc = PeConfig::from_design(&e.design);
        assert_eq!(r2.out, crate::pe::word::matmul(&pc, &a, &b, m, kk, nn),
                   "SLO-routed bits must match the routed design point");
        let s = c.stats();
        assert_eq!(s.slo_requests, 2);
        assert_eq!(s.slo_exact, 1);
        assert_eq!(s.slo_tier.iter().sum::<u64>(), 2);
        assert_eq!(s.slo_tier[Tier::Exact.idx()], 1);
        assert_eq!(s.slo_tier[e.tier().idx()], 1);
        assert_eq!(s.slo_unsatisfiable, 0);
        c.shutdown();
    }

    #[test]
    fn unroutable_slos_are_refused_typed_not_served() {
        // 16-bit pool: the registry covers only 8-bit signed, so any
        // SLO is a typed Unsatisfiable — and no request must be served
        let c = Coordinator::new(CoordinatorConfig {
            workers: 1, backend: BackendKind::Word, n_bits: 16,
            ..Default::default()
        });
        let err = c.try_call(GemmRequest {
            a: vec![1; 4], b: vec![1; 4], m: 2, kk: 2, nn: 2,
            slo: Some(AccuracySlo::exact()), ..Default::default()
        }).unwrap_err();
        assert!(matches!(err, RouteError::Unsatisfiable { n_bits: 16, .. }));
        // malformed SLO on a routable pool: typed Invalid
        let c8 = Coordinator::new(CoordinatorConfig {
            workers: 1, backend: BackendKind::Word, ..Default::default()
        });
        let err = c8.try_call(GemmRequest {
            a: vec![1; 4], b: vec![1; 4], m: 2, kk: 2, nn: 2,
            slo: Some(AccuracySlo::default()), ..Default::default()
        }).unwrap_err();
        assert!(matches!(err, RouteError::Invalid(_)));
        let s = c.stats();
        assert_eq!(s.requests, 0, "refused requests never execute");
        assert_eq!(s.slo_requests, 1);
        assert_eq!(s.slo_unsatisfiable, 1);
        // Invalid is the caller's bug, not a routing miss: not counted
        assert_eq!(c8.stats().slo_requests, 0);
        c.shutdown();
        c8.shutdown();
    }

    #[test]
    fn family_override_serves_the_zoo_variant_bits() {
        let (m, kk, nn) = (10, 8, 12);
        let a = ints(61, m * kk);
        let b = ints(62, kk * nn);
        for family in [Family::Trunc, Family::Loa] {
            let c = Coordinator::new(CoordinatorConfig {
                workers: 2, backend: BackendKind::Lut, ..Default::default()
            });
            let r = c.call(GemmRequest {
                a: a.clone(), b: b.clone(), m, kk, nn, k: 4,
                family: Some(family), ..Default::default()
            });
            let pc = PeConfig::new(8, true, family, 4);
            let want = crate::pe::word::matmul(&pc, &a, &b, m, kk, nn);
            assert_eq!(r.out, want, "{family:?}");
            // and the override actually changes the arithmetic
            let rd = c.call(GemmRequest {
                a: a.clone(), b: b.clone(), m, kk, nn, k: 4,
                ..Default::default()
            });
            assert_ne!(r.out, rd.out,
                       "{family:?} at k=4 must differ from proposed");
            c.shutdown();
        }
    }

    #[test]
    fn slo_routed_apps_count_and_refuse_like_gemm() {
        use crate::apps::image::scene;
        let c = Coordinator::new(CoordinatorConfig {
            workers: 2, backend: BackendKind::Word, ..Default::default()
        });
        let img = scene(16, 16);
        let loose = AccuracySlo { max_nmed: Some(1e-2), min_psnr_db: None };
        let r = c.serve_edge_slo(&img, &loose).unwrap();
        assert_eq!(r.app, AppKind::Edge);
        let e = zoo::route(8, true, &loose).unwrap();
        // served bits match the routed design point run directly
        let mut g = crate::apps::WordGemm {
            cfg: PeConfig::from_design(&e.design),
        };
        let want = edge::pipeline(&mut g, &img);
        assert_eq!(r.out.data, want.data);
        let bad = AccuracySlo { max_nmed: Some(-3.0), min_psnr_db: None };
        assert!(c.serve_dct_slo(&img, &bad).is_err());
        let s = c.stats();
        assert_eq!(s.slo_requests, 1);
        assert_eq!(s.app(AppKind::Edge).requests, 1);
        c.shutdown();
    }

    #[test]
    fn sw_tile_parses_pins_once_and_yields_to_explicit_config() {
        for bad in ["", "8", "x8", "8x", "0x8", "8x0", "axb", "8x8x8"] {
            assert_eq!(parse_sw_tile(bad), None, "{bad:?}");
        }
        assert_eq!(parse_sw_tile("16x128"), Some((16, 128)));
        // whoever pins first (this override or a concurrent autotune)
        // wins for the process; later pins must not repin. Tile shape
        // is bit-safe, so sharing the pin with other tests is safe.
        let first = if set_sw_tile_override((16, 128)) {
            (16, 128)
        } else {
            effective_sw_tile().expect("a pin exists if override lost")
        };
        assert_eq!(effective_sw_tile(), Some(first));
        assert!(!set_sw_tile_override((1, 1)));
        assert_eq!(effective_sw_tile(), Some(first));
        assert_eq!(autotune_sw_tile(2), first,
                   "autotune returns the pinned value without sweeping");
        // the pin covers configs without an explicit shape ...
        let cfg = CoordinatorConfig {
            backend: BackendKind::Lut, ..Default::default()
        };
        assert_eq!(cfg.tile_shape(), first);
        // ... but an explicit per-config shape still wins
        let cfg = CoordinatorConfig {
            backend: BackendKind::Word, sw_tile: Some((8, 48)),
            ..Default::default()
        };
        assert_eq!(cfg.tile_shape(), (8, 48));
        // and the per-tile device backends ignore it entirely
        let cfg = CoordinatorConfig {
            backend: BackendKind::Systolic, sa_size: 8, ..Default::default()
        };
        assert_eq!(cfg.tile_shape(), (8, 8));
    }

    #[test]
    fn batch_macs_calibration_pins_once_within_bounds() {
        let v = calibrate_batch_macs();
        assert!((1u64 << 16..=1 << 24).contains(&v),
                "calibrated budget out of range: {v}");
        assert_eq!(default_batch_macs(), v);
        assert_eq!(calibrate_batch_macs(), v, "second call returns the pin");
        assert_eq!(CoordinatorConfig::default().batch_macs, v,
                   "new configs pick up the calibrated budget");
    }

    #[test]
    fn app_responses_report_energy_per_image() {
        use crate::apps::image::scene;
        let c = Coordinator::new(CoordinatorConfig {
            workers: 2, backend: BackendKind::Lut, ..Default::default()
        });
        let img = scene(24, 24);
        let r = c.serve_dct(&img, 5);
        assert!(r.energy_uj() > 0.0);
        let s = c.stats();
        assert!(s.dct.mean_energy_uj() > 0.0);
        // energy-vs-quality pair is available at the stats level
        assert!(s.dct.mean_psnr_db() > 0.0);
        c.shutdown();
    }
}
