//! Gate-level netlist constructors for every PE design in the paper's
//! tables, plus the single-cell netlists behind Table II.
//!
//! The PE *grid* netlist has the interface of one accumulate cycle:
//! inputs `a[N], b[N], s[W], k[W]`, outputs `s'[W], k'[W]` — exactly the
//! word-level model's `mac_step` (equivalence is tested bit-for-bit on
//! random vectors). The drain *merge* adder (Kogge-Stone) is built
//! separately: it exists in silicon (area/leakage) but fires once per
//! result, not once per MAC, so it is excluded from per-cycle activity.

use crate::cells::CellKind;
use crate::netlist::{NetId, Netlist};
use crate::Family;

use super::Design;

/// Single-cell netlists (Table II rows). Interface: inputs a, b, cin, sin;
/// outputs carry, sum.
pub fn cell_netlist(kind: CellKind) -> Netlist {
    let mut nl = Netlist::new(kind.name());
    let a = nl.input();
    let b = nl.input();
    let cin = nl.input();
    let sin = nl.input();
    let (c, s) = build_cell(&mut nl, kind, a, b, cin, sin);
    nl.mark_output(c);
    nl.mark_output(s);
    nl
}

/// Instantiate one cell inside a larger netlist. Returns (carry, sum).
pub fn build_cell(nl: &mut Netlist, kind: CellKind, a: NetId, b: NetId,
                  cin: NetId, sin: NetId) -> (NetId, NetId) {
    match kind {
        // conventional exact cells [6]: product gate + textbook FA
        CellKind::ExactPpc => {
            let p = nl.and2(a, b);
            nl.full_adder(p, cin, sin)
        }
        CellKind::ExactNppc => {
            let x = nl.nand2(a, b);
            nl.full_adder(x, cin, sin)
        }
        // proposed exact cells: product gate + mirror adder (MAJ3 carry)
        CellKind::PropExactPpc => {
            let p = nl.and2(a, b);
            nl.mirror_adder(p, cin, sin)
        }
        CellKind::PropExactNppc => {
            let x = nl.nand2(a, b);
            nl.mirror_adder(x, cin, sin)
        }
        // proposed approximate PPC: C = p, S = NOR(NOR(sin,cin), p)
        CellKind::PropApxPpc => {
            let p = nl.and2(a, b);
            let n1 = nl.nor2(sin, cin);
            let s = nl.nor2(n1, p);
            (p, s)
        }
        // proposed approximate NPPC ("NAND-based"): x = NAND(a,b),
        // o = OR(sin,cin), S = NAND(o,x), C = INV(S) = o & x
        CellKind::PropApxNppc => {
            let x = nl.nand2(a, b);
            let o = nl.or2(sin, cin);
            let s = nl.nand2(o, x);
            let c = nl.inv(s);
            (c, s)
        }
        // Waris SiPS'19 [12]: S = XNOR(p, sin), C = cin (wire)
        CellKind::Sips12Ppc => {
            let p = nl.and2(a, b);
            let s = nl.xnor2(p, sin);
            (cin, s)
        }
        CellKind::Sips12Nppc => {
            let x = nl.nand2(a, b);
            let s = nl.xnor2(x, sin);
            (cin, s)
        }
        // Chen NANOARCH'15 [6] inexact: S = ~sin, C = p & cin
        CellKind::Nano6Ppc => {
            let p = nl.and2(a, b);
            let c = nl.and2(p, cin);
            let s = nl.inv(sin);
            (c, s)
        }
        CellKind::Nano6Nppc => {
            let x = nl.nand2(a, b);
            let c = nl.and2(x, cin);
            let s = nl.inv(sin);
            (c, s)
        }
        // AxSA [5]: carry-elided compressor — exact XOR3 sum, C = 0
        CellKind::Axsa5Ppc => {
            let p = nl.and2(a, b);
            let s = nl.xor3(p, cin, sin);
            let z = nl.const0();
            (z, s)
        }
        CellKind::Axsa5Nppc => {
            let x = nl.nand2(a, b);
            let s = nl.xor3(x, cin, sin);
            let z = nl.const0();
            (z, s)
        }
        // truncated: the product gate is removed entirely. PPC positions
        // degenerate to a half adder on (cin, sin); NPPC positions see
        // the dropped product's Baugh-Wooley complement tied high, i.e.
        // a full adder with x = 1: C = OR, S = XNOR.
        CellKind::TruncPpc => nl.half_adder(cin, sin),
        CellKind::TruncNppc => {
            let c = nl.or2(cin, sin);
            let s = nl.xnor2(cin, sin);
            (c, s)
        }
        // LOA: S = product | sin, C = cin (wire — no carry logic at all)
        CellKind::LoaPpc => {
            let p = nl.and2(a, b);
            let s = nl.or2(p, sin);
            (cin, s)
        }
        CellKind::LoaNppc => {
            let x = nl.nand2(a, b);
            let s = nl.or2(x, sin);
            (cin, s)
        }
    }
}

/// Kogge-Stone parallel-prefix adder over two w-bit rails (mod 2^w).
/// Returns the sum nets. ~w log w gates, O(log w) depth — the PE's drain
/// merge path.
pub fn kogge_stone(nl: &mut Netlist, a: &[NetId], b: &[NetId]) -> Vec<NetId> {
    let w = a.len();
    assert_eq!(b.len(), w);
    let mut g: Vec<NetId> = Vec::with_capacity(w);
    let mut p: Vec<NetId> = Vec::with_capacity(w);
    for i in 0..w {
        g.push(nl.and2(a[i], b[i]));
        p.push(nl.xor2(a[i], b[i]));
    }
    let psave = p.clone();
    let mut dist = 1usize;
    while dist < w {
        let (gp, pp) = (g.clone(), p.clone());
        for i in dist..w {
            // G = G_hi | (P_hi & G_lo); P = P_hi & P_lo
            let t = nl.and2(pp[i], gp[i - dist]);
            g[i] = nl.or2(gp[i], t);
            p[i] = nl.and2(pp[i], pp[i - dist]);
        }
        dist *= 2;
    }
    let mut sum = Vec::with_capacity(w);
    sum.push(psave[0]);
    for i in 1..w {
        sum.push(nl.xor2(psave[i], g[i - 1]));
    }
    sum
}

/// Ripple-carry adder (used by the conventional-MAC baselines).
pub fn ripple_adder(nl: &mut Netlist, a: &[NetId], b: &[NetId]) -> Vec<NetId> {
    let mut carry = nl.const0();
    let mut sum = Vec::with_capacity(a.len());
    for i in 0..a.len() {
        let (c, s) = nl.full_adder(a[i], b[i], carry);
        sum.push(s);
        carry = c;
    }
    sum
}

/// A built PE: the per-cycle grid netlist, the drain merge netlist, and
/// bookkeeping for the hardware model.
pub struct PeNetlists {
    /// Per-cycle cell grid (one MAC step: `a, b, s, k -> s', k'`).
    pub grid: Netlist,
    /// Drain merge adder (Kogge-Stone resolve of the two rails).
    pub merge: Netlist,
    /// Operand width in bits.
    pub n: u32,
    /// Accumulator width in bits.
    pub w: u32,
    /// PPC-flavor cells instantiated in the grid.
    pub ppc_cells: u32,
    /// NPPC-flavor cells instantiated in the grid.
    pub nppc_cells: u32,
}

/// Which exact cell flavor a design uses above its approximate region.
fn exact_kinds(optimized: bool) -> (CellKind, CellKind) {
    if optimized {
        (CellKind::PropExactPpc, CellKind::PropExactNppc)
    } else {
        (CellKind::ExactPpc, CellKind::ExactNppc)
    }
}

/// Approximate cell flavor for a family (PPC-position, NPPC-position).
fn approx_kinds(family: Family) -> (CellKind, CellKind) {
    match family {
        Family::Proposed => (CellKind::PropApxPpc, CellKind::PropApxNppc),
        Family::Sips12 => (CellKind::Sips12Ppc, CellKind::Sips12Nppc),
        Family::Nano6 => (CellKind::Nano6Ppc, CellKind::Nano6Nppc),
        Family::Axsa5 => (CellKind::Axsa5Ppc, CellKind::Axsa5Nppc),
        Family::Trunc => (CellKind::TruncPpc, CellKind::TruncNppc),
        Family::Loa => (CellKind::LoaPpc, CellKind::LoaNppc),
    }
}

/// Build the full PE grid netlist for a design point.
///
/// The wiring mirrors `word::mac_step` row for row; the structural
/// equivalence test in this module's `tests` is the proof.
pub fn pe_netlists(d: &Design, w: u32) -> PeNetlists {
    let n = d.n;
    let signed = d.is_signed();
    let mut nl = Netlist::new(&format!("pe_{}_{}b", d.family.name(), n));
    let a: Vec<NetId> = (0..n).map(|_| nl.input()).collect();
    let b: Vec<NetId> = (0..n).map(|_| nl.input()).collect();
    let s_in: Vec<NetId> = (0..w).map(|_| nl.input()).collect();
    let k_in: Vec<NetId> = (0..w).map(|_| nl.input()).collect();

    let mut s_net = s_in.clone();
    let mut k_net = k_in.clone();
    let zero = nl.const0();
    let one = nl.const1();

    let mut ppc = 0u32;
    let mut nppc = 0u32;

    // helper: value-preserving carry insertion with HA ripple
    fn insert_carry(nl: &mut Netlist, k_net: &mut [NetId], zero: NetId,
                    mut w_pos: usize, mut net: NetId) {
        while w_pos < k_net.len() {
            if k_net[w_pos] == zero {
                k_net[w_pos] = net;
                return;
            }
            let (c, s) = nl.half_adder(k_net[w_pos], net);
            k_net[w_pos] = s;
            net = c;
            w_pos += 1;
        }
    }

    // Baugh-Wooley correction constant (signed): tie-high inserts. These
    // columns are >= N > any paper k, i.e. always in the exact region.
    if signed {
        insert_carry(&mut nl, &mut k_net, zero, n as usize, one);
        for bit in (2 * n - 1)..w {
            insert_carry(&mut nl, &mut k_net, zero, bit as usize, one);
        }
    }

    let (ex_ppc, ex_nppc) = exact_kinds(d.optimized_exact);
    let (ax_ppc, ax_nppc) = approx_kinds(d.family);

    for j in 0..n {
        // NPPC weights for this row
        let nppc_of = |wt: u32| -> bool {
            if !signed {
                return false;
            }
            let i = wt - j;
            if j < n - 1 { i == n - 1 } else { i < n - 1 }
        };
        // evaluate all cells against the *current* rails
        let mut new_s: Vec<(usize, NetId)> = Vec::new();
        let mut carries: Vec<(usize, NetId)> = Vec::new();
        for i in 0..n {
            let wt = (i + j) as usize;
            let is_n = nppc_of(i + j);
            let approx = ((i + j) as u32) < d.k;
            let kind = match (approx, is_n) {
                (false, false) => ex_ppc,
                (false, true) => ex_nppc,
                (true, false) => ax_ppc,
                (true, true) => ax_nppc,
            };
            if kind.is_nppc() || (is_n && !approx) {
                nppc += 1;
            } else {
                ppc += 1;
            }
            let (c, s) =
                build_cell(&mut nl, kind, a[i as usize], b[j as usize],
                           k_net[wt], s_net[wt]);
            new_s.push((wt, s));
            carries.push((wt + 1, c));
        }
        // commit row outputs: sum rail in place, carries shifted up
        let lo = j as usize;
        let hi = (j + n) as usize; // exclusive span end
        let touched: Vec<usize> = new_s.iter().map(|&(wt, _)| wt).collect();
        for &(wt, s) in &new_s {
            s_net[wt] = s;
        }
        // consumed k rail positions reset to 0 (their value moved into the
        // cells); untouched (truncated) positions keep their net
        for wt in lo..hi.min(w as usize) {
            if touched.contains(&wt) {
                k_net[wt] = zero;
            }
        }
        for &(wt, c) in &carries {
            if wt < w as usize {
                insert_carry(&mut nl, &mut k_net, zero, wt, c);
            }
        }
    }

    for &s in &s_net {
        nl.mark_output(s);
    }
    for &k in &k_net {
        nl.mark_output(k);
    }
    // sequential boundary: operand regs + carry-save accumulator rails
    nl.add_dffs(2 * n + 2 * w);

    // drain merge: Kogge-Stone resolve of the two rails
    let mut merge = Netlist::new(&format!("pe_merge_{}b", n));
    let ma: Vec<NetId> = (0..w).map(|_| merge.input()).collect();
    let mb: Vec<NetId> = (0..w).map(|_| merge.input()).collect();
    let sum = kogge_stone(&mut merge, &ma, &mb);
    for s in sum {
        merge.mark_output(s);
    }

    PeNetlists { grid: nl, merge, n, w, ppc_cells: ppc, nppc_cells: nppc }
}

/// Conventional (non-PPC/NPPC) exact MAC baselines of Table III:
/// an array multiplier (AND grid + FA carry-save rows + ripple CPA)
/// followed by a separate W-bit accumulator adder.
///
/// `hybrid_accumulator` models HA-FSA \[10\] (slightly leaner final
/// stage); `false` models the Gemmini-style PE \[13\].
pub fn conventional_mac_netlist(n: u32, w: u32, hybrid_accumulator: bool)
                                -> Netlist {
    let name = if hybrid_accumulator { "ha_fsa_mac" } else { "gemmini_mac" };
    let mut nl = Netlist::new(name);
    let a: Vec<NetId> = (0..n).map(|_| nl.input()).collect();
    let b: Vec<NetId> = (0..n).map(|_| nl.input()).collect();
    let c_in: Vec<NetId> = (0..w).map(|_| nl.input()).collect();
    let zero = nl.const0();
    let one = nl.const1();

    // Baugh-Wooley signed array: complemented products on the sign
    // row/column + the two correction constants (columns N and 2N-1).
    let mut sum_rail: Vec<NetId> = vec![zero; (2 * n) as usize];
    let mut car_rail: Vec<NetId> = vec![zero; (2 * n) as usize];
    sum_rail[n as usize] = one;
    sum_rail[(2 * n - 1) as usize] = one;
    for j in 0..n {
        for i in 0..n {
            let wt = (i + j) as usize;
            let complemented = (i == n - 1) ^ (j == n - 1);
            let p = if complemented {
                nl.nand2(a[i as usize], b[j as usize])
            } else {
                nl.and2(a[i as usize], b[j as usize])
            };
            let (c, s) = nl.full_adder(p, car_rail[wt], sum_rail[wt]);
            sum_rail[wt] = s;
            if wt + 1 < car_rail.len() {
                car_rail[wt + 1] = c;
            }
        }
    }
    // vector-merge CPA over the product
    let prod = ripple_adder(&mut nl, &sum_rail, &car_rail);
    // separate accumulator add: acc' = acc + prod (sign-extended)
    let mut prod_ext = prod.clone();
    let msb = *prod.last().unwrap();
    while (prod_ext.len() as u32) < w {
        prod_ext.push(msb);
    }
    let acc = if hybrid_accumulator {
        // HA-FSA: carry-save "hybrid" accumulator — keep high half lazy
        let sum = kogge_stone(&mut nl, &prod_ext, &c_in);
        sum
    } else {
        ripple_adder(&mut nl, &prod_ext, &c_in)
    };
    for s in acc {
        nl.mark_output(s);
    }
    nl.add_dffs(2 * n + w);
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::word::{mac_step, PeConfig};
    use crate::pe::Signedness;

    fn bits(v: u64, n: u32) -> Vec<u8> {
        (0..n).map(|i| ((v >> i) & 1) as u8).collect()
    }

    fn from_bits(b: &[u8]) -> u64 {
        b.iter().enumerate().fold(0u64, |acc, (i, &v)| acc | ((v as u64) << i))
    }

    fn check_equivalence(d: &Design, iters: u64) {
        let cfg = PeConfig::from_design(d);
        let w = cfg.w;
        let nets = pe_netlists(d, w);
        let mut state = 0xC0FFEE123u64;
        let mut rnd = || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let mut scratch = Vec::new();
        for it in 0..iters {
            let a = rnd() & ((1 << d.n) - 1);
            let b = rnd() & ((1 << d.n) - 1);
            let s0 = if it == 0 { 0 } else { rnd() & cfg.word_mask() };
            let k0 = if it == 0 { 0 } else { rnd() & cfg.word_mask() };
            let (s1, k1) = mac_step(&cfg, a, b, s0, k0);
            let mut inp = bits(a, d.n);
            inp.extend(bits(b, d.n));
            inp.extend(bits(s0, w));
            inp.extend(bits(k0, w));
            let out = nets.grid.eval_into(&inp, &mut scratch);
            let s_nl = from_bits(&out[..w as usize]);
            let k_nl = from_bits(&out[w as usize..]);
            assert_eq!((s_nl, k_nl), (s1, k1),
                       "{:?} a={a:#x} b={b:#x} s0={s0:#x} k0={k0:#x}", d);
        }
    }

    #[test]
    fn grid_matches_word_model_exact_signed() {
        check_equivalence(&Design::proposed_exact(8, Signedness::Signed), 300);
        check_equivalence(&Design::conventional_exact(8, Signedness::Signed), 300);
        check_equivalence(&Design::proposed_exact(4, Signedness::Signed), 300);
    }

    #[test]
    fn grid_matches_word_model_exact_unsigned() {
        check_equivalence(&Design::proposed_exact(8, Signedness::Unsigned), 300);
        check_equivalence(&Design::proposed_exact(4, Signedness::Unsigned), 300);
    }

    #[test]
    fn grid_matches_word_model_approx_families() {
        for family in Family::ALL {
            for k in [2u32, 4, 7] {
                check_equivalence(
                    &Design::approximate(8, Signedness::Signed, family, k), 200);
                check_equivalence(
                    &Design::approximate(8, Signedness::Unsigned, family, k), 200);
            }
        }
    }

    #[test]
    fn cell_counts_match_paper() {
        // paper: 8-bit signed PE uses 50 PPC + 14 NPPC cells
        let d = Design::proposed_exact(8, Signedness::Signed);
        let nets = pe_netlists(&d, 24);
        assert_eq!(nets.ppc_cells, 50);
        assert_eq!(nets.nppc_cells, 14);
        // unsigned: all N^2 are PPC
        let d = Design::proposed_exact(8, Signedness::Unsigned);
        let nets = pe_netlists(&d, 24);
        assert_eq!(nets.ppc_cells, 64);
        assert_eq!(nets.nppc_cells, 0);
    }

    #[test]
    fn kogge_stone_adds() {
        let mut nl = Netlist::new("ks");
        let a: Vec<NetId> = (0..16).map(|_| nl.input()).collect();
        let b: Vec<NetId> = (0..16).map(|_| nl.input()).collect();
        let s = kogge_stone(&mut nl, &a, &b);
        for x in s {
            nl.mark_output(x);
        }
        for (x, y) in [(0u64, 0u64), (1, 1), (12345, 54321), (65535, 1),
                       (0xAAAA, 0x5555), (0xFFFF, 0xFFFF)] {
            let mut inp = bits(x, 16);
            inp.extend(bits(y, 16));
            let out = nl.eval(&inp);
            assert_eq!(from_bits(&out), (x + y) & 0xFFFF, "{x}+{y}");
        }
    }

    #[test]
    fn axsa_cells_smaller_than_exact_but_bigger_than_proposed_apx() {
        let mk = |f: Family| pe_netlists(
            &Design::approximate(8, Signedness::Signed, f, 7), 24).grid.area();
        let axsa = mk(Family::Axsa5);
        let prop = mk(Family::Proposed);
        let exact = pe_netlists(
            &Design::proposed_exact(8, Signedness::Signed), 24).grid.area();
        assert!(axsa < exact, "carry elision must save area");
        assert!(prop < axsa, "proposed approx must beat AxSA on area");
    }

    #[test]
    fn conventional_mac_slower_than_fused_pe() {
        // The fused grid stays carry-save per cycle; the conventional MAC
        // resolves a full CPA every cycle — the paper's Table III shows
        // this as a >2x delay and >60% PADP gap (our area ordering
        // deviates slightly: DESIGN.md §6).
        let pe = pe_netlists(&Design::proposed_exact(8, Signedness::Signed), 24);
        let mac = conventional_mac_netlist(8, 24, false);
        assert!(mac.critical_path_ps() > 1.5 * pe.grid.critical_path_ps());
    }
}
