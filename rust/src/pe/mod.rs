//! Processing-element models.
//!
//! * [`word`] — the word-level functional model: one fused MAC = N
//!   bit-plane row updates on a `u64` carry-save accumulator.
//!   Bit-identical to `python/compile/kernels/ref.py` (tested against the
//!   exported goldens) and to the gate-level netlists in [`netlist_builder`].
//! * [`lut`] — the table-driven hot path: per-design-point product tables
//!   plus a tiny carry-save-window automaton, bit-identical to [`word`]
//!   but an order of magnitude faster on GEMM-shaped workloads.
//! * [`netlist_builder`] — constructs the full gate-level netlist of each
//!   PE design (grid of PPC/NPPC cells + Kogge-Stone merge + operand
//!   registers) for the hardware model in [`crate::hw`].

pub mod lut;
pub mod netlist_builder;
pub mod word;

pub use word::{Pe, PeConfig};

use crate::Family;

/// Which arithmetic a PE implements.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Signedness {
    /// All-PPC grid (paper Fig. 6a).
    Unsigned,
    /// Baugh-Wooley grid with NPPC cells on the sign row/column (Fig. 5/6b).
    Signed,
}

/// A PE *design point* as it appears in the paper's tables: an operand
/// width, a signedness, a cell family and an approximation level.
#[derive(Clone, Copy, Debug)]
pub struct Design {
    /// Operand width in bits.
    pub n: u32,
    /// Unsigned all-PPC grid or signed Baugh-Wooley grid.
    pub signed: Signedness,
    /// Approximate-cell family for the low-`k` columns.
    pub family: Family,
    /// Number of approximate least-significant columns (0 = exact PE).
    pub k: u32,
    /// True for the paper's optimized exact cells ("Proposed" exact rows);
    /// false for the conventional exact cells of \[6\]. Only affects the
    /// hardware model — exact cells are functionally identical.
    pub optimized_exact: bool,
}

impl Design {
    /// Exact PE built from the paper's optimized (mirror-adder) cells.
    pub fn proposed_exact(n: u32, signed: Signedness) -> Self {
        Design { n, signed, family: Family::Proposed, k: 0, optimized_exact: true }
    }

    /// Exact PE built from the conventional cells of \[6\].
    pub fn conventional_exact(n: u32, signed: Signedness) -> Self {
        Design { n, signed, family: Family::Proposed, k: 0, optimized_exact: false }
    }

    /// Approximate PE: `family` cells on the `k` least-significant columns.
    pub fn approximate(n: u32, signed: Signedness, family: Family, k: u32) -> Self {
        Design { n, signed, family, k, optimized_exact: true }
    }

    /// The paper's default approximation level k = N - 1.
    pub fn approximate_default(n: u32, signed: Signedness, family: Family) -> Self {
        Self::approximate(n, signed, family, n - 1)
    }

    /// Design point matching a runtime [`PeConfig`], assuming the
    /// paper's optimized exact cells (the serving default — exact cells
    /// are functionally identical, so `PeConfig` does not distinguish
    /// them; hardware metrics and energy tables do).
    pub fn from_pe_config(cfg: &PeConfig) -> Self {
        Design {
            n: cfg.n,
            signed: if cfg.signed { Signedness::Signed } else { Signedness::Unsigned },
            family: cfg.family,
            k: cfg.k,
            optimized_exact: true,
        }
    }

    /// Whether this design uses the signed (Baugh-Wooley) grid.
    pub fn is_signed(&self) -> bool {
        self.signed == Signedness::Signed
    }
}
