//! Product-LUT GEMM engine — the table-driven hot path.
//!
//! The PPC/NPPC processing elements are deterministic functions of at most
//! 8-bit operands, so every `(family, n, k, signedness)` design point can be
//! compiled once into lookup tables and then applied with plain integer adds
//! (the trick EvoApproxLib-style flows use to make approximate multipliers
//! fast enough for network-scale evaluation). Two tables are needed because
//! the paper's PE is a *fused MAC*, not a bare multiplier:
//!
//! 1. **Product table** (`2^N x 2^N` i32): the exact signed product of each
//!    encoded operand pair. For `k == 0` this alone reproduces the PE
//!    (tested exhaustively in [`word`](super::word)).
//! 2. **State automaton** (`states x 4^k` packed u32): for `k > 0` the
//!    approximate cells read the live carry-save accumulator, so chained
//!    MACs are *not* the sum of single-MAC products. But approximation is
//!    confined to grid columns `< k`, carries only propagate upward, and
//!    the Baugh-Wooley constant lands above column `N-1 >= k`; hence the
//!    low `k` bits of the `(s, kc)` rails evolve autonomously from the low
//!    `k` bits of the operands, and the *value deviation* of each MAC is a
//!    function of that window alone. The automaton enumerates the window
//!    states reachable from the reset accumulator (empirically tiny:
//!    ~`2^(k-1)` for the proposed family, 2 for nano6) and stores, per
//!    `(state, a_lo, b_lo)`, the deviation and the successor state.
//!
//! A MAC then costs two table reads and two adds:
//! `acc += prod[a][b] + err(state, a_lo, b_lo); state = next(state, ..)`,
//! which is bit-identical to the word-level bit-plane walk (differential
//! suite: `tests/backend_equiv.rs`) and an order of magnitude faster
//! (`cargo bench --bench hotpath`, `lut_vs_word`).
//!
//! Tables are built lazily and shared process-wide through [`cached`]
//! (keyed by the [`PeConfig`] fields, `Arc`-shared across coordinator
//! workers). Unsupported design points (`n > 8`, `k > n`, or a table over
//! [`TABLE_BYTES_BUDGET`]) transparently fall back to
//! [`word::matmul`](super::word::matmul) via [`matmul`] — same bits,
//! just not table speed.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::word::{mac_step_planned, matmul as word_matmul, MacPlan, PeConfig};
use crate::Family;

/// Hard ceiling on a single automaton's size; larger design points fall
/// back to the word-level path rather than ballooning resident memory.
pub const TABLE_BYTES_BUDGET: usize = 64 << 20;

/// Compiled lookup tables for one PE design point.
pub struct ProductLut {
    /// The design point these tables were compiled for.
    pub cfg: PeConfig,
    /// `2^N x 2^N` exact signed products of decoded operand pairs,
    /// indexed `(a_enc << N) | b_enc`.
    prod: Vec<i32>,
    /// Automaton, state-major: entry `(state << 2k) | (a_lo << k | b_lo)`
    /// packs `err` (i16, high half) and the successor state index (u16,
    /// low half). Empty when `k == 0` (the PE is exact and stateless).
    trans: Vec<u32>,
    /// Carry-save window value `(s_lo, kc_lo)` of each automaton state
    /// (index-aligned with the transition table; `[(0, 0)]` when exact).
    /// The energy subsystem embeds these windows into netlist frames, so
    /// its tables share this automaton's state indices by construction.
    win: Vec<(u64, u64)>,
    n_states: usize,
    /// Approximate-window width in bits (== `cfg.k`).
    kb: u32,
}

impl ProductLut {
    /// Whether a design point is LUT-compilable at all (size limits are
    /// checked during the build, which can still return `None`).
    pub fn supports(cfg: &PeConfig) -> bool {
        cfg.n <= 8 && cfg.k <= cfg.n
    }

    /// Compile the tables for `cfg`. Returns `None` for unsupported or
    /// over-budget design points (callers fall back to the word model).
    pub fn try_build(cfg: &PeConfig) -> Option<Self> {
        if !Self::supports(cfg) {
            return None;
        }
        let n = cfg.n;
        let size = 1usize << n;
        // one authoritative operand decode, shared with the word path
        let dec = |enc: u64| -> i64 { cfg.decode_operand(enc) };
        let mut prod = vec![0i32; size * size];
        for a in 0..size {
            let da = dec(a as u64);
            for b in 0..size {
                prod[(a << n) | b] = (da * dec(b as u64)) as i32;
            }
        }
        if cfg.k == 0 {
            return Some(ProductLut { cfg: *cfg, prod, trans: Vec::new(),
                                     win: vec![(0, 0)], n_states: 1, kb: 0 });
        }

        // Discover the reachable window states breadth-first from the
        // reset accumulator, emitting one state-major transition row per
        // state as it is dequeued.
        let kb = cfg.k;
        let kmask = (1u64 << kb) - 1;
        let n_inputs = 1usize << (2 * kb);
        let plan = MacPlan::new(cfg);
        let mut states: Vec<(u64, u64)> = vec![(0, 0)];
        let mut index: HashMap<(u64, u64), u16> = HashMap::new();
        index.insert((0, 0), 0);
        let mut trans: Vec<u32> = Vec::new();
        let mut next_state = 0usize;
        while next_state < states.len() {
            let (s_lo, kc_lo) = states[next_state];
            let t0 = (s_lo + kc_lo) as i64;
            for a_lo in 0..(1u64 << kb) {
                let base_a = dec(a_lo);
                for b_lo in 0..(1u64 << kb) {
                    let (s1, k1) = mac_step_planned(&plan, a_lo, b_lo,
                                                    s_lo, kc_lo);
                    let err = plan.resolve(s1, k1) - t0 - base_a * dec(b_lo);
                    let Ok(err16) = i16::try_from(err) else {
                        return None; // cannot pack; fall back
                    };
                    let st = (s1 & kmask, k1 & kmask);
                    let idx = match index.get(&st) {
                        Some(&i) => i,
                        None => {
                            if states.len() > u16::MAX as usize
                                || (states.len() + 1) * n_inputs * 4
                                    > TABLE_BYTES_BUDGET
                            {
                                return None;
                            }
                            let i = states.len() as u16;
                            states.push(st);
                            index.insert(st, i);
                            i
                        }
                    };
                    trans.push(((err16 as u16 as u32) << 16) | idx as u32);
                }
            }
            next_state += 1;
        }
        let n_states = states.len();
        Some(ProductLut { cfg: *cfg, prod, trans, win: states, n_states, kb })
    }

    /// Number of reachable approximate-window states (1 when exact).
    pub fn states(&self) -> usize {
        self.n_states
    }

    /// Carry-save window value `(s_lo, kc_lo)` of automaton state `i`.
    pub(crate) fn state_window(&self, i: usize) -> (u64, u64) {
        self.win[i]
    }

    /// Successor state index for `(state, (a_lo << k) | b_lo)`. Only
    /// valid when `cfg.k > 0` (the exact automaton has no transitions).
    pub(crate) fn next_state(&self, state: usize, key: usize) -> usize {
        (self.trans_entry(state, key) & 0xFFFF) as usize
    }

    /// Approximate-window width in bits (`== cfg.k` for compiled points).
    #[inline(always)]
    pub(crate) fn window_bits(&self) -> u32 {
        self.kb
    }

    /// Product-table read at a precombined `(a_enc << N) | b_enc` index.
    /// Hot-loop primitive for the blocked microkernel in [`crate::gemm`].
    #[inline(always)]
    pub(crate) fn prod_entry(&self, idx: usize) -> i64 {
        self.prod[idx] as i64
    }

    /// Automaton transition read: packed `(err i16 << 16) | next_state`
    /// for `(state, (a_lo << k) | b_lo)`. Only valid when `cfg.k > 0`.
    #[inline(always)]
    pub(crate) fn trans_entry(&self, state: usize, key: usize) -> u32 {
        self.trans[(state << (2 * self.kb)) | key]
    }

    /// Resident table footprint in bytes.
    pub fn table_bytes(&self) -> usize {
        self.prod.len() * 4 + self.trans.len() * 4
    }

    /// One resolved dot product `sum_t a[t]*b[t]` through the PE — the
    /// LUT equivalent of streaming `kk` MACs into one accumulator.
    /// Delegates to [`Self::matmul`] as a 1x1 GEMM so there is exactly
    /// one implementation of the table walk.
    pub fn dot(&self, a: &[i64], b: &[i64]) -> i64 {
        assert_eq!(a.len(), b.len());
        self.matmul(a, b, 1, a.len(), 1)[0]
    }

    /// Table-driven GEMM `C(m x nn) = A(m x kk) @ B(kk x nn)`: the
    /// *naive reference walk* — one (accumulator, state) chain at a time
    /// over a transposed B, lightly blocked over output columns and
    /// parallelized across output-row chunks for large problems.
    /// Bit-identical to [`word::matmul`](super::word::matmul) on the
    /// same config, and the
    /// baseline the cache-blocked driver in [`crate::gemm`] is measured
    /// against (`benches/hotpath.rs`, `blocked_vs_naive`). Serving paths
    /// should prefer [`crate::gemm::BlockedGemm`].
    pub fn matmul(&self, a: &[i64], b: &[i64], m: usize, kk: usize,
                  nn: usize) -> Vec<i64> {
        assert_eq!(a.len(), m * kk);
        assert_eq!(b.len(), kk * nn);
        let n = self.cfg.n as usize;
        let ae: Vec<u16> = a.iter().map(|&v| self.cfg.encode(v) as u16).collect();
        // B transposed once: unit-stride inner loops
        let mut bt = vec![0u16; kk * nn];
        for t in 0..kk {
            for j in 0..nn {
                bt[j * kk + t] = self.cfg.encode(b[t * nn + j]) as u16;
            }
        }
        let mut out = vec![0i64; m * nn];
        // block width: 32 B-columns x kk u16 ~ 64*kk bytes per panel sweep
        const JB: usize = 32;
        let row_chunk_job = |i0: usize, rows: &mut [i64]| {
            let n_rows = rows.len() / nn.max(1);
            let mut jb = 0;
            while jb < nn {
                let jw = (nn - jb).min(JB);
                for r in 0..n_rows {
                    let arow = &ae[(i0 + r) * kk..(i0 + r + 1) * kk];
                    for j in jb..jb + jw {
                        let brow = &bt[j * kk..(j + 1) * kk];
                        let mut acc = 0i64;
                        if self.trans.is_empty() {
                            for t in 0..kk {
                                let ai = arow[t] as usize;
                                let bi = brow[t] as usize;
                                acc += self.prod[(ai << n) | bi] as i64;
                            }
                        } else {
                            let kb = self.kb as usize;
                            let kmask = (1usize << kb) - 1;
                            let mut st = 0usize;
                            for t in 0..kk {
                                let ai = arow[t] as usize;
                                let bi = brow[t] as usize;
                                acc += self.prod[(ai << n) | bi] as i64;
                                let key = ((ai & kmask) << kb) | (bi & kmask);
                                let e = self.trans[(st << (2 * kb)) | key];
                                acc += (e >> 16) as i16 as i64;
                                st = (e & 0xFFFF) as usize;
                            }
                        }
                        rows[r * nn + j] = self.cfg.decode(acc as u64);
                    }
                }
                jb += jw;
            }
        };
        let work = m * nn * kk;
        let threads = std::thread::available_parallelism()
            .map(|p| p.get()).unwrap_or(1).min(8);
        // Parallelize only problems that are both big and tall: coordinator
        // workers call this per SA-sized tile (m <= 8) from an already-
        // parallel pool, where per-call thread spawns would oversubscribe
        // and cost more than the ~10 µs of work they fan out.
        if work >= 1 << 18 && threads > 1 && m >= 2 * threads {
            std::thread::scope(|scope| {
                let chunk = m.div_ceil(threads);
                for (ci, rows) in out.chunks_mut(chunk * nn).enumerate() {
                    let row_chunk_job = &row_chunk_job;
                    scope.spawn(move || row_chunk_job(ci * chunk, rows));
                }
            });
        } else {
            row_chunk_job(0, &mut out);
        }
        out
    }
}

/// Cache key: every [`PeConfig`] field that changes the tables.
type LutKey = (u32, u32, bool, Family, u32);

fn key_of(cfg: &PeConfig) -> LutKey {
    (cfg.n, cfg.w, cfg.signed, cfg.family, cfg.k)
}

struct LutCache {
    tables: Mutex<HashMap<LutKey, Option<Arc<ProductLut>>>>,
    hits: AtomicU64,
    builds: AtomicU64,
}

fn cache() -> &'static LutCache {
    static CACHE: OnceLock<LutCache> = OnceLock::new();
    CACHE.get_or_init(|| LutCache {
        tables: Mutex::new(HashMap::new()),
        hits: AtomicU64::new(0),
        builds: AtomicU64::new(0),
    })
}

/// Fetch (building on first use) the shared tables for a design point.
/// `None` means the point is not LUT-compilable — callers fall back to
/// the word model. The returned `Arc` is shared across all workers.
pub fn cached(cfg: &PeConfig) -> Option<Arc<ProductLut>> {
    let c = cache();
    if let Some(entry) = c.tables.lock().unwrap().get(&key_of(cfg)) {
        c.hits.fetch_add(1, Ordering::Relaxed);
        return entry.clone();
    }
    // build outside the lock (builds are idempotent; a racing duplicate
    // build is wasted work, not an error)
    let built = ProductLut::try_build(cfg).map(Arc::new);
    c.builds.fetch_add(1, Ordering::Relaxed);
    c.tables.lock().unwrap()
        .entry(key_of(cfg))
        .or_insert(built)
        .clone()
}

/// Cumulative cache counters: `(hits, builds)` since process start.
pub fn cache_counters() -> (u64, u64) {
    let c = cache();
    (c.hits.load(Ordering::Relaxed), c.builds.load(Ordering::Relaxed))
}

/// Table-driven GEMM with transparent fallback: uses the shared LUT when
/// the design point supports it, the word-level bit-plane walk otherwise.
/// Always bit-identical to [`word::matmul`](super::word::matmul).
pub fn matmul(cfg: &PeConfig, a: &[i64], b: &[i64], m: usize, kk: usize,
              nn: usize) -> Vec<i64> {
    match cached(cfg) {
        Some(lut) => lut.matmul(a, b, m, kk, nn),
        None => word_matmul(cfg, a, b, m, kk, nn),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ints(seed: u64, len: usize) -> Vec<i64> {
        let mut s = seed | 1;
        (0..len).map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s as i64 & 255) - 128
        }).collect()
    }

    #[test]
    fn lut_matches_word_all_families_and_ks() {
        let (m, kk, nn) = (9usize, 13usize, 7usize);
        let a = ints(1, m * kk);
        let b = ints(2, kk * nn);
        for family in Family::ALL {
            for signed in [true, false] {
                for k in [0u32, 2, 4, 7] {
                    let cfg = PeConfig::new(8, signed, family, k);
                    let lut = ProductLut::try_build(&cfg)
                        .expect("8-bit points are LUT-compilable");
                    assert_eq!(lut.matmul(&a, &b, m, kk, nn),
                               word_matmul(&cfg, &a, &b, m, kk, nn),
                               "{family:?} signed={signed} k={k}");
                }
            }
        }
    }

    #[test]
    fn reachable_state_counts_are_tiny() {
        // the whole point of the automaton: the window state space
        // collapses (proposed ~2^(k-1), nano6 has 2 states at any k)
        for (family, k, want_max) in [
            (Family::Proposed, 7u32, 64usize),
            (Family::Axsa5, 7, 128),
            (Family::Sips12, 7, 128),
            (Family::Nano6, 7, 2),
        ] {
            let cfg = PeConfig::new(8, true, family, k);
            let lut = ProductLut::try_build(&cfg).unwrap();
            assert!(lut.states() <= want_max,
                    "{family:?}: {} states", lut.states());
            assert!(lut.table_bytes() <= TABLE_BYTES_BUDGET);
        }
    }

    #[test]
    fn dot_matches_matmul_cell() {
        let cfg = PeConfig::new(8, true, Family::Sips12, 5);
        let lut = ProductLut::try_build(&cfg).unwrap();
        let a = ints(3, 33);
        let b = ints(4, 33);
        let y = lut.matmul(&a, &b, 1, 33, 1);
        assert_eq!(lut.dot(&a, &b), y[0]);
    }

    #[test]
    fn unsupported_points_fall_back_bit_identically() {
        // 16-bit operands exceed the product-table width: matmul() must
        // transparently route to the word model
        let cfg = PeConfig::new(16, true, Family::Proposed, 3);
        assert!(!ProductLut::supports(&cfg));
        assert!(ProductLut::try_build(&cfg).is_none());
        let a = ints(5, 4 * 6);
        let b = ints(6, 6 * 5);
        assert_eq!(matmul(&cfg, &a, &b, 4, 6, 5),
                   word_matmul(&cfg, &a, &b, 4, 6, 5));
        // k beyond the operand width is also word-model territory
        let cfg2 = PeConfig::new(8, true, Family::Proposed, 12);
        assert!(ProductLut::try_build(&cfg2).is_none());
        assert_eq!(matmul(&cfg2, &a, &b, 4, 6, 5),
                   word_matmul(&cfg2, &a, &b, 4, 6, 5));
    }

    #[test]
    fn cache_shares_one_arc_per_design_point() {
        let cfg = PeConfig::new(8, true, Family::Axsa5, 3);
        let t1 = cached(&cfg).unwrap();
        let t2 = cached(&cfg).unwrap();
        assert!(Arc::ptr_eq(&t1, &t2));
        let (hits, _) = cache_counters();
        assert!(hits >= 1);
        // a different k is a different table
        let cfg2 = PeConfig::new(8, true, Family::Axsa5, 4);
        let t3 = cached(&cfg2).unwrap();
        assert!(!Arc::ptr_eq(&t1, &t3));
    }

    #[test]
    fn four_bit_designs_including_k_equals_n() {
        // n=4 puts the Baugh-Wooley NPPC column inside the approximate
        // window at k=4 — the automaton must still be exact
        let (m, kk, nn) = (5usize, 11usize, 6usize);
        let a: Vec<i64> = ints(7, m * kk).iter().map(|v| v % 8).collect();
        let b: Vec<i64> = ints(8, kk * nn).iter().map(|v| v % 8).collect();
        for family in Family::ALL {
            for k in [0u32, 2, 3, 4] {
                let cfg = PeConfig::new(4, true, family, k);
                let lut = ProductLut::try_build(&cfg).unwrap();
                assert_eq!(lut.matmul(&a, &b, m, kk, nn),
                           word_matmul(&cfg, &a, &b, m, kk, nn),
                           "{family:?} k={k}");
            }
        }
    }

    #[test]
    fn exact_lut_equals_integer_gemm() {
        let cfg = PeConfig::new(8, true, Family::Proposed, 0);
        let lut = ProductLut::try_build(&cfg).unwrap();
        let (m, kk, nn) = (6usize, 9usize, 8usize);
        let a = ints(11, m * kk);
        let b = ints(12, kk * nn);
        let y = lut.matmul(&a, &b, m, kk, nn);
        for i in 0..m {
            for j in 0..nn {
                let want: i64 = (0..kk)
                    .map(|t| a[i * kk + t] * b[t * nn + j]).sum();
                assert_eq!(y[i * nn + j], want, "({i},{j})");
            }
        }
    }
}
