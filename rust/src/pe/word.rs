//! Word-level PE functional model — the system's hot path.
//!
//! One fused MAC folds the N×N Baugh-Wooley partial-product grid into a
//! W-bit carry-save accumulator `(s, k)` using N bit-plane row updates of
//! full-width bitwise ops (the same formulation the L1 Pallas kernel uses
//! on uint32 lanes; here on `u64`, so N up to 16 / W up to 48).
//!
//! Row `j` is a 3:2 compressor layer over bit span `[j, j+N)`:
//!   * exact cells: `S' = X^S^K`, `C = maj(X,S,K)` (X carries the NPPC
//!     complement, so PPC and NPPC share one expression);
//!   * approximate cells (`w < k`) apply the family's Table-I semantics;
//!   * bits outside the span pass through; carries escaping the span top
//!     are merged with a value-preserving add (the PE's merge logic —
//!     always exact, always above column N >= k).
//!
//! Invariant (tested exhaustively at N=4, randomized at N=8/16): with
//! `k == 0` the resolved accumulator equals `c + Σ a·b  (mod 2^W)`.

use super::{Design, Signedness};
use crate::Family;

/// Static configuration of one PE instance.
#[derive(Clone, Copy, Debug)]
pub struct PeConfig {
    /// Operand width in bits (<= 16).
    pub n: u32,
    /// Accumulator width in bits (<= 48). Default `2n + 8`.
    pub w: u32,
    /// Two's-complement operands (Baugh-Wooley grid) vs unsigned.
    pub signed: bool,
    /// Approximate-cell family for the low-`k` columns.
    pub family: Family,
    /// Number of approximate least-significant columns (0 = exact).
    pub k: u32,
}

impl PeConfig {
    /// Configuration with the default accumulator width `2n + 8`.
    pub fn new(n: u32, signed: bool, family: Family, k: u32) -> Self {
        PeConfig { n, w: 2 * n + 8, signed, family, k }
    }

    /// Configuration matching a paper-table [`Design`] point.
    pub fn from_design(d: &Design) -> Self {
        Self::new(d.n, d.signed == Signedness::Signed, d.family, d.k)
    }

    /// All-ones mask of the W-bit accumulator.
    #[inline]
    pub fn word_mask(&self) -> u64 {
        (1u64 << self.w) - 1
    }

    /// Baugh-Wooley correction constant at width W (DESIGN.md §1):
    /// `+2^N` plus ones on bits `[2N-1, W)` (the wrapped `-2^(2N-1)`).
    #[inline]
    pub fn bw_const(&self) -> u64 {
        let n = self.n;
        let w = self.w;
        ((1u64 << n) | (((1u64 << (w - (2 * n - 1))) - 1) << (2 * n - 1)))
            & self.word_mask()
    }

    /// NPPC (complemented-product) positions of row `j`, as absolute bit
    /// weights: `i == N-1` for j < N-1, `i in 0..N-1` for the last row.
    #[inline]
    pub fn nppc_mask(&self, j: u32) -> u64 {
        if !self.signed {
            return 0;
        }
        let n = self.n;
        if j < n - 1 {
            1u64 << (n - 1 + j)
        } else {
            ((1u64 << (n - 1)) - 1) << j
        }
    }

    /// Encode a signed/unsigned integer operand to its N-bit pattern.
    #[inline]
    pub fn encode(&self, v: i64) -> u64 {
        (v as u64) & ((1u64 << self.n) - 1)
    }

    /// Sign-extend (or zero-extend) a W-bit accumulator value.
    #[inline]
    pub fn decode(&self, v: u64) -> i64 {
        let v = v & self.word_mask();
        if self.signed && (v >> (self.w - 1)) & 1 == 1 {
            (v | !self.word_mask()) as i64
        } else {
            v as i64
        }
    }

    /// Decode an N-bit operand encoding back to its integer value (the
    /// hardware only sees N bits, so out-of-range inputs wrap here).
    /// The single authority both the word and LUT paths rely on for
    /// operand semantics.
    #[inline]
    pub fn decode_operand(&self, enc: u64) -> i64 {
        let mask_n = (1u64 << self.n) - 1;
        let enc = enc & mask_n;
        if self.signed && (enc >> (self.n - 1)) & 1 == 1 {
            (enc | !mask_n) as i64
        } else {
            enc as i64
        }
    }
}

/// One processing element: carry-save accumulator + the cell grid.
#[derive(Clone, Debug)]
pub struct Pe {
    /// Design point of this element.
    pub cfg: PeConfig,
    plan: MacPlan,
    /// Sum rail of the carry-save accumulator.
    pub s: u64,
    /// Carry rail.
    pub k: u64,
    /// Toggle count (Hamming distance of successive states) — the activity
    /// proxy used by the energy model.
    pub toggles: u64,
    /// MAC operations executed since construction.
    pub macs: u64,
}

impl Pe {
    /// A fresh element with a zeroed accumulator.
    pub fn new(cfg: PeConfig) -> Self {
        Pe { cfg, plan: MacPlan::new(&cfg), s: 0, k: 0, toggles: 0, macs: 0 }
    }

    /// Zero the carry-save accumulator (counters are kept).
    pub fn reset(&mut self) {
        self.s = 0;
        self.k = 0;
    }

    /// Fused MAC: fold `a*b` into the accumulator through the
    /// (possibly approximate) cell grid. `a`, `b` are N-bit encodings.
    #[inline]
    pub fn mac(&mut self, a: u64, b: u64) {
        let (s, k) = mac_step_planned(&self.plan, a, b, self.s, self.k);
        self.toggles += (s ^ self.s).count_ones() as u64
            + (k ^ self.k).count_ones() as u64;
        self.s = s;
        self.k = k;
        self.macs += 1;
    }

    /// Drain: resolve the carry-save state with the exact merge adder.
    #[inline]
    pub fn resolve(&self) -> i64 {
        self.cfg.decode(self.s.wrapping_add(self.k) & self.cfg.word_mask())
    }

    /// Convenience: full `a*b + c` through a fresh accumulator.
    pub fn mac_value(cfg: &PeConfig, a: i64, b: i64, c: i64) -> i64 {
        let mut pe = Pe::new(*cfg);
        pe.s = (c as u64) & cfg.word_mask();
        pe.mac(cfg.encode(a), cfg.encode(b));
        pe.resolve()
    }
}

/// The row-pipeline MAC update (pure function of the config).
///
/// Mirrors `ref.mac_scalar` / `ref.mac_step` exactly — any change here must
/// be made in the Python oracle too (goldens enforce this).
#[inline]
pub fn mac_step(cfg: &PeConfig, a: u64, b: u64, s0: u64, k0: u64) -> (u64, u64) {
    let n = cfg.n;
    let mw = cfg.word_mask();
    let au = a & ((1u64 << n) - 1);
    let mut s = s0 & mw;
    let mut kc = k0 & mw;
    if cfg.signed {
        kc = kc.wrapping_add(cfg.bw_const()) & mw;
    }
    let amask = (1u64 << cfg.k) - 1;
    for j in 0..n {
        let span = (((1u64 << n) - 1) << j) & mw;
        let p = if (b >> j) & 1 == 1 { (au << j) & mw } else { 0 };
        let nm = cfg.nppc_mask(j);
        let x = (p ^ nm) & mw;
        let aa = span & amask;
        let ee = span & !amask & mw;
        let osk = s | kc;
        let (s_a, c_a, k_pass) = match cfg.family {
            Family::Proposed => {
                let ap = aa & !nm;
                let an = aa & nm;
                let s_a = ((osk & !x) & ap) | (((!osk) | !x) & an);
                let c_a = (x & ap) | ((osk & x) & an);
                (s_a, c_a, 0)
            }
            Family::Sips12 => ((!(x ^ s)) & aa, kc & aa, 0),
            Family::Nano6 => ((!s) & aa, (x & kc) & aa, 0),
            // AxSA [5]: carry-elided compressor — exact sum, no carry out
            Family::Axsa5 => ((x ^ s ^ kc) & aa, 0, 0),
            // Truncated: product dropped, exact 3:2 on the nm tie-off
            Family::Trunc => ((nm ^ s ^ kc) & aa,
                              ((nm & s) | (nm & kc) | (s & kc)) & aa, 0),
            // LOA: OR-fold the product into the sum, pass the carry
            Family::Loa => ((x | s) & aa, kc & aa, 0),
        };
        let s_e = (x ^ s ^ kc) & ee;
        let c_e = ((x & s) | (x & kc) | (s & kc)) & ee;
        s = ((s_a | s_e) | (s & !span)) & mw;
        kc = ((((c_a | c_e) & mw) << 1) | k_pass).wrapping_add(kc & !span & mw)
            & mw;
    }
    (s, kc)
}

/// Precomputed per-row masks for the hot MAC kernel (§Perf).
///
/// `mac_step` recomputes every span/NPPC/approx mask on each call; for
/// GEMM-shaped workloads the configuration is fixed across millions of
/// MACs, so [`MacPlan`] hoists them once. `mac_step_planned` is verified
/// bit-identical to `mac_step` (see tests::planned_matches_spec).
#[derive(Clone, Copy, Debug)]
struct RowMasks {
    nspan: u64,
    nm: u64,
    ap: u64,
    an: u64,
    aa: u64,
    ee: u64,
}

/// The hoisted per-config mask plan consumed by [`mac_step_planned`]
/// (see `RowMasks` above for what is precomputed and why).
#[derive(Clone, Debug)]
pub struct MacPlan {
    /// The design point the plan was built for.
    pub cfg: PeConfig,
    mw: u64,
    bw: u64,
    opmask: u64,
    n_rows: usize,
    rows: [RowMasks; 16],
}

impl MacPlan {
    /// Hoist every per-row mask for `cfg` (one-time cost per GEMM call).
    pub fn new(cfg: &PeConfig) -> Self {
        let mw = cfg.word_mask();
        let amask = (1u64 << cfg.k) - 1;
        assert!(cfg.n <= 16, "operand width capped at 16 bits");
        let mut rows = [RowMasks { nspan: mw, nm: 0, ap: 0, an: 0,
                                   aa: 0, ee: 0 }; 16];
        for j in 0..cfg.n {
            let span = (((1u64 << cfg.n) - 1) << j) & mw;
            let nm = cfg.nppc_mask(j);
            let aa = span & amask;
            rows[j as usize] = RowMasks {
                nspan: !span & mw,
                nm,
                ap: aa & !nm,
                an: aa & nm,
                aa,
                ee: span & !amask & mw,
            };
        }
        MacPlan {
            cfg: *cfg,
            mw,
            bw: if cfg.signed { cfg.bw_const() } else { 0 },
            opmask: (1u64 << cfg.n) - 1,
            n_rows: cfg.n as usize,
            rows,
        }
    }

    /// Drain a carry-save state pair to its signed integer value.
    #[inline]
    pub fn resolve(&self, s: u64, kc: u64) -> i64 {
        self.cfg.decode(s.wrapping_add(kc) & self.mw)
    }
}

/// Planned fused MAC — the optimized hot path. Bit-identical to
/// [`mac_step`].
#[inline]
pub fn mac_step_planned(plan: &MacPlan, a: u64, b: u64, s0: u64, k0: u64)
                        -> (u64, u64) {
    match plan.cfg.family {
        Family::Proposed => mac_rows::<0>(plan, a, b, s0, k0),
        Family::Axsa5 => mac_rows::<1>(plan, a, b, s0, k0),
        Family::Sips12 => mac_rows::<2>(plan, a, b, s0, k0),
        Family::Nano6 => mac_rows::<3>(plan, a, b, s0, k0),
        Family::Trunc => mac_rows::<4>(plan, a, b, s0, k0),
        Family::Loa => mac_rows::<5>(plan, a, b, s0, k0),
    }
}

#[inline(always)]
fn mac_rows<const FAM: u8>(plan: &MacPlan, a: u64, b: u64, s0: u64, k0: u64)
                           -> (u64, u64) {
    let mw = plan.mw;
    let au = a & plan.opmask;
    let mut s = s0 & mw;
    let mut kc = (k0 & mw).wrapping_add(plan.bw) & mw;
    for (j, rm) in plan.rows[..plan.n_rows].iter().enumerate() {
        // branchless product row: all-ones mask when bit j of b is set
        let sel = ((b >> j) & 1).wrapping_neg();
        let p = (au << j) & sel & mw;
        let x = (p ^ rm.nm) & mw;
        let osk = s | kc;
        let (s_a, c_a) = match FAM {
            0 => (((osk & !x) & rm.ap) | (((!osk) | !x) & rm.an),
                  (x & rm.ap) | ((osk & x) & rm.an)),
            1 => ((x ^ s ^ kc) & rm.aa, 0),
            2 => ((!(x ^ s)) & rm.aa, kc & rm.aa),
            3 => ((!s) & rm.aa, (x & kc) & rm.aa),
            4 => ((rm.nm ^ s ^ kc) & rm.aa,
                  ((rm.nm & s) | (rm.nm & kc) | (s & kc)) & rm.aa),
            _ => ((x | s) & rm.aa, kc & rm.aa),
        };
        let s_e = (x ^ s ^ kc) & rm.ee;
        let c_e = ((x & s) | (x & kc) | (s & kc)) & rm.ee;
        s = (s_a | s_e) | (s & rm.nspan);
        kc = (((c_a | c_e) & mw) << 1).wrapping_add(kc & rm.nspan) & mw;
    }
    (s, kc)
}

/// Approximate matmul through the word-level PE (one logical PE per output
/// element — the systolic simulator in [`crate::systolic`] models the
/// physical array; this is the fast functional equivalent).
pub fn matmul(cfg: &PeConfig, a: &[i64], b: &[i64], m: usize, kk: usize,
              nn: usize) -> Vec<i64> {
    assert_eq!(a.len(), m * kk);
    assert_eq!(b.len(), kk * nn);
    if cfg.k == 0 {
        // exact PE == integer MAC mod 2^W: skip the bit-plane walk
        // entirely (§Perf: ~40x on exact-path workloads). The carry-save
        // state is unobservable for k = 0, so this is bit-identical.
        return matmul_exact_fast(cfg, a, b, m, kk, nn);
    }
    let plan = MacPlan::new(cfg);
    let mut out = vec![0i64; m * nn];
    // B transposed once: unit-stride inner loops (§Perf: ~15% on 64^3)
    let mut bt = vec![0u64; kk * nn];
    for t in 0..kk {
        for j in 0..nn {
            bt[j * kk + t] = cfg.encode(b[t * nn + j]);
        }
    }
    let ae: Vec<u64> = a.iter().map(|&v| cfg.encode(v)).collect();
    let row_job = |i: usize, out_row: &mut [i64]| {
        let arow = &ae[i * kk..(i + 1) * kk];
        for (j, o) in out_row.iter_mut().enumerate() {
            let brow = &bt[j * kk..(j + 1) * kk];
            let mut s = 0u64;
            let mut kc = 0u64;
            for t in 0..kk {
                let (s2, k2) = mac_step_planned(&plan, arow[t], brow[t], s, kc);
                s = s2;
                kc = k2;
            }
            *o = plan.resolve(s, kc);
        }
    };
    // parallelize across output rows for large problems (§Perf)
    let work = m * nn * kk;
    let threads = std::thread::available_parallelism()
        .map(|p| p.get()).unwrap_or(1).min(8);
    if work >= 1 << 16 && threads > 1 && m > 1 {
        std::thread::scope(|scope| {
            let chunk = m.div_ceil(threads);
            for (ci, rows) in out.chunks_mut(chunk * nn).enumerate() {
                let row_job = &row_job;
                scope.spawn(move || {
                    for (r, out_row) in rows.chunks_mut(nn).enumerate() {
                        row_job(ci * chunk + r, out_row);
                    }
                });
            }
        });
    } else {
        for (i, out_row) in out.chunks_mut(nn).enumerate() {
            row_job(i, out_row);
        }
    }
    out
}

/// Exact-path GEMM: plain integer MACs wrapped to the PE's W-bit
/// accumulator semantics (used by `matmul` when k == 0).
fn matmul_exact_fast(cfg: &PeConfig, a: &[i64], b: &[i64], m: usize,
                     kk: usize, nn: usize) -> Vec<i64> {
    let dec_op = |v: i64| -> i64 { cfg.decode_operand(v as u64) };
    let ae: Vec<i64> = a.iter().map(|&v| dec_op(v)).collect();
    let mut bt = vec![0i64; kk * nn];
    for t in 0..kk {
        for j in 0..nn {
            bt[j * kk + t] = dec_op(b[t * nn + j]);
        }
    }
    let mut out = vec![0i64; m * nn];
    for i in 0..m {
        let arow = &ae[i * kk..(i + 1) * kk];
        for j in 0..nn {
            let brow = &bt[j * kk..(j + 1) * kk];
            let acc: i64 = arow.iter().zip(brow)
                .map(|(&x, &y)| x.wrapping_mul(y))
                .fold(0i64, |s, p| s.wrapping_add(p));
            out[i * nn + j] = cfg.decode((acc as u64) & cfg.word_mask());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n: u32, signed: bool, family: Family, k: u32) -> PeConfig {
        PeConfig::new(n, signed, family, k)
    }

    #[test]
    fn exact_mac_exhaustive_4bit_signed() {
        let c4 = cfg(4, true, Family::Proposed, 0);
        for a in -8i64..8 {
            for b in -8i64..8 {
                for c in [0i64, 1, -7, 100, -100, 30000, -30000] {
                    assert_eq!(Pe::mac_value(&c4, a, b, c), a * b + c,
                               "a={a} b={b} c={c}");
                }
            }
        }
    }

    #[test]
    fn exact_mac_exhaustive_4bit_unsigned() {
        let c4 = cfg(4, false, Family::Proposed, 0);
        for a in 0i64..16 {
            for b in 0i64..16 {
                assert_eq!(Pe::mac_value(&c4, a, b, 37), a * b + 37);
            }
        }
    }

    #[test]
    fn exact_mac_randomized_8_and_16bit() {
        let mut state = 0x12345678u64;
        let mut rnd = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        for n in [8u32, 16] {
            let c = cfg(n, true, Family::Proposed, 0);
            let half = 1i64 << (n - 1);
            for _ in 0..2000 {
                let a = (rnd() as i64 % (2 * half)) - half;
                let b = (rnd() as i64 % (2 * half)) - half;
                let acc = (rnd() as i64 % 100_000) - 50_000;
                assert_eq!(Pe::mac_value(&c, a, b, acc), a * b + acc,
                           "n={n} a={a} b={b} c={acc}");
            }
        }
    }

    #[test]
    fn k0_exact_for_all_families() {
        for family in Family::ALL {
            let c = cfg(8, true, family, 0);
            for (a, b) in [(-128i64, -128i64), (127, 127), (-77, 33), (5, -9)] {
                assert_eq!(Pe::mac_value(&c, a, b, 0), a * b, "{family:?}");
            }
        }
    }

    #[test]
    fn accumulation_over_many_macs_exact() {
        let c = cfg(8, true, Family::Proposed, 0);
        let mut pe = Pe::new(c);
        let mut want = 0i64;
        for i in 0..200i64 {
            let a = (i * 37 % 255) - 127;
            let b = (i * 91 % 255) - 127;
            pe.mac(c.encode(a), c.encode(b));
            want += a * b;
        }
        assert_eq!(pe.resolve(), want);
    }

    #[test]
    fn approx_error_monotone_in_k() {
        let mut prev = 0f64;
        for k in [0u32, 2, 4, 6, 8] {
            let c = cfg(8, true, Family::Proposed, k);
            let mut sed = 0f64;
            for a in (-128i64..128).step_by(5) {
                for b in (-128i64..128).step_by(7) {
                    sed += (Pe::mac_value(&c, a, b, 0) - a * b).abs() as f64;
                }
            }
            assert!(sed >= prev, "k={k}: {sed} < {prev}");
            prev = sed;
        }
    }

    #[test]
    fn planned_matches_spec() {
        // the optimized kernel must be bit-identical to the readable spec
        let mut state = 0xABCDEFu64;
        let mut rnd = || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for family in Family::ALL {
            for signed in [false, true] {
                for k in [0u32, 3, 8, 12] {
                    let c = PeConfig::new(8, signed, family, k);
                    let plan = MacPlan::new(&c);
                    for _ in 0..200 {
                        let a = rnd() & 0xFF;
                        let b = rnd() & 0xFF;
                        let s = rnd() & c.word_mask();
                        let kc = rnd() & c.word_mask();
                        assert_eq!(mac_step_planned(&plan, a, b, s, kc),
                                   mac_step(&c, a, b, s, kc),
                                   "{family:?} signed={signed} k={k}");
                    }
                }
            }
        }
    }

    #[test]
    fn toggle_counter_advances() {
        let c = cfg(8, true, Family::Proposed, 0);
        let mut pe = Pe::new(c);
        pe.mac(c.encode(57), c.encode(-33));
        assert!(pe.toggles > 0);
        assert_eq!(pe.macs, 1);
    }

    #[test]
    fn exact_fast_path_matches_bitplane_path() {
        // matmul(k=0) takes the integer fast path; it must equal the
        // bit-plane walk exactly, including unsigned and wraparound cases
        let a: Vec<i64> = (0..48).map(|i| ((i * 97) % 255) - 127).collect();
        let b: Vec<i64> = (0..60).map(|i| ((i * 61) % 255) - 127).collect();
        for signed in [true, false] {
            let c = cfg(8, signed, Family::Proposed, 0);
            let fast = matmul(&c, &a, &b, 4, 12, 5);
            // bypass the fast path via the planned kernel
            let plan = MacPlan::new(&c);
            for i in 0..4 {
                for j in 0..5 {
                    let mut s = 0u64;
                    let mut kc = 0u64;
                    for t in 0..12 {
                        let (s2, k2) = mac_step_planned(
                            &plan, c.encode(a[i * 12 + t]),
                            c.encode(b[t * 5 + j]), s, kc);
                        s = s2;
                        kc = k2;
                    }
                    assert_eq!(fast[i * 5 + j], plan.resolve(s, kc),
                               "signed={signed} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn matmul_exact_matches_integer() {
        let c = cfg(8, true, Family::Proposed, 0);
        let a: Vec<i64> = (0..12).map(|i| ((i * 53) % 255) - 127).collect();
        let b: Vec<i64> = (0..20).map(|i| ((i * 29) % 255) - 127).collect();
        let y = matmul(&c, &a, &b, 3, 4, 5);
        for i in 0..3 {
            for j in 0..5 {
                let mut want = 0i64;
                for t in 0..4 {
                    want += a[i * 4 + t] * b[t * 5 + j];
                }
                assert_eq!(y[i * 5 + j], want);
            }
        }
    }
}
