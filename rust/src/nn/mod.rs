//! Served quantized CNN inference with per-layer approximation plans.
//!
//! The paper's pitch — approximate PEs keep "competitive output quality"
//! on error-resilient vision workloads — is only measurable end-to-end
//! on a real multi-layer network, and the per-layer selection literature
//! (e.g. positive/negative approximate multipliers for DNN accelerators,
//! arXiv 2107.09366) shows the payoff comes from choosing the
//! approximation *per layer*. This module is that workload: a small
//! int8-quantized CNN classifier with
//!
//! * a [`Layer`] graph (`Conv2d`/`Relu`/`MaxPool`/`Dense`) with int8
//!   weights, i32-range accumulators, and requantize scales reusing
//!   [`crate::apps::bdcn::requant`] (convolutions) and the shared
//!   [`rshift_round`]`/`[`clip8`] helpers (dense layers);
//! * a seeded, checked-in weight set ([`Network::seeded`]) and a tiny
//!   deterministic eval batch ([`eval_batch`]) — both mirrored
//!   bit-for-bit by `python/compile/kernels/cnn_goldens.py`;
//! * an [`InferPlan`] assigning each GEMM-bearing layer its own design
//!   point `(family, k)` or a per-layer [`AccuracySlo`] resolved through
//!   the zoo router ([`zoo::route`]) — the default plan keeps the first
//!   and last layers exact and approximates the middle
//!   ([`InferPlan::mixed_default`]).
//!
//! Every convolution lowers through the shared [`im2col`] pass onto the
//! GEMM path. [`Network::forward`] stacks the whole batch's patch
//! matrices row-wise into **one** GEMM per layer, so the batch shares a
//! single weight B panel and consecutive batch tiles coalesce in the
//! coordinator's worker pool (asserted against
//! `ServiceStats::coalesced_calls` in `tests/nn_infer.rs`). The serving
//! entrypoint is [`crate::coordinator::Coordinator::serve_nn`], which
//! threads per-layer metered energy into [`NnStats`]; `axsys infer` and
//! `axsys nn-report` (→ `NN_report.json`) expose it on the CLI.

use std::sync::OnceLock;

use crate::apps::bdcn::{requant, Tensor};
use crate::apps::im2col::{im2col, out_dims};
use crate::apps::image::{psnr, scene, texture, Image};
use crate::apps::{clip8, rshift_round, Gemm, WordGemm};
use crate::bench::XorShift;
use crate::pe::word::PeConfig;
use crate::zoo::{self, AccuracySlo, DesignEntry, RouteError, ZOO_N_BITS};
use crate::Family;

/// Network input is a fixed `INPUT_SIDE x INPUT_SIDE` grayscale image
/// (larger/smaller wire images are nearest-resampled by [`input_from`]).
pub const INPUT_SIDE: usize = 16;

/// Number of output classes (logits per image).
pub const N_CLASSES: usize = 10;

/// One node of the quantized network graph.
///
/// All activations are int8-range `i64` values; GEMM accumulators stay
/// in the i32 range (the widest layer sums 72 products of
/// `[0,127] x [-64,63]`, far inside the blocked engines' W=24
/// carry-save accumulator).
#[derive(Clone, Debug)]
pub enum Layer {
    /// Strided 2-D convolution, lowered to GEMM via [`im2col`]. The
    /// accumulators requantize through [`requant`]`(·, shift)` — the
    /// bdcn idiom, which fuses the ReLU clamp into the int8 scale
    /// (`[0, 127]` activations).
    Conv2d {
        /// Stable layer name (stats keys, report rows).
        name: &'static str,
        /// HWIO weight tensor `(kh, kw, cin, cout)`, int8 values.
        w: Tensor,
        /// Output-grid stride (≥ 1).
        stride: usize,
        /// SAME zero padding when true, VALID when false.
        pad: bool,
        /// Right-shift requantization scale.
        shift: u32,
    },
    /// Standalone `max(0, x)` — used after a dense layer whose requant
    /// keeps the full signed int8 range.
    Relu,
    /// VALID max-pooling over a `k x k` window (unfolded through the
    /// same strided [`im2col`] pass the convolutions use).
    MaxPool {
        /// Window side.
        k: usize,
        /// Window stride.
        stride: usize,
    },
    /// Fully-connected layer on the flattened `(y, x, c)` activation.
    /// Requantizes symmetrically ([`rshift_round`] + [`clip8`]), so
    /// logits keep their sign.
    Dense {
        /// Stable layer name (stats keys, report rows).
        name: &'static str,
        /// Row-major `(d_in, d_out)` weight matrix, int8 values.
        w: Vec<i64>,
        /// Input features.
        d_in: usize,
        /// Output features.
        d_out: usize,
        /// Right-shift requantization scale.
        shift: u32,
    },
}

/// The quantized network: an ordered [`Layer`] graph.
#[derive(Clone, Debug)]
pub struct Network {
    /// Layers in execution order.
    pub layers: Vec<Layer>,
}

/// Deterministic int8 weights from the shared xorshift stream
/// (`python/compile/kernels/cnn_goldens.py` mirrors this exactly).
/// Range `[-64, 63]` keeps deep-layer accumulators comfortably inside
/// the requant scales.
fn seeded_weights(seed: u64, len: usize) -> Vec<i64> {
    let mut x = XorShift::new(seed);
    (0..len).map(|_| (x.next_u64() & 127) as i64 - 64).collect()
}

impl Network {
    /// The checked-in classifier: 16x16x1 input → 10 logits.
    ///
    /// ```text
    /// conv1  3x3  1→4  SAME  s1 shift7   (GEMM 256B x 9 x 4)
    /// pool   2x2 VALID s2               → 8x8x4
    /// conv2  3x3  4→8  SAME  s2 shift7   (GEMM 16B x 36 x 8) → 4x4x8
    /// conv3  3x3  8→8  VALID s1 shift7   (GEMM 4B  x 72 x 8) → 2x2x8
    /// dense1 32→16 shift6 + relu         (GEMM B x 32 x 16)
    /// dense2 16→10 shift8                (GEMM B x 16 x 10) → logits
    /// ```
    ///
    /// Weight seeds are fixed and layer-distinct; the same seeds drive
    /// the Python oracle, so every weight is cross-language pinned.
    pub fn seeded() -> Network {
        Network {
            layers: vec![
                Layer::Conv2d {
                    name: "conv1",
                    w: Tensor { shape: [3, 3, 1, 4],
                                data: seeded_weights(0xD1CE01, 36) },
                    stride: 1,
                    pad: true,
                    shift: 7,
                },
                Layer::MaxPool { k: 2, stride: 2 },
                Layer::Conv2d {
                    name: "conv2",
                    w: Tensor { shape: [3, 3, 4, 8],
                                data: seeded_weights(0xD1CE11, 288) },
                    stride: 2,
                    pad: true,
                    shift: 7,
                },
                Layer::Conv2d {
                    name: "conv3",
                    w: Tensor { shape: [3, 3, 8, 8],
                                data: seeded_weights(0xD1CE21, 576) },
                    stride: 1,
                    pad: false,
                    shift: 7,
                },
                Layer::Dense {
                    name: "dense1",
                    w: seeded_weights(0xD1CE31, 512),
                    d_in: 32,
                    d_out: 16,
                    shift: 6,
                },
                Layer::Relu,
                // shift 8 keeps the logits off the int8 rails for the
                // seeded weights (saturated logits would blunt the
                // PSNR/top-1 quality metrics)
                Layer::Dense {
                    name: "dense2",
                    w: seeded_weights(0xD1CE41, 160),
                    d_in: 16,
                    d_out: N_CLASSES,
                    shift: 8,
                },
            ],
        }
    }

    /// Names of the GEMM-bearing layers, in execution order — the slots
    /// an [`InferPlan`] assigns design points to.
    pub fn gemm_layer_names(&self) -> Vec<&'static str> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv2d { name, .. } | Layer::Dense { name, .. } => {
                    Some(*name)
                }
                _ => None,
            })
            .collect()
    }

    /// Number of GEMM-bearing layers (= [`InferPlan`] slots).
    pub fn n_gemm_layers(&self) -> usize {
        self.gemm_layer_names().len()
    }

    /// Run the batch through the graph. `exec(slot, a, b, m, kk, nn)`
    /// computes each layer's GEMM (`slot` is the GEMM-bearing layer
    /// index) — plug in per-layer [`WordGemm`]s for the single-threaded
    /// reference or per-layer `CoordinatorGemm`s for the served path;
    /// both see identical operands, so the serving tiler is the only
    /// thing between them (and it cannot change the bits).
    ///
    /// The whole batch goes through **one** `exec` call per layer: the
    /// patch matrices are stacked row-wise (`m = batch * out_pixels`)
    /// against the layer's single weight matrix `b`, which is what lets
    /// the coordinator share one B panel across the batch and coalesce
    /// consecutive batch tiles.
    ///
    /// Returns the flattened logits, `batch * N_CLASSES` values.
    pub fn forward(
        &self,
        batch: &[Image],
        exec: &mut dyn FnMut(usize, &[i64], &[i64], usize, usize, usize)
            -> Vec<i64>,
    ) -> Vec<i64> {
        assert!(!batch.is_empty(), "empty inference batch");
        let mut xs: Vec<Vec<i64>> = batch.iter().map(input_from).collect();
        let (mut h, mut w, mut c) = (INPUT_SIDE, INPUT_SIDE, 1usize);
        let mut slot = 0usize;
        for layer in &self.layers {
            match layer {
                Layer::Conv2d { w: wq, stride, pad, shift, .. } => {
                    let [kh, kw, cin, cout] = wq.shape;
                    assert_eq!(cin, c, "channel mismatch entering conv");
                    let (oh, ow) = out_dims(h, w, kh, kw, *stride, *pad);
                    let feat = kh * kw * cin;
                    let mut a =
                        Vec::with_capacity(batch.len() * oh * ow * feat);
                    for x in &xs {
                        a.extend(im2col(x, h, w, cin, kh, kw, *stride, *pad));
                    }
                    let m = batch.len() * oh * ow;
                    let y = exec(slot, &a, &wq.data, m, feat, cout);
                    assert_eq!(y.len(), m * cout, "conv GEMM output shape");
                    slot += 1;
                    let per = oh * ow * cout;
                    xs = (0..batch.len())
                        .map(|b| {
                            y[b * per..(b + 1) * per]
                                .iter()
                                .map(|&v| requant(v, *shift))
                                .collect()
                        })
                        .collect();
                    h = oh;
                    w = ow;
                    c = cout;
                }
                Layer::MaxPool { k, stride } => {
                    xs = xs
                        .iter()
                        .map(|x| maxpool(x, h, w, c, *k, *stride))
                        .collect();
                    let (oh, ow) = out_dims(h, w, *k, *k, *stride, false);
                    h = oh;
                    w = ow;
                }
                Layer::Dense { w: wd, d_in, d_out, shift, .. } => {
                    let mut a = Vec::with_capacity(batch.len() * d_in);
                    for x in &xs {
                        assert_eq!(x.len(), *d_in, "flatten size into dense");
                        a.extend(x);
                    }
                    let y = exec(slot, &a, wd, batch.len(), *d_in, *d_out);
                    assert_eq!(y.len(), batch.len() * d_out,
                               "dense GEMM output shape");
                    slot += 1;
                    xs = (0..batch.len())
                        .map(|b| {
                            y[b * d_out..(b + 1) * d_out]
                                .iter()
                                .map(|&v| clip8(rshift_round(v, *shift)))
                                .collect()
                        })
                        .collect();
                }
                Layer::Relu => {
                    for x in xs.iter_mut() {
                        for v in x.iter_mut() {
                            *v = (*v).max(0);
                        }
                    }
                }
            }
        }
        let logits = xs.concat();
        assert_eq!(logits.len(), batch.len() * N_CLASSES,
                   "graph must end in {N_CLASSES} logits per image");
        logits
    }
}

/// The process-wide default network (seeded weights are deterministic,
/// so every pool and every server sees identical parameters).
pub fn default_network() -> &'static Network {
    static NET: OnceLock<Network> = OnceLock::new();
    NET.get_or_init(Network::seeded)
}

/// Center a grayscale image to `[-128, 127]` on the fixed
/// `INPUT_SIDE x INPUT_SIDE` grid. Exact-size images pass through
/// unchanged (the oracle path); other sizes are nearest-neighbour
/// resampled so any wire image is servable.
pub fn input_from(img: &Image) -> Vec<i64> {
    assert!(img.h > 0 && img.w > 0, "empty input image");
    let s = INPUT_SIDE;
    if img.h == s && img.w == s {
        return img.data.iter().map(|&v| v as i64 - 128).collect();
    }
    (0..s * s)
        .map(|i| {
            let (y, x) = (i / s, i % s);
            img.data[(y * img.h / s) * img.w + x * img.w / s] as i64 - 128
        })
        .collect()
}

/// The deterministic eval batch: one structured scene plus seeded
/// textures, all at the network's input size. Mirrored by the Python
/// oracle for the cross-language goldens.
pub fn eval_batch(n: usize) -> Vec<Image> {
    (0..n)
        .map(|i| {
            if i == 0 {
                scene(INPUT_SIDE, INPUT_SIDE)
            } else {
                texture(INPUT_SIDE, INPUT_SIDE, 0x5EED0 + i as u64)
            }
        })
        .collect()
}

/// VALID max-pooling via the strided [`im2col`] unfold: per output
/// pixel, the channel-wise max over the window taps.
pub fn maxpool(x: &[i64], h: usize, w: usize, cin: usize, k: usize,
               stride: usize) -> Vec<i64> {
    let mat = im2col(x, h, w, cin, k, k, stride, false);
    let (oh, ow) = out_dims(h, w, k, k, stride, false);
    let taps = k * k;
    let feat = taps * cin;
    let mut out = vec![0i64; oh * ow * cin];
    for p in 0..oh * ow {
        for c in 0..cin {
            out[p * cin + c] = (0..taps)
                .map(|t| mat[p * feat + t * cin + c])
                .max()
                .unwrap();
        }
    }
    out
}

/// Per-layer approximation assignment for one GEMM-bearing layer.
#[derive(Clone, Debug)]
pub enum LayerPlan {
    /// Bit-exact arithmetic (`k = 0`, family-independent).
    Exact,
    /// A pinned design point; `family = None` keeps the serving pool's
    /// configured family.
    Point {
        /// Multiplier family (`None` = pool default).
        family: Option<Family>,
        /// Approximation level.
        k: u32,
    },
    /// Route this layer through the zoo: the cheapest registered design
    /// point satisfying the SLO runs the layer (typed refusal when
    /// unsatisfiable — a layer is never silently served degraded).
    Slo(AccuracySlo),
}

/// A full inference plan: one [`LayerPlan`] per GEMM-bearing layer, in
/// execution order.
#[derive(Clone, Debug)]
pub struct InferPlan {
    /// Human-readable plan label (report rows, stats).
    pub name: String,
    /// Per-GEMM-layer assignments (`len == Network::n_gemm_layers`).
    pub layers: Vec<LayerPlan>,
}

/// The default mixed plan's middle-layer approximation levels, cycled
/// over the interior layers (proposed family). Graded: the layer right
/// after the exact stem is the most conservative, the deepest interior
/// conv the most aggressive — approximation error injected early passes
/// through every later layer, so tolerance grows with depth.
pub const MIXED_KS: [u32; 3] = [4, 6, 5];

impl InferPlan {
    /// Every layer bit-exact (the reference row of `nn-report`).
    pub fn exact(n: usize) -> InferPlan {
        InferPlan { name: "exact".into(), layers: vec![LayerPlan::Exact; n] }
    }

    /// Every layer at the same design point (`family = None` keeps the
    /// pool's family) — the "uniform-k" rows of `nn-report`.
    pub fn uniform(family: Option<Family>, k: u32, n: usize) -> InferPlan {
        let name = match family {
            Some(f) => format!("uniform {}/k{k}", f.name()),
            None => format!("uniform k{k}"),
        };
        InferPlan {
            name,
            layers: vec![LayerPlan::Point { family, k }; n],
        }
    }

    /// First and last layers exact, interior at level `k` on the pool's
    /// family — the wire semantics of an `AppKind::Nn` request carrying
    /// a plain `k` (the bdcn hybrid idiom generalized). `k = 0` is the
    /// exact plan.
    pub fn hybrid_k(k: u32, n: usize) -> InferPlan {
        let mut p = InferPlan::exact(n);
        p.name = format!("hybrid k{k}");
        if k > 0 {
            for lp in p.layers.iter_mut().take(n.saturating_sub(1)).skip(1) {
                *lp = LayerPlan::Point { family: None, k };
            }
        }
        p
    }

    /// The default served plan: exact first/last, interior layers on
    /// pinned proposed-family points cycling [`MIXED_KS`]. Pinned (not
    /// SLO-routed) so the Python oracle can mirror it literally.
    pub fn mixed_default(n: usize) -> InferPlan {
        let mut p = InferPlan::exact(n);
        p.name = "mixed".into();
        for (i, lp) in
            p.layers.iter_mut().enumerate().take(n.saturating_sub(1)).skip(1)
        {
            *lp = LayerPlan::Point {
                family: Some(Family::Proposed),
                k: MIXED_KS[(i - 1) % MIXED_KS.len()],
            };
        }
        p
    }

    /// Exact first/last with every interior layer routed through the
    /// zoo at `slo` — the wire semantics of an `AppKind::Nn` request
    /// carrying an accuracy SLO.
    pub fn slo_mixed(slo: AccuracySlo, n: usize) -> InferPlan {
        let mut p = InferPlan::exact(n);
        p.name = format!("mixed slo {slo}");
        for lp in p.layers.iter_mut().take(n.saturating_sub(1)).skip(1) {
            *lp = LayerPlan::Slo(slo);
        }
        p
    }

    /// Resolve every slot to a concrete `(family, k)` design point,
    /// routing SLO slots through `route` (the coordinator passes its
    /// counted `route_slo`; [`Self::resolve`] uses the bare zoo router).
    /// `family = None` means "pool default" and is exact-equivalent
    /// when `k = 0`.
    pub fn resolve_with(
        &self,
        route: &mut dyn FnMut(&AccuracySlo)
            -> Result<&'static DesignEntry, RouteError>,
    ) -> Result<Vec<(Option<Family>, u32)>, RouteError> {
        self.layers
            .iter()
            .map(|lp| match lp {
                LayerPlan::Exact => Ok((None, 0)),
                LayerPlan::Point { family, k } => Ok((*family, *k)),
                LayerPlan::Slo(s) => {
                    route(s).map(|e| (Some(e.design.family), e.design.k))
                }
            })
            .collect()
    }

    /// [`Self::resolve_with`] against the process-wide zoo registry
    /// (8-bit signed — the network's operand shape).
    pub fn resolve(
        &self,
    ) -> Result<Vec<(Option<Family>, u32)>, RouteError> {
        self.resolve_with(&mut |s| zoo::route(ZOO_N_BITS, true, s))
    }
}

/// Run the single-threaded reference: one [`WordGemm`] per GEMM-bearing
/// layer at the resolved design points (`default_family` substitutes
/// for `None` slots — pass the serving pool's configured family for
/// differential tests). The served path must be bit-identical to this.
pub fn reference_logits(net: &Network, batch: &[Image],
                        points: &[(Option<Family>, u32)],
                        default_family: Family) -> Vec<i64> {
    assert_eq!(points.len(), net.n_gemm_layers(), "plan/network mismatch");
    let mut gs: Vec<WordGemm> = points
        .iter()
        .map(|&(f, k)| WordGemm {
            cfg: PeConfig::new(ZOO_N_BITS, true, f.unwrap_or(default_family),
                               k),
        })
        .collect();
    net.forward(batch,
                &mut |slot, a, b, m, kk, nn| gs[slot].gemm(a, b, m, kk, nn))
}

/// Render a logits vector as a `batch x N_CLASSES` u8 image (logit +
/// 128; lossless for int8 logits) — the `out` payload of a served
/// [`crate::coordinator::AppResponse`], so inference rides the existing
/// application wire frames unchanged.
pub fn logits_image(logits: &[i64], batch: usize) -> Image {
    assert_eq!(logits.len(), batch * N_CLASSES);
    let mut img = Image::new(batch, N_CLASSES);
    for (o, &v) in img.data.iter_mut().zip(logits.iter()) {
        *o = (v + 128).clamp(0, 255) as u8;
    }
    img
}

/// Index of the first maximal logit of one row (ties break low — the
/// numpy `argmax` convention the oracle shares).
pub fn top1_of(row: &[i64]) -> usize {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best
}

/// Output quality of `logits` against the exact reference: PSNR over
/// the u8-mapped logit vectors (infinite when bit-identical) and the
/// fraction of batch images whose top-1 class matches.
pub fn quality(logits: &[i64], exact: &[i64]) -> (f64, f64) {
    assert_eq!(logits.len(), exact.len());
    let to_u8 = |l: &[i64]| -> Vec<u8> {
        l.iter().map(|&v| (v + 128).clamp(0, 255) as u8).collect()
    };
    let p = psnr(&to_u8(exact), &to_u8(logits));
    let n = logits.len() / N_CLASSES;
    let hits = (0..n)
        .filter(|&b| {
            top1_of(&logits[b * N_CLASSES..(b + 1) * N_CLASSES])
                == top1_of(&exact[b * N_CLASSES..(b + 1) * N_CLASSES])
        })
        .count();
    (p, hits as f64 / n as f64)
}

/// Per-GEMM-layer serving record: the resolved design point, the
/// layer's GEMM geometry, and its metered share of the network energy.
#[derive(Clone, Debug)]
pub struct LayerStat {
    /// Layer name (`conv1` … `dense2`).
    pub name: &'static str,
    /// Resolved family override (`None` = pool default).
    pub family: Option<Family>,
    /// Resolved approximation level.
    pub k: u32,
    /// GEMM rows (batch * output pixels).
    pub m: usize,
    /// GEMM inner dimension (receptive-field features).
    pub kk: usize,
    /// GEMM columns (output channels / features).
    pub nn: usize,
    /// MACs executed for this layer.
    pub macs: u64,
    /// Metered data-dependent energy of this layer, femtojoules.
    pub energy_fj: f64,
    /// MACs covered by an energy meter (`== macs` when fully metered).
    pub metered_macs: u64,
}

impl LayerStat {
    /// Resolved design-point label (`exact`, `proposed/k6`, `pool/k4`).
    pub fn point_label(&self) -> String {
        match (self.family, self.k) {
            (_, 0) => "exact".into(),
            (Some(f), k) => format!("{}/k{k}", f.name()),
            (None, k) => format!("pool/k{k}"),
        }
    }
}

/// Network-level result of one served inference batch: the logits, the
/// per-layer energy breakdown, and output quality vs the exact
/// reference (served through the same path).
#[derive(Clone, Debug)]
pub struct NnStats {
    /// The plan that ran (its [`InferPlan::name`]).
    pub plan: String,
    /// Images in the batch.
    pub batch: usize,
    /// Per-GEMM-layer records, in execution order.
    pub layers: Vec<LayerStat>,
    /// Total metered energy of the plan's run, femtojoules. Computed by
    /// folding the per-layer stats in order, so it equals the sum of
    /// `layers[i].energy_fj` *exactly* (pinned in `tests/nn_infer.rs`).
    pub total_energy_fj: f64,
    /// Flattened logits (`batch * N_CLASSES`).
    pub logits: Vec<i64>,
    /// PSNR of the u8-mapped logits vs the exact reference (infinite
    /// when the plan itself is exact).
    pub logit_psnr_db: f64,
    /// Fraction of the batch whose top-1 class matches the exact
    /// reference (1.0 when exact).
    pub top1_match: f64,
}

impl NnStats {
    /// Total metered energy in microjoules.
    pub fn total_energy_uj(&self) -> f64 {
        self.total_energy_fj * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exact_exec(
    ) -> impl FnMut(usize, &[i64], &[i64], usize, usize, usize) -> Vec<i64>
    {
        |_, a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize| {
            let mut y = vec![0i64; m * nn];
            for i in 0..m {
                for j in 0..nn {
                    y[i * nn + j] = (0..kk)
                        .map(|t| a[i * kk + t] * b[t * nn + j])
                        .sum();
                }
            }
            y
        }
    }

    #[test]
    fn seeded_network_is_deterministic_and_shaped() {
        let n1 = Network::seeded();
        let n2 = Network::seeded();
        assert_eq!(n1.n_gemm_layers(), 5);
        assert_eq!(n1.gemm_layer_names(),
                   ["conv1", "conv2", "conv3", "dense1", "dense2"]);
        for (a, b) in n1.layers.iter().zip(n2.layers.iter()) {
            match (a, b) {
                (Layer::Conv2d { w: wa, .. }, Layer::Conv2d { w: wb, .. }) => {
                    assert_eq!(wa.data, wb.data);
                    assert!(wa.data.iter().all(|&v| (-64..=63).contains(&v)));
                }
                (Layer::Dense { w: wa, .. }, Layer::Dense { w: wb, .. }) => {
                    assert_eq!(wa, wb);
                }
                _ => {}
            }
        }
        // layer seeds are distinct: conv1 and conv2 streams differ
        let (w1, w2) = (seeded_weights(0xD1CE01, 36),
                        seeded_weights(0xD1CE11, 36));
        assert_ne!(w1, w2);
    }

    #[test]
    fn forward_reaches_logits_with_exact_math() {
        let net = Network::seeded();
        let batch = eval_batch(2);
        let logits = net.forward(&batch, &mut exact_exec());
        assert_eq!(logits.len(), 2 * N_CLASSES);
        assert!(logits.iter().all(|&v| (-128..=127).contains(&v)),
                "dense2 requant must clip logits to int8: {logits:?}");
        // exact plan through the word backend gives the same bits
        let pts = InferPlan::exact(net.n_gemm_layers()).resolve().unwrap();
        let r = reference_logits(&net, &batch, &pts, Family::Proposed);
        assert_eq!(logits, r, "word model at k=0 must equal plain matmul");
    }

    #[test]
    fn maxpool_picks_the_channelwise_window_max() {
        // 2 channels, 4x4 -> 2x2 with 2x2/s2 windows
        let mut x = vec![0i64; 4 * 4 * 2];
        // channel 0: value = linear index; channel 1: negated
        for y in 0..4 {
            for xx in 0..4 {
                x[(y * 4 + xx) * 2] = (y * 4 + xx) as i64;
                x[(y * 4 + xx) * 2 + 1] = -((y * 4 + xx) as i64);
            }
        }
        let p = maxpool(&x, 4, 4, 2, 2, 2);
        assert_eq!(p.len(), 2 * 2 * 2);
        // window (0,0) covers indices {0,1,4,5}: max 5 (c0), 0 (c1)
        assert_eq!(&p[0..2], &[5, 0]);
        // window (1,1) covers {10,11,14,15}: max 15 (c0), -10 (c1)
        assert_eq!(&p[6..8], &[15, -10]);
    }

    #[test]
    fn input_from_centers_and_resamples() {
        let exact = scene(INPUT_SIDE, INPUT_SIDE);
        let x = input_from(&exact);
        assert_eq!(x.len(), INPUT_SIDE * INPUT_SIDE);
        assert_eq!(x[0], exact.data[0] as i64 - 128);
        // a larger image resamples deterministically onto the grid
        let big = scene(64, 64);
        let xb = input_from(&big);
        assert_eq!(xb.len(), INPUT_SIDE * INPUT_SIDE);
        assert_eq!(xb[0], big.data[0] as i64 - 128); // (0,0) maps to (0,0)
        assert_eq!(xb[1], big.data[4] as i64 - 128); // x=1 -> src x=4
    }

    #[test]
    fn plans_resolve_as_documented() {
        let n = 5;
        let exact = InferPlan::exact(n).resolve().unwrap();
        assert!(exact.iter().all(|&(f, k)| f.is_none() && k == 0));

        let hy = InferPlan::hybrid_k(6, n).resolve().unwrap();
        assert_eq!(hy[0], (None, 0));
        assert_eq!(hy[n - 1], (None, 0));
        assert!(hy[1..n - 1].iter().all(|&(f, k)| f.is_none() && k == 6));
        // k = 0 hybrid is the exact plan
        let hy0 = InferPlan::hybrid_k(0, n).resolve().unwrap();
        assert!(hy0.iter().all(|&(_, k)| k == 0));

        let mx = InferPlan::mixed_default(n).resolve().unwrap();
        assert_eq!(mx[0], (None, 0));
        assert_eq!(mx[n - 1], (None, 0));
        assert_eq!(mx[1], (Some(Family::Proposed), MIXED_KS[0]));
        assert_eq!(mx[2], (Some(Family::Proposed), MIXED_KS[1]));
        assert_eq!(mx[3], (Some(Family::Proposed), MIXED_KS[2]));

        // SLO slots route through the zoo and honour the bound
        let slo = AccuracySlo { max_nmed: Some(2.5e-3), min_psnr_db: None };
        let sm = InferPlan::slo_mixed(slo, n).resolve().unwrap();
        assert_eq!(sm[0], (None, 0));
        for &(f, k) in &sm[1..n - 1] {
            let e = zoo::registry()
                .iter()
                .find(|e| Some(e.design.family) == f && e.design.k == k)
                .expect("routed point is registered");
            assert!(e.nmed <= 2.5e-3, "routed point violates the SLO");
        }
        // an unsatisfiable per-layer SLO is a typed refusal
        let bad = AccuracySlo { max_nmed: None, min_psnr_db: Some(1e6) };
        assert!(InferPlan::slo_mixed(bad, n).resolve().is_err());
    }

    #[test]
    fn top1_breaks_ties_low_and_quality_is_exactly_one_for_identical() {
        assert_eq!(top1_of(&[3, 7, 7, 1]), 1);
        assert_eq!(top1_of(&[-5, -5, -5]), 0);
        let l = vec![1i64; 2 * N_CLASSES];
        let (p, t) = quality(&l, &l);
        assert!(p.is_infinite());
        assert_eq!(t, 1.0);
    }

    #[test]
    fn logits_image_round_trips_int8_logits() {
        let logits: Vec<i64> = (0..N_CLASSES as i64)
            .map(|v| v * 20 - 100)
            .collect();
        let img = logits_image(&logits, 1);
        assert_eq!((img.h, img.w), (1, N_CLASSES));
        let back: Vec<i64> =
            img.data.iter().map(|&v| v as i64 - 128).collect();
        assert_eq!(back, logits);
    }
}
