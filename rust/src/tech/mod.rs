//! 90 nm-class standard-cell library + calibration.
//!
//! The paper synthesizes with Cadence Genus on 90 nm UMC; neither is
//! available here, so this module provides a first-order cell library
//! whose *absolute* numbers are calibrated so the conventional exact PPC
//! of \[6\] lands on the paper's Table II row (25.81 µm², 1.03 µW @
//! random activity, 262 ps). Every other number in Tables II-IV is then
//! *composed structurally* from netlists — never copied from the paper —
//! so relative comparisons are genuine model output (DESIGN.md §2).
//!
//! Raw per-gate values are typical of published 90 nm libraries
//! (fanout-of-4-ish delays, switching energies of a few fJ).

/// Gate primitive kinds understood by the netlist evaluator.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum GateKind {
    /// Primary input (no cost, no logic).
    Input,
    /// Tied-low constant.
    Const0,
    /// Tied-high constant.
    Const1,
    /// Inverter.
    Inv,
    /// 2-input AND.
    And2,
    /// 2-input OR.
    Or2,
    /// 2-input NAND.
    Nand2,
    /// 2-input NOR.
    Nor2,
    /// 2-input XOR.
    Xor2,
    /// 2-input XNOR.
    Xnor2,
    /// Majority-of-3 complex gate (mirror-adder carry stage).
    Maj3,
}

/// Per-kind parameters plus global calibration scale factors.
pub struct Library {
    /// Area scale applied to every gate's raw µm² figure.
    pub area_cal: f64,
    /// Delay scale applied to every gate's raw ps figure.
    pub delay_cal: f64,
    /// Switching-energy scale applied to every gate's raw fJ figure.
    pub energy_cal: f64,
    /// Leakage scale applied to every gate's raw nW figure.
    pub leak_cal: f64,
    /// D-flip-flop area, µm² (calibrated).
    pub dff_area: f64,
    /// D-flip-flop switching energy per clock, fJ (calibrated).
    pub dff_energy_fj: f64,
    /// D-flip-flop leakage, nW (calibrated).
    pub dff_leak_nw: f64,
    /// Clock-to-Q added once to every register-to-register path.
    pub dff_cq_ps: f64,
}

/// Raw (uncalibrated) parameters: (area, delay_ps, energy_fj, leak_nw).
fn raw(kind: GateKind) -> (f64, f64, f64, f64) {
    match kind {
        GateKind::Input | GateKind::Const0 | GateKind::Const1 => (0.0, 0.0, 0.0, 0.0),
        GateKind::Inv => (1.6, 16.0, 0.45, 0.9),
        GateKind::Nand2 => (2.3, 22.0, 0.70, 1.3),
        GateKind::Nor2 => (2.3, 24.0, 0.72, 1.3),
        GateKind::And2 => (3.1, 34.0, 0.95, 1.7),
        GateKind::Or2 => (3.1, 36.0, 0.97, 1.7),
        GateKind::Xor2 => (4.6, 52.0, 1.60, 2.4),
        GateKind::Xnor2 => (4.6, 52.0, 1.60, 2.4),
        // Mirror-adder carry stage as one complex gate: transistor-level
        // it is only mildly cheaper than the discrete 3xAND2 + 2xOR2 carry
        // (the paper's proposed-exact saving over [6] is ~3-6%).
        GateKind::Maj3 => (14.0, 40.0, 1.80, 4.0),
    }
}

impl Library {
    /// Calibrated cell area, µm².
    pub fn area(&self, kind: GateKind) -> f64 {
        raw(kind).0 * self.area_cal
    }

    /// Calibrated propagation delay, ps.
    pub fn delay_ps(&self, kind: GateKind) -> f64 {
        raw(kind).1 * self.delay_cal
    }

    /// Calibrated switching energy per output toggle, fJ.
    pub fn energy_fj(&self, kind: GateKind) -> f64 {
        raw(kind).2 * self.energy_cal
    }

    /// Calibrated leakage power, nW.
    pub fn leak_nw(&self, kind: GateKind) -> f64 {
        raw(kind).3 * self.leak_cal
    }
}

/// Calibration: chosen once so the conventional exact PPC cell reproduces
/// paper Table II row 1 (see `hw::tests::table2_calibration_anchor`).
pub const LIB: Library = Library {
    area_cal: 0.928,
    delay_cal: 1.553,
    energy_cal: 0.301,
    leak_cal: 0.301,
    dff_area: 6.1 * 0.928,
    dff_energy_fj: 1.9 * 0.301,
    dff_leak_nw: 2.6 * 0.301,
    dff_cq_ps: 45.0,
};

/// Clock period used throughout the paper's SA tables (250 MHz).
pub const PERIOD_NS_250MHZ: f64 = 4.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_gate_costs() {
        // complex gates cost more than simple ones
        assert!(LIB.area(GateKind::Xor2) > LIB.area(GateKind::Nand2));
        assert!(LIB.area(GateKind::Nand2) > LIB.area(GateKind::Inv));
        assert!(LIB.delay_ps(GateKind::Xor2) > LIB.delay_ps(GateKind::Inv));
        assert!(LIB.energy_fj(GateKind::Maj3) > LIB.energy_fj(GateKind::Inv));
    }

    #[test]
    fn nand_cheaper_than_and() {
        // the premise behind the paper's NAND-based NPPC
        assert!(LIB.area(GateKind::Nand2) < LIB.area(GateKind::And2));
        assert!(LIB.delay_ps(GateKind::Nand2) < LIB.delay_ps(GateKind::And2));
    }
}
