//! BDCN-lite CNN edge detection (paper §V-B, CNN-based path).
//!
//! Runs the int8-quantized cascade network trained at artifact-build time
//! (`python/compile/bdcn.py`) through the approximate GEMM backend:
//! blocks 0-1 approximate (level k), blocks 2-3 exact — the paper's
//! Fig. 12 hybrid scheme. Bit-identical to `bdcn.forward_int8`.

use std::path::Path;

use super::image::Image;
use super::Gemm;

/// Cascade blocks in the BDCN-lite network.
pub const N_BLOCKS: usize = 4;
/// Requant shift after each block's first conv (bdcn.DEFAULT_SHIFTS).
pub const SHIFT_W1: u32 = 7;
/// Requant shift after each block's second conv.
pub const SHIFT_W2: u32 = 9;
/// Requant shift applied to the summed side outputs.
pub const SHIFT_SIDE: u32 = 8;

/// One conv tensor: HWIO layout (kh, kw, cin, cout), int8 values in i64.
#[derive(Clone, Debug)]
pub struct Tensor {
    /// `(kh, kw, cin, cout)` dimensions.
    pub shape: [usize; 4],
    /// Row-major (HWIO) weight values.
    pub data: Vec<i64>,
}

/// Quantized weights of one cascade block.
#[derive(Clone, Debug)]
pub struct Block {
    /// First 3x3 conv of the block.
    pub w1: Tensor,
    /// Second 3x3 conv of the block.
    pub w2: Tensor,
    /// 1-channel side-output conv.
    pub side: Tensor,
}

/// Parse `artifacts/bdcn_weights.txt` (see `bdcn.export_qparams_txt`).
pub fn load_weights(path: &Path) -> anyhow::Result<Vec<Block>> {
    let text = std::fs::read_to_string(path)?;
    let mut tensors = std::collections::HashMap::new();
    for line in text.lines() {
        let mut it = line.split_whitespace();
        let name = match it.next() {
            Some(n) => n.to_string(),
            None => continue,
        };
        let dims: Vec<usize> = (0..4)
            .map(|_| it.next().unwrap().parse().unwrap())
            .collect();
        let data: Vec<i64> = it.map(|v| v.parse().unwrap()).collect();
        anyhow::ensure!(data.len() == dims.iter().product::<usize>(),
                        "tensor {name}: bad length");
        tensors.insert(name, Tensor { shape: [dims[0], dims[1], dims[2], dims[3]], data });
    }
    let mut blocks = Vec::new();
    for i in 0..N_BLOCKS {
        blocks.push(Block {
            w1: tensors.remove(&format!("b{i}_w1"))
                .ok_or_else(|| anyhow::anyhow!("missing b{i}_w1"))?,
            w2: tensors.remove(&format!("b{i}_w2"))
                .ok_or_else(|| anyhow::anyhow!("missing b{i}_w2"))?,
            side: tensors.remove(&format!("b{i}_side"))
                .ok_or_else(|| anyhow::anyhow!("missing b{i}_side"))?,
        });
    }
    Ok(blocks)
}

/// SAME-padding integer conv lowered to GEMM via the shared im2col pass.
/// `x`: (h, w, cin) int values; returns raw int32-range accumulators
/// (h, w, cout). Feature order matches `bdcn._conv_q`.
fn conv(g: &mut dyn Gemm, x: &[i64], h: usize, w: usize, wq: &Tensor)
        -> Vec<i64> {
    let [kh, kw, cin, cout] = wq.shape;
    let mat = super::im2col::im2col(x, h, w, cin, kh, kw, 1, true);
    g.gemm(&mat, &wq.data, h * w, kh * kw * cin, cout)
}

/// Requantize an accumulator to a ReLU-clipped int8 activation — the
/// shared post-conv scale of every quantized CNN in the repo (this
/// cascade and the served classifier in [`crate::nn`]).
#[inline]
pub fn requant(v: i64, shift: u32) -> i64 {
    ((v + (1i64 << (shift - 1))) >> shift).clamp(0, 127)
}

/// Full quantized forward pass. `g_approx` runs blocks 0-1 (level k baked
/// into its PE config); `g_exact` runs blocks 2-3.
pub fn forward(g_approx: &mut dyn Gemm, g_exact: &mut dyn Gemm,
               blocks: &[Block], img: &Image) -> Image {
    let (h, w) = (img.h, img.w);
    let mut x: Vec<i64> = img.data.iter().map(|&v| v as i64 - 128).collect();
    let mut cin = 1usize;
    let mut side_acc = vec![0i64; h * w];
    for (bi, blk) in blocks.iter().enumerate() {
        let g: &mut dyn Gemm = if bi < 2 { g_approx } else { g_exact };
        debug_assert_eq!(cin, blk.w1.shape[2]);
        let a1 = conv(g, &x, h, w, &blk.w1);
        let c1 = blk.w1.shape[3];
        let x1: Vec<i64> = a1.iter().map(|&v| requant(v, SHIFT_W1)).collect();
        let a2 = conv(g, &x1, h, w, &blk.w2);
        let c2 = blk.w2.shape[3];
        let x2: Vec<i64> = a2.iter().map(|&v| requant(v, SHIFT_W2)).collect();
        let s = conv(g, &x2, h, w, &blk.side); // cout = 1
        for (acc, &v) in side_acc.iter_mut().zip(s.iter()) {
            *acc += v;
        }
        x = x2;
        cin = c2;
        let _ = c1;
    }
    let mut out = Image::new(h, w);
    for (o, &v) in out.data.iter_mut().zip(side_acc.iter()) {
        let e = (v + (1i64 << (SHIFT_SIDE - 1))) >> SHIFT_SIDE;
        *o = (e + 128).clamp(0, 255) as u8;
    }
    out
}

/// Convenience: forward pass with word-level backends at level `k`.
pub fn forward_word(blocks: &[Block], img: &Image, k: u32) -> Image {
    use crate::apps::WordGemm;
    use crate::pe::word::PeConfig;
    use crate::Family;
    let mut ga = WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, k) };
    let mut ge = WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, 0) };
    forward(&mut ga, &mut ge, blocks, img)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::image::{psnr, scene};

    fn artifacts() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/bdcn_weights.txt");
        p.exists().then_some(p)
    }

    #[test]
    fn weights_load_and_run() {
        let Some(p) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let blocks = load_weights(&p).unwrap();
        assert_eq!(blocks.len(), N_BLOCKS);
        assert_eq!(blocks[0].w1.shape, [3, 3, 1, 8]);
        let img = scene(32, 32);
        let e0 = forward_word(&blocks, &img, 0);
        let e2 = forward_word(&blocks, &img, 2);
        let e8 = forward_word(&blocks, &img, 8);
        let p2 = psnr(&e0.data, &e2.data);
        let p8 = psnr(&e0.data, &e8.data);
        assert!(p2 >= p8, "cascade quality must degrade with k: {p2} vs {p8}");
        assert!(p2 > 25.0, "k=2 CNN PSNR too low: {p2}");
    }
}
