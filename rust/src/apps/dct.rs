//! 8x8 integer-scaled DCT image compression (paper §V-A).
//!
//! Bit-identical mirror of `python/compile/model.py`: HEVC integer
//! coefficients, the (9,9,6,6) shift schedule, int8 coefficient storage,
//! forward + reconstruction through the approximate GEMM backend.
//! Served end-to-end by [`crate::coordinator::Coordinator::serve_dct`]
//! (golden PSNR pinned in `tests/golden_psnr.rs`); requires image
//! dimensions that are multiples of 8.

use super::image::Image;
use super::{clip8, rshift_round, Gemm};

/// HEVC 8-point integer DCT matrix (fits int8).
pub const DCT8: [[i64; 8]; 8] = [
    [64, 64, 64, 64, 64, 64, 64, 64],
    [89, 75, 50, 18, -18, -50, -75, -89],
    [83, 36, -36, -83, -83, -36, 36, 83],
    [75, -18, -89, -50, 50, 89, 18, -75],
    [64, -64, -64, 64, 64, -64, -64, 64],
    [50, -89, 18, 75, -75, -18, 89, -50],
    [36, -83, 83, -36, -36, 83, -83, 36],
    [18, -50, 75, -89, 89, -75, 50, -18],
];

/// Stage shift schedule (model.py DCT_SHIFTS).
pub const SHIFTS: [u32; 4] = [9, 9, 6, 6];

fn dct_mat() -> Vec<i64> {
    DCT8.iter().flatten().copied().collect()
}

fn dct_mat_t() -> Vec<i64> {
    let mut t = vec![0i64; 64];
    for i in 0..8 {
        for j in 0..8 {
            t[j * 8 + i] = DCT8[i][j];
        }
    }
    t
}

/// (H, W) image -> stacked 8x8 blocks (nb*8 x 8, row-major block order).
fn to_blocks(img: &[i64], h: usize, w: usize) -> Vec<i64> {
    let (nbh, nbw) = (h / 8, w / 8);
    let mut out = vec![0i64; h * w];
    for bi in 0..nbh {
        for bj in 0..nbw {
            let base = (bi * nbw + bj) * 64;
            for r in 0..8 {
                for c in 0..8 {
                    out[base + r * 8 + c] = img[(bi * 8 + r) * w + bj * 8 + c];
                }
            }
        }
    }
    out
}

fn from_blocks(blocks: &[i64], h: usize, w: usize) -> Vec<i64> {
    let (nbh, nbw) = (h / 8, w / 8);
    let mut out = vec![0i64; h * w];
    for bi in 0..nbh {
        for bj in 0..nbw {
            let base = (bi * nbw + bj) * 64;
            for r in 0..8 {
                for c in 0..8 {
                    out[(bi * 8 + r) * w + bj * 8 + c] = blocks[base + r * 8 + c];
                }
            }
        }
    }
    out
}

/// Per-block `mat(8x8) @ block`: one wide GEMM with blocks side by side —
/// identical contraction order to model.py's `_blockwise_left`.
fn blockwise_left(g: &mut dyn Gemm, mat: &[i64], blocks: &[i64]) -> Vec<i64> {
    let nb = blocks.len() / 64;
    let mut wide = vec![0i64; 64 * nb]; // (8, nb*8)
    for t in 0..nb {
        for r in 0..8 {
            for c in 0..8 {
                wide[r * (nb * 8) + t * 8 + c] = blocks[t * 64 + r * 8 + c];
            }
        }
    }
    let out = g.gemm(mat, &wide, 8, 8, nb * 8);
    let mut res = vec![0i64; 64 * nb];
    for t in 0..nb {
        for r in 0..8 {
            for c in 0..8 {
                res[t * 64 + r * 8 + c] = out[r * (nb * 8) + t * 8 + c];
            }
        }
    }
    res
}

/// Per-block `block @ mat(8x8)`: one tall GEMM (nb*8 x 8) @ (8 x 8).
fn blockwise_right(g: &mut dyn Gemm, blocks: &[i64], mat: &[i64]) -> Vec<i64> {
    g.gemm(blocks, mat, blocks.len() / 8, 8, 8)
}

/// Forward DCT: centered image -> int8 coefficient blocks.
pub fn forward(g: &mut dyn Gemm, img: &Image) -> Vec<i64> {
    let centered: Vec<i64> = img.data.iter().map(|&v| v as i64 - 128).collect();
    let x = to_blocks(&centered, img.h, img.w);
    let t = blockwise_left(g, &dct_mat(), &x);
    let t: Vec<i64> = t.iter().map(|&v| clip8(rshift_round(v, SHIFTS[0]))).collect();
    let y = blockwise_right(g, &t, &dct_mat_t());
    y.iter().map(|&v| clip8(rshift_round(v, SHIFTS[1]))).collect()
}

/// Inverse DCT: int8 coefficient blocks -> reconstructed image.
pub fn inverse(g: &mut dyn Gemm, coeff: &[i64], h: usize, w: usize) -> Image {
    let t = blockwise_left(g, &dct_mat_t(), coeff);
    let t: Vec<i64> = t.iter().map(|&v| clip8(rshift_round(v, SHIFTS[2]))).collect();
    let x = blockwise_right(g, &t, &dct_mat());
    let x: Vec<i64> = x.iter().map(|&v| rshift_round(v, SHIFTS[3])).collect();
    let flat = from_blocks(&x, h, w);
    let mut img = Image::new(h, w);
    for (o, &v) in img.data.iter_mut().zip(flat.iter()) {
        *o = (v + 128).clamp(0, 255) as u8;
    }
    img
}

/// Full compress -> reconstruct pipeline; returns (reconstruction, coeffs).
pub fn pipeline(g: &mut dyn Gemm, img: &Image) -> (Image, Vec<i64>) {
    let c = forward(g, img);
    let r = inverse(g, &c, img.h, img.w);
    (r, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::image::{psnr, scene};
    use crate::apps::WordGemm;
    use crate::pe::word::PeConfig;
    use crate::Family;

    fn word(k: u32) -> WordGemm {
        WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, k) }
    }

    #[test]
    fn blocks_roundtrip() {
        let img: Vec<i64> = (0..(16 * 24) as i64).collect();
        assert_eq!(from_blocks(&to_blocks(&img, 16, 24), 16, 24), img);
    }

    #[test]
    fn exact_reconstruction_high_quality() {
        let img = scene(64, 64);
        let (recon, _) = pipeline(&mut word(0), &img);
        let p = psnr(&img.data, &recon.data);
        assert!(p > 38.0, "exact DCT pipeline PSNR {p}");
    }

    #[test]
    fn approx_vs_exact_quality_ordering() {
        let img = scene(64, 64);
        let (exact, _) = pipeline(&mut word(0), &img);
        let mut prev = f64::INFINITY;
        for k in [2u32, 4, 6, 8] {
            let (r, _) = pipeline(&mut word(k), &img);
            let p = psnr(&exact.data, &r.data);
            assert!(p <= prev + 1.0, "k={k}: PSNR {p} vs prev {prev}");
            assert!(p > 15.0, "k={k} unusable: {p}");
            prev = p;
        }
    }

    #[test]
    fn coefficients_are_int8() {
        let img = scene(64, 64);
        let c = forward(&mut word(0), &img);
        assert!(c.iter().all(|&v| (-128..=127).contains(&v)));
    }
}
