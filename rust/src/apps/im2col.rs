//! im2col lowering: convolutions → GEMM (paper §V-B).
//!
//! Both edge paths (the Laplacian kernel and the BDCN-lite CNN) and the
//! served CNN classifier ([`crate::nn`]) lower their convolutions to a
//! single `patches @ weights` product so they ride the same tiled GEMM
//! hot path as every other workload — and, through
//! [`super::CoordinatorGemm`], the coordinator's worker pool.
//!
//! Patch layout (pinned by the Python oracle's `model._im2col3` and
//! `bdcn._conv_q`): row `y*out_w + x` holds the receptive field of
//! output pixel `(y, x)`; feature column `(dy*kw + dx)*cin + c`.

/// Unfold a row-major `(h, w, cin)` input into an
/// `(out_h*out_w, kh*kw*cin)` patch matrix, sampling output pixels on a
/// `stride`-spaced grid.
///
/// `pad = true` is SAME zero padding (`out = ceil(h/stride) x
/// ceil(w/stride)`, top-left pad `kh/2` / `kw/2` — the CNN path; at
/// `stride = 1` this is the historical `out = h x w` geometry);
/// `pad = false` is VALID (`out = (h-kh)/stride+1 x (w-kw)/stride+1`,
/// the kernel path). Out-of-image taps contribute zeros — for
/// pre-centered inputs that is the 128-gray border the oracle uses.
/// MaxPool and strided convolutions ([`crate::nn`]) use `stride > 1`;
/// `stride = 1` callers are bit-for-bit unchanged.
pub fn im2col(x: &[i64], h: usize, w: usize, cin: usize, kh: usize,
              kw: usize, stride: usize, pad: bool) -> Vec<i64> {
    assert_eq!(x.len(), h * w * cin, "input shape");
    assert!(kh <= h && kw <= w, "kernel larger than input");
    assert!(stride >= 1, "stride must be >= 1");
    let (ph, pw) = if pad { (kh / 2, kw / 2) } else { (0, 0) };
    let (oh, ow) = if pad {
        (h.div_ceil(stride), w.div_ceil(stride))
    } else {
        ((h - kh) / stride + 1, (w - kw) / stride + 1)
    };
    let feat = kh * kw * cin;
    let mut mat = vec![0i64; oh * ow * feat];
    for dy in 0..kh {
        for dx in 0..kw {
            for y in 0..oh {
                let sy = (y * stride) as isize + dy as isize - ph as isize;
                if sy < 0 || sy >= h as isize {
                    continue; // zero padding
                }
                for xx in 0..ow {
                    let sx = (xx * stride) as isize + dx as isize
                        - pw as isize;
                    if sx < 0 || sx >= w as isize {
                        continue;
                    }
                    let src = (sy as usize * w + sx as usize) * cin;
                    let dst = (y * ow + xx) * feat + (dy * kw + dx) * cin;
                    mat[dst..dst + cin].copy_from_slice(&x[src..src + cin]);
                }
            }
        }
    }
    mat
}

/// Output spatial dimensions of [`im2col`] for the given geometry —
/// exported so conv layers and their callers agree on the grid without
/// re-deriving it.
pub fn out_dims(h: usize, w: usize, kh: usize, kw: usize, stride: usize,
                pad: bool) -> (usize, usize) {
    if pad {
        (h.div_ceil(stride), w.div_ceil(stride))
    } else {
        ((h - kh) / stride + 1, (w - kw) / stride + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_3x3_matches_direct_patch_extraction() {
        let (h, w) = (5usize, 6usize);
        let x: Vec<i64> = (0..(h * w) as i64).collect();
        let mat = im2col(&x, h, w, 1, 3, 3, 1, false);
        let (oh, ow) = (h - 2, w - 2);
        assert_eq!((oh, ow), out_dims(h, w, 3, 3, 1, false));
        assert_eq!(mat.len(), oh * ow * 9);
        for y in 0..oh {
            for xx in 0..ow {
                for dy in 0..3 {
                    for dx in 0..3 {
                        assert_eq!(mat[(y * ow + xx) * 9 + dy * 3 + dx],
                                   x[(y + dy) * w + (xx + dx)],
                                   "({y},{xx}) tap ({dy},{dx})");
                    }
                }
            }
        }
    }

    #[test]
    fn same_padding_zeros_the_border_taps() {
        let (h, w) = (3usize, 3usize);
        let x = vec![7i64; h * w];
        let mat = im2col(&x, h, w, 1, 3, 3, 1, true);
        assert_eq!(mat.len(), h * w * 9);
        // corner pixel (0,0): taps with dy<1 or dx<1 fall outside
        for dy in 0..3 {
            for dx in 0..3 {
                let want = if dy == 0 || dx == 0 { 0 } else { 7 };
                assert_eq!(mat[dy * 3 + dx], want, "tap ({dy},{dx})");
            }
        }
        // centre pixel sees the full field
        let c = (w + 1) * 9;
        assert!(mat[c..c + 9].iter().all(|&v| v == 7));
    }

    #[test]
    fn multi_channel_feature_order_is_tap_major() {
        // (dy*kw + dx)*cin + c — channels contiguous per tap
        let (h, w, cin) = (3usize, 3usize, 2usize);
        let x: Vec<i64> = (0..(h * w * cin) as i64).collect();
        let mat = im2col(&x, h, w, cin, 1, 1, 1, false);
        assert_eq!(mat, x); // 1x1 kernel is the identity unfold
        let mat3 = im2col(&x, h, w, cin, 3, 3, 1, true);
        // centre tap (dy=1, dx=1) of output pixel (0,0) is input (0,0)
        let base = (3 + 1) * cin;
        assert_eq!(&mat3[base..base + cin], &x[0..cin]);
    }

    #[test]
    fn strided_valid_geometry_and_taps() {
        // 2x2 window, stride 2 on 6x6: the MaxPool unfold geometry
        let (h, w) = (6usize, 6usize);
        let x: Vec<i64> = (0..(h * w) as i64).collect();
        let mat = im2col(&x, h, w, 1, 2, 2, 2, false);
        assert_eq!(out_dims(h, w, 2, 2, 2, false), (3, 3));
        assert_eq!(mat.len(), 3 * 3 * 4);
        for y in 0..3 {
            for xx in 0..3 {
                for dy in 0..2 {
                    for dx in 0..2 {
                        assert_eq!(mat[(y * 3 + xx) * 4 + dy * 2 + dx],
                                   x[(y * 2 + dy) * w + (xx * 2 + dx)],
                                   "({y},{xx}) tap ({dy},{dx})");
                    }
                }
            }
        }
        // non-divisible extent floors: 3x3 stride 2 on 6x6 -> 2x2
        assert_eq!(out_dims(h, w, 3, 3, 2, false), (2, 2));
        assert_eq!(im2col(&x, h, w, 1, 3, 3, 2, false).len(), 2 * 2 * 9);
    }

    #[test]
    fn strided_same_geometry_and_padding() {
        // SAME 3x3 stride 2 on 8x8 -> ceil(8/2) = 4x4, pad 1
        let (h, w) = (8usize, 8usize);
        let x: Vec<i64> = (1..=(h * w) as i64).collect();
        let mat = im2col(&x, h, w, 1, 3, 3, 2, true);
        assert_eq!(out_dims(h, w, 3, 3, 2, true), (4, 4));
        assert_eq!(mat.len(), 4 * 4 * 9);
        // output (0,0) samples input rows/cols -1..1: the (dy=0) and
        // (dx=0) taps are zero padding, centre tap is input (0,0)
        for dy in 0..3 {
            for dx in 0..3 {
                let want = if dy == 0 || dx == 0 {
                    0
                } else {
                    x[(dy - 1) * w + (dx - 1)]
                };
                assert_eq!(mat[dy * 3 + dx], want, "tap ({dy},{dx})");
            }
        }
        // output (1,1) is centred on input (2,2): fully interior
        let base = (4 + 1) * 9;
        for dy in 0..3 {
            for dx in 0..3 {
                assert_eq!(mat[base + dy * 3 + dx],
                           x[(1 + dy) * w + (1 + dx)]);
            }
        }
        // odd extent: SAME stride 2 on 7x7 -> ceil(7/2) = 4x4
        let x7: Vec<i64> = (0..49).collect();
        assert_eq!(out_dims(7, 7, 3, 3, 2, true), (4, 4));
        assert_eq!(im2col(&x7, 7, 7, 1, 3, 3, 2, true).len(), 4 * 4 * 9);
    }

    #[test]
    fn stride_one_same_keeps_the_historical_geometry() {
        // the edge/bdcn callers pass stride 1: out = h x w (SAME) /
        // (h-kh+1) x (w-kw+1) (VALID), exactly as before the stride
        // parameter existed
        assert_eq!(out_dims(16, 16, 3, 3, 1, true), (16, 16));
        assert_eq!(out_dims(16, 16, 3, 3, 1, false), (14, 14));
        let x: Vec<i64> = (0..25).collect();
        let strided = im2col(&x, 5, 5, 1, 3, 3, 1, true);
        assert_eq!(strided.len(), 5 * 5 * 9);
    }
}
