//! im2col lowering: convolutions → GEMM (paper §V-B).
//!
//! Both edge paths (the Laplacian kernel and the BDCN-lite CNN) lower
//! their convolutions to a single `patches @ weights` product so they
//! ride the same tiled GEMM hot path as every other workload — and,
//! through [`super::CoordinatorGemm`], the coordinator's worker pool.
//!
//! Patch layout (pinned by the Python oracle's `model._im2col3` and
//! `bdcn._conv_q`): row `y*out_w + x` holds the receptive field of
//! output pixel `(y, x)`; feature column `(dy*kw + dx)*cin + c`.

/// Unfold a row-major `(h, w, cin)` input into an
/// `(out_h*out_w, kh*kw*cin)` patch matrix.
///
/// `pad = true` is SAME zero padding (`out = h x w`, the CNN path);
/// `pad = false` is VALID (`out = (h-kh+1) x (w-kw+1)`, the kernel
/// path). Out-of-image taps contribute zeros — for pre-centered inputs
/// that is the 128-gray border the oracle uses.
pub fn im2col(x: &[i64], h: usize, w: usize, cin: usize, kh: usize,
              kw: usize, pad: bool) -> Vec<i64> {
    assert_eq!(x.len(), h * w * cin, "input shape");
    assert!(kh <= h && kw <= w, "kernel larger than input");
    let (ph, pw) = if pad { (kh / 2, kw / 2) } else { (0, 0) };
    let (oh, ow) = if pad { (h, w) } else { (h + 1 - kh, w + 1 - kw) };
    let feat = kh * kw * cin;
    let mut mat = vec![0i64; oh * ow * feat];
    for dy in 0..kh {
        for dx in 0..kw {
            for y in 0..oh {
                let sy = y as isize + dy as isize - ph as isize;
                if sy < 0 || sy >= h as isize {
                    continue; // zero padding
                }
                for xx in 0..ow {
                    let sx = xx as isize + dx as isize - pw as isize;
                    if sx < 0 || sx >= w as isize {
                        continue;
                    }
                    let src = (sy as usize * w + sx as usize) * cin;
                    let dst = (y * ow + xx) * feat + (dy * kw + dx) * cin;
                    mat[dst..dst + cin].copy_from_slice(&x[src..src + cin]);
                }
            }
        }
    }
    mat
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_3x3_matches_direct_patch_extraction() {
        let (h, w) = (5usize, 6usize);
        let x: Vec<i64> = (0..(h * w) as i64).collect();
        let mat = im2col(&x, h, w, 1, 3, 3, false);
        let (oh, ow) = (h - 2, w - 2);
        assert_eq!(mat.len(), oh * ow * 9);
        for y in 0..oh {
            for xx in 0..ow {
                for dy in 0..3 {
                    for dx in 0..3 {
                        assert_eq!(mat[(y * ow + xx) * 9 + dy * 3 + dx],
                                   x[(y + dy) * w + (xx + dx)],
                                   "({y},{xx}) tap ({dy},{dx})");
                    }
                }
            }
        }
    }

    #[test]
    fn same_padding_zeros_the_border_taps() {
        let (h, w) = (3usize, 3usize);
        let x = vec![7i64; h * w];
        let mat = im2col(&x, h, w, 1, 3, 3, true);
        assert_eq!(mat.len(), h * w * 9);
        // corner pixel (0,0): taps with dy<1 or dx<1 fall outside
        for dy in 0..3 {
            for dx in 0..3 {
                let want = if dy == 0 || dx == 0 { 0 } else { 7 };
                assert_eq!(mat[dy * 3 + dx], want, "tap ({dy},{dx})");
            }
        }
        // centre pixel sees the full field
        let c = (w + 1) * 9;
        assert!(mat[c..c + 9].iter().all(|&v| v == 7));
    }

    #[test]
    fn multi_channel_feature_order_is_tap_major() {
        // (dy*kw + dx)*cin + c — channels contiguous per tap
        let (h, w, cin) = (3usize, 3usize, 2usize);
        let x: Vec<i64> = (0..(h * w * cin) as i64).collect();
        let mat = im2col(&x, h, w, cin, 1, 1, false);
        assert_eq!(mat, x); // 1x1 kernel is the identity unfold
        let mat3 = im2col(&x, h, w, cin, 3, 3, true);
        // centre tap (dy=1, dx=1) of output pixel (0,0) is input (0,0)
        let base = (3 + 1) * cin;
        assert_eq!(&mat3[base..base + cin], &x[0..cin]);
    }
}
