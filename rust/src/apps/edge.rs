//! Laplacian-kernel edge detection via im2col + approximate GEMM
//! (paper §V-B, kernel-based path). Mirrors `model.edge_pipeline`.
//! The stencil lowers to one `(P, 9) @ (9, 1)` product, so plugging in
//! [`super::CoordinatorGemm`] parallelizes it across the worker pool.

use super::im2col::im2col;
use super::image::Image;
use super::{rshift_round, Gemm};

/// 8-neighbour Laplacian (sums to zero — invariant to the -128 centering).
pub const LAPLACIAN: [i64; 9] = [-1, -1, -1, -1, 8, -1, -1, -1, -1];

/// uint8 image -> uint8-range edge map of size (h-2) x (w-2).
pub fn pipeline(g: &mut dyn Gemm, img: &Image) -> Image {
    let (h, w) = (img.h, img.w);
    let (oh, ow) = (h - 2, w - 2);
    let centered: Vec<i64> =
        img.data.iter().map(|&v| v as i64 - 128).collect();
    // VALID im2col: (P, 9) patches, column order (dy, dx) — matches
    // the oracle's _im2col3
    let mat = im2col(&centered, h, w, 1, 3, 3, 1, false);
    let y = g.gemm(&mat, &LAPLACIAN, oh * ow, 9, 1);
    let mut out = Image::new(oh, ow);
    for (o, &v) in out.data.iter_mut().zip(y.iter()) {
        *o = rshift_round(v.abs(), 2).clamp(0, 255) as u8;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::image::{psnr, scene};
    use crate::apps::WordGemm;
    use crate::pe::word::PeConfig;
    use crate::Family;

    fn word(k: u32) -> WordGemm {
        WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, k) }
    }

    #[test]
    fn exact_edges_detect_structure() {
        let img = scene(64, 64);
        let e = pipeline(&mut word(0), &img);
        assert_eq!((e.h, e.w), (62, 62));
        // checkerboard + disks must produce a meaningful number of edges
        let frac = e.data.iter().filter(|&&v| v > 32).count() as f64
            / e.data.len() as f64;
        assert!(frac > 0.02 && frac < 0.6, "{frac}");
    }

    #[test]
    fn flat_region_no_edges() {
        let mut img = Image::new(16, 16);
        img.data.fill(77);
        let e = pipeline(&mut word(0), &img);
        assert!(e.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn quality_degrades_with_k() {
        let img = scene(64, 64);
        let exact = pipeline(&mut word(0), &img);
        let p2 = psnr(&exact.data, &pipeline(&mut word(2), &img).data);
        let p8 = psnr(&exact.data, &pipeline(&mut word(8), &img).data);
        assert!(p2 > p8, "k=2 ({p2}) should beat k=8 ({p8})");
        assert!(p2 > 20.0, "k=2 PSNR too low: {p2}");
    }
}
