//! Image utilities: deterministic procedural test scenes (bit-identical
//! to `python/compile/image.py` — integer-only math), PGM I/O, PSNR and
//! SSIM quality metrics. The checked-in golden images under
//! `rust/tests/data/*.pgm` (oracle-tuned to the paper's §V headline
//! PSNRs) are read back through [`read_pgm`].
//!
//! PGM decoding is exposed as [`decode_pgm`] with the typed
//! [`PgmError`]: application images arrive **over the wire** as inline
//! PGM payloads (see [`crate::net`]), so every malformed header,
//! truncated payload or oversized dimension must surface as a
//! structured error reply — never a panic in a server thread.

use std::fmt;
use std::path::Path;

/// Grayscale image, row-major u8.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    /// Height in pixels.
    pub h: usize,
    /// Width in pixels.
    pub w: usize,
    /// Row-major pixel values.
    pub data: Vec<u8>,
}

impl Image {
    /// An all-black `h x w` image.
    pub fn new(h: usize, w: usize) -> Self {
        Image { h, w, data: vec![0; h * w] }
    }

    /// Pixel at `(y, x)`.
    #[inline]
    pub fn at(&self, y: usize, x: usize) -> u8 {
        self.data[y * self.w + x]
    }

    /// Set pixel at `(y, x)`.
    #[inline]
    pub fn set(&mut self, y: usize, x: usize, v: u8) {
        self.data[y * self.w + x] = v;
    }

    /// Pixels widened to i64 (GEMM operand form).
    pub fn to_i64(&self) -> Vec<i64> {
        self.data.iter().map(|&v| v as i64).collect()
    }

    /// Pixels widened to i32 (PJRT tensor form).
    pub fn to_i32(&self) -> Vec<i32> {
        self.data.iter().map(|&v| v as i32).collect()
    }
}

/// The canonical test scene; must match `compile.image.scene` exactly.
pub fn scene(h: usize, w: usize) -> Image {
    let mut img = Image::new(h, w);
    for y in 0..h {
        for x in 0..w {
            let mut v = ((x * 255) / (w - 1)) as i64;
            if y < h / 3 {
                v = if ((x / 16) + (y / 16)) % 2 == 0 { 224 } else { 32 };
            }
            img.set(y, x, v as u8);
        }
    }
    let disks: [(i64, i64, i64, u8); 3] = [
        ((h / 2) as i64, (w / 4) as i64, (h / 8) as i64, 200),
        ((h / 2) as i64, (w / 2) as i64, (h / 10) as i64, 90),
        (((5 * h) / 8) as i64, ((3 * w) / 4) as i64, (h / 7) as i64, 150),
    ];
    for y in 0..h {
        for x in 0..w {
            for &(cy, cx, r, val) in &disks {
                let d = (y as i64 - cy).pow(2) + (x as i64 - cx).pow(2);
                if d < r * r {
                    img.set(y, x, val);
                }
            }
        }
    }
    for y in (3 * h) / 4..h {
        for x in 0..w {
            let v = if ((x + y) / 8) % 2 == 0 { 240 } else { 16 };
            img.set(y, x, v);
        }
    }
    for y in 0..h {
        for x in 0..w {
            if y < 2 || y >= h - 2 || x < 2 || x >= w - 2 {
                img.set(y, x, 8);
            }
        }
    }
    img
}

/// Seeded LCG texture; must match `compile.image.texture`.
pub fn texture(h: usize, w: usize, seed: u64) -> Image {
    let mut img = Image::new(h, w);
    let mut state = seed;
    for i in 0..h * w {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        img.data[i] = ((state >> 33) & 0xFF) as u8;
    }
    img
}

/// Largest accepted PGM dimension per side: refuses pathological
/// headers (e.g. arriving over the network) before any allocation.
pub const MAX_PGM_DIM: usize = 4096;

/// Why a PGM payload failed to decode. Typed so remote callers get a
/// structured error reply (the wire path feeds untrusted bytes straight
/// into [`decode_pgm`]) instead of a panic or a stringly error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PgmError {
    /// Missing or wrong `P5` signature (only binary PGM is supported).
    BadMagic,
    /// Header ended before width, height and maxval were all present.
    TruncatedHeader,
    /// Width or height is not a positive decimal integer.
    BadDimension,
    /// Width or height exceeds [`MAX_PGM_DIM`] (refused pre-allocation).
    Oversized,
    /// Maxval other than 255 (only 8-bit pixels are supported).
    UnsupportedMaxval,
    /// Pixel payload shorter than the `w * h` bytes the header promised.
    TruncatedPayload {
        /// Bytes the header promised (`w * h`).
        expected: usize,
        /// Bytes actually present after the header.
        got: usize,
    },
}

impl fmt::Display for PgmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PgmError::BadMagic => write!(f, "not a binary (P5) PGM"),
            PgmError::TruncatedHeader => write!(f, "truncated PGM header"),
            PgmError::BadDimension => {
                write!(f, "width/height is not a positive integer")
            }
            PgmError::Oversized => {
                write!(f, "dimensions exceed {MAX_PGM_DIM} pixels per side")
            }
            PgmError::UnsupportedMaxval => {
                write!(f, "maxval must be 255 (8-bit pixels)")
            }
            PgmError::TruncatedPayload { expected, got } => {
                write!(f, "pixel payload truncated: header promises \
                           {expected} bytes, {got} present")
            }
        }
    }
}

impl std::error::Error for PgmError {}

/// Serialize to the binary PGM (P5) byte form [`decode_pgm`] parses —
/// the inline image form application requests carry over the wire.
pub fn encode_pgm(img: &Image) -> Vec<u8> {
    let mut out = format!("P5\n{} {}\n255\n", img.w, img.h).into_bytes();
    out.extend_from_slice(&img.data);
    out
}

/// Binary PGM (P5) writer (the byte form of [`encode_pgm`]).
pub fn write_pgm(path: &Path, img: &Image) -> std::io::Result<()> {
    std::fs::write(path, encode_pgm(img))
}

/// Binary PGM (P5) reader ([`decode_pgm`] over the file's bytes).
pub fn read_pgm(path: &Path) -> std::io::Result<Image> {
    let buf = std::fs::read(path)?;
    decode_pgm(&buf).map_err(|e| std::io::Error::new(
        std::io::ErrorKind::InvalidData,
        format!("bad PGM {}: {e}", path.display())))
}

/// Decode a binary PGM (P5) payload: `P5 <w> <h> 255` header tokens
/// separated by any whitespace run, `#` comment lines allowed anywhere
/// in the header, then one whitespace byte and `w * h` raw pixels
/// (trailing bytes are ignored). Every failure is a typed [`PgmError`];
/// this function never panics on arbitrary input (fuzzed in the tests
/// below).
pub fn decode_pgm(buf: &[u8]) -> Result<Image, PgmError> {
    let mut pos = 0usize;
    let mut tokens: Vec<&[u8]> = Vec::new();
    while tokens.len() < 4 && pos < buf.len() {
        // skip whitespace
        while pos < buf.len() && buf[pos].is_ascii_whitespace() {
            pos += 1;
        }
        if pos < buf.len() && buf[pos] == b'#' {
            while pos < buf.len() && buf[pos] != b'\n' {
                pos += 1;
            }
            continue;
        }
        if pos >= buf.len() {
            break;
        }
        let start = pos;
        while pos < buf.len() && !buf[pos].is_ascii_whitespace() {
            pos += 1;
        }
        tokens.push(&buf[start..pos]);
    }
    match tokens.first() {
        Some(t) if *t == b"P5" => {}
        _ => return Err(PgmError::BadMagic),
    }
    if tokens.len() < 4 {
        return Err(PgmError::TruncatedHeader);
    }
    let dim = |t: &[u8]| -> Result<usize, PgmError> {
        let v: usize = std::str::from_utf8(t)
            .ok()
            .and_then(|s| s.parse().ok())
            .ok_or(PgmError::BadDimension)?;
        if v == 0 {
            return Err(PgmError::BadDimension);
        }
        Ok(v)
    };
    let w = dim(tokens[1])?;
    let h = dim(tokens[2])?;
    if w > MAX_PGM_DIM || h > MAX_PGM_DIM {
        return Err(PgmError::Oversized);
    }
    if tokens[3] != b"255" {
        return Err(PgmError::UnsupportedMaxval);
    }
    pos += 1; // exactly one whitespace byte separates maxval from pixels
    let expected = w * h; // bounded by MAX_PGM_DIM² — cannot overflow
    let got = buf.len().saturating_sub(pos);
    if got < expected {
        return Err(PgmError::TruncatedPayload { expected, got });
    }
    Ok(Image { h, w, data: buf[pos..pos + expected].to_vec() })
}

/// Peak signal-to-noise ratio in dB against a 255 peak. `f64::INFINITY`
/// for identical inputs (the paper reports this as "Inf"/ideal).
pub fn psnr(a: &[u8], b: &[u8]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mse: f64 = a.iter().zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>() / a.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (255.0f64 * 255.0 / mse).log10()
    }
}

/// Global (single-window) SSIM — matches `compile.image.ssim`.
pub fn ssim(a: &[u8], b: &[u8]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let c1 = (0.01f64 * 255.0).powi(2);
    let c2 = (0.03f64 * 255.0).powi(2);
    let mu_a = a.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mu_b = b.iter().map(|&v| v as f64).sum::<f64>() / n;
    let va = a.iter().map(|&v| (v as f64 - mu_a).powi(2)).sum::<f64>() / n;
    let vb = b.iter().map(|&v| (v as f64 - mu_b).powi(2)).sum::<f64>() / n;
    let cov = a.iter().zip(b)
        .map(|(&x, &y)| (x as f64 - mu_a) * (y as f64 - mu_b))
        .sum::<f64>() / n;
    ((2.0 * mu_a * mu_b + c1) * (2.0 * cov + c2))
        / ((mu_a * mu_a + mu_b * mu_b + c1) * (va + vb + c2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scene_is_deterministic_and_structured() {
        let a = scene(64, 64);
        let b = scene(64, 64);
        assert_eq!(a, b);
        // border
        assert_eq!(a.at(0, 0), 8);
        assert_eq!(a.at(63, 63), 8);
        // checkerboard region exists
        assert!(a.data.iter().any(|&v| v == 224));
        assert!(a.data.iter().any(|&v| v == 32));
    }

    #[test]
    fn texture_reproducible() {
        assert_eq!(texture(8, 8, 1234), texture(8, 8, 1234));
        assert_ne!(texture(8, 8, 1234).data, texture(8, 8, 999).data);
    }

    #[test]
    fn pgm_roundtrip() {
        let img = scene(32, 48);
        let dir = std::env::temp_dir().join("axsys_test_pgm");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.pgm");
        write_pgm(&p, &img).unwrap();
        let back = read_pgm(&p).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn pgm_decoder_accepts_comments_and_loose_whitespace() {
        // legal PGM variability: comments after the magic and on their
        // own lines, multi-byte whitespace runs between header tokens
        let img = scene(16, 8);
        let mut buf =
            b"P5 # binary pgm\n# a full comment line\n  8\t16 \n255\n".to_vec();
        buf.extend_from_slice(&img.data);
        assert_eq!(decode_pgm(&buf), Ok(img.clone()));
        // the canonical writer form round-trips through the decoder
        assert_eq!(decode_pgm(&encode_pgm(&img)), Ok(img));
    }

    #[test]
    fn pgm_decoder_returns_typed_errors_never_panics() {
        // wrong / missing magic
        assert_eq!(decode_pgm(b"P2\n2 2\n255\n1234"), Err(PgmError::BadMagic));
        assert_eq!(decode_pgm(b""), Err(PgmError::BadMagic));
        // header ends before maxval
        assert_eq!(decode_pgm(b"P5\n2"), Err(PgmError::TruncatedHeader));
        assert_eq!(decode_pgm(b"P5\n2 2"), Err(PgmError::TruncatedHeader));
        // non-numeric / non-positive dimensions
        assert_eq!(decode_pgm(b"P5\n-2 4\n255\n"), Err(PgmError::BadDimension));
        assert_eq!(decode_pgm(b"P5\n2x 4\n255\n"), Err(PgmError::BadDimension));
        assert_eq!(decode_pgm(b"P5\n0 4\n255\n"), Err(PgmError::BadDimension));
        // unsupported maxval (16-bit PGM)
        assert_eq!(decode_pgm(b"P5\n2 2\n65535\n\0\0\0\0\0\0\0\0"),
                   Err(PgmError::UnsupportedMaxval));
        // payload shorter than the header promises
        assert_eq!(decode_pgm(b"P5\n4 4\n255\nabc"),
                   Err(PgmError::TruncatedPayload { expected: 16, got: 3 }));
        // oversized dimensions refuse before allocating the pixel buffer
        let huge = format!("P5\n{} 2\n255\n", MAX_PGM_DIM + 1);
        assert_eq!(decode_pgm(huge.as_bytes()), Err(PgmError::Oversized));
    }

    #[test]
    fn pgm_decoder_survives_random_garbage() {
        // arbitrary byte soup must produce Ok or a typed Err — no panics
        // (these bytes arrive straight off a TCP socket)
        let mut s = 0x5EEDu64;
        for case in 0..200 {
            let len = (case * 7) % 64;
            let bytes: Vec<u8> = (0..len).map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                s as u8
            }).collect();
            let _ = decode_pgm(&bytes);
            // prefixing the magic exercises the header tokenizer too
            let mut with_magic = b"P5\n".to_vec();
            with_magic.extend_from_slice(&bytes);
            let _ = decode_pgm(&with_magic);
        }
    }

    #[test]
    fn psnr_ssim_identities() {
        let img = scene(32, 32);
        assert!(psnr(&img.data, &img.data).is_infinite());
        assert!((ssim(&img.data, &img.data) - 1.0).abs() < 1e-12);
        let mut noisy = img.data.clone();
        for (i, v) in noisy.iter_mut().enumerate() {
            if i % 7 == 0 {
                *v = v.saturating_add(10);
            }
        }
        let p = psnr(&img.data, &noisy);
        assert!(p > 20.0 && p < 60.0, "{p}");
        assert!(ssim(&img.data, &noisy) < 1.0);
    }
}
