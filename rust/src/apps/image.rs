//! Image utilities: deterministic procedural test scenes (bit-identical
//! to `python/compile/image.py` — integer-only math), PGM I/O, PSNR and
//! SSIM quality metrics. The checked-in golden images under
//! `rust/tests/data/*.pgm` (oracle-tuned to the paper's §V headline
//! PSNRs) are read back through [`read_pgm`].

use std::io::{Read, Write};
use std::path::Path;

/// Grayscale image, row-major u8.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    /// Height in pixels.
    pub h: usize,
    /// Width in pixels.
    pub w: usize,
    /// Row-major pixel values.
    pub data: Vec<u8>,
}

impl Image {
    /// An all-black `h x w` image.
    pub fn new(h: usize, w: usize) -> Self {
        Image { h, w, data: vec![0; h * w] }
    }

    /// Pixel at `(y, x)`.
    #[inline]
    pub fn at(&self, y: usize, x: usize) -> u8 {
        self.data[y * self.w + x]
    }

    /// Set pixel at `(y, x)`.
    #[inline]
    pub fn set(&mut self, y: usize, x: usize, v: u8) {
        self.data[y * self.w + x] = v;
    }

    /// Pixels widened to i64 (GEMM operand form).
    pub fn to_i64(&self) -> Vec<i64> {
        self.data.iter().map(|&v| v as i64).collect()
    }

    /// Pixels widened to i32 (PJRT tensor form).
    pub fn to_i32(&self) -> Vec<i32> {
        self.data.iter().map(|&v| v as i32).collect()
    }
}

/// The canonical test scene; must match `compile.image.scene` exactly.
pub fn scene(h: usize, w: usize) -> Image {
    let mut img = Image::new(h, w);
    for y in 0..h {
        for x in 0..w {
            let mut v = ((x * 255) / (w - 1)) as i64;
            if y < h / 3 {
                v = if ((x / 16) + (y / 16)) % 2 == 0 { 224 } else { 32 };
            }
            img.set(y, x, v as u8);
        }
    }
    let disks: [(i64, i64, i64, u8); 3] = [
        ((h / 2) as i64, (w / 4) as i64, (h / 8) as i64, 200),
        ((h / 2) as i64, (w / 2) as i64, (h / 10) as i64, 90),
        (((5 * h) / 8) as i64, ((3 * w) / 4) as i64, (h / 7) as i64, 150),
    ];
    for y in 0..h {
        for x in 0..w {
            for &(cy, cx, r, val) in &disks {
                let d = (y as i64 - cy).pow(2) + (x as i64 - cx).pow(2);
                if d < r * r {
                    img.set(y, x, val);
                }
            }
        }
    }
    for y in (3 * h) / 4..h {
        for x in 0..w {
            let v = if ((x + y) / 8) % 2 == 0 { 240 } else { 16 };
            img.set(y, x, v);
        }
    }
    for y in 0..h {
        for x in 0..w {
            if y < 2 || y >= h - 2 || x < 2 || x >= w - 2 {
                img.set(y, x, 8);
            }
        }
    }
    img
}

/// Seeded LCG texture; must match `compile.image.texture`.
pub fn texture(h: usize, w: usize, seed: u64) -> Image {
    let mut img = Image::new(h, w);
    let mut state = seed;
    for i in 0..h * w {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        img.data[i] = ((state >> 33) & 0xFF) as u8;
    }
    img
}

/// Binary PGM (P5) writer.
pub fn write_pgm(path: &Path, img: &Image) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{} {}\n255\n", img.w, img.h)?;
    f.write_all(&img.data)?;
    Ok(())
}

/// Binary PGM (P5) reader.
pub fn read_pgm(path: &Path) -> std::io::Result<Image> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    parse_pgm(&buf).ok_or_else(|| std::io::Error::new(
        std::io::ErrorKind::InvalidData, format!("bad PGM: {}", path.display())))
}

fn parse_pgm(buf: &[u8]) -> Option<Image> {
    // P5\n<w> <h>\n255\n<data> with optional comment lines
    let mut pos = 0usize;
    let mut tokens = Vec::new();
    while tokens.len() < 4 && pos < buf.len() {
        // skip whitespace
        while pos < buf.len() && buf[pos].is_ascii_whitespace() {
            pos += 1;
        }
        if pos < buf.len() && buf[pos] == b'#' {
            while pos < buf.len() && buf[pos] != b'\n' {
                pos += 1;
            }
            continue;
        }
        let start = pos;
        while pos < buf.len() && !buf[pos].is_ascii_whitespace() {
            pos += 1;
        }
        tokens.push(&buf[start..pos]);
    }
    if tokens.len() < 4 || tokens[0] != b"P5" {
        return None;
    }
    let w: usize = std::str::from_utf8(tokens[1]).ok()?.parse().ok()?;
    let h: usize = std::str::from_utf8(tokens[2]).ok()?.parse().ok()?;
    if tokens[3] != b"255" {
        return None;
    }
    pos += 1; // single whitespace after maxval
    let data = buf.get(pos..pos + h * w)?.to_vec();
    Some(Image { h, w, data })
}

/// Peak signal-to-noise ratio in dB against a 255 peak. `f64::INFINITY`
/// for identical inputs (the paper reports this as "Inf"/ideal).
pub fn psnr(a: &[u8], b: &[u8]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mse: f64 = a.iter().zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>() / a.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (255.0f64 * 255.0 / mse).log10()
    }
}

/// Global (single-window) SSIM — matches `compile.image.ssim`.
pub fn ssim(a: &[u8], b: &[u8]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let c1 = (0.01f64 * 255.0).powi(2);
    let c2 = (0.03f64 * 255.0).powi(2);
    let mu_a = a.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mu_b = b.iter().map(|&v| v as f64).sum::<f64>() / n;
    let va = a.iter().map(|&v| (v as f64 - mu_a).powi(2)).sum::<f64>() / n;
    let vb = b.iter().map(|&v| (v as f64 - mu_b).powi(2)).sum::<f64>() / n;
    let cov = a.iter().zip(b)
        .map(|(&x, &y)| (x as f64 - mu_a) * (y as f64 - mu_b))
        .sum::<f64>() / n;
    ((2.0 * mu_a * mu_b + c1) * (2.0 * cov + c2))
        / ((mu_a * mu_a + mu_b * mu_b + c1) * (va + vb + c2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scene_is_deterministic_and_structured() {
        let a = scene(64, 64);
        let b = scene(64, 64);
        assert_eq!(a, b);
        // border
        assert_eq!(a.at(0, 0), 8);
        assert_eq!(a.at(63, 63), 8);
        // checkerboard region exists
        assert!(a.data.iter().any(|&v| v == 224));
        assert!(a.data.iter().any(|&v| v == 32));
    }

    #[test]
    fn texture_reproducible() {
        assert_eq!(texture(8, 8, 1234), texture(8, 8, 1234));
        assert_ne!(texture(8, 8, 1234).data, texture(8, 8, 999).data);
    }

    #[test]
    fn pgm_roundtrip() {
        let img = scene(32, 48);
        let dir = std::env::temp_dir().join("axsys_test_pgm");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.pgm");
        write_pgm(&p, &img).unwrap();
        let back = read_pgm(&p).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn psnr_ssim_identities() {
        let img = scene(32, 32);
        assert!(psnr(&img.data, &img.data).is_infinite());
        assert!((ssim(&img.data, &img.data) - 1.0).abs() < 1e-12);
        let mut noisy = img.data.clone();
        for (i, v) in noisy.iter_mut().enumerate() {
            if i % 7 == 0 {
                *v = v.saturating_add(10);
            }
        }
        let p = psnr(&img.data, &noisy);
        assert!(p > 20.0 && p < 60.0, "{p}");
        assert!(ssim(&img.data, &noisy) < 1.0);
    }
}
