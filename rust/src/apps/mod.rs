//! Application pipelines (paper §V): DCT image compression, Laplacian
//! edge detection, and the BDCN-lite CNN edge detector — each driven
//! through a pluggable GEMM backend so the same pipeline runs on the
//! word-level PE model, the cycle-accurate systolic array, the AOT
//! PJRT artifacts, or — via [`CoordinatorGemm`] — the coordinator's
//! tiled worker pool (the serving path; see
//! [`crate::coordinator::Coordinator::serve_dct`] and friends).
//!
//! Convolutions are lowered to GEMM with the shared [`im2col`] pass, so
//! every pipeline is a sequence of matrix products on whichever backend
//! the caller plugs in.

pub mod bdcn;
pub mod dct;
pub mod edge;
pub mod im2col;
pub mod image;

use crate::coordinator::{Coordinator, GemmRequest};
use crate::pe::word::PeConfig;
use crate::systolic::{SaStats, Systolic};

/// Integer GEMM backend abstraction: `C(m x n) = A(m x k) @ B(k x n)`.
pub trait Gemm {
    /// Compute `C(m x nn) = A(m x kk) @ B(kk x nn)` (row-major slices).
    fn gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize)
            -> Vec<i64>;

    /// Execution stats accumulated so far, if the backend tracks any.
    fn stats(&self) -> Option<SaStats> {
        None
    }
}

/// Fast functional backend: one virtual PE per output element, routed
/// through the cache-blocked word engine ([`crate::gemm::matmul_word`]).
pub struct WordGemm {
    /// PE design point (family, widths, signedness, approximation `k`).
    pub cfg: PeConfig,
}

impl Gemm for WordGemm {
    fn gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize)
            -> Vec<i64> {
        crate::gemm::matmul_word(&self.cfg, a, b, m, kk, nn)
    }
}

/// Table-driven backend: shared product-LUT tables through the blocked
/// driver ([`crate::gemm::matmul`]), bit-identical to [`WordGemm`]
/// (falls back to the word kernel for non-LUT-compilable design points).
pub struct LutGemm {
    /// PE design point (family, widths, signedness, approximation `k`).
    pub cfg: PeConfig,
}

impl Gemm for LutGemm {
    fn gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize)
            -> Vec<i64> {
        crate::gemm::matmul(&self.cfg, a, b, m, kk, nn)
    }
}

/// Cycle-accurate backend: tiles through a real systolic array and
/// accumulates cycle/energy statistics.
pub struct SystolicGemm {
    /// The simulated array (owns the PE grid and operand registers).
    pub sa: Systolic,
    /// Cycle/toggle/MAC statistics merged over every call so far.
    pub stats: SaStats,
}

impl SystolicGemm {
    /// A `size`×`size` array of PEs configured by `cfg`.
    pub fn new(cfg: PeConfig, size: usize) -> Self {
        SystolicGemm { sa: Systolic::square(cfg, size), stats: SaStats::default() }
    }
}

impl Gemm for SystolicGemm {
    fn gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize)
            -> Vec<i64> {
        let (y, st) = self.sa.gemm(a, b, m, kk, nn);
        self.stats.merge(&st);
        y
    }

    fn stats(&self) -> Option<SaStats> {
        Some(self.stats)
    }
}

/// Serving-path backend: implements [`Gemm`] by submitting every matrix
/// product to a running [`Coordinator`], which tiles it to the array's
/// output geometry and fans the tiles across its worker pool.
///
/// Bit-identical to the single-threaded `word`/`lut`/`systolic`
/// backends at every approximation level, because the coordinator tiles
/// only the *output* dimensions: each output element's carry-save MAC
/// chain still walks the full inner dimension in order
/// (`tests/prop_equiv.rs` fuzzes this equivalence).
pub struct CoordinatorGemm<'a> {
    coord: &'a Coordinator,
    /// Approximation level submitted with every request.
    pub k: u32,
    /// Multiplier-family override submitted with every request (`None`
    /// = the pool's configured family). Set by the SLO-routed app
    /// endpoints so a routed design point pins *both* family and `k`.
    pub family: Option<crate::Family>,
    /// Execution stats merged from every response so far.
    pub stats: SaStats,
    /// GEMM requests issued through the coordinator so far.
    pub requests: u64,
}

impl<'a> CoordinatorGemm<'a> {
    /// Adapter submitting every product to `coord` at approximation `k`.
    pub fn new(coord: &'a Coordinator, k: u32) -> Self {
        Self::with_family(coord, None, k)
    }

    /// Adapter pinning the full design point: every product runs at
    /// `family` (`None` = pool default) and approximation `k`.
    pub fn with_family(coord: &'a Coordinator, family: Option<crate::Family>,
                       k: u32) -> Self {
        CoordinatorGemm { coord, k, family, stats: SaStats::default(),
                          requests: 0 }
    }
}

impl Gemm for CoordinatorGemm<'_> {
    fn gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize)
            -> Vec<i64> {
        let resp = self.coord.call(GemmRequest {
            a: a.to_vec(),
            b: b.to_vec(),
            m,
            kk,
            nn,
            k: self.k,
            family: self.family,
            ..Default::default()
        });
        self.requests += 1;
        self.stats.merge(&resp.sa_stats);
        resp.out
    }

    fn stats(&self) -> Option<SaStats> {
        Some(self.stats)
    }
}

/// Arithmetic right shift with round-to-nearest (matches the Python
/// models' `_rshift_round`; Rust `>>` on i64 is arithmetic like numpy's).
#[inline]
pub fn rshift_round(v: i64, s: u32) -> i64 {
    if s == 0 { v } else { (v + (1i64 << (s - 1))) >> s }
}

/// Saturate to the int8 range (coefficient storage in the DCT pipeline).
#[inline]
pub fn clip8(v: i64) -> i64 {
    v.clamp(-128, 127)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Family;

    #[test]
    fn word_and_systolic_backends_agree() {
        let cfg = PeConfig::new(8, true, Family::Proposed, 5);
        let a: Vec<i64> = (0..40).map(|i| (i * 13 % 255) - 127).collect();
        let b: Vec<i64> = (0..55).map(|i| (i * 29 % 255) - 127).collect();
        let mut wg = WordGemm { cfg };
        let mut sg = SystolicGemm::new(cfg, 8);
        let mut lg = LutGemm { cfg };
        let w = wg.gemm(&a, &b, 8, 5, 11);
        assert_eq!(w, sg.gemm(&a, &b, 8, 5, 11));
        assert_eq!(w, lg.gemm(&a, &b, 8, 5, 11));
        assert!(sg.stats().unwrap().macs > 0);
    }

    #[test]
    fn coordinator_gemm_matches_word_backend() {
        use crate::coordinator::{BackendKind, CoordinatorConfig};
        let cfg = PeConfig::new(8, true, Family::Proposed, 3);
        let a: Vec<i64> = (0..60).map(|i| (i * 17 % 255) - 127).collect();
        let b: Vec<i64> = (0..36).map(|i| (i * 23 % 255) - 127).collect();
        let want = WordGemm { cfg }.gemm(&a, &b, 10, 6, 6);
        let c = Coordinator::new(CoordinatorConfig {
            workers: 2,
            backend: BackendKind::Word,
            ..Default::default()
        });
        let mut g = CoordinatorGemm::new(&c, 3);
        assert_eq!(g.gemm(&a, &b, 10, 6, 6), want);
        assert_eq!(g.requests, 1);
        assert!(g.stats().unwrap().macs > 0);
        c.shutdown();
    }

    #[test]
    fn rshift_round_matches_numpy_semantics() {
        // python: (v + (1 << (s-1))) >> s with floor division
        assert_eq!(rshift_round(10, 2), 3);   // 10.5 -> floor(14/4)=3
        assert_eq!(rshift_round(-10, 2), -2); // (-10+2)>>2 = -8>>2 = -2
        assert_eq!(rshift_round(7, 0), 7);
    }
}
