//! # axsys — energy-efficient exact & approximate systolic arrays
//!
//! Reproduction of *"Energy Efficient Exact and Approximate Systolic Array
//! Architecture for Matrix Multiplication"* (Jaswal, Krishna, Srinivasu —
//! VLSID 2026) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): the approximate-GEMM Pallas
//!   kernel — bit-exact word-level emulation of the paper's PPC/NPPC grid.
//! * **Layer 2** (`python/compile/`): DCT, Laplacian-edge and BDCN-lite
//!   pipelines in JAX, AOT-lowered to HLO text artifacts.
//! * **Layer 3** (this crate): the coordinator — gate-level hardware model,
//!   cycle-accurate systolic-array simulator, error-metric engines, the
//!   GEMM tiling/batching service, and a PJRT runtime that executes the
//!   AOT artifacts. Python never runs on the request path.
//!
//! Module map (layer diagram and request data-flow: `ARCHITECTURE.md`
//! at the repository root; experiment index: DESIGN.md):
//!
//! | module        | role |
//! |---------------|------|
//! | [`cells`]     | PPC/NPPC truth-table cells, exact + approximate + baselines |
//! | [`netlist`]   | gate-level netlists: evaluation, STA, toggle power |
//! | [`tech`]      | 90 nm-class standard-cell library + calibration |
//! | [`pe`]        | PE functional models ([`pe::word`] bit-plane walk, [`pe::lut`] product-LUT tables) + PE netlist builders |
//! | [`gemm`]      | cache-blocked (MC×KC×NC, packed-panel) GEMM driver all software backends route through: 8-chain LUT microkernel, 64-lane bit-plane word kernel, startup block-size autotune |
//! | [`energy`]    | data-dependent per-MAC energy model: netlist activity replay + per-design-point [`energy::EnergyLut`] tables the meters read |
//! | [`systolic`]  | cycle-accurate output-stationary systolic array |
//! | [`error`]     | ED / NMED / MRED sweeps (paper Table V, Figs 9-10) |
//! | [`hw`]        | metric composition cell→PE→SA (Tables II-IV, Fig 8) |
//! | [`apps`]      | DCT / edge / BDCN pipelines (+ [`apps::im2col`] conv→GEMM lowering, [`apps::CoordinatorGemm`] serving adapter) + image I/O + PSNR/SSIM |
//! | [`runtime`]   | PJRT client: load + execute `artifacts/*.hlo.txt` (feature `pjrt`) |
//! | [`coordinator`]| GEMM request router: tiler, batched+coalesced dispatch, worker pool — plus the app endpoints (`serve_dct`/`serve_edge`/`serve_bdcn`) with per-app stats and latency percentiles |
//! | [`nn`]        | served quantized CNN inference: int8 [`nn::Layer`] graph, seeded [`nn::Network`], per-layer approximation plans ([`nn::InferPlan`]) resolved through the zoo router, batch-stacked conv→GEMM lowering, per-layer [`nn::NnStats`] energy/accuracy |
//! | [`net`]       | framed TCP serving layer: versioned wire protocol, sharded `poll(2)` event-loop server (readiness-backoff admission gate, resolver pool) fronting the coordinator, blocking client + [`net::client::RemoteGemm`], load generator with a ≥1k-connection scale mode |
//! | [`zoo`]       | design-point registry (families × k with oracle-pinned energy/error columns) + the [`zoo::AccuracySlo`] router that picks the cheapest point meeting a per-request accuracy SLO |
//! | [`bench`]     | tiny criterion-free measurement harness + the `bench-report` JSON emitter |
//!
//! ## Choosing a GEMM backend
//!
//! Four backends compute the same approximate arithmetic; pick by what
//! you need to observe (all are request-selectable in [`coordinator`]):
//!
//! * [`coordinator::BackendKind::Lut`] — table-driven
//!   ([`pe::lut`]): per-design-point product table + carry-save-window
//!   automaton, built once and `Arc`-shared across workers, executed
//!   through the cache-blocked driver in [`gemm`]. Bit-identical
//!   to `Word` and the fastest path for serving (≥5× on large GEMMs, see
//!   `benches/hotpath.rs` `lut_vs_word`). Use it whenever you only need
//!   results. Design points it cannot compile (`n > 8`, `k > n`,
//!   over-budget tables) transparently fall back to the word model.
//! * [`coordinator::BackendKind::Word`] — the word-level bit-plane walk
//!   ([`pe::word`], blocked by [`gemm`]): no table build cost, works for
//!   every `n <= 16`, and
//!   is the normative software model the Python oracle pins. Use it for
//!   one-off calls, wide operands, or when auditing the LUT path.
//! * [`coordinator::BackendKind::Systolic`] — cycle-accurate array
//!   simulation: adds cycle/toggle/energy observability at ~1000× the
//!   cost. Use it when the *hardware* numbers matter, not throughput.
//! * [`coordinator::BackendKind::Pjrt`] — the AOT Pallas artifacts via
//!   PJRT (requires the `pjrt` feature + artifacts; chunked-K deployment
//!   mode, bit-identical only at `k = 0`).
//!
//! The compile-checked version of the choice (the README quickstart):
//!
//! ```
//! use axsys::pe::word::{matmul as word_matmul, PeConfig};
//! use axsys::Family;
//!
//! // a design point: 8-bit signed operands, the paper's proposed cells,
//! // 4 approximate least-significant columns
//! let cfg = PeConfig::new(8, true, Family::Proposed, 4);
//! let a: Vec<i64> = (0..4 * 3).map(|i| (i * 37 % 255) - 127).collect();
//! let b: Vec<i64> = (0..3 * 2).map(|i| (i * 91 % 255) - 127).collect();
//!
//! // normative word model vs the blocked serving driver: same bits
//! let y_word = word_matmul(&cfg, &a, &b, 4, 3, 2);
//! let y_blocked = axsys::gemm::matmul(&cfg, &a, &b, 4, 3, 2);
//! assert_eq!(y_word, y_blocked);
//! ```
//!
//! And the served path — submit to a worker pool on any backend and get
//! the same bits back:
//!
//! ```
//! use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig,
//!                          GemmRequest};
//!
//! let pool = Coordinator::new(CoordinatorConfig {
//!     workers: 2,
//!     backend: BackendKind::Lut,
//!     ..Default::default()
//! });
//! let resp = pool.call(GemmRequest {
//!     a: vec![1; 8 * 8], b: vec![2; 8 * 8],
//!     m: 8, kk: 8, nn: 8,
//!     k: 0, // exact request
//!     ..Default::default() // no family override, no accuracy SLO
//! });
//! assert_eq!(resp.out[0], 16); // sum of 8 products of 1*2
//! let stats = pool.stats();
//! assert_eq!(stats.requests, 1);
//! pool.shutdown();
//! ```
//!
//! ## Coordinator-served applications
//!
//! The paper's §V pipelines run end-to-end through the coordinator: the
//! convolutions are lowered to GEMM by [`apps::im2col`], every matrix
//! product is submitted via [`apps::CoordinatorGemm`], and the
//! [`coordinator::Coordinator::serve_dct`] / `serve_edge` / `serve_bdcn`
//! endpoints report quality PSNR, per-app counters and latency
//! percentiles in [`coordinator::ServiceStats`]. Because the coordinator
//! tiles only output dimensions, the served results are **bit-identical**
//! to the single-threaded backends at every approximation level
//! (fuzzed in `tests/prop_equiv.rs`, golden-pinned in
//! `tests/golden_psnr.rs`: DCT 38.21 dB, edge 30.45 dB — the paper's
//! headline numbers).
//!
//! ## Network serving
//!
//! The [`net`] layer puts a process boundary in front of the pool:
//! `axsys serve --listen ADDR` exposes the coordinator over a
//! length-prefixed, versioned binary TCP protocol (GEMM, application
//! requests with inline PGM images, stats snapshots, typed errors).
//! The server pipelines per connection behind a max-inflight admission
//! gate that **blocks reads instead of dropping**, and
//! [`net::client::RemoteGemm`] implements [`apps::Gemm`] so any
//! pipeline runs remotely unchanged — bit-identically, as
//! `tests/net_serve.rs` pins for every backend. `axsys loadgen` drives
//! a live server with a seeded multi-client mix and emits
//! `BENCH_serve_net.json`.
//!
//! ## Energy accounting
//!
//! Every served request also reports calibrated, **data-dependent**
//! energy: the [`energy`] subsystem derives a per-MAC energy model
//! straight from the gate netlists (activity replay through
//! [`netlist::Stepper`], tabulated per design point in
//! [`energy::EnergyLut`]) and the execution layers meter with it —
//! table lookups on the blocked software engines, true netlist replay
//! on the cycle-accurate systolic backend. See
//! [`coordinator::GemmResponse::energy_uj`],
//! [`coordinator::ServiceStats`], the `energy-report` CLI subcommand,
//! and the "Energy data-flow" section of ARCHITECTURE.md. Metering
//! observes and never reorders — the bit-identity suites run with it
//! enabled.

#![warn(missing_docs)]

pub mod apps;
pub mod bench;
pub mod cells;
pub mod coordinator;
pub mod energy;
pub mod error;
pub mod gemm;
pub mod hw;
pub mod net;
pub mod netlist;
pub mod nn;
pub mod pe;
pub mod runtime;
pub mod systolic;
pub mod tech;
pub mod zoo;

/// Approximate-cell families evaluated throughout the paper, plus the
/// zoo variants registered by [`zoo`].
///
/// `Proposed` is the paper's contribution (Table I); `Axsa5`/`Sips12`/
/// `Nano6` are reconstructions of the baselines it compares against
/// (DESIGN.md §2):
/// * `Axsa5`  — Waris et al., IEEE TC 2021 \[5\]: carry-elided compressor
///   (exact 3-input XOR sum, carry output removed).
/// * `Sips12` — Waris et al., SiPS 2019 \[12\]: XNOR-based inexact cell.
/// * `Nano6`  — Chen/Lombardi, NANOARCH 2015 \[6\]: inexact cell.
///
/// The last two are classic approximate-multiplier techniques from the
/// wider literature, expressed in the same PPC/NPPC cell grid so the
/// [`zoo`] registry spans more of the energy/accuracy plane:
/// * `Trunc` — truncated partial products: the AND gate of every
///   approximate column is dropped, 3:2 compression stays exact.
/// * `Loa`   — lower-part OR adder (Mahdiani et al.): approximate
///   columns OR the product into the sum rail and pass carries through.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Family {
    /// The paper's proposed approximate PPC/NPPC cells (Table I).
    Proposed,
    /// Carry-elided compressor baseline (Waris et al., IEEE TC 2021).
    Axsa5,
    /// XNOR-based inexact cell baseline (Waris et al., SiPS 2019).
    Sips12,
    /// Inexact cell baseline (Chen/Lombardi, NANOARCH 2015).
    Nano6,
    /// Truncated-partial-product zoo variant (dropped AND gates).
    Trunc,
    /// Lower-part-OR-adder zoo variant (Mahdiani et al. LOA).
    Loa,
}

impl Family {
    /// Every family: the paper's four in comparison order, then the zoo
    /// variants.
    pub const ALL: [Family; 6] =
        [Family::Proposed, Family::Axsa5, Family::Sips12, Family::Nano6,
         Family::Trunc, Family::Loa];

    /// Stable lower-case name (CLI + cache keys).
    pub fn name(self) -> &'static str {
        match self {
            Family::Proposed => "proposed",
            Family::Axsa5 => "axsa5",
            Family::Sips12 => "sips12",
            Family::Nano6 => "nano6",
            Family::Trunc => "trunc",
            Family::Loa => "loa",
        }
    }

    /// Inverse of [`Self::name`] (`None` for unknown names).
    pub fn parse(s: &str) -> Option<Family> {
        Self::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// Label used in the paper's tables (zoo variants use their
    /// literature names — they do not appear in the paper).
    pub fn paper_label(self) -> &'static str {
        match self {
            Family::Proposed => "Proposed",
            Family::Axsa5 => "Design [5]",
            Family::Sips12 => "Design [12]",
            Family::Nano6 => "Design [6]",
            Family::Trunc => "Truncated",
            Family::Loa => "LOA",
        }
    }
}
