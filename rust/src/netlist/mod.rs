//! Gate-level netlists: construction, levelized evaluation, static timing
//! and toggle-activity power — the "synthesis" substrate standing in for
//! the paper's Cadence Genus flow (DESIGN.md §2).
//!
//! Netlists are DAGs built in topological order (a builder can only
//! reference already-created nets), so evaluation is a single forward
//! pass; static timing is the longest weighted path; dynamic power is
//! per-gate toggle counting over simulated vector streams (the same
//! first-order `α·C·V²·f` model synthesis power tools report).
//!
//! Activity replay is incremental: a [`Stepper`] feeds input vectors one
//! at a time and returns the *per-step* switched energy in femtojoules
//! (per-gate library energy for every toggled net, plus the register
//! clocking term), so callers can attribute energy to individual cycles
//! instead of only aggregate power. [`Netlist::power_uw`] is a thin
//! aggregation over the stepper, and the data-dependent per-MAC model in
//! [`crate::energy`] is built entirely on this API (DESIGN.md §4).

pub mod verilog;

use crate::tech::{self, GateKind};

/// Index of a net (the output of one gate) inside a [`Netlist`].
pub type NetId = u32;

/// Sentinel for unused gate input slots.
const NONE: NetId = u32::MAX;

/// One gate instance: a primitive kind plus up to three input nets.
#[derive(Clone, Copy, Debug)]
pub struct Gate {
    /// The primitive this gate instantiates.
    pub kind: GateKind,
    /// Input nets (unused slots hold the internal sentinel).
    pub ins: [NetId; 3],
}

/// A combinational netlist plus its sequential boundary (DFF count).
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    /// Gates in topological (creation) order; a gate's output NetId is
    /// its index here.
    pub gates: Vec<Gate>,
    /// Primary inputs (order = evaluation argument order).
    pub inputs: Vec<NetId>,
    /// Primary outputs.
    pub outputs: Vec<NetId>,
    /// D-flip-flops on the sequential boundary (registers); they are not
    /// part of the combinational graph but count for area/power.
    pub dffs: u32,
    /// Human-readable name (test messages, Verilog headers).
    pub name: String,
}

impl Netlist {
    /// An empty named netlist.
    pub fn new(name: &str) -> Self {
        Netlist { name: name.to_string(), ..Default::default() }
    }

    fn push(&mut self, kind: GateKind, ins: [NetId; 3]) -> NetId {
        for &i in &ins {
            debug_assert!(i == NONE || (i as usize) < self.gates.len(),
                          "forward reference in netlist");
        }
        self.gates.push(Gate { kind, ins });
        (self.gates.len() - 1) as NetId
    }

    /// Declare a primary input; returns its net.
    pub fn input(&mut self) -> NetId {
        let id = self.push(GateKind::Input, [NONE; 3]);
        self.inputs.push(id);
        id
    }

    /// Tied-low constant net.
    pub fn const0(&mut self) -> NetId {
        self.push(GateKind::Const0, [NONE; 3])
    }

    /// Tied-high constant net.
    pub fn const1(&mut self) -> NetId {
        self.push(GateKind::Const1, [NONE; 3])
    }

    /// Inverter gate.
    pub fn inv(&mut self, a: NetId) -> NetId {
        self.push(GateKind::Inv, [a, NONE, NONE])
    }

    /// 2-input AND gate.
    pub fn and2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::And2, [a, b, NONE])
    }

    /// 2-input OR gate.
    pub fn or2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Or2, [a, b, NONE])
    }

    /// 2-input NAND gate.
    pub fn nand2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Nand2, [a, b, NONE])
    }

    /// 2-input NOR gate.
    pub fn nor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Nor2, [a, b, NONE])
    }

    /// 2-input XOR gate.
    pub fn xor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Xor2, [a, b, NONE])
    }

    /// 2-input XNOR gate.
    pub fn xnor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Xnor2, [a, b, NONE])
    }

    /// Majority-of-three as a single complex gate (CMOS mirror-adder
    /// carry stage — the optimization the proposed exact cells use).
    pub fn maj3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        self.push(GateKind::Maj3, [a, b, c])
    }

    /// 3-input XOR as two cascaded XOR2 (sum stage of a full adder).
    pub fn xor3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        let t = self.xor2(a, b);
        self.xor2(t, c)
    }

    /// Textbook full adder from discrete gates: returns (carry, sum).
    pub fn full_adder(&mut self, a: NetId, b: NetId, c: NetId) -> (NetId, NetId) {
        let s = self.xor3(a, b, c);
        let t1 = self.and2(a, b);
        let t2 = self.and2(a, c);
        let t3 = self.and2(b, c);
        let t4 = self.or2(t1, t2);
        let carry = self.or2(t4, t3);
        (carry, s)
    }

    /// Mirror full adder: XOR sum path + single MAJ3 complex-gate carry.
    pub fn mirror_adder(&mut self, a: NetId, b: NetId, c: NetId) -> (NetId, NetId) {
        let s = self.xor3(a, b, c);
        let carry = self.maj3(a, b, c);
        (carry, s)
    }

    /// Half adder: returns (carry, sum).
    pub fn half_adder(&mut self, a: NetId, b: NetId) -> (NetId, NetId) {
        (self.and2(a, b), self.xor2(a, b))
    }

    /// Append a net to the primary outputs.
    pub fn mark_output(&mut self, n: NetId) {
        self.outputs.push(n);
    }

    /// Register `count` D-flip-flops on the sequential boundary.
    pub fn add_dffs(&mut self, count: u32) {
        self.dffs += count;
    }

    /// Logic-gate count (inputs and constants excluded).
    pub fn gate_count(&self) -> usize {
        self.gates.iter()
            .filter(|g| !matches!(g.kind,
                GateKind::Input | GateKind::Const0 | GateKind::Const1))
            .count()
    }

    // -- evaluation ---------------------------------------------------

    /// Evaluate one input vector into `values` (one entry per gate, in
    /// gate order) without collecting outputs — the core shared by
    /// [`Self::eval_into`] and the activity [`Stepper`].
    pub fn eval_values(&self, inputs: &[u8], values: &mut Vec<u8>) {
        assert_eq!(inputs.len(), self.inputs.len(), "{}", self.name);
        values.clear();
        values.reserve(self.gates.len());
        let mut in_iter = 0usize;
        for g in &self.gates {
            let v = match g.kind {
                GateKind::Input => {
                    let v = inputs[in_iter];
                    in_iter += 1;
                    v
                }
                GateKind::Const0 => 0,
                GateKind::Const1 => 1,
                _ => {
                    let a = values[g.ins[0] as usize];
                    let b = if g.ins[1] == NONE { 0 } else { values[g.ins[1] as usize] };
                    let c = if g.ins[2] == NONE { 0 } else { values[g.ins[2] as usize] };
                    match g.kind {
                        GateKind::Inv => a ^ 1,
                        GateKind::And2 => a & b,
                        GateKind::Or2 => a | b,
                        GateKind::Nand2 => (a & b) ^ 1,
                        GateKind::Nor2 => (a | b) ^ 1,
                        GateKind::Xor2 => a ^ b,
                        GateKind::Xnor2 => a ^ b ^ 1,
                        GateKind::Maj3 => (a & b) | (a & c) | (b & c),
                        _ => unreachable!(),
                    }
                }
            };
            values.push(v);
        }
    }

    /// Evaluate on one input vector; `values` is scratch storage reused
    /// across calls (resized as needed). Returns output bits.
    pub fn eval_into(&self, inputs: &[u8], values: &mut Vec<u8>) -> Vec<u8> {
        self.eval_values(inputs, values);
        self.outputs.iter().map(|&o| values[o as usize]).collect()
    }

    /// 64-lane bit-parallel evaluation: every input (and every resulting
    /// gate value) is a `u64` mask carrying one boolean per lane, and
    /// one pass evaluates 64 independent input vectors at once — all
    /// primitives are bitwise, so lanes cannot interact. This is the
    /// workhorse behind the [`crate::energy::EnergyLut`] build (millions
    /// of frames per design point); lane `l` of every value equals the
    /// scalar [`Self::eval_values`] result on lane `l`'s inputs (tested).
    pub fn eval_values64(&self, inputs: &[u64], values: &mut Vec<u64>) {
        assert_eq!(inputs.len(), self.inputs.len(), "{}", self.name);
        values.clear();
        values.reserve(self.gates.len());
        let mut in_iter = 0usize;
        for g in &self.gates {
            let v = match g.kind {
                GateKind::Input => {
                    let v = inputs[in_iter];
                    in_iter += 1;
                    v
                }
                GateKind::Const0 => 0,
                GateKind::Const1 => u64::MAX,
                _ => {
                    let a = values[g.ins[0] as usize];
                    let b = if g.ins[1] == NONE { 0 } else { values[g.ins[1] as usize] };
                    let c = if g.ins[2] == NONE { 0 } else { values[g.ins[2] as usize] };
                    match g.kind {
                        GateKind::Inv => !a,
                        GateKind::And2 => a & b,
                        GateKind::Or2 => a | b,
                        GateKind::Nand2 => !(a & b),
                        GateKind::Nor2 => !(a | b),
                        GateKind::Xor2 => a ^ b,
                        GateKind::Xnor2 => !(a ^ b),
                        GateKind::Maj3 => (a & b) | (a & c) | (b & c),
                        _ => unreachable!(),
                    }
                }
            };
            values.push(v);
        }
    }

    /// Switched energy between two gate-value frames (as produced by
    /// [`Self::eval_values`]): the calibrated per-gate energy of every
    /// toggled net plus the register clocking term (half the DFFs toggle
    /// per cycle — the same convention [`Self::power_uw`] uses).
    /// Returns `(energy in fJ, toggled nets)`.
    pub fn frame_energy(&self, prev: &[u8], cur: &[u8]) -> (f64, u64) {
        debug_assert_eq!(prev.len(), self.gates.len());
        debug_assert_eq!(cur.len(), self.gates.len());
        let lib = tech::LIB;
        let mut energy_fj = 0f64;
        let mut toggles = 0u64;
        for (i, g) in self.gates.iter().enumerate() {
            if cur[i] != prev[i] {
                toggles += 1;
                energy_fj += lib.energy_fj(g.kind);
            }
        }
        energy_fj += self.dffs as f64 * lib.dff_energy_fj * 0.5;
        (energy_fj, toggles)
    }

    /// Start an incremental activity replay over this netlist.
    pub fn stepper(&self) -> Stepper<'_> {
        Stepper { nl: self, prev: Vec::new(), cur: Vec::new() }
    }

    /// Evaluate on one input vector with fresh scratch (convenience
    /// wrapper over [`Self::eval_into`]).
    pub fn eval(&self, inputs: &[u8]) -> Vec<u8> {
        self.eval_into(inputs, &mut Vec::new())
    }

    // -- metrics ------------------------------------------------------

    /// Cell area in µm² (gates + DFFs, calibrated library).
    pub fn area(&self) -> f64 {
        let lib = tech::LIB;
        self.gates.iter().map(|g| lib.area(g.kind)).sum::<f64>()
            + self.dffs as f64 * lib.dff_area
    }

    /// Static timing: critical combinational path in ps.
    pub fn critical_path_ps(&self) -> f64 {
        let lib = tech::LIB;
        let mut arr = vec![0f64; self.gates.len()];
        let mut worst = 0f64;
        for (i, g) in self.gates.iter().enumerate() {
            let mut t = 0f64;
            for &inp in &g.ins {
                if inp != NONE {
                    t = t.max(arr[inp as usize]);
                }
            }
            arr[i] = t + lib.delay_ps(g.kind);
            if arr[i] > worst {
                worst = arr[i];
            }
        }
        worst + lib.dff_cq_ps
    }

    /// Simulate `vectors` consecutive input vectors and return
    /// (dynamic+leakage power in µW, total toggles). A thin aggregation
    /// over the per-step [`Stepper`] replay.
    ///
    /// `period_ns` is the clock period (paper Table IV runs at 250 MHz).
    pub fn power_uw(&self, vectors: &[Vec<u8>], period_ns: f64) -> (f64, u64) {
        let lib = tech::LIB;
        let mut st = self.stepper();
        let mut energy_fj = 0f64;
        let mut toggles = 0u64;
        for v in vectors {
            let (e, t) = st.step(v);
            energy_fj += e;
            toggles += t;
        }
        let cycles = (vectors.len().max(2) - 1) as f64;
        let leak_uw = self.gates.iter().map(|g| lib.leak_nw(g.kind)).sum::<f64>()
            / 1000.0
            + self.dffs as f64 * lib.dff_leak_nw / 1000.0;
        // 1 fJ per 1 ns == 1e-15 J / 1e-9 s == 1e-6 W == 1 µW
        let dyn_uw = energy_fj / (cycles * period_ns);
        (dyn_uw + leak_uw, toggles)
    }
}

/// Incremental activity replay over one [`Netlist`]: feed input vectors
/// one at a time, get back the switched energy of each step.
///
/// The first step only establishes the activity baseline (it returns
/// zero energy, exactly like the first vector of [`Netlist::power_uw`]);
/// every later step returns the calibrated switched energy of the
/// transition from the previous frame (gate toggles + register
/// clocking). [`Stepper::snapshot`] / [`Stepper::restore`] save and
/// re-establish a baseline in O(gates), which is what lets
/// [`crate::energy`] tabulate millions of transitions *from the same
/// quiescent frame* without re-evaluating it each time.
pub struct Stepper<'a> {
    nl: &'a Netlist,
    /// Gate values of the current baseline frame (empty before the
    /// first step).
    prev: Vec<u8>,
    cur: Vec<u8>,
}

impl Stepper<'_> {
    /// Evaluate `inputs` and return `(switched energy fJ, toggled nets)`
    /// relative to the previous frame; the evaluated frame becomes the
    /// new baseline. The first step returns `(0.0, 0)`.
    pub fn step(&mut self, inputs: &[u8]) -> (f64, u64) {
        self.nl.eval_values(inputs, &mut self.cur);
        if self.prev.is_empty() {
            std::mem::swap(&mut self.prev, &mut self.cur);
            return (0.0, 0);
        }
        let (energy_fj, toggles) = self.nl.frame_energy(&self.prev, &self.cur);
        std::mem::swap(&mut self.prev, &mut self.cur);
        (energy_fj, toggles)
    }

    /// Opaque snapshot of the current baseline frame's gate values.
    pub fn snapshot(&self) -> Vec<u8> {
        self.prev.clone()
    }

    /// Re-establish a previously snapshotted baseline (O(gates) copy,
    /// no energy accounted).
    pub fn restore(&mut self, snap: &[u8]) {
        self.prev.clear();
        self.prev.extend_from_slice(snap);
    }

    /// Output bits of the current baseline frame (empty before the
    /// first step).
    pub fn outputs(&self) -> Vec<u8> {
        if self.prev.is_empty() {
            return Vec::new();
        }
        self.nl.outputs.iter().map(|&o| self.prev[o as usize]).collect()
    }
}

/// Deterministic xorshift vector generator for activity simulation.
pub fn random_vectors(n_inputs: usize, count: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut v = Vec::with_capacity(n_inputs);
        for _ in 0..n_inputs {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            v.push((s & 1) as u8);
        }
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_adder_truth_table() {
        let mut nl = Netlist::new("fa");
        let a = nl.input();
        let b = nl.input();
        let c = nl.input();
        let (carry, sum) = nl.full_adder(a, b, c);
        nl.mark_output(carry);
        nl.mark_output(sum);
        for v in 0..8u8 {
            let bits = [(v >> 2) & 1, (v >> 1) & 1, v & 1];
            let out = nl.eval(&bits);
            let want = bits[0] + bits[1] + bits[2];
            assert_eq!(out[0] * 2 + out[1], want);
        }
    }

    #[test]
    fn mirror_adder_equals_full_adder() {
        let mut nl = Netlist::new("ma");
        let a = nl.input();
        let b = nl.input();
        let c = nl.input();
        let (carry, sum) = nl.mirror_adder(a, b, c);
        nl.mark_output(carry);
        nl.mark_output(sum);
        for v in 0..8u8 {
            let bits = [(v >> 2) & 1, (v >> 1) & 1, v & 1];
            let out = nl.eval(&bits);
            assert_eq!(out[0] * 2 + out[1], bits.iter().sum::<u8>());
        }
    }

    #[test]
    fn mirror_adder_is_smaller_and_faster() {
        let mut fa = Netlist::new("fa");
        let i: Vec<_> = (0..3).map(|_| fa.input()).collect();
        let (c, s) = fa.full_adder(i[0], i[1], i[2]);
        fa.mark_output(c);
        fa.mark_output(s);
        let mut ma = Netlist::new("ma");
        let i: Vec<_> = (0..3).map(|_| ma.input()).collect();
        let (c, s) = ma.mirror_adder(i[0], i[1], i[2]);
        ma.mark_output(c);
        ma.mark_output(s);
        assert!(ma.area() < fa.area());
        assert!(ma.critical_path_ps() <= fa.critical_path_ps());
    }

    #[test]
    fn power_positive_and_deterministic() {
        let mut nl = Netlist::new("x");
        let a = nl.input();
        let b = nl.input();
        let (c, s) = nl.half_adder(a, b);
        nl.mark_output(c);
        nl.mark_output(s);
        let vecs = random_vectors(2, 200, 7);
        let (p1, t1) = nl.power_uw(&vecs, 4.0);
        let (p2, t2) = nl.power_uw(&vecs, 4.0);
        assert!(p1 > 0.0);
        assert_eq!(t1, t2);
        assert!((p1 - p2).abs() < 1e-12);
    }

    #[test]
    fn random_vectors_deterministic() {
        assert_eq!(random_vectors(8, 10, 1), random_vectors(8, 10, 1));
        assert_ne!(random_vectors(8, 10, 1), random_vectors(8, 10, 2));
    }

    #[test]
    fn bit_parallel_eval_matches_scalar_lanes() {
        // one 64-lane evaluation == 64 scalar evaluations, every gate
        let mut nl = Netlist::new("lanes");
        let a = nl.input();
        let b = nl.input();
        let c = nl.input();
        let (fc, fs) = nl.full_adder(a, b, c);
        let m = nl.maj3(a, b, fs);
        let x = nl.xnor2(fc, m);
        let i = nl.inv(x);
        let nr = nl.nor2(i, fs);
        nl.mark_output(nr);
        // lane l gets inputs (l&1, (l>>1)&1, (l>>2)&1), repeating
        let lane_inputs = [0xAAAA_AAAA_AAAA_AAAAu64,
                           0xCCCC_CCCC_CCCC_CCCC,
                           0xF0F0_F0F0_F0F0_F0F0];
        let mut v64 = Vec::new();
        nl.eval_values64(&lane_inputs, &mut v64);
        let mut v8 = Vec::new();
        for l in 0..64u64 {
            let inp = [(l & 1) as u8, ((l >> 1) & 1) as u8,
                       ((l >> 2) & 1) as u8];
            nl.eval_values(&inp, &mut v8);
            for (g, &w) in v64.iter().enumerate() {
                assert_eq!(((w >> l) & 1) as u8, v8[g], "gate {g} lane {l}");
            }
        }
    }

    #[test]
    fn stepper_aggregates_to_power_uw() {
        // power_uw is defined as an aggregation over the stepper; the
        // per-step energies must reproduce its dynamic-energy total and
        // toggle count exactly
        let mut nl = Netlist::new("agg");
        let a = nl.input();
        let b = nl.input();
        let (c, s) = nl.full_adder(a, b, a);
        nl.mark_output(c);
        nl.mark_output(s);
        nl.add_dffs(3);
        let vecs = random_vectors(2, 150, 11);
        let mut st = nl.stepper();
        let mut energy = 0.0;
        let mut toggles = 0u64;
        for v in &vecs {
            let (e, t) = st.step(v);
            energy += e;
            toggles += t;
        }
        let (p, t) = nl.power_uw(&vecs, 4.0);
        assert_eq!(toggles, t);
        let lib = tech::LIB;
        let leak = nl.gates.iter().map(|g| lib.leak_nw(g.kind)).sum::<f64>()
            / 1000.0 + nl.dffs as f64 * lib.dff_leak_nw / 1000.0;
        let dyn_uw = energy / ((vecs.len() - 1) as f64 * 4.0);
        assert!((p - (dyn_uw + leak)).abs() < 1e-12, "{p} vs {}", dyn_uw + leak);
    }

    #[test]
    fn stepper_first_step_is_free_and_restore_rebaselines() {
        let mut nl = Netlist::new("rz");
        let a = nl.input();
        let b = nl.input();
        let (c, s) = nl.half_adder(a, b);
        nl.mark_output(c);
        nl.mark_output(s);
        let mut st = nl.stepper();
        assert_eq!(st.step(&[0, 0]), (0.0, 0), "baseline step is free");
        let quiet = st.snapshot();
        let (e1, t1) = st.step(&[1, 1]);
        assert!(e1 > 0.0 && t1 > 0);
        assert_eq!(st.outputs(), vec![1, 0]);
        // restoring the quiescent baseline makes the same transition
        // cost the same energy again (the EnergyLut build pattern)
        st.restore(&quiet);
        let (e2, t2) = st.step(&[1, 1]);
        assert_eq!((e1, t1), (e2, t2));
        // without the restore, 1,1 -> 1,1 switches nothing
        let (e3, t3) = st.step(&[1, 1]);
        assert_eq!((e3, t3), (0.0, 0));
    }
}
