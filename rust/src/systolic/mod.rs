//! Cycle-accurate output-stationary systolic array (paper Fig. 1).
//!
//! Operands enter skewed: row `i` of A is injected into the array's west
//! edge delayed by `i` cycles, column `j` of B into the north edge delayed
//! by `j`; every PE multiplies the operands registered at its inputs and
//! folds the product into its local carry-save accumulator. For a square
//! `size x size` GEMM with K = size the full result is available after
//! `3*size - 2` cycles (the latency formula of \[11\], verified in tests).

use crate::energy::Replayer;
use crate::pe::word::{Pe, PeConfig};
use crate::pe::Design;
use crate::tech::PERIOD_NS_250MHZ;

/// Execution statistics for one GEMM (or one tile stream).
#[derive(Clone, Copy, Debug, Default)]
pub struct SaStats {
    /// Compute cycles (skew fill + K stream) across all tiles.
    pub cycles: u64,
    /// Drain cycles (result readout, pipelined column-wise).
    pub drain_cycles: u64,
    /// Total MAC operations executed by PEs.
    pub macs: u64,
    /// Total accumulator-bit toggles (activity proxy for energy).
    pub toggles: u64,
    /// Number of (rows x cols) output tiles processed.
    pub tiles: u64,
    /// Modeled data-dependent energy of the metered MACs, femtojoules
    /// (the canonical per-MAC model of [`crate::energy`]; 0.0 when
    /// unmetered).
    pub energy_fj: f64,
    /// MAC operations covered by an energy meter (`== macs` when the
    /// request was fully metered; 0 when the backend has no meter).
    pub metered_macs: u64,
}

impl SaStats {
    /// Compute + drain cycles.
    pub fn total_cycles(&self) -> u64 {
        self.cycles + self.drain_cycles
    }

    /// Accumulate another stats block into this one (per-field sums).
    pub fn merge(&mut self, other: &SaStats) {
        self.cycles += other.cycles;
        self.drain_cycles += other.drain_cycles;
        self.macs += other.macs;
        self.toggles += other.toggles;
        self.tiles += other.tiles;
        self.energy_fj += other.energy_fj;
        self.metered_macs += other.metered_macs;
    }

    /// Metered energy in microjoules.
    pub fn energy_uj(&self) -> f64 {
        self.energy_fj * 1e-9
    }

    /// Mean modeled power (µW) at the paper's 250 MHz clock: metered
    /// energy over the simulated cycle count when available (systolic
    /// backend), else over MAC-serialized single-PE time (one MAC per
    /// cycle — the software engines have no cycle notion).
    pub fn avg_power_uw(&self) -> f64 {
        let cycles = if self.total_cycles() > 0 {
            self.total_cycles()
        } else {
            self.macs
        };
        if cycles == 0 {
            return 0.0;
        }
        // 1 fJ per 1 ns == 1 µW
        self.energy_fj / (cycles as f64 * PERIOD_NS_250MHZ)
    }
}

/// An `rows x cols` output-stationary systolic array of word-level PEs.
pub struct Systolic {
    /// Design point of every PE in the array.
    pub cfg: PeConfig,
    /// Array height (output rows per tile).
    pub rows: usize,
    /// Array width (output columns per tile).
    pub cols: usize,
    pes: Vec<Pe>,
    // operand registers between PEs (index [i][j])
    a_reg: Vec<Option<u64>>,
    b_reg: Vec<Option<u64>>,
    /// Optional gate-level energy meter (see [`Self::enable_meter`]).
    meter: Option<Replayer>,
}

impl Systolic {
    /// A fresh `rows x cols` array of PEs configured by `cfg`.
    pub fn new(cfg: PeConfig, rows: usize, cols: usize) -> Self {
        Systolic {
            cfg,
            rows,
            cols,
            pes: vec![Pe::new(cfg); rows * cols],
            a_reg: vec![None; rows * cols],
            b_reg: vec![None; rows * cols],
            meter: None,
        }
    }

    /// Square `size x size` array (the paper's geometry).
    pub fn square(cfg: PeConfig, size: usize) -> Self {
        Self::new(cfg, size, size)
    }

    /// Enable the gate-level activity meter: every MAC replays the PE's
    /// grid netlist (the canonical frame of [`crate::energy`]) and its
    /// switched energy lands in [`SaStats::energy_fj`]. This is the
    /// ground-truth cross-check for the table-driven meters — direct
    /// netlist evaluation at real request activity — and it works for
    /// any buildable design point (no table-size limit). It adds
    /// roughly an order of magnitude on top of the already
    /// cycle-accurate simulation, which is why it is opt-in: the
    /// coordinator's systolic workers opt in, the fuzz suites do not.
    pub fn enable_meter(&mut self) {
        self.meter = Some(Replayer::new(&Design::from_pe_config(&self.cfg)));
    }

    fn clear(&mut self) {
        for pe in &mut self.pes {
            pe.reset();
        }
        self.a_reg.fill(None);
        self.b_reg.fill(None);
    }

    /// Stream one (rows x cols) output tile: `a_panel` is rows x kk
    /// (row-major), `b_panel` kk x cols. Returns resolved outputs
    /// (row-major rows x cols) and per-tile stats. Cycle-accurate:
    /// simulates the skewed wavefront register by register.
    pub fn run_tile(&mut self, a_panel: &[i64], b_panel: &[i64], kk: usize)
                    -> (Vec<i64>, SaStats) {
        assert_eq!(a_panel.len(), self.rows * kk);
        assert_eq!(b_panel.len(), kk * self.cols);
        self.clear();
        let total_cycles = (self.rows - 1) + (self.cols - 1) + kk;
        let mut stats = SaStats { tiles: 1, ..Default::default() };
        let toggles0: u64 = self.pes.iter().map(|p| p.toggles).sum();
        let macs0: u64 = self.pes.iter().map(|p| p.macs).sum();

        for cycle in 0..total_cycles {
            // shift operand registers east/south (reverse order so a value
            // moves one hop per cycle)
            for i in 0..self.rows {
                for j in (1..self.cols).rev() {
                    self.a_reg[i * self.cols + j] =
                        self.a_reg[i * self.cols + j - 1];
                }
                // west edge injection for row i: element t = cycle - i
                self.a_reg[i * self.cols] = cycle.checked_sub(i)
                    .filter(|&t| t < kk)
                    .map(|t| self.cfg.encode(a_panel[i * kk + t]));
            }
            for j in 0..self.cols {
                for i in (1..self.rows).rev() {
                    self.b_reg[i * self.cols + j] =
                        self.b_reg[(i - 1) * self.cols + j];
                }
                self.b_reg[j] = cycle.checked_sub(j)
                    .filter(|&t| t < kk)
                    .map(|t| self.cfg.encode(b_panel[t * self.cols + j]));
            }
            // MAC wherever both operands are present
            for i in 0..self.rows {
                for j in 0..self.cols {
                    if let (Some(a), Some(b)) = (self.a_reg[i * self.cols + j],
                                                 self.b_reg[i * self.cols + j]) {
                        if self.meter.is_some() {
                            // charge the canonical frame's gate energy
                            // against the PE's pre-MAC rails
                            let (ps, pk) = {
                                let pe = &self.pes[i * self.cols + j];
                                (pe.s, pe.k)
                            };
                            let m = self.meter.as_mut().unwrap();
                            stats.energy_fj += m.mac_fj(a, b, ps, pk);
                            stats.metered_macs += 1;
                        }
                        self.pes[i * self.cols + j].mac(a, b);
                    }
                }
            }
        }

        let out: Vec<i64> = self.pes.iter().map(|p| p.resolve()).collect();
        stats.cycles = total_cycles as u64;
        // drain: one column per cycle through the merge adders
        stats.drain_cycles = self.cols as u64;
        stats.macs = self.pes.iter().map(|p| p.macs).sum::<u64>() - macs0;
        stats.toggles = self.pes.iter().map(|p| p.toggles).sum::<u64>() - toggles0;
        (out, stats)
    }

    /// Arbitrary GEMM `C = A(m x kk) @ B(kk x nn)`, tiled over the array.
    /// Ragged edges are handled by zero-padding the panels (the padded
    /// MACs multiply by zero through the same hardware path).
    pub fn gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize,
                nn: usize) -> (Vec<i64>, SaStats) {
        assert_eq!(a.len(), m * kk);
        assert_eq!(b.len(), kk * nn);
        let mut out = vec![0i64; m * nn];
        let mut stats = SaStats::default();
        let mut a_panel = vec![0i64; self.rows * kk];
        let mut b_panel = vec![0i64; kk * self.cols];
        let mut ti = 0;
        while ti < m {
            let th = (m - ti).min(self.rows);
            a_panel.fill(0);
            for i in 0..th {
                a_panel[i * kk..i * kk + kk]
                    .copy_from_slice(&a[(ti + i) * kk..(ti + i) * kk + kk]);
            }
            let mut tj = 0;
            while tj < nn {
                let tw = (nn - tj).min(self.cols);
                b_panel.fill(0);
                for t in 0..kk {
                    for j in 0..tw {
                        b_panel[t * self.cols + j] = b[t * nn + tj + j];
                    }
                }
                let (tile, ts) = self.run_tile(&a_panel, &b_panel, kk);
                stats.merge(&ts);
                for i in 0..th {
                    for j in 0..tw {
                        out[(ti + i) * nn + tj + j] = tile[i * self.cols + j];
                    }
                }
                tj += tw;
            }
            ti += th;
        }
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::word::matmul;
    use crate::Family;

    fn cfg(k: u32) -> PeConfig {
        PeConfig::new(8, true, Family::Proposed, k)
    }

    fn ints(seed: u64, len: usize) -> Vec<i64> {
        let mut s = seed | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as i64 & 255) - 128
            })
            .collect()
    }

    #[test]
    fn latency_formula_3n_minus_2() {
        // paper §II: N x N matmul on an N x N array takes 3N-2 cycles
        for size in [3usize, 4, 8, 16] {
            let mut sa = Systolic::square(cfg(0), size);
            let a = ints(1, size * size);
            let b = ints(2, size * size);
            let (_, st) = sa.run_tile(&a, &b, size);
            assert_eq!(st.cycles, (3 * size - 2) as u64, "size={size}");
        }
    }

    #[test]
    fn exact_square_matches_integer_matmul() {
        let size = 8;
        let mut sa = Systolic::square(cfg(0), size);
        let a = ints(3, size * size);
        let b = ints(4, size * size);
        let (y, _) = sa.run_tile(&a, &b, size);
        for i in 0..size {
            for j in 0..size {
                let want: i64 = (0..size).map(|t| a[i * size + t] * b[t * size + j]).sum();
                assert_eq!(y[i * size + j], want, "({i},{j})");
            }
        }
    }

    #[test]
    fn tiled_gemm_matches_word_matmul_all_families() {
        // SA result must equal the functional word-level matmul for every
        // family and k (the array adds scheduling, not arithmetic)
        let (m, kk, nn) = (13usize, 9usize, 11usize);
        let a = ints(5, m * kk);
        let b = ints(6, kk * nn);
        for family in Family::ALL {
            for k in [0u32, 3, 7] {
                let c = PeConfig::new(8, true, family, k);
                let mut sa = Systolic::new(c, 4, 5);
                let (y, st) = sa.gemm(&a, &b, m, kk, nn);
                let want = matmul(&c, &a, &b, m, kk, nn);
                assert_eq!(y, want, "{family:?} k={k}");
                assert!(st.tiles >= 9); // ceil(13/4)*ceil(11/5) = 4*3
            }
        }
    }

    #[test]
    fn gemm_independent_of_array_shape() {
        let (m, kk, nn) = (16usize, 8usize, 16usize);
        let a = ints(7, m * kk);
        let b = ints(8, kk * nn);
        let c = cfg(5);
        let (y1, _) = Systolic::new(c, 8, 8).gemm(&a, &b, m, kk, nn);
        let (y2, _) = Systolic::new(c, 3, 5).gemm(&a, &b, m, kk, nn);
        let (y3, _) = Systolic::new(c, 16, 2).gemm(&a, &b, m, kk, nn);
        assert_eq!(y1, y2);
        assert_eq!(y1, y3);
    }

    #[test]
    fn stats_accumulate() {
        let mut sa = Systolic::square(cfg(0), 4);
        let a = ints(9, 8 * 4);
        let b = ints(10, 4 * 8);
        let (_, st) = sa.gemm(&a, &b, 8, 4, 8);
        assert_eq!(st.tiles, 4);
        assert_eq!(st.macs, 4 * 16 * 4); // tiles * PEs * K
        assert!(st.toggles > 0);
    }

    #[test]
    fn meter_charges_every_mac_without_changing_bits() {
        let (m, kk, nn) = (6usize, 7usize, 5usize);
        let a = ints(11, m * kk);
        let b = ints(12, kk * nn);
        let c = cfg(3);
        let (want, st0) = Systolic::new(c, 4, 4).gemm(&a, &b, m, kk, nn);
        assert_eq!(st0.energy_fj, 0.0, "unmetered array charges nothing");
        assert_eq!(st0.metered_macs, 0);
        let mut sa = Systolic::new(c, 4, 4);
        sa.enable_meter();
        let (got, st) = sa.gemm(&a, &b, m, kk, nn);
        assert_eq!(got, want, "metering must not change bits");
        assert_eq!(st.metered_macs, st.macs, "full coverage");
        assert!(st.energy_fj > 0.0);
        assert!(st.energy_uj() > 0.0 && st.avg_power_uw() > 0.0);
    }

    #[test]
    fn zero_matrix_zero_toggles_on_sum_rail() {
        let mut sa = Systolic::square(cfg(0), 4);
        let a = vec![0i64; 16];
        let b = vec![0i64; 16];
        let (y, _) = sa.run_tile(&a, &b, 4);
        assert!(y.iter().all(|&v| v == 0));
    }
}
