//! Cache-blocked GEMM driver — the shared throughput layer.
//!
//! Every software backend (`word`, `lut`, and through them the
//! coordinator's worker devices) routes its matrix products through
//! [`BlockedGemm`]: a classic MC×KC×NC packed-panel driver in the BLIS
//! mold, specialized to the PE's fused-MAC semantics. Three microkernels
//! cover the design space:
//!
//! * **exact** (`k == 0`): the carry-save state is unobservable, so the
//!   kernel is plain wrapping-i64 MACs on decoded operands (same bits as
//!   the word model's exact fast path, tested there);
//! * **lut** (`k > 0`, LUT-compilable point): two table reads + two adds
//!   per MAC against the process-shared [`ProductLut`] tables — 64
//!   accumulator/automaton chains in flight on wide sweeps, 8
//!   otherwise;
//! * **word** (`k > 0`, non-compilable point): the bit-plane walk — the
//!   64-lane transposed kernel ([`lanes`]) on wide blocks (metered or
//!   not), the scalar [`mac_step_planned`] 4-chain kernel on narrow
//!   (< 32-column) fallbacks.
//!
//! ## Why blocking helps, and why it cannot change the bits
//!
//! The driver encodes A once per call (natural row stride), copy-packs
//! each NC×KC transposed panel of B into contiguous scratch (L1/L2
//! resident at the default sizes), and walks a multi-chain register
//! microkernel over MC×NC output blocks: 8 output columns (LUT) or a
//! 64-wide lane group (word) advance together, which turns the
//! serially-dependent per-element automaton/carry-save chain into many
//! independent dependency chains the CPU can overlap. That is where the
//! speedup over the naive one-chain-at-a-time loop comes from (see
//! `benches/hotpath.rs`, `blocked_vs_naive`).
//!
//! Blocking parameters default to [`BlockSizes::default`]; long-lived
//! serving processes pin a measured choice instead — either an explicit
//! [`set_block_override`] (the CLI `--block-sizes MCxKCxNC`) or the
//! [`autotune_blocks`] startup sweep. Both are process-wide and
//! perf-only: block sizes can never change the bits.
//!
//! Bit-identity is structural: tiling and packing only *reorder
//! independent output elements*. Each output element `C[i][j]` still
//! folds its operand pairs `t = 0, 1, …, K-1` into its own accumulator
//! in exactly the order the word model uses — the per-element carry-save
//! (or automaton) state is carried across KC panels, never reset or
//! split. The K loop is therefore never reassociated, and
//! `blocked == naive == word` for every design point (fuzzed over ragged
//! shapes in `tests/prop_equiv.rs`).
//!
//! Packing scratch lives inside the [`BlockedGemm`] value and is reused
//! across calls, so a long-lived engine (one per coordinator worker, or
//! the thread-local one behind [`matmul`]) performs no per-request
//! packing allocation.
//!
//! ## Energy metering
//!
//! An engine can carry an [`EnergyLut`] meter ([`BlockedGemm::set_meter`]):
//! each kernel then charges every MAC its canonical data-dependent energy
//! with one extra table read — the LUT kernel indexes with the automaton
//! state it already chases, the scalar word kernel recovers the state
//! from its live rails, the 64-lane word kernel chases one automaton
//! state per lane next to the compute planes and charges whole lane
//! frames per step (`EnergyLut::mac_fj_lanes` — the fused metering
//! path, so attaching a meter no longer drops the hot path to the
//! scalar walk), the exact kernel uses the stateless `k = 0` row. The
//! accumulated femtojoules drain through [`BlockedGemm::take_energy_fj`].
//! Metering only *reads* operands and states the kernels already hold —
//! it cannot reorder a MAC chain, so metered results are bit-identical
//! to unmetered ones (asserted in this module's tests and fuzzed with
//! metering enabled in `tests/energy_model.rs`).
//!
//! ```
//! use axsys::gemm::{BlockSizes, BlockedGemm};
//! use axsys::pe::word::{matmul as word_matmul, PeConfig};
//! use axsys::Family;
//!
//! let cfg = PeConfig::new(8, true, Family::Proposed, 4);
//! let a: Vec<i64> = (0..7 * 9).map(|i| (i % 19) - 9).collect();
//! let b: Vec<i64> = (0..9 * 5).map(|i| (i % 23) - 11).collect();
//! // deliberately ragged block sizes: raggedness cannot change the bits
//! let mut eng = BlockedGemm::new(BlockSizes { mc: 2, kc: 3, nc: 2 });
//! let blocked = eng.matmul(&cfg, &a, &b, 7, 9, 5);
//! assert_eq!(blocked, word_matmul(&cfg, &a, &b, 7, 9, 5));
//! ```

use std::cell::RefCell;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::energy::EnergyLut;
use crate::pe::lut::{self, ProductLut};
use crate::pe::word::{mac_step_planned, MacPlan, PeConfig};

pub mod lanes;

use lanes::{lane_get, pack_b_lanes, LanePlan, LANES};

/// Cache-blocking parameters of the driver: C is computed in MC×NC
/// blocks, each fed by KC-deep packed operand panels.
///
/// At the defaults the packed B panel (NC×KC) is 32 KiB as u16
/// encodings — L1/L2-resident while the microkernel sweeps it (A
/// streams from a once-per-call encoded copy at its natural stride).
/// Any sizes ≥ 1 are legal (zeros are clamped); results are
/// bit-identical for every choice, only speed changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSizes {
    /// Output rows per block (packed-A panel height).
    pub mc: usize,
    /// Inner-dimension depth per packed panel.
    pub kc: usize,
    /// Output columns per block (packed-B panel height).
    pub nc: usize,
}

impl Default for BlockSizes {
    fn default() -> Self {
        BlockSizes { mc: 64, kc: 256, nc: 64 }
    }
}

impl BlockSizes {
    /// Parse a `MCxKCxNC` triple (the CLI `--block-sizes` syntax), e.g.
    /// `"64x256x64"`. Every component must be a positive integer.
    pub fn parse(s: &str) -> Option<BlockSizes> {
        let mut it = s.split('x');
        let mc = it.next()?.parse().ok()?;
        let kc = it.next()?.parse().ok()?;
        let nc = it.next()?.parse().ok()?;
        if it.next().is_some() || mc == 0 || kc == 0 || nc == 0 {
            return None;
        }
        Some(BlockSizes { mc, kc, nc })
    }
}

/// The process-wide pinned blocking (None until an override or autotune
/// pins one). Library constructors never pin implicitly — results are
/// bit-identical for every choice, so this is purely a perf knob and the
/// defaults stay deterministic for tests and one-shot callers.
static PINNED_BLOCKS: OnceLock<BlockSizes> = OnceLock::new();

/// Pin the process-wide blocking (the CLI `--block-sizes` override).
/// First pin wins — returns `false` if autotune or an earlier override
/// already pinned a value (which then stays in force).
pub fn set_block_override(bs: BlockSizes) -> bool {
    PINNED_BLOCKS.set(bs).is_ok()
}

/// The blocking new engines should use: the pinned value if an override
/// or [`autotune_blocks`] ran, the [`BlockSizes::default`] otherwise.
pub fn effective_blocks() -> BlockSizes {
    PINNED_BLOCKS.get().copied().unwrap_or_default()
}

/// Run a short startup sweep over a candidate MC/KC/NC grid on the LUT
/// serving kernel and pin the fastest triple process-wide (once — later
/// calls return the pinned value immediately). ~tens of ms; the CLI
/// entry points call this at startup unless `--block-sizes` pinned an
/// explicit choice. Bit-identity is unconditional on block sizes, so
/// the sweep only ever changes speed.
pub fn autotune_blocks() -> BlockSizes {
    *PINNED_BLOCKS.get_or_init(|| {
        let cfg = PeConfig::new(8, true, crate::Family::Proposed, 4);
        let s = 96usize;
        let a = crate::bench::xorshift_ints(11, s * s);
        let b = crate::bench::xorshift_ints(12, s * s);
        let mut best = (f64::INFINITY, BlockSizes::default());
        for mc in [32, 64, 128] {
            for kc in [128, 256] {
                for nc in [32, 64, 128] {
                    let bs = BlockSizes { mc, kc, nc };
                    let mut eng = BlockedGemm::single_threaded(bs);
                    // warm the scratch, then best-of-2
                    eng.matmul(&cfg, &a, &b, s, s, s);
                    let mut dt = f64::INFINITY;
                    for _ in 0..2 {
                        let t0 = Instant::now();
                        std::hint::black_box(
                            eng.matmul(&cfg, &a, &b, s, s, s));
                        dt = dt.min(t0.elapsed().as_secs_f64());
                    }
                    if dt < best.0 {
                        best = (dt, bs);
                    }
                }
            }
        }
        best.1
    })
}

/// Reusable packing + per-block state buffers (grow-only, never freed
/// between calls — the "no per-request allocation" contract).
#[derive(Default)]
struct Scratch {
    /// Packed A panel, u16 operand encodings (lut kernel).
    a16: Vec<u16>,
    /// Packed transposed B panel, u16 encodings (lut kernel).
    b16: Vec<u16>,
    /// Packed A panel, u64 encodings (word kernel).
    a64: Vec<u64>,
    /// Packed transposed B panel, u64 encodings (word kernel).
    b64: Vec<u64>,
    /// Packed A panel, decoded i64 operands (exact kernel).
    ai: Vec<i64>,
    /// Packed transposed B panel, decoded i64 operands (exact kernel).
    bi: Vec<i64>,
    /// Per-element accumulators of the current block (exact + lut).
    acc: Vec<i64>,
    /// Per-element automaton states of the current block (lut).
    st: Vec<u16>,
    /// Per-element sum rail of the current block (word).
    s_rail: Vec<u64>,
    /// Per-element carry rail of the current block (word).
    k_rail: Vec<u64>,
    /// Packed B bit-planes of the current panel (lane word kernel).
    bpl: Vec<u64>,
    /// Per-lane-group sum planes of the current block (lane word kernel).
    spl: Vec<u64>,
    /// Per-lane-group carry planes of the current block (lane word kernel).
    kpl: Vec<u64>,
    /// Per-(group, t, lane) B encodings of the current panel (metered
    /// lane word kernel: the meter gathers them lane-major, the planes
    /// in `bpl` are bit-major).
    ben: Vec<u16>,
    /// Per-(row, group, lane) automaton states of the current block
    /// (metered lane word kernel).
    lst: Vec<u16>,
}

/// Dimensions of one (block, panel) microkernel invocation. The A
/// operand is encoded once per call as full rows (stride `a_stride`
/// = kk); `a_base` points at the current block's `(icb, pcb)` corner.
/// The B panel is copy-packed per block (`nw` rows of `kw`).
struct BlockShape {
    mh: usize,
    nw: usize,
    kw: usize,
    a_stride: usize,
    a_base: usize,
}

/// Problem operands shared across the block loops.
struct Operands<'a> {
    a: &'a [i64],
    b: &'a [i64],
    kk: usize,
    nn: usize,
}

/// Resolved per-call engine (carries everything the kernels need).
enum Eng<'a> {
    /// `k == 0`: wrapping integer MACs on decoded operands.
    Exact(PeConfig),
    /// `k > 0`, LUT-compilable: product table + window automaton.
    Lut(&'a ProductLut),
    /// `k > 0`, word fallback: bit-plane walk per MAC.
    Word(MacPlan),
}

/// The shared cache-blocked GEMM driver. Owns its packing scratch, so
/// keep one per worker/thread and reuse it across calls.
pub struct BlockedGemm {
    /// Blocking parameters (change freely between calls; the scratch
    /// resizes lazily).
    pub blocks: BlockSizes,
    /// Whether large problems may fan out across scoped threads.
    parallel: bool,
    /// Whether the word path may use the 64-lane bit-plane kernel
    /// ([`lanes`]) on wide-enough blocks — metered or not — and the
    /// LUT path its 64-chain sweep (default on).
    lanes: bool,
    scratch: Scratch,
    /// Optional per-MAC energy meter (see module docs, §Energy metering).
    meter: Option<Arc<EnergyLut>>,
    /// Metered femtojoules accumulated since the last
    /// [`Self::take_energy_fj`].
    energy_fj: f64,
}

impl Default for BlockedGemm {
    fn default() -> Self {
        Self::new(BlockSizes::default())
    }
}

impl BlockedGemm {
    /// A driver with the given blocking parameters and empty scratch.
    /// Large problems are split across threads; callers that already
    /// run inside a worker pool should use [`Self::single_threaded`].
    pub fn new(blocks: BlockSizes) -> Self {
        BlockedGemm { blocks, parallel: true, lanes: true,
                      scratch: Scratch::default(), meter: None,
                      energy_fj: 0.0 }
    }

    /// A driver that never spawns threads: every call runs sequentially
    /// on the caller's thread with the engine's own reusable scratch
    /// (zero per-call allocation beyond the output). This is what each
    /// coordinator worker owns — stacked coalesced GEMMs can be large,
    /// and nested fan-out from an already-parallel pool would
    /// oversubscribe the host.
    pub fn single_threaded(blocks: BlockSizes) -> Self {
        BlockedGemm { blocks, parallel: false, lanes: true,
                      scratch: Scratch::default(), meter: None,
                      energy_fj: 0.0 }
    }

    /// Enable/disable the 64-lane kernels (default on): the word
    /// engine's bit-plane lane kernel and the LUT engine's 64-chain
    /// sweep. The lane and scalar kernels are bit-identical (metered
    /// or not) — this exists for A/B benchmarking (`bench-report`
    /// reports the speedups) and for the differential fuzz that proves
    /// the identity.
    pub fn set_lane_kernel(&mut self, on: bool) {
        self.lanes = on;
    }

    /// Install (or clear) the per-MAC energy meter. The table must match
    /// the design point of subsequent calls — the coordinator workers
    /// swap the right table in per dispatch group; a mismatch is a
    /// caller bug (debug-asserted in the kernels' driver).
    pub fn set_meter(&mut self, meter: Option<Arc<EnergyLut>>) {
        self.meter = meter;
    }

    /// Drain the femtojoules metered since the last call (0.0 when no
    /// meter is installed).
    pub fn take_energy_fj(&mut self) -> f64 {
        std::mem::take(&mut self.energy_fj)
    }

    /// Blocked GEMM `C(m×nn) = A(m×kk) @ B(kk×nn)` for a design point,
    /// choosing the fastest bit-identical engine: the exact kernel at
    /// `k = 0`, the shared product-LUT tables when the point compiles
    /// (via [`lut::cached`]), the word kernel otherwise.
    pub fn matmul(&mut self, cfg: &PeConfig, a: &[i64], b: &[i64], m: usize,
                  kk: usize, nn: usize) -> Vec<i64> {
        if cfg.k > 0 {
            if let Some(l) = lut::cached(cfg) {
                return self.matmul_lut(&l, a, b, m, kk, nn);
            }
        }
        self.matmul_word(cfg, a, b, m, kk, nn)
    }

    /// Blocked GEMM on a pre-fetched product-LUT table (the coordinator
    /// workers memoize the `Arc` per request-`k` and call this directly,
    /// skipping the global cache lock). Falls through to the exact
    /// kernel when the table's design point is exact.
    pub fn matmul_lut(&mut self, lut: &ProductLut, a: &[i64], b: &[i64],
                      m: usize, kk: usize, nn: usize) -> Vec<i64> {
        let eng = if lut.cfg.k == 0 {
            Eng::Exact(lut.cfg)
        } else {
            Eng::Lut(lut)
        };
        self.run(&eng, a, b, m, kk, nn)
    }

    /// Blocked GEMM that never consults the LUT cache: exact kernel at
    /// `k = 0`, bit-plane word kernel otherwise. The blocked equivalent
    /// of [`crate::pe::word::matmul`], bit-identical to it.
    pub fn matmul_word(&mut self, cfg: &PeConfig, a: &[i64], b: &[i64],
                       m: usize, kk: usize, nn: usize) -> Vec<i64> {
        let eng = if cfg.k == 0 {
            Eng::Exact(*cfg)
        } else {
            Eng::Word(MacPlan::new(cfg))
        };
        self.run(&eng, a, b, m, kk, nn)
    }

    fn run(&mut self, eng: &Eng, a: &[i64], b: &[i64], m: usize, kk: usize,
           nn: usize) -> Vec<i64> {
        assert_eq!(a.len(), m * kk, "A shape");
        assert_eq!(b.len(), kk * nn, "B shape");
        let mut out = vec![0i64; m * nn];
        if m == 0 || nn == 0 {
            return out;
        }
        let op = Operands { a, b, kk, nn };
        // clone the Arc so the meter borrow is independent of `self`
        // (the scratch and the energy accumulator are borrowed mutably
        // below)
        let meter_arc = self.meter.clone();
        let meter = meter_arc.as_deref();
        if let Some(el) = meter {
            let cfg = match eng {
                Eng::Exact(c) => *c,
                Eng::Lut(l) => l.cfg,
                Eng::Word(p) => p.cfg,
            };
            debug_assert!(el.cfg.n == cfg.n && el.cfg.k == cfg.k
                          && el.cfg.signed == cfg.signed
                          && el.cfg.family == cfg.family,
                          "energy meter / engine design-point mismatch");
        }
        // parallelize across output-row chunks for large problems, same
        // policy as the naive engines — unless this engine was built
        // with `single_threaded` (coordinator workers: their pool is
        // the parallelism, and the sequential path is the zero-alloc one)
        let work = m * nn * kk;
        let threads = std::thread::available_parallelism()
            .map(|p| p.get()).unwrap_or(1).min(8);
        if self.parallel && work >= 1 << 18 && threads > 1 && m >= 2 * threads {
            let bs = self.blocks;
            let lanes = self.lanes;
            let chunk = m.div_ceil(threads);
            // per-chunk energies summed in chunk order afterwards, so the
            // metered total is deterministic for a given thread split
            let mut chunk_fj = vec![0f64; m.div_ceil(chunk)];
            std::thread::scope(|scope| {
                for ((ci, rows), fj) in out.chunks_mut(chunk * nn).enumerate()
                    .zip(chunk_fj.iter_mut())
                {
                    let op = &op;
                    scope.spawn(move || {
                        let mut local = Scratch::default();
                        *fj = drive_rows(eng, &bs, &mut local, op, meter,
                                         lanes, ci * chunk, rows);
                    });
                }
            });
            self.energy_fj += chunk_fj.into_iter().sum::<f64>();
        } else {
            self.energy_fj += drive_rows(eng, &self.blocks, &mut self.scratch,
                                         &op, meter, self.lanes, 0, &mut out);
        }
        out
    }
}

/// Compute output rows `i0 .. i0 + out_rows.len()/nn` of C into
/// `out_rows` with the full MC×KC×NC block structure. Per-element state
/// (accumulator + automaton state, or the two carry-save rails) is
/// carried across KC panels in increasing-`t` order, which is what keeps
/// every output element's MAC chain identical to the unblocked walk.
/// Returns the femtojoules metered over these rows (0.0 unmetered).
fn drive_rows(eng: &Eng, bs: &BlockSizes, sc: &mut Scratch, op: &Operands,
              meter: Option<&EnergyLut>, lanes: bool, i0: usize,
              out_rows: &mut [i64]) -> f64 {
    // The 64-lane transposed kernel covers the word path on wide-enough
    // outputs, metered or not: the meter chases one automaton state per
    // lane next to the compute planes (`EnergyLut::mac_fj_lanes`), so
    // it no longer needs the scalar rails. Narrow outputs under-fill
    // the lane groups, so they keep the scalar 4-chain kernel — the
    // scalar walk is solely the < LANE_MIN_COLS fallback. The choice is
    // fixed per call — block state layouts never mix.
    if let Eng::Word(plan) = eng {
        if lanes && op.nn >= LANE_MIN_COLS {
            return drive_rows_word_lanes(plan, bs, sc, op, meter, i0,
                                         out_rows);
        }
    }
    let nn = op.nn;
    let kk = op.kk;
    let h = out_rows.len() / nn;
    let mc = bs.mc.max(1);
    let kc = bs.kc.max(1);
    let nc = bs.nc.max(1);
    // A is encoded ONCE for the whole call (rows i0..i0+h, natural kk
    // stride) — blocks then slice into it, so no element is re-encoded
    // per column stripe. B panels are copy-packed per block below.
    match eng {
        Eng::Exact(cfg) => {
            sc.ai.resize(h * kk, 0);
            for i in 0..h {
                let src = &op.a[(i0 + i) * kk..(i0 + i + 1) * kk];
                let dst = &mut sc.ai[i * kk..(i + 1) * kk];
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = cfg.decode_operand(v as u64);
                }
            }
            sc.bi.resize(nc * kc, 0);
            sc.acc.resize(mc * nc, 0);
        }
        Eng::Lut(l) => {
            sc.a16.resize(h * kk, 0);
            for i in 0..h {
                let src = &op.a[(i0 + i) * kk..(i0 + i + 1) * kk];
                let dst = &mut sc.a16[i * kk..(i + 1) * kk];
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = l.cfg.encode(v) as u16;
                }
            }
            sc.b16.resize(nc * kc, 0);
            sc.acc.resize(mc * nc, 0);
            sc.st.resize(mc * nc, 0);
        }
        Eng::Word(plan) => {
            sc.a64.resize(h * kk, 0);
            for i in 0..h {
                let src = &op.a[(i0 + i) * kk..(i0 + i + 1) * kk];
                let dst = &mut sc.a64[i * kk..(i + 1) * kk];
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = plan.cfg.encode(v);
                }
            }
            sc.s_rail.resize(mc * nc, 0);
            sc.k_rail.resize(mc * nc, 0);
            sc.b64.resize(nc * kc, 0);
        }
    }
    let mut energy_fj = 0f64;
    let mut icb = 0;
    while icb < h {
        let mh = (h - icb).min(mc);
        let mut jcb = 0;
        while jcb < nn {
            let nw = (nn - jcb).min(nc);
            match eng {
                Eng::Exact(_) => sc.acc[..mh * nw].fill(0),
                Eng::Lut(_) => {
                    sc.acc[..mh * nw].fill(0);
                    sc.st[..mh * nw].fill(0);
                }
                Eng::Word(_) => {
                    sc.s_rail[..mh * nw].fill(0);
                    sc.k_rail[..mh * nw].fill(0);
                }
            }
            // KC panels in increasing t order: the per-element state
            // survives from one panel to the next
            let mut pcb = 0;
            while pcb < kk {
                let kw = (kk - pcb).min(kc);
                let sh = BlockShape { mh, nw, kw, a_stride: kk,
                                      a_base: icb * kk + pcb };
                let bt = (pcb, jcb);
                energy_fj += match eng {
                    Eng::Exact(cfg) => {
                        pack_b_exact(cfg, sc, op, bt, &sh);
                        kernel_exact(&sh, &sc.ai, &sc.bi, &mut sc.acc, meter)
                    }
                    Eng::Lut(l) => {
                        pack_b_enc16(&l.cfg, sc, op, bt, &sh);
                        kernel_lut(l, &sh, &sc.a16, &sc.b16, &mut sc.acc,
                                   &mut sc.st, meter, lanes)
                    }
                    Eng::Word(plan) => {
                        pack_b_enc64(&plan.cfg, sc, op, bt, &sh);
                        kernel_word(plan, &sh, &sc.a64, &sc.b64,
                                    &mut sc.s_rail, &mut sc.k_rail, meter)
                    }
                };
                pcb += kw;
            }
            // resolve + write back the finished block
            for i in 0..mh {
                let dst = &mut out_rows[(icb + i) * nn + jcb
                                        ..(icb + i) * nn + jcb + nw];
                match eng {
                    Eng::Exact(cfg) => {
                        for (j, o) in dst.iter_mut().enumerate() {
                            *o = cfg.decode(sc.acc[i * nw + j] as u64);
                        }
                    }
                    Eng::Lut(l) => {
                        for (j, o) in dst.iter_mut().enumerate() {
                            *o = l.cfg.decode(sc.acc[i * nw + j] as u64);
                        }
                    }
                    Eng::Word(plan) => {
                        for (j, o) in dst.iter_mut().enumerate() {
                            *o = plan.resolve(sc.s_rail[i * nw + j],
                                              sc.k_rail[i * nw + j]);
                        }
                    }
                }
            }
            jcb += nw;
        }
        icb += mh;
    }
    energy_fj
}

/// Minimum output width before the 64-lane word kernel pays for itself:
/// below this the lane groups are mostly padding lanes and the scalar
/// 4-chain kernel is cheaper. Any value is bit-safe — this is a pure
/// perf threshold.
const LANE_MIN_COLS: usize = 32;

/// The word-engine block driver on the 64-lane transposed kernel
/// ([`lanes::LanePlan::mac64`]): same MC×KC×NC block walk and the same
/// per-element KC-panel state carrying as [`drive_rows`], but the block
/// state lives as bit-planes per 64-output-column lane group instead of
/// scalar rails. Returns the femtojoules metered over these rows (0.0
/// unmetered).
///
/// Metering is fused into the lane loop: one `u16` automaton state per
/// (block row, lane) is reset with the block and chased across KC
/// panels exactly like the plane state, and each `(group, t)` frame
/// charges all live lanes with one state-major table gather
/// ([`EnergyLut::mac_fj_lanes`]) *before* its `mac64` step — the same
/// pre-step convention as the scalar meter. Padding lanes of a short
/// group are never charged. The meter only reads the lane-major B
/// encodings stashed at pack time (`Scratch::ben`) and its own state
/// row — the compute planes are untouched, so metering cannot change
/// the bits; the metered total equals the scalar meter's to summation
/// order (every per-MAC table read is identical).
///
/// Bit-identity: a lane is one output column; its plane bits walk the
/// exact `mac_step_planned` chain (pinned per-lane in `lanes::tests`),
/// and the block/panel order here never reassociates any chain — it is
/// the same schedule as the scalar driver.
fn drive_rows_word_lanes(plan: &MacPlan, bs: &BlockSizes, sc: &mut Scratch,
                         op: &Operands, meter: Option<&EnergyLut>, i0: usize,
                         out_rows: &mut [i64]) -> f64 {
    let lp = LanePlan::new(&plan.cfg);
    let w = lp.width();
    let nb = lp.b_planes();
    let nn = op.nn;
    let kk = op.kk;
    let h = out_rows.len() / nn;
    let mc = bs.mc.max(1);
    let kc = bs.kc.max(1);
    let nc = bs.nc.max(1);
    // A encoded once per call, exactly like the scalar word arm
    sc.a64.resize(h * kk, 0);
    for i in 0..h {
        let src = &op.a[(i0 + i) * kk..(i0 + i + 1) * kk];
        let dst = &mut sc.a64[i * kk..(i + 1) * kk];
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = plan.cfg.encode(v);
        }
    }
    let groups_max = nc.div_ceil(LANES);
    sc.spl.resize(mc * groups_max * w, 0);
    sc.kpl.resize(mc * groups_max * w, 0);
    sc.bpl.resize(groups_max * kc * nb, 0);
    if meter.is_some() {
        sc.ben.resize(groups_max * kc * LANES, 0);
        sc.lst.resize(mc * groups_max * LANES, 0);
    }
    let mut energy_fj = 0f64;
    let mut benc = [0u64; LANES];
    let mut icb = 0;
    while icb < h {
        let mh = (h - icb).min(mc);
        let mut jcb = 0;
        while jcb < nn {
            let nw = (nn - jcb).min(nc);
            let groups = nw.div_ceil(LANES);
            sc.spl[..mh * groups * w].fill(0);
            sc.kpl[..mh * groups * w].fill(0);
            if meter.is_some() {
                // per-lane automaton states reset with the block, like
                // the plane state (and the scalar rails)
                sc.lst[..mh * groups * LANES].fill(0);
            }
            // KC panels in increasing t order: plane state survives from
            // one panel to the next, same contract as the scalar driver
            let mut pcb = 0;
            while pcb < kk {
                let kw = (kk - pcb).min(kc);
                // pack this panel of B into bit-planes per (group, t):
                // bit l of plane j = bit j of encode(B[t][jcb + g*64 + l])
                for g in 0..groups {
                    let l0 = jcb + g * LANES;
                    let gl = (nw - g * LANES).min(LANES);
                    for t in 0..kw {
                        let src = &op.b[(pcb + t) * nn + l0..][..gl];
                        for (e, &v) in benc[..gl].iter_mut().zip(src) {
                            *e = plan.cfg.encode(v);
                        }
                        pack_b_lanes(nb, &benc[..gl],
                                     &mut sc.bpl[(g * kc + t) * nb..][..nb]);
                        if meter.is_some() {
                            // lane-major copy for the meter's gathers
                            let dst = &mut sc.ben[(g * kc + t) * LANES..][..gl];
                            for (d, &e) in dst.iter_mut().zip(&benc[..gl]) {
                                *d = e as u16;
                            }
                        }
                    }
                }
                for i in 0..mh {
                    let arow = &sc.a64[(icb + i) * kk + pcb..][..kw];
                    for g in 0..groups {
                        let base = (i * groups + g) * w;
                        let (spl, kpl) = (&mut sc.spl[base..base + w],
                                          &mut sc.kpl[base..base + w]);
                        if let Some(el) = meter {
                            // fused metering: charge the frame's live
                            // lanes at their pre-step states, then step
                            let gl = (nw - g * LANES).min(LANES);
                            let lb = (i * groups + g) * LANES;
                            let lst = &mut sc.lst[lb..lb + gl];
                            for (t, &av) in arow.iter().enumerate() {
                                energy_fj += el.mac_fj_lanes(
                                    av, &sc.ben[(g * kc + t) * LANES..][..gl],
                                    lst);
                                lp.mac64(av,
                                         &sc.bpl[(g * kc + t) * nb..][..nb],
                                         spl, kpl);
                            }
                        } else {
                            for (t, &av) in arow.iter().enumerate() {
                                lp.mac64(av,
                                         &sc.bpl[(g * kc + t) * nb..][..nb],
                                         spl, kpl);
                            }
                        }
                    }
                }
                pcb += kw;
            }
            // resolve + write back: gather each lane's rails out of the
            // planes and drain through the same exact merge adder
            for i in 0..mh {
                let dst = &mut out_rows[(icb + i) * nn + jcb
                                        ..(icb + i) * nn + jcb + nw];
                for (j, o) in dst.iter_mut().enumerate() {
                    let g = j / LANES;
                    let l = j % LANES;
                    let base = (i * groups + g) * w;
                    *o = plan.resolve(lane_get(&sc.spl[base..base + w], l),
                                      lane_get(&sc.kpl[base..base + w], l));
                }
            }
            jcb += nw;
        }
        icb += mh;
    }
    energy_fj
}

/// Copy-pack the B(pc0.., col0..) panel transposed as decoded i64
/// operands (nw×kw, unit-stride inner dimension).
fn pack_b_exact(cfg: &PeConfig, sc: &mut Scratch, op: &Operands,
                bt: (usize, usize), sh: &BlockShape) {
    let (bpc, col0) = bt;
    for t in 0..sh.kw {
        let src = &op.b[(bpc + t) * op.nn + col0..][..sh.nw];
        for (j, &v) in src.iter().enumerate() {
            sc.bi[j * sh.kw + t] = cfg.decode_operand(v as u64);
        }
    }
}

/// u16-encoding flavor of [`pack_b_exact`] (lut kernel).
fn pack_b_enc16(cfg: &PeConfig, sc: &mut Scratch, op: &Operands,
                bt: (usize, usize), sh: &BlockShape) {
    let (bpc, col0) = bt;
    for t in 0..sh.kw {
        let src = &op.b[(bpc + t) * op.nn + col0..][..sh.nw];
        for (j, &v) in src.iter().enumerate() {
            sc.b16[j * sh.kw + t] = cfg.encode(v) as u16;
        }
    }
}

/// u64-encoding flavor of [`pack_b_exact`] (word kernel).
fn pack_b_enc64(cfg: &PeConfig, sc: &mut Scratch, op: &Operands,
                bt: (usize, usize), sh: &BlockShape) {
    let (bpc, col0) = bt;
    for t in 0..sh.kw {
        let src = &op.b[(bpc + t) * op.nn + col0..][..sh.nw];
        for (j, &v) in src.iter().enumerate() {
            sc.b64[j * sh.kw + t] = cfg.encode(v);
        }
    }
}

/// Exact microkernel: 4 output columns per sweep, wrapping i64 MACs.
/// With a meter, each MAC adds its stateless (`k = 0`) table energy;
/// the arithmetic is untouched. Returns metered fJ.
fn kernel_exact(sh: &BlockShape, ai: &[i64], bi: &[i64], acc: &mut [i64],
                elut: Option<&EnergyLut>) -> f64 {
    let (mh, nw, kw) = (sh.mh, sh.nw, sh.kw);
    let mut efj = 0f64;
    for i in 0..mh {
        let arow = &ai[sh.a_base + i * sh.a_stride..][..kw];
        let racc = &mut acc[i * nw..(i + 1) * nw];
        let mut j = 0;
        while j + 4 <= nw {
            let b0 = &bi[j * kw..(j + 1) * kw];
            let b1 = &bi[(j + 1) * kw..(j + 2) * kw];
            let b2 = &bi[(j + 2) * kw..(j + 3) * kw];
            let b3 = &bi[(j + 3) * kw..(j + 4) * kw];
            let (mut c0, mut c1, mut c2, mut c3) =
                (racc[j], racc[j + 1], racc[j + 2], racc[j + 3]);
            for t in 0..kw {
                let av = arow[t];
                c0 = c0.wrapping_add(av.wrapping_mul(b0[t]));
                c1 = c1.wrapping_add(av.wrapping_mul(b1[t]));
                c2 = c2.wrapping_add(av.wrapping_mul(b2[t]));
                c3 = c3.wrapping_add(av.wrapping_mul(b3[t]));
                if let Some(el) = elut {
                    efj += el.mac_fj(0, av as u64, b0[t] as u64)
                        + el.mac_fj(0, av as u64, b1[t] as u64)
                        + el.mac_fj(0, av as u64, b2[t] as u64)
                        + el.mac_fj(0, av as u64, b3[t] as u64);
                }
            }
            racc[j] = c0;
            racc[j + 1] = c1;
            racc[j + 2] = c2;
            racc[j + 3] = c3;
            j += 4;
        }
        while j < nw {
            let bj = &bi[j * kw..(j + 1) * kw];
            let mut c = racc[j];
            for t in 0..kw {
                c = c.wrapping_add(arow[t].wrapping_mul(bj[t]));
                if let Some(el) = elut {
                    efj += el.mac_fj(0, arow[t] as u64, bj[t] as u64);
                }
            }
            racc[j] = c;
            j += 1;
        }
    }
    efj
}

/// How many (accumulator, automaton-state) chains the LUT microkernel
/// keeps in flight per sweep. Two table reads + two adds per MAC leave
/// the CPU starved for independent work at 4 chains; 8 fills the
/// load/ALU ports without spilling the chain registers.
const LUT_CHAINS: usize = 8;

/// Chains per sweep of the LUT microkernel's lane variant: 64
/// independent chains — the word engine's lane width — batched through
/// the state-major product/energy/transition tables, so the memory
/// system sees 64 concurrent read streams per step instead of 8. The
/// chain state spills to L1 (1.5 KiB per sweep), which table-read
/// latency hides; narrow remainders fall back to the 8-chain sweep.
const LUT_LANES: usize = 64;

/// Mask extracting the next-state index out of a packed
/// [`ProductLut::trans_entry`] (`err i16 << 16 | state u16`). The width
/// is load-bearing: a state index wider than 16 bits would be silently
/// truncated here, so [`kernel_lut`] asserts every compiled table fits
/// (the builder already refuses to emit one that does not — this pins
/// the two layers to the same contract).
const STATE_MASK: usize = 0xFFFF;

/// Table-driven microkernel: [`LUT_LANES`] output columns advance
/// together on wide sweeps (when `lanes` is on), [`LUT_CHAINS`]
/// otherwise — many independent (accumulator, automaton-state) chains
/// in flight is the ILP the naive per-element loop cannot expose, and
/// the 64-chain sweep additionally batches the state-major table reads
/// into 64 concurrent streams. Chain grouping cannot change the bits:
/// every chain is one output column walking its own full-`t` order.
/// With a meter, each MAC adds one energy-table read indexed by the
/// very automaton state the kernel chases anyway. Returns metered fJ.
#[allow(clippy::too_many_arguments)]
fn kernel_lut(lut: &ProductLut, sh: &BlockShape, a16: &[u16], b16: &[u16],
              acc: &mut [i64], st: &mut [u16], elut: Option<&EnergyLut>,
              lanes: bool) -> f64 {
    let (mh, nw, kw) = (sh.mh, sh.nw, sh.kw);
    let n = lut.cfg.n;
    let two_n = 2 * n as usize;
    let kb = lut.window_bits() as usize;
    let kmask = (1usize << kb) - 1;
    // state indices ride the low 16 bits of the packed transition entry;
    // a wider automaton would corrupt state silently below, so refuse it
    // loudly (the table builder bounds states to u16::MAX — this assert
    // ties the microkernel to that contract, incl. the widest n=8/k=8
    // point, see tests::widest_window_states_fit_the_packed_mask)
    assert!(lut.states() <= STATE_MASK + 1,
            "ProductLut has {} states; the packed-entry mask carries at \
             most {}", lut.states(), STATE_MASK + 1);
    debug_assert!(kb as u32 == lut.cfg.k || lut.cfg.k == 0,
                  "window width / design-point k mismatch");
    let mut efj = 0f64;
    for i in 0..mh {
        let arow = &a16[sh.a_base + i * sh.a_stride..][..kw];
        let racc = &mut acc[i * nw..(i + 1) * nw];
        let rst = &mut st[i * nw..(i + 1) * nw];
        let mut j = 0;
        while lanes && j + LUT_LANES <= nw {
            let b: [&[u16]; LUT_LANES] =
                core::array::from_fn(|u| &b16[(j + u) * kw..(j + u + 1) * kw]);
            let mut c: [i64; LUT_LANES] =
                core::array::from_fn(|u| racc[j + u]);
            let mut s: [usize; LUT_LANES] =
                core::array::from_fn(|u| rst[j + u] as usize);
            for t in 0..kw {
                let ai = arow[t] as usize;
                let ahi = ai << n;
                let alo = (ai & kmask) << kb;
                for u in 0..LUT_LANES {
                    let bi = b[u][t] as usize;
                    c[u] += lut.prod_entry(ahi | bi);
                    if let Some(el) = elut {
                        efj += el.entry((s[u] << two_n) | ahi | bi);
                    }
                    let e = lut.trans_entry(s[u], alo | (bi & kmask));
                    c[u] += (e >> 16) as i16 as i64;
                    s[u] = e as usize & STATE_MASK;
                }
            }
            for u in 0..LUT_LANES {
                racc[j + u] = c[u];
                rst[j + u] = s[u] as u16;
            }
            j += LUT_LANES;
        }
        while j + LUT_CHAINS <= nw {
            let b: [&[u16]; LUT_CHAINS] =
                core::array::from_fn(|u| &b16[(j + u) * kw..(j + u + 1) * kw]);
            let mut c: [i64; LUT_CHAINS] =
                core::array::from_fn(|u| racc[j + u]);
            let mut s: [usize; LUT_CHAINS] =
                core::array::from_fn(|u| rst[j + u] as usize);
            for t in 0..kw {
                let ai = arow[t] as usize;
                let ahi = ai << n;
                let alo = (ai & kmask) << kb;
                for u in 0..LUT_CHAINS {
                    let bi = b[u][t] as usize;
                    c[u] += lut.prod_entry(ahi | bi);
                    if let Some(el) = elut {
                        efj += el.entry((s[u] << two_n) | ahi | bi);
                    }
                    let e = lut.trans_entry(s[u], alo | (bi & kmask));
                    c[u] += (e >> 16) as i16 as i64;
                    s[u] = e as usize & STATE_MASK;
                }
            }
            for u in 0..LUT_CHAINS {
                racc[j + u] = c[u];
                rst[j + u] = s[u] as u16;
            }
            j += LUT_CHAINS;
        }
        while j < nw {
            let bj = &b16[j * kw..(j + 1) * kw];
            let mut c = racc[j];
            let mut s = rst[j] as usize;
            for t in 0..kw {
                let ai = arow[t] as usize;
                let bi = bj[t] as usize;
                c += lut.prod_entry((ai << n) | bi);
                if let Some(el) = elut {
                    efj += el.entry((s << two_n) | (ai << n) | bi);
                }
                let e = lut.trans_entry(s, ((ai & kmask) << kb) | (bi & kmask));
                c += (e >> 16) as i16 as i64;
                s = e as usize & STATE_MASK;
            }
            racc[j] = c;
            rst[j] = s as u16;
            j += 1;
        }
    }
    efj
}

/// Word microkernel: 4 carry-save (s, k) chains per sweep through
/// [`mac_step_planned`]. With a meter, each MAC's automaton state is
/// recovered from the live rails' low-`k` window before the step.
/// Returns metered fJ.
fn kernel_word(plan: &MacPlan, sh: &BlockShape, a64: &[u64], b64: &[u64],
               s_rail: &mut [u64], k_rail: &mut [u64],
               elut: Option<&EnergyLut>) -> f64 {
    let (mh, nw, kw) = (sh.mh, sh.nw, sh.kw);
    let mut efj = 0f64;
    for i in 0..mh {
        let arow = &a64[sh.a_base + i * sh.a_stride..][..kw];
        let rs = &mut s_rail[i * nw..(i + 1) * nw];
        let rk = &mut k_rail[i * nw..(i + 1) * nw];
        let mut j = 0;
        while j + 4 <= nw {
            let b0 = &b64[j * kw..(j + 1) * kw];
            let b1 = &b64[(j + 1) * kw..(j + 2) * kw];
            let b2 = &b64[(j + 2) * kw..(j + 3) * kw];
            let b3 = &b64[(j + 3) * kw..(j + 4) * kw];
            let (mut s0, mut s1, mut s2, mut s3) =
                (rs[j], rs[j + 1], rs[j + 2], rs[j + 3]);
            let (mut k0, mut k1, mut k2, mut k3) =
                (rk[j], rk[j + 1], rk[j + 2], rk[j + 3]);
            for t in 0..kw {
                let av = arow[t];
                if let Some(el) = elut {
                    efj += el.mac_fj(el.state_of_rails(s0, k0), av, b0[t])
                        + el.mac_fj(el.state_of_rails(s1, k1), av, b1[t])
                        + el.mac_fj(el.state_of_rails(s2, k2), av, b2[t])
                        + el.mac_fj(el.state_of_rails(s3, k3), av, b3[t]);
                }
                (s0, k0) = mac_step_planned(plan, av, b0[t], s0, k0);
                (s1, k1) = mac_step_planned(plan, av, b1[t], s1, k1);
                (s2, k2) = mac_step_planned(plan, av, b2[t], s2, k2);
                (s3, k3) = mac_step_planned(plan, av, b3[t], s3, k3);
            }
            rs[j] = s0;
            rs[j + 1] = s1;
            rs[j + 2] = s2;
            rs[j + 3] = s3;
            rk[j] = k0;
            rk[j + 1] = k1;
            rk[j + 2] = k2;
            rk[j + 3] = k3;
            j += 4;
        }
        while j < nw {
            let bj = &b64[j * kw..(j + 1) * kw];
            let (mut s, mut k) = (rs[j], rk[j]);
            for t in 0..kw {
                if let Some(el) = elut {
                    efj += el.mac_fj(el.state_of_rails(s, k), arow[t], bj[t]);
                }
                (s, k) = mac_step_planned(plan, arow[t], bj[t], s, k);
            }
            rs[j] = s;
            rk[j] = k;
            j += 1;
        }
    }
    efj
}

thread_local! {
    static ENGINE: RefCell<BlockedGemm> =
        RefCell::new(BlockedGemm::new(effective_blocks()));
}

/// Blocked GEMM through a thread-local [`BlockedGemm`] (default block
/// sizes, scratch reused per thread). The drop-in replacement for
/// [`crate::pe::word::matmul`] / [`crate::pe::lut::matmul`] on the hot
/// path — bit-identical to both.
pub fn matmul(cfg: &PeConfig, a: &[i64], b: &[i64], m: usize, kk: usize,
              nn: usize) -> Vec<i64> {
    ENGINE.with(|e| e.borrow_mut().matmul(cfg, a, b, m, kk, nn))
}

/// Word-only flavor of [`matmul`]: blocked driver, but never consults
/// the LUT cache (exact kernel at `k = 0`, bit-plane kernel otherwise).
/// Use when auditing the normative word semantics at blocked speed.
pub fn matmul_word(cfg: &PeConfig, a: &[i64], b: &[i64], m: usize, kk: usize,
                   nn: usize) -> Vec<i64> {
    ENGINE.with(|e| e.borrow_mut().matmul_word(cfg, a, b, m, kk, nn))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::xorshift_ints as ints;
    use crate::pe::word::matmul as word_matmul;
    use crate::Family;

    #[test]
    fn blocked_matches_word_all_families_and_ks() {
        let (m, kk, nn) = (11usize, 19usize, 13usize);
        let a = ints(1, m * kk);
        let b = ints(2, kk * nn);
        let mut eng = BlockedGemm::default();
        for family in Family::ALL {
            for signed in [true, false] {
                for k in [0u32, 2, 4, 7] {
                    let cfg = PeConfig::new(8, signed, family, k);
                    let want = word_matmul(&cfg, &a, &b, m, kk, nn);
                    assert_eq!(eng.matmul(&cfg, &a, &b, m, kk, nn), want,
                               "lut engine: {family:?} signed={signed} k={k}");
                    assert_eq!(eng.matmul_word(&cfg, &a, &b, m, kk, nn), want,
                               "word engine: {family:?} signed={signed} k={k}");
                }
            }
        }
    }

    #[test]
    fn ragged_block_sizes_do_not_change_bits() {
        // shapes never multiples of the block sizes, state carried
        // across many KC panels
        let (m, kk, nn) = (17usize, 29usize, 11usize);
        let a = ints(3, m * kk);
        let b = ints(4, kk * nn);
        let cfg = PeConfig::new(8, true, Family::Proposed, 5);
        let want = word_matmul(&cfg, &a, &b, m, kk, nn);
        for bs in [BlockSizes { mc: 1, kc: 1, nc: 1 },
                   BlockSizes { mc: 2, kc: 3, nc: 5 },
                   BlockSizes { mc: 5, kc: 7, nc: 3 },
                   BlockSizes { mc: 64, kc: 256, nc: 64 }] {
            let mut eng = BlockedGemm::new(bs);
            assert_eq!(eng.matmul(&cfg, &a, &b, m, kk, nn), want, "{bs:?}");
            assert_eq!(eng.matmul_word(&cfg, &a, &b, m, kk, nn), want,
                       "{bs:?} word");
        }
    }

    #[test]
    fn wide_operands_fall_back_to_the_word_kernel() {
        // n = 16 has no product table; matmul must route to the word
        // kernel and stay bit-identical
        let cfg = PeConfig::new(16, true, Family::Proposed, 3);
        let a = ints(5, 6 * 9);
        let b = ints(6, 9 * 4);
        let mut eng = BlockedGemm::default();
        assert_eq!(eng.matmul(&cfg, &a, &b, 6, 9, 4),
                   word_matmul(&cfg, &a, &b, 6, 9, 4));
    }

    #[test]
    fn scratch_reuse_across_heterogeneous_calls() {
        // one engine serving mixed shapes and design points (the
        // coordinator-worker pattern) must stay correct call after call
        let mut eng = BlockedGemm::default();
        for (i, &(m, kk, nn, k)) in [(8usize, 8usize, 8usize, 0u32),
                                     (3, 40, 2, 6), (13, 5, 17, 2),
                                     (1, 1, 1, 7), (8, 24, 8, 4)]
            .iter().enumerate() {
            let cfg = PeConfig::new(8, true, Family::Sips12, k);
            let a = ints(10 + i as u64, m * kk);
            let b = ints(20 + i as u64, kk * nn);
            assert_eq!(eng.matmul(&cfg, &a, &b, m, kk, nn),
                       word_matmul(&cfg, &a, &b, m, kk, nn),
                       "call {i}: ({m},{kk},{nn}) k={k}");
        }
    }

    #[test]
    fn parallel_row_split_is_bit_identical() {
        // large-problem path (threaded row chunks, per-thread scratch)
        let (m, kk, nn) = (64usize, 64usize, 64usize);
        let a = ints(7, m * kk);
        let b = ints(8, kk * nn);
        let cfg = PeConfig::new(8, true, Family::Proposed, 4);
        let mut eng = BlockedGemm::default();
        assert_eq!(eng.matmul(&cfg, &a, &b, m, kk, nn),
                   word_matmul(&cfg, &a, &b, m, kk, nn));
    }

    #[test]
    fn single_threaded_engine_matches_parallel() {
        // a problem big enough to trip the parallel engine's fan-out:
        // the sequential (coordinator-worker) engine must produce the
        // same bits without spawning
        let (m, kk, nn) = (64usize, 64usize, 64usize);
        let a = ints(13, m * kk);
        let b = ints(14, kk * nn);
        let cfg = PeConfig::new(8, true, Family::Proposed, 4);
        let mut par = BlockedGemm::default();
        let mut seq = BlockedGemm::single_threaded(BlockSizes::default());
        assert_eq!(seq.matmul(&cfg, &a, &b, m, kk, nn),
                   par.matmul(&cfg, &a, &b, m, kk, nn));
        assert_eq!(seq.matmul_word(&cfg, &a, &b, m, kk, nn),
                   par.matmul_word(&cfg, &a, &b, m, kk, nn));
    }

    #[test]
    fn thread_local_convenience_matches() {
        let cfg = PeConfig::new(8, true, Family::Nano6, 3);
        let a = ints(9, 10 * 7);
        let b = ints(10, 7 * 9);
        assert_eq!(matmul(&cfg, &a, &b, 10, 7, 9),
                   word_matmul(&cfg, &a, &b, 10, 7, 9));
    }

    #[test]
    fn metering_changes_no_bits_and_matches_chain_aggregation() {
        // the meter observes: metered results == unmetered results, and
        // the metered total equals the per-element chain aggregation
        // through the same table (tolerance: cross-element f64 order)
        let (m, kk, nn) = (6usize, 14usize, 5usize);
        let a = ints(21, m * kk);
        let b = ints(22, kk * nn);
        for k in [0u32, 3] {
            let cfg = PeConfig::new(8, true, Family::Proposed, k);
            let elut = crate::energy::cached(&cfg).expect("8-bit tabulates");
            let mut eng = BlockedGemm::default();
            let want = eng.matmul(&cfg, &a, &b, m, kk, nn);
            assert_eq!(eng.take_energy_fj(), 0.0, "unmetered engine");
            eng.set_meter(Some(elut.clone()));
            assert_eq!(eng.matmul(&cfg, &a, &b, m, kk, nn), want,
                       "metered lut engine changed bits (k={k})");
            let e_lut = eng.take_energy_fj();
            assert_eq!(eng.matmul_word(&cfg, &a, &b, m, kk, nn), want,
                       "metered word engine changed bits (k={k})");
            let e_word = eng.take_energy_fj();
            let mut want_fj = 0.0;
            for i in 0..m {
                for j in 0..nn {
                    let ops: Vec<(i64, i64)> = (0..kk)
                        .map(|t| (a[i * kk + t], b[t * nn + j])).collect();
                    want_fj += elut.chain_fj(&ops);
                }
            }
            assert!(want_fj > 0.0);
            for (label, e) in [("lut", e_lut), ("word", e_word)] {
                assert!((e - want_fj).abs() <= 1e-6 * want_fj,
                        "{label} k={k}: {e} vs {want_fj}");
            }
        }
    }

    #[test]
    fn lane_word_kernel_is_bit_identical_to_scalar() {
        // the 64-lane transposed kernel vs the scalar 4-chain kernel vs
        // the naive word walk, over ragged shapes that leave a partial
        // lane group, at 8- and 16-bit operand widths
        let (m, kk, nn) = (9usize, 21usize, 45usize);
        let a = ints(31, m * kk);
        let b = ints(32, kk * nn);
        let bs = BlockSizes { mc: 4, kc: 5, nc: 40 };
        for n in [8u32, 16] {
            for family in Family::ALL {
                let cfg = PeConfig::new(n, true, family, 3);
                let want = word_matmul(&cfg, &a, &b, m, kk, nn);
                let mut on = BlockedGemm::single_threaded(bs);
                let mut off = BlockedGemm::single_threaded(bs);
                off.set_lane_kernel(false);
                assert_eq!(on.matmul_word(&cfg, &a, &b, m, kk, nn), want,
                           "lanes on: n={n} {family:?}");
                assert_eq!(off.matmul_word(&cfg, &a, &b, m, kk, nn), want,
                           "lanes off: n={n} {family:?}");
            }
        }
    }

    #[test]
    fn metered_lane_kernels_match_the_scalar_meter() {
        // the fused metering path: with the lane kernels engaged (wide
        // outputs, ragged 70-column tail crossing lane groups and the
        // 8-chain remainder), a metered engine must produce the same
        // bits AND the same femtojoules (to summation-order rounding)
        // as the scalar metered walk — for both the word and the lut
        // engine, across KC panel boundaries that carry lane state
        let (m, kk, nn) = (7usize, 13usize, 70usize);
        let a = ints(41, m * kk);
        let b = ints(42, kk * nn);
        let bs = BlockSizes { mc: 4, kc: 5, nc: 70 };
        for family in [Family::Proposed, Family::Loa] {
            for k in [2u32, 3, 7] {
                let cfg = PeConfig::new(8, true, family, k);
                let Some(elut) = crate::energy::cached(&cfg) else {
                    continue;
                };
                let want = word_matmul(&cfg, &a, &b, m, kk, nn);
                let mut lane = BlockedGemm::single_threaded(bs);
                let mut scal = BlockedGemm::single_threaded(bs);
                scal.set_lane_kernel(false);
                lane.set_meter(Some(elut.clone()));
                scal.set_meter(Some(elut.clone()));
                for engine in ["word", "lut"] {
                    let (got_l, got_s) = if engine == "word" {
                        (lane.matmul_word(&cfg, &a, &b, m, kk, nn),
                         scal.matmul_word(&cfg, &a, &b, m, kk, nn))
                    } else {
                        (lane.matmul(&cfg, &a, &b, m, kk, nn),
                         scal.matmul(&cfg, &a, &b, m, kk, nn))
                    };
                    assert_eq!(got_l, want,
                               "{engine} lanes {family:?} k={k}");
                    assert_eq!(got_s, want,
                               "{engine} scalar {family:?} k={k}");
                    let (e_l, e_s) = (lane.take_energy_fj(),
                                      scal.take_energy_fj());
                    assert!(e_s > 0.0, "{engine} {family:?} k={k}");
                    assert!((e_l - e_s).abs() <= 1e-9 * e_s,
                            "{engine} {family:?} k={k}: lane {e_l} fJ \
                             vs scalar {e_s} fJ");
                }
            }
        }
    }

    #[test]
    fn metered_narrow_outputs_fall_back_to_the_scalar_walk() {
        // below LANE_MIN_COLS the metered word path keeps the scalar
        // 4-chain kernel — bits and a positive metered total either way
        let (m, kk, nn) = (4usize, 9usize, 12usize);
        let a = ints(43, m * kk);
        let b = ints(44, kk * nn);
        let cfg = PeConfig::new(8, true, Family::Proposed, 3);
        let elut = crate::energy::cached(&cfg).expect("8-bit tabulates");
        let want = word_matmul(&cfg, &a, &b, m, kk, nn);
        let mut eng = BlockedGemm::single_threaded(BlockSizes::default());
        eng.set_meter(Some(elut));
        assert_eq!(eng.matmul_word(&cfg, &a, &b, m, kk, nn), want);
        assert!(eng.take_energy_fj() > 0.0, "meter must still run");
    }

    #[test]
    fn lut_lane_sweep_is_bit_identical_to_the_chain_sweep() {
        // 64-chain vs 8-chain LUT sweeps over a width that exercises
        // the lane sweep, the chain sweep and the scalar remainder in
        // one block row (unmetered; the metered A/B lives in
        // metered_lane_kernels_match_the_scalar_meter)
        let (m, kk, nn) = (5usize, 23usize, 77usize);
        let a = ints(45, m * kk);
        let b = ints(46, kk * nn);
        let bs = BlockSizes { mc: 3, kc: 7, nc: 77 };
        for family in Family::ALL {
            let cfg = PeConfig::new(8, true, family, 4);
            let want = word_matmul(&cfg, &a, &b, m, kk, nn);
            let mut on = BlockedGemm::single_threaded(bs);
            let mut off = BlockedGemm::single_threaded(bs);
            off.set_lane_kernel(false);
            assert_eq!(on.matmul(&cfg, &a, &b, m, kk, nn), want,
                       "lanes on: {family:?}");
            assert_eq!(off.matmul(&cfg, &a, &b, m, kk, nn), want,
                       "lanes off: {family:?}");
        }
    }

    #[test]
    fn widest_window_states_fit_the_packed_mask() {
        // regression for the packed-entry state mask: at the widest
        // compilable window the automaton must still fit the 16-bit
        // state field the microkernel unpacks with STATE_MASK, and the
        // blocked LUT path must stay bit-identical to the word model
        let mut widest = None;
        for k in (1..=8u32).rev() {
            let cfg = PeConfig::new(8, true, Family::Proposed, k);
            if let Some(l) = lut::cached(&cfg) {
                widest = Some((cfg, l));
                break;
            }
        }
        let (cfg, l) = widest.expect("some 8-bit window compiles");
        assert!(l.states() <= STATE_MASK + 1,
                "{} states overflow the packed mask", l.states());
        let (m, kk, nn) = (6usize, 17usize, 11usize);
        let a = ints(51, m * kk);
        let b = ints(52, kk * nn);
        let mut eng = BlockedGemm::default();
        assert_eq!(eng.matmul_lut(&l, &a, &b, m, kk, nn),
                   word_matmul(&cfg, &a, &b, m, kk, nn),
                   "widest window k={}", cfg.k);
    }

    #[test]
    fn block_sizes_parse_cli_triples() {
        assert_eq!(BlockSizes::parse("64x256x64"),
                   Some(BlockSizes { mc: 64, kc: 256, nc: 64 }));
        assert_eq!(BlockSizes::parse("1x1x1"),
                   Some(BlockSizes { mc: 1, kc: 1, nc: 1 }));
        for bad in ["", "64", "64x256", "64x256x64x2", "0x1x1", "axbxc"] {
            assert_eq!(BlockSizes::parse(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn process_blocking_pins_once_and_stays() {
        // whoever pins first (this test's autotune or a concurrent
        // override) wins for the process; later pins must not repin.
        // Bit-identity across block sizes makes sharing the process-wide
        // pin with other tests safe.
        let first = autotune_blocks();
        assert!(first.mc >= 1 && first.kc >= 1 && first.nc >= 1);
        assert_eq!(effective_blocks(), first);
        assert_eq!(autotune_blocks(), first);
        assert!(!set_block_override(BlockSizes { mc: 1, kc: 1, nc: 1 }));
        assert_eq!(effective_blocks(), first);
    }
}
