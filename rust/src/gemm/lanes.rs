//! 64-lane transposed bit-plane word engine.
//!
//! The scalar word kernel ([`crate::pe::word::mac_step_planned`]) walks
//! one MAC chain at a time: every `u64` holds the W accumulator bits of a
//! *single* output element, and each of the N row updates costs ~15
//! full-width bitwise ops per element. This module transposes the layout
//! — the same trick `energy::EnergyLut::try_build` uses to tabulate
//! netlists 64 design inputs at a time: a `u64` *plane* holds bit `i` of
//! 64 **independent** MAC chains (bit `l` of plane `i` = bit `i` of lane
//! `l`'s accumulator). Every bitwise op in the row update then advances
//! all 64 lanes at once, and only the two value-preserving adds in the
//! scalar kernel (the Baugh-Wooley constant injection and the per-row
//! carry merge) need care: they become bit-serial ripple adders over the
//! W planes (`sum = x ^ y ^ c`, `carry = maj(x, y, c)`), exactly the
//! ripple form of the adds they replace.
//!
//! In the blocked GEMM driver a lane = one output column of the current
//! block, so the broadcast operand `a` is shared by all lanes (same A row
//! element) and only B differs per lane — B packs once per panel into N
//! bit-planes per inner-dimension step. Op count per MAC drops from
//! ~`15·N` per element to ~`(10·N² + 5·N·W) / 64` per element (~6× at
//! `n = 8, w = 24`), before counting the removed per-element loop
//! overhead.
//!
//! ## Why this cannot change the bits
//!
//! [`LanePlan::mac64`] computes, per lane, the *identical* boolean
//! function as `mac_step_planned`: the per-plane cell expressions are the
//! scalar per-bit expressions with each mask bit broadcast across lanes,
//! and the two ripple adders are bit-exact expansions of the two
//! `wrapping_add`s (carries out of plane `w-1` are dropped, matching the
//! scalar `& word_mask()`). `tests::lane_matches_planned_chains` pins
//! this per-lane over every family × signedness × k, and the blocked
//! driver's fuzz (`tests/prop_equiv.rs`) pins the full GEMM path.
//!
//! ## Fused energy metering
//!
//! Attaching a meter no longer drops this path back to the scalar walk.
//! The per-MAC energy is an exact function of `(a, b, window state)`
//! (DESIGN.md §4), and the window state is a bijection of the low-`k`
//! rail bits the planes already carry — so the metered driver
//! (`gemm::drive_rows_word_lanes`) chases one `u16` automaton state per
//! lane beside the planes and charges each `(group, t)` frame with a
//! single 64-lane state-major table gather
//! (`energy::EnergyLut::mac_fj_lanes`) *before* the frame's [`mac64`]
//! step — the same pre-step read the scalar meter does via
//! `state_of_rails`. The meter reads lane-major B encodings stashed at
//! pack time and never touches a compute plane, so metered lane results
//! are bit-identical to unmetered ones and the metered total equals the
//! scalar meter's to f64 summation order (identical per-MAC reads).
//! Pinned by `gemm::tests::metered_lane_kernels_match_the_scalar_meter`,
//! the metered-lane fuzz in `tests/prop_equiv.rs`, and the extended
//! Python oracle (`python/compile/kernels/lanes_check.py`, which walks
//! per-lane energy-index streams against scalar rail windows).
//!
//! [`mac64`]: LanePlan::mac64

use crate::pe::word::PeConfig;
use crate::Family;

/// Number of independent MAC chains one plane set carries.
pub const LANES: usize = 64;

/// Upper bound on the accumulator width W (matches [`PeConfig`]'s cap).
pub const MAX_W: usize = 48;

/// Per-row constants of the lane kernel: the scalar row masks of
/// [`crate::pe::word`], kept in scalar (per-bit) form — the kernel
/// broadcasts one bit across the 64 lanes as it visits each plane.
#[derive(Clone, Copy)]
struct LaneRow {
    /// First plane of this row's bit span (`j`).
    lo: usize,
    /// One past the last span plane (`min(j + n, w)`).
    hi: usize,
    /// NPPC (complemented-product) positions, absolute bit weights.
    nm: u64,
    /// Approximate-column positions within the span (`span & (2^k - 1)`).
    aa: u64,
}

/// Hoisted per-design-point plan for the 64-lane MAC kernel — the
/// transposed counterpart of [`crate::pe::word::MacPlan`].
#[derive(Clone)]
pub struct LanePlan {
    /// The design point the plan was built for.
    pub cfg: PeConfig,
    w: usize,
    n_rows: usize,
    fam: u8,
    /// Baugh-Wooley correction constant (0 when unsigned).
    bw: u64,
    opmask: u64,
    rows: [LaneRow; 16],
}

impl LanePlan {
    /// Build the plan (one-time cost per GEMM call, like `MacPlan`).
    pub fn new(cfg: &PeConfig) -> Self {
        assert!(cfg.n <= 16, "operand width capped at 16 bits");
        assert!((cfg.w as usize) <= MAX_W, "accumulator width capped at 48");
        let w = cfg.w as usize;
        let mw = cfg.word_mask();
        let amask = (1u64 << cfg.k) - 1;
        let mut rows = [LaneRow { lo: 0, hi: 0, nm: 0, aa: 0 }; 16];
        for j in 0..cfg.n as usize {
            let span = (((1u64 << cfg.n) - 1) << j) & mw;
            rows[j] = LaneRow {
                lo: j,
                hi: (j + cfg.n as usize).min(w),
                nm: cfg.nppc_mask(j as u32),
                aa: span & amask,
            };
        }
        LanePlan {
            cfg: *cfg,
            w,
            n_rows: cfg.n as usize,
            fam: match cfg.family {
                Family::Proposed => 0,
                Family::Axsa5 => 1,
                Family::Sips12 => 2,
                Family::Nano6 => 3,
                Family::Trunc => 4,
                Family::Loa => 5,
            },
            bw: if cfg.signed { cfg.bw_const() } else { 0 },
            opmask: (1u64 << cfg.n) - 1,
            rows,
        }
    }

    /// Accumulator width in planes (`cfg.w`).
    #[inline]
    pub fn width(&self) -> usize {
        self.w
    }

    /// Operand width in bit-planes (`cfg.n` — the length `b_planes` must
    /// have in [`Self::mac64`]).
    #[inline]
    pub fn b_planes(&self) -> usize {
        self.n_rows
    }

    /// One fused MAC across 64 independent chains.
    ///
    /// * `a` — the broadcast A-operand encoding (shared by every lane);
    /// * `b_planes` — the 64 lanes' B encodings, transposed: bit `l` of
    ///   `b_planes[j]` is bit `j` of lane `l`'s operand (see
    ///   [`pack_b_lanes`]);
    /// * `sp` / `kp` — the sum/carry rails as `w` planes, updated in
    ///   place (bit `l` of plane `i` = bit `i` of lane `l`'s rail).
    #[inline]
    pub fn mac64(&self, a: u64, b_planes: &[u64], sp: &mut [u64],
                 kp: &mut [u64]) {
        debug_assert_eq!(b_planes.len(), self.n_rows);
        debug_assert!(sp.len() >= self.w && kp.len() >= self.w);
        match self.fam {
            0 => self.mac64_rows::<0>(a, b_planes, sp, kp),
            1 => self.mac64_rows::<1>(a, b_planes, sp, kp),
            2 => self.mac64_rows::<2>(a, b_planes, sp, kp),
            3 => self.mac64_rows::<3>(a, b_planes, sp, kp),
            4 => self.mac64_rows::<4>(a, b_planes, sp, kp),
            _ => self.mac64_rows::<5>(a, b_planes, sp, kp),
        }
    }

    #[inline(always)]
    fn mac64_rows<const FAM: u8>(&self, a: u64, bp: &[u64], sp: &mut [u64],
                                 kp: &mut [u64]) {
        let w = self.w;
        let au = a & self.opmask;
        // the scalar `kc = kc.wrapping_add(bw)`: ripple-add the broadcast
        // constant bit-serially over the planes (carry out of plane w-1
        // drops, matching the scalar `& word_mask()`)
        if self.bw != 0 {
            let mut carry = 0u64;
            for (i, k) in kp.iter_mut().enumerate().take(w) {
                let bb = 0u64.wrapping_sub((self.bw >> i) & 1);
                let old = *k;
                *k = old ^ bb ^ carry;
                carry = (old & bb) | (old & carry) | (bb & carry);
            }
        }
        let mut c_out = [0u64; MAX_W];
        for (j, rm) in self.rows[..self.n_rows].iter().enumerate() {
            // per-lane product-row select: bit j of each lane's b
            let sel = bp[j];
            // cell layer: planes inside the span. Each plane's new sum
            // bit depends only on that plane, so s updates in place; the
            // produced carries are buffered (they land one plane up).
            for i in rm.lo..rm.hi {
                let abit = 0u64.wrapping_sub((au >> (i - j)) & 1);
                let p = sel & abit;
                let x = p ^ 0u64.wrapping_sub((rm.nm >> i) & 1);
                let s = sp[i];
                let k = kp[i];
                let (s2, c) = if (rm.aa >> i) & 1 == 0 {
                    // exact 3:2 compressor (PPC and NPPC share it: x
                    // already carries the complement)
                    (x ^ s ^ k, (x & s) | (x & k) | (s & k))
                } else {
                    let osk = s | k;
                    match FAM {
                        0 => {
                            if (rm.nm >> i) & 1 == 0 {
                                (osk & !x, x) // proposed PPC cell
                            } else {
                                (!osk | !x, osk & x) // proposed NPPC cell
                            }
                        }
                        1 => (x ^ s ^ k, 0), // AxSA [5]: carry elided
                        2 => (!(x ^ s), k),  // SiPS [12]
                        3 => (!s, x & k),    // NANOARCH [6]
                        4 => {
                            // truncated: drop the product — the cell
                            // input collapses to the nm tie-off (x ^ p)
                            let t = x ^ p;
                            (t ^ s ^ k, (t & s) | (t & k) | (s & k))
                        }
                        _ => (x | s, k), // LOA: OR-fold, pass the carry
                    }
                };
                sp[i] = s2;
                c_out[i] = c;
            }
            // the scalar `kc = (carries << 1).wrapping_add(kc & !span)`:
            // shift = carries land one plane up; the add ripples from the
            // span bottom (below it nothing changes), carry out of plane
            // w-1 drops
            let mut carry = 0u64;
            for i in rm.lo..w {
                let add = if i > rm.lo && i <= rm.hi { c_out[i - 1] } else { 0 };
                let pass = if i >= rm.hi { kp[i] } else { 0 };
                kp[i] = add ^ pass ^ carry;
                carry = (add & pass) | (add & carry) | (pass & carry);
            }
        }
    }
}

/// Pack up to 64 B-operand encodings into `n` transposed bit-planes:
/// bit `l` of `planes[j]` = bit `j` of `bvals[l]`. Lanes past
/// `bvals.len()` pack as zero (they compute garbage nobody reads).
pub fn pack_b_lanes(n: usize, bvals: &[u64], planes: &mut [u64]) {
    debug_assert!(bvals.len() <= LANES && planes.len() >= n);
    for p in planes[..n].iter_mut() {
        *p = 0;
    }
    for (l, &b) in bvals.iter().enumerate() {
        for (j, p) in planes[..n].iter_mut().enumerate() {
            *p |= ((b >> j) & 1) << l;
        }
    }
}

/// Gather lane `l`'s W-bit rail value out of a plane array.
#[inline]
pub fn lane_get(planes: &[u64], l: usize) -> u64 {
    let mut v = 0u64;
    for (i, &p) in planes.iter().enumerate() {
        v |= ((p >> l) & 1) << i;
    }
    v
}

/// Scatter a W-bit rail value into lane `l` of a plane array (test and
/// seeding helper — the GEMM driver always starts from zeroed planes).
pub fn lane_set(planes: &mut [u64], l: usize, v: u64) {
    for (i, p) in planes.iter_mut().enumerate() {
        *p = (*p & !(1u64 << l)) | (((v >> i) & 1) << l);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::word::{mac_step_planned, MacPlan};

    fn rnd(state: &mut u64) -> u64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        *state
    }

    #[test]
    fn lane_roundtrip_set_get() {
        let mut planes = [0u64; 24];
        let mut st = 0x5EED_u64;
        let vals: Vec<u64> =
            (0..LANES).map(|_| rnd(&mut st) & 0xFF_FFFF).collect();
        for (l, &v) in vals.iter().enumerate() {
            lane_set(&mut planes, l, v);
        }
        for (l, &v) in vals.iter().enumerate() {
            assert_eq!(lane_get(&planes, l), v, "lane {l}");
        }
    }

    #[test]
    fn lane_matches_planned_chains() {
        // 64 independent random chains, stepped together through mac64,
        // must match 64 scalar mac_step_planned walks bit-for-bit — for
        // every family, signedness, and k (including k > n clamps).
        let mut st = 0xABCDEF_u64;
        for family in Family::ALL {
            for signed in [false, true] {
                for k in [1u32, 3, 8, 12] {
                    let cfg = PeConfig::new(8, signed, family, k);
                    let plan = MacPlan::new(&cfg);
                    let lp = LanePlan::new(&cfg);
                    let w = lp.width();
                    let mut sp = vec![0u64; w];
                    let mut kp = vec![0u64; w];
                    let mut s = [0u64; LANES];
                    let mut kc = [0u64; LANES];
                    for l in 0..LANES {
                        s[l] = rnd(&mut st) & cfg.word_mask();
                        kc[l] = rnd(&mut st) & cfg.word_mask();
                        lane_set(&mut sp, l, s[l]);
                        lane_set(&mut kp, l, kc[l]);
                    }
                    let mut bplanes = vec![0u64; lp.b_planes()];
                    for step in 0..6 {
                        let a = rnd(&mut st) & 0xFF;
                        let bs: Vec<u64> =
                            (0..LANES).map(|_| rnd(&mut st) & 0xFF).collect();
                        pack_b_lanes(lp.b_planes(), &bs, &mut bplanes);
                        lp.mac64(a, &bplanes, &mut sp, &mut kp);
                        for l in 0..LANES {
                            let (s2, k2) =
                                mac_step_planned(&plan, a, bs[l], s[l], kc[l]);
                            s[l] = s2;
                            kc[l] = k2;
                            assert_eq!(
                                (lane_get(&sp, l), lane_get(&kp, l)),
                                (s2, k2),
                                "{family:?} signed={signed} k={k} \
                                 step={step} lane={l}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn lane_chain_resolves_like_scalar() {
        // from reset, a full MAC chain resolved through MacPlan::resolve
        // equals the scalar chain's value (the GEMM-driver usage)
        let mut st = 0x1234_u64;
        let cfg = PeConfig::new(8, true, Family::Proposed, 5);
        let plan = MacPlan::new(&cfg);
        let lp = LanePlan::new(&cfg);
        let mut sp = vec![0u64; lp.width()];
        let mut kp = vec![0u64; lp.width()];
        let mut scalar: Vec<(u64, u64)> = vec![(0, 0); LANES];
        let mut bplanes = vec![0u64; lp.b_planes()];
        for _ in 0..32 {
            let a = rnd(&mut st) & 0xFF;
            let bs: Vec<u64> = (0..LANES).map(|_| rnd(&mut st) & 0xFF).collect();
            pack_b_lanes(lp.b_planes(), &bs, &mut bplanes);
            lp.mac64(a, &bplanes, &mut sp, &mut kp);
            for (l, sk) in scalar.iter_mut().enumerate() {
                *sk = mac_step_planned(&plan, a, bs[l], sk.0, sk.1);
            }
        }
        for (l, &(s, kc)) in scalar.iter().enumerate() {
            assert_eq!(plan.resolve(lane_get(&sp, l), lane_get(&kp, l)),
                       plan.resolve(s, kc), "lane {l}");
        }
    }

    #[test]
    fn short_lane_groups_pack_zero_tails() {
        // a ragged (tail) lane group: only 5 live lanes; the packed tail
        // lanes must read back as b = 0 and not disturb the live ones
        let cfg = PeConfig::new(8, false, Family::Sips12, 4);
        let plan = MacPlan::new(&cfg);
        let lp = LanePlan::new(&cfg);
        let mut sp = vec![0u64; lp.width()];
        let mut kp = vec![0u64; lp.width()];
        let mut bplanes = vec![0u64; lp.b_planes()];
        let bs = [3u64, 250, 0, 77, 128];
        pack_b_lanes(lp.b_planes(), &bs, &mut bplanes);
        lp.mac64(200, &bplanes, &mut sp, &mut kp);
        for (l, &b) in bs.iter().enumerate() {
            let (s2, k2) = mac_step_planned(&plan, 200, b, 0, 0);
            assert_eq!((lane_get(&sp, l), lane_get(&kp, l)), (s2, k2),
                       "live lane {l}");
        }
    }
}
