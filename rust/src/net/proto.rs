//! The framed binary wire protocol (version 1).
//!
//! Every frame is `[len: u32 LE][magic: u16][version: u8][kind: u8]
//! [body]`, where `len` counts everything after itself. Integers are
//! little-endian; floats travel as their IEEE-754 bit pattern. The
//! decoder is hardened against untrusted input: truncated, corrupted
//! or oversized frames produce a typed [`ProtoError`] — never a panic
//! and never an unbounded allocation (length and element caps are
//! checked before any buffer is sized).
//!
//! Encoding and decoding go through **reusable buffers**
//! ([`encode`]/[`read_frame`]/[`write_frame`] all take a caller-owned
//! scratch `Vec`), so a busy connection allocates only for the decoded
//! matrices themselves.
//!
//! The **encoder is fallible too**: every length that travels as a
//! `u32` is validated against its protocol cap before a single byte is
//! written ([`encode`] returns [`ProtoError`] instead of silently
//! truncating a >4 GiB payload's length prefix), so a frame that
//! encodes is always a frame that decodes.
//!
//! Requests may carry an **accuracy SLO** ([`AccuracySlo`]) as a
//! trailing suffix on [`GemmReq`]/[`AppReq`] — one flags byte plus one
//! f64 per stated bound. Pre-SLO frames simply end before the suffix
//! and decode as `slo: None`, so version 1 stays wire-compatible in
//! both directions; a *present* suffix is validated strictly (zero or
//! unknown flags, truncated or out-of-range bounds → typed errors).
//! Stats frames grow the same way: the SLO routing counters ride a
//! trailing suffix that decodes as zeros when absent.
//!
//! Readiness-driven callers that own raw receive buffers use
//! [`try_decode`], the partial-buffer form of [`decode`]: `Ok(None)`
//! means "frame incomplete, read more bytes", without ambiguity against
//! genuinely malformed input.
//!
//! Round-trip identity (`decode(encode(f)) == f`) is fuzzed over 500
//! seeded frames of every kind — including empty matrices and ragged
//! shapes — in this module's tests; decoder rejection of hostile input
//! and encoder rejection of cap-breaking payloads are covered there
//! too.

use crate::apps::image::{Image, MAX_PGM_DIM};
use crate::coordinator::AppKind;
use crate::zoo::AccuracySlo;

/// Magic tag at the start of every frame payload.
pub const MAGIC: u16 = 0xA551;
/// Wire-protocol version this build speaks.
pub const VERSION: u8 = 1;
/// Hard cap on one frame's payload length (128 MiB).
pub const MAX_FRAME_LEN: usize = 1 << 27;
/// Hard cap on elements per wire matrix — operands *and* results
/// (refused before allocating). Sized so the largest legal frame, a
/// [`GemmReq`] carrying two cap-sized `i64` operands, still fits
/// [`MAX_FRAME_LEN`] with header room; the server also bounds `m * nn`
/// at admission, so every accepted request's reply is encodable.
pub const MAX_GEMM_ELEMS: usize = (1 << 23) - 64;
/// Hard cap on an inline PGM payload in an application request: the
/// largest legal image ([`MAX_PGM_DIM`]² pixels) plus header room, so
/// every PGM the decoder accepts is also receivable over the wire.
pub const MAX_PGM_LEN: usize = MAX_PGM_DIM * MAX_PGM_DIM + 4096;
/// Hard cap on a typed error reply's message bytes: the largest message
/// that still fits [`MAX_FRAME_LEN`] with header room. Checked by the
/// encoder so an error frame can never itself be unencodable.
pub const MAX_ERR_MSG_LEN: usize = MAX_FRAME_LEN - 16;

const K_GEMM_REQ: u8 = 1;
const K_GEMM_RESP: u8 = 2;
const K_APP_REQ: u8 = 3;
const K_APP_RESP: u8 = 4;
const K_STATS_REQ: u8 = 5;
const K_STATS_RESP: u8 = 6;
const K_ERROR: u8 = 7;

/// Why a frame failed to decode (or the stream failed underneath it).
#[derive(Debug)]
pub enum ProtoError {
    /// Underlying socket/stream failure while reading a frame.
    Io(std::io::Error),
    /// Frame payload did not start with [`MAGIC`].
    BadMagic(u16),
    /// Frame version is not [`VERSION`].
    BadVersion(u8),
    /// Unknown message-kind byte.
    UnknownKind(u8),
    /// A declared length exceeds a protocol cap (frame, matrix or
    /// image) — refused before any allocation.
    Oversized {
        /// The declared length / element count.
        len: usize,
        /// The cap it exceeded.
        max: usize,
    },
    /// Payload ended before the advertised content.
    Truncated {
        /// Bytes the decoder needed next.
        need: usize,
        /// Bytes still available.
        have: usize,
    },
    /// Structurally invalid payload (bad field values, trailing bytes).
    Malformed(&'static str),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "i/o: {e}"),
            ProtoError::BadMagic(m) => write!(f, "bad frame magic 0x{m:04x}"),
            ProtoError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v}")
            }
            ProtoError::UnknownKind(k) => write!(f, "unknown message kind {k}"),
            ProtoError::Oversized { len, max } => {
                write!(f, "declared length {len} exceeds cap {max}")
            }
            ProtoError::Truncated { need, have } => {
                write!(f, "truncated payload: need {need} bytes, have {have}")
            }
            ProtoError::Malformed(what) => write!(f, "malformed frame: {what}"),
        }
    }
}

impl std::error::Error for ProtoError {}

/// Machine-readable class of a typed error reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// Structurally invalid request (framing or field values).
    Malformed,
    /// The inline PGM payload failed to decode, or the image does not
    /// fit the application's shape rules.
    BadImage,
    /// The request names a capability this server does not have (e.g.
    /// `bdcn` without loaded weights, an unexpected frame kind).
    Unsupported,
    /// A size cap was exceeded.
    TooLarge,
    /// The server failed internally.
    Internal,
    /// The request's accuracy SLO cannot be met by any design point the
    /// server's zoo registers for its operand shape. The request was
    /// **not** executed — the protocol never silently degrades accuracy.
    SloUnsatisfiable,
}

impl ErrCode {
    /// Every code, in wire-value order.
    pub const ALL: [ErrCode; 6] = [ErrCode::Malformed, ErrCode::BadImage,
                                   ErrCode::Unsupported, ErrCode::TooLarge,
                                   ErrCode::Internal,
                                   ErrCode::SloUnsatisfiable];

    /// Stable wire value.
    pub fn code(self) -> u16 {
        match self {
            ErrCode::Malformed => 1,
            ErrCode::BadImage => 2,
            ErrCode::Unsupported => 3,
            ErrCode::TooLarge => 4,
            ErrCode::Internal => 5,
            ErrCode::SloUnsatisfiable => 6,
        }
    }

    /// Inverse of [`Self::code`] (`None` for unknown values).
    pub fn from_code(v: u16) -> Option<ErrCode> {
        Self::ALL.into_iter().find(|c| c.code() == v)
    }
}

/// One GEMM request: `C(m x nn) = A(m x kk) @ B(kk x nn)` at level `k`
/// (the wire form of [`crate::coordinator::GemmRequest`]).
#[derive(Clone, Debug, PartialEq)]
pub struct GemmReq {
    /// Approximation level (0 = exact).
    pub k: u32,
    /// Output rows.
    pub m: u32,
    /// Inner (contraction) dimension.
    pub kk: u32,
    /// Output columns.
    pub nn: u32,
    /// Left operand, row-major `m x kk`.
    pub a: Vec<i64>,
    /// Right operand, row-major `kk x nn`.
    pub b: Vec<i64>,
    /// Optional accuracy SLO: travels as a trailing suffix (flags byte
    /// + one f64 per stated bound) so pre-SLO frames — which simply end
    /// after `b` — still decode as `None`. When set, the server routes
    /// the design point (family *and* `k`) and the request's own `k` is
    /// advisory only.
    pub slo: Option<AccuracySlo>,
}

/// One GEMM response (the wire form of
/// [`crate::coordinator::GemmResponse`] plus its merged stats).
#[derive(Clone, Debug, PartialEq)]
pub struct GemmResp {
    /// Output rows.
    pub m: u32,
    /// Output columns.
    pub nn: u32,
    /// Server-side submit-to-complete latency of the pool request, µs.
    pub latency_us: f64,
    /// Output tiles the request was split into.
    pub tiles: u64,
    /// MAC operations executed.
    pub macs: u64,
    /// Metered data-dependent energy, femtojoules.
    pub energy_fj: f64,
    /// MACs covered by an energy meter (`== macs` when fully metered).
    pub metered_macs: u64,
    /// Result matrix, row-major `m x nn`.
    pub out: Vec<i64>,
}

impl GemmResp {
    /// Server-metered energy of this request in microjoules.
    pub fn energy_uj(&self) -> f64 {
        self.energy_fj * 1e-9
    }
}

/// One application request: the image travels inline as a binary PGM
/// payload (decoded server-side by [`crate::apps::image::decode_pgm`]).
#[derive(Clone, Debug, PartialEq)]
pub struct AppReq {
    /// Which pipeline to run.
    pub app: AppKind,
    /// Approximation level (0 = exact).
    pub k: u32,
    /// Inline binary PGM (P5) image payload.
    pub pgm: Vec<u8>,
    /// Optional accuracy SLO, same trailing-suffix wire form (and the
    /// same backward compatibility) as [`GemmReq::slo`].
    pub slo: Option<AccuracySlo>,
}

/// One application response (the wire form of
/// [`crate::coordinator::AppResponse`]).
#[derive(Clone, Debug, PartialEq)]
pub struct AppResp {
    /// Which pipeline served this request.
    pub app: AppKind,
    /// The paper's §V quality metric (may be infinite for exact
    /// self-referential runs — the bit pattern round-trips).
    pub psnr_db: f64,
    /// End-to-end pipeline latency on the server, µs.
    pub latency_us: f64,
    /// GEMM sub-requests the pipeline issued.
    pub gemm_requests: u64,
    /// Metered energy of every GEMM stage, femtojoules.
    pub energy_fj: f64,
    /// MAC operations executed across the pipeline's GEMM stages.
    pub macs: u64,
    /// Output-image height.
    pub h: u32,
    /// Output-image width.
    pub w: u32,
    /// Row-major output pixels (`h * w` bytes).
    pub pixels: Vec<u8>,
}

impl AppResp {
    /// Rebuild the reply's output image from the wire fields.
    pub fn image(&self) -> Image {
        Image {
            h: self.h as usize,
            w: self.w as usize,
            data: self.pixels.clone(),
        }
    }

    /// Server-metered energy of this request in microjoules.
    pub fn energy_uj(&self) -> f64 {
        self.energy_fj * 1e-9
    }
}

/// Snapshot of coordinator + network statistics (the stats frame's
/// body). Built server-side from
/// [`crate::coordinator::Coordinator::stats_snapshot`] and the fleet
/// [`crate::net::server::NetStats`] — both cloned under one short lock
/// each, *then* encoded.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireStats {
    /// GEMM pool requests completed.
    pub requests: u64,
    /// Output tiles executed.
    pub tiles: u64,
    /// MAC operations executed.
    pub macs: u64,
    /// Fleet total of metered energy, femtojoules.
    pub energy_fj: f64,
    /// MACs covered by an energy meter.
    pub metered_macs: u64,
    /// GEMM latency p50 over the retained window, µs.
    pub latency_p50_us: f64,
    /// GEMM latency p90, µs.
    pub latency_p90_us: f64,
    /// GEMM latency p99, µs.
    pub latency_p99_us: f64,
    /// Mean GEMM latency, µs.
    pub mean_latency_us: f64,
    /// TCP connections accepted since the server started.
    pub connections: u64,
    /// Frames read off client sockets.
    pub frames_in: u64,
    /// Frames written back to clients.
    pub frames_out: u64,
    /// Bytes read off client sockets (length prefixes included).
    pub bytes_in: u64,
    /// Bytes written back to clients.
    pub bytes_out: u64,
    /// Server-side request latency p50 (admission to reply written), µs.
    pub net_p50_us: f64,
    /// Server-side request latency p90, µs.
    pub net_p90_us: f64,
    /// Server-side request latency p99, µs.
    pub net_p99_us: f64,
    /// SLO-routed requests admitted (GEMM + app). Travels — with the
    /// three fields after it — as a trailing suffix, so stats frames
    /// from pre-SLO servers decode with zeros here.
    pub slo_requests: u64,
    /// SLO-routed requests that landed on the exact tier.
    pub slo_exact: u64,
    /// Requests refused with [`ErrCode::SloUnsatisfiable`].
    pub slo_unsatisfiable: u64,
    /// SLO-routed requests per accuracy tier
    /// ([`crate::zoo::Tier::ALL`] order: exact, high, mid, low).
    pub slo_tier: [u64; 4],
}

impl WireStats {
    /// Fleet total of metered energy in microjoules.
    pub fn total_energy_uj(&self) -> f64 {
        self.energy_fj * 1e-9
    }

    /// Mean metered energy per MAC in femtojoules (0.0 before any
    /// metered MAC).
    pub fn mean_mac_fj(&self) -> f64 {
        if self.metered_macs == 0 {
            0.0
        } else {
            self.energy_fj / self.metered_macs as f64
        }
    }
}

/// A typed error reply.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireError {
    /// Machine-readable error class.
    pub code: ErrCode,
    /// Human-readable detail.
    pub msg: String,
}

/// One protocol message (request or reply).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// GEMM request (client → server).
    GemmReq(GemmReq),
    /// GEMM response (server → client).
    GemmResp(GemmResp),
    /// Application request with an inline PGM image (client → server).
    AppReq(AppReq),
    /// Application response (server → client).
    AppResp(AppResp),
    /// Stats snapshot request (client → server, empty body).
    StatsReq,
    /// Stats snapshot response (server → client).
    StatsResp(WireStats),
    /// Typed error reply (server → client).
    Error(WireError),
}

// ---- encoding ------------------------------------------------------

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_i64s(out: &mut Vec<u8>, s: &[i64]) {
    out.reserve(s.len() * 8);
    for &v in s {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

// SLO wire suffix: one flags byte (bit 0 = `max_nmed` present, bit 1 =
// `min_psnr_db` present), then one f64 per present bound in bit order.
// Absence of the suffix (payload ends first) means "no SLO" — that is
// exactly what a pre-SLO encoder emits, so old frames stay decodable.
const SLO_F_NMED: u8 = 1 << 0;
const SLO_F_PSNR: u8 = 1 << 1;

fn put_slo(out: &mut Vec<u8>, slo: &AccuracySlo) {
    let mut flags = 0u8;
    if slo.max_nmed.is_some() {
        flags |= SLO_F_NMED;
    }
    if slo.min_psnr_db.is_some() {
        flags |= SLO_F_PSNR;
    }
    put_u8(out, flags);
    if let Some(v) = slo.max_nmed {
        put_f64(out, v);
    }
    if let Some(v) = slo.min_psnr_db {
        put_f64(out, v);
    }
}

/// Encoder-side SLO validation: a frame that encodes must decode, so
/// the same bound checks the decoder applies run before any byte is
/// written (empty SLOs travel as `None`, never as a zero flags byte).
fn check_slo(slo: Option<&AccuracySlo>) -> Result<(), ProtoError> {
    if let Some(s) = slo {
        s.validate()
            .map_err(|_| ProtoError::Malformed("SLO bounds out of range"))?;
    }
    Ok(())
}

fn app_code(app: AppKind) -> u8 {
    AppKind::ALL.iter().position(|&a| a == app).unwrap_or(0) as u8
}

fn app_from(code: u8) -> Result<AppKind, ProtoError> {
    match AppKind::ALL.get(code as usize) {
        Some(&a) => Ok(a),
        None => Err(ProtoError::Malformed("unknown application code")),
    }
}

/// Encode a GEMM request straight from borrowed operand slices — the
/// client's hot path. Byte-identical to
/// `encode(&Frame::GemmReq(..), out)` without materializing the owned
/// wire struct (no operand copy beyond the serialization itself).
///
/// Fails (without touching `out`) when a dimension pair exceeds
/// [`MAX_GEMM_ELEMS`] or an operand slice does not match its declared
/// shape — the exact conditions under which the resulting bytes would
/// not decode.
pub fn encode_gemm_req(k: u32, m: u32, kk: u32, nn: u32, a: &[i64],
                       b: &[i64], out: &mut Vec<u8>)
                       -> Result<(), ProtoError> {
    encode_gemm_req_slo(k, m, kk, nn, a, b, None, out)
}

/// [`encode_gemm_req`] with an optional accuracy SLO — the suffix-aware
/// form every GEMM-request encode routes through. A stated SLO is
/// validated before any byte is written (same bounds the decoder
/// enforces); `None` emits a byte-identical pre-SLO frame.
pub fn encode_gemm_req_slo(k: u32, m: u32, kk: u32, nn: u32, a: &[i64],
                           b: &[i64], slo: Option<&AccuracySlo>,
                           out: &mut Vec<u8>)
                           -> Result<(), ProtoError> {
    let ea = checked_elems(m, kk)?;
    let eb = checked_elems(kk, nn)?;
    if a.len() != ea || b.len() != eb {
        return Err(ProtoError::Malformed(
            "operand length does not match the declared dimensions"));
    }
    check_slo(slo)?;
    out.clear();
    out.extend_from_slice(&[0u8; 4]); // length, patched below
    put_u16(out, MAGIC);
    put_u8(out, VERSION);
    put_u8(out, K_GEMM_REQ);
    put_u32(out, k);
    put_u32(out, m);
    put_u32(out, kk);
    put_u32(out, nn);
    put_i64s(out, a);
    put_i64s(out, b);
    if let Some(s) = slo {
        put_slo(out, s);
    }
    let len = (out.len() - 4) as u32;
    out[..4].copy_from_slice(&len.to_le_bytes());
    Ok(())
}

/// Encode `frame` into `out` (cleared first): the 4-byte length prefix,
/// then magic/version/kind and the body. The buffer is reusable across
/// calls — steady-state encoding allocates nothing beyond its high-water
/// mark.
///
/// Every length that travels as a wire `u32` is validated against its
/// cap **before any byte is written** ([`MAX_GEMM_ELEMS`],
/// [`MAX_PGM_LEN`], [`MAX_PGM_DIM`], [`MAX_ERR_MSG_LEN`]); on failure
/// `out` is left untouched. This closes the unchecked-`as u32` class of
/// bug where a >4 GiB payload silently truncated its length prefix.
pub fn encode(frame: &Frame, out: &mut Vec<u8>) -> Result<(), ProtoError> {
    if let Frame::GemmReq(r) = frame {
        return encode_gemm_req_slo(r.k, r.m, r.kk, r.nn, &r.a, &r.b,
                                   r.slo.as_ref(), out);
    }
    // validate first, then write: a cap-breaking frame never clobbers
    // the caller's scratch buffer
    match frame {
        Frame::GemmResp(r) => {
            let eo = checked_elems(r.m, r.nn)?;
            if r.out.len() != eo {
                return Err(ProtoError::Malformed(
                    "result length does not match the declared dimensions"));
            }
        }
        Frame::AppReq(r) => {
            if r.pgm.len() > MAX_PGM_LEN {
                return Err(ProtoError::Oversized {
                    len: r.pgm.len(),
                    max: MAX_PGM_LEN,
                });
            }
            check_slo(r.slo.as_ref())?;
        }
        Frame::AppResp(r) => {
            if r.h as usize > MAX_PGM_DIM || r.w as usize > MAX_PGM_DIM {
                return Err(ProtoError::Oversized {
                    len: r.h.max(r.w) as usize,
                    max: MAX_PGM_DIM,
                });
            }
            if r.pixels.len() != (r.h as usize) * (r.w as usize) {
                return Err(ProtoError::Malformed(
                    "pixel length does not match the declared dimensions"));
            }
        }
        Frame::Error(e) => {
            if e.msg.len() > MAX_ERR_MSG_LEN {
                return Err(ProtoError::Oversized {
                    len: e.msg.len(),
                    max: MAX_ERR_MSG_LEN,
                });
            }
        }
        Frame::GemmReq(_) | Frame::StatsReq | Frame::StatsResp(_) => {}
    }
    out.clear();
    out.extend_from_slice(&[0u8; 4]); // length, patched below
    put_u16(out, MAGIC);
    put_u8(out, VERSION);
    match frame {
        Frame::GemmReq(_) => unreachable!("encoded by encode_gemm_req above"),
        Frame::GemmResp(r) => {
            put_u8(out, K_GEMM_RESP);
            put_u32(out, r.m);
            put_u32(out, r.nn);
            put_f64(out, r.latency_us);
            put_u64(out, r.tiles);
            put_u64(out, r.macs);
            put_f64(out, r.energy_fj);
            put_u64(out, r.metered_macs);
            put_i64s(out, &r.out);
        }
        Frame::AppReq(r) => {
            put_u8(out, K_APP_REQ);
            put_u8(out, app_code(r.app));
            put_u32(out, r.k);
            put_u32(out, r.pgm.len() as u32);
            out.extend_from_slice(&r.pgm);
            if let Some(s) = &r.slo {
                put_slo(out, s);
            }
        }
        Frame::AppResp(r) => {
            put_u8(out, K_APP_RESP);
            put_u8(out, app_code(r.app));
            put_f64(out, r.psnr_db);
            put_f64(out, r.latency_us);
            put_u64(out, r.gemm_requests);
            put_f64(out, r.energy_fj);
            put_u64(out, r.macs);
            put_u32(out, r.h);
            put_u32(out, r.w);
            out.extend_from_slice(&r.pixels);
        }
        Frame::StatsReq => put_u8(out, K_STATS_REQ),
        Frame::StatsResp(s) => {
            put_u8(out, K_STATS_RESP);
            put_u64(out, s.requests);
            put_u64(out, s.tiles);
            put_u64(out, s.macs);
            put_f64(out, s.energy_fj);
            put_u64(out, s.metered_macs);
            put_f64(out, s.latency_p50_us);
            put_f64(out, s.latency_p90_us);
            put_f64(out, s.latency_p99_us);
            put_f64(out, s.mean_latency_us);
            put_u64(out, s.connections);
            put_u64(out, s.frames_in);
            put_u64(out, s.frames_out);
            put_u64(out, s.bytes_in);
            put_u64(out, s.bytes_out);
            put_f64(out, s.net_p50_us);
            put_f64(out, s.net_p90_us);
            put_f64(out, s.net_p99_us);
            // SLO counter suffix (pre-SLO decoders never see it: they
            // stop at net_p99_us and reject the trailing bytes, which
            // is version-correct — a stats *reader* must understand
            // what the server measured)
            put_u64(out, s.slo_requests);
            put_u64(out, s.slo_exact);
            put_u64(out, s.slo_unsatisfiable);
            for t in s.slo_tier {
                put_u64(out, t);
            }
        }
        Frame::Error(e) => {
            put_u8(out, K_ERROR);
            put_u16(out, e.code.code());
            put_u32(out, e.msg.len() as u32);
            out.extend_from_slice(e.msg.as_bytes());
        }
    }
    let len = (out.len() - 4) as u32;
    out[..4].copy_from_slice(&len.to_le_bytes());
    Ok(())
}

// ---- decoding ------------------------------------------------------

struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Rd { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.remaining() < n {
            return Err(ProtoError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ProtoError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn i64s(&mut self, count: usize) -> Result<Vec<i64>, ProtoError> {
        let bytes = self.take(count * 8)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Decode the optional SLO suffix at the current read position. A
/// payload that simply ends here is a pre-SLO frame (`None`); a present
/// suffix must be well-formed — a zero or unknown flags byte, a
/// truncated bound, or bound values the router would reject (non-finite
/// or out of range) are all typed errors, never a silently dropped SLO.
fn rd_slo(rd: &mut Rd) -> Result<Option<AccuracySlo>, ProtoError> {
    if rd.remaining() == 0 {
        return Ok(None);
    }
    let flags = rd.u8()?;
    if flags == 0 || flags & !(SLO_F_NMED | SLO_F_PSNR) != 0 {
        return Err(ProtoError::Malformed("invalid SLO flags byte"));
    }
    let max_nmed =
        if flags & SLO_F_NMED != 0 { Some(rd.f64()?) } else { None };
    let min_psnr_db =
        if flags & SLO_F_PSNR != 0 { Some(rd.f64()?) } else { None };
    let slo = AccuracySlo { max_nmed, min_psnr_db };
    slo.validate()
        .map_err(|_| ProtoError::Malformed("SLO bounds out of range"))?;
    Ok(Some(slo))
}

fn checked_elems(x: u32, y: u32) -> Result<usize, ProtoError> {
    let n = (x as u64) * (y as u64);
    if n > MAX_GEMM_ELEMS as u64 {
        return Err(ProtoError::Oversized {
            len: n.min(usize::MAX as u64) as usize,
            max: MAX_GEMM_ELEMS,
        });
    }
    Ok(n as usize)
}

/// Decode one frame payload (everything after the length prefix).
fn decode_payload(buf: &[u8]) -> Result<Frame, ProtoError> {
    let mut rd = Rd::new(buf);
    let magic = rd.u16()?;
    if magic != MAGIC {
        return Err(ProtoError::BadMagic(magic));
    }
    let ver = rd.u8()?;
    if ver != VERSION {
        return Err(ProtoError::BadVersion(ver));
    }
    let kind = rd.u8()?;
    let frame = match kind {
        K_GEMM_REQ => {
            let k = rd.u32()?;
            let m = rd.u32()?;
            let kk = rd.u32()?;
            let nn = rd.u32()?;
            let ea = checked_elems(m, kk)?;
            let eb = checked_elems(kk, nn)?;
            let (a, b) = (rd.i64s(ea)?, rd.i64s(eb)?);
            Frame::GemmReq(GemmReq { k, m, kk, nn, a, b,
                                     slo: rd_slo(&mut rd)? })
        }
        K_GEMM_RESP => {
            let m = rd.u32()?;
            let nn = rd.u32()?;
            let latency_us = rd.f64()?;
            let tiles = rd.u64()?;
            let macs = rd.u64()?;
            let energy_fj = rd.f64()?;
            let metered_macs = rd.u64()?;
            let eo = checked_elems(m, nn)?;
            Frame::GemmResp(GemmResp { m, nn, latency_us, tiles, macs,
                                       energy_fj, metered_macs,
                                       out: rd.i64s(eo)? })
        }
        K_APP_REQ => {
            let app = app_from(rd.u8()?)?;
            let k = rd.u32()?;
            let len = rd.u32()? as usize;
            if len > MAX_PGM_LEN {
                return Err(ProtoError::Oversized { len, max: MAX_PGM_LEN });
            }
            let pgm = rd.take(len)?.to_vec();
            Frame::AppReq(AppReq { app, k, pgm, slo: rd_slo(&mut rd)? })
        }
        K_APP_RESP => {
            let app = app_from(rd.u8()?)?;
            let psnr_db = rd.f64()?;
            let latency_us = rd.f64()?;
            let gemm_requests = rd.u64()?;
            let energy_fj = rd.f64()?;
            let macs = rd.u64()?;
            let h = rd.u32()?;
            let w = rd.u32()?;
            if h as usize > MAX_PGM_DIM || w as usize > MAX_PGM_DIM {
                return Err(ProtoError::Oversized {
                    len: h.max(w) as usize,
                    max: MAX_PGM_DIM,
                });
            }
            let px = (h as usize) * (w as usize);
            Frame::AppResp(AppResp { app, psnr_db, latency_us, gemm_requests,
                                     energy_fj, macs, h, w,
                                     pixels: rd.take(px)?.to_vec() })
        }
        K_STATS_REQ => Frame::StatsReq,
        K_STATS_RESP => {
            let mut s = WireStats {
                requests: rd.u64()?,
                tiles: rd.u64()?,
                macs: rd.u64()?,
                energy_fj: rd.f64()?,
                metered_macs: rd.u64()?,
                latency_p50_us: rd.f64()?,
                latency_p90_us: rd.f64()?,
                latency_p99_us: rd.f64()?,
                mean_latency_us: rd.f64()?,
                connections: rd.u64()?,
                frames_in: rd.u64()?,
                frames_out: rd.u64()?,
                bytes_in: rd.u64()?,
                bytes_out: rd.u64()?,
                net_p50_us: rd.f64()?,
                net_p90_us: rd.f64()?,
                net_p99_us: rd.f64()?,
                ..Default::default()
            };
            // SLO counter suffix: absent on pre-SLO servers → zeros
            if rd.remaining() != 0 {
                s.slo_requests = rd.u64()?;
                s.slo_exact = rd.u64()?;
                s.slo_unsatisfiable = rd.u64()?;
                for t in s.slo_tier.iter_mut() {
                    *t = rd.u64()?;
                }
            }
            Frame::StatsResp(s)
        }
        K_ERROR => {
            let raw = rd.u16()?;
            let code = match ErrCode::from_code(raw) {
                Some(c) => c,
                None => return Err(ProtoError::Malformed("unknown error code")),
            };
            let len = rd.u32()? as usize;
            let msg = String::from_utf8(rd.take(len)?.to_vec())
                .map_err(|_| ProtoError::Malformed("error message not UTF-8"))?;
            Frame::Error(WireError { code, msg })
        }
        other => return Err(ProtoError::UnknownKind(other)),
    };
    if rd.remaining() != 0 {
        return Err(ProtoError::Malformed("trailing bytes after frame body"));
    }
    Ok(frame)
}

/// Decode one frame from the start of a **partial** receive buffer —
/// the readiness-driven server's reassembly primitive. Returns:
///
/// * `Ok(Some((frame, consumed)))` — one complete frame decoded;
///   `consumed` bytes (length prefix included) can be drained.
/// * `Ok(None)` — the buffer holds a valid prefix of an incomplete
///   frame; read more bytes and call again. Never returned for input
///   that could not grow into a legal frame.
/// * `Err(_)` — the buffer can never become a legal frame (bad length
///   prefix, bad magic/version/kind, malformed body); the connection's
///   framing is unrecoverable.
pub fn try_decode(buf: &[u8]) -> Result<Option<(Frame, usize)>, ProtoError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_LEN {
        return Err(ProtoError::Oversized { len, max: MAX_FRAME_LEN });
    }
    if len < 4 {
        return Err(ProtoError::Malformed("frame length below header size"));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some((decode_payload(&buf[4..4 + len])?, 4 + len)))
}

/// Decode one full frame (length prefix included) from the start of
/// `buf`; returns the frame and the bytes consumed. Every failure is a
/// typed error — the decoder never panics on arbitrary input. The
/// complete-buffer form of [`try_decode`]: an incomplete frame is
/// reported as [`ProtoError::Truncated`].
pub fn decode(buf: &[u8]) -> Result<(Frame, usize), ProtoError> {
    match try_decode(buf)? {
        Some(r) => Ok(r),
        None => {
            let need = if buf.len() < 4 {
                4
            } else {
                4 + u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize
            };
            Err(ProtoError::Truncated { need, have: buf.len() })
        }
    }
}

/// Read one frame from `r`. `Ok(None)` means clean EOF at a frame
/// boundary (the peer closed between frames); EOF inside a frame is an
/// error. `scratch` is the reusable payload buffer.
pub fn read_frame<R: std::io::Read>(
    r: &mut R,
    scratch: &mut Vec<u8>,
) -> Result<Option<Frame>, ProtoError> {
    let mut hdr = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut hdr[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None)
                } else {
                    Err(ProtoError::Malformed(
                        "connection closed inside a frame header"))
                };
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    if len > MAX_FRAME_LEN {
        return Err(ProtoError::Oversized { len, max: MAX_FRAME_LEN });
    }
    if len < 4 {
        return Err(ProtoError::Malformed("frame length below header size"));
    }
    scratch.clear();
    scratch.resize(len, 0);
    r.read_exact(scratch).map_err(ProtoError::Io)?;
    decode_payload(scratch).map(Some)
}

/// Encode `frame` into `scratch` and write it whole to `w`; returns the
/// total bytes written (length prefix included). Fails with the
/// encoder's typed error on a cap-breaking frame (before writing) or
/// [`ProtoError::Io`] on a stream failure.
pub fn write_frame<W: std::io::Write>(
    w: &mut W,
    frame: &Frame,
    scratch: &mut Vec<u8>,
) -> Result<usize, ProtoError> {
    encode(frame, scratch)?;
    w.write_all(scratch).map_err(ProtoError::Io)?;
    Ok(scratch.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::XorShift;

    fn rand_f(x: &mut XorShift) -> f64 {
        (x.next_u64() % 1_000_000) as f64 / 7.0
    }

    fn rand_slo(x: &mut XorShift) -> Option<AccuracySlo> {
        // half None (the pre-SLO wire form), half every flags combo
        match x.next_u64() % 6 {
            0 => Some(AccuracySlo {
                max_nmed: Some((x.next_u64() % 1000) as f64 * 1e-5),
                min_psnr_db: None,
            }),
            1 => Some(AccuracySlo {
                max_nmed: None,
                min_psnr_db: Some(1.0 + (x.next_u64() % 60) as f64),
            }),
            2 => Some(AccuracySlo {
                max_nmed: Some((x.next_u64() % 1000) as f64 * 1e-5),
                min_psnr_db: Some(1.0 + (x.next_u64() % 60) as f64),
            }),
            _ => None,
        }
    }

    fn rand_frame(x: &mut XorShift) -> Frame {
        match x.next_u64() % 7 {
            0 => {
                // ragged sizes including empty matrices
                let m = (x.next_u64() % 13) as u32;
                let kk = (x.next_u64() % 9) as u32;
                let nn = (x.next_u64() % 13) as u32;
                Frame::GemmReq(GemmReq {
                    k: (x.next_u64() % 9) as u32,
                    m,
                    kk,
                    nn,
                    a: (0..(m * kk) as usize).map(|_| x.next_u64() as i64)
                        .collect(),
                    b: (0..(kk * nn) as usize).map(|_| x.next_u64() as i64)
                        .collect(),
                    slo: rand_slo(x),
                })
            }
            1 => {
                let m = (x.next_u64() % 11) as u32;
                let nn = (x.next_u64() % 11) as u32;
                Frame::GemmResp(GemmResp {
                    m,
                    nn,
                    latency_us: rand_f(x),
                    tiles: x.next_u64() % 1000,
                    macs: x.next_u64() % 100_000,
                    energy_fj: rand_f(x),
                    metered_macs: x.next_u64() % 100_000,
                    out: (0..(m * nn) as usize).map(|_| x.next_u64() as i64)
                        .collect(),
                })
            }
            2 => Frame::AppReq(AppReq {
                app: AppKind::ALL[x.next_u64() as usize % AppKind::ALL.len()],
                k: (x.next_u64() % 9) as u32,
                pgm: (0..(x.next_u64() % 300) as usize)
                    .map(|_| x.next_u64() as u8)
                    .collect(),
                slo: rand_slo(x),
            }),
            3 => {
                let h = (x.next_u64() % 10) as u32;
                let w = (x.next_u64() % 10) as u32;
                Frame::AppResp(AppResp {
                    app: AppKind::ALL[x.next_u64() as usize % AppKind::ALL.len()],
                    psnr_db: if x.next_u64() % 8 == 0 {
                        f64::INFINITY
                    } else {
                        rand_f(x)
                    },
                    latency_us: rand_f(x),
                    gemm_requests: x.next_u64() % 100,
                    energy_fj: rand_f(x),
                    macs: x.next_u64() % 100_000,
                    h,
                    w,
                    pixels: (0..(h * w) as usize).map(|_| x.next_u64() as u8)
                        .collect(),
                })
            }
            4 => Frame::StatsReq,
            5 => Frame::StatsResp(WireStats {
                requests: x.next_u64() % 10_000,
                tiles: x.next_u64() % 10_000,
                macs: x.next_u64(),
                energy_fj: rand_f(x),
                metered_macs: x.next_u64(),
                latency_p50_us: rand_f(x),
                latency_p90_us: rand_f(x),
                latency_p99_us: rand_f(x),
                mean_latency_us: rand_f(x),
                connections: x.next_u64() % 100,
                frames_in: x.next_u64() % 100_000,
                frames_out: x.next_u64() % 100_000,
                bytes_in: x.next_u64(),
                bytes_out: x.next_u64(),
                net_p50_us: rand_f(x),
                net_p90_us: rand_f(x),
                net_p99_us: rand_f(x),
                slo_requests: x.next_u64() % 10_000,
                slo_exact: x.next_u64() % 10_000,
                slo_unsatisfiable: x.next_u64() % 100,
                slo_tier: [x.next_u64() % 100, x.next_u64() % 100,
                           x.next_u64() % 100, x.next_u64() % 100],
            }),
            _ => {
                let n = (x.next_u64() % 40) as usize;
                Frame::Error(WireError {
                    code: ErrCode::ALL[(x.next_u64() % 6) as usize],
                    msg: (0..n)
                        .map(|_| char::from(b'a' + (x.next_u64() % 26) as u8))
                        .collect(),
                })
            }
        }
    }

    #[test]
    fn fuzz_round_trip_identity_500_cases() {
        let mut x = XorShift::new(0xF0A1);
        let mut buf = Vec::new();
        for case in 0..500 {
            let f = rand_frame(&mut x);
            encode(&f, &mut buf).unwrap();
            let (back, used) =
                decode(&buf).unwrap_or_else(|e| panic!("case {case}: {e}"));
            assert_eq!(used, buf.len(), "case {case}: partial consume");
            assert_eq!(back, f, "case {case}: round trip not identity");
        }
    }

    #[test]
    fn streamed_frames_read_back_in_order() {
        let mut x = XorShift::new(0xBEEF);
        let frames: Vec<Frame> = (0..40).map(|_| rand_frame(&mut x)).collect();
        let mut stream = Vec::new();
        let mut buf = Vec::new();
        for f in &frames {
            encode(f, &mut buf).unwrap();
            stream.extend_from_slice(&buf);
        }
        let mut cur = std::io::Cursor::new(stream);
        let mut scratch = Vec::new();
        for (i, f) in frames.iter().enumerate() {
            let got = read_frame(&mut cur, &mut scratch).unwrap().unwrap();
            assert_eq!(&got, f, "frame {i}");
        }
        assert!(read_frame(&mut cur, &mut scratch).unwrap().is_none(),
                "clean EOF after the last frame");
    }

    #[test]
    fn decoder_rejects_truncation_corruption_and_oversize_without_panic() {
        let mut x = XorShift::new(0x7E57);
        let mut buf = Vec::new();
        for _ in 0..50 {
            let f = rand_frame(&mut x);
            encode(&f, &mut buf).unwrap();
            // every strict prefix fails with a typed error, never panics
            let step = (buf.len() / 17).max(1);
            for cut in (0..buf.len()).step_by(step) {
                assert!(decode(&buf[..cut]).is_err(),
                        "prefix {cut} of {} must not decode", buf.len());
            }
        }
        // corrupted magic
        encode(&Frame::StatsReq, &mut buf).unwrap();
        buf[4] ^= 0xFF;
        assert!(matches!(decode(&buf), Err(ProtoError::BadMagic(_))));
        // bad version
        encode(&Frame::StatsReq, &mut buf).unwrap();
        buf[6] = 99;
        assert!(matches!(decode(&buf), Err(ProtoError::BadVersion(99))));
        // unknown kind
        encode(&Frame::StatsReq, &mut buf).unwrap();
        buf[7] = 0xEE;
        assert!(matches!(decode(&buf), Err(ProtoError::UnknownKind(0xEE))));
        // oversized length prefix refuses before reading anything
        let mut bad = ((MAX_FRAME_LEN as u32) + 1).to_le_bytes().to_vec();
        bad.extend_from_slice(&[0u8; 16]);
        assert!(matches!(decode(&bad), Err(ProtoError::Oversized { .. })));
        // a length below the header size is structurally invalid
        let mut tiny = 2u32.to_le_bytes().to_vec();
        tiny.extend_from_slice(&[0u8, 0u8]);
        assert!(matches!(decode(&tiny), Err(ProtoError::Malformed(_))));
        // trailing garbage inside the declared payload is rejected
        encode(&Frame::StatsReq, &mut buf).unwrap();
        buf.push(0xAB);
        let len = (buf.len() - 4) as u32;
        buf[..4].copy_from_slice(&len.to_le_bytes());
        assert!(matches!(decode(&buf), Err(ProtoError::Malformed(_))));
        // oversized matrix dims reject before allocating
        encode(&Frame::GemmReq(GemmReq {
            k: 0, m: 0, kk: 0, nn: 0, a: vec![], b: vec![], slo: None,
        }), &mut buf).unwrap();
        buf[12..16].copy_from_slice(&(1u32 << 16).to_le_bytes()); // m
        buf[16..20].copy_from_slice(&(1u32 << 16).to_le_bytes()); // kk
        assert!(matches!(decode(&buf), Err(ProtoError::Oversized { .. })));
        // oversized inline image length rejects before allocating
        encode(&Frame::AppReq(AppReq {
            app: AppKind::Dct, k: 0, pgm: vec![], slo: None,
        }), &mut buf).unwrap();
        // payload layout: magic(2) ver(1) kind(1) app(1) k(4) len(4)
        buf[13..17].copy_from_slice(&((MAX_PGM_LEN as u32) + 1).to_le_bytes());
        assert!(matches!(decode(&buf), Err(ProtoError::Oversized { .. })));
    }

    #[test]
    fn borrowed_gemm_encode_matches_owned_form() {
        let mut x = XorShift::new(0x60DD);
        for _ in 0..20 {
            let m = (x.next_u64() % 9) as u32;
            let kk = (x.next_u64() % 7) as u32;
            let nn = (x.next_u64() % 9) as u32;
            let k = (x.next_u64() % 8) as u32;
            let a: Vec<i64> =
                (0..(m * kk) as usize).map(|_| x.next_u64() as i64).collect();
            let b: Vec<i64> =
                (0..(kk * nn) as usize).map(|_| x.next_u64() as i64).collect();
            let slo = rand_slo(&mut x);
            let mut owned = Vec::new();
            encode(&Frame::GemmReq(GemmReq {
                k, m, kk, nn, a: a.clone(), b: b.clone(), slo,
            }), &mut owned).unwrap();
            let mut borrowed = Vec::new();
            encode_gemm_req_slo(k, m, kk, nn, &a, &b, slo.as_ref(),
                                &mut borrowed).unwrap();
            assert_eq!(owned, borrowed);
            if slo.is_none() {
                // the SLO-less borrowed form is byte-identical too
                let mut legacy = Vec::new();
                encode_gemm_req(k, m, kk, nn, &a, &b, &mut legacy).unwrap();
                assert_eq!(owned, legacy);
            }
        }
    }

    #[test]
    fn err_codes_round_trip() {
        for c in ErrCode::ALL {
            assert_eq!(ErrCode::from_code(c.code()), Some(c));
        }
        assert_eq!(ErrCode::from_code(999), None);
    }

    #[test]
    fn encoder_rejects_cap_breaking_frames_without_writing() {
        // regression for the unchecked `len as u32` class of bug: every
        // encode path that writes a u32 length must validate it first
        let sentinel = vec![0xAAu8; 8];
        let mut buf = sentinel.clone();
        // operand length inconsistent with the declared dims
        let r = encode(&Frame::GemmReq(GemmReq {
            k: 0, m: 2, kk: 2, nn: 2, a: vec![1; 3], b: vec![1; 4],
            slo: None,
        }), &mut buf);
        assert!(matches!(r, Err(ProtoError::Malformed(_))));
        assert_eq!(buf, sentinel, "failed encode must not touch the buffer");
        // dims whose product exceeds the wire element cap
        let r = encode(&Frame::GemmReq(GemmReq {
            k: 0, m: 1 << 16, kk: 1 << 16, nn: 1, a: vec![], b: vec![],
            slo: None,
        }), &mut buf);
        assert!(matches!(r, Err(ProtoError::Oversized { .. })));
        let r = encode(&Frame::GemmResp(GemmResp {
            m: 1 << 16, nn: 1 << 16, latency_us: 0.0, tiles: 0, macs: 0,
            energy_fj: 0.0, metered_macs: 0, out: vec![],
        }), &mut buf);
        assert!(matches!(r, Err(ProtoError::Oversized { .. })));
        // inline PGM payload over the wire cap
        let r = encode(&Frame::AppReq(AppReq {
            app: AppKind::Dct, k: 0, pgm: vec![0; MAX_PGM_LEN + 1],
            slo: None,
        }), &mut buf);
        assert!(matches!(r, Err(ProtoError::Oversized { .. })));
        // response image dims over the PGM cap / inconsistent pixels
        let r = encode(&Frame::AppResp(AppResp {
            app: AppKind::Edge, psnr_db: 0.0, latency_us: 0.0,
            gemm_requests: 0, energy_fj: 0.0, macs: 0,
            h: (MAX_PGM_DIM + 1) as u32, w: 1, pixels: vec![],
        }), &mut buf);
        assert!(matches!(r, Err(ProtoError::Oversized { .. })));
        let r = encode(&Frame::AppResp(AppResp {
            app: AppKind::Edge, psnr_db: 0.0, latency_us: 0.0,
            gemm_requests: 0, energy_fj: 0.0, macs: 0,
            h: 2, w: 2, pixels: vec![0; 5],
        }), &mut buf);
        assert!(matches!(r, Err(ProtoError::Malformed(_))));
        assert_eq!(buf, sentinel, "failed encode must not touch the buffer");
        // every rejected frame would also have been refused by the
        // decoder — and the accepted ones still round-trip
        let ok = Frame::Error(WireError {
            code: ErrCode::Internal,
            msg: "x".repeat(64),
        });
        encode(&ok, &mut buf).unwrap();
        assert_eq!(decode(&buf).unwrap().0, ok);
    }

    fn patch_len(buf: &mut [u8]) {
        let len = (buf.len() - 4) as u32;
        buf[..4].copy_from_slice(&len.to_le_bytes());
    }

    #[test]
    fn slo_suffix_round_trips_and_pre_slo_frames_still_decode() {
        let base = GemmReq {
            k: 3, m: 2, kk: 2, nn: 2,
            a: vec![1, 2, 3, 4], b: vec![5, 6, 7, 8], slo: None,
        };
        let mut buf = Vec::new();
        // every flags combination round-trips bit-exactly (inf psnr
        // bound is invalid, so bounds here are finite)
        for slo in [
            AccuracySlo { max_nmed: Some(2.5e-4), min_psnr_db: None },
            AccuracySlo { max_nmed: None, min_psnr_db: Some(30.0) },
            AccuracySlo { max_nmed: Some(1e-3), min_psnr_db: Some(25.5) },
        ] {
            let f = Frame::GemmReq(GemmReq { slo: Some(slo), ..base.clone() });
            encode(&f, &mut buf).unwrap();
            assert_eq!(decode(&buf).unwrap().0, f);
            let g = Frame::AppReq(AppReq {
                app: AppKind::Edge, k: 2, pgm: b"P5 1 1 255 x".to_vec(),
                slo: Some(slo),
            });
            encode(&g, &mut buf).unwrap();
            assert_eq!(decode(&buf).unwrap().0, g);
        }
        // a frame encoded without an SLO is byte-for-byte the pre-SLO
        // wire form — its payload ends right after the `b` operand —
        // and decodes to `slo: None` (old clients keep working)
        encode(&Frame::GemmReq(base.clone()), &mut buf).unwrap();
        assert_eq!(buf.len(), 4 + 2 + 1 + 1 + 16 + 32 + 32);
        match decode(&buf).unwrap().0 {
            Frame::GemmReq(r) => assert_eq!(r.slo, None),
            other => panic!("wrong frame kind: {other:?}"),
        }
        // a stats frame from a pre-SLO server (no counter suffix)
        // decodes with the SLO counters zeroed
        let stats = WireStats {
            requests: 7, slo_requests: 3, slo_exact: 1,
            slo_unsatisfiable: 2, slo_tier: [1, 1, 1, 0],
            ..Default::default()
        };
        encode(&Frame::StatsResp(stats.clone()), &mut buf).unwrap();
        assert_eq!(decode(&buf).unwrap().0, Frame::StatsResp(stats));
        buf.truncate(buf.len() - 7 * 8); // strip the SLO suffix
        patch_len(&mut buf);
        match decode(&buf).unwrap().0 {
            Frame::StatsResp(s) => {
                assert_eq!(s.requests, 7);
                assert_eq!(s.slo_requests, 0);
                assert_eq!(s.slo_tier, [0; 4]);
            }
            other => panic!("wrong frame kind: {other:?}"),
        }
    }

    #[test]
    fn garbage_slo_suffixes_are_rejected_typed() {
        let base = GemmReq {
            k: 0, m: 1, kk: 1, nn: 1, a: vec![9], b: vec![9], slo: None,
        };
        let mut clean = Vec::new();
        encode(&Frame::GemmReq(base.clone()), &mut clean).unwrap();
        let with_suffix = |suffix: &[u8]| {
            let mut b = clean.clone();
            b.extend_from_slice(suffix);
            patch_len(&mut b);
            b
        };
        // a zero flags byte states no bound: not a legal suffix
        assert!(matches!(decode(&with_suffix(&[0])),
                         Err(ProtoError::Malformed(_))));
        // unknown flag bits are from the future: refuse, don't guess
        assert!(matches!(decode(&with_suffix(&[0b100])),
                         Err(ProtoError::Malformed(_))));
        // flags promise a bound the payload doesn't carry
        assert!(matches!(decode(&with_suffix(&[SLO_F_NMED, 1, 2, 3])),
                         Err(ProtoError::Truncated { .. })));
        // non-finite and out-of-range bounds are refused at the wire
        for bad in [f64::NAN, f64::INFINITY, -1.0] {
            let mut s = vec![SLO_F_NMED];
            s.extend_from_slice(&bad.to_bits().to_le_bytes());
            assert!(matches!(decode(&with_suffix(&s)),
                             Err(ProtoError::Malformed(_))),
                    "max_nmed = {bad} must be rejected");
        }
        // bytes *after* a well-formed suffix are trailing garbage
        let mut s = vec![SLO_F_NMED];
        s.extend_from_slice(&1e-3f64.to_bits().to_le_bytes());
        s.push(0xAB);
        assert!(matches!(decode(&with_suffix(&s)),
                         Err(ProtoError::Malformed(_))));
        // and the encoder refuses the same bounds the decoder would
        for bad in [
            AccuracySlo { max_nmed: None, min_psnr_db: None },
            AccuracySlo { max_nmed: Some(f64::NAN), min_psnr_db: None },
            AccuracySlo { max_nmed: None, min_psnr_db: Some(-2.0) },
        ] {
            let sentinel = vec![0x5A; 6];
            let mut buf = sentinel.clone();
            let r = encode(&Frame::GemmReq(GemmReq {
                slo: Some(bad), ..base.clone()
            }), &mut buf);
            assert!(matches!(r, Err(ProtoError::Malformed(_))));
            assert_eq!(buf, sentinel, "failed encode must not write");
        }
    }

    #[test]
    fn try_decode_resumes_cleanly_across_partial_buffers() {
        let mut x = XorShift::new(0x9A37);
        let frames: Vec<Frame> = (0..30).map(|_| rand_frame(&mut x)).collect();
        let mut stream = Vec::new();
        let mut buf = Vec::new();
        for f in &frames {
            encode(f, &mut buf).unwrap();
            stream.extend_from_slice(&buf);
        }
        // feed the byte stream in adversarial chunk sizes; every frame
        // must come out intact and in order, with exact byte accounting
        for chunk in [1usize, 3, 7, 64, 1009] {
            let mut rbuf: Vec<u8> = Vec::new();
            let mut got = Vec::new();
            let mut fed = 0;
            while fed < stream.len() || !rbuf.is_empty() {
                let n = chunk.min(stream.len() - fed);
                rbuf.extend_from_slice(&stream[fed..fed + n]);
                fed += n;
                loop {
                    match try_decode(&rbuf).unwrap() {
                        Some((f, used)) => {
                            rbuf.drain(..used);
                            got.push(f);
                        }
                        None => break,
                    }
                }
                if fed == stream.len() && rbuf.is_empty() {
                    break;
                }
            }
            assert_eq!(got, frames, "chunk size {chunk}");
        }
        // a buffer that can never become a legal frame errors out
        // instead of asking for more bytes
        let bad = ((MAX_FRAME_LEN as u32) + 1).to_le_bytes();
        assert!(matches!(try_decode(&bad),
                         Err(ProtoError::Oversized { .. })));
        assert!(matches!(try_decode(&2u32.to_le_bytes()),
                         Err(ProtoError::Malformed(_))));
        // and a strict prefix of a legal frame is Ok(None), not an error
        encode(&Frame::StatsReq, &mut buf).unwrap();
        for cut in 0..buf.len() {
            assert!(try_decode(&buf[..cut]).unwrap().is_none(),
                    "prefix {cut} must ask for more bytes");
        }
    }
}
