//! Minimal `poll(2)` / `getrlimit(2)` FFI for the event-loop server.
//!
//! The crate is std-only, so readiness multiplexing binds the libc
//! symbols directly instead of pulling in `libc`/`mio`. Only what the
//! shard loops need is declared: `poll` with an EINTR retry wrapper,
//! the event bits the loops inspect, and an open-files rlimit raiser so
//! the scale load generator can hold thousands of sockets.
//!
//! Portable `poll` (not `epoll`/`kqueue`) keeps one code path across
//! Linux and macOS; at the per-shard fd counts the server runs
//! (thousands of connections split over N shards), the O(fds) scan per
//! wakeup is far below the request-handling cost.

use std::io;
use std::os::unix::io::RawFd;

/// Readiness: data available to read (or a pending accept).
pub const POLLIN: i16 = 0x001;
/// Readiness: writable without blocking.
pub const POLLOUT: i16 = 0x004;
/// Result only: error condition on the descriptor.
pub const POLLERR: i16 = 0x008;
/// Result only: peer hung up (read may still drain buffered bytes).
pub const POLLHUP: i16 = 0x010;
/// Result only: the descriptor is not open.
pub const POLLNVAL: i16 = 0x020;

/// One entry of a `poll(2)` set, ABI-compatible with `struct pollfd`.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    /// Descriptor to watch (< 0 = ignore this entry).
    pub fd: RawFd,
    /// Requested events ([`POLLIN`] | [`POLLOUT`]).
    pub events: i16,
    /// Returned events, filled by the kernel.
    pub revents: i16,
}

impl PollFd {
    /// Watch `fd` for `events`, with `revents` cleared.
    pub fn new(fd: RawFd, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }
}

#[cfg(target_os = "macos")]
type NfdsT = u32;
#[cfg(not(target_os = "macos"))]
type NfdsT = core::ffi::c_ulong;

/// Process resource limit pair, ABI-compatible with `struct rlimit`
/// (both fields are `u64` on the 64-bit Unixes this crate targets).
#[repr(C)]
#[derive(Clone, Copy, Debug)]
struct RLimit {
    cur: u64,
    max: u64,
}

#[cfg(target_os = "macos")]
const RLIMIT_NOFILE: i32 = 8;
#[cfg(not(target_os = "macos"))]
const RLIMIT_NOFILE: i32 = 7;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
}

/// Block until a descriptor in `fds` is ready, `timeout_ms` elapses
/// (`-1` = forever), or an error. Returns the number of entries with
/// nonzero `revents`; retries transparently on `EINTR`.
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe {
            poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms)
        };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// Raise the soft open-files limit to the hard limit and return the
/// resulting soft value. Needed by the scale load generator, which can
/// hold thousands of sockets from one process; a no-op when the soft
/// limit already equals the hard one.
pub fn raise_nofile_limit() -> io::Result<u64> {
    let mut lim = RLimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return Err(io::Error::last_os_error());
    }
    if lim.cur >= lim.max {
        return Ok(lim.cur);
    }
    let want = RLimit { cur: lim.max, max: lim.max };
    if unsafe { setrlimit(RLIMIT_NOFILE, &want) } != 0 {
        // Not fatal for callers: report the still-effective soft limit.
        return Ok(lim.cur);
    }
    Ok(want.cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn poll_reports_readable_after_write() {
        use std::os::unix::io::AsRawFd;
        let lis = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut tx = TcpStream::connect(lis.local_addr().unwrap()).unwrap();
        let (rx, _) = lis.accept().unwrap();
        let mut fds = [PollFd::new(rx.as_raw_fd(), POLLIN)];
        // nothing written yet: poll with a zero timeout sees no events
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
        tx.write_all(b"x").unwrap();
        tx.flush().unwrap();
        let n = poll_fds(&mut fds, 2000).unwrap();
        assert_eq!(n, 1);
        assert_ne!(fds[0].revents & POLLIN, 0);
    }

    #[test]
    fn nofile_limit_is_queryable_and_sane() {
        let soft = raise_nofile_limit().unwrap();
        assert!(soft >= 64, "soft open-files limit {soft} is implausible");
    }
}

/// The event-loop server requires `poll(2)`; non-Unix targets have no
/// readiness syscall to bind in a std-only crate.
#[cfg(not(unix))]
compile_error!(
    "axsys::net requires a Unix target: the event-loop server binds poll(2)"
);
