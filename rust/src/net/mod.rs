//! Network serving layer: a framed TCP boundary in front of the
//! coordinator — std-only (a `poll(2)`-driven event loop over
//! nonblocking sockets, no async runtime, no external crates).
//!
//! Until this module existed every request entered through an
//! in-process [`crate::coordinator::Coordinator`] handle; the related
//! DNN-accelerator literature treats these arrays as *shared*
//! infrastructure that many workloads multiplex onto, which needs a
//! real wire boundary with admission control, not a library call. The
//! pieces:
//!
//! * [`proto`] — the length-prefixed, versioned binary wire protocol:
//!   GEMM requests/responses, application requests with inline PGM
//!   payloads, stats snapshots and typed error replies, all
//!   encoded/decoded through reusable buffers, with
//!   [`proto::try_decode`] for incremental reassembly from partial
//!   buffers and cap-validated (never silently truncating) encoders.
//!   GEMM and application requests carry an optional accuracy-SLO
//!   suffix ([`crate::zoo::AccuracySlo`]) so clients can ask for
//!   "cheapest design point meeting this error bound" instead of a
//!   fixed `k`; unsatisfiable SLOs come back as typed
//!   [`proto::ErrCode::SloUnsatisfiable`] error frames, never as
//!   silently-degraded results.
//! * [`server`] — a sharded, readiness-driven TCP server fronting a
//!   running coordinator: the acceptor round-robins connections across
//!   N shard event loops, each multiplexing thousands of nonblocking
//!   sockets with per-connection frame-reassembly state machines and
//!   in-order reply pipelining; a fixed resolver pool executes requests
//!   on the worker pool so shards never block. The max-inflight
//!   admission gate **backpressures (stops polling a saturated
//!   connection for read) rather than drops**, shutdown drains
//!   gracefully, and per-connection + fleet [`server::NetStats`] fold
//!   per shard (including SLO-routed request and rejection counts) —
//!   no global lock on any hot path.
//! * [`client`] — a blocking client library; [`client::RemoteGemm`]
//!   implements the [`crate::apps::Gemm`] trait, so every existing
//!   application pipeline and differential test runs over TCP
//!   unchanged.
//! * [`loadgen`] — a closed-loop multi-client load generator with a
//!   seeded xorshift request mix plus a thread-multiplexed **scale
//!   mode** (thousands of concurrent connections with per-reply
//!   integrity checks), reporting throughput, latency percentiles and
//!   server-metered energy as `BENCH_serve_net.json`.
//!
//! Results served over TCP are **bit-identical** to the in-process
//! coordinator path on every backend: the wire carries exact `i64`
//! operands and the server submits them to the same worker pool
//! (`tests/net_serve.rs` pins this for `word`/`lut`/`systolic`, GEMM
//! and all three application pipelines).
//!
//! The frame lifecycle (where backpressure lives) is documented in
//! ARCHITECTURE.md's "Network data-flow" section.

pub mod client;
pub mod loadgen;
pub mod proto;
pub mod server;
mod sys;

use std::fmt;

/// Client-side failure of one framed request/reply exchange.
#[derive(Debug)]
pub enum NetError {
    /// Socket-level failure (connect/read/write) or server disconnect.
    Io(std::io::Error),
    /// The peer violated the wire protocol.
    Proto(proto::ProtoError),
    /// The server answered with a typed error frame.
    Server {
        /// Machine-readable error class.
        code: proto::ErrCode,
        /// Human-readable detail from the server.
        msg: String,
    },
    /// The server answered with a frame kind that does not match the
    /// request that was sent.
    Unexpected(&'static str),
}

impl fmt::Display for NetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetError::Io(e) => write!(f, "network i/o: {e}"),
            NetError::Proto(e) => write!(f, "wire protocol: {e}"),
            NetError::Server { code, msg } => {
                write!(f, "server error ({code:?}): {msg}")
            }
            NetError::Unexpected(what) => write!(f, "unexpected reply: {what}"),
        }
    }
}

impl std::error::Error for NetError {}

impl From<std::io::Error> for NetError {
    fn from(e: std::io::Error) -> Self {
        NetError::Io(e)
    }
}

impl From<proto::ProtoError> for NetError {
    fn from(e: proto::ProtoError) -> Self {
        match e {
            proto::ProtoError::Io(io) => NetError::Io(io),
            other => NetError::Proto(other),
        }
    }
}
