//! Closed-loop TCP load generator (the `axsys loadgen` subcommand).
//!
//! Spins [`LoadgenConfig::clients`] client threads, each with its own
//! connection and a **seeded xorshift request mix** — GEMM shapes drawn
//! from `8..=40` per dimension, approximation levels `0..=k_max`, and
//! (unless disabled) periodic `dct`/`edge` application requests with
//! inline PGM images. Reports client-observed throughput and
//! p50/p90/p99 latency plus the **server-reported** pool counters and
//! metered energy from a stats frame, and returns the whole summary as
//! a [`Json`] document (written to `BENCH_serve_net.json` by the CLI,
//! uploaded as a CI artifact by the loopback smoke job).
//!
//! The request mix varies sizes, levels and request kinds; the cell
//! *family* is a property of the server's pool configuration, so
//! sweeping families means pointing the generator at differently
//! configured servers.
//!
//! **Scale mode** ([`ScaleConfig`] / [`run_scale`], `loadgen --conns`)
//! stresses the event-loop front-end instead of the pool: a few worker
//! threads multiplex *thousands* of concurrent connections (all held
//! open simultaneously behind a barrier), pipeline tiny tagged `1x1x1`
//! GEMMs down each one, and verify every reply byte-for-byte — a lost,
//! reordered or corrupted reply fails the run. Its summary is the
//! `axsys-serve-scale/v1` document backing `BENCH_serve_net.json`'s
//! concurrency numbers.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use crate::apps::image::{scene, texture};
use crate::bench::{xorshift_ints, Json, XorShift};
use crate::coordinator::{percentile_sorted, AppKind};
use crate::zoo::AccuracySlo;

use super::client::Client;
use super::{sys, NetError};

/// Knobs of one load-generation run (all have CLI flags).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address (`HOST:PORT`).
    pub addr: String,
    /// Concurrent client connections.
    pub clients: usize,
    /// Total requests across all clients.
    pub requests: usize,
    /// Approximation levels are drawn uniformly from `0..=k_max`.
    pub k_max: u32,
    /// Base seed of the deterministic request mix.
    pub seed: u64,
    /// Include `dct`/`edge` application requests in the mix.
    pub apps: bool,
    /// Accuracy SLO attached to every other request (`--slo`): half the
    /// mix is SLO-routed by the server, half runs at the drawn `k`, so
    /// one run exercises both admission paths.
    pub slo: Option<AccuracySlo>,
}

impl LoadgenConfig {
    /// Default mix against `addr`: 4 clients, 64 requests, `k <= 6`,
    /// apps included.
    pub fn new(addr: String) -> Self {
        LoadgenConfig {
            addr,
            clients: 4,
            requests: 64,
            k_max: 6,
            seed: 0x5EED,
            apps: true,
            slo: None,
        }
    }
}

/// Knobs of one scale-mode run (`loadgen --conns`): connection-count
/// stress against the event-loop front-end rather than pool throughput.
#[derive(Clone, Debug)]
pub struct ScaleConfig {
    /// Server address (`HOST:PORT`).
    pub addr: String,
    /// Concurrent connections, all held open simultaneously (clamped to
    /// what the process's open-files limit can hold after
    /// [`run_scale`] raises it).
    pub conns: usize,
    /// Pipelined requests per connection.
    pub per_conn: usize,
    /// Worker threads multiplexing the connections (0 = auto-size from
    /// the host's available parallelism).
    pub threads: usize,
}

impl ScaleConfig {
    /// Default stress shape against `addr`: 1000 connections, 4
    /// pipelined requests each, auto thread count.
    pub fn new(addr: String) -> Self {
        ScaleConfig { addr, conns: 1000, per_conn: 4, threads: 0 }
    }
}

/// Default artifact location: `BENCH_serve_net.json` at the repository
/// root, next to `BENCH_hotpath.json`.
pub fn default_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .join("BENCH_serve_net.json")
}

struct WorkerOut {
    gemm_lat: Vec<f64>,
    app_lat: Vec<f64>,
    macs: u64,
}

fn worker(addr: String, n: usize, seed: u64, k_max: u32, apps: bool,
          slo: Option<AccuracySlo>) -> Result<WorkerOut, NetError> {
    let mut client = Client::connect(addr.as_str())?;
    let mut rng = XorShift::new(seed);
    let mut out = WorkerOut {
        gemm_lat: Vec::with_capacity(n),
        app_lat: Vec::new(),
        macs: 0,
    };
    for i in 0..n {
        let k = (rng.next_u64() % (k_max as u64 + 1)) as u32;
        // with --slo, every other request is SLO-routed by the server
        let rslo = if i % 2 == 0 { slo.as_ref() } else { None };
        if apps && i % 8 == 7 {
            // every 8th request exercises an app pipeline end-to-end,
            // cycling nn inference -> dct -> edge. The cycle starts at
            // nn so even the shortest smoke run (one app request per
            // client) sends CNN classifier traffic over the wire.
            let (app, img) = match (i / 8) % 3 {
                0 => (AppKind::Nn, scene(16, 16)),
                1 => (AppKind::Dct, scene(32, 32)),
                _ => (AppKind::Edge, texture(24, 24, seed ^ i as u64)),
            };
            let t0 = Instant::now();
            let r = client.app_slo(app, &img, k, rslo)?;
            out.app_lat.push(t0.elapsed().as_secs_f64() * 1e6);
            out.macs += r.macs;
        } else {
            let m = 8 + (rng.next_u64() % 33) as usize;
            let kk = 8 + (rng.next_u64() % 17) as usize;
            let nn = 8 + (rng.next_u64() % 33) as usize;
            let a = xorshift_ints(rng.next_u64(), m * kk);
            let b = xorshift_ints(rng.next_u64(), kk * nn);
            let t0 = Instant::now();
            client.send_gemm_slo(&a, &b, m, kk, nn, k, rslo)?;
            let r = client.recv_gemm()?;
            out.gemm_lat.push(t0.elapsed().as_secs_f64() * 1e6);
            out.macs += r.macs;
        }
    }
    Ok(out)
}

fn lat_json(sorted: &[f64]) -> Json {
    let mean = if sorted.is_empty() {
        0.0
    } else {
        sorted.iter().sum::<f64>() / sorted.len() as f64
    };
    Json::obj()
        .set("count", Json::Int(sorted.len() as i64))
        .set("p50", Json::Num(percentile_sorted(sorted, 0.50)))
        .set("p90", Json::Num(percentile_sorted(sorted, 0.90)))
        .set("p99", Json::Num(percentile_sorted(sorted, 0.99)))
        .set("max", Json::Num(sorted.last().copied().unwrap_or(0.0)))
        .set("mean", Json::Num(mean))
}

/// Run the configured fleet against a live server and return the
/// summary document. Any client-side failure (connect refused, typed
/// server error, protocol violation) aborts the run with that error —
/// a clean exit means every request got a correct-kind reply.
pub fn run(cfg: &LoadgenConfig) -> Result<Json, NetError> {
    let clients = cfg.clients.max(1);
    // the probe connection doubles as the stats poller at the end
    let mut probe = Client::connect(cfg.addr.as_str())?;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for ci in 0..clients {
        let n = cfg.requests / clients
            + usize::from(ci < cfg.requests % clients);
        if n == 0 {
            continue;
        }
        let addr = cfg.addr.clone();
        let seed = cfg.seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(ci as u64 + 1);
        let (k_max, apps, slo) = (cfg.k_max, cfg.apps, cfg.slo);
        handles.push(std::thread::Builder::new()
            .name(format!("axsys-loadgen-{ci}"))
            .spawn(move || worker(addr, n, seed, k_max, apps, slo))
            .expect("spawn loadgen client"));
    }
    let mut gemm_lat = Vec::new();
    let mut app_lat = Vec::new();
    let mut macs = 0u64;
    for h in handles {
        let w = h.join().expect("loadgen client thread")?;
        gemm_lat.extend(w.gemm_lat);
        app_lat.extend(w.app_lat);
        macs += w.macs;
    }
    let wall = t0.elapsed().as_secs_f64();
    // server-reported counters + metered energy (snapshot-then-encode
    // server-side: polling never holds the pool's stats lock)
    let ws = probe.stats()?;
    let mut all: Vec<f64> =
        gemm_lat.iter().chain(app_lat.iter()).copied().collect();
    all.sort_by(f64::total_cmp);
    gemm_lat.sort_by(f64::total_cmp);
    app_lat.sort_by(f64::total_cmp);
    let served = all.len();
    println!("loadgen: {} requests over {} clients in {:.3}s ({:.1} req/s)",
             served, clients, wall, served as f64 / wall.max(1e-9));
    println!("  latency µs: p50 {:.0}  p90 {:.0}  p99 {:.0}  max {:.0}",
             percentile_sorted(&all, 0.50), percentile_sorted(&all, 0.90),
             percentile_sorted(&all, 0.99),
             all.last().copied().unwrap_or(0.0));
    println!("  server: {} pool requests, {:.3} µJ metered ({:.2} fJ/MAC), \
              {} frames in / {} out",
             ws.requests, ws.total_energy_uj(), ws.mean_mac_fj(),
             ws.frames_in, ws.frames_out);
    if cfg.slo.is_some() {
        println!("  slo: {} routed ({} exact tier, tiers {:?}), \
                  {} unsatisfiable",
                 ws.slo_requests, ws.slo_exact, ws.slo_tier,
                 ws.slo_unsatisfiable);
    }
    Ok(Json::obj()
        .set("schema", Json::Str("axsys-serve-net/v1".into()))
        .set("config", Json::obj()
            .set("addr", Json::Str(cfg.addr.clone()))
            .set("clients", Json::Int(clients as i64))
            .set("requests", Json::Int(cfg.requests as i64))
            .set("k_max", Json::Int(cfg.k_max as i64))
            .set("seed", Json::Int(cfg.seed as i64))
            .set("apps", Json::Bool(cfg.apps))
            .set("slo", match &cfg.slo {
                Some(s) => Json::Str(s.to_string()),
                None => Json::Null,
            }))
        .set("wall_s", Json::Num(wall))
        .set("served_requests", Json::Int(served as i64))
        .set("throughput_req_per_sec",
             Json::Num(served as f64 / wall.max(1e-9)))
        .set("client_macs", Json::Int(macs as i64))
        .set("latency_us", lat_json(&all))
        .set("gemm_latency_us", lat_json(&gemm_lat))
        .set("app_latency_us", lat_json(&app_lat))
        .set("server", Json::obj()
            .set("requests", Json::Int(ws.requests as i64))
            .set("tiles", Json::Int(ws.tiles as i64))
            .set("macs", Json::Int(ws.macs as i64))
            .set("energy_uj_total", Json::Num(ws.total_energy_uj()))
            .set("mean_mac_fj", Json::Num(ws.mean_mac_fj()))
            .set("metered_macs", Json::Int(ws.metered_macs as i64))
            .set("latency_us", Json::obj()
                .set("p50", Json::Num(ws.latency_p50_us))
                .set("p90", Json::Num(ws.latency_p90_us))
                .set("p99", Json::Num(ws.latency_p99_us))
                .set("mean", Json::Num(ws.mean_latency_us)))
            .set("slo", Json::obj()
                .set("requests", Json::Int(ws.slo_requests as i64))
                .set("exact", Json::Int(ws.slo_exact as i64))
                .set("unsatisfiable",
                     Json::Int(ws.slo_unsatisfiable as i64))
                .set("tiers", Json::Arr(ws.slo_tier.iter()
                    .map(|&t| Json::Int(t as i64)).collect())))
            .set("net", Json::obj()
                .set("connections", Json::Int(ws.connections as i64))
                .set("frames_in", Json::Int(ws.frames_in as i64))
                .set("frames_out", Json::Int(ws.frames_out as i64))
                .set("bytes_in", Json::Int(ws.bytes_in as i64))
                .set("bytes_out", Json::Int(ws.bytes_out as i64))
                .set("p50", Json::Num(ws.net_p50_us))
                .set("p90", Json::Num(ws.net_p90_us))
                .set("p99", Json::Num(ws.net_p99_us)))))
}

/// The scale worker's slice: its connections all open before the
/// barrier, so every slice across every thread is concurrent.
fn scale_worker(addr: String, first: usize, count: usize, per_conn: usize,
                barrier: Arc<Barrier>) -> Result<Vec<f64>, NetError> {
    let mut clients = Vec::with_capacity(count);
    for c in first..first + count {
        clients.push((c, Client::connect(addr.as_str())?));
    }
    barrier.wait(); // every configured connection is now open at once
    let mut lat = Vec::with_capacity(count * per_conn);
    let mut t_send = Vec::with_capacity(per_conn);
    for (c, client) in clients.iter_mut() {
        // pipeline the whole batch, then read replies strictly in
        // order: each reply must carry its request's tag back — a
        // dropped, duplicated or reordered reply shifts every later
        // tag and fails the verification below
        t_send.clear();
        for i in 0..per_conn {
            let tag = ((*c as i64) << 20) | i as i64;
            client.send_gemm(&[tag], &[1], 1, 1, 1, 0)?;
            t_send.push(Instant::now());
        }
        for i in 0..per_conn {
            let r = client.recv_gemm()?;
            lat.push(t_send[i].elapsed().as_secs_f64() * 1e6);
            let tag = ((*c as i64) << 20) | i as i64;
            if r.out.as_slice() != [tag] {
                return Err(NetError::Unexpected(
                    "scale reply lost, reordered or corrupted"));
            }
        }
    }
    Ok(lat)
}

/// Run the connection-scale stress and return the
/// `axsys-serve-scale/v1` summary document. A clean return proves zero
/// lost/reordered/corrupted replies across every connection (each reply
/// is verified against its request's unique tag); any violation — or
/// any socket/protocol failure — aborts with the error.
pub fn run_scale(cfg: &ScaleConfig) -> Result<Json, NetError> {
    // thousands of sockets from one process: lift the soft open-files
    // limit to the hard one, then clamp the plan to what actually fits
    // (2 fds of headroom per connection: the socket plus kernel slack
    // for accept-side churn, wake pairs and the probe)
    let limit = sys::raise_nofile_limit().unwrap_or(1024);
    let cap = (limit as usize / 2).saturating_sub(128).max(16);
    let mut conns = cfg.conns.max(1);
    if conns > cap {
        eprintln!("loadgen: open-files limit {limit} caps the run at \
                   {cap} connections (asked for {conns})");
        conns = cap;
    }
    let per_conn = cfg.per_conn.max(1);
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads = match cfg.threads {
        0 => (cores * 2).clamp(1, 64),
        t => t,
    }
    .min(conns);
    let mut probe = Client::connect(cfg.addr.as_str())?;
    let barrier = Arc::new(Barrier::new(threads));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    let mut first = 0usize;
    for ti in 0..threads {
        let count = conns / threads + usize::from(ti < conns % threads);
        let addr = cfg.addr.clone();
        let b = barrier.clone();
        handles.push(std::thread::Builder::new()
            .name(format!("axsys-scale-{ti}"))
            .spawn(move || scale_worker(addr, first, count, per_conn, b))
            .expect("spawn scale worker"));
        first += count;
    }
    let mut lat = Vec::with_capacity(conns * per_conn);
    for h in handles {
        lat.extend(h.join().expect("scale worker thread")?);
    }
    let wall = t0.elapsed().as_secs_f64();
    let ws = probe.stats()?;
    lat.sort_by(f64::total_cmp);
    let served = lat.len();
    println!("loadgen scale: {} conns x {} requests in {:.3}s \
              ({:.0} req/s, {} threads)",
             conns, per_conn, wall,
             served as f64 / wall.max(1e-9), threads);
    println!("  latency µs: p50 {:.0}  p90 {:.0}  p99 {:.0}  max {:.0}",
             percentile_sorted(&lat, 0.50), percentile_sorted(&lat, 0.90),
             percentile_sorted(&lat, 0.99),
             lat.last().copied().unwrap_or(0.0));
    println!("  server: {} connections seen, {} frames in / {} out",
             ws.connections, ws.frames_in, ws.frames_out);
    Ok(Json::obj()
        .set("schema", Json::Str("axsys-serve-scale/v1".into()))
        .set("config", Json::obj()
            .set("addr", Json::Str(cfg.addr.clone()))
            .set("conns", Json::Int(conns as i64))
            .set("per_conn", Json::Int(per_conn as i64))
            .set("threads", Json::Int(threads as i64)))
        .set("wall_s", Json::Num(wall))
        .set("served_requests", Json::Int(served as i64))
        .set("throughput_req_per_sec",
             Json::Num(served as f64 / wall.max(1e-9)))
        .set("latency_us", lat_json(&lat))
        .set("server", Json::obj()
            .set("connections", Json::Int(ws.connections as i64))
            .set("frames_in", Json::Int(ws.frames_in as i64))
            .set("frames_out", Json::Int(ws.frames_out as i64))
            .set("bytes_in", Json::Int(ws.bytes_in as i64))
            .set("bytes_out", Json::Int(ws.bytes_out as i64))
            .set("net_p50_us", Json::Num(ws.net_p50_us))
            .set("net_p90_us", Json::Num(ws.net_p90_us))
            .set("net_p99_us", Json::Num(ws.net_p99_us))))
}
