//! Thread-per-connection TCP server fronting a running
//! [`Coordinator`].
//!
//! Each accepted connection gets a **reader** thread (decodes frames,
//! validates, submits GEMMs to the pool) and a **writer** thread
//! (resolves pending replies in admission order, encodes them through a
//! reusable buffer, flushes when the queue runs dry). The bounded
//! channel between them is the **admission gate**: when
//! [`ServerConfig::max_inflight`] replies are pending, the reader
//! blocks handing over the next request, stops reading the socket, the
//! kernel's receive window fills, and the client's writes stall — the
//! server backpressures instead of dropping or reordering. Replies are
//! written strictly in request order per connection, so pipelined
//! clients can match replies to requests positionally.
//!
//! [`NetServer::shutdown`] drains gracefully: the listener stops
//! accepting, every connection's read side is half-closed (no *new*
//! requests are admitted), already-admitted requests complete on the
//! pool and their replies flush before the connection threads are
//! joined. Statistics are kept **per connection** and folded into fleet
//! totals ([`NetServer::stats`], the stats frame) on demand, so no hot
//! path ever contends on one global lock.

use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::apps::bdcn::Block;
use crate::apps::image::decode_pgm;
use crate::apps::image::Image;
use crate::coordinator::{AppKind, Coordinator, GemmRequest, LatencyRing,
                         ServiceStats};

use super::proto::{self, AppResp, ErrCode, Frame, GemmResp, ProtoError,
                   WireError, WireStats};

/// Per-connection and fleet-level network counters. The latency ring is
/// the same sampler [`ServiceStats`] uses
/// ([`LatencyRing`]), recording server-side
/// admission-to-reply-written time per request.
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    /// TCP connections accepted (fleet level only).
    pub connections_opened: u64,
    /// Connections fully torn down (fleet level only).
    pub connections_closed: u64,
    /// Frames read off the socket.
    pub frames_in: u64,
    /// Frames written back.
    pub frames_out: u64,
    /// Bytes read (length prefixes included).
    pub bytes_in: u64,
    /// Bytes written.
    pub bytes_out: u64,
    /// GEMM request frames seen (valid or not).
    pub gemm_requests: u64,
    /// Application request frames seen.
    pub app_requests: u64,
    /// Stats request frames seen.
    pub stats_requests: u64,
    /// Typed error frames sent.
    pub error_replies: u64,
    latency: LatencyRing,
}

impl NetStats {
    /// Server-side request latency percentile (admission → reply
    /// written) over the retained ring window.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency.percentile(p)
    }

    fn record_latency(&mut self, us: f64) {
        self.latency.record(us);
    }

    /// Fold another stats block into this one (fleet totals = closed
    /// connections + every live connection's block).
    pub fn merge(&mut self, other: &NetStats) {
        self.connections_opened += other.connections_opened;
        self.connections_closed += other.connections_closed;
        self.frames_in += other.frames_in;
        self.frames_out += other.frames_out;
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
        self.gemm_requests += other.gemm_requests;
        self.app_requests += other.app_requests;
        self.stats_requests += other.stats_requests;
        self.error_replies += other.error_replies;
        self.latency.merge(&other.latency);
    }
}

/// Static configuration of one [`NetServer`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Admission gate: max replies pending per connection before the
    /// reader stops reading the socket (0 selects
    /// [`Self::DEFAULT_MAX_INFLIGHT`]). This bounds both memory and
    /// pool queue pressure per client; excess requests wait in the
    /// kernel's socket buffers on the *client's* side.
    pub max_inflight: usize,
    /// Socket write timeout per connection (`None` = never time out).
    /// A client that stops *reading* its replies eventually stalls the
    /// connection's writer in `write`; this bounds that stall — and
    /// therefore how long [`NetServer::shutdown`]'s drain can block on
    /// an unresponsive client before abandoning its connection.
    pub write_timeout: Option<Duration>,
    /// Trained BDCN weights, if this server should serve `bdcn`
    /// requests (without them, `bdcn` gets a typed `Unsupported` reply).
    pub bdcn: Option<Arc<Vec<Block>>>,
}

impl ServerConfig {
    /// Default admission-gate depth.
    pub const DEFAULT_MAX_INFLIGHT: usize = 32;
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_inflight: Self::DEFAULT_MAX_INFLIGHT,
            write_timeout: Some(Duration::from_secs(30)),
            bdcn: None,
        }
    }
}

struct State {
    coord: Arc<Coordinator>,
    cfg: ServerConfig,
    opened: AtomicU64,
    closed_count: AtomicU64,
    /// Folded stats of closed connections.
    closed: Mutex<NetStats>,
    /// Live per-connection stats blocks.
    live: Mutex<Vec<Arc<Mutex<NetStats>>>>,
    /// One cloned handle per **live** connection (keyed by connection
    /// id), for the shutdown drain's read-side half-close. Entries are
    /// pruned when their connection finishes — a long-running server
    /// must not accumulate one dup'd fd per connection ever accepted.
    conns: Mutex<Vec<(u64, TcpStream)>>,
    stop: AtomicBool,
}

impl State {
    /// Fleet totals: closed-connection accumulator + live blocks. Holds
    /// the `live` registry lock across the fold so a connection moving
    /// from live to closed (see `connection_loop`) is counted exactly
    /// once — lock order is always `live` → `closed`/per-connection.
    fn net_stats(&self) -> NetStats {
        let live = self.live.lock().unwrap();
        let mut total = self.closed.lock().unwrap().clone();
        for cs in live.iter() {
            let snap = cs.lock().unwrap().clone();
            total.merge(&snap);
        }
        drop(live);
        total.connections_opened = self.opened.load(Ordering::Relaxed);
        total.connections_closed = self.closed_count.load(Ordering::Relaxed);
        total
    }
}

/// The TCP server: an accept loop plus two threads per live connection,
/// all fronting one shared [`Coordinator`] worker pool.
pub struct NetServer {
    addr: SocketAddr,
    state: Arc<State>,
    accept: Option<std::thread::JoinHandle<()>>,
    conn_threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral loopback
    /// port) and start accepting connections against `coord`. The
    /// coordinator is shared — in-process callers may keep submitting
    /// through their own `Arc` clone, and served results stay
    /// bit-identical to theirs.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        coord: Arc<Coordinator>,
        mut cfg: ServerConfig,
    ) -> std::io::Result<NetServer> {
        if cfg.max_inflight == 0 {
            cfg.max_inflight = ServerConfig::DEFAULT_MAX_INFLIGHT;
        }
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(State {
            coord,
            cfg,
            opened: AtomicU64::new(0),
            closed_count: AtomicU64::new(0),
            closed: Mutex::new(NetStats::default()),
            live: Mutex::new(Vec::new()),
            conns: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
        });
        let conn_threads = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let state = state.clone();
            let threads = conn_threads.clone();
            std::thread::Builder::new()
                .name("axsys-net-accept".into())
                .spawn(move || accept_loop(listener, state, threads))
                .expect("spawn accept thread")
        };
        Ok(NetServer { addr, state, accept: Some(accept), conn_threads })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Fleet network statistics (closed + live connections folded).
    pub fn stats(&self) -> NetStats {
        self.state.net_stats()
    }

    /// Graceful drain: stop accepting, half-close every connection's
    /// read side so no new requests are admitted, let already-admitted
    /// requests complete on the pool and their replies flush, then join
    /// every thread. A connection whose client has stopped reading is
    /// abandoned once its write stalls past
    /// [`ServerConfig::write_timeout`], which bounds the drain. Also
    /// runs on `Drop`.
    pub fn shutdown(mut self) {
        self.teardown();
    }

    fn teardown(&mut self) {
        if self.state.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock accept() with a throwaway connection to ourselves;
        // unspecified bind addresses are woken via the matching-family
        // loopback (both tried — v6-only stacks refuse the v4 one)
        let mut wakes = vec![self.addr];
        if self.addr.ip().is_unspecified() {
            let mut v4 = self.addr;
            v4.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
            let mut v6 = self.addr;
            v6.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST));
            wakes = vec![v4, v6];
        }
        let woke = wakes.iter().any(|w| {
            TcpStream::connect_timeout(w, Duration::from_secs(1)).is_ok()
        });
        if let Some(h) = self.accept.take() {
            if woke {
                let _ = h.join();
            }
            // no self-connect succeeded (exotic bind address): detach
            // the accept thread rather than hang shutdown on its join —
            // it exits with the process and holds no request state
        }
        // half-close read sides: readers see EOF, writers drain + flush
        for (_, c) in self.state.conns.lock().unwrap().iter() {
            let _ = c.shutdown(Shutdown::Read);
        }
        let threads: Vec<_> =
            self.conn_threads.lock().unwrap().drain(..).collect();
        for h in threads {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.teardown();
    }
}

fn accept_loop(listener: TcpListener, state: Arc<State>,
               threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>) {
    for stream in listener.incoming() {
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // accept() can fail persistently (e.g. fd exhaustion);
                // back off instead of spinning a core until it clears
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        let _ = stream.set_nodelay(true);
        let id = state.opened.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            state.conns.lock().unwrap().push((id, clone));
        }
        let st = state.clone();
        let h = std::thread::Builder::new()
            .name("axsys-net-conn".into())
            .spawn(move || connection_loop(stream, st, id))
            .expect("spawn connection thread");
        // reap handles of connections that already finished (their
        // threads have exited; dropping the handle just detaches) so a
        // long-running server holds state only for live connections
        let mut t = threads.lock().unwrap();
        t.retain(|h| !h.is_finished());
        t.push(h);
    }
}

/// A reply slot, enqueued by the reader in request order. `Ready`
/// carries an immediately-known reply (typed errors); the others are
/// resolved by the writer thread so the reader can keep admitting
/// pipelined requests while earlier ones execute.
enum Pending {
    Ready(Frame, Instant),
    Gemm { id: u64, t0: Instant },
    App { app: AppKind, k: u32, img: Image, t0: Instant },
    Stats(Instant),
}

fn connection_loop(stream: TcpStream, state: Arc<State>, id: u64) {
    let cs: Arc<Mutex<NetStats>> = Arc::new(Mutex::new(NetStats::default()));
    state.live.lock().unwrap().push(cs.clone());
    let finish = |state: &Arc<State>, cs: &Arc<Mutex<NetStats>>| {
        // move this connection's block from live to closed atomically
        // w.r.t. `State::net_stats` (same `live` → `closed` lock order)
        let mut live = state.live.lock().unwrap();
        let snap = cs.lock().unwrap().clone();
        state.closed.lock().unwrap().merge(&snap);
        live.retain(|e| !Arc::ptr_eq(e, cs));
        drop(live);
        // release this connection's dup'd drain handle (fd) too
        state.conns.lock().unwrap().retain(|(cid, _)| *cid != id);
        state.closed_count.fetch_add(1, Ordering::Relaxed);
    };
    let wstream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            finish(&state, &cs);
            return;
        }
    };
    // bound writer stalls on clients that stop reading (see
    // ServerConfig::write_timeout) — a timed-out write errors the
    // writer out, which also bounds the shutdown drain
    let _ = wstream.set_write_timeout(state.cfg.write_timeout);
    let (tx, rx) = sync_channel::<Pending>(state.cfg.max_inflight.max(1));
    let writer = {
        let st = state.clone();
        let wcs = cs.clone();
        std::thread::Builder::new()
            .name("axsys-net-write".into())
            .spawn(move || writer_loop(wstream, st, wcs, rx))
            .expect("spawn writer thread")
    };
    reader_loop(stream, &state, &cs, tx);
    let _ = writer.join();
    finish(&state, &cs);
}

fn reader_loop(stream: TcpStream, state: &Arc<State>,
               cs: &Arc<Mutex<NetStats>>, tx: SyncSender<Pending>) {
    let mut br = BufReader::new(stream);
    let mut scratch = Vec::new();
    loop {
        let frame = match proto::read_frame(&mut br, &mut scratch) {
            Ok(Some(f)) => f,
            Ok(None) => break,               // clean EOF (or drain half-close)
            Err(ProtoError::Io(_)) => break, // connection died
            Err(e) => {
                // framing is unrecoverable: answer with a typed error,
                // then close this connection (others are unaffected)
                let _ = tx.send(Pending::Ready(
                    Frame::Error(WireError {
                        code: err_code_for(&e),
                        msg: e.to_string(),
                    }),
                    Instant::now(),
                ));
                break;
            }
        };
        {
            let mut s = cs.lock().unwrap();
            s.frames_in += 1;
            s.bytes_in += (scratch.len() + 4) as u64;
        }
        let t0 = Instant::now();
        let pending = match frame {
            Frame::GemmReq(req) => {
                cs.lock().unwrap().gemm_requests += 1;
                admit_gemm(state, req, t0)
            }
            Frame::AppReq(req) => {
                cs.lock().unwrap().app_requests += 1;
                admit_app(state, req, t0)
            }
            Frame::StatsReq => {
                cs.lock().unwrap().stats_requests += 1;
                Pending::Stats(t0)
            }
            _ => reply_err(
                ErrCode::Unsupported,
                "server accepts gemm/app/stats request frames only",
                t0,
            ),
        };
        // the admission gate: blocks when `max_inflight` replies are
        // already pending, which stops socket reads (backpressure, not
        // drops — the reply order per connection is never disturbed)
        if tx.send(pending).is_err() {
            break; // writer gone (socket error)
        }
    }
    // dropping tx lets the writer drain every admitted reply and exit
}

fn reply_err(code: ErrCode, msg: &str, t0: Instant) -> Pending {
    Pending::Ready(Frame::Error(WireError { code, msg: msg.to_string() }), t0)
}

fn err_code_for(e: &ProtoError) -> ErrCode {
    match e {
        ProtoError::Oversized { .. } => ErrCode::TooLarge,
        _ => ErrCode::Malformed,
    }
}

/// Highest approximation level the serving surface accepts (the PE
/// models are defined for k through the accumulator width; hostile
/// values would poison worker threads).
const MAX_WIRE_K: u32 = 16;

fn admit_gemm(state: &Arc<State>, req: proto::GemmReq, t0: Instant)
              -> Pending {
    let (m, kk, nn) = (req.m as usize, req.kk as usize, req.nn as usize);
    if m == 0 || kk == 0 || nn == 0 {
        return reply_err(ErrCode::Malformed,
                         "gemm dimensions must be positive", t0);
    }
    if req.k > MAX_WIRE_K {
        return reply_err(ErrCode::Unsupported,
                         "approximation level k exceeds the supported range",
                         t0);
    }
    // the decoder bounds the operands (m*kk, kk*nn), but the *result*
    // is allocated pool-side as m x nn — bound it here too, or a tiny
    // frame (e.g. kk = 1 with huge m, nn) could demand a terabyte-scale
    // allocation and an unencodable reply
    if (m as u64) * (nn as u64) > proto::MAX_GEMM_ELEMS as u64 {
        return reply_err(ErrCode::TooLarge,
                         "result matrix m*nn exceeds the wire element cap",
                         t0);
    }
    // operand lengths were validated against m/kk/nn by the decoder;
    // submit() fans the tiles across the shared pool without blocking
    // this thread on execution (only on pool-queue backpressure)
    let id = state.coord.submit(GemmRequest {
        a: req.a,
        b: req.b,
        m,
        kk,
        nn,
        k: req.k,
    });
    Pending::Gemm { id, t0 }
}

fn admit_app(state: &Arc<State>, req: proto::AppReq, t0: Instant) -> Pending {
    if req.k > MAX_WIRE_K {
        return reply_err(ErrCode::Unsupported,
                         "approximation level k exceeds the supported range",
                         t0);
    }
    let img = match decode_pgm(&req.pgm) {
        Ok(i) => i,
        Err(e) => {
            return reply_err(ErrCode::BadImage,
                             &format!("bad PGM payload: {e}"), t0);
        }
    };
    match req.app {
        AppKind::Dct if img.h % 8 != 0 || img.w % 8 != 0 => {
            reply_err(ErrCode::BadImage,
                      "dct needs multiple-of-8 image dimensions", t0)
        }
        AppKind::Edge if img.h < 3 || img.w < 3 => {
            reply_err(ErrCode::BadImage,
                      "edge needs an image of at least 3x3", t0)
        }
        AppKind::Bdcn if state.cfg.bdcn.is_none() => {
            reply_err(ErrCode::Unsupported,
                      "bdcn weights are not loaded on this server", t0)
        }
        app => Pending::App { app, k: req.k, img, t0 },
    }
}

fn wire_stats(s: &ServiceStats, n: &NetStats) -> WireStats {
    WireStats {
        requests: s.requests,
        tiles: s.tiles,
        macs: s.sim_macs,
        energy_fj: s.energy_fj,
        metered_macs: s.metered_macs,
        latency_p50_us: s.latency_percentile(0.50),
        latency_p90_us: s.latency_percentile(0.90),
        latency_p99_us: s.latency_percentile(0.99),
        mean_latency_us: s.mean_latency_us(),
        connections: n.connections_opened,
        frames_in: n.frames_in,
        frames_out: n.frames_out,
        bytes_in: n.bytes_in,
        bytes_out: n.bytes_out,
        net_p50_us: n.latency_percentile(0.50),
        net_p90_us: n.latency_percentile(0.90),
        net_p99_us: n.latency_percentile(0.99),
    }
}

/// Resolve one pending slot into its reply frame. GEMMs block on the
/// pool's completion signal; app requests run the full served pipeline
/// here (their GEMM stages fan out across the pool while the reader
/// keeps admitting later requests).
fn resolve(state: &State, p: Pending) -> (Frame, Instant) {
    match p {
        Pending::Ready(f, t0) => (f, t0),
        Pending::Gemm { id, t0 } => {
            let resp = state.coord.wait(id);
            (Frame::GemmResp(GemmResp {
                m: resp.m as u32,
                nn: resp.nn as u32,
                latency_us: resp.latency_us,
                tiles: resp.tiles,
                macs: resp.sa_stats.macs,
                energy_fj: resp.sa_stats.energy_fj,
                metered_macs: resp.sa_stats.metered_macs,
                out: resp.out,
            }), t0)
        }
        Pending::App { app, k, img, t0 } => {
            let r = match app {
                AppKind::Bdcn => {
                    let blocks =
                        state.cfg.bdcn.clone().expect("checked at admission");
                    state.coord.serve_bdcn(&blocks, &img, k)
                }
                _ => state.coord.call_app(app, &img, k)
                    .expect("weight-free app"),
            };
            (Frame::AppResp(AppResp {
                app,
                psnr_db: r.psnr_db,
                latency_us: r.latency_us,
                gemm_requests: r.gemm_requests,
                energy_fj: r.sa_stats.energy_fj,
                macs: r.sa_stats.macs,
                h: r.out.h as u32,
                w: r.out.w as u32,
                pixels: r.out.data,
            }), t0)
        }
        Pending::Stats(t0) => {
            // snapshot both stat blocks under their own short locks,
            // release, then encode — the coordinator's stats lock is
            // never held across frame encoding
            let s = state.coord.stats_snapshot();
            let n = state.net_stats();
            (Frame::StatsResp(wire_stats(&s, &n)), t0)
        }
    }
}

fn writer_loop(stream: TcpStream, state: Arc<State>,
               cs: Arc<Mutex<NetStats>>, rx: Receiver<Pending>) {
    let mut bw = BufWriter::new(stream);
    let mut scratch = Vec::new();
    loop {
        // batch-friendly: only flush when no reply is immediately ready
        let item = match rx.try_recv() {
            Ok(i) => i,
            Err(TryRecvError::Empty) => {
                if bw.flush().is_err() {
                    break;
                }
                match rx.recv() {
                    Ok(i) => i,
                    Err(_) => break, // reader closed the queue: drained
                }
            }
            Err(TryRecvError::Disconnected) => break,
        };
        // flush fully-encoded earlier replies before blocking in
        // resolve (pool wait / app execution): a pipelined client must
        // receive reply N as soon as it exists, not when N+1 finishes
        if !matches!(&item, Pending::Ready(..)) && bw.flush().is_err() {
            break;
        }
        let (frame, t0) = resolve(&state, item);
        match proto::write_frame(&mut bw, &frame, &mut scratch) {
            Ok(n) => {
                let us = t0.elapsed().as_secs_f64() * 1e6;
                let mut s = cs.lock().unwrap();
                s.frames_out += 1;
                s.bytes_out += n as u64;
                s.record_latency(us);
                if matches!(frame, Frame::Error(_)) {
                    s.error_replies += 1;
                }
            }
            Err(_) => break,
        }
    }
    let _ = bw.flush();
}
