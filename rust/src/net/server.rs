//! Readiness-driven event-loop TCP server fronting a running
//! [`Coordinator`].
//!
//! The acceptor thread round-robins accepted connections across a fixed
//! set of **shards**; each shard thread runs a `poll(2)`-based event
//! loop (the thin FFI binding lives in `net/sys.rs`) over its
//! nonblocking sockets, with a
//! per-connection state machine for frame reassembly (a growable read
//! buffer parsed by [`proto::try_decode`]), in-order reply pipelining
//! (a `VecDeque` of reply slots, encoded strictly in admission order)
//! and the reusable encode scratch shared across the shard — the
//! steady-state hot path allocates no per-request buffers.
//!
//! Requests are executed by a fixed **resolver** pool: shards never
//! block, so a slow GEMM (pool-queue backpressure, app pipelines) on
//! one connection cannot stall the thousands of others on its shard.
//! Resolvers run the coordinator call, catch handler panics into typed
//! `Internal` error replies, and post completions back to the owning
//! shard through its inbox + wake socket.
//!
//! The admission gate is **readiness backoff**: while a connection has
//! [`ServerConfig::max_inflight`] replies pending, its socket is
//! dropped from the shard's `POLLIN` set and buffered bytes stay
//! unparsed — the kernel's receive window fills and the client's writes
//! stall. Backpressure, never drops, and reply order per connection is
//! never disturbed, exactly as in the thread-per-connection
//! predecessor.
//!
//! [`NetServer::shutdown`] drains gracefully: the listener stops
//! accepting, every shard takes one final read sweep (everything the
//! clients sent before the drain is still admitted), stops reading,
//! lets admitted requests complete on the pool, flushes the replies and
//! reaps its connections. Statistics are kept **per connection** and
//! folded per shard into fleet totals ([`NetServer::stats`], the stats
//! frame) on demand, so no hot path ever contends on one global lock —
//! and every stats lock recovers from poisoning, so one panicking
//! handler cannot take fleet observability down with it.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::apps::bdcn::Block;
use crate::apps::image::decode_pgm;
use crate::apps::image::Image;
use crate::coordinator::{AppKind, Coordinator, GemmRequest, LatencyRing,
                         ServiceStats};
use crate::zoo::{AccuracySlo, RouteError};

use super::proto::{self, AppResp, ErrCode, Frame, GemmResp, ProtoError,
                   WireError, WireStats};
use super::sys::{self, PollFd, POLLERR, POLLHUP, POLLIN, POLLNVAL, POLLOUT};

/// Lock a stats mutex, recovering from poisoning: these blocks hold
/// fold-only counters, so a panic mid-update leaves at worst one sample
/// off — strictly better than poisoning fleet stats for every other
/// connection (the pre-event-loop server's failure mode).
fn lk<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Per-connection and fleet-level network counters. The latency ring is
/// the same sampler [`ServiceStats`] uses
/// ([`LatencyRing`]), recording server-side
/// admission-to-reply-written time per request.
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    /// TCP connections accepted (fleet level only).
    pub connections_opened: u64,
    /// Connections fully torn down (fleet level only).
    pub connections_closed: u64,
    /// Frames read off the socket.
    pub frames_in: u64,
    /// Frames written back.
    pub frames_out: u64,
    /// Bytes read (length prefixes included).
    pub bytes_in: u64,
    /// Bytes written.
    pub bytes_out: u64,
    /// GEMM request frames seen (valid or not).
    pub gemm_requests: u64,
    /// Application request frames seen.
    pub app_requests: u64,
    /// Stats request frames seen.
    pub stats_requests: u64,
    /// Request frames that carried an accuracy SLO (valid or not).
    pub slo_requests: u64,
    /// [`ErrCode::SloUnsatisfiable`] replies sent (the SLO named an
    /// accuracy no registered design point provides — the request was
    /// refused, never silently served exact).
    pub slo_rejections: u64,
    /// Typed error frames sent.
    pub error_replies: u64,
    latency: LatencyRing,
}

impl NetStats {
    /// Server-side request latency percentile (admission → reply
    /// written) over the retained ring window.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency.percentile(p)
    }

    fn record_latency(&mut self, us: f64) {
        self.latency.record(us);
    }

    /// Fold another stats block into this one (fleet totals = closed
    /// connections + every live connection's block).
    pub fn merge(&mut self, other: &NetStats) {
        self.connections_opened += other.connections_opened;
        self.connections_closed += other.connections_closed;
        self.frames_in += other.frames_in;
        self.frames_out += other.frames_out;
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
        self.gemm_requests += other.gemm_requests;
        self.app_requests += other.app_requests;
        self.stats_requests += other.stats_requests;
        self.slo_requests += other.slo_requests;
        self.slo_rejections += other.slo_rejections;
        self.error_replies += other.error_replies;
        self.latency.merge(&other.latency);
    }
}

/// Static configuration of one [`NetServer`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Admission gate: max replies pending per connection before the
    /// shard stops polling the socket for read (0 selects
    /// [`Self::DEFAULT_MAX_INFLIGHT`]). This bounds both memory and
    /// pool queue pressure per client; excess requests wait in the
    /// kernel's socket buffers on the *client's* side.
    pub max_inflight: usize,
    /// Write-stall timeout per connection (`None` = never time out).
    /// A client that stops *reading* its replies eventually fills its
    /// receive window; once a connection's pending output makes no
    /// progress for this long it is abandoned — which also bounds how
    /// long [`NetServer::shutdown`]'s drain can wait on it.
    pub write_timeout: Option<Duration>,
    /// Trained BDCN weights, if this server should serve `bdcn`
    /// requests (without them, `bdcn` gets a typed `Unsupported` reply).
    pub bdcn: Option<Arc<Vec<Block>>>,
    /// Event-loop shards (acceptor round-robins connections across
    /// them; 0 = auto-size from the host's available parallelism).
    pub shards: usize,
    /// Resolver threads executing admitted requests on the pool
    /// (0 = auto-size from the shard count).
    pub resolvers: usize,
}

impl ServerConfig {
    /// Default admission-gate depth.
    pub const DEFAULT_MAX_INFLIGHT: usize = 32;
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_inflight: Self::DEFAULT_MAX_INFLIGHT,
            write_timeout: Some(Duration::from_secs(30)),
            bdcn: None,
            shards: 0,
            resolvers: 0,
        }
    }
}

/// Bytes of unflushed reply data per connection above which the shard
/// stops encoding further replies for it (they stay queued in their
/// slots) until the socket drains.
const WRITE_HIGH_WATER: usize = 256 * 1024;

/// Shard poll timeout: bounds how stale a write-stall check can be and
/// how long a stopped shard waits before re-checking its exit
/// condition. Completions and new connections cut it short via the wake
/// socket.
const POLL_TIMEOUT_MS: i32 = 200;

/// Read chunk size per readiness cycle.
const READ_CHUNK: usize = 64 * 1024;

/// A message posted to a shard's inbox (drained on every wake).
enum Msg {
    /// A freshly accepted connection to adopt.
    Conn(TcpStream),
    /// A resolver finished the request `(conn, seq)`.
    Done { conn: u64, seq: u64, frame: Frame },
}

/// One shard: inbox + wake channel + its slice of the sharded stats.
struct Shard {
    inbox: Mutex<Vec<Msg>>,
    /// Write end of the shard's loopback wake pair (nonblocking: a
    /// full pipe means a wake is already pending).
    wake_tx: TcpStream,
    /// Live per-connection stats blocks owned by this shard.
    live: Mutex<Vec<Arc<Mutex<NetStats>>>>,
    /// Folded stats of this shard's closed connections.
    closed: Mutex<NetStats>,
}

impl Shard {
    fn post(&self, msg: Msg) {
        lk(&self.inbox).push(msg);
        self.wake();
    }

    fn wake(&self) {
        // one pending byte is enough; WouldBlock = already signalled
        let _ = (&self.wake_tx).write(&[1u8]);
    }
}

/// A unit of work handed to the resolver pool.
enum Work {
    Gemm(GemmRequest),
    App { app: AppKind, k: u32, img: Image, slo: Option<AccuracySlo> },
    Stats,
}

/// A resolver job: which shard/connection/slot the reply belongs to.
struct Job {
    shard: usize,
    conn: u64,
    seq: u64,
    work: Work,
}

struct State {
    coord: Arc<Coordinator>,
    cfg: ServerConfig,
    opened: AtomicU64,
    closed_count: AtomicU64,
    shards: Vec<Shard>,
    stop: AtomicBool,
}

impl State {
    /// Fleet totals: every shard's closed-connection accumulator + live
    /// blocks. Holds each shard's `live` registry lock across its fold
    /// so a connection moving from live to closed (see `reap`) is
    /// counted exactly once — lock order is always `live` →
    /// `closed`/per-connection, never nested.
    fn net_stats(&self) -> NetStats {
        let mut total = NetStats::default();
        for shard in &self.shards {
            let live = lk(&shard.live);
            total.merge(&lk(&shard.closed).clone());
            for cs in live.iter() {
                let snap = lk(cs).clone();
                total.merge(&snap);
            }
        }
        total.connections_opened = self.opened.load(Ordering::Relaxed);
        total.connections_closed = self.closed_count.load(Ordering::Relaxed);
        total
    }
}

/// The TCP server: one acceptor, N shard event loops, M resolver
/// threads, all fronting one shared [`Coordinator`] worker pool.
pub struct NetServer {
    addr: SocketAddr,
    state: Arc<State>,
    accept: Option<std::thread::JoinHandle<()>>,
    shard_threads: Vec<std::thread::JoinHandle<()>>,
    resolver_threads: Vec<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral loopback
    /// port) and start accepting connections against `coord`. The
    /// coordinator is shared — in-process callers may keep submitting
    /// through their own `Arc` clone, and served results stay
    /// bit-identical to theirs.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        coord: Arc<Coordinator>,
        mut cfg: ServerConfig,
    ) -> std::io::Result<NetServer> {
        if cfg.max_inflight == 0 {
            cfg.max_inflight = ServerConfig::DEFAULT_MAX_INFLIGHT;
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if cfg.shards == 0 {
            cfg.shards = cores.clamp(1, 4);
        }
        if cfg.resolvers == 0 {
            cfg.resolvers = (cfg.shards * 2).max(4);
        }
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let mut shards = Vec::with_capacity(cfg.shards);
        let mut wake_rxs = Vec::with_capacity(cfg.shards);
        for _ in 0..cfg.shards {
            let (tx, rx) = wake_pair()?;
            shards.push(Shard {
                inbox: Mutex::new(Vec::new()),
                wake_tx: tx,
                live: Mutex::new(Vec::new()),
                closed: Mutex::new(NetStats::default()),
            });
            wake_rxs.push(rx);
        }
        let state = Arc::new(State {
            coord,
            cfg,
            opened: AtomicU64::new(0),
            closed_count: AtomicU64::new(0),
            shards,
            stop: AtomicBool::new(false),
        });
        let (jobs_tx, jobs_rx) = channel::<Job>();
        let jobs_rx = Arc::new(Mutex::new(jobs_rx));
        let mut resolver_threads = Vec::new();
        for ri in 0..state.cfg.resolvers {
            let st = state.clone();
            let rx = jobs_rx.clone();
            resolver_threads.push(std::thread::Builder::new()
                .name(format!("axsys-net-resolve-{ri}"))
                .spawn(move || resolver_loop(st, rx))
                .expect("spawn resolver thread"));
        }
        let mut shard_threads = Vec::new();
        for (si, wake_rx) in wake_rxs.into_iter().enumerate() {
            let st = state.clone();
            let jobs = jobs_tx.clone();
            shard_threads.push(std::thread::Builder::new()
                .name(format!("axsys-net-shard-{si}"))
                .spawn(move || shard_loop(st, si, wake_rx, jobs))
                .expect("spawn shard thread"));
        }
        // the shard threads now hold the only job senders: when the
        // last shard exits at teardown, the resolvers see a closed
        // channel and drain out
        drop(jobs_tx);
        let accept = {
            let st = state.clone();
            std::thread::Builder::new()
                .name("axsys-net-accept".into())
                .spawn(move || accept_loop(listener, st))
                .expect("spawn accept thread")
        };
        Ok(NetServer {
            addr,
            state,
            accept: Some(accept),
            shard_threads,
            resolver_threads,
        })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Fleet network statistics (closed + live connections folded
    /// across every shard).
    pub fn stats(&self) -> NetStats {
        self.state.net_stats()
    }

    /// Graceful drain: stop accepting, let every shard sweep up the
    /// bytes its clients already sent and stop reading, let
    /// already-admitted requests complete on the pool and their replies
    /// flush, then join every thread. A connection whose client has
    /// stopped reading is abandoned once its pending output stalls past
    /// [`ServerConfig::write_timeout`], which bounds the drain. Also
    /// runs on `Drop`.
    pub fn shutdown(mut self) {
        self.teardown();
    }

    fn teardown(&mut self) {
        if self.state.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock accept() with a throwaway connection to ourselves;
        // unspecified bind addresses are woken via the matching-family
        // loopback (both tried — v6-only stacks refuse the v4 one)
        let mut wakes = vec![self.addr];
        if self.addr.ip().is_unspecified() {
            let mut v4 = self.addr;
            v4.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
            let mut v6 = self.addr;
            v6.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST));
            wakes = vec![v4, v6];
        }
        let woke = wakes.iter().any(|w| {
            TcpStream::connect_timeout(w, Duration::from_secs(1)).is_ok()
        });
        if let Some(h) = self.accept.take() {
            if woke {
                let _ = h.join();
            }
            // no self-connect succeeded (exotic bind address): detach
            // the accept thread rather than hang shutdown on its join —
            // it exits with the process and holds no request state
        }
        for shard in &self.state.shards {
            shard.wake();
        }
        for h in self.shard_threads.drain(..) {
            let _ = h.join();
        }
        // all job senders are gone now: resolvers drain and exit
        for h in self.resolver_threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Build one loopback wake pair: any thread pokes the write end, the
/// owning shard holds the nonblocking read end in its poll set.
fn wake_pair() -> std::io::Result<(TcpStream, TcpStream)> {
    let lis = TcpListener::bind((std::net::Ipv4Addr::LOCALHOST, 0))?;
    let tx = TcpStream::connect(lis.local_addr()?)?;
    let (rx, _) = lis.accept()?;
    tx.set_nodelay(true)?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((tx, rx))
}

fn accept_loop(listener: TcpListener, state: Arc<State>) {
    let nshards = state.shards.len() as u64;
    for stream in listener.incoming() {
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // accept() can fail persistently (e.g. fd exhaustion);
                // back off instead of spinning a core until it clears
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        let _ = stream.set_nodelay(true);
        if stream.set_nonblocking(true).is_err() {
            continue;
        }
        let id = state.opened.fetch_add(1, Ordering::Relaxed);
        state.shards[(id % nshards) as usize].post(Msg::Conn(stream));
    }
}

/// One reply slot, in admission order. `reply` is filled immediately
/// for admission errors and by a resolver completion otherwise; the
/// shard encodes slots strictly front-to-back, so pipelined clients can
/// match replies to requests positionally.
struct Slot {
    seq: u64,
    t0: Instant,
    reply: Option<Frame>,
}

/// Per-connection state machine of the event loop. The buffers are the
/// zero-allocation story: `rbuf`/`wbuf` grow to their steady-state
/// high-water mark once and are reused for every subsequent frame.
struct Conn {
    stream: TcpStream,
    stats: Arc<Mutex<NetStats>>,
    /// Unparsed inbound bytes (frame reassembly buffer).
    rbuf: Vec<u8>,
    /// Encoded-but-unflushed outbound bytes.
    wbuf: Vec<u8>,
    /// Flushed prefix of `wbuf`.
    wpos: usize,
    /// In-order reply pipeline.
    pending: VecDeque<Slot>,
    next_seq: u64,
    /// No further bytes will be read (EOF, framing error, or drain).
    read_closed: bool,
    /// Tear down now, discarding anything unflushed.
    dead: bool,
    /// Last instant the socket accepted outbound bytes (write-stall
    /// clock, armed only while `wbuf` is nonempty).
    last_progress: Instant,
}

impl Conn {
    fn new(stream: TcpStream, stats: Arc<Mutex<NetStats>>) -> Conn {
        Conn {
            stream,
            stats,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            next_seq: 0,
            read_closed: false,
            dead: false,
            last_progress: Instant::now(),
        }
    }

    fn unflushed(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Drained and flushed: nothing left to answer or write.
    fn finished(&self) -> bool {
        self.dead
            || (self.read_closed
                && self.pending.is_empty()
                && self.unflushed() == 0)
    }
}

fn shard_loop(state: Arc<State>, si: usize, wake_rx: TcpStream,
              jobs: Sender<Job>) {
    let shard = &state.shards[si];
    let max_inflight = state.cfg.max_inflight;
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_conn: u64 = 0;
    let mut scratch = Vec::new();
    let mut chunk = vec![0u8; READ_CHUNK];
    let mut pollfds: Vec<PollFd> = Vec::new();
    let mut poll_ids: Vec<u64> = Vec::new();
    loop {
        let stopping = state.stop.load(Ordering::SeqCst);
        // 1. inbox: adopt new connections, land resolver completions
        for msg in lk(&shard.inbox).drain(..) {
            match msg {
                Msg::Conn(stream) => {
                    let cs = Arc::new(Mutex::new(NetStats::default()));
                    lk(&shard.live).push(cs.clone());
                    let id = next_conn;
                    next_conn += 1;
                    conns.insert(id, Conn::new(stream, cs));
                }
                Msg::Done { conn, seq, frame } => {
                    // the connection may have died while the request
                    // executed; a completion for a reaped conn is noise
                    if let Some(c) = conns.get_mut(&conn) {
                        if let Some(slot) =
                            c.pending.iter_mut().find(|s| s.seq == seq)
                        {
                            slot.reply = Some(frame);
                        }
                    }
                }
            }
        }
        // 2. drain entry: one final read sweep per connection picks up
        // everything its client sent before shutdown, then the read
        // side closes (idempotent, so connections adopted mid-drain —
        // the accept race — are swept on their first iteration too)
        if stopping {
            for c in conns.values_mut() {
                if !c.read_closed && !c.dead {
                    read_some(c, &mut chunk);
                    c.read_closed = true;
                }
            }
        }
        // 3. pump every connection: parse → admit → encode → flush
        for (&id, c) in conns.iter_mut() {
            pump(&state, si, id, c, &jobs, &mut scratch, stopping,
                 max_inflight);
        }
        // 4. reap finished connections (stats move live → closed)
        let finished: Vec<u64> = conns.iter()
            .filter(|(_, c)| c.finished())
            .map(|(&id, _)| id)
            .collect();
        for id in finished {
            let c = conns.remove(&id).expect("reaped conn");
            reap(&state, shard, c);
        }
        if stopping && conns.is_empty() {
            return;
        }
        // 5. build the poll set: wake channel + per-connection interest.
        // The admission gate lives here — a connection at its inflight
        // budget contributes no POLLIN, so the shard simply stops
        // reading it until replies retire (readiness backoff).
        pollfds.clear();
        poll_ids.clear();
        pollfds.push(PollFd::new(raw_fd(&wake_rx), POLLIN));
        poll_ids.push(u64::MAX);
        for (&id, c) in conns.iter() {
            let mut ev = 0i16;
            if !c.read_closed && !c.dead && c.pending.len() < max_inflight {
                ev |= POLLIN;
            }
            if c.unflushed() > 0 {
                ev |= POLLOUT;
            }
            if ev != 0 {
                pollfds.push(PollFd::new(raw_fd(&c.stream), ev));
                poll_ids.push(id);
            }
        }
        if sys::poll_fds(&mut pollfds, POLL_TIMEOUT_MS).is_err() {
            // only reachable on EBADF-class bugs; retire the shard's
            // write-stall clock checks still run next iteration
            std::thread::sleep(Duration::from_millis(10));
        }
        // 6. readiness: drain the wake channel, read readable sockets
        // (writes are flushed by the next pump pass)
        if pollfds[0].revents & POLLIN != 0 {
            let mut sink = [0u8; 64];
            while let Ok(n) = (&wake_rx).read(&mut sink) {
                if n == 0 || n < sink.len() {
                    break;
                }
            }
        }
        for (pf, &id) in pollfds.iter().zip(&poll_ids).skip(1) {
            let Some(c) = conns.get_mut(&id) else { continue };
            if pf.revents & POLLNVAL != 0 {
                c.dead = true;
                continue;
            }
            if pf.revents & (POLLIN | POLLHUP | POLLERR) != 0 {
                read_some(c, &mut chunk);
            }
        }
        // 7. write-stall clock: a client that stopped reading holds
        // unflushed replies forever — abandon it after the timeout
        if let Some(t) = state.cfg.write_timeout {
            for c in conns.values_mut() {
                if c.unflushed() > 0 && c.last_progress.elapsed() > t {
                    c.dead = true;
                }
            }
        }
    }
}

fn raw_fd(s: &TcpStream) -> std::os::unix::io::RawFd {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

/// Nonblocking read sweep: append everything currently available to
/// `rbuf`. EOF half-closes the read side; hard errors kill the conn.
fn read_some(c: &mut Conn, chunk: &mut [u8]) {
    loop {
        match (&c.stream).read(chunk) {
            Ok(0) => {
                c.read_closed = true;
                break;
            }
            Ok(n) => {
                c.rbuf.extend_from_slice(&chunk[..n]);
                if n < chunk.len() {
                    break; // kernel buffer drained
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                break;
            }
        }
    }
}

/// One pump pass: parse admitted frames out of `rbuf`, encode every
/// front-of-queue reply that is ready, flush, repeat until no progress
/// (encoding retires slots, which frees admission budget, which may
/// unlock more parsing — the loop runs that chain to quiescence).
#[allow(clippy::too_many_arguments)]
fn pump(state: &Arc<State>, si: usize, id: u64, c: &mut Conn,
        jobs: &Sender<Job>, scratch: &mut Vec<u8>, stopping: bool,
        max_inflight: usize) {
    loop {
        let parsed = parse_frames(state, si, id, c, jobs, stopping,
                                  max_inflight);
        let encoded = encode_ready(c, scratch);
        if !(parsed || encoded) {
            break;
        }
    }
    flush(c);
}

/// Parse complete frames from the reassembly buffer while the inflight
/// budget allows (the drain ignores the budget: everything already
/// received is answered). Returns true when at least one frame was
/// admitted.
fn parse_frames(state: &Arc<State>, si: usize, id: u64, c: &mut Conn,
                jobs: &Sender<Job>, stopping: bool, max_inflight: usize)
                -> bool {
    let mut off = 0;
    let mut any = false;
    loop {
        if c.dead || (!stopping && c.pending.len() >= max_inflight) {
            break;
        }
        match proto::try_decode(&c.rbuf[off..]) {
            Ok(Some((frame, used))) => {
                {
                    let mut s = lk(&c.stats);
                    s.frames_in += 1;
                    s.bytes_in += used as u64;
                }
                off += used;
                any = true;
                admit(state, si, id, c, jobs, frame);
            }
            Ok(None) => break,
            Err(e) => {
                // framing is unrecoverable: answer with a typed error,
                // then close this connection (others are unaffected)
                let seq = c.next_seq;
                c.next_seq += 1;
                c.pending.push_back(Slot {
                    seq,
                    t0: Instant::now(),
                    reply: Some(Frame::Error(WireError {
                        code: err_code_for(&e),
                        msg: e.to_string(),
                    })),
                });
                c.read_closed = true;
                off = c.rbuf.len();
                any = true;
                break;
            }
        }
    }
    c.rbuf.drain(..off);
    any
}

/// Validate one request frame and enqueue its reply slot: admission
/// failures answer immediately, valid work ships to the resolver pool.
fn admit(state: &Arc<State>, si: usize, id: u64, c: &mut Conn,
         jobs: &Sender<Job>, frame: Frame) {
    let t0 = Instant::now();
    let seq = c.next_seq;
    c.next_seq += 1;
    let admitted = match frame {
        Frame::GemmReq(req) => {
            let mut s = lk(&c.stats);
            s.gemm_requests += 1;
            if req.slo.is_some() {
                s.slo_requests += 1;
            }
            drop(s);
            admit_gemm(req)
        }
        Frame::AppReq(req) => {
            let mut s = lk(&c.stats);
            s.app_requests += 1;
            if req.slo.is_some() {
                s.slo_requests += 1;
            }
            drop(s);
            admit_app(state, req)
        }
        Frame::StatsReq => {
            lk(&c.stats).stats_requests += 1;
            Ok(Work::Stats)
        }
        _ => Err(WireError {
            code: ErrCode::Unsupported,
            msg: "server accepts gemm/app/stats request frames only".into(),
        }),
    };
    let reply = match admitted {
        Ok(work) => {
            match jobs.send(Job { shard: si, conn: id, seq, work }) {
                Ok(()) => None,
                // resolvers only disappear at teardown
                Err(_) => Some(Frame::Error(WireError {
                    code: ErrCode::Internal,
                    msg: "server is shutting down".into(),
                })),
            }
        }
        Err(e) => Some(Frame::Error(e)),
    };
    c.pending.push_back(Slot { seq, t0, reply });
}

/// Encode every front-of-queue slot whose reply is ready, stopping at
/// the write high-water mark. Stats are recorded at encode time (the
/// reply now exists and is committed to the socket in order). Returns
/// true when at least one slot retired.
fn encode_ready(c: &mut Conn, scratch: &mut Vec<u8>) -> bool {
    let mut any = false;
    while let Some(front) = c.pending.front() {
        if front.reply.is_none() || c.unflushed() >= WRITE_HIGH_WATER {
            break;
        }
        let slot = c.pending.pop_front().expect("front exists");
        let mut frame = slot.reply.expect("checked ready");
        if proto::encode(&frame, scratch).is_err() {
            // unreachable through admission (it bounds every reply),
            // kept as defense in depth: substitute a typed error so the
            // client's positional reply matching survives
            frame = Frame::Error(WireError {
                code: ErrCode::Internal,
                msg: "reply exceeded wire limits".into(),
            });
            if proto::encode(&frame, scratch).is_err() {
                c.dead = true;
                return any;
            }
        }
        c.wbuf.extend_from_slice(scratch);
        let us = slot.t0.elapsed().as_secs_f64() * 1e6;
        let mut s = lk(&c.stats);
        s.frames_out += 1;
        s.bytes_out += scratch.len() as u64;
        s.record_latency(us);
        if let Frame::Error(e) = &frame {
            s.error_replies += 1;
            if e.code == ErrCode::SloUnsatisfiable {
                s.slo_rejections += 1;
            }
        }
        any = true;
    }
    any
}

/// Nonblocking flush of the connection's outbound buffer.
fn flush(c: &mut Conn) {
    while c.wpos < c.wbuf.len() {
        match (&c.stream).write(&c.wbuf[c.wpos..]) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => {
                c.wpos += n;
                c.last_progress = Instant::now();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    if c.wpos == c.wbuf.len() {
        c.wbuf.clear();
        c.wpos = 0;
        c.last_progress = Instant::now();
    } else if c.wpos >= WRITE_HIGH_WATER {
        // reclaim the flushed prefix without waiting for full drain
        c.wbuf.drain(..c.wpos);
        c.wpos = 0;
    }
}

/// Close a finished connection and move its stats block from the
/// shard's live registry to its closed accumulator (same `live` →
/// per-conn → `closed` order as [`State::net_stats`], never nested with
/// `closed`).
fn reap(state: &Arc<State>, shard: &Shard, c: Conn) {
    let mut live = lk(&shard.live);
    let snap = lk(&c.stats).clone();
    lk(&shard.closed).merge(&snap);
    live.retain(|e| !Arc::ptr_eq(e, &c.stats));
    drop(live);
    state.closed_count.fetch_add(1, Ordering::Relaxed);
    let _ = c.stream.shutdown(Shutdown::Both);
}

fn err_code_for(e: &ProtoError) -> ErrCode {
    match e {
        ProtoError::Oversized { .. } => ErrCode::TooLarge,
        _ => ErrCode::Malformed,
    }
}

/// Highest approximation level the serving surface accepts (the PE
/// models are defined for k through the accumulator width; hostile
/// values would poison worker threads).
const MAX_WIRE_K: u32 = 16;

fn admit_gemm(req: proto::GemmReq) -> Result<Work, WireError> {
    let (m, kk, nn) = (req.m as usize, req.kk as usize, req.nn as usize);
    if m == 0 || kk == 0 || nn == 0 {
        return Err(WireError {
            code: ErrCode::Malformed,
            msg: "gemm dimensions must be positive".into(),
        });
    }
    if req.k > MAX_WIRE_K {
        return Err(WireError {
            code: ErrCode::Unsupported,
            msg: "approximation level k exceeds the supported range".into(),
        });
    }
    // the decoder bounds the operands (m*kk, kk*nn), but the *result*
    // is allocated pool-side as m x nn — bound it here too, or a tiny
    // frame (e.g. kk = 1 with huge m, nn) could demand a terabyte-scale
    // allocation and an unencodable reply
    if (m as u64) * (nn as u64) > proto::MAX_GEMM_ELEMS as u64 {
        return Err(WireError {
            code: ErrCode::TooLarge,
            msg: "result matrix m*nn exceeds the wire element cap".into(),
        });
    }
    Ok(Work::Gemm(GemmRequest { a: req.a, b: req.b, m, kk, nn, k: req.k,
                                slo: req.slo, ..Default::default() }))
}

/// Map a routing failure to its wire reply: an unsatisfiable SLO is its
/// own machine-readable code (the client can renegotiate), a malformed
/// one is the client's framing bug.
fn route_error_frame(e: &RouteError) -> Frame {
    Frame::Error(WireError {
        code: match e {
            RouteError::Unsatisfiable { .. } => ErrCode::SloUnsatisfiable,
            RouteError::Invalid(_) => ErrCode::Malformed,
        },
        msg: e.to_string(),
    })
}

fn admit_app(state: &Arc<State>, req: proto::AppReq)
             -> Result<Work, WireError> {
    if req.k > MAX_WIRE_K {
        return Err(WireError {
            code: ErrCode::Unsupported,
            msg: "approximation level k exceeds the supported range".into(),
        });
    }
    let img = match decode_pgm(&req.pgm) {
        Ok(i) => i,
        Err(e) => {
            return Err(WireError {
                code: ErrCode::BadImage,
                msg: format!("bad PGM payload: {e}"),
            });
        }
    };
    match req.app {
        AppKind::Dct if img.h % 8 != 0 || img.w % 8 != 0 => Err(WireError {
            code: ErrCode::BadImage,
            msg: "dct needs multiple-of-8 image dimensions".into(),
        }),
        AppKind::Edge if img.h < 3 || img.w < 3 => Err(WireError {
            code: ErrCode::BadImage,
            msg: "edge needs an image of at least 3x3".into(),
        }),
        AppKind::Bdcn if state.cfg.bdcn.is_none() => Err(WireError {
            code: ErrCode::Unsupported,
            msg: "bdcn weights are not loaded on this server".into(),
        }),
        // the zoo's accuracy columns cover the weight-free pipelines;
        // bdcn has no registered profile, so an SLO on it would have to
        // be guessed — refuse instead of silently approximating
        AppKind::Bdcn if req.slo.is_some() => Err(WireError {
            code: ErrCode::Unsupported,
            msg: "bdcn does not support SLO routing".into(),
        }),
        app => Ok(Work::App { app, k: req.k, img, slo: req.slo }),
    }
}

fn wire_stats(s: &ServiceStats, n: &NetStats) -> WireStats {
    WireStats {
        requests: s.requests,
        tiles: s.tiles,
        macs: s.sim_macs,
        energy_fj: s.energy_fj,
        metered_macs: s.metered_macs,
        latency_p50_us: s.latency_percentile(0.50),
        latency_p90_us: s.latency_percentile(0.90),
        latency_p99_us: s.latency_percentile(0.99),
        mean_latency_us: s.mean_latency_us(),
        connections: n.connections_opened,
        frames_in: n.frames_in,
        frames_out: n.frames_out,
        bytes_in: n.bytes_in,
        bytes_out: n.bytes_out,
        net_p50_us: n.latency_percentile(0.50),
        net_p90_us: n.latency_percentile(0.90),
        net_p99_us: n.latency_percentile(0.99),
        slo_requests: s.slo_requests,
        slo_exact: s.slo_exact,
        slo_unsatisfiable: s.slo_unsatisfiable,
        slo_tier: s.slo_tier,
    }
}

/// Resolver thread: execute admitted work on the shared pool and post
/// the reply frame back to the owning shard. Handler panics are caught
/// into typed `Internal` error replies — one poisoned request must not
/// take down a resolver (or, transitively, the positional reply
/// pipeline of its connection).
fn resolver_loop(state: Arc<State>, rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = {
            let guard = lk(&rx);
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // every shard exited: drain complete
            }
        };
        let frame = catch_unwind(AssertUnwindSafe(|| {
            resolve_work(&state, job.work)
        }))
        .unwrap_or_else(|_| {
            Frame::Error(WireError {
                code: ErrCode::Internal,
                msg: "internal error while serving the request".into(),
            })
        });
        state.shards[job.shard].post(Msg::Done {
            conn: job.conn,
            seq: job.seq,
            frame,
        });
    }
}

/// Execute one admitted request. GEMMs submit to the pool and block on
/// its completion signal *here*, in the resolver — never in a shard —
/// so pool-queue backpressure throttles resolvers, not event loops.
fn resolve_work(state: &State, work: Work) -> Frame {
    match work {
        Work::Gemm(req) => {
            // SLO routing happens pool-side; an unroutable request is a
            // typed refusal, never a silently-exact (or silently
            // degraded) execution
            let id = match state.coord.try_submit(req) {
                Ok(id) => id,
                Err(e) => return route_error_frame(&e),
            };
            let resp = state.coord.wait(id);
            Frame::GemmResp(GemmResp {
                m: resp.m as u32,
                nn: resp.nn as u32,
                latency_us: resp.latency_us,
                tiles: resp.tiles,
                macs: resp.sa_stats.macs,
                energy_fj: resp.sa_stats.energy_fj,
                metered_macs: resp.sa_stats.metered_macs,
                out: resp.out,
            })
        }
        Work::App { app, k, img, slo } => {
            let r = match app {
                AppKind::Bdcn => {
                    let blocks =
                        state.cfg.bdcn.clone().expect("checked at admission");
                    state.coord.serve_bdcn(&blocks, &img, k)
                }
                _ => match slo {
                    Some(s) => match state.coord.call_app_slo(app, &img, &s) {
                        Ok(r) => r.expect("weight-free app"),
                        Err(e) => return route_error_frame(&e),
                    },
                    None => state.coord.call_app(app, &img, k)
                        .expect("weight-free app"),
                },
            };
            Frame::AppResp(AppResp {
                app,
                psnr_db: r.psnr_db,
                latency_us: r.latency_us,
                gemm_requests: r.gemm_requests,
                energy_fj: r.sa_stats.energy_fj,
                macs: r.sa_stats.macs,
                h: r.out.h as u32,
                w: r.out.w as u32,
                pixels: r.out.data,
            })
        }
        Work::Stats => {
            // snapshot both stat blocks under their own short locks,
            // release, then encode — no stats lock is ever held across
            // frame encoding
            let s = state.coord.stats_snapshot();
            let n = state.net_stats();
            Frame::StatsResp(wire_stats(&s, &n))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisoned_stats_lock_recovers() {
        // lk() must hand back the data of a poisoned mutex: stats are
        // fold-only counters, so the worst case is one stale sample —
        // never a panic cascade through every other connection
        let m = Arc::new(Mutex::new(NetStats::default()));
        {
            let m = m.clone();
            let _ = std::thread::spawn(move || {
                let _guard = m.lock().unwrap();
                panic!("poison the stats lock");
            })
            .join();
        }
        assert!(m.is_poisoned());
        lk(&m).frames_in += 1;
        assert_eq!(lk(&m).frames_in, 1);
    }
}
