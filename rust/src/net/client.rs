//! Blocking client library for the framed TCP protocol.
//!
//! [`Client`] is a thin request/reply wrapper around one connection
//! with reusable encode/decode buffers; its split
//! [`Client::send_gemm`] / [`Client::recv_gemm`] halves let callers
//! pipeline many requests before reading any reply (the server answers
//! strictly in request order per connection). [`RemoteGemm`] implements
//! the [`Gemm`] trait over a connection, so every existing application
//! pipeline and differential test runs against a remote server
//! unchanged — and bit-identically, since the wire carries exact `i64`
//! operands into the same worker pool.

use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::apps::image::{encode_pgm, Image};
use crate::apps::Gemm;
use crate::coordinator::AppKind;
use crate::systolic::SaStats;

use crate::zoo::AccuracySlo;

use super::proto::{self, AppResp, Frame, GemmResp, WireStats};
use super::NetError;

/// One blocking connection to a [`crate::net::server::NetServer`].
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    wbuf: Vec<u8>,
    rbuf: Vec<u8>,
}

impl Client {
    /// Connect to a serving address (e.g. `"127.0.0.1:4817"` or the
    /// value printed by `axsys serve --listen`).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer,
            wbuf: Vec::new(),
            rbuf: Vec::new(),
        })
    }

    /// Send one raw frame (low-level; the typed helpers below cover the
    /// request kinds the server accepts).
    pub fn send(&mut self, frame: &Frame) -> Result<(), NetError> {
        proto::write_frame(&mut self.writer, frame, &mut self.wbuf)?;
        Ok(())
    }

    /// Receive one raw frame (blocking). A clean server-side close
    /// surfaces as an `Io` error with `UnexpectedEof`.
    pub fn recv(&mut self) -> Result<Frame, NetError> {
        match proto::read_frame(&mut self.reader, &mut self.rbuf)? {
            Some(f) => Ok(f),
            None => Err(NetError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ))),
        }
    }

    /// Send one GEMM request without waiting for the reply (the
    /// pipelining half; pair with [`Self::recv_gemm`] in the same
    /// order). Serializes straight from the borrowed operand slices —
    /// no owned wire struct, no operand double-copy on the hot path.
    pub fn send_gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize,
                     nn: usize, k: u32) -> Result<(), NetError> {
        self.send_gemm_slo(a, b, m, kk, nn, k, None)
    }

    /// [`Self::send_gemm`] with an optional accuracy SLO: when stated,
    /// the server routes the cheapest registered design point meeting
    /// it (and `k` is advisory only); an unsatisfiable SLO comes back
    /// as a typed [`super::proto::ErrCode::SloUnsatisfiable`] reply.
    #[allow(clippy::too_many_arguments)]
    pub fn send_gemm_slo(&mut self, a: &[i64], b: &[i64], m: usize,
                         kk: usize, nn: usize, k: u32,
                         slo: Option<&AccuracySlo>) -> Result<(), NetError> {
        assert_eq!(a.len(), m * kk, "A shape");
        assert_eq!(b.len(), kk * nn, "B shape");
        proto::encode_gemm_req_slo(k, m as u32, kk as u32, nn as u32, a, b,
                                   slo, &mut self.wbuf)?;
        self.writer.write_all(&self.wbuf)?;
        Ok(())
    }

    /// Receive the next GEMM reply (blocking); typed error frames
    /// surface as [`NetError::Server`].
    pub fn recv_gemm(&mut self) -> Result<GemmResp, NetError> {
        match self.recv()? {
            Frame::GemmResp(r) => Ok(r),
            Frame::Error(e) => Err(NetError::Server { code: e.code, msg: e.msg }),
            _ => Err(NetError::Unexpected("expected a GEMM response")),
        }
    }

    /// Synchronous GEMM call: `C(m x nn) = A(m x kk) @ B(kk x nn)` at
    /// approximation level `k`, served by the remote pool.
    pub fn gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize,
                nn: usize, k: u32) -> Result<GemmResp, NetError> {
        self.send_gemm(a, b, m, kk, nn, k)?;
        self.recv_gemm()
    }

    /// Synchronous SLO-routed GEMM call: the server picks the cheapest
    /// registered design point satisfying `slo`.
    pub fn gemm_slo(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize,
                    nn: usize, slo: &AccuracySlo)
                    -> Result<GemmResp, NetError> {
        self.send_gemm_slo(a, b, m, kk, nn, 0, Some(slo))?;
        self.recv_gemm()
    }

    /// Synchronous application call: the image travels inline as a
    /// binary PGM payload and the server runs the full served pipeline.
    pub fn app(&mut self, app: AppKind, img: &Image, k: u32)
               -> Result<AppResp, NetError> {
        self.app_slo(app, img, k, None)
    }

    /// [`Self::app`] with an optional accuracy SLO (when stated, the
    /// server routes the design point and `k` is advisory only).
    pub fn app_slo(&mut self, app: AppKind, img: &Image, k: u32,
                   slo: Option<&AccuracySlo>) -> Result<AppResp, NetError> {
        self.send(&Frame::AppReq(proto::AppReq {
            app,
            k,
            pgm: encode_pgm(img),
            slo: slo.copied(),
        }))?;
        match self.recv()? {
            Frame::AppResp(r) => Ok(r),
            Frame::Error(e) => Err(NetError::Server { code: e.code, msg: e.msg }),
            _ => Err(NetError::Unexpected("expected an app response")),
        }
    }

    /// Fetch a coordinator + network statistics snapshot.
    pub fn stats(&mut self) -> Result<WireStats, NetError> {
        self.send(&Frame::StatsReq)?;
        match self.recv()? {
            Frame::StatsResp(s) => Ok(s),
            Frame::Error(e) => Err(NetError::Server { code: e.code, msg: e.msg }),
            _ => Err(NetError::Unexpected("expected a stats response")),
        }
    }
}

/// Remote [`Gemm`] backend: every matrix product is shipped over the
/// framed TCP protocol to a serving pool and the result dropped back
/// into the caller's pipeline. Bit-identical to the in-process
/// [`crate::apps::CoordinatorGemm`] against the same pool configuration
/// (`tests/net_serve.rs`), so application pipelines and differential
/// tests run over the network unchanged.
///
/// The [`Gemm`] trait is infallible, so network failures panic with
/// context — matching the in-process adapter, whose pool-gone failure
/// mode also panics. Callers that need recoverable errors should use
/// [`Client`] directly.
pub struct RemoteGemm {
    client: Client,
    /// Approximation level submitted with every product.
    pub k: u32,
    /// Server-reported execution stats merged from every response.
    pub stats: SaStats,
    /// GEMM requests issued so far.
    pub requests: u64,
}

impl RemoteGemm {
    /// Connect to a serving address and fix the approximation level
    /// submitted with every product.
    pub fn connect<A: ToSocketAddrs>(addr: A, k: u32)
                                     -> std::io::Result<RemoteGemm> {
        Ok(RemoteGemm {
            client: Client::connect(addr)?,
            k,
            stats: SaStats::default(),
            requests: 0,
        })
    }
}

impl Gemm for RemoteGemm {
    fn gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize)
            -> Vec<i64> {
        let r = self.client.gemm(a, b, m, kk, nn, self.k)
            .expect("remote GEMM failed");
        self.requests += 1;
        self.stats.merge(&SaStats {
            tiles: r.tiles,
            macs: r.macs,
            energy_fj: r.energy_fj,
            metered_macs: r.metered_macs,
            ..Default::default()
        });
        r.out
    }

    fn stats(&self) -> Option<SaStats> {
        Some(self.stats)
    }
}
