//! Data-dependent energy accounting — the per-MAC energy model every
//! execution layer meters with (DESIGN.md §4).
//!
//! The paper's headline claim is *energy*, and the energy of PPC/NPPC
//! approximate multipliers is strongly operand-dependent (Spantidi et
//! al.), so the serving stack cannot report credible numbers from
//! random-vector averages ([`crate::hw`]'s granularity). This module
//! derives per-MAC energy from the gate netlists themselves and makes it
//! cheap enough for the table-driven hot path:
//!
//! ## The model: canonical return-to-zero frames
//!
//! A PE's per-MAC switched energy in a live chain depends on the full
//! previous gate state — a function of the entire accumulator history,
//! which no small table can key. The model therefore fixes a canonical
//! activity convention: each MAC is charged the netlist's switched
//! energy for the transition **quiescent frame → active frame**, where
//! the active frame carries the operands plus the *carry-save window*
//! (the low `k` bits of the `(s, kc)` rails — exactly the state the
//! product-LUT automaton in [`crate::pe::lut`] tracks) and the quiescent
//! frame is all-zero inputs. Under this return-to-zero convention the
//! per-MAC energy is an **exact** function of `(a, b, window state)`:
//!
//! * [`Replayer`] is the ground truth — it drives the real PE grid
//!   netlist frame by frame through [`crate::netlist::Stepper`];
//! * [`EnergyLut`] tabulates the same function once per design point
//!   (reusing the `ProductLut` automaton's state indices, so the blocked
//!   GEMM kernels can meter with one extra table read per MAC);
//! * `tests/energy_model.rs` pins `EnergyLut` aggregation == direct
//!   netlist replay **exactly** (same f64 values in the same order).
//!
//! What the model captures: operand-value data dependence (the dominant
//! term — product rows light up with operand magnitude), cell-family
//! differences (approximate cells switch fewer/cheaper gates), the
//! Baugh-Wooley sign machinery, and the per-MAC register clocking term.
//! What it abstracts away: the dependence of exact-region toggles on the
//! full accumulator value (second-order; the window captures the state
//! interaction that feeds back into the *results*), and the drain merge
//! adder (fires once per output element, amortized over the `kk`-MAC
//! chain — the same treatment [`crate::hw::pe_metrics`] applies).
//!
//! The conventional-MAC baselines of Table III are tabulated through the
//! *same* convention ([`conventional_mean_mac_fj`]), so the savings the
//! `energy-report` CLI and the golden test print are model output, not
//! copied constants. Metering observes and never reorders: the meters
//! read operands and states the kernels already hold, and the bit-identity
//! suites run with metering enabled.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::apps::Gemm;
use crate::netlist::Netlist;
use crate::pe::lut::{self, ProductLut};
use crate::pe::netlist_builder::{conventional_mac_netlist, pe_netlists};
use crate::pe::word::{mac_step_planned, MacPlan, PeConfig};
use crate::pe::Design;
use crate::Family;

/// Hard ceiling on one energy table's size; larger design points fall
/// back to unmetered execution (or [`Replayer`]-based metering on the
/// cycle-accurate path) rather than ballooning resident memory.
pub const TABLE_BYTES_BUDGET: usize = 96 << 20;

/// Write the low `dst.len()` bits of `v` into `dst` (LSB first) — the
/// netlist frame encoding shared by the table build and the replayer.
fn fill_bits(dst: &mut [u8], v: u64) {
    for (i, d) in dst.iter_mut().enumerate() {
        *d = ((v >> i) & 1) as u8;
    }
}

// ---------------------------------------------------------------------
// EnergyLut — the tabulated fast path.
// ---------------------------------------------------------------------

/// Per-design-point energy table: the canonical per-MAC switched energy
/// (fJ, register clocking included) for every `(window state, a, b)`.
///
/// State indices are the `ProductLut` automaton's indices *by
/// construction* (the table is built by embedding each automaton state's
/// window into netlist frames), so the blocked LUT kernel meters with
/// the state register it already chases. The word kernel recovers the
/// index from its live rails through [`EnergyLut::state_of_rails`].
pub struct EnergyLut {
    /// The design point the table was compiled for (default accumulator
    /// width `2n + 8`; callers with custom widths should not meter with
    /// this table).
    pub cfg: PeConfig,
    /// Whether the exact-region cells are the paper's optimized
    /// (mirror-adder) flavor — distinguishes "Proposed exact" from
    /// "Exact \[6\]" tables, which `PeConfig` alone cannot.
    pub optimized_exact: bool,
    /// The automaton whose state indices this table shares.
    plut: Arc<ProductLut>,
    /// State-major energies: `(state << 2n) | (a_enc << n) | b_enc`.
    e: Vec<f64>,
    /// Packed window `(s_lo << k) | kc_lo` → automaton state index
    /// (`u16::MAX` for unreachable windows).
    win_index: Vec<u16>,
    /// Window width in bits (`== cfg.k`).
    kb: u32,
}

impl EnergyLut {
    /// Whether a design point can have an energy table at all (same
    /// domain as the product LUT; the build may still return `None` on
    /// the byte budget).
    pub fn supports(cfg: &PeConfig) -> bool {
        ProductLut::supports(cfg)
    }

    /// Compile the table for a design point. The build walks every
    /// `(state, a, b)` frame through the 64-lane bit-parallel evaluator
    /// ([`Netlist::eval_values64`]: 64 consecutive `b` values per pass)
    /// and accumulates each lane's switched energy in the same per-gate
    /// order as [`Netlist::frame_energy`] — so every entry is f64-exact
    /// against the scalar [`Replayer`] (the consistency tests compare
    /// with `==`). Returns `None` for unsupported or over-budget points.
    pub fn try_build(d: &Design) -> Option<EnergyLut> {
        let cfg = PeConfig::from_design(d);
        let plut = lut::cached(&cfg)?;
        let n = cfg.n as usize;
        let w = cfg.w as usize;
        let size = 1usize << n;
        let n_states = plut.states();
        if n_states * size * size * 8 > TABLE_BYTES_BUDGET {
            return None;
        }
        let grid = pe_netlists(d, cfg.w).grid;
        // quiescent baseline, broadcast to all lanes
        let mut scratch8 = Vec::new();
        grid.eval_values(&vec![0u8; grid.inputs.len()], &mut scratch8);
        let quiet_bc: Vec<u64> = scratch8.iter()
            .map(|&v| 0u64.wrapping_sub(v as u64))
            .collect();
        let gate_fj: Vec<f64> = grid.gates.iter()
            .map(|g| crate::tech::LIB.energy_fj(g.kind))
            .collect();
        let dff_fj = grid.dffs as f64 * crate::tech::LIB.dff_energy_fj * 0.5;
        // lane l of a block encodes b = base + l: bits < 6 come from the
        // lane index (fixed patterns), higher bits from the block base
        const LANE_BITS: [u64; 6] = [
            0xAAAA_AAAA_AAAA_AAAA, 0xCCCC_CCCC_CCCC_CCCC,
            0xF0F0_F0F0_F0F0_F0F0, 0xFF00_FF00_FF00_FF00,
            0xFFFF_0000_FFFF_0000, 0xFFFF_FFFF_0000_0000,
        ];
        let bcast = |bit: u64| 0u64.wrapping_sub(bit & 1);
        let lanes = size.min(64);
        let mut inputs64 = vec![0u64; grid.inputs.len()];
        let mut vals64: Vec<u64> = Vec::new();
        let mut e = vec![0f64; n_states << (2 * n)];
        for si in 0..n_states {
            let (ws, wk) = plut.state_window(si);
            for bit in 0..w {
                inputs64[2 * n + bit] = bcast(ws >> bit);
                inputs64[2 * n + w + bit] = bcast(wk >> bit);
            }
            for a in 0..size {
                for (bit, slot) in inputs64[..n].iter_mut().enumerate() {
                    *slot = bcast((a as u64) >> bit);
                }
                let mut base = 0usize;
                while base < size {
                    for bit in 0..n {
                        inputs64[n + bit] = if bit < 6 {
                            LANE_BITS[bit]
                        } else {
                            bcast((base as u64) >> bit)
                        };
                    }
                    grid.eval_values64(&inputs64, &mut vals64);
                    let mut lane_fj = [0f64; 64];
                    for (g, &v) in vals64.iter().enumerate() {
                        let mut dmask = v ^ quiet_bc[g];
                        if dmask != 0 {
                            let efj = gate_fj[g];
                            while dmask != 0 {
                                let l = dmask.trailing_zeros() as usize;
                                lane_fj[l] += efj;
                                dmask &= dmask - 1;
                            }
                        }
                    }
                    let row = (si << (2 * n)) | (a << n) | base;
                    for (l, fj) in lane_fj.iter().enumerate().take(lanes) {
                        e[row + l] = *fj + dff_fj;
                    }
                    base += 64;
                }
            }
        }
        let kb = cfg.k;
        let mut win_index = vec![u16::MAX; 1usize << (2 * kb)];
        for si in 0..n_states {
            let (ws, wk) = plut.state_window(si);
            win_index[((ws as usize) << kb) | wk as usize] = si as u16;
        }
        Some(EnergyLut {
            cfg,
            optimized_exact: d.optimized_exact,
            plut,
            e,
            win_index,
            kb,
        })
    }

    /// Number of automaton states the table covers (1 when exact).
    pub fn states(&self) -> usize {
        self.e.len() >> (2 * self.cfg.n)
    }

    /// Resident table footprint in bytes.
    pub fn table_bytes(&self) -> usize {
        self.e.len() * 8 + self.win_index.len() * 2
    }

    /// Raw energy read at a precombined `(state << 2n) | (a << n) | b`
    /// index — hot-loop primitive for the metered kernels in
    /// [`crate::gemm`].
    #[inline(always)]
    pub(crate) fn entry(&self, idx: usize) -> f64 {
        self.e[idx]
    }

    /// Canonical energy (fJ) of one MAC: operand encodings + automaton
    /// state index.
    #[inline(always)]
    pub fn mac_fj(&self, state: usize, a_enc: u64, b_enc: u64) -> f64 {
        let n = self.cfg.n;
        let m = (1u64 << n) - 1;
        self.e[(state << (2 * n)) | (((a_enc & m) as usize) << n)
               | (b_enc & m) as usize]
    }

    /// Automaton state index of live carry-save rails (the word kernel's
    /// metering path; rails reached from a reset accumulator are always
    /// reachable windows).
    #[inline(always)]
    pub fn state_of_rails(&self, s: u64, kc: u64) -> usize {
        let kmask = (1u64 << self.kb) - 1;
        self.win_index[(((s & kmask) as usize) << self.kb)
                       | (kc & kmask) as usize] as usize
    }

    /// Advance the automaton state by one MAC from operand encodings.
    /// The fused lane kernels in [`crate::gemm`] chase one state per
    /// lane with this instead of re-deriving it from live rails; the
    /// two are equal by construction (`state_of_rails` after a step ==
    /// `next_state` of the pre-step state — pinned by
    /// `tests::rails_state_lookup_matches_chain_walk`).
    #[inline(always)]
    pub(crate) fn next_state(&self, state: usize, a_enc: u64, b_enc: u64)
                             -> usize {
        if self.kb == 0 {
            return 0;
        }
        let kb = self.kb as usize;
        let kmask = (1usize << kb) - 1;
        self.plut.next_state(state, ((a_enc as usize & kmask) << kb)
                             | (b_enc as usize & kmask))
    }

    /// Fused lane-group metering step: charge every lane of one
    /// lane-group frame its canonical pre-step energy (state-major
    /// table gathers — 64 independent read streams), then advance the
    /// per-lane automaton states. `b_enc`/`st` are the live lanes of
    /// one `(group, t)` frame; the broadcast A operand is shared.
    /// Returns the frame's femtojoules. This is the whole metering
    /// cost of the 64-lane word kernel: the compute planes are never
    /// touched, so it cannot change the bits.
    #[inline]
    pub(crate) fn mac_fj_lanes(&self, a_enc: u64, b_enc: &[u16],
                               st: &mut [u16]) -> f64 {
        let n = self.cfg.n as usize;
        let m = (1usize << n) - 1;
        let ahi = (a_enc as usize & m) << n;
        let mut fj = 0.0;
        for (s, &be) in st.iter_mut().zip(b_enc) {
            let bi = be as usize & m;
            fj += self.e[((*s as usize) << (2 * n)) | ahi | bi];
            *s = self.next_state(*s as usize, a_enc, be as u64) as u16;
        }
        fj
    }

    /// Aggregate one MAC chain's energy through the tables (state from
    /// reset; fJ). Must equal [`Replayer::chain_fj`] *exactly* — the
    /// consistency contract `tests/energy_model.rs` enforces.
    pub fn chain_fj(&self, ops: &[(i64, i64)]) -> f64 {
        let n = self.cfg.n as usize;
        let mut st = 0usize;
        let mut total = 0.0;
        for &(a, b) in ops {
            let ae = self.cfg.encode(a) as usize;
            let be = self.cfg.encode(b) as usize;
            total += self.e[(st << (2 * n)) | (ae << n) | be];
            st = self.next_state(st, ae as u64, be as u64);
        }
        total
    }
}

/// Cache key: every field that changes the table.
type EnergyKey = (u32, bool, Family, u32, bool);

fn key_of(d: &Design) -> EnergyKey {
    (d.n, d.is_signed(), d.family, d.k, d.optimized_exact)
}

fn cache() -> &'static Mutex<HashMap<EnergyKey, Option<Arc<EnergyLut>>>> {
    static CACHE: OnceLock<Mutex<HashMap<EnergyKey, Option<Arc<EnergyLut>>>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Fetch (building on first use) the shared energy table for a design
/// point; `None` means the point is not tabulable — callers either skip
/// metering or fall back to [`Replayer`]-based replay. Tables are
/// process-wide, `Arc`-shared across coordinator workers alongside
/// [`crate::pe::lut::cached`]'s product tables.
pub fn cached_design(d: &Design) -> Option<Arc<EnergyLut>> {
    let key = key_of(d);
    if let Some(hit) = cache().lock().unwrap().get(&key) {
        return hit.clone();
    }
    // build outside the lock (idempotent; a racing duplicate build is
    // wasted work, not an error)
    let built = EnergyLut::try_build(d).map(Arc::new);
    cache().lock().unwrap().entry(key).or_insert(built).clone()
}

/// [`cached_design`] for a runtime [`PeConfig`], assuming the paper's
/// optimized exact cells (the serving default; see
/// [`Design::from_pe_config`]). The table is built at the default
/// accumulator width `2n + 8`.
pub fn cached(cfg: &PeConfig) -> Option<Arc<EnergyLut>> {
    cached_design(&Design::from_pe_config(cfg))
}

// ---------------------------------------------------------------------
// Replayer — the direct-netlist ground truth.
// ---------------------------------------------------------------------

/// Reusable direct-replay engine for one design point: owns the PE grid
/// netlist, its quiescent baseline frame and the scratch buffers, and
/// charges each MAC the canonical frame's switched energy straight from
/// the gates. This is the ground truth the [`EnergyLut`] must reproduce
/// exactly, and the meter behind the cycle-accurate systolic backend
/// (which can therefore meter *any* buildable design point, including
/// ones too wide for tables).
pub struct Replayer {
    /// The design point being replayed.
    pub cfg: PeConfig,
    plan: MacPlan,
    grid: Netlist,
    quiet: Vec<u8>,
    vals: Vec<u8>,
    frame: Vec<u8>,
}

impl Replayer {
    /// Build the grid netlist for `d` and snapshot its quiescent frame.
    pub fn new(d: &Design) -> Replayer {
        let cfg = PeConfig::from_design(d);
        let grid = pe_netlists(d, cfg.w).grid;
        let mut vals = Vec::new();
        let zero = vec![0u8; grid.inputs.len()];
        grid.eval_values(&zero, &mut vals);
        let quiet = vals.clone();
        let frame = vec![0u8; grid.inputs.len()];
        Replayer { cfg, plan: MacPlan::new(&cfg), grid, quiet, vals, frame }
    }

    /// Canonical energy (fJ) of one MAC: operand encodings + the live
    /// carry-save rails (only their low-`k` window enters the frame).
    pub fn mac_fj(&mut self, a_enc: u64, b_enc: u64, s: u64, kc: u64) -> f64 {
        let n = self.cfg.n as usize;
        let w = self.cfg.w as usize;
        let kmask = (1u64 << self.cfg.k) - 1;
        fill_bits(&mut self.frame[..n], a_enc);
        fill_bits(&mut self.frame[n..2 * n], b_enc);
        fill_bits(&mut self.frame[2 * n..2 * n + w], s & kmask);
        fill_bits(&mut self.frame[2 * n + w..], kc & kmask);
        self.grid.eval_values(&self.frame, &mut self.vals);
        self.grid.frame_energy(&self.quiet, &self.vals).0
    }

    /// Total energy (fJ) of one MAC chain from a reset accumulator,
    /// advancing the rails through the word model between frames.
    pub fn chain_fj(&mut self, ops: &[(i64, i64)]) -> f64 {
        let (mut s, mut kc) = (0u64, 0u64);
        let mut total = 0.0;
        for &(a, b) in ops {
            let ae = self.cfg.encode(a);
            let be = self.cfg.encode(b);
            total += self.mac_fj(ae, be, s, kc);
            let (s2, k2) = mac_step_planned(&self.plan, ae, be, s, kc);
            s = s2;
            kc = k2;
        }
        total
    }
}

// ---------------------------------------------------------------------
// Stream-level measurement + array composition.
// ---------------------------------------------------------------------

/// Mean per-MAC energy (fJ) of a design over an operand stream replayed
/// as chains of `chain_len` MACs (carry-save state reset per chain) —
/// the primitive behind the golden savings test and `energy-report`.
pub fn mean_mac_fj(d: &Design, a_ops: &[i64], b_ops: &[i64],
                   chain_len: usize) -> f64 {
    assert_eq!(a_ops.len(), b_ops.len(), "operand stream shape");
    assert!(chain_len > 0 && !a_ops.is_empty());
    let mut r = Replayer::new(d);
    let mut total = 0.0;
    let mut i = 0;
    while i < a_ops.len() {
        let end = (i + chain_len).min(a_ops.len());
        let ops: Vec<(i64, i64)> = a_ops[i..end]
            .iter()
            .zip(&b_ops[i..end])
            .map(|(&a, &b)| (a, b))
            .collect();
        total += r.chain_fj(&ops);
        i = end;
    }
    total / a_ops.len() as f64
}

/// Mean per-MAC replay energy (fJ) of a design over recorded workload
/// chains (each chain restarts the accumulator, mirroring one output
/// element's fold).
pub fn mean_mac_fj_chains(d: &Design, chains: &[Vec<(i64, i64)>]) -> f64 {
    let mut r = Replayer::new(d);
    let mut total = 0.0;
    let mut macs = 0usize;
    for c in chains {
        total += r.chain_fj(c);
        macs += c.len();
    }
    if macs == 0 {
        return 0.0;
    }
    total / macs as f64
}

/// Mean per-MAC energy (fJ) of a conventional (multiplier + CPA +
/// accumulator-adder) MAC through the same canonical-frame convention:
/// the whole array multiplier, vector-merge CPA and accumulator adder
/// switch every cycle — the structural energy disadvantage the paper's
/// fused carry-save PE removes. `hybrid` selects HA-FSA \[10\] over the
/// Gemmini-style PE \[13\].
pub fn conventional_mean_mac_fj(n: u32, hybrid: bool, a_ops: &[i64],
                                b_ops: &[i64]) -> f64 {
    assert_eq!(a_ops.len(), b_ops.len(), "operand stream shape");
    assert!(!a_ops.is_empty());
    let w = 2 * n + 8;
    let nl = conventional_mac_netlist(n, w, hybrid);
    let zero = vec![0u8; nl.inputs.len()];
    let mut vals = Vec::new();
    nl.eval_values(&zero, &mut vals);
    let quiet = vals.clone();
    let mut frame = vec![0u8; nl.inputs.len()];
    let mask = (1u64 << n) - 1;
    let n = n as usize;
    let mut total = 0.0;
    for (&a, &b) in a_ops.iter().zip(b_ops) {
        fill_bits(&mut frame[..n], a as u64 & mask);
        fill_bits(&mut frame[n..2 * n], b as u64 & mask);
        nl.eval_values(&frame, &mut vals);
        total += nl.frame_energy(&quiet, &vals).0;
    }
    total / a_ops.len() as f64
}

/// Array-level energy per cycle (fJ): `size²` PEs at `mean_mac_fj` each
/// plus the operand skew registers' clocking — the same structural
/// composition [`crate::hw::sa_metrics`] uses, with the random-activity
/// PE power replaced by the data-dependent per-MAC model.
pub fn array_fj_per_cycle(mean_mac_fj: f64, size: usize, n_bits: u32) -> f64 {
    let lib = crate::tech::LIB;
    let skew = (size * (size - 1)) as f64 * n_bits as f64
        * lib.dff_energy_fj * 0.5;
    (size * size) as f64 * mean_mac_fj + skew
}

// ---------------------------------------------------------------------
// Workload operand capture (real activity for energy-report).
// ---------------------------------------------------------------------

/// GEMM adapter that records sampled per-output-element MAC chains while
/// delegating to the blocked word engine — how `energy-report` captures
/// real workload operand streams from the §V pipelines.
pub struct RecordingGemm {
    cfg: PeConfig,
    /// Recorded operand chains, one per sampled output element.
    pub chains: Vec<Vec<(i64, i64)>>,
    cap: usize,
}

impl RecordingGemm {
    /// Recorder at design point `cfg` keeping at most `cap` chains.
    pub fn new(cfg: PeConfig, cap: usize) -> Self {
        RecordingGemm { cfg, chains: Vec::new(), cap }
    }
}

impl Gemm for RecordingGemm {
    fn gemm(&mut self, a: &[i64], b: &[i64], m: usize, kk: usize, nn: usize)
            -> Vec<i64> {
        // sample a coarse grid of output elements per call so every GEMM
        // stage of a pipeline contributes chains
        let si = (m / 4).max(1);
        let sj = (nn / 4).max(1);
        'outer: for i in (0..m).step_by(si) {
            for j in (0..nn).step_by(sj) {
                if self.chains.len() >= self.cap {
                    break 'outer;
                }
                self.chains.push(
                    (0..kk).map(|t| (a[i * kk + t], b[t * nn + j])).collect());
            }
        }
        crate::gemm::matmul_word(&self.cfg, a, b, m, kk, nn)
    }
}

/// Operand chains captured from the DCT compression pipeline on a
/// deterministic `side × side` scene, exact arithmetic (k = 0) so every
/// design point replays the *same* stream.
pub fn dct_workload_chains(side: usize, cap: usize) -> Vec<Vec<(i64, i64)>> {
    let img = crate::apps::image::scene(side, side);
    let mut g = RecordingGemm::new(
        PeConfig::new(8, true, Family::Proposed, 0), cap);
    let _ = crate::apps::dct::pipeline(&mut g, &img);
    g.chains
}

/// Operand chains captured from the Laplacian edge pipeline (im2col
/// conv→GEMM lowering included), exact arithmetic.
pub fn edge_workload_chains(side: usize, cap: usize) -> Vec<Vec<(i64, i64)>> {
    let img = crate::apps::image::scene(side, side);
    let mut g = RecordingGemm::new(
        PeConfig::new(8, true, Family::Proposed, 0), cap);
    let _ = crate::apps::edge::pipeline(&mut g, &img);
    g.chains
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::xorshift_ints as ints;
    use crate::pe::Signedness;

    fn chain(seed: u64, len: usize) -> Vec<(i64, i64)> {
        let a = ints(seed, len);
        let b = ints(seed.wrapping_add(1), len);
        a.into_iter().zip(b).collect()
    }

    #[test]
    fn table_equals_replay_exactly_small_points() {
        // n = 4 keeps the table tiny; exactness must hold bit-for-bit
        for family in Family::ALL {
            for k in [0u32, 2, 4] {
                let d = Design::approximate(4, Signedness::Signed, family, k);
                let lut = EnergyLut::try_build(&d).expect("4-bit builds");
                let mut rep = Replayer::new(&d);
                let ops = chain(97 + k as u64, 40);
                assert_eq!(lut.chain_fj(&ops), rep.chain_fj(&ops),
                           "{family:?} k={k}");
            }
        }
    }

    #[test]
    fn energy_is_operand_dependent() {
        // the whole point: zero operands switch almost nothing, dense
        // operands light the grid up
        let d = Design::proposed_exact(8, Signedness::Signed);
        let mut r = Replayer::new(&d);
        let quiet = r.chain_fj(&[(0, 0); 8]);
        let busy = r.chain_fj(&[(-1, -1); 8]);
        assert!(busy > 2.0 * quiet, "busy {busy} vs quiet {quiet}");
    }

    #[test]
    fn exact_cell_flavor_changes_the_table() {
        let ops = chain(5, 64);
        let e6 = mean_mac_fj(&Design::conventional_exact(8, Signedness::Signed),
                             &ops.iter().map(|o| o.0).collect::<Vec<_>>(),
                             &ops.iter().map(|o| o.1).collect::<Vec<_>>(), 16);
        let pe = mean_mac_fj(&Design::proposed_exact(8, Signedness::Signed),
                             &ops.iter().map(|o| o.0).collect::<Vec<_>>(),
                             &ops.iter().map(|o| o.1).collect::<Vec<_>>(), 16);
        assert!(pe < e6, "mirror-adder cells must be cheaper: {pe} vs {e6}");
    }

    #[test]
    fn cache_shares_one_arc_and_rejects_unsupported() {
        let cfg = PeConfig::new(8, true, Family::Proposed, 2);
        let t1 = cached(&cfg).expect("8-bit point tabulates");
        let t2 = cached(&cfg).expect("cache hit");
        assert!(Arc::ptr_eq(&t1, &t2));
        assert!(t1.states() >= 1);
        assert!(t1.table_bytes() <= TABLE_BYTES_BUDGET);
        // distinct exact-cell flavors are distinct tables
        let d6 = Design::conventional_exact(8, Signedness::Signed);
        let t6 = cached_design(&d6).expect("exact [6] tabulates");
        assert!(!Arc::ptr_eq(&t1, &t6));
        // 16-bit operands exceed the product-table domain
        let wide = PeConfig::new(16, true, Family::Proposed, 3);
        assert!(!EnergyLut::supports(&wide));
        assert!(cached(&wide).is_none());
    }

    #[test]
    fn rails_state_lookup_matches_chain_walk() {
        let d = Design::approximate(8, Signedness::Signed, Family::Proposed, 3);
        let lut = EnergyLut::try_build(&d).unwrap();
        let cfg = lut.cfg;
        let plan = MacPlan::new(&cfg);
        let ops = chain(31, 50);
        // walking rails + state_of_rails must reproduce chain_fj exactly
        let (mut s, mut kc) = (0u64, 0u64);
        let mut total = 0.0;
        for &(a, b) in &ops {
            let (ae, be) = (cfg.encode(a), cfg.encode(b));
            total += lut.mac_fj(lut.state_of_rails(s, kc), ae, be);
            let (s2, k2) = mac_step_planned(&plan, ae, be, s, kc);
            s = s2;
            kc = k2;
        }
        assert_eq!(total, lut.chain_fj(&ops));
    }

    #[test]
    fn fused_lane_metering_equals_per_lane_chain_walks_exactly() {
        // mac_fj_lanes charges frame-major (all lanes of step t, then
        // t+1); per lane that is exactly the chain walk — same table
        // entries, same per-lane state sequence, and f64 addition over
        // the same per-lane value sequence, so the per-lane partial
        // sums are reproduced exactly, not just to rounding.
        let d = Design::approximate(8, Signedness::Signed, Family::Proposed, 3);
        let lut = EnergyLut::try_build(&d).unwrap();
        let cfg = lut.cfg;
        let lanes = 5usize;
        let steps = 40usize;
        let chains: Vec<Vec<(i64, i64)>> = (0..lanes)
            .map(|l| chain(100 + l as u64, steps)).collect();
        // the broadcast A operand is shared across lanes (the lane
        // kernel's layout), so overwrite each chain's a with lane 0's
        let a_ops: Vec<i64> = chains[0].iter().map(|o| o.0).collect();
        let mut st = vec![0u16; lanes];
        let mut total = 0.0;
        for (t, &a) in a_ops.iter().enumerate() {
            let be: Vec<u16> = chains.iter()
                .map(|c| cfg.encode(c[t].1) as u16).collect();
            total += lut.mac_fj_lanes(cfg.encode(a), &be, &mut st);
        }
        let want: f64 = chains.iter().map(|c| {
            let ops: Vec<(i64, i64)> = c.iter().enumerate()
                .map(|(t, o)| (a_ops[t], o.1)).collect();
            lut.chain_fj(&ops)
        }).sum();
        assert!(total > 0.0);
        assert!((total - want).abs() <= 1e-9 * want,
                "fused {total} vs per-lane chains {want}");
        // final per-lane states equal the scalar rails-derived states
        let plan = MacPlan::new(&cfg);
        for (l, c) in chains.iter().enumerate() {
            let (mut s, mut kc) = (0u64, 0u64);
            for (t, o) in c.iter().enumerate() {
                let (ae, be) = (cfg.encode(a_ops[t]), cfg.encode(o.1));
                (s, kc) = mac_step_planned(&plan, ae, be, s, kc);
            }
            assert_eq!(st[l] as usize, lut.state_of_rails(s, kc), "lane {l}");
        }
    }

    #[test]
    fn workload_chains_are_captured() {
        let chains = dct_workload_chains(16, 24);
        assert!(!chains.is_empty() && chains.len() <= 24);
        assert!(chains.iter().all(|c| !c.is_empty()));
        let d = Design::proposed_exact(8, Signedness::Signed);
        assert!(mean_mac_fj_chains(&d, &chains) > 0.0);
    }
}
