//! Error-metric engines: ED, MED, NMED, MRED (Liang/Han/Lombardi \[16\]).
//!
//! The paper evaluates 8-bit PEs over all 65 536 operand pairs (c = 0) —
//! `exhaustive_metrics` reproduces that; `random_metrics` extends to
//! accumulating MAC chains where the carry-save state interacts with the
//! approximate columns.

use crate::pe::word::{mac_step_planned, MacPlan, PeConfig};
use crate::Family;

/// Summary error metrics for one design point.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorMetrics {
    /// Mean error distance E\[|approx - exact|\].
    pub med: f64,
    /// MED normalized by the maximum output magnitude.
    pub nmed: f64,
    /// Mean relative error distance E\[|approx-exact| / |exact|\] over
    /// non-zero exact outputs.
    pub mred: f64,
    /// Worst-case |ED| seen.
    pub max_ed: u64,
    /// Error rate: fraction of inputs with any deviation.
    pub error_rate: f64,
}

/// Largest |product| used for NMED normalization.
pub fn max_product(n: u32, signed: bool) -> f64 {
    if signed {
        // |(-2^(N-1)) * (-2^(N-1))| = 2^(2N-2)
        (1u64 << (2 * n - 2)) as f64
    } else {
        let m = (1u64 << n) - 1;
        (m * m) as f64
    }
}

/// Exhaustive sweep over all operand pairs of one multiply (c = 0) —
/// the paper's Table V setting. O(4^N): instant for N <= 8.
pub fn exhaustive_metrics(cfg: &PeConfig) -> ErrorMetrics {
    let n = cfg.n;
    let (lo, hi): (i64, i64) = if cfg.signed {
        (-(1i64 << (n - 1)), 1i64 << (n - 1))
    } else {
        (0, 1i64 << n)
    };
    let mut sed = 0f64;
    let mut sred = 0f64;
    let mut nz = 0u64;
    let mut errs = 0u64;
    let mut max_ed = 0u64;
    let total = ((hi - lo) * (hi - lo)) as f64;
    let plan = MacPlan::new(cfg);
    for a in lo..hi {
        for b in lo..hi {
            let (s, k) = mac_step_planned(&plan, cfg.encode(a), cfg.encode(b), 0, 0);
            let y = cfg.decode(s.wrapping_add(k) & cfg.word_mask());
            let exact = a * b;
            let ed = (y - exact).unsigned_abs();
            if ed > 0 {
                errs += 1;
            }
            max_ed = max_ed.max(ed);
            sed += ed as f64;
            if exact != 0 {
                sred += ed as f64 / exact.abs() as f64;
                nz += 1;
            }
        }
    }
    let med = sed / total;
    ErrorMetrics {
        med,
        nmed: med / max_product(n, cfg.signed),
        mred: if nz > 0 { sred / nz as f64 } else { 0.0 },
        max_ed,
        error_rate: errs as f64 / total,
    }
}

/// Randomized sweep over accumulating dot products of length `chain`:
/// measures how the approximate carry-save state behaves under real GEMM
/// accumulation (not covered by the single-MAC exhaustive sweep).
pub fn chained_metrics(cfg: &PeConfig, chain: usize, samples: usize,
                       seed: u64) -> ErrorMetrics {
    let mut state = seed | 1;
    let mut rnd = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    let half = 1i64 << (cfg.n - 1);
    let mut sed = 0f64;
    let mut sred = 0f64;
    let mut nz = 0u64;
    let mut errs = 0u64;
    let mut max_ed = 0u64;
    let plan = MacPlan::new(cfg);
    for _ in 0..samples {
        let mut s = 0u64;
        let mut k = 0u64;
        let mut exact = 0i64;
        for _ in 0..chain {
            let a = if cfg.signed {
                (rnd() as i64 & (2 * half - 1)) - half
            } else {
                rnd() as i64 & (2 * half - 1)
            };
            let b = if cfg.signed {
                (rnd() as i64 & (2 * half - 1)) - half
            } else {
                rnd() as i64 & (2 * half - 1)
            };
            let (s2, k2) = mac_step_planned(&plan, cfg.encode(a), cfg.encode(b), s, k);
            s = s2;
            k = k2;
            exact += a * b;
        }
        let y = cfg.decode(s.wrapping_add(k) & cfg.word_mask());
        let ed = (y - exact).unsigned_abs();
        if ed > 0 {
            errs += 1;
        }
        max_ed = max_ed.max(ed);
        sed += ed as f64;
        if exact != 0 {
            sred += ed as f64 / exact.abs() as f64;
            nz += 1;
        }
    }
    let med = sed / samples as f64;
    ErrorMetrics {
        med,
        nmed: med / (max_product(cfg.n, cfg.signed) * chain as f64),
        mred: if nz > 0 { sred / nz as f64 } else { 0.0 },
        max_ed,
        error_rate: errs as f64 / samples as f64,
    }
}

/// Table V row: metrics for a family at a given k (8-bit by default).
pub fn table5_row(family: Family, k: u32, n: u32)
                  -> (ErrorMetrics, ErrorMetrics) {
    let unsigned = exhaustive_metrics(&PeConfig::new(n, false, family, k));
    let signed = exhaustive_metrics(&PeConfig::new(n, true, family, k));
    (unsigned, signed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_has_zero_error() {
        for signed in [false, true] {
            let cfg = PeConfig::new(8, signed, Family::Proposed, 0);
            let m = exhaustive_metrics(&cfg);
            assert_eq!(m.med, 0.0);
            assert_eq!(m.error_rate, 0.0);
            assert_eq!(m.max_ed, 0);
        }
    }

    #[test]
    fn nmed_monotone_in_k_proposed() {
        let mut prev = -1.0;
        for k in [0u32, 2, 4, 5, 6, 8] {
            let m = exhaustive_metrics(&PeConfig::new(8, true, Family::Proposed, k));
            assert!(m.nmed >= prev, "k={k}");
            prev = m.nmed;
        }
    }

    #[test]
    fn proposed_matches_paper_table5_scale() {
        // paper signed 8-bit: k=4 -> NMED 0.0004; k=6 -> 0.0022
        let m4 = exhaustive_metrics(&PeConfig::new(8, true, Family::Proposed, 4));
        assert!((0.0002..0.0008).contains(&m4.nmed), "{}", m4.nmed);
        let m6 = exhaustive_metrics(&PeConfig::new(8, true, Family::Proposed, 6));
        assert!((0.0015..0.0030).contains(&m6.nmed), "{}", m6.nmed);
    }

    #[test]
    fn family_ordering_matches_paper_at_k6() {
        // paper Table V (signed, k=6): proposed < [5] < [12] < [6]
        let nmed = |f: Family| {
            exhaustive_metrics(&PeConfig::new(8, true, f, 6)).nmed
        };
        let p = nmed(Family::Proposed);
        let d5 = nmed(Family::Axsa5);
        let d12 = nmed(Family::Sips12);
        let d6 = nmed(Family::Nano6);
        assert!(p < d5, "proposed {p} !< axsa5 {d5}");
        assert!(d5 < d12, "axsa5 {d5} !< sips12 {d12}");
        assert!(d12 < d6, "sips12 {d12} !< nano6 {d6}");
    }

    #[test]
    fn chained_metrics_exact_zero() {
        let cfg = PeConfig::new(8, true, Family::Proposed, 0);
        let m = chained_metrics(&cfg, 16, 200, 11);
        assert_eq!(m.med, 0.0);
    }

    #[test]
    fn chained_error_grows_with_chain() {
        let cfg = PeConfig::new(8, true, Family::Proposed, 6);
        let short = chained_metrics(&cfg, 2, 400, 5).med;
        let long = chained_metrics(&cfg, 32, 400, 5).med;
        assert!(long > short);
    }
}
