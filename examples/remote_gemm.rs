//! Remote GEMM over the framed TCP protocol.
//!
//! Spawns the network server **in-process** on an ephemeral loopback
//! port, connects the `RemoteGemm` client adapter, and runs the
//! quickstart matrices on the exact (`k = 0`) and `k = 4` approximate
//! design points — checking the remote results bit-for-bit against the
//! in-process word model and printing per-request round-trip latency
//! plus the **server-metered** data-dependent energy.
//!
//! ```bash
//! cargo run --release --example remote_gemm
//! ```

use std::sync::Arc;
use std::time::Instant;

use axsys::apps::{Gemm, WordGemm};
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use axsys::net::client::{Client, RemoteGemm};
use axsys::net::server::{NetServer, ServerConfig};
use axsys::pe::word::PeConfig;
use axsys::Family;

fn main() {
    // a serving pool fronted by the TCP server, all in this process
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers: 2,
        backend: BackendKind::Lut,
        ..Default::default()
    }));
    let server = NetServer::bind("127.0.0.1:0", coord, ServerConfig::default())
        .expect("bind loopback");
    let addr = server.local_addr();
    println!("remote_gemm: serving on {addr} (lut backend, 2 workers)");

    // the quickstart operands
    let (m, kk, nn) = (16usize, 8usize, 16usize);
    let a: Vec<i64> = (0..m * kk).map(|i| ((i * 37) % 255) as i64 - 127).collect();
    let b: Vec<i64> = (0..kk * nn).map(|i| ((i * 91) % 255) as i64 - 127).collect();

    for k in [0u32, 4] {
        // RemoteGemm implements the Gemm trait: any pipeline built on it
        // (DCT, edge, BDCN, the differential tests) runs over TCP unchanged
        let mut rg = RemoteGemm::connect(addr, k).expect("connect");
        let t0 = Instant::now();
        let y = rg.gemm(&a, &b, m, kk, nn);
        let us = t0.elapsed().as_secs_f64() * 1e6;
        let cfg = PeConfig::new(8, true, Family::Proposed, k);
        let want = WordGemm { cfg }.gemm(&a, &b, m, kk, nn);
        assert_eq!(y, want, "remote result must be bit-identical to the \
                             in-process word model at k={k}");
        let st = rg.stats().expect("server-reported stats");
        println!("  k={k}: C[0][0..4] = {:?}  round-trip {us:.0} µs, \
                  server-metered {:.5} µJ over {} MACs",
                 &y[..4], st.energy_uj(), st.macs);
    }

    // one stats frame for the fleet view
    let mut c = Client::connect(addr).expect("connect");
    let ws = c.stats().expect("stats frame");
    println!("  server totals: {} pool requests, {:.5} µJ metered \
              ({:.2} fJ/MAC), {} frames in / {} out",
             ws.requests, ws.total_energy_uj(), ws.mean_mac_fj(),
             ws.frames_in, ws.frames_out);
    server.shutdown();
    println!("remote results bit-identical at k = 0 and k = 4");
}
