//! BDCN-lite CNN edge detection (paper §V-B / Fig. 12-13 / Table VI).
//!
//! The hybrid scheme of the paper's Fig. 12: the first two cascade blocks
//! run their convolutions on approximate PEs (level k), the rest exact.
//! All convolutions are lowered to GEMM (shared im2col pass) and served
//! **through the coordinator's worker pool** on the table-driven LUT
//! backend. Demonstrates the paper's core observation — the CNN cascade
//! absorbs arithmetic error far better than the kernel-based detector.
//!
//! Requires `make artifacts` (the CNN is trained at artifact-build time).
//!
//! ```bash
//! cargo run --release --example cnn_edge_pipeline [-- out_dir]
//! ```

use axsys::apps::bdcn;
use axsys::apps::image::{scene, ssim, write_pgm};
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use axsys::runtime::{Runtime, TensorI32};

fn main() -> anyhow::Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "out".into());
    std::fs::create_dir_all(&out)?;
    let dir = Runtime::default_artifacts_dir();
    let blocks = bdcn::load_weights(&dir.join("bdcn_weights.txt"))
        .map_err(|e| anyhow::anyhow!(
            "{e:#}\nrun `make artifacts` first (trains the CNN)"))?;

    let img = scene(128, 128);
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 4,
        backend: BackendKind::Lut,
        ..Default::default()
    });
    let exact = coord.serve_bdcn(&blocks, &img, 0);
    write_pgm(std::path::Path::new(&out).join("bdcn_exact.pgm").as_path(),
              &exact.out)?;

    // kernel-based comparison uses the same image and the same pool
    let lap_exact = coord.serve_edge(&img, 0);

    println!("{:<4} {:>14} {:>9} {:>16} (approx vs exact)", "k",
             "BDCN PSNR(dB)", "SSIM", "kernel PSNR(dB)");
    for k in [2u32, 4, 6, 8] {
        let e = coord.serve_bdcn(&blocks, &img, k);
        let lap = coord.serve_edge(&img, k);
        println!("{:<4} {:>14.2} {:>9.4} {:>16.2}", k,
                 e.psnr_db, ssim(&exact.out.data, &e.out.data), lap.psnr_db);
        write_pgm(std::path::Path::new(&out)
                  .join(format!("bdcn_k{k}.pgm")).as_path(), &e.out)?;
    }
    let s = coord.stats();
    println!("\nservice: {} bdcn + {} edge app requests, {} GEMM \
              sub-requests ({} lut MACs), gemm p99 {:.1} µs",
             s.bdcn.requests, s.edge.requests, s.requests, s.lut_macs,
             s.latency_percentile(0.99));
    println!("(the CNN cascade should stay well above the kernel method at\n\
              every k — the paper's Table VI pattern)");

    // PJRT cross-check: the full quantized CNN lowered from JAX
    // (needs the pjrt feature compiled in)
    if cfg!(feature = "pjrt") && dir.join("bdcn128.hlo.txt").exists() {
        let rt = Runtime::new(&dir)?;
        let outs = rt.run("bdcn128", &[
            TensorI32::new(vec![128, 128], img.to_i32()),
            TensorI32::scalar1(6),
        ])?;
        let got: Vec<u8> = outs[0].data.iter()
            .map(|&v| v.clamp(0, 255) as u8).collect();
        let want = coord.serve_bdcn(&blocks, &img, 6);
        anyhow::ensure!(got == want.out.data,
                        "PJRT bdcn128 must match the served pipeline (k=6)");
        println!("PJRT bdcn128 artifact matches the served pipeline bit-for-bit (k=6)");
    }
    coord.shutdown();
    println!("edge maps written to {out}/");
    Ok(())
}
