//! Quickstart: the three ways to run an approximate GEMM with axsys.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. word-level PE model (fast functional emulation) + the
//!    cache-blocked serving driver (same bits, microkernel speed),
//! 2. cycle-accurate systolic array (the paper's Fig. 1 architecture),
//! 3. the GEMM coordinator (serving layer, worker pool with batched,
//!    coalesced dispatch).
//!
//! If `make artifacts` has been run, it also executes the AOT-compiled
//! Pallas kernel through PJRT and checks all paths agree bit-for-bit.

use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig, GemmRequest};
use axsys::pe::word::{matmul, PeConfig};
use axsys::runtime::{Runtime, TensorI32};
use axsys::systolic::Systolic;
use axsys::Family;

fn main() -> anyhow::Result<()> {
    let (m, kk, nn) = (16usize, 8usize, 16usize);
    let a: Vec<i64> = (0..m * kk).map(|i| ((i * 37) % 255) as i64 - 127).collect();
    let b: Vec<i64> = (0..kk * nn).map(|i| ((i * 91) % 255) as i64 - 127).collect();
    let k_level = 4u32; // approximate the 4 least-significant columns

    // 1. word-level functional model
    let cfg = PeConfig::new(8, true, Family::Proposed, k_level);
    let y_word = matmul(&cfg, &a, &b, m, kk, nn);
    println!("word model:      C[0][0..4] = {:?}", &y_word[..4]);

    // 1b. the cache-blocked serving driver (what the coordinator's
    // workers run): tiling/packing only reorders independent output
    // elements, so the bits cannot change
    let y_blocked = axsys::gemm::matmul(&cfg, &a, &b, m, kk, nn);
    println!("blocked driver:  C[0][0..4] = {:?}", &y_blocked[..4]);
    assert_eq!(y_word, y_blocked, "blocked driver must match bit-for-bit");

    // 2. cycle-accurate systolic array
    let mut sa = Systolic::square(cfg, 8);
    let (y_sa, stats) = sa.gemm(&a, &b, m, kk, nn);
    println!("systolic array:  C[0][0..4] = {:?}  ({} cycles, {} MACs)",
             &y_sa[..4], stats.total_cycles(), stats.macs);
    assert_eq!(y_word, y_sa, "SA must match the word model bit-for-bit");

    // 3. the coordinator (tiling + worker pool + batching)
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        backend: BackendKind::Systolic,
        ..Default::default()
    });
    let resp = coord.call(GemmRequest {
        a: a.clone(), b: b.clone(), m, kk, nn, k: k_level,
    });
    println!("coordinator:     C[0][0..4] = {:?}  ({:.0} µs)",
             &resp.out[..4], resp.latency_us);
    assert_eq!(y_word, resp.out);
    coord.shutdown();

    // 4. AOT Pallas kernel via PJRT (needs `make artifacts` + `--features pjrt`)
    let dir = Runtime::default_artifacts_dir();
    if cfg!(feature = "pjrt") && dir.join("gemm64.hlo.txt").exists() {
        let rt = Runtime::new(&dir)?;
        // gemm64 is 64x64: embed our matrices in a zero-padded 64x64 pair
        let mut a64 = vec![0i32; 64 * 64];
        let mut b64 = vec![0i32; 64 * 64];
        for i in 0..m {
            for t in 0..kk {
                a64[i * 64 + t] = a[i * kk + t] as i32;
            }
        }
        for t in 0..kk {
            for j in 0..nn {
                b64[t * 64 + j] = b[t * nn + j] as i32;
            }
        }
        let outs = rt.run("gemm64", &[
            TensorI32::new(vec![64, 64], a64.clone()),
            TensorI32::new(vec![64, 64], b64.clone()),
            TensorI32::scalar1(k_level as i32),
        ])?;
        // compare like-for-like: zero padding changes the approximate
        // accumulator walk, so run the word model on the padded problem
        let a64_i: Vec<i64> = a64.iter().map(|&v| v as i64).collect();
        let b64_i: Vec<i64> = b64.iter().map(|&v| v as i64).collect();
        let want64 = matmul(&cfg, &a64_i, &b64_i, 64, 64, 64);
        let y_pjrt: Vec<i64> = outs[0].data.iter().map(|&v| v as i64).collect();
        println!("PJRT (Pallas):   C[0][0..4] = {:?}", &y_pjrt[..4]);
        assert_eq!(want64, y_pjrt,
                   "AOT kernel must match the Rust models bit-for-bit");
        println!("\nall four paths agree bit-for-bit at k = {k_level}");
    } else {
        println!("\n(artifacts missing — run `make artifacts` to test the PJRT path)");
    }
    Ok(())
}
