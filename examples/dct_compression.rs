//! DCT image compression (paper §V-A / Fig. 11 / Table VI column "DCT").
//!
//! Runs the 8x8 integer DCT compress->reconstruct pipeline on the 256x256
//! test scene **through the coordinator's serving path**: every GEMM
//! stage is tiled and executed by the worker pool on the cycle-accurate
//! systolic backend (bit-identical to the single-threaded path — see
//! `rust/tests/prop_equiv.rs`). Reports PSNR/SSIM of each approximate
//! reconstruction against the exact design's output (the paper's
//! metric), plus PSNR vs the original, and cross-checks the AOT PJRT
//! artifact when available.
//!
//! ```bash
//! cargo run --release --example dct_compression [-- out_dir]
//! ```

use axsys::apps::dct;
use axsys::apps::image::{psnr, scene, ssim, write_pgm};
use axsys::apps::{CoordinatorGemm, WordGemm};
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use axsys::pe::word::PeConfig;
use axsys::runtime::{Runtime, TensorI32};
use axsys::Family;

fn main() -> anyhow::Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "out".into());
    std::fs::create_dir_all(&out)?;
    let img = scene(256, 256);
    write_pgm(std::path::Path::new(&out).join("dct_input.pgm").as_path(), &img)?;

    let coord = Coordinator::new(CoordinatorConfig {
        workers: 4,
        backend: BackendKind::Systolic,
        ..Default::default()
    });
    let exact = coord.serve_dct(&img, 0);
    println!("exact pipeline vs original: PSNR {:.2} dB (served, {} GEMM \
              sub-requests)", exact.psnr_db, exact.gemm_requests);
    write_pgm(std::path::Path::new(&out).join("dct_exact.pgm").as_path(),
              &exact.out)?;

    println!("\n{:<4} {:>10} {:>8} {:>12}   (approx vs exact — paper Table VI)",
             "k", "PSNR(dB)", "SSIM", "SA cycles");
    for k in [2u32, 4, 6, 8] {
        let mut g = CoordinatorGemm::new(&coord, k);
        let (r, _) = dct::pipeline(&mut g, &img);
        println!("{:<4} {:>10.2} {:>8.4} {:>12}", k,
                 psnr(&exact.out.data, &r.data), ssim(&exact.out.data, &r.data),
                 g.stats.total_cycles());
        write_pgm(std::path::Path::new(&out)
                  .join(format!("dct_k{k}.pgm")).as_path(), &r)?;
    }
    let s = coord.stats();
    println!("\nservice: {} dct app requests, {} GEMM sub-requests, {} tiles, \
              gemm latency p50 {:.1} µs / p99 {:.1} µs",
             s.dct.requests, s.requests, s.tiles,
             s.latency_percentile(0.50), s.latency_percentile(0.99));
    coord.shutdown();

    // cross-check with the AOT artifact (full pipeline lowered from JAX;
    // needs the pjrt feature compiled in)
    let dir = Runtime::default_artifacts_dir();
    if cfg!(feature = "pjrt") && dir.join("dct256.hlo.txt").exists() {
        let rt = Runtime::new(&dir)?;
        let outs = rt.run("dct256", &[
            TensorI32::new(vec![256, 256], img.to_i32()),
            TensorI32::scalar1(2),
        ])?;
        let recon: Vec<u8> = outs[0].data.iter()
            .map(|&v| v.clamp(0, 255) as u8).collect();
        let mut g = WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, 2) };
        let (r2, _) = dct::pipeline(&mut g, &img);
        anyhow::ensure!(recon == r2.data,
                        "PJRT DCT pipeline must match the Rust pipeline");
        println!("\nPJRT dct256 artifact matches the Rust pipeline bit-for-bit (k=2)");
    }
    println!("images written to {out}/");
    Ok(())
}
