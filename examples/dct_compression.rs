//! DCT image compression (paper §V-A / Fig. 11 / Table VI column "DCT").
//!
//! Runs the 8x8 integer DCT compress->reconstruct pipeline on the 256x256
//! test scene through three backends — exact PE, approximate PE at a sweep
//! of k, and the AOT PJRT artifact — reporting PSNR/SSIM of each
//! approximate reconstruction **against the exact design's output**
//! (the paper's metric), plus PSNR vs the original.
//!
//! ```bash
//! cargo run --release --example dct_compression [-- out_dir]
//! ```

use axsys::apps::dct;
use axsys::apps::image::{psnr, scene, ssim, write_pgm};
use axsys::apps::{SystolicGemm, WordGemm};
use axsys::pe::word::PeConfig;
use axsys::runtime::{Runtime, TensorI32};
use axsys::Family;

fn main() -> anyhow::Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "out".into());
    std::fs::create_dir_all(&out)?;
    let img = scene(256, 256);
    write_pgm(std::path::Path::new(&out).join("dct_input.pgm").as_path(), &img)?;

    let mut exact = WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, 0) };
    let (r_exact, _) = dct::pipeline(&mut exact, &img);
    println!("exact pipeline vs original: PSNR {:.2} dB",
             psnr(&img.data, &r_exact.data));
    write_pgm(std::path::Path::new(&out).join("dct_exact.pgm").as_path(),
              &r_exact)?;

    println!("\n{:<4} {:>10} {:>8}   (approx vs exact — paper Table VI)",
             "k", "PSNR(dB)", "SSIM");
    for k in [2u32, 4, 6, 8] {
        let mut g = SystolicGemm::new(
            PeConfig::new(8, true, Family::Proposed, k), 8);
        let (r, _) = dct::pipeline(&mut g, &img);
        println!("{:<4} {:>10.2} {:>8.4}", k,
                 psnr(&r_exact.data, &r.data), ssim(&r_exact.data, &r.data));
        write_pgm(std::path::Path::new(&out)
                  .join(format!("dct_k{k}.pgm")).as_path(), &r)?;
    }

    // cross-check with the AOT artifact (full pipeline lowered from JAX;
    // needs the pjrt feature compiled in)
    let dir = Runtime::default_artifacts_dir();
    if cfg!(feature = "pjrt") && dir.join("dct256.hlo.txt").exists() {
        let rt = Runtime::new(&dir)?;
        let outs = rt.run("dct256", &[
            TensorI32::new(vec![256, 256], img.to_i32()),
            TensorI32::scalar1(2),
        ])?;
        let recon: Vec<u8> = outs[0].data.iter()
            .map(|&v| v.clamp(0, 255) as u8).collect();
        let mut g = WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, 2) };
        let (r2, _) = dct::pipeline(&mut g, &img);
        anyhow::ensure!(recon == r2.data,
                        "PJRT DCT pipeline must match the Rust pipeline");
        println!("\nPJRT dct256 artifact matches the Rust pipeline bit-for-bit (k=2)");
    }
    println!("images written to {out}/");
    Ok(())
}
