//! End-to-end serving driver (the repo's E2E validation workload).
//!
//! Drives the GEMM coordinator with a closed-loop synthetic client fleet:
//! mixed-size matmul requests at several approximation levels, executed
//! on a chosen backend (word / lut / systolic / pjrt), reporting
//! throughput, latency percentiles, product-LUT cache activity and — for
//! the cycle-accurate backend — simulated cycles and the hardware model's
//! energy estimate for both the exact and the approximate configuration
//! (the paper's headline energy story).
//!
//! ```bash
//! cargo run --release --example serve_gemm -- [requests] [workers] [backend]
//! ```

use std::time::Instant;

use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig, GemmRequest};
use axsys::hw::sa_metrics;
use axsys::pe::{Design, Signedness};
use axsys::Family;

struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

fn run_fleet(backend: BackendKind, workers: usize, requests: usize, k: u32)
             -> (f64, Vec<f64>, axsys::coordinator::ServiceStats) {
    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        backend,
        ..Default::default()
    });
    let mut rng = Lcg(0xDECAF + k as u64);
    let t0 = Instant::now();
    let mut ids = Vec::with_capacity(requests);
    for _ in 0..requests {
        let m = 8 + (rng.next() % 56) as usize;
        let kk = 8 + (rng.next() % 24) as usize;
        let nn = 8 + (rng.next() % 56) as usize;
        let a: Vec<i64> = (0..m * kk)
            .map(|_| (rng.next() as i64 & 255) - 128).collect();
        let b: Vec<i64> = (0..kk * nn)
            .map(|_| (rng.next() as i64 & 255) - 128).collect();
        ids.push(coord.submit(GemmRequest { a, b, m, kk, nn, k }));
    }
    let mut lats: Vec<f64> = ids.into_iter()
        .map(|id| coord.wait(id).latency_us).collect();
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = coord.stats_snapshot();
    coord.shutdown();
    (wall, lats, stats)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(128);
    let workers: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(4);
    let backend = match args.get(2) {
        Some(v) => match BackendKind::parse(v) {
            Some(b) => b,
            None => {
                eprintln!("unknown backend '{v}' (expected {})",
                          BackendKind::names());
                std::process::exit(2);
            }
        },
        None => BackendKind::Systolic,
    };
    let k = 7u32;
    println!("serve_gemm: {requests} requests, {workers} workers, {backend:?}, k={k}");

    let (wall, lats, stats) = run_fleet(backend, workers, requests, k);
    let pct = |p: f64| lats[(p * (lats.len() - 1) as f64) as usize];
    println!("  wall {:.3}s -> {:.1} req/s, {:.1} tiles/s", wall,
             requests as f64 / wall, stats.tiles as f64 / wall);
    println!("  latency µs: p50 {:.0}  p90 {:.0}  p99 {:.0}  max {:.0}",
             pct(0.50), pct(0.90), pct(0.99), stats.max_latency_us);
    if stats.lut_macs > 0 {
        println!("  lut: {} MACs table-served, {} tables built, {} cache hits",
                 stats.lut_macs, stats.lut_builds, stats.lut_cache_hits);
    }

    if stats.metered_macs > 0 {
        // calibrated, data-dependent energy from the per-MAC model (the
        // meters every backend carries; see rust/src/energy)
        println!("  metered energy: {:.3} µJ total, {:.2} fJ/MAC over {} MACs",
                 stats.total_energy_uj(), stats.mean_mac_fj(),
                 stats.metered_macs);
    }
    if stats.sim_cycles > 0 {
        // the random-activity hardware-model estimate, for contrast with
        // the metered number above (paper's energy story: same workload,
        // exact vs approximate SA)
        let exact = Design::proposed_exact(8, Signedness::Signed);
        let conv = Design::conventional_exact(8, Signedness::Signed);
        let apx = Design::approximate(8, Signedness::Signed, Family::Proposed, k);
        let cyc = stats.sim_cycles as f64;
        let uj = |d: &Design| cyc * 4.0 * sa_metrics(d, 8).power_uw * 1e-9;
        let (e6, ep, ea) = (uj(&conv), uj(&exact), uj(&apx));
        println!("  simulated {} cycles / {} MACs on the 8x8 SA", stats.sim_cycles,
                 stats.sim_macs);
        println!("  random-activity estimate @250MHz: exact[6] {:.2} µJ | \
                  proposed exact {:.2} µJ (-{:.1}%) | proposed approx \
                  {:.2} µJ (-{:.1}%)",
                 e6, ep, (1.0 - ep / e6) * 100.0, ea, (1.0 - ea / e6) * 100.0);
    }
}
