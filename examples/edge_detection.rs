//! Laplacian-kernel edge detection (paper §V-B / Fig. 13 top row).
//!
//! Sweeps the approximation factor k and reports PSNR/SSIM of each
//! approximate edge map against the exact design's output, on both the
//! word-level backend and the cycle-accurate systolic array (with cycle
//! and energy accounting from the hardware model).
//!
//! ```bash
//! cargo run --release --example edge_detection [-- out_dir]
//! ```

use axsys::apps::edge;
use axsys::apps::image::{psnr, scene, ssim, write_pgm};
use axsys::apps::{Gemm, SystolicGemm, WordGemm};
use axsys::hw::sa_metrics;
use axsys::pe::word::PeConfig;
use axsys::pe::{Design, Signedness};
use axsys::Family;

fn main() -> anyhow::Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "out".into());
    std::fs::create_dir_all(&out)?;
    let img = scene(256, 256);

    let mut g_exact = WordGemm { cfg: PeConfig::new(8, true, Family::Proposed, 0) };
    let e_exact = edge::pipeline(&mut g_exact, &img);
    write_pgm(std::path::Path::new(&out).join("edge_exact.pgm").as_path(),
              &e_exact)?;

    println!("{:<4} {:>10} {:>8} {:>12} {:>14}", "k", "PSNR(dB)", "SSIM",
             "SA cycles", "energy est.");
    for k in [2u32, 4, 6, 8] {
        let cfg = PeConfig::new(8, true, Family::Proposed, k);
        let mut g = SystolicGemm::new(cfg, 8);
        let e = edge::pipeline(&mut g, &img);
        let st = g.stats().unwrap();
        // energy estimate: simulated cycles x SA power @ 250 MHz
        let d = Design::approximate(8, Signedness::Signed, Family::Proposed, k);
        let m = sa_metrics(&d, 8);
        let energy_uj = st.total_cycles() as f64 * 4.0 * m.power_uw * 1e-9;
        println!("{:<4} {:>10.2} {:>8.4} {:>12} {:>11.2} µJ", k,
                 psnr(&e_exact.data, &e.data), ssim(&e_exact.data, &e.data),
                 st.total_cycles(), energy_uj);
        write_pgm(std::path::Path::new(&out)
                  .join(format!("edge_k{k}.pgm")).as_path(), &e)?;
    }

    // exact-vs-exact sanity: the paper's metric peaks at identity
    let e_again = edge::pipeline(&mut g_exact, &img);
    assert!(psnr(&e_exact.data, &e_again.data).is_infinite());
    println!("\nedge maps written to {out}/");
    Ok(())
}
