//! Laplacian-kernel edge detection (paper §V-B / Fig. 13 top row).
//!
//! The 3x3 stencil is lowered to one `(P, 9) @ (9, 1)` GEMM by the
//! shared im2col pass and served **through the coordinator**: the
//! cycle-accurate systolic backend executes the tiles, so each sweep
//! point also reports simulated cycles and the hardware model's energy
//! estimate. Sweeps the approximation factor k and reports PSNR/SSIM of
//! each approximate edge map against the exact design's output.
//!
//! ```bash
//! cargo run --release --example edge_detection [-- out_dir]
//! ```

use axsys::apps::edge;
use axsys::apps::image::{psnr, scene, ssim, write_pgm};
use axsys::apps::CoordinatorGemm;
use axsys::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use axsys::hw::sa_metrics;
use axsys::pe::{Design, Signedness};
use axsys::Family;

fn main() -> anyhow::Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "out".into());
    std::fs::create_dir_all(&out)?;
    let img = scene(256, 256);

    let coord = Coordinator::new(CoordinatorConfig {
        workers: 4,
        backend: BackendKind::Systolic,
        ..Default::default()
    });
    let mut g_exact = CoordinatorGemm::new(&coord, 0);
    let e_exact = edge::pipeline(&mut g_exact, &img);
    write_pgm(std::path::Path::new(&out).join("edge_exact.pgm").as_path(),
              &e_exact)?;

    println!("{:<4} {:>10} {:>8} {:>12} {:>14}", "k", "PSNR(dB)", "SSIM",
             "SA cycles", "energy est.");
    for k in [2u32, 4, 6, 8] {
        let mut g = CoordinatorGemm::new(&coord, k);
        let e = edge::pipeline(&mut g, &img);
        let st = g.stats;
        // energy estimate: simulated cycles x SA power @ 250 MHz
        let d = Design::approximate(8, Signedness::Signed, Family::Proposed, k);
        let m = sa_metrics(&d, 8);
        let energy_uj = st.total_cycles() as f64 * 4.0 * m.power_uw * 1e-9;
        println!("{:<4} {:>10.2} {:>8.4} {:>12} {:>11.2} µJ", k,
                 psnr(&e_exact.data, &e.data), ssim(&e_exact.data, &e.data),
                 st.total_cycles(), energy_uj);
        write_pgm(std::path::Path::new(&out)
                  .join(format!("edge_k{k}.pgm")).as_path(), &e)?;
    }

    // the same sweep point through the app endpoint: quality comes back
    // precomputed (approx vs served-exact), with per-app stats
    let resp = coord.serve_edge(&img, 4);
    println!("\nserve_edge(k=4): PSNR {:.2} dB, {} GEMM sub-requests, \
              latency {:.0} µs",
             resp.psnr_db, resp.gemm_requests, resp.latency_us);
    let s = coord.stats();
    println!("service: {} edge app requests, gemm latency p50 {:.1} µs / \
              p99 {:.1} µs", s.edge.requests,
             s.latency_percentile(0.50), s.latency_percentile(0.99));

    // exact-vs-exact sanity: the paper's metric peaks at identity
    let e_again = edge::pipeline(&mut g_exact, &img);
    assert!(psnr(&e_exact.data, &e_again.data).is_infinite());
    coord.shutdown();
    println!("\nedge maps written to {out}/");
    Ok(())
}
